"""Coalesced Tsetlin Machine (CoTM) — functional JAX implementation.

The CoTM [Glimsdal & Granmo, arXiv:2108.07594] shares one pool of ``n``
clauses across ``m`` classes through a signed integer weight matrix
``W (m, n)``.  Each clause is a conjunction over ``K`` Boolean literals
selected by Tsetlin Automata (TA).

The computational identities used throughout this repo (and mirrored by the
IMPACT crossbars) are:

    include_kj = ta_state_kj > n_states            # TA action
    viol_bj    = sum_k (1 - L_bk) * include_kj     # "interaction current"
    clause_bj  = (viol_bj == 0)                    # CSA threshold
    scores_bi  = sum_j W_ij * clause_bj            # class crossbar column sum
    pred_b     = argmax_i scores_bi

``viol`` is exactly the clause-column current of the paper's clause crossbar
(each (literal=0, include) pair contributes ~5uA; the CSA fires "0" above
4.1uA, i.e. whenever at least one violation exists), and ``scores`` is the
class-crossbar column current.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CoTMConfig:
    n_literals: int          # K (features *including* negations)
    n_clauses: int           # n
    n_classes: int           # m
    n_states: int = 128      # N: per-action state count (states span [1, 2N])
    threshold: int = 32      # T: vote clamp used by training feedback
    specificity: float = 5.0  # s
    boost_true_positive: bool = True

    def init(self, key: Array) -> "CoTMParams":
        kt, _ = jax.random.split(key)
        # TAs start uniformly at the exclude/include boundary (N or N+1).
        ta = jnp.asarray(
            self.n_states
            + jax.random.bernoulli(kt, 0.5, (self.n_literals, self.n_clauses)),
            jnp.int32,
        )
        w = jnp.zeros((self.n_classes, self.n_clauses), jnp.int32)
        return CoTMParams(ta_state=ta, weights=w)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CoTMParams:
    ta_state: Array   # (K, n) int32 in [1, 2N]
    weights: Array    # (m, n) int32 signed


# ---------------------------------------------------------------------------
# Inference
# ---------------------------------------------------------------------------

def include_mask(ta_state: Array, n_states: int) -> Array:
    """TA action: include iff the state sits in the upper half."""
    return ta_state > n_states


def violation_counts(literals: Array, include: Array) -> Array:
    """Per-clause count of (literal==0, include) pairs: the crossbar current.

    literals: (..., K) bool / {0,1};  include: (K, n) bool.
    Returns (..., n) int32.
    """
    not_l = (1 - literals.astype(jnp.int8))
    return jax.lax.dot_general(
        not_l, include.astype(jnp.int8),
        (((not_l.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def clause_outputs(literals: Array, include: Array, *, training: bool = False) -> Array:
    """Boolean clause outputs (..., n).

    During inference, "empty" clauses (no include) are forced to 0 so that
    untrained clauses do not vote; during training they output 1 (standard TM
    semantics so fresh clauses can capture patterns).
    """
    viol = violation_counts(literals, include)
    fired = viol == 0
    if not training:
        nonempty = include.any(axis=0)
        fired = jnp.logical_and(fired, nonempty)
    return fired


def class_scores(clauses: Array, weights: Array) -> Array:
    """Weighted votes: (..., n) x (m, n) -> (..., m) int32."""
    c = clauses.astype(jnp.int8)
    return jax.lax.dot_general(
        c, weights.astype(jnp.int32),
        (((c.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def forward(params: CoTMParams, literals: Array, cfg: CoTMConfig,
            *, training: bool = False) -> tuple[Array, Array]:
    """Returns (clauses (..., n) bool, scores (..., m) int32)."""
    inc = include_mask(params.ta_state, cfg.n_states)
    clauses = clause_outputs(literals, inc, training=training)
    return clauses, class_scores(clauses, params.weights)


@partial(jax.jit, static_argnames=("cfg",))
def predict(params: CoTMParams, literals: Array, cfg: CoTMConfig) -> Array:
    _, scores = forward(params, literals, cfg)
    return jnp.argmax(scores, axis=-1)


def to_unipolar(weights: Array) -> tuple[Array, Array]:
    """Paper's signed->unsigned shift: W' = W + |W_min| (argmax preserving).

    Returns (unipolar weights, the scalar shift that was added).
    """
    shift = jnp.maximum(-jnp.min(weights), 0)
    return weights + shift, shift
