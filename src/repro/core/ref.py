"""Pure-numpy oracle for CoTM inference — the ground truth for tests.

Deliberately written in the most literal transliteration of the paper's
equations (loops where that is clearest) so the vectorized JAX / Pallas
implementations have an independent reference.
"""
from __future__ import annotations

import numpy as np


def clause_outputs_ref(literals: np.ndarray, include: np.ndarray,
                       training: bool = False) -> np.ndarray:
    """literals (B, K) {0,1}; include (K, n) {0,1} -> clauses (B, n) {0,1}.

    C_j = AND_i (L_i OR NOT include_i); empty clauses output `training`.
    """
    B, K = literals.shape
    K2, n = include.shape
    assert K == K2
    out = np.zeros((B, n), dtype=bool)
    nonempty = include.any(axis=0)
    for b in range(B):
        for j in range(n):
            ok = True
            for i in range(K):
                if include[i, j] and not literals[b, i]:
                    ok = False
                    break
            out[b, j] = ok and (training or nonempty[j])
    return out


def violation_counts_ref(literals: np.ndarray, include: np.ndarray) -> np.ndarray:
    """The clause-crossbar column 'current': count of (L=0, include) pairs."""
    return (1 - literals.astype(np.int64)) @ include.astype(np.int64)


def class_scores_ref(clauses: np.ndarray, weights: np.ndarray) -> np.ndarray:
    """clauses (B, n), weights (m, n) -> (B, m)."""
    return clauses.astype(np.int64) @ weights.astype(np.int64).T


def predict_ref(literals: np.ndarray, include: np.ndarray,
                weights: np.ndarray) -> np.ndarray:
    c = clause_outputs_ref(literals, include)
    return class_scores_ref(c, weights).argmax(axis=-1)
