from .booleanize import booleanize, n_literals, with_negations
from .cotm import (CoTMConfig, CoTMParams, class_scores, clause_outputs,
                   forward, include_mask, predict, to_unipolar,
                   violation_counts)
from .train import train_epochs, train_step_batch, train_step_sequential

__all__ = [
    "CoTMConfig", "CoTMParams", "booleanize", "n_literals", "with_negations",
    "class_scores", "clause_outputs", "forward", "include_mask", "predict",
    "to_unipolar", "violation_counts", "train_epochs", "train_step_batch",
    "train_step_sequential",
]
