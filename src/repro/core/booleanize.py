"""Booleanization: raw features -> Boolean literals (original + negated).

The paper's data-preparation step: each feature is threshold-encoded into one
or more bits; every bit is paired with its negation, so K = 2 * n_bits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def threshold_bits(x: Array, thresholds: Array) -> Array:
    """x: (..., F) -> bits (..., F * len(thresholds)) via x > t (thermometer)."""
    bits = x[..., None] > thresholds  # (..., F, T)
    return bits.reshape(*x.shape[:-1], -1)


def thermometer_thresholds(n_bits: int, lo: float = 0.0, hi: float = 1.0) -> Array:
    """Evenly spaced thresholds strictly inside (lo, hi)."""
    return lo + (hi - lo) * (jnp.arange(1, n_bits + 1) / (n_bits + 1))


def with_negations(bits: Array) -> Array:
    """bits (..., B) -> literals (..., 2B): [bits, ~bits]."""
    return jnp.concatenate([bits, ~bits], axis=-1)


def booleanize(x: Array, *, n_bits: int = 1, lo: float = 0.0,
               hi: float = 1.0) -> Array:
    """Full pipeline: threshold-encode then append negations.

    x (..., F) -> literals (..., 2 * F * n_bits) bool.
    """
    t = thermometer_thresholds(n_bits, lo, hi)
    return with_negations(threshold_bits(x, t))


def n_literals(n_features: int, n_bits: int = 1) -> int:
    return 2 * n_features * n_bits
