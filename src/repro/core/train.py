"""CoTM training: coalesced clause pool + signed weights, Type I/II feedback.

Follows Glimsdal & Granmo (arXiv:2108.07594): per sample, the true class is
reinforced with polarity c=+1 and one uniformly sampled negative class with
polarity c=-1.  For a class update with polarity ``c``:

    v   = clamp(scores[class], -T, T)
    p   = (T - c*v) / (2T)                      # per-clause update probability
    for each clause j drawn with prob p:
        if sign(W[class, j]) == c:  Type I feedback (pattern reinforcement)
        else:                       Type II feedback (pattern invalidation)
        if clause_j fired:          W[class, j] += c

Type I  (recognise): a fired clause strengthens includes of present literals
        (prob 1 with boost, else (s-1)/s) and weakens includes of absent
        literals (prob 1/s); a non-fired clause weakens all (prob 1/s).
Type II (reject): a fired clause pushes excluded TAs of absent literals one
        step toward include (prob 1), eventually breaking the clause.

Two execution modes:

* ``train_step_sequential`` — faithful per-sample scan (the reference
  semantics; used by fidelity tests).
* ``train_step_batch`` — the production/distributed mode.  The batch sum of
  TA deltas factors into THREE (K,B)x(B,n) integer matmuls once the 1/s
  Bernoulli thinning field is shared across the batch (mean-preserving; the
  thinning then gates *accumulated* event counts instead of single events):

      present = litT   @ (type1 & fired)          # reward counts
      absent  = ~litT  @ (type1 & fired)          # penalty counts
      inval   = ~litT  @ (type2 & fired)          # Type II counts
      ta_delta = hi*present - lo*(absent + decay) + excluded*inval

  This runs on the MXU, needs no (B,K,n) intermediates, and shards over the
  batch axis with a single psum — it is the formulation lowered in the
  multi-pod dry-run.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .cotm import (CoTMConfig, CoTMParams, class_scores, clause_outputs,
                   include_mask)

Array = jax.Array


def _int_matmul(a: Array, b: Array) -> Array:
    return jax.lax.dot_general(a, b, (((a.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def batch_deltas(params: CoTMParams, literals: Array, labels: Array,
                 key: Array, cfg: CoTMConfig) -> tuple[Array, Array]:
    """Summed (ta_delta (K,n), w_delta (m,n)) for a batch — matmul form."""
    B, K = literals.shape
    n = cfg.n_clauses
    m = cfg.n_classes
    T = cfg.threshold

    inc = include_mask(params.ta_state, cfg.n_states)
    fired = clause_outputs(literals, inc, training=True)        # (B, n)
    scores = class_scores(fired, params.weights)                # (B, m)

    k_neg, k_sel, k_hi, k_lo = jax.random.split(key, 4)
    neg = (labels + jax.random.randint(k_neg, (B,), 1, m)) % m
    tgt = jnp.concatenate([labels, neg])                        # (2B,)
    pol = jnp.concatenate([jnp.ones(B, jnp.int32),
                           -jnp.ones(B, jnp.int32)])            # (2B,)

    rows = jnp.arange(B)
    v = jnp.concatenate([scores[rows, labels], scores[rows, neg]])
    v = jnp.clip(v, -T, T)
    p = (T - pol * v).astype(jnp.float32) / (2 * T)             # (2B,)
    sel = jax.random.bernoulli(k_sel, p[:, None], (2 * B, n))   # (2B, n)

    w_rows = params.weights[tgt]                                # (2B, n)
    sign = jnp.where(w_rows >= 0, 1, -1)
    match = sign == pol[:, None]
    fired2 = jnp.concatenate([fired, fired])                    # (2B, n)

    t1f = (sel & match & fired2).astype(jnp.int8)               # (2B, n)
    t1nf = (sel & match & ~fired2)                              # (2B, n)
    t2f = (sel & ~match & fired2).astype(jnp.int8)              # (2B, n)

    lit_t = literals.astype(jnp.int8).T                         # (K, B)
    lit2_t = jnp.concatenate([lit_t, lit_t], axis=1)            # (K, 2B)
    not_lit2_t = (1 - lit2_t)

    present = _int_matmul(lit2_t, t1f)                          # (K, n)
    absent = _int_matmul(not_lit2_t, t1f)                       # (K, n)
    inval = _int_matmul(not_lit2_t, t2f)                        # (K, n)
    decay = t1nf.sum(0, dtype=jnp.int32)[None, :]               # (1, n)

    s = cfg.specificity
    hi = (jnp.ones((K, n), jnp.int32) if cfg.boost_true_positive
          else jax.random.bernoulli(k_hi, (s - 1.0) / s, (K, n)).astype(jnp.int32))
    lo = jax.random.bernoulli(k_lo, 1.0 / s, (K, n)).astype(jnp.int32)
    excl = (~inc).astype(jnp.int32)

    ta_delta = hi * present - lo * (absent + decay) + excl * inval

    # Weight deltas: scatter-add per-class rows == one-hot matmul (MXU).
    onehot = jax.nn.one_hot(tgt, m, dtype=jnp.int8).T           # (m, 2B)
    w_upd = (pol[:, None] * (sel & fired2)).astype(jnp.int8)    # (2B, n)
    w_delta = _int_matmul(onehot, w_upd)                        # (m, n)
    return ta_delta, w_delta


def apply_deltas(params: CoTMParams, ta_delta: Array, w_delta: Array,
                 cfg: CoTMConfig) -> CoTMParams:
    ta = jnp.clip(params.ta_state + ta_delta, 1, 2 * cfg.n_states)
    return CoTMParams(ta_state=ta, weights=params.weights + w_delta)


@partial(jax.jit, static_argnames=("cfg",))
def train_step_batch(params: CoTMParams, literals: Array, labels: Array,
                     key: Array, cfg: CoTMConfig) -> CoTMParams:
    ta_d, w_d = batch_deltas(params, literals, labels, key, cfg)
    return apply_deltas(params, ta_d, w_d, cfg)


# ---------------------------------------------------------------------------
# Faithful per-sample reference semantics
# ---------------------------------------------------------------------------

def _sample_deltas(params: CoTMParams, literals: Array, label: Array,
                   key: Array, cfg: CoTMConfig) -> tuple[Array, Array]:
    """Per-sample deltas (batch of 1) via the same matmul machinery."""
    ta_d, w_d = batch_deltas(params, literals[None, :], label[None],
                             key, cfg)
    return ta_d, w_d


@partial(jax.jit, static_argnames=("cfg",))
def train_step_sequential(params: CoTMParams, literals: Array, labels: Array,
                          key: Array, cfg: CoTMConfig) -> CoTMParams:
    """Faithful per-sample sequential updates (fori_loop over the batch)."""
    B = literals.shape[0]
    keys = jax.random.split(key, B)

    def body(i, p):
        ta_d, w_d = _sample_deltas(p, literals[i], labels[i], keys[i], cfg)
        return apply_deltas(p, ta_d, w_d, cfg)

    return jax.lax.fori_loop(0, B, body, params)


def train_epochs(params: CoTMParams, literals: Array, labels: Array,
                 key: Array, cfg: CoTMConfig, *, epochs: int = 1,
                 batch_size: int = 32, sequential: bool = False,
                 ) -> CoTMParams:
    """Simple host-side training loop (shuffles once per epoch)."""
    n = literals.shape[0]
    n_batches = n // batch_size
    step = train_step_sequential if sequential else train_step_batch
    for _ in range(epochs):
        key, k_shuf, k_ep = jax.random.split(key, 3)
        perm = jax.random.permutation(k_shuf, n)
        lit = literals[perm][: n_batches * batch_size]
        lab = labels[perm][: n_batches * batch_size]
        lit = lit.reshape(n_batches, batch_size, -1)
        lab = lab.reshape(n_batches, batch_size)
        for b in range(n_batches):
            params = step(params, lit[b], lab[b],
                          jax.random.fold_in(k_ep, b), cfg)
    return params
