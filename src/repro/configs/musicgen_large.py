"""musicgen-large [audio] — 48L d=2048 32H (kv=32) d_ff=8192 vocab=2048,
decoder-only over EnCodec tokens (4 codebooks).  [arXiv:2306.05284; hf]

EnCodec frontend is a STUB: inputs are the 4 parallel token streams
(B, S, 4); embeddings summed, one LM head per codebook.  Adaptation noted
in DESIGN.md: learned positional embeddings replaced by RoPE.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio", modality="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, act="gelu", mlp_gated=False, norm="layer",
    rope_theta=10_000.0, n_codebooks=4,
)
