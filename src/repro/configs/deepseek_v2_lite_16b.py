"""deepseek-v2-lite-16b [moe] — 27L d=2048 16H, MLA kv_lora=512,
d_ff_expert=1408, vocab 102400, MoE 2 shared + 64 routed top-6, first layer
dense (d_ff 10944).  [arXiv:2405.04434; hf]
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, act="silu",
    rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  first_dense_layers=1, d_ff_dense=10944),
)
