"""zamba2-7b [hybrid] — 81L d=3584 Mamba2 (state=64) + ONE shared
attention block (32H kv=32, d_ff=14336) every 6 layers, vocab 32000.
[arXiv:2411.15242; unverified]

Runs the long_500k cell (Mamba2 state + ring-buffer shared attention; see
DESIGN.md adaptations).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, act="gelu",
    rope_theta=10_000.0,
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2,
                  n_groups=2, conv_width=4, chunk=16),
    hybrid_attn_every=6,
)
