"""starcoder2-3b [dense] — 30L d=3072 24H (GQA kv=2) d_ff=12288
vocab=49152, RoPE, plain-GELU MLP, LayerNorm.  [arXiv:2402.19173; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, head_dim=128,
    d_ff=12288, vocab=49152, act="gelu", mlp_gated=False, norm="layer",
    rope_theta=100_000.0,
)
