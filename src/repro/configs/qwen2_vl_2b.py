"""qwen2-vl-2b [vlm] — 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

Modality frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed patch embeddings (B, S_img, d); M-RoPE positions (3, B, S).
"""
from repro.models.config import ModelConfig

VISION_TOKENS = 256   # stub: 16x16 patch grid per image

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm", modality="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936, act="silu",
    rope_theta=1_000_000.0, rope_style="mrope", mrope_sections=(16, 24, 24),
)
