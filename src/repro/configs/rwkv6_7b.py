"""rwkv6-7b [ssm] — Finch: 32L d=4096 (attn-free, data-dependent decay)
d_ff=14336 vocab=65536.  [arXiv:2404.05892; hf]

Runs the long_500k cell (O(1) recurrent state per token).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
    d_ff=14336, vocab=65536, act="relu2", rope_style="none",
    ssm=SSMConfig(kind="rwkv6", state_dim=64, head_dim=64, chunk=16,
                  decay_lora=64),
)
