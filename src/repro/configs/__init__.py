"""Architecture registry: the 10 assigned configs + the paper's own CoTM.

``get_config(name)`` returns the exact published ModelConfig;
``cells(name)`` returns the assigned (shape -> applicable) map — long_500k
runs only for the sub-quadratic families (ssm / hybrid), per the brief.
"""
from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeSpec

ARCH_IDS = [
    "grok-1-314b",
    "deepseek-v2-lite-16b",
    "qwen2-vl-2b",
    "musicgen-large",
    "llama3-8b",
    "qwen3-8b",
    "gemma-7b",
    "starcoder2-3b",
    "rwkv6-7b",
    "zamba2-7b",
]

_MODULES = {a: a.replace("-", "_") for a in ARCH_IDS}

# long_500k needs sub-quadratic attention: run for ssm/hybrid only
# (skip recorded per-cell in EXPERIMENTS.md §Dry-run).
LONG_CONTEXT_ARCHS = {"rwkv6-7b", "zamba2-7b"}


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def cells(name: str) -> dict[str, bool]:
    """shape name -> applicable? for this arch (40 assigned cells total:
    32 runnable + 8 recorded long_500k skips)."""
    return {shape: (shape != "long_500k" or name in LONG_CONTEXT_ARCHS)
            for shape in SHAPES}


def all_cells() -> list[tuple[str, str, bool]]:
    return [(arch, shape, ok)
            for arch in ARCH_IDS
            for shape, ok in cells(arch).items()]


__all__ = ["ARCH_IDS", "LONG_CONTEXT_ARCHS", "get_config", "cells",
           "all_cells", "SHAPES", "ShapeSpec"]
