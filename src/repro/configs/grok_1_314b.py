"""grok-1-314b [moe] — 64L d=6144 48H (GQA kv=8) d_ff=32768, MoE 8e top-2,
vocab 131072.  [hf:xai-org/grok-1; unverified]

Memory posture: the only arch that needs full ZeRO-3 (params sharded over
data too) and bf16 Adam moments to fit one 256-chip v5e pod.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab=131072, act="gelu",
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768),
    zero3=True, opt_moment_dtype="bfloat16", grad_accum_dtype="bfloat16",
)
