"""Training substrate: optimizer, step, checkpointing, compression, FT."""
from .checkpoint import CheckpointManager
from .compression import compressed_grad_allreduce, int8_psum
from .online import OnlineTrainer
from .optimizer import AdamWConfig, TrainState, apply_updates, init_state
from .runtime import RuntimeConfig, SimulatedFailure, TrainLoop
from .step import cast_tree, make_train_step

__all__ = [
    "AdamWConfig", "TrainState", "apply_updates", "init_state",
    "make_train_step", "cast_tree", "CheckpointManager",
    "compressed_grad_allreduce", "int8_psum", "RuntimeConfig",
    "SimulatedFailure", "TrainLoop", "OnlineTrainer",
]
