"""Gradient compression: int8 ring all-reduce with error feedback.

For data-parallel traffic on slow inter-pod links, gradients are exchanged
as int8 with a shared per-tensor scale.  The all-reduce is decomposed so
the WIRE format is int8 in both phases (the accumulation happens locally
in int32):

    1. shared scale     = pmax(max|v|) / 127
    2. reduce-scatter   : all_to_all of the int8 shards; each device sums
                          its shard in int32 and REQUANTIZES to int8
                          (second scale = pmax of shard maxima)
    3. all-gather       : int8 shards gathered, dequantized once

Error feedback (Seide et al. / 1-bit SGD lineage): each device carries the
quantization residual ``e`` and adds it to the next step's gradient, so
the compression bias cancels over steps instead of accumulating — the
property test in ``tests/test_compression.py`` checks exactly this.

Wire bytes: 1/4 of f32 (plus two scalar scales), at <1% relative error per
step on typical gradient distributions.  Used by the shard_map-based DP
trainer in ``examples/train_lm.py --compress-grads``; the GSPMD paths keep
XLA's native collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import compat

Array = jax.Array


def _quantize(v: Array, scale: Array) -> Array:
    q = jnp.round(v / jnp.maximum(scale, 1e-30))
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def int8_psum(v: Array, axis_name: str) -> Array:
    """All-reduce ``v`` over ``axis_name`` with int8 wire format.

    Must be called inside shard_map/pmap with ``axis_name`` bound.
    The leading dimension of the flattened tensor is padded to the axis
    size for the all_to_all phase.
    """
    n = compat.axis_size(axis_name)
    shape = v.shape
    flat = v.reshape(-1)
    pad = (-flat.size) % n
    flat = jnp.pad(flat, (0, pad))

    # Phase 1: shared input scale.
    scale1 = jax.lax.pmax(jnp.max(jnp.abs(flat)), axis_name) / 127.0
    q = _quantize(flat, scale1).reshape(n, -1)

    # Phase 2: reduce-scatter via all_to_all (int8 on the wire).
    shards = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                                tiled=False)            # (n, chunk) int8
    local_sum = shards.astype(jnp.int32).sum(axis=0)    # my shard, int32
    local_f = local_sum.astype(jnp.float32) * scale1

    # Phase 3: requantize + all-gather (int8 on the wire).
    scale2 = jax.lax.pmax(jnp.max(jnp.abs(local_f)), axis_name) / 127.0
    q2 = _quantize(local_f, scale2)
    gathered = jax.lax.all_gather(q2, axis_name)        # (n, chunk) int8
    out = gathered.astype(jnp.float32).reshape(-1) * scale2
    return out[:flat.size - pad if pad else None][:v.size].reshape(shape)


def compressed_grad_allreduce(grads, errors, axis_name: str):
    """Error-feedback wrapper: returns (summed grads, new error state)."""
    def one(g, e):
        v = g.astype(jnp.float32) + e
        total = int8_psum(v, axis_name)
        # Residual = what this device meant to send minus what survived
        # phase-1 quantization (the part it can still correct next step).
        e_new = v - _roundtrip(v, axis_name)
        return total, e_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def _roundtrip(v: Array, axis_name: str) -> Array:
    """This device's contribution as it survives quantization (phase-1
    quantize/dequantize) — the error-feedback residual reference."""
    flat = v.reshape(-1)
    scale1 = jax.lax.pmax(jnp.max(jnp.abs(flat)), axis_name) / 127.0
    q = _quantize(flat, scale1)
    return (q.astype(jnp.float32) * scale1).reshape(v.shape)
