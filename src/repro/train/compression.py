"""Model + gradient compression.

Two independent compressors live here:

* **Clause pruning** (:func:`prune_clauses`): a post-training pass over a
  programmed ``IMPACTSystem`` that (a) retires clause columns that never
  fire on a calibration batch — their cells stop drawing leakage current
  every sweep — and (b) merges duplicate clause columns (identical at
  the ternary device abstraction) by summing their class-crossbar rows,
  exact for ideal systems because the class read is linear in the drive.
  The returned :class:`PruneStats` re-anchors the paper's Table 4 energy
  per *effective* clause.  Pairs with ``RuntimeSpec(packing="2bit")``:
  pruning shrinks the live column population, packing shrinks the bytes
  per column.

* **Gradient compression** (below): int8 ring all-reduce with error
  feedback for data-parallel training traffic.

For data-parallel traffic on slow inter-pod links, gradients are exchanged
as int8 with a shared per-tensor scale.  The all-reduce is decomposed so
the WIRE format is int8 in both phases (the accumulation happens locally
in int32):

    1. shared scale     = pmax(max|v|) / 127
    2. reduce-scatter   : all_to_all of the int8 shards; each device sums
                          its shard in int32 and REQUANTIZES to int8
                          (second scale = pmax of shard maxima)
    3. all-gather       : int8 shards gathered, dequantized once

Error feedback (Seide et al. / 1-bit SGD lineage): each device carries the
quantization residual ``e`` and adds it to the next step's gradient, so
the compression bias cancels over steps instead of accumulating — the
property test in ``tests/test_compression.py`` checks exactly this.

Wire bytes: 1/4 of f32 (plus two scalar scales), at <1% relative error per
step on typical gradient distributions.  Used by the shard_map-based DP
trainer in ``examples/train_lm.py --compress-grads``; the GSPMD paths keep
XLA's native collectives.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .. import compat

Array = jax.Array


# -- clause pruning ----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PruneStats:
    """What a :func:`prune_clauses` pass removed, and the re-anchored
    Table 4 energy figure.

    ``n_effective`` is the count of clause columns that still draw
    meaningful current after pruning — the denominator the paper's
    per-clause energy story should use once dead columns stop burning
    leakage every sweep.  ``energy_per_effective_clause_j`` is the
    pruned system's read energy per calibration datapoint per effective
    clause (``impact.energy.energy_per_effective_clause``).
    """
    n_clauses: int
    n_effective: int
    n_never_fired: int
    n_duplicates: int
    calibration_batch: int
    energy_per_effective_clause_j: float


def _g_from_current(i: np.ndarray, *, v_read: float, nonlin: float,
                    cutoff: float) -> np.ndarray:
    """Exact inverse of ``yflash.read_current`` (piecewise linear): the
    conductance that reads back as current ``i``."""
    return np.where(i >= cutoff * v_read, i / v_read, i / (v_read * nonlin))


def prune_clauses(system, literals, *, merge_duplicates: bool = True):
    """Prune a programmed ``IMPACTSystem`` against a calibration batch.

    Two reductions, both physical erase operations on the clause
    crossbar (a retired column's cells go to 0 S and its ``nonempty``
    bit clears, so it neither fires nor draws leakage):

    1. **Never-fired columns**: clauses that fire on no calibration
       datapoint.  Exact on the calibration batch (a clause that never
       fires contributes nothing to any class current there); on other
       inputs this is the usual calibration-pruning bet.
    2. **Duplicate columns** (``merge_duplicates=True``): columns with
       identical ternary code patterns (``kernels.packing``
       classification) compute the same clause function, so all but the
       first are erased and their class-crossbar rows are summed into
       the survivor's row — EXACT for ideal (variability-free) systems
       because the class read is linear in the drive; under device
       variability the merged column's quantized current is the class
       mean (same contract as ``packing="2bit"``).

    Returns ``(pruned_system, PruneStats)``.  The pruned system is a new
    ``IMPACTSystem`` (same geometry — tiles are not re-packed, columns
    are erased in place) whose ``encode_stats`` carries the pruning
    record; compile it with ``RuntimeSpec(packing="2bit")`` to stack
    both compressions.
    """
    from ..impact import yflash
    from ..kernels import packing, ref

    lits = jnp.asarray(literals)
    B = int(lits.shape[0])
    R, C, tr, tc = system.clause_i.shape
    S, sr, M = system.class_i.shape
    n_pad = C * tc
    nonempty = np.asarray(system._nonempty_eff()).astype(bool)

    fired, _ = ref.impact_clause_bits_ref(
        lits, system.clause_i, system._nonempty_eff(),
        thresh=yflash.I_CSA_THRESHOLD)
    ever = np.asarray(fired).any(axis=0)
    alive = nonempty & ever
    n_never = int((nonempty & ~ever).sum())

    clause_i = np.asarray(system.clause_i, np.float32).copy()
    clause_g = np.asarray(system.clause_g, np.float32).copy()
    class_i = np.asarray(system.class_i, np.float32).copy()
    class_g = np.asarray(system.class_g, np.float32).copy()
    # Flat views: clause column j lives at tile (j // tc, j % tc) and
    # class-crossbar flat row j (n_clauses <= S*sr by construction).
    cls_i_flat = class_i.reshape(S * sr, M)

    n_dup = 0
    if merge_duplicates:
        flat_ci = clause_i.transpose(0, 2, 1, 3).reshape(R * tr, n_pad)
        codes = np.asarray(packing.classify_currents(jnp.asarray(flat_ci)))
        keep_of: dict[bytes, int] = {}
        for j in np.flatnonzero(alive):
            key = codes[:, j].tobytes()
            keep = keep_of.setdefault(key, int(j))
            if keep != j:
                cls_i_flat[keep] += cls_i_flat[j]
                cls_i_flat[j] = 0.0
                alive[j] = False
                n_dup += 1
        class_g = _g_from_current(
            class_i, v_read=yflash.V_READ, nonlin=yflash.LCS_NONLINEARITY,
            cutoff=yflash.G_NONLIN_CUTOFF).astype(np.float32)

    # Erase every retired column: cells to 0 S / 0 A, nonempty cleared.
    dead = nonempty & ~alive
    col_mask = (~dead).reshape(C, tc)[None, :, None, :]
    clause_i *= col_mask
    clause_g *= col_mask
    new_nonempty = np.asarray(system.nonempty).astype(bool) & ~dead

    pruned = dataclasses.replace(
        system,
        clause_g=jnp.asarray(clause_g), clause_i=jnp.asarray(clause_i),
        class_g=jnp.asarray(class_g), class_i=jnp.asarray(class_i),
        nonempty=jnp.asarray(new_nonempty))

    n_eff = int(alive.sum())
    from ..impact import energy as energy_mod
    _, i_cl, i_cs = ref.fused_impact_metered_ref(
        lits, pruned.clause_i, pruned._nonempty_eff(), pruned.class_i,
        thresh=yflash.I_CSA_THRESHOLD)
    read_j = float(yflash.V_READ * yflash.T_READ
                   * (np.asarray(i_cl).sum() + np.asarray(i_cs).sum()))
    stats = PruneStats(
        n_clauses=int(system.n_clauses), n_effective=n_eff,
        n_never_fired=n_never, n_duplicates=n_dup, calibration_batch=B,
        energy_per_effective_clause_j=energy_mod.energy_per_effective_clause(
            read_j, B, n_eff))
    pruned.encode_stats = dict(system.encode_stats,
                               pruning=dataclasses.asdict(stats))
    return pruned, stats


def _quantize(v: Array, scale: Array) -> Array:
    q = jnp.round(v / jnp.maximum(scale, 1e-30))
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def int8_psum(v: Array, axis_name: str) -> Array:
    """All-reduce ``v`` over ``axis_name`` with int8 wire format.

    Must be called inside shard_map/pmap with ``axis_name`` bound.
    The leading dimension of the flattened tensor is padded to the axis
    size for the all_to_all phase.
    """
    n = compat.axis_size(axis_name)
    shape = v.shape
    flat = v.reshape(-1)
    pad = (-flat.size) % n
    flat = jnp.pad(flat, (0, pad))

    # Phase 1: shared input scale.
    scale1 = jax.lax.pmax(jnp.max(jnp.abs(flat)), axis_name) / 127.0
    q = _quantize(flat, scale1).reshape(n, -1)

    # Phase 2: reduce-scatter via all_to_all (int8 on the wire).
    shards = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                                tiled=False)            # (n, chunk) int8
    local_sum = shards.astype(jnp.int32).sum(axis=0)    # my shard, int32
    local_f = local_sum.astype(jnp.float32) * scale1

    # Phase 3: requantize + all-gather (int8 on the wire).
    scale2 = jax.lax.pmax(jnp.max(jnp.abs(local_f)), axis_name) / 127.0
    q2 = _quantize(local_f, scale2)
    gathered = jax.lax.all_gather(q2, axis_name)        # (n, chunk) int8
    out = gathered.astype(jnp.float32).reshape(-1) * scale2
    return out[:flat.size - pad if pad else None][:v.size].reshape(shape)


def compressed_grad_allreduce(grads, errors, axis_name: str):
    """Error-feedback wrapper: returns (summed grads, new error state)."""
    def one(g, e):
        v = g.astype(jnp.float32) + e
        total = int8_psum(v, axis_name)
        # Residual = what this device meant to send minus what survived
        # phase-1 quantization (the part it can still correct next step).
        e_new = v - _roundtrip(v, axis_name)
        return total, e_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def _roundtrip(v: Array, axis_name: str) -> Array:
    """This device's contribution as it survives quantization (phase-1
    quantize/dequantize) — the error-feedback residual reference."""
    flat = v.reshape(-1)
    scale1 = jax.lax.pmax(jnp.max(jnp.abs(flat)), axis_name) / 127.0
    q = _quantize(flat, scale1)
    return (q.astype(jnp.float32) * scale1).reshape(v.shape)
