"""Online in-memory TA training under live traffic (arXiv:2408.09456).

The companion paper to IMPACT performs Tsetlin-automata *updates* in the
same Y-Flash array inference reads from; IMBUE (arXiv:2305.12914) carries
the feedback on the same Boolean-to-current datapath.  ``OnlineTrainer``
reproduces that loop on an already-deployed ``IMPACTSystem``:

1. **Feedback sweep (analog read).**  Clause outputs come off the clause
   crossbar (the CSA datapath, training semantics: empty clauses fire),
   class votes off the digital weight copy — the hybrid analog-clause /
   digital-vote split of the companion paper's feedback controller.
2. **TA transitions (compiled kernel).**  The Type I/II delta matmuls run
   through the session's registered ``ta_feedback`` primitive (Pallas
   kernel or einsum oracle — bit-identical by the parity contract).
3. **In-array write-back (pulse trains).**  Only TAs whose *action*
   flipped touch the array: ``pulse_until`` drives exactly those cells
   across the Boolean HCS/LCS boundary with ``program_pulse``/
   ``erase_pulse`` trains, under the same D2D/C2C variability model the
   read path uses (per-device tau/asymptote spread sampled once per
   grid, per-pulse log-normal C2C noise).  Changed weight cells re-tune
   the class tile within the paper's fine-tune tolerance band.
4. **Billing.**  Write energy comes from the ACTUAL pulse counts via
   ``encode_energy`` into the ``write_energy_j`` lane of the standard
   ``EnergyReport`` — so an interleaved train+serve run aggregates
   training joules and serving joules through one meter stack, and a
   zero-flip update bills exactly 0.0 J (no pulses, no energy).

The write-back mutates the ``IMPACTSystem`` arrays in place and refreshes
every compiled ``InferenceSession`` cached on it: operand shapes never
change, so serving sessions pick up the new conductances WITHOUT a
retrace — updates and requests interleave through the same engine seam.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cotm import CoTMConfig, CoTMParams, class_scores, include_mask
from ..core.train import _int_matmul, apply_deltas
from ..impact import tiles as tiles_mod
from ..impact import yflash
from ..impact.energy import EnergyReport, encode_energy
from ..impact.tiles import weight_targets
from ..impact.yflash import (DeviceVariation, G_HCS_BOOL, G_LCS,
                             I_CSA_THRESHOLD, read_current)
from ..kernels import backends as backends_mod
from ..kernels import packing as packing_mod
from ..kernels.ref import pad_to

Array = jax.Array


class OnlineTrainer:
    """Interleaved in-array CoTM training on a deployed ``IMPACTSystem``.

    ``session`` must be a plain (non-co-resident, unpacked) compiled
    session of the system being trained; its backend lowers the
    ``ta_feedback`` primitive and its spec's interpret policy applies.
    ``params`` are the digital TA/weight copies the deployed system was
    encoded from (the feedback controller state).  ``variability=False``
    gives the ideal-device twin: no D2D spread, no C2C write noise.
    """

    def __init__(self, session, params: CoTMParams, cfg: CoTMConfig, *,
                 key: Array, pulse_width: float = 1e-3,
                 class_pulse_width: float = 50e-6,
                 weight_tol_segments: float = 5.0, max_pulses: int = 64,
                 variability: bool = True, trace=None):
        if session.spec.coresident is not None:
            raise ValueError(
                "OnlineTrainer needs a single-tenant session — training "
                "writes re-program the shared fabric under a co-resident "
                "plan's feet (train the member system, then rebalance)")
        if session.spec.packing == "2bit":
            raise ValueError(
                "OnlineTrainer needs an unpacked session — the write path "
                "targets the f32 conductance grid (packed serving "
                "sessions cached on the same system are re-packed after "
                "every update)")
        self.session = session
        self.system = session.system
        self.params = params
        self.cfg = cfg
        self.pulse_width = float(pulse_width)
        self.class_pulse_width = float(class_pulse_width)
        self.max_pulses = int(max_pulses)
        self.variability = bool(variability)
        self.trace = trace

        sys_ = self.system
        R, C, tr, tc = sys_.clause_i.shape
        S, sr, m = sys_.class_i.shape
        # The weight->conductance map is FROZEN at encode time: the same
        # unipolar shift and segment scale the class tile was programmed
        # with.  Weights running past the encoded range saturate at the
        # band edges (a physical conductance range, not an error).
        self._shift = int(sys_.encode_stats["weight_shift"])
        self._w_max = max(int(sys_.encode_stats["weights"]["w_max"]), 1)
        seg = (yflash.G_RANGE_HI - yflash.G_RANGE_LO) / self._w_max
        self._w_tol = float(weight_tol_segments) * seg
        self._w_uni_pad = self._unipolar_padded(params.weights)

        # D2D variability is a property of the physical cells: sampled
        # ONCE per grid here and reused by every write sweep (the read
        # path's spread is already baked into the encoded conductances).
        k_cl, k_cls, self._key = jax.random.split(key, 3)
        if self.variability:
            self._clause_var = DeviceVariation.sample(k_cl, (R * tr, C * tc))
            self._class_var = DeviceVariation.sample(k_cls, (S * sr, m))
        else:
            self._clause_var = DeviceVariation.none((R * tr, C * tc))
            self._class_var = DeviceVariation.none((S * sr, m))

        #: f64 running meter: every update's write bill accumulates here;
        #: the per-update ``records`` entries must sum to it exactly.
        self.write_energy_j: float = 0.0
        self.records: list[dict[str, Any]] = []
        self.reports: list[EnergyReport] = []
        self._step = 0

    # -- helpers ------------------------------------------------------------
    def _unipolar_padded(self, weights: Array) -> Array:
        S, sr, m = self.system.class_i.shape
        w_uni = jnp.clip(weights + self._shift, 0, self._w_max)
        return pad_to(w_uni.T.astype(jnp.int32), S * sr, 0)       # (S*sr, m)

    def _refresh_sessions(self) -> None:
        """Propagate the mutated grid into every compiled session.  The
        operand arrays are re-read per call (same shapes — no retrace),
        but the nonempty mask and any compile-time packed operand are
        cached on the session and must be refreshed by hand."""
        sys_ = self.system
        sessions = list(sys_.__dict__.get("_sessions", {}).values())
        if self.session not in sessions:
            sessions.append(self.session)
        for sess in sessions:
            sess._nonempty = sys_._nonempty_eff()
            if sess._packed is not None:
                sess._packed = packing_mod.pack_clause_operand(sys_.clause_i)

    def evaluate(self, literals: Array, labels: Array) -> float:
        """Held-out accuracy through the ANALOG serving path (the same
        compiled ``predict`` executable live traffic rides)."""
        preds = np.asarray(self.session.predict(literals).predictions)
        return float((preds == np.asarray(labels)).mean())

    # -- one update sweep ---------------------------------------------------
    def update(self, literals: Array, labels: Array,
               key: Array | None = None) -> dict[str, Any]:
        """One batched Type I/II update: analog feedback sweep, compiled
        ``ta_feedback`` deltas, in-array pulse-train write-back.  Returns
        the per-update billing/convergence record (also appended to
        ``records``; a matching ``EnergyReport`` with this update's
        ``write_energy_j`` is appended to ``reports``)."""
        t0 = self.trace.clock() if self.trace is not None else 0.0
        if key is None:
            self._key, key = jax.random.split(self._key)
        cfg = self.cfg
        sys_ = self.system
        B, K = literals.shape
        n, m, T = cfg.n_clauses, cfg.n_classes, cfg.threshold

        # 1. Analog feedback sweep: clause bits off the crossbar with
        # TRAINING semantics (the all-ones mask lets empty clauses fire,
        # exactly ``clause_outputs(..., training=True)``); votes off the
        # digital weight copy.
        lit = jnp.asarray(literals, jnp.int8)
        inc = include_mask(self.params.ta_state, cfg.n_states)
        fired, i_col = backends_mod.get_backend(
            self.session.spec.backend).impact_clause_bits(
                lit, sys_.clause_i, jnp.ones_like(sys_.nonempty),
                thresh=I_CSA_THRESHOLD,
                interpret=self.session.spec.interpret)
        fired = fired[:, :n]
        scores = class_scores(fired, self.params.weights)

        # 2. Feedback masks (identical construction to
        # ``core.train.batch_deltas``) + the compiled delta primitive.
        k_neg, k_sel, k_hi, k_lo, k_wc, k_ww = jax.random.split(key, 6)
        labels = jnp.asarray(labels, jnp.int32)
        neg = (labels + jax.random.randint(k_neg, (B,), 1, m)) % m
        tgt = jnp.concatenate([labels, neg])                      # (2B,)
        pol = jnp.concatenate([jnp.ones(B, jnp.int32),
                               -jnp.ones(B, jnp.int32)])
        rows = jnp.arange(B)
        v = jnp.clip(jnp.concatenate([scores[rows, labels],
                                      scores[rows, neg]]), -T, T)
        p = (T - pol * v).astype(jnp.float32) / (2 * T)
        sel = jax.random.bernoulli(k_sel, p[:, None], (2 * B, n))
        sign = jnp.where(self.params.weights[tgt] >= 0, 1, -1)
        match = sign == pol[:, None]
        fired2 = jnp.concatenate([fired, fired])                  # (2B, n)
        lit2 = jnp.concatenate([lit, lit], axis=0)                # (2B, K)
        s = cfg.specificity
        hi = (jnp.ones((K, n), jnp.int32) if cfg.boost_true_positive
              else jax.random.bernoulli(
                  k_hi, (s - 1.0) / s, (K, n)).astype(jnp.int32))
        lo = jax.random.bernoulli(k_lo, 1.0 / s,
                                  (K, n)).astype(jnp.int32)
        ta_delta = self.session.ta_feedback(lit2, fired2, sel, match,
                                            hi, lo, inc)
        onehot = jax.nn.one_hot(tgt, m, dtype=jnp.int8).T
        w_upd = (pol[:, None] * (sel & fired2)).astype(jnp.int8)
        w_delta = _int_matmul(onehot, w_upd)
        new_params = apply_deltas(self.params, ta_delta, w_delta, cfg)

        # 3. Write-back: only ACTION flips touch the clause array.
        R, C, tr, tc = sys_.clause_i.shape
        S, sr, _ = sys_.class_i.shape
        inc_new = include_mask(new_params.ta_state, cfg.n_states)
        flip = pad_to(pad_to(inc_new != inc, R * tr, 0), C * tc, 1)
        inc_pad = pad_to(pad_to(inc_new, R * tr, 0), C * tc, 1)
        g_cl = sys_.clause_g.transpose(0, 2, 1, 3).reshape(R * tr, C * tc)
        # Untouched cells get the trivial band [0, inf): zero pulses by
        # construction, so an update with no flips bills exactly 0.0 J.
        tlo = jnp.where(flip & inc_pad, G_HCS_BOOL, 0.0)
        thi = jnp.where(flip, jnp.where(inc_pad, jnp.inf, G_LCS), jnp.inf)
        g_cl, np_cl, ne_cl = yflash.pulse_until(
            g_cl, target_lo=tlo, target_hi=thi,
            width_prog=self.pulse_width, width_erase=self.pulse_width,
            var=self._clause_var, key=k_wc, max_pulses=self.max_pulses,
            c2c=self.variability)
        unconv = tiles_mod.n_unconverged(g_cl, tlo, thi)

        # Changed weight cells re-tune within the fine-tune band.
        w_uni_new = self._unipolar_padded(new_params.weights)
        changed = w_uni_new != self._w_uni_pad
        target = weight_targets(w_uni_new, self._w_max)
        wlo = jnp.where(changed, target - self._w_tol, 0.0)
        whi = jnp.where(changed, target + self._w_tol, jnp.inf)
        g_cls = sys_.class_g.reshape(S * sr, m)
        g_cls, np_w, ne_w = yflash.pulse_until(
            g_cls, target_lo=wlo, target_hi=whi,
            width_prog=self.class_pulse_width,
            width_erase=self.class_pulse_width,
            var=self._class_var, key=k_ww, max_pulses=self.max_pulses,
            c2c=self.variability)
        unconv += tiles_mod.n_unconverged(g_cls, wlo, whi)

        # 4. Bill the ACTUAL pulses (f64 host-side, like every meter).
        e_p_cl, e_e_cl = encode_energy(np_cl, ne_cl, self.pulse_width,
                                       self.pulse_width)
        e_p_w, e_e_w = encode_energy(np_w, ne_w, self.class_pulse_width,
                                     self.class_pulse_width)
        e_write = float(e_p_cl + e_e_cl + e_p_w + e_e_w)
        # The feedback sweep's clause read bills like any serving read.
        e_read = float(yflash.V_READ * np.float64(np.asarray(i_col).sum())
                       * yflash.T_READ)

        # 5. Mutate the system in place + refresh every cached session.
        sys_.clause_g = g_cl.reshape(R, tr, C, tc).transpose(0, 2, 1, 3)
        sys_.clause_i = read_current(sys_.clause_g)
        sys_.class_g = g_cls.reshape(S, sr, m)
        sys_.class_i = read_current(sys_.class_g)
        sys_.nonempty = pad_to(inc_new.any(axis=0), C * tc, 0)
        self._refresh_sessions()
        self.params = new_params
        self._w_uni_pad = w_uni_new

        record = dict(
            step=self._step,
            write_energy_j=e_write,
            read_energy_j=e_read,
            prog_pulses=int(np_cl.sum()) + int(np_w.sum()),
            erase_pulses=int(ne_cl.sum()) + int(ne_w.sum()),
            n_unconverged=int(unconv),
            n_flips=int(jnp.sum(inc_new != inc)),
            n_weight_cells=int(changed.sum()),
        )
        self.records.append(record)
        self.write_energy_j += e_write
        self.reports.append(EnergyReport(
            read_energy_j=e_read, clause_energy_j=e_read,
            class_energy_j=0.0,
            program_energy_j=sys_.encode_stats["program_energy_j"],
            erase_energy_j=sys_.encode_stats["erase_energy_j"],
            latency_s=sys_._grid_latency(), ops_crosspoint=B * K * n,
            datapoints=B, write_energy_j=e_write))
        self._step += 1
        if self.trace is not None:
            self.trace.span("train_update", t0, self.trace.clock(),
                            args=dict(step=record["step"],
                                      write_energy_j=e_write,
                                      n_flips=record["n_flips"],
                                      n_unconverged=record["n_unconverged"]))
        return record
