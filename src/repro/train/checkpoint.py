"""Sharded checkpointing with atomic publish, async writes, and elastic
restore.

Layout:  <dir>/step_<N>/
            manifest.json       tree structure, shapes, dtypes, step
            <leaf_id>.npy       one file per leaf (full logical array)
         <dir>/LATEST           text file naming the newest valid step

Fault-tolerance properties:

* **atomic publish** — writes go to ``step_<N>.tmp`` and are renamed into
  place only after every leaf and the manifest are fsynced; a crash
  mid-save can never corrupt the latest checkpoint;
* **async** — ``save(..., blocking=False)`` snapshots to host memory and
  writes on a daemon thread; the next save joins the previous one;
* **elastic restore** — leaves are stored as full logical arrays, so a
  checkpoint written on one mesh restores onto ANY mesh/topology: restore
  takes the target shardings and ``jax.device_put``s each leaf (this is
  the single-controller equivalent of shard-file re-chunking; a multi-host
  deployment would key files by shard index and reassemble — same
  manifest schema, noted here for the 1000-node posture);
* **self-validating** — ``latest_step`` skips unreadable/partial steps.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    ids = ["leaf_" + "".join(
        str(jax.tree_util.keystr((k,))) for k in path).replace("'", "")
        .replace("[", "_").replace("]", "").replace(".", "_")
        for path, _ in flat]
    return ids, [leaf for _, leaf in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: PyTree, *, blocking: bool = True):
        """Snapshot to host and persist; returns immediately if async."""
        self.wait()
        ids, leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        manifest = {
            "step": int(step),
            # Restore is template-driven; the manifest records the leaf
            # inventory for validation and external tooling.
            "treedef": str(jax.tree_util.tree_structure(tree)),
            "leaves": [{"id": i, "shape": list(a.shape),
                        "dtype": str(a.dtype)}
                       for i, a in zip(ids, host_leaves)],
        }

        def write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, a in zip(ids, host_leaves):
                np.save(tmp / f"{i}.npy", a)
            with open(tmp / "manifest.json", "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            latest = self.dir / "LATEST"
            with open(latest, "w") as f:
                f.write(str(step))
                f.flush()
                os.fsync(f.fileno())
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, *, step: int | None = None,
                shardings: PyTree | None = None) -> tuple[PyTree, int]:
        """Restore into the structure of ``template``; ``shardings`` (same
        structure, NamedSharding or None leaves) places each leaf on the
        CURRENT mesh — elastic across topologies."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        ids, leaves, treedef = _flatten(template)
        assert len(ids) == len(manifest["leaves"]), "tree structure changed"
        sh_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None)
            if shardings is not None else [None] * len(ids))
        out = []
        for i, (leaf_id, sh) in enumerate(zip(ids, sh_leaves)):
            arr = np.load(d / f"{leaf_id}.npy")
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step
