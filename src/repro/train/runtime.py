"""Fault-tolerant training runtime: auto-resume, heartbeats, stragglers.

``TrainLoop`` wraps a jitted train step with the operational machinery a
1000-node deployment needs from the controller side:

* **auto-resume** — on start, restore the latest valid checkpoint (atomic
  manifests mean a mid-save crash rolls back to the previous step);
* **periodic async checkpointing** — snapshot every ``save_every`` steps
  off the critical path;
* **straggler mitigation** — every step is timed against a deadline
  derived from a running median (``deadline_factor`` x median); breaches
  increment a counter and invoke ``on_straggler`` (in a real cluster this
  hook triggers hot-spare swap / topology rebalance; here it logs and, if
  breaches persist, forces a checkpoint so the job can be rescheduled);
* **failure injection** — ``fail_at_step`` raises mid-run; the restart
  test proves the loop resumes bit-exact from the last checkpoint;
* **heartbeat file** — liveness signal for an external watchdog.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import statistics
import time
from typing import Any, Callable, Iterator

import jax

from .checkpoint import CheckpointManager

PyTree = Any


@dataclasses.dataclass
class RuntimeConfig:
    ckpt_dir: str
    max_steps: int = 100
    save_every: int = 20
    keep: int = 3
    deadline_factor: float = 3.0
    straggler_patience: int = 3
    heartbeat_every: int = 10
    fail_at_step: int | None = None      # test hook


class SimulatedFailure(RuntimeError):
    pass


class TrainLoop:
    def __init__(self, train_step: Callable, state: PyTree,
                 data_iter: Iterator[dict], cfg: RuntimeConfig, *,
                 state_shardings: PyTree | None = None,
                 on_straggler: Callable[[int, float], None] | None = None):
        self.train_step = train_step
        self.state = state
        self.data_iter = data_iter
        self.cfg = cfg
        self.mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.state_shardings = state_shardings
        self.on_straggler = on_straggler
        self.step_times: list[float] = []
        self.straggler_events = 0
        self.metrics_log: list[dict] = []

    # -- resume ------------------------------------------------------------
    def maybe_resume(self) -> int:
        latest = self.mgr.latest_step()
        if latest is None:
            return 0
        self.state, step = self.mgr.restore(
            self.state, shardings=self.state_shardings)
        return step

    def _heartbeat(self, step: int):
        hb = pathlib.Path(self.cfg.ckpt_dir) / "HEARTBEAT"
        hb.write_text(json.dumps({"step": step, "t": time.time()}))

    # -- main loop -----------------------------------------------------------
    def run(self, seed: int = 0) -> PyTree:
        start = self.maybe_resume()
        consecutive_slow = 0
        for step in range(start, self.cfg.max_steps):
            if self.cfg.fail_at_step is not None \
                    and step == self.cfg.fail_at_step:
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = next(self.data_iter)
            t0 = time.time()
            self.state, metrics = self.train_step(
                self.state, batch, seed + step)
            jax.block_until_ready(jax.tree.leaves(self.state)[0])
            dt = time.time() - t0
            self.step_times.append(dt)
            self.metrics_log.append(
                {k: float(v) for k, v in metrics.items()})

            # Straggler detection against the running median.
            if len(self.step_times) >= 5:
                med = statistics.median(self.step_times[-20:])
                if dt > self.cfg.deadline_factor * max(med, 1e-6):
                    self.straggler_events += 1
                    consecutive_slow += 1
                    if self.on_straggler:
                        self.on_straggler(step, dt)
                    if consecutive_slow >= self.cfg.straggler_patience:
                        # Persistent slowdown: checkpoint so the scheduler
                        # can migrate the job.
                        self.mgr.save(step + 1, self.state, blocking=False)
                        consecutive_slow = 0
                else:
                    consecutive_slow = 0

            if (step + 1) % self.cfg.save_every == 0:
                self.mgr.save(step + 1, self.state, blocking=False)
            if (step + 1) % self.cfg.heartbeat_every == 0:
                self._heartbeat(step + 1)
        self.mgr.save(self.cfg.max_steps, self.state, blocking=True)
        return self.state
