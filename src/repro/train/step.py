"""Training step: bf16 compute over f32 master, grad accumulation, ZeRO.

``make_train_step(model, opt_cfg, grad_shardings)`` returns a function

    train_step(state, batch, seed) -> (state, metrics)

* the batch carries a leading gradient-accumulation axis; microbatches are
  consumed by a ``lax.scan`` so activation memory is bounded by one
  microbatch regardless of the global batch;
* master params are f32; each microbatch casts to the model's compute
  dtype (bf16) INSIDE the grad function, so gradients accumulate in f32
  with the cast folded into the backward pass;
* the f32 gradient accumulator is sharding-constrained to the optimizer
  (ZeRO) layout, so GSPMD reduce-scatters each microbatch's gradients
  instead of all-reducing them and the accumulator occupies 1/|data| of
  each parameter — required for grok-314b to fit.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, TrainState, apply_updates

Array = jax.Array


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, tree)


def make_train_step(model, opt_cfg: AdamWConfig,
                    grad_shardings=None) -> Callable:
    cfg = model.cfg

    def loss_fn(master_params, microbatch):
        params = cast_tree(master_params, cfg.dtype)
        loss, metrics = model.loss(params, microbatch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain_grads(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(
            lambda g, s: g if s is None
            else jax.lax.with_sharding_constraint(g, s),
            grads, grad_shardings)

    accum_dtype = jnp.dtype(getattr(cfg, "grad_accum_dtype", "float32"))

    def train_step(state: TrainState, batch: dict, seed: Array):
        accum = jax.tree.leaves(batch)[0].shape[0]

        def micro(carry, microbatch):
            g_acc, l_acc = carry
            (loss, _), grads = grad_fn(state.params, microbatch)
            grads = constrain_grads(grads)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(accum_dtype), g_acc, grads)
            return (g_acc, l_acc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                          state.params)
        g0 = constrain_grads(g0)
        (grads, loss_sum), _ = jax.lax.scan(micro, (g0, jnp.zeros(())),
                                            batch)
        grads = jax.tree.map(lambda g: g / accum, grads)
        new_state, opt_metrics = apply_updates(state, grads, opt_cfg)
        metrics = {"loss": loss_sum / accum, **opt_metrics}
        return new_state, metrics

    return train_step
