"""AdamW with mixed-precision master weights and dtype-configurable moments.

Implemented from scratch (no optax in this container).  The TrainState
holds f32 master params plus first/second moments whose dtype is set per
architecture (bf16 for grok-314b — the only way 3x314B optimizer tensors
fit one 256-chip v5e pod; see DESIGN.md memory posture table).

Sharding: the launcher places ``state.params`` with the param rule table
(ZeRO-3 for grok) and the moments with the ZeRO opt table — GSPMD then
reduce-scatters gradients into the shard and all-gathers updated params,
i.e. textbook ZeRO-1/3 without hand-written collectives.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    moment_dtype: Any = jnp.float32

    def schedule(self, step: Array) -> Array:
        """Linear warmup -> constant (cosine handled by the launcher)."""
        warm = jnp.minimum(step.astype(jnp.float32) / max(self.warmup_steps,
                                                          1), 1.0)
        return self.lr * warm


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: Array          # () int32
    params: PyTree       # f32 master
    m: PyTree            # first moment (moment_dtype)
    v: PyTree            # second moment (moment_dtype)


def init_state(params: PyTree, cfg: AdamWConfig) -> TrainState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree: PyTree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(state: TrainState, grads: PyTree,
                  cfg: AdamWConfig) -> tuple[TrainState, dict]:
    """One AdamW step; returns (new state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = cfg.schedule(step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        p_new = p - lr * (update + cfg.weight_decay * p)
        return p_new, m32.astype(cfg.moment_dtype), v32.astype(
            cfg.moment_dtype)

    flat_p, treedef = jax.tree.flatten(state.params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return (TrainState(step=step, params=new_p, m=new_m, v=new_v),
            {"grad_norm": gnorm, "lr": lr})
