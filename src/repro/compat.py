"""jax version shims shared across the package.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed ``check_rep`` to ``check_vma``; route through whichever this
jax build provides so call sites can use the modern spelling.
"""
from __future__ import annotations

import jax

try:
    _impl = jax.shard_map
    _REP_KW = "check_vma"
except AttributeError:                       # older jax: experimental home
    from jax.experimental.shard_map import shard_map as _impl
    _REP_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: bool | None = None, **kw):
    if check_vma is not None:
        kw[_REP_KW] = check_vma
    return _impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def axis_size(axis_name) -> jax.Array:
    """``jax.lax.axis_size`` fallback: psum of 1 over the bound axis."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


__all__ = ["shard_map", "axis_size"]
