"""Synthetic datasets (offline container: no real MNIST/CIFAR available).

Two generators:

* ``digits``: procedural 28x28 digit glyphs (5x7 font, upscaled, jittered,
  noised) — the MNIST stand-in used by the quickstart and the accuracy
  benchmarks.  Same booleanized dimensionality as the paper (K = 2*28*28).
* ``prototype``: per-class random Boolean prototypes + bit-flip noise, with
  configurable (#classes, #features) — used to instantiate Table 5's seven
  datasets at their published literal/clause/class dimensions.
"""
from __future__ import annotations

import numpy as np

_FONT = {
    0: [".###.", "#...#", "#..##", "#.#.#", "##..#", "#...#", ".###."],
    1: ["..#..", ".##..", "..#..", "..#..", "..#..", "..#..", ".###."],
    2: [".###.", "#...#", "....#", "...#.", "..#..", ".#...", "#####"],
    3: [".###.", "#...#", "....#", "..##.", "....#", "#...#", ".###."],
    4: ["...#.", "..##.", ".#.#.", "#..#.", "#####", "...#.", "...#."],
    5: ["#####", "#....", "####.", "....#", "....#", "#...#", ".###."],
    6: ["..##.", ".#...", "#....", "####.", "#...#", "#...#", ".###."],
    7: ["#####", "....#", "...#.", "..#..", ".#...", ".#...", ".#..."],
    8: [".###.", "#...#", "#...#", ".###.", "#...#", "#...#", ".###."],
    9: [".###.", "#...#", "#...#", ".####", "....#", "...#.", ".##.."],
}


def _glyph(digit: int) -> np.ndarray:
    rows = _FONT[digit]
    g = np.array([[c == "#" for c in r] for r in rows], dtype=np.float32)
    # Upscale 5x7 -> 15x21 (x3), leaving room to jitter inside 28x28.
    return np.kron(g, np.ones((3, 3), np.float32))


def digits(n: int, *, seed: int = 0, noise: float = 0.03,
           jitter: int = 3) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images (n, 784) float32 in [0,1], labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    imgs = np.zeros((n, 28, 28), np.float32)
    glyphs = {d: _glyph(d) for d in range(10)}
    for i, d in enumerate(labels):
        g = glyphs[int(d)]
        h, w = g.shape
        dy = rng.integers(0, 28 - h - jitter) + rng.integers(0, jitter + 1)
        dx = rng.integers(0, 28 - w - jitter) + rng.integers(0, jitter + 1)
        canvas = rng.uniform(0.0, 0.15, (28, 28)).astype(np.float32)
        patch = np.where(g > 0, rng.uniform(0.6, 1.0, g.shape), canvas[dy:dy + h, dx:dx + w])
        canvas[dy:dy + h, dx:dx + w] = patch
        flip = rng.random((28, 28)) < noise
        canvas = np.where(flip, 1.0 - canvas, canvas)
        imgs[i] = canvas
    return imgs.reshape(n, 784), labels


def prototype(n: int, *, n_classes: int, n_features: int,
              protos_per_class: int = 2, flip: float = 0.08,
              seed: int = 0, proto_seed: int = 1234,
              ) -> tuple[np.ndarray, np.ndarray]:
    """Boolean prototype datasets: sample a class prototype, flip bits.

    ``proto_seed`` fixes the class prototypes (shared across train/test
    splits); ``seed`` drives the per-sample draws.
    """
    proto_rng = np.random.default_rng(proto_seed)
    rng = np.random.default_rng(seed)
    protos = proto_rng.random((n_classes, protos_per_class, n_features)) < 0.5
    labels = rng.integers(0, n_classes, size=n).astype(np.int32)
    which = rng.integers(0, protos_per_class, size=n)
    x = protos[labels, which].astype(np.float32)
    mask = rng.random((n, n_features)) < flip
    x = np.where(mask, 1.0 - x, x)
    return x, labels


# Table 5 dataset stand-ins: (classes, clauses, literals) from the paper.
TABLE5 = {
    "iris":           dict(classes=3,  clauses=12,   literals=32),
    "cifar2":         dict(classes=2,  clauses=1000, literals=2048),
    "kws6":           dict(classes=6,  clauses=300,  literals=754),
    "fashion_mnist":  dict(classes=10, clauses=500,  literals=1568),
    "emg":            dict(classes=7,  clauses=300,  literals=192),
    "gesture_phase":  dict(classes=5,  clauses=300,  literals=424),
    "human_activity": dict(classes=6,  clauses=800,  literals=1632),
}


def table5_dataset(name: str, n: int, *, seed: int = 0,
                   flip: float = 0.08) -> tuple[np.ndarray, np.ndarray, dict]:
    """Synthetic stand-in at the paper's published dimensions.

    Features = literals/2 (negations are appended by the booleanizer).
    """
    spec = TABLE5[name]
    x, y = prototype(n, n_classes=spec["classes"],
                     n_features=spec["literals"] // 2, flip=flip, seed=seed)
    return x, y, spec
