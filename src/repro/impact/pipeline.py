"""End-to-end IMPACT system: trained CoTM -> crossbar tiles -> inference.

Implements the paper's Fig. 14 modular scaling:

* literals beyond one tile's rows are split across R "row shards"; each
  shard produces PARTIAL clauses, combined by digital AND;
* clauses beyond one tile's rows in the class crossbar are split across S
  shards; partial class currents are digitised (ADC) and summed digitally.

The same split is the `model`-axis sharding used by the distributed runtime
(the digital AND == psum of violation bits; the ADC+add == psum of partial
sums), so this module is both the hardware simulator and the reference
semantics for the multi-pod lowering.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..core.cotm import CoTMConfig, CoTMParams, include_mask, to_unipolar
from . import energy as energy_mod
from .energy import EnergyReport
from .tiles import (ClassTile, ClauseTile, encode_class_tile,
                    encode_clause_tile)
from .yflash import I_CSA_THRESHOLD, T_READ, V_READ, read_current

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class IMPACTConfig:
    max_tile_rows: int = 2048     # clause-tile rows (literals)
    max_tile_cols: int = 512      # clause-tile columns (clauses)
    max_class_rows: int = 2048    # class-tile rows (clauses)
    variability: bool = True
    finetune: bool = True
    mask_empty: bool = True
    encode_pulse_width: float = 1e-3


def _pad_to(x: Array, size: int, axis: int, value=0) -> Array:
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@dataclasses.dataclass
class IMPACTSystem:
    """Programmed crossbar grid + digital periphery."""
    clause_g: Array        # (R, C, tr, tc) conductances
    nonempty: Array        # (n_pad,) digital empty-clause mask
    class_g: Array         # (S, sr, m) conductances
    n_literals: int
    n_clauses: int
    n_classes: int
    cfg: IMPACTConfig
    encode_stats: dict[str, Any]

    # -- inference ----------------------------------------------------------
    def clause_bits(self, literals: Array) -> tuple[Array, Array]:
        """(B, K) -> (clauses (B, n_pad) bool, clause tile currents)."""
        B = literals.shape[0]
        R, C, tr, tc = self.clause_g.shape
        lit = _pad_to(literals.astype(jnp.float32), R * tr, axis=1, value=1)
        drive = (1.0 - lit).reshape(B, R, tr)
        i_cell = read_current(self.clause_g)                    # (R,C,tr,tc)
        i_col = jnp.einsum("brk,rckj->brcj", drive, i_cell)     # (B,R,C,tc)
        partial = i_col < I_CSA_THRESHOLD                       # CSA per shard
        fired = jnp.all(partial, axis=1).reshape(B, C * tc)     # digital AND
        if self.cfg.mask_empty:
            fired = jnp.logical_and(fired, self.nonempty)
        return fired, i_col

    def class_scores(self, clauses: Array) -> tuple[Array, Array]:
        """(B, n_pad) -> (scores (B, m) = summed shard currents, currents)."""
        B = clauses.shape[0]
        S, sr, m = self.class_g.shape
        drive = _pad_to(clauses.astype(jnp.float32), S * sr, axis=1)
        drive = drive.reshape(B, S, sr)
        i_cell = read_current(self.class_g)                     # (S,sr,m)
        i_col = jnp.einsum("bsn,snm->bsm", drive, i_cell)       # per-shard ADC
        return i_col.sum(axis=1), i_col                         # digital add

    def predict(self, literals: Array) -> Array:
        clauses, _ = self.clause_bits(literals)
        scores, _ = self.class_scores(clauses)
        return jnp.argmax(scores, axis=-1)

    def infer_with_report(self, literals: Array) -> tuple[Array, EnergyReport]:
        B = literals.shape[0]
        clauses, i_clause = self.clause_bits(literals)
        scores, i_class = self.class_scores(clauses)
        preds = jnp.argmax(scores, axis=-1)

        e_clause = float((V_READ * i_clause * T_READ).sum())
        e_class = float((V_READ * i_class * T_READ).sum())
        R, C, tr, tc = self.clause_g.shape
        lat = energy_mod.inference_latency(
            n_clause_cols=min(tc, self.n_clauses), n_class_cols=self.n_classes,
            clause_tiles_parallel=1)
        ops = B * (self.n_literals * self.n_clauses
                   + self.n_clauses * self.n_classes)
        report = EnergyReport(
            read_energy_j=e_clause + e_class,
            clause_energy_j=e_clause, class_energy_j=e_class,
            program_energy_j=self.encode_stats["program_energy_j"],
            erase_energy_j=self.encode_stats["erase_energy_j"],
            latency_s=lat, ops_crosspoint=ops, datapoints=B)
        return preds, report

    # -- metrics ------------------------------------------------------------
    def area_mm2(self) -> dict[str, float]:
        # Paper convention (Table 4): area of the *occupied* region.
        return dict(
            clause=energy_mod.tile_area_mm2(self.n_literals, self.n_clauses),
            class_=energy_mod.tile_area_mm2(self.n_clauses, self.n_classes),
        )


def build_system(params: CoTMParams, cfg: CoTMConfig, key: Array,
                 impact_cfg: IMPACTConfig = IMPACTConfig()) -> IMPACTSystem:
    """Map a trained CoTM onto crossbar tiles (Figs. 6, 9, 11)."""
    K, n = params.ta_state.shape
    m = params.weights.shape[0]
    ic = impact_cfg

    include = include_mask(params.ta_state, cfg.n_states)
    R = -(-K // ic.max_tile_rows)
    C = -(-n // ic.max_tile_cols)
    inc_pad = _pad_to(_pad_to(include, R * ic.max_tile_rows, 0),
                      C * ic.max_tile_cols, 1)

    k_cl, k_w = jax.random.split(key)
    # Encode every clause tile (vectorised over the whole padded array —
    # equivalent to per-tile encoding since cells are independent).
    tile_inc = inc_pad  # (R*tr, C*tc)
    clause_tile, cl_stats = encode_clause_tile(
        tile_inc, k_cl, pulse_width=ic.encode_pulse_width,
        variability=ic.variability)
    tr, tc = ic.max_tile_rows, ic.max_tile_cols
    clause_g = clause_tile.g.reshape(R, tr, C, tc).transpose(0, 2, 1, 3)

    # Class crossbar: signed -> unipolar shift, then two-phase tuning.
    w_uni, shift = to_unipolar(params.weights)                 # (m, n)
    w_t = w_uni.T                                              # (n, m)
    S = -(-n // ic.max_class_rows)
    w_pad = _pad_to(w_t, S * ic.max_class_rows, 0)
    class_tile, w_stats = encode_class_tile(
        w_pad, k_w, variability=ic.variability, finetune=ic.finetune)
    class_g = class_tile.g.reshape(S, ic.max_class_rows, m)

    e_prog_cl, e_er_cl = energy_mod.encode_energy(
        cl_stats["prog_pulses"], cl_stats["erase_pulses"],
        ic.encode_pulse_width, ic.encode_pulse_width)
    pre_p = w_stats["pretune_prog"]
    pre_e = w_stats["pretune_erase"]
    e_prog_w, e_er_w = energy_mod.encode_energy(pre_p, pre_e, 500e-6, 500e-6)
    if ic.finetune:
        e_fp, e_fe = energy_mod.encode_energy(
            w_stats["finetune_prog"], w_stats["finetune_erase"], 50e-6, 50e-6)
        e_prog_w += e_fp
        e_er_w += e_fe

    stats = dict(clause=cl_stats, weights=w_stats,
                 weight_shift=int(shift),
                 program_energy_j=e_prog_cl + e_prog_w,
                 erase_energy_j=e_er_cl + e_er_w)
    nonempty = _pad_to(include.any(axis=0), C * tc, 0)
    return IMPACTSystem(
        clause_g=clause_g, nonempty=nonempty, class_g=class_g,
        n_literals=K, n_clauses=n, n_classes=m, cfg=ic, encode_stats=stats)
