"""End-to-end IMPACT system: trained CoTM -> crossbar tiles -> inference.

Implements the paper's Fig. 14 modular scaling:

* literals beyond one tile's rows are split across R "row shards"; each
  shard produces PARTIAL clauses, combined by digital AND;
* clauses beyond one tile's rows in the class crossbar are split across S
  shards; partial class currents are digitised (ADC) and summed digitally.

The same split is the `model`-axis sharding used by the distributed runtime
(the digital AND == psum of violation bits; the ADC+add == psum of partial
sums), so this module is both the hardware simulator and the reference
semantics for the multi-pod lowering.

Inference is Pallas-backed: ``build_system`` converts conductances to
per-cell read currents ONCE (``yflash.read_current`` hoisted out of the
per-call path), and every entry point — ``clause_bits``, ``class_scores``,
``predict``, ``infer_with_report`` — is a jitted function with an
``impl={"pallas", "xla"}`` switch.  ``impl="pallas"`` (the default) routes
``predict`` through the fused ``kernels.fused_impact`` crossbar->CSA->
class-sum kernel (clause bits stay in VMEM; interpret mode on CPU like the
other kernels) and the staged entry points through ``kernels.crossbar_mvm``
per shard; ``impl="xla"`` runs the pure-einsum oracles from ``kernels.ref``
for A/B testing.  Energy accounting rides the staged path, where the shard
column currents the paper meters are explicit.

``infer_step`` is the continuous-batching entry point: one crossbar sweep
over a fixed-capacity slot-table buffer with a validity mask, returning
per-lane (per-request) read energies so the serving scheduler
(``serve.impact_engine``) can admit/release lanes between sweeps and bill
each request individually.

Multi-device: every entry point takes a ``mesh`` (or inherits the
system-level one from ``build_system(..., mesh=...)``); when the R/S
shard counts divide the mesh's ``model`` axis, inference runs the
``sharding.crossbar`` shard_map lowering — the Fig. 14 digital AND and
ADC+add become the two psums — and falls back to the single-device
kernels otherwise.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cotm import CoTMConfig, CoTMParams, include_mask, to_unipolar
from ..kernels import ops, ref
from ..kernels.ref import pad_to as _pad_to
from ..sharding import crossbar as crossbar_sh
from . import energy as energy_mod
from .energy import EnergyReport
from .tiles import (ClassTile, ClauseTile, encode_class_tile,
                    encode_clause_tile)
from .yflash import I_CSA_THRESHOLD, T_READ, V_READ, read_current

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class IMPACTConfig:
    max_tile_rows: int = 2048     # clause-tile rows (literals)
    max_tile_cols: int = 512      # clause-tile columns (clauses)
    max_class_rows: int = 2048    # class-tile rows (clauses)
    variability: bool = True
    finetune: bool = True
    mask_empty: bool = True
    encode_pulse_width: float = 1e-3


# --- jitted inference entry points (module level => shared trace cache) ----

@partial(jax.jit, static_argnames=("impl", "thresh"))
def _clause_bits(literals: Array, clause_i: Array, nonempty: Array, *,
                 impl: str, thresh: float) -> tuple[Array, Array]:
    """-> (fired (B, C*tc) bool, shard column currents (B, R, C, tc))."""
    if impl == "xla":
        return ref.impact_clause_bits_ref(literals, clause_i, nonempty,
                                          thresh=thresh)
    B = literals.shape[0]
    R, C, tr, tc = clause_i.shape
    lit = _pad_to(literals.astype(jnp.float32), R * tr, axis=1, value=1)
    drive = (1.0 - lit).reshape(B, R, tr)
    cols = []
    for r in range(R):                          # static shard unroll
        cur = clause_i[r].transpose(1, 0, 2).reshape(tr, C * tc)
        cols.append(ops.crossbar_mvm(drive[:, r], cur, v_read=1.0,
                                     cutoff=0.0))
    i_col = jnp.stack(cols, axis=1).reshape(B, R, C, tc)
    fired = jnp.all(i_col < thresh, axis=1).reshape(B, C * tc)
    return jnp.logical_and(fired, nonempty.astype(bool)), i_col


@partial(jax.jit, static_argnames=("impl",))
def _class_scores(clauses: Array, class_i: Array, *,
                  impl: str) -> tuple[Array, Array]:
    """-> (scores (B, m) = summed shard currents, currents (B, S, m))."""
    if impl == "xla":
        return ref.impact_class_scores_ref(clauses, class_i)
    B = clauses.shape[0]
    S, sr, m = class_i.shape
    drive = _pad_to(clauses.astype(jnp.float32), S * sr, axis=1)
    drive = drive[:, :S * sr].reshape(B, S, sr)
    i_col = jnp.stack(
        [ops.crossbar_mvm(drive[:, s], class_i[s], v_read=1.0, cutoff=0.0)
         for s in range(S)], axis=1)            # per-shard ADC
    return i_col.sum(axis=1), i_col             # digital add


@partial(jax.jit, static_argnames=("impl", "thresh", "mesh"))
def _predict(literals: Array, clause_i: Array, nonempty: Array,
             class_i: Array, *, impl: str, thresh: float,
             mesh=None) -> Array:
    scores = ops.fused_impact(literals, clause_i, nonempty, class_i,
                              thresh=thresh, impl=impl, mesh=mesh)
    return jnp.argmax(scores, axis=-1)


def _metered_scores(literals: Array, clause_i: Array, nonempty: Array,
                    class_i: Array, valid: Array | None, *, impl: str,
                    thresh: float, mesh) -> tuple[Array, Array, Array]:
    """Shared metered core: -> (scores (B, m), per-lane summed clause
    currents (B,), per-lane summed class currents (B,)).  The ONE place
    that routes between the shard_map lowering (mesh can hold the R/S
    grid) and the single-device staged path — keep the routing predicate
    here so every metered caller shards (or falls back) identically."""
    if mesh is not None and crossbar_sh.shardable(
            mesh, clause_i.shape[0], class_i.shape[0]):
        return crossbar_sh.fused_impact_shmap(
            literals, clause_i, nonempty, class_i, thresh=thresh,
            mesh=mesh, impl=impl, valid=valid, meter=True)
    fired, i_clause = _clause_bits(literals, clause_i, nonempty,
                                   impl=impl, thresh=thresh)
    if valid is not None:
        fired = jnp.logical_and(fired, valid[:, None])
        i_clause = i_clause * valid[:, None, None, None]
    scores, i_class = _class_scores(fired, class_i, impl=impl)
    return scores, i_clause.sum(axis=(1, 2, 3)), i_class.sum(axis=(1, 2))


@partial(jax.jit, static_argnames=("impl", "thresh", "meter", "mesh"))
def _infer_step(literals: Array, clause_i: Array, nonempty: Array,
                class_i: Array, valid: Array, *, impl: str, thresh: float,
                meter: bool, mesh=None) -> tuple[Array, Array, Array]:
    """One scheduler step over a fixed-capacity slot table: classify every
    lane of the (capacity, K) literal buffer in a single crossbar sweep.

    -> (preds (B,), per-lane clause read energy (B,) J, per-lane class
    read energy (B,) J).  ``valid`` (B,) marks occupied lanes; free lanes
    hold all-1 literals (rows float, no current) and are metered at
    exactly zero, so admitting a request into a free slot mid-serve never
    perturbs other lanes' scores or bills.  Invalid lanes return the
    sentinel prediction -1 (a free lane fires every nonempty clause, so
    its argmax would otherwise look like a real class).  With
    ``meter=False`` the step runs the fused kernel (max-throughput path)
    and the energy outputs are zeros; ``mesh`` distributes the crossbar
    grid per ``sharding.crossbar``.
    """
    B = literals.shape[0]
    valid = valid.astype(bool)
    if not meter:
        scores = ops.fused_impact(literals, clause_i, nonempty, class_i,
                                  thresh=thresh, impl=impl, mesh=mesh)
        zeros = jnp.zeros((B,), jnp.float32)
        return jnp.where(valid, jnp.argmax(scores, axis=-1), -1), \
            zeros, zeros
    scores, i_cl, i_cs = _metered_scores(
        literals, clause_i, nonempty, class_i, valid, impl=impl,
        thresh=thresh, mesh=mesh)
    e_cl, e_cs = energy_mod.per_lane_read_energy(i_cl, i_cs)
    return jnp.where(valid, jnp.argmax(scores, axis=-1), -1), e_cl, e_cs


@partial(jax.jit, static_argnames=("impl", "thresh", "mesh"))
def _infer_metered(literals: Array, clause_i: Array, nonempty: Array,
                   class_i: Array, valid: Array | None, *, impl: str,
                   thresh: float, mesh=None) -> tuple[Array, Array, Array]:
    """Staged inference with current metering: -> (preds, sum I_clause,
    sum I_class).  The current sums are the paper's measured quantities;
    reducing them inside the jit keeps the (B, R, n_pad) current tensor
    transient.  ``valid`` (B,) masks batch-padding lanes out of the
    meters: an all-1 literal pad lane draws no CLAUSE current (every row
    floats) but fires every nonempty clause, so unmasked it would bill
    phantom class-tile energy.  With a shardable ``mesh`` the currents
    come from the distributed lowering (per-device partials psummed), so
    metering works from a sharded grid too."""
    scores, i_cl_lane, i_cs_lane = _metered_scores(
        literals, clause_i, nonempty, class_i, valid, impl=impl,
        thresh=thresh, mesh=mesh)
    return jnp.argmax(scores, axis=-1), i_cl_lane.sum(), i_cs_lane.sum()


@dataclasses.dataclass
class IMPACTSystem:
    """Programmed crossbar grid + digital periphery.

    ``mesh`` (optional jax Mesh with a ``model`` axis) distributes the
    R/S row-shards across devices for every inference entry point (see
    ``sharding.crossbar``); per-call ``mesh=`` arguments override it.
    """
    clause_g: Array        # (R, C, tr, tc) conductances
    nonempty: Array        # (n_pad,) digital empty-clause mask
    class_g: Array         # (S, sr, m) conductances
    clause_i: Array        # (R, C, tr, tc) per-cell read currents (hoisted)
    class_i: Array         # (S, sr, m) per-cell read currents (hoisted)
    n_literals: int
    n_clauses: int
    n_classes: int
    cfg: IMPACTConfig
    encode_stats: dict[str, Any]
    mesh: Any = None

    def _mesh_eff(self, mesh):
        return mesh if mesh is not None else self.mesh

    def _nonempty_eff(self) -> Array:
        if self.cfg.mask_empty:
            return self.nonempty
        return jnp.ones_like(self.nonempty)

    @staticmethod
    def _check_impl(impl: str) -> None:
        if impl not in ("pallas", "xla"):
            raise ValueError(
                f"impl must be 'pallas' or 'xla', got {impl!r}")

    # -- inference ----------------------------------------------------------
    def clause_bits(self, literals: Array, *,
                    impl: str = "pallas") -> tuple[Array, Array]:
        """(B, K) -> (clauses (B, n_pad) bool, clause tile currents)."""
        self._check_impl(impl)
        return _clause_bits(literals, self.clause_i, self._nonempty_eff(),
                            impl=impl, thresh=I_CSA_THRESHOLD)

    def class_scores(self, clauses: Array, *,
                     impl: str = "pallas") -> tuple[Array, Array]:
        """(B, n_pad) -> (scores (B, m) = summed shard currents, currents)."""
        self._check_impl(impl)
        return _class_scores(clauses, self.class_i, impl=impl)

    def predict(self, literals: Array, *, impl: str = "pallas",
                mesh=None) -> Array:
        """Fast path: fused Pallas crossbar->CSA->class-sum kernel; with a
        (system- or call-level) mesh, the shard_map lowering."""
        self._check_impl(impl)
        return _predict(literals, self.clause_i, self._nonempty_eff(),
                        self.class_i, impl=impl, thresh=I_CSA_THRESHOLD,
                        mesh=self._mesh_eff(mesh))

    def infer_step(self, literals: Array, valid: Array, *,
                   impl: str = "pallas", meter: bool = False,
                   mesh=None) -> tuple[Array, Array, Array]:
        """Per-step entry point for the continuous-batching scheduler: one
        crossbar sweep over a fixed-shape slot-table buffer.  Jits once per
        (capacity, impl, meter, mesh) — the host-side scheduler calls it
        every step with the same shape, so admission patterns never
        retrace.

        -> (preds (B,), per-lane clause energy (B,) J, per-lane class
        energy (B,) J); invalid lanes predict the sentinel -1; energies
        are zeros when ``meter=False`` (fused kernel path)."""
        self._check_impl(impl)
        return _infer_step(literals, self.clause_i, self._nonempty_eff(),
                           self.class_i, jnp.asarray(valid), impl=impl,
                           thresh=I_CSA_THRESHOLD, meter=meter,
                           mesh=self._mesh_eff(mesh))

    def _grid_latency(self) -> float:
        """Fig. 14 latency of one sweep: ALL n_clauses columns stream
        through the (R, C) grid's C parallel column-tiles (R row-shards
        evaluate concurrently and AND digitally, so R cancels)."""
        C = self.clause_g.shape[1]
        return energy_mod.inference_latency(
            n_clause_cols=self.n_clauses, n_class_cols=self.n_classes,
            clause_tiles_parallel=C)

    def step_report(self, e_clause_lanes: Array, e_class_lanes: Array,
                    datapoints: int) -> EnergyReport:
        """Fold one step's per-lane read energies (from ``infer_step``)
        into the paper's batch-level ``EnergyReport``; per-request
        attribution sums exactly to the batch meter."""
        return energy_mod.report_from_lane_energies(
            e_clause_lanes, e_class_lanes,
            program_energy_j=self.encode_stats["program_energy_j"],
            erase_energy_j=self.encode_stats["erase_energy_j"],
            latency_s=self._grid_latency(),
            ops_per_datapoint=(self.n_literals * self.n_clauses
                               + self.n_clauses * self.n_classes),
            datapoints=datapoints,
            area_mm2=sum(self.area_mm2().values()))

    def infer_with_report(self, literals: Array, *,
                          impl: str = "pallas",
                          valid: Array | None = None,
                          mesh=None) -> tuple[Array, EnergyReport]:
        """``valid`` (B,) bool marks real lanes in a padded batch; padding
        lanes are excluded from the energy/ops/datapoint accounting (their
        predictions still come back and are dropped by the caller)."""
        self._check_impl(impl)
        B = (literals.shape[0] if valid is None
             else int(np.asarray(valid).sum()))
        preds, i_clause_sum, i_class_sum = _infer_metered(
            literals, self.clause_i, self._nonempty_eff(), self.class_i,
            valid if valid is None else jnp.asarray(valid),
            impl=impl, thresh=I_CSA_THRESHOLD, mesh=self._mesh_eff(mesh))

        e_clause = float(V_READ * i_clause_sum * T_READ)
        e_class = float(V_READ * i_class_sum * T_READ)
        ops_xp = B * (self.n_literals * self.n_clauses
                      + self.n_clauses * self.n_classes)
        report = EnergyReport(
            read_energy_j=e_clause + e_class,
            clause_energy_j=e_clause, class_energy_j=e_class,
            program_energy_j=self.encode_stats["program_energy_j"],
            erase_energy_j=self.encode_stats["erase_energy_j"],
            latency_s=self._grid_latency(), ops_crosspoint=ops_xp,
            datapoints=B, area_mm2=sum(self.area_mm2().values()))
        return preds, report

    # -- metrics ------------------------------------------------------------
    def area_mm2(self) -> dict[str, float]:
        # Paper convention (Table 4): area of the *occupied* region.
        return dict(
            clause=energy_mod.tile_area_mm2(self.n_literals, self.n_clauses),
            class_=energy_mod.tile_area_mm2(self.n_clauses, self.n_classes),
        )


def build_system(params: CoTMParams, cfg: CoTMConfig, key: Array,
                 impact_cfg: IMPACTConfig = IMPACTConfig(), *,
                 mesh=None) -> IMPACTSystem:
    """Map a trained CoTM onto crossbar tiles (Figs. 6, 9, 11).  ``mesh``
    (optional) makes every inference entry point serve from a grid
    distributed over the mesh's ``model``/data axes."""
    K, n = params.ta_state.shape
    m = params.weights.shape[0]
    ic = impact_cfg

    include = include_mask(params.ta_state, cfg.n_states)
    R = -(-K // ic.max_tile_rows)
    C = -(-n // ic.max_tile_cols)
    inc_pad = _pad_to(_pad_to(include, R * ic.max_tile_rows, 0),
                      C * ic.max_tile_cols, 1)

    k_cl, k_w = jax.random.split(key)
    # Encode every clause tile (vectorised over the whole padded array —
    # equivalent to per-tile encoding since cells are independent).
    tile_inc = inc_pad  # (R*tr, C*tc)
    clause_tile, cl_stats = encode_clause_tile(
        tile_inc, k_cl, pulse_width=ic.encode_pulse_width,
        variability=ic.variability)
    tr, tc = ic.max_tile_rows, ic.max_tile_cols
    clause_g = clause_tile.g.reshape(R, tr, C, tc).transpose(0, 2, 1, 3)

    # Class crossbar: signed -> unipolar shift, then two-phase tuning.
    w_uni, shift = to_unipolar(params.weights)                 # (m, n)
    w_t = w_uni.T                                              # (n, m)
    S = -(-n // ic.max_class_rows)
    w_pad = _pad_to(w_t, S * ic.max_class_rows, 0)
    class_tile, w_stats = encode_class_tile(
        w_pad, k_w, variability=ic.variability, finetune=ic.finetune)
    class_g = class_tile.g.reshape(S, ic.max_class_rows, m)

    e_prog_cl, e_er_cl = energy_mod.encode_energy(
        cl_stats["prog_pulses"], cl_stats["erase_pulses"],
        ic.encode_pulse_width, ic.encode_pulse_width)
    pre_p = w_stats["pretune_prog"]
    pre_e = w_stats["pretune_erase"]
    e_prog_w, e_er_w = energy_mod.encode_energy(pre_p, pre_e, 500e-6, 500e-6)
    if ic.finetune:
        e_fp, e_fe = energy_mod.encode_energy(
            w_stats["finetune_prog"], w_stats["finetune_erase"], 50e-6, 50e-6)
        e_prog_w += e_fp
        e_er_w += e_fe

    stats = dict(clause=cl_stats, weights=w_stats,
                 weight_shift=int(shift),
                 program_energy_j=e_prog_cl + e_prog_w,
                 erase_energy_j=e_er_cl + e_er_w)
    nonempty = _pad_to(include.any(axis=0), C * tc, 0)
    # Conductance -> read-current conversion happens ONCE here; every
    # inference call (jitted above) consumes the precomputed currents.
    return IMPACTSystem(
        clause_g=clause_g, nonempty=nonempty, class_g=class_g,
        clause_i=read_current(clause_g), class_i=read_current(class_g),
        n_literals=K, n_clauses=n, n_classes=m, cfg=ic, encode_stats=stats,
        mesh=mesh)
