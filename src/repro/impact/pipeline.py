"""End-to-end IMPACT system: trained CoTM -> crossbar tiles -> inference.

Implements the paper's Fig. 14 modular scaling:

* literals beyond one tile's rows are split across R "row shards"; each
  shard produces PARTIAL clauses, combined by digital AND;
* clauses beyond one tile's rows in the class crossbar are split across S
  shards; partial class currents are digitised (ADC) and summed digitally.

The same split is the `model`-axis sharding used by the distributed runtime
(the digital AND == psum of violation bits; the ADC+add == psum of partial
sums), so this module is both the hardware simulator and the reference
semantics for the multi-pod lowering.

``build_system`` converts conductances to per-cell read currents ONCE
(``yflash.read_current`` hoisted out of the per-call path) and returns an
``IMPACTSystem`` — the *programmed hardware*.  Runtime configuration
lives one level up: ``system.compile(RuntimeSpec(...))`` resolves a
frozen spec (backend registry name, mesh topology, metering mode —
``"off"`` / ``"staged"`` / ``"fused"`` in-kernel meters — interpret
policy, slot capacity) once into an ``InferenceSession`` of
AOT-compiled executables for ``predict`` / ``infer_step`` /
``infer_with_report`` (see ``impact.runtime``).  The old per-call
``impl=`` / ``mesh=`` / ``meter=`` kwargs keep working through thin
shims that warn ``SpecDeprecationWarning`` and forward to a session
cached on the system.

``clause_bits`` / ``class_scores`` remain per-stage introspection helpers
(jitted, registry-dispatched) for tests and notebooks that want to look
at the analog quantities between the two crossbars.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..core.cotm import CoTMConfig, CoTMParams, include_mask, to_unipolar
from ..kernels import backends
from ..kernels.ref import pad_to as _pad_to
from . import energy as energy_mod
from .energy import EnergyReport
from .tiles import (ClassTile, ClauseTile, encode_class_tile,
                    encode_clause_tile)
from .yflash import I_CSA_THRESHOLD, read_current

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class IMPACTConfig:
    max_tile_rows: int = 2048     # clause-tile rows (literals)
    max_tile_cols: int = 512      # clause-tile columns (clauses)
    max_class_rows: int = 2048    # class-tile rows (clauses)
    variability: bool = True
    finetune: bool = True
    mask_empty: bool = True
    encode_pulse_width: float = 1e-3


# --- jitted stage helpers (module level => shared trace cache) -------------
# ``impl`` is a backend-registry key; the registry object carries the
# actual lowering, so these never switch on strings.

@partial(jax.jit, static_argnames=("impl", "thresh"))
def _clause_bits(literals: Array, clause_i: Array, nonempty: Array, *,
                 impl: str, thresh: float) -> tuple[Array, Array]:
    """-> (fired (B, C*tc) bool, shard column currents (B, R, C, tc))."""
    return backends.get_backend(impl).impact_clause_bits(
        literals, clause_i, nonempty, thresh=thresh)


@partial(jax.jit, static_argnames=("impl",))
def _class_scores(clauses: Array, class_i: Array, *,
                  impl: str) -> tuple[Array, Array]:
    """-> (scores (B, m) = summed shard currents, currents (B, S, m))."""
    return backends.get_backend(impl).impact_class_scores(clauses, class_i)


@dataclasses.dataclass
class IMPACTSystem:
    """Programmed crossbar grid + digital periphery.

    ``mesh`` (optional jax Mesh with a ``model`` axis) is the
    system-level default topology: sessions compiled from a spec whose
    topology has no mesh inherit it (see ``RuntimeSpec.topology``).
    """
    clause_g: Array        # (R, C, tr, tc) conductances
    nonempty: Array        # (n_pad,) digital empty-clause mask
    class_g: Array         # (S, sr, m) conductances
    clause_i: Array        # (R, C, tr, tc) per-cell read currents (hoisted)
    class_i: Array         # (S, sr, m) per-cell read currents (hoisted)
    n_literals: int
    n_clauses: int
    n_classes: int
    cfg: IMPACTConfig
    encode_stats: dict[str, Any]
    mesh: Any = None

    def _nonempty_eff(self) -> Array:
        if self.cfg.mask_empty:
            return self.nonempty
        return jnp.ones_like(self.nonempty)

    # -- compiled-session runtime ------------------------------------------
    def compile(self, spec=None) -> "Any":
        """Resolve a ``RuntimeSpec`` ONCE into an ``InferenceSession``
        (cached per spec — compiling the same spec twice returns the
        same session, so sessions are safe to re-derive anywhere).

        ``spec=None`` compiles the default spec: the ``pallas`` backend,
        the system-level mesh (if any) with ``shard="auto"``, staged
        metering.  See ``impact.runtime``.
        """
        from . import runtime as rt
        spec = rt.RuntimeSpec() if spec is None else spec
        cache = self.__dict__.setdefault("_sessions", {})
        if spec not in cache:
            cache[spec] = rt.InferenceSession(self, spec)
        return cache[spec]

    def _legacy_session(self, what: str, kwargs: dict[str, Any],
                        metering: str = "staged"):
        """Deprecation shim core: map old per-call kwargs onto a cached
        session.  Explicitly passed runtime-config kwargs warn; bare
        calls forward silently (they already mean "the default spec")."""
        from . import runtime as rt
        legacy = sorted(k for k, v in kwargs.items() if v is not None)
        if legacy:
            warnings.warn(
                f"IMPACTSystem.{what}({', '.join(legacy)}=...) is "
                f"deprecated: encode runtime configuration in a "
                f"RuntimeSpec and compile it once — "
                f"system.compile(RuntimeSpec(...)).{what}(...) "
                f"(see the README migration table)",
                rt.SpecDeprecationWarning, stacklevel=3)
        return self.compile(rt.legacy_spec(
            impl=kwargs.get("impl"), mesh=kwargs.get("mesh"),
            metering=metering))

    # -- inference ----------------------------------------------------------
    def clause_bits(self, literals: Array, *,
                    impl: str = "pallas") -> tuple[Array, Array]:
        """(B, K) -> (clauses (B, n_pad) bool, clause tile currents)."""
        return _clause_bits(literals, self.clause_i, self._nonempty_eff(),
                            impl=impl, thresh=I_CSA_THRESHOLD)

    def class_scores(self, clauses: Array, *,
                     impl: str = "pallas") -> tuple[Array, Array]:
        """(B, n_pad) -> (scores (B, m) = summed shard currents, currents)."""
        return _class_scores(clauses, self.class_i, impl=impl)

    def predict(self, literals: Array, *, impl: str | None = None,
                mesh=None) -> Array:
        """Fast path: fused crossbar->CSA->class-sum argmax through the
        default session (``impl=``/``mesh=`` are deprecated shims)."""
        session = self._legacy_session("predict",
                                       dict(impl=impl, mesh=mesh))
        return session.predict(literals).predictions

    def infer_step(self, literals: Array, valid: Array, *,
                   impl: str | None = None, meter: bool | None = None,
                   mesh=None) -> tuple[Array, Array, Array]:
        """Per-step entry point for the continuous-batching scheduler —
        deprecated shim over ``session.infer_step`` (the scheduler itself
        holds a session; see ``serve.impact_engine``).

        -> (preds (B,), per-lane clause energy (B,) J, per-lane class
        energy (B,) J); invalid lanes predict the sentinel -1; energies
        are zeros without metering (fused kernel path)."""
        session = self._legacy_session(
            "infer_step", dict(impl=impl, meter=meter, mesh=mesh),
            metering="staged" if meter else "off")
        res = session.infer_step(literals, valid)
        return res.predictions, res.e_clause_lanes, res.e_class_lanes

    def infer_with_report(self, literals: Array, *,
                          impl: str | None = None,
                          valid: Array | None = None,
                          mesh=None) -> tuple[Array, EnergyReport]:
        """``valid`` (B,) bool marks real lanes in a padded batch; padding
        lanes are excluded from the energy/ops/datapoint accounting and
        predict the sentinel -1."""
        session = self._legacy_session("infer_with_report",
                                       dict(impl=impl, mesh=mesh))
        res = session.infer_with_report(literals, valid=valid)
        return res.predictions, res.report

    def _grid_latency(self) -> float:
        """Fig. 14 latency of one sweep: ALL n_clauses columns stream
        through the (R, C) grid's C parallel column-tiles (R row-shards
        evaluate concurrently and AND digitally, so R cancels)."""
        C = self.clause_g.shape[1]
        return energy_mod.inference_latency(
            n_clause_cols=self.n_clauses, n_class_cols=self.n_classes,
            clause_tiles_parallel=C)

    def step_report(self, e_clause_lanes: Array, e_class_lanes: Array,
                    datapoints: int) -> EnergyReport:
        """Fold one step's per-lane read energies (from ``infer_step``)
        into the paper's batch-level ``EnergyReport``; per-request
        attribution sums exactly to the batch meter."""
        return energy_mod.report_from_lane_energies(
            e_clause_lanes, e_class_lanes,
            program_energy_j=self.encode_stats["program_energy_j"],
            erase_energy_j=self.encode_stats["erase_energy_j"],
            latency_s=self._grid_latency(),
            ops_per_datapoint=(self.n_literals * self.n_clauses
                               + self.n_clauses * self.n_classes),
            datapoints=datapoints,
            area_mm2=sum(self.area_mm2().values()))

    # -- metrics ------------------------------------------------------------
    def area_mm2(self) -> dict[str, float]:
        # Paper convention (Table 4): area of the *occupied* region.
        return dict(
            clause=energy_mod.tile_area_mm2(self.n_literals, self.n_clauses),
            class_=energy_mod.tile_area_mm2(self.n_clauses, self.n_classes),
        )


def build_system(params: CoTMParams, cfg: CoTMConfig, key: Array,
                 impact_cfg: IMPACTConfig = IMPACTConfig(), *,
                 mesh=None) -> IMPACTSystem:
    """Map a trained CoTM onto crossbar tiles (Figs. 6, 9, 11).  ``mesh``
    (optional) becomes the system-level default topology every compiled
    session inherits (``RuntimeSpec.topology`` can override it)."""
    K, n = params.ta_state.shape
    m = params.weights.shape[0]
    ic = impact_cfg

    include = include_mask(params.ta_state, cfg.n_states)
    R = -(-K // ic.max_tile_rows)
    C = -(-n // ic.max_tile_cols)
    inc_pad = _pad_to(_pad_to(include, R * ic.max_tile_rows, 0),
                      C * ic.max_tile_cols, 1)

    k_cl, k_w = jax.random.split(key)
    # Encode every clause tile (vectorised over the whole padded array —
    # equivalent to per-tile encoding since cells are independent).
    tile_inc = inc_pad  # (R*tr, C*tc)
    clause_tile, cl_stats = encode_clause_tile(
        tile_inc, k_cl, pulse_width=ic.encode_pulse_width,
        variability=ic.variability)
    tr, tc = ic.max_tile_rows, ic.max_tile_cols
    clause_g = clause_tile.g.reshape(R, tr, C, tc).transpose(0, 2, 1, 3)

    # Class crossbar: signed -> unipolar shift, then two-phase tuning.
    w_uni, shift = to_unipolar(params.weights)                 # (m, n)
    w_t = w_uni.T                                              # (n, m)
    S = -(-n // ic.max_class_rows)
    w_pad = _pad_to(w_t, S * ic.max_class_rows, 0)
    class_tile, w_stats = encode_class_tile(
        w_pad, k_w, variability=ic.variability, finetune=ic.finetune)
    class_g = class_tile.g.reshape(S, ic.max_class_rows, m)

    e_prog_cl, e_er_cl = energy_mod.encode_energy(
        cl_stats["prog_pulses"], cl_stats["erase_pulses"],
        ic.encode_pulse_width, ic.encode_pulse_width)
    pre_p = w_stats["pretune_prog"]
    pre_e = w_stats["pretune_erase"]
    e_prog_w, e_er_w = energy_mod.encode_energy(pre_p, pre_e, 500e-6, 500e-6)
    if ic.finetune:
        e_fp, e_fe = energy_mod.encode_energy(
            w_stats["finetune_prog"], w_stats["finetune_erase"], 50e-6, 50e-6)
        e_prog_w += e_fp
        e_er_w += e_fe

    stats = dict(clause=cl_stats, weights=w_stats,
                 weight_shift=int(shift),
                 program_energy_j=e_prog_cl + e_prog_w,
                 erase_energy_j=e_er_cl + e_er_w)
    nonempty = _pad_to(include.any(axis=0), C * tc, 0)
    # Conductance -> read-current conversion happens ONCE here; every
    # compiled session consumes the precomputed currents.
    return IMPACTSystem(
        clause_g=clause_g, nonempty=nonempty, class_g=class_g,
        clause_i=read_current(clause_g), class_i=read_current(class_g),
        n_literals=K, n_clauses=n, n_classes=m, cfg=ic, encode_stats=stats,
        mesh=mesh)
