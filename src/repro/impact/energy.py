"""IMPACT energy / latency / area model — calibrated to Table 4.

Paper anchors:
  Programming (avg)  139 nJ / pulse  (5 V x 139 uA x 200 us)
  Erasing (avg)      0.8 pJ / pulse  (8 V x 1 nA x 100 us)
  Reading LCS        3.2e-5 pJ       (2 V x ~3 nA x 5 ns, Boolean mode)
  Reading HCS        0.05 pJ         (2 V x 5 uA x 5 ns, Boolean mode)
  Energy/datapoint   67.99 pJ (clause tile, 500x1568), 16.22 pJ (class tile)
  Energy/op          5.76 pJ/column worst case (2048 cells all HCS)
  GOPS               413.6    (op = one crosspoint interaction)
  TOPS/W             24.56    (op = MAC-equivalent: 2 per crosspoint)
  Area               3.159 um^2/device

Note the paper's op-accounting: GOPS divides *crosspoint interactions* by
latency, while TOPS/W divides *MAC-equivalents* (2x) by energy; we reproduce
both conventions and label them.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .yflash import T_READ, V_READ

Array = jnp.ndarray

# Per-pulse energies (J)
E_PROGRAM_PULSE = 5.0 * 139e-6 * 200e-6     # 139 nJ
E_ERASE_PULSE = 8.0 * 1e-9 * 100e-6         # 0.8 pJ
AREA_PER_DEVICE_UM2 = 3.159
T_COLUMN = T_READ                            # one column evaluated per 5 ns


@dataclasses.dataclass
class EnergyReport:
    read_energy_j: float          # total inference read energy
    clause_energy_j: float
    class_energy_j: float
    program_energy_j: float       # one-time encode cost
    erase_energy_j: float
    latency_s: float
    ops_crosspoint: float
    datapoints: int
    area_mm2: float | None = None  # occupied crossbar area (system-level)
    #: Online-training write energy (J): program/erase pulse trains the
    #: in-array TA updates applied THIS report's window — distinct from
    #: ``program_energy_j``/``erase_energy_j``, which carry the one-time
    #: encode cost.  Serving-only reports bill exactly 0.0 here.
    write_energy_j: float = 0.0

    @property
    def energy_per_datapoint_j(self) -> float:
        return self.read_energy_j / max(self.datapoints, 1)

    @property
    def gops(self) -> float:
        # Empty aggregates (0 datapoints / 0 latency) report 0.0 instead
        # of raising, same convention as energy_per_datapoint_j.
        if self.latency_s <= 0.0:
            return 0.0
        return (self.ops_crosspoint / max(self.datapoints, 1)) \
            / self.latency_s / 1e9

    @property
    def tops_per_w(self) -> float:
        # MAC-equivalents (2 per crosspoint op) / read energy; an empty
        # aggregate (read_energy_j == 0) reports 0.0 instead of raising.
        if self.read_energy_j <= 0.0:
            return 0.0
        return (2 * self.ops_crosspoint / self.read_energy_j) / 1e12

    @property
    def tops_per_mm2(self) -> float:
        # MAC-equivalent throughput per occupied crossbar area (Table 4 /
        # Table 6 convention).  Reports built by ``IMPACTSystem`` carry
        # the system's area; a report without one cannot silently render
        # a fake 0.0 metric.
        if self.area_mm2 is None:
            raise ValueError(
                "tops_per_mm2 needs the crossbar area: this EnergyReport "
                "was built without area_mm2 (use IMPACTSystem reports, or "
                "set area_mm2 from IMPACTSystem.area_mm2())")
        # Empty aggregates (0 latency) report 0.0 instead of raising,
        # same convention as the gops / tops_per_w guards above.
        if self.latency_s <= 0.0:
            return 0.0
        ops_per_dp = self.ops_crosspoint / max(self.datapoints, 1)
        return (2 * ops_per_dp / self.latency_s) / 1e12 / self.area_mm2


def read_energy_from_currents(currents: Array) -> Array:
    """E = V_R * I * t_read summed over columns — the paper's measurement."""
    return (V_READ * currents * T_READ).sum(axis=-1)


def per_lane_read_energy(i_clause_lane: Array, i_class_lane: Array,
                         ) -> tuple[Array, Array]:
    """Per-request read-energy attribution: lane-summed crossbar currents
    (B,) -> (clause joules (B,), class joules (B,)).  Same E = V_R * I *
    t_read accounting as the batch meters, kept per lane so a serving
    scheduler can bill each request for exactly the current its datapoint
    drew (padding/invalid lanes arrive pre-masked to zero)."""
    return (V_READ * i_clause_lane * T_READ,
            V_READ * i_class_lane * T_READ)


def report_from_lane_energies(e_clause_lanes: Array, e_class_lanes: Array, *,
                              program_energy_j: float, erase_energy_j: float,
                              latency_s: float, ops_per_datapoint: float,
                              datapoints: int,
                              area_mm2: float | None = None,
                              write_energy_j: float = 0.0) -> "EnergyReport":
    """Fold per-lane (per-request) read energies into a batch-level
    ``EnergyReport`` — the aggregation point where request attribution and
    the paper's per-batch accounting provably agree (sum of lanes == batch
    meter).  ``write_energy_j`` carries this window's online-training
    pulse energy (0.0 for serving-only reports)."""
    e_cl = float(np.asarray(e_clause_lanes, dtype=np.float64).sum())
    e_cs = float(np.asarray(e_class_lanes, dtype=np.float64).sum())
    return EnergyReport(
        read_energy_j=e_cl + e_cs,
        clause_energy_j=e_cl, class_energy_j=e_cs,
        program_energy_j=program_energy_j, erase_energy_j=erase_energy_j,
        latency_s=latency_s,
        ops_crosspoint=ops_per_datapoint * datapoints,
        datapoints=datapoints, area_mm2=area_mm2,
        write_energy_j=write_energy_j)


def encode_energy(n_program_pulses: Array, n_erase_pulses: Array,
                  width_prog: float, width_erase: float) -> tuple[float, float]:
    """One-time tile-programming energy, scaled by actual pulse widths."""
    e_p = float(n_program_pulses.sum()) * E_PROGRAM_PULSE * (width_prog / 200e-6)
    e_e = float(n_erase_pulses.sum()) * E_ERASE_PULSE * (width_erase / 100e-6)
    return e_p, e_e


def tile_area_mm2(rows: int, cols: int) -> float:
    return rows * cols * AREA_PER_DEVICE_UM2 * 1e-6


def energy_per_effective_clause(read_energy_j: float, datapoints: int,
                                n_effective: int) -> float:
    """Re-anchored Table 4 figure after clause pruning.

    The paper divides read energy by the PROGRAMMED clause count; once a
    pruning pass (``train.compression.prune_clauses``) erases never-
    firing and duplicate columns, the honest per-clause denominator is
    the count of columns still drawing current.  Degenerate inputs
    (nothing survived, empty calibration batch) report 0.0 rather than
    raising — the benchmark records them as-is.
    """
    if n_effective <= 0 or datapoints <= 0:
        return 0.0
    return read_energy_j / float(datapoints) / float(n_effective)


def inference_latency(n_clause_cols: int, n_class_cols: int,
                      clause_tiles_parallel: int = 1) -> float:
    """Fig. 14 timing model.  ``n_clause_cols`` counts ALL clause columns
    of the system; the grid's C column-tiles stream their columns through
    per-tile CSA banks in parallel (``clause_tiles_parallel = C``), each
    column taking one 5 ns read cycle, so the clause stage runs for
    ``ceil(n / C)`` cycles.  The R row-shards of a column evaluate
    concurrently and AND digitally, so R does not appear.  The class
    tile's ``n_class_cols`` columns all read concurrently afterwards:
    one more cycle.

    ``ceil(n / C)`` is the BALANCED column assignment — a deliberate
    idealization.  ``build_system`` packs columns contiguously (tile 0
    fills first), whose bottleneck tile streams ``min(tc, n)`` columns:
    at most one ragged tile's worth (< tc cycles) more than the balanced
    figure, and identical whenever n is a multiple of C or C == 1 (the
    Table 4 single-tile anchors).  The balanced model is kept because it
    is a property of the (R, C) grid alone, matching the paper's
    modular-scaling argument rather than one encoder's packing order."""
    tiles = max(clause_tiles_parallel, 1)
    return -(-n_clause_cols // tiles) * T_COLUMN + T_COLUMN
