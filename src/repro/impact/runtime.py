"""Compiled-session runtime: ``RuntimeSpec`` -> ``InferenceSession``.

The paper's deployment story is a *fixed* fabricated system — tile
geometry, shard topology, and metering are decided once at programming
time, not per inference call.  This module gives the reproduction the
same shape: a frozen declarative **``RuntimeSpec``** (backend name, mesh
topology, metering mode, precision, interpret policy, slot capacity)
that ``IMPACTSystem.compile(spec)`` resolves ONCE into an immutable
**``InferenceSession``**:

* the backend is looked up in the registry (``kernels.backends``) at
  compile time — no per-call ``impl=`` string switches;
* the shard placement (``sharding.crossbar.shard_plan``: fully sharded,
  asymmetric R-only / S-only, or single-device) is resolved from the
  spec's topology at compile time — no per-call ``mesh=`` plumbing;
* every entry point (``predict`` / ``infer_step`` /
  ``infer_with_report``) is an AOT-lowered executable
  (``jax.jit(...).lower(...).compile()``) at the session's fixed shapes:
  ``capacity`` and ``batch_sizes`` compile at session build, other batch
  shapes compile once on first use and are cached — an executable can
  never retrace, which the session's trace counters
  (``session.trace_count``) pin in tests;
* results come back as a unified ``InferenceResult`` (predictions,
  scores, optional ``EnergyReport`` / per-lane energies) instead of
  per-entry-point tuple shapes.

The legacy per-call kwargs (``impl=``, ``mesh=``, ``meter=``,
``meter_energy=``) keep working through thin shims on ``IMPACTSystem``
and ``IMPACTEngine`` that emit ``SpecDeprecationWarning`` and forward to
a session cached on the system, so old call sites run unchanged (and
bit-identically) while the repo itself is held warning-clean by the
tier-1 filter in ``pytest.ini``.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import backends
from ..kernels import packing as packing_mod
from ..kernels import ref as kernels_ref
from ..sharding import crossbar as crossbar_sh
from . import energy as energy_mod
from .energy import EnergyReport
from .yflash import I_CSA_THRESHOLD, T_READ, V_READ

Array = jax.Array

METERING_MODES = ("off", "staged", "fused")
PRECISIONS = ("float32",)
#: Clause-crossbar operand layouts: ``"none"`` streams f32 per-cell
#: currents, ``"2bit"`` packs the ternary cells into the
#: ``kernels.packing`` bitplane layout at session build (compile time)
#: — the executable's dominant operand shrinks ~16x and unpacking fuses
#: into the kernel on the packed backends.
PACKINGS = ("none", "2bit")

#: Canonical input dtypes of every session executable.  Callers may pass
#: bool / int / float {0,1} literals; the session casts ONCE before the
#: executable so AOT avals never fragment by caller dtype.
LITERAL_DTYPE = jnp.int8


class SpecDeprecationWarning(DeprecationWarning):
    """Per-call runtime-config kwargs (``impl=`` / ``mesh=`` / ``meter=``
    / ``meter_energy=``) are deprecated: encode them in a ``RuntimeSpec``
    and run through ``IMPACTSystem.compile(spec)``.  Tier-1 promotes this
    warning to an error for the repo's own callers (``pytest.ini``)."""


@dataclasses.dataclass(frozen=True)
class Topology:
    """Where the crossbar grid lives on the device mesh.

    ``mesh``: a jax Mesh with a ``model`` axis (and optional
    ``pod``/``data`` batch axes); ``None`` inherits the system-level mesh
    from ``build_system(..., mesh=...)``.  ``shard`` picks the placement
    of the (R, S) shard grid on the model axis — ``"auto"`` shards
    whatever divides (both, R-only, or S-only with the other operand
    replicated), ``"both"``/``"r"``/``"s"`` demand a placement (compile
    raises if the shard count doesn't divide), ``"none"`` forces the
    single-device kernels even on a meshed system.
    """
    mesh: Any = None
    shard: str = "auto"

    def __post_init__(self):
        if self.shard not in crossbar_sh.SHARD_MODES:
            raise ValueError(
                f"topology shard mode must be one of "
                f"{crossbar_sh.SHARD_MODES}, got {self.shard!r}")


@dataclasses.dataclass(frozen=True)
class TenantSpan:
    """Half-open block spans of ONE resident tenant inside a co-resident
    combined grid: literal rows ``[lit_lo, lit_hi)``, clause columns
    ``[col_lo, col_hi)``, class columns ``[cls_lo, cls_hi)``.  Produced
    by ``build_coresident`` — the spans ARE the block-diagonal placement,
    and everything off-block is 0 A by construction."""
    lit_lo: int
    lit_hi: int
    col_lo: int
    col_hi: int
    cls_lo: int
    cls_hi: int

    def __post_init__(self):
        for lo, hi, what in ((self.lit_lo, self.lit_hi, "literal"),
                             (self.col_lo, self.col_hi, "clause"),
                             (self.cls_lo, self.cls_hi, "class")):
            if not 0 <= lo < hi:
                raise ValueError(f"tenant {what} span [{lo}, {hi}) is "
                                 f"empty or negative")


@dataclasses.dataclass(frozen=True)
class CoResidentPlan:
    """Hashable placement of T tenants on one shared crossbar grid.

    Ordered, non-overlapping ``TenantSpan`` blocks; tenant t's *model
    id* is its index here, and a co-resident session's executables take
    a per-lane ``model_ids`` (B,) int32 operand selecting which tenant
    each slot-table lane belongs to.  A frozen ``RuntimeSpec`` carries
    the plan (``coresident=``), so session caching and retrace guards
    work unchanged.
    """
    spans: tuple[TenantSpan, ...]

    def __post_init__(self):
        object.__setattr__(self, "spans", tuple(self.spans))
        if not self.spans:
            raise ValueError("a CoResidentPlan needs at least one tenant")
        for a, b in zip(self.spans, self.spans[1:]):
            if (b.lit_lo < a.lit_hi or b.col_lo < a.col_hi
                    or b.cls_lo < a.cls_hi):
                raise ValueError(
                    "tenant spans must be ordered and non-overlapping "
                    f"(got {a} then {b})")

    @property
    def n_tenants(self) -> int:
        return len(self.spans)

    @property
    def clause_spans(self) -> tuple[tuple[int, int], ...]:
        return tuple((s.col_lo, s.col_hi) for s in self.spans)

    @property
    def class_spans(self) -> tuple[tuple[int, int], ...]:
        return tuple((s.cls_lo, s.cls_hi) for s in self.spans)

    @property
    def literal_spans(self) -> tuple[tuple[int, int], ...]:
        return tuple((s.lit_lo, s.lit_hi) for s in self.spans)

    def validate_against(self, system) -> None:
        last = self.spans[-1]
        if (last.lit_hi > system.n_literals
                or last.col_hi > system.n_clauses
                or last.cls_hi > system.n_classes):
            raise ValueError(
                f"co-resident plan {last} exceeds the combined grid "
                f"(K={system.n_literals}, n={system.n_clauses}, "
                f"M={system.n_classes}) — compile the plan against the "
                f"system build_coresident returned it with")


@dataclasses.dataclass(frozen=True)
class RuntimeSpec:
    """Declarative, hashable description of ONE inference runtime.

    Resolved exactly once by ``IMPACTSystem.compile`` — everything that
    used to be a per-call kwarg is a field here:

    ==================  =============================================
    field               replaces
    ==================  =============================================
    ``backend``         ``impl="pallas" | "xla"`` (registry key)
    ``topology``        ``mesh=`` threading (+ asymmetric placement)
    ``metering``        ``meter=`` / ``meter_energy=``
    ``interpret``       ``interpret=`` (None = auto off-TPU)
    ``capacity``        the serving slot-table shape (``max_batch``)
    ``batch_sizes``     extra predict shapes to AOT-compile eagerly
    ``packing``         (new) clause-operand layout, see ``PACKINGS``
    ==================  =============================================

    ``metering="fused"`` accumulates the read-energy meters INSIDE the
    fused kernel (a second VMEM accumulator over the column currents the
    datapath already computes), so ``infer_with_report`` and per-request
    billing ride the fused single-pass path at serving speed;
    ``"staged"`` meters on the staged per-shard path — the slower oracle
    the fused meters are pinned against; ``"off"`` serves through the
    fused kernel at max throughput and bills nothing.  On a sharded
    topology both metered modes lower to the same ``shard_map`` datapath
    (its per-device stages materialize the partial currents anyway, and
    the per-lane meters are psummed exactly once).  ``precision`` is
    validated for forward compatibility (the analog model is float32 end
    to end today).

    ``packing="2bit"`` compiles the COMPRESSED datapath: the session
    quantizes the clause crossbar to the 2-bit bitplane layout once at
    build time, the executables take the packed codes + dequant levels
    as operands (~16x smaller than the f32 currents), and the packed
    backends unpack inside the kernel.  Argmax parity with the unpacked
    path holds on every backend and shard plan (the CSA decision bits
    survive quantization); ``"none"`` (default) is the f32 datapath.

    ``coresident`` (a ``CoResidentPlan`` from ``build_coresident``)
    compiles the MULTI-TENANT datapath: the system is a block-diagonal
    combined grid, every executable takes a per-lane ``model_ids``
    operand, predictions are tenant-LOCAL (argmax restricted to the
    lane's own class span), and per-lane meters are tenant-pure.
    Composes with ``packing="2bit"`` and all four shard plans.
    """
    backend: str = "pallas"
    topology: Topology = Topology()
    metering: str = "staged"
    precision: str = "float32"
    packing: str = "none"
    interpret: bool | None = None
    capacity: int | None = None
    batch_sizes: tuple[int, ...] = ()
    coresident: CoResidentPlan | None = None
    #: VMEM budget (bytes/core) the static IR audit prices kernel
    #: working sets against; None = analysis.vmem default (16 MiB).
    vmem_budget_bytes: int | None = None

    def __post_init__(self):
        if self.metering not in METERING_MODES:
            raise ValueError(f"metering must be one of {METERING_MODES}, "
                             f"got {self.metering!r}")
        if self.precision not in PRECISIONS:
            raise ValueError(f"precision must be one of {PRECISIONS}, "
                             f"got {self.precision!r}")
        if self.packing not in PACKINGS:
            raise ValueError(f"packing must be one of {PACKINGS}, "
                             f"got {self.packing!r}")
        if self.capacity is not None and self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        if self.vmem_budget_bytes is not None and self.vmem_budget_bytes < 1:
            raise ValueError(f"vmem_budget_bytes must be >= 1, "
                             f"got {self.vmem_budget_bytes}")
        object.__setattr__(self, "batch_sizes",
                           tuple(int(b) for b in self.batch_sizes))
        if any(b < 1 for b in self.batch_sizes):
            raise ValueError(f"batch_sizes must be >= 1, "
                             f"got {self.batch_sizes}")


@dataclasses.dataclass(frozen=True)
class InferenceResult:
    """Unified result of every session entry point.

    ``predictions`` is always set (sentinel -1 on invalid lanes for
    ``infer_step``); ``scores`` rides the fused paths that materialise
    class currents; ``report`` is the batch-level ``EnergyReport`` from
    ``infer_with_report``; the per-lane energies (J) ride ``infer_step``
    so a serving scheduler can bill each request individually.
    """
    predictions: Array
    scores: Array | None = None
    report: EnergyReport | None = None
    e_clause_lanes: Array | None = None
    e_class_lanes: Array | None = None


class InferenceSession:
    """Immutable compiled runtime for one ``(IMPACTSystem, RuntimeSpec)``.

    Built by ``IMPACTSystem.compile(spec)`` (which caches sessions per
    spec — compiling the same spec twice returns the same session).  All
    spec resolution (backend lookup, mesh/shard-plan placement, metering
    mode) happens here, once; the entry points only look up an
    executable and run it.
    """

    def __init__(self, system, spec: RuntimeSpec):
        self.spec = spec
        self.system = system
        self.backend = backends.get_backend(spec.backend)
        top = spec.topology
        self.mesh = top.mesh if top.mesh is not None else system.mesh
        R, S = system.clause_i.shape[0], system.class_i.shape[0]
        self.plan = (crossbar_sh.shard_plan(self.mesh, R, S, top.shard)
                     if self.mesh is not None else None)
        if self.mesh is None and top.shard not in ("auto", "none"):
            raise ValueError(
                f"topology demands shard={top.shard!r} but neither the "
                f"spec nor the system provides a mesh")
        self._nonempty = system._nonempty_eff()
        # Co-residency: the spec's plan is validated against the combined
        # grid once, and the tenant span tables become small embedded
        # constants of every executable (the per-lane model_ids operand
        # indexes them at run time).
        self.coresident = spec.coresident
        if self.coresident is not None:
            self.coresident.validate_against(system)
            self._clause_spans = jnp.asarray(self.coresident.clause_spans,
                                             jnp.int32)
            self._class_spans = jnp.asarray(self.coresident.class_spans,
                                            jnp.int32)
        # Compile-time packing: the quantized clause operand is built
        # ONCE here (concrete arrays), so every executable of this
        # session takes the 2-bit codes + levels instead of the f32
        # currents — the compressed layout is a property of the session,
        # not of any call.
        self._packed = (packing_mod.pack_clause_operand(system.clause_i)
                        if spec.packing == "2bit" else None)
        self._exes: dict[tuple[str, int], Any] = {}
        self._irs: dict[tuple[str, int], str] = {}
        self._traces: collections.Counter = collections.Counter()
        # Programming-time compilation: the serving sweep and any
        # declared predict shapes are executables before the first
        # request arrives.
        if spec.capacity is not None:
            self._exe("infer_step", spec.capacity)
        for b in spec.batch_sizes:
            self._exe("predict", b)

    # -- properties ---------------------------------------------------------
    @property
    def capacity(self) -> int | None:
        return self.spec.capacity

    @property
    def meters_energy(self) -> bool:
        return self.spec.metering != "off"

    @property
    def trace_count(self) -> int:
        """Total number of times any entry point's python body was traced
        (== number of compiles).  Frozen after warmup: the retrace-guard
        tests assert this does not move across serving."""
        return int(sum(self._traces.values()))

    def compiled_shapes(self, entry: str | None = None) -> list[tuple]:
        return sorted(k for k in self._exes
                      if entry is None or k[0] == entry)

    def is_compiled(self, entry: str, batch: int) -> bool:
        return (entry, batch) in self._exes

    def warm(self, batch: int, entry: str = "infer_step") -> None:
        """Ensure the ``(entry, batch)`` executable exists (AOT compile
        only — nothing is executed, unlike the old warmup sweeps)."""
        self._exe(entry, batch)

    def cost_analysis(self, entry: str, batch: int) -> dict[str, float]:
        """XLA's cost analysis of the ``(entry, batch)`` executable,
        normalized to ``{"flops", "bytes_accessed"}`` floats (missing
        counters report 0.0 — some lowerings omit them).  Compiles the
        executable on demand like every other session access; feeding
        the analytic cost model (``impact.costmodel``) this way means
        predictions always price the exact executable that serves."""
        exe = self._exe(entry, batch)
        ca = exe.cost_analysis()
        # jax has returned both a bare dict and a one-element list of
        # dicts across versions; normalize either.
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        ca = ca or {}
        return dict(flops=float(ca.get("flops", 0.0)),
                    bytes_accessed=float(ca.get("bytes accessed", 0.0)))

    def ir_text(self, entry: str, batch: int) -> str:
        """Lowered StableHLO of the ``(entry, batch)`` executable — the
        exact artifact handed to XLA, captured at compile time.  Compiles
        on demand like every other session access."""
        self._exe(entry, batch)
        return self._irs[(entry, batch)]

    def audit(self, entry: str | None = None, batch: int | None = None, *,
              baselines=None):
        """Static IR audit of this session's executables (see
        ``analysis.ir_audit``): precision ladder (no f64, no sub-f32
        meters), host isolation (no callbacks/infeed/outfeed), Pallas
        VMEM working set vs ``spec.vmem_budget_bytes``, and executable
        fingerprints (diffed against ``baselines`` when given).  Audits
        every compiled executable by default, or one ``(entry, batch)``
        pair — compiling it on demand."""
        from ..analysis import ir_audit as _ir_audit
        if entry is not None and batch is not None:
            self._exe(entry, batch)
        return _ir_audit.audit_session(self, entry, batch,
                                       baselines=baselines)

    # -- entry points -------------------------------------------------------
    def _model_ids(self, model_ids, batch: int) -> Array | None:
        """Canonicalize the per-lane tenant selector: required (and only
        accepted) on a co-resident session."""
        if self.coresident is None:
            if model_ids is not None:
                raise ValueError(
                    "model_ids= only applies to a co-resident session "
                    "(RuntimeSpec(coresident=...))")
            return None
        if model_ids is None:
            raise ValueError(
                "a co-resident session needs model_ids (B,) int32 — "
                "which tenant does each lane belong to?")
        mids = jnp.asarray(model_ids, jnp.int32)
        if mids.shape != (batch,):
            raise ValueError(f"model_ids shape {mids.shape} does not "
                             f"match the batch ({batch},)")
        return mids

    def predict(self, literals, model_ids=None) -> InferenceResult:
        """Fast path: fused crossbar->CSA->class-sum scores + argmax.

        On a co-resident session ``model_ids`` (B,) int32 selects each
        lane's tenant; predictions are tenant-LOCAL class indices and
        ``scores`` is the combined (B, M_total) current vector (zero
        outside each lane's own class span).
        """
        lits = self._lits(literals)
        mids = self._model_ids(model_ids, lits.shape[0])
        exe = self._exe("predict", lits.shape[0])
        if mids is None:
            preds, scores = exe(lits, *self._operands())
        else:
            preds, scores = exe(lits, mids, *self._operands())
        return InferenceResult(predictions=preds, scores=scores)

    def infer_step(self, literals, valid, model_ids=None) -> InferenceResult:
        """One scheduler sweep over a fixed-capacity slot buffer.

        ``valid`` (B,) marks occupied lanes; invalid lanes predict the
        sentinel -1 and bill exactly zero.  Per-lane read energies are
        zeros when the spec's metering is ``"off"`` (fused-kernel path).
        On a co-resident session ``model_ids`` selects each lane's
        tenant and predictions are tenant-local.
        """
        lits = self._lits(literals)
        v = jnp.asarray(valid, jnp.bool_)
        mids = self._model_ids(model_ids, lits.shape[0])
        exe = self._exe("infer_step", lits.shape[0])
        if mids is None:
            preds, e_cl, e_cs = exe(lits, v, *self._operands())
        else:
            preds, e_cl, e_cs = exe(lits, v, mids, *self._operands())
        return InferenceResult(predictions=preds, e_clause_lanes=e_cl,
                               e_class_lanes=e_cs)

    def infer_with_report(self, literals, valid=None,
                          model_ids=None) -> InferenceResult:
        """Metered inference with the paper's batch-level ``EnergyReport``
        — a single fused pass under ``metering="fused"``, the staged
        per-shard path under ``"staged"`` (same joules either way).
        ``valid`` (B,) bool marks real lanes in a padded batch; padding
        lanes are excluded from the energy/ops/datapoint accounting and
        predict the sentinel -1 (same contract as ``infer_step``)."""
        if not self.meters_energy:
            raise RuntimeError(
                "this session was compiled with metering='off' — "
                "infer_with_report needs RuntimeSpec(metering='fused') "
                "(single-pass, serving speed) or 'staged' (the oracle)")
        lits = self._lits(literals)
        B = lits.shape[0]
        v_np = (np.ones((B,), bool) if valid is None
                else np.asarray(valid, bool))
        mids = self._model_ids(model_ids, B)
        exe = self._exe("infer_with_report", B)
        if mids is None:
            preds, i_cl_sum, i_cs_sum = exe(lits, jnp.asarray(v_np),
                                            *self._operands())
        else:
            preds, i_cl_sum, i_cs_sum = exe(lits, jnp.asarray(v_np), mids,
                                            *self._operands())
        sys_ = self.system
        e_clause = float(V_READ * i_cl_sum * T_READ)
        e_class = float(V_READ * i_cs_sum * T_READ)
        n_dp = int(v_np.sum())
        ops_xp = n_dp * (sys_.n_literals * sys_.n_clauses
                         + sys_.n_clauses * sys_.n_classes)
        report = EnergyReport(
            read_energy_j=e_clause + e_class,
            clause_energy_j=e_clause, class_energy_j=e_class,
            program_energy_j=sys_.encode_stats["program_energy_j"],
            erase_energy_j=sys_.encode_stats["erase_energy_j"],
            latency_s=sys_._grid_latency(), ops_crosspoint=ops_xp,
            datapoints=n_dp, area_mm2=sum(sys_.area_mm2().values()))
        return InferenceResult(predictions=preds, report=report)

    def ta_feedback(self, lit2, fired2, sel, match, hi, lo, include) -> Array:
        """CoTM Type I/II TA feedback deltas -> (K, n) int32 — the online
        trainer's compiled update primitive (arXiv:2408.09456), routed
        through the session's registered backend like every serving entry.

        ``lit2`` (2B, K) doubled literal rows; ``fired2``/``sel``/``match``
        (2B, n) feedback masks; ``hi``/``lo`` (K, n) int32 Bernoulli
        draws; ``include`` (K, n) current TA actions.  All stochastic
        draws are precomputed operands, so the Pallas kernel and the
        einsum oracle return bit-identical deltas (see
        ``kernels.ref.ta_feedback_ref``).
        """
        lit2 = jnp.asarray(lit2, LITERAL_DTYPE)
        exe = self._exe("ta_feedback", lit2.shape[0])
        return exe(lit2, jnp.asarray(fired2, jnp.bool_),
                   jnp.asarray(sel, jnp.bool_),
                   jnp.asarray(match, jnp.bool_),
                   jnp.asarray(hi, jnp.int32), jnp.asarray(lo, jnp.int32),
                   jnp.asarray(include, jnp.bool_))

    # -- compiled-function plumbing -----------------------------------------
    def _lits(self, literals) -> Array:
        return jnp.asarray(literals, LITERAL_DTYPE)

    def _operands(self) -> tuple[Array, ...]:
        """The weight-side executable operands: ``(clause_i, nonempty,
        class_i)`` unpacked, ``(bits, levels, nonempty, class_i)`` for a
        ``packing="2bit"`` session."""
        sys_ = self.system
        if self._packed is not None:
            return (self._packed.bits, self._packed.levels,
                    self._nonempty, sys_.class_i)
        return sys_.clause_i, self._nonempty, sys_.class_i

    def input_bytes(self, entry: str, batch: int) -> int:
        """Exact byte count of the ``(entry, batch)`` executable's input
        arrays per sweep (the HBM-resident operand footprint the sweep
        must stream).  Independent of XLA's ``cost_analysis`` counters —
        this is the layout-level number the packing gate compares."""
        n = batch * self.system.n_literals * jnp.dtype(LITERAL_DTYPE).itemsize
        if entry != "predict":
            n += batch * jnp.dtype(jnp.bool_).itemsize      # valid mask
        if self.coresident is not None:
            n += batch * jnp.dtype(jnp.int32).itemsize      # model_ids
        for op in self._operands():
            n += op.size * op.dtype.itemsize
        return int(n)

    def _exe(self, entry: str, batch: int):
        key = (entry, batch)
        exe = self._exes.get(key)
        if exe is None:
            exe = self._compile_entry(entry, batch)
            self._exes[key] = exe
        return exe

    def _compile_entry(self, entry: str, batch: int):
        sys_ = self.system
        if entry == "ta_feedback":
            # The feedback entry is span-independent (no weight-side
            # constants, no tenant routing): ``batch`` is the DOUBLED
            # update-row count 2B.
            K, n = sys_.n_literals, sys_.n_clauses
            row = lambda dt: jax.ShapeDtypeStruct((batch, n), dt)
            cell = lambda dt: jax.ShapeDtypeStruct((K, n), dt)
            lowered = jax.jit(self._ta_feedback_fn).lower(
                jax.ShapeDtypeStruct((batch, K), LITERAL_DTYPE),
                row(jnp.bool_), row(jnp.bool_), row(jnp.bool_),
                cell(jnp.int32), cell(jnp.int32), cell(jnp.bool_))
            self._irs[(entry, batch)] = lowered.as_text()
            return lowered.compile()
        lit = jax.ShapeDtypeStruct((batch, sys_.n_literals), LITERAL_DTYPE)
        valid = jax.ShapeDtypeStruct((batch,), jnp.bool_)
        consts = self._operands()
        if self.coresident is not None:
            # Co-resident executables take the per-lane tenant selector
            # as one extra runtime operand, between the masks and the
            # weight-side constants.
            mids = jax.ShapeDtypeStruct((batch,), jnp.int32)
            if entry == "predict":
                lowered = jax.jit(self._predict_fn).lower(lit, mids, *consts)
            elif entry == "infer_step":
                lowered = jax.jit(self._infer_step_fn).lower(
                    lit, valid, mids, *consts)
            elif entry == "infer_with_report":
                lowered = jax.jit(self._report_fn).lower(
                    lit, valid, mids, *consts)
            else:
                raise ValueError(f"unknown entry point {entry!r}")
        elif entry == "predict":
            lowered = jax.jit(self._predict_fn).lower(lit, *consts)
        elif entry == "infer_step":
            lowered = jax.jit(self._infer_step_fn).lower(lit, valid, *consts)
        elif entry == "infer_with_report":
            lowered = jax.jit(self._report_fn).lower(lit, valid, *consts)
        else:
            raise ValueError(f"unknown entry point {entry!r}")
        # The lowered StableHLO is the artifact the static IR audit
        # scans; keep the text (the Lowered object does not survive
        # .compile()) so audits never retrace or recompile.
        self._irs[(entry, batch)] = lowered.as_text()
        return lowered.compile()

    # The traced bodies below run ONLY inside ``.lower()`` — the trace
    # counter bumps are python side effects that count compilations.
    def _scores_expr(self, literals, *operands):
        if self._packed is not None:
            bits, levels, nonempty, class_i = operands
            packed = packing_mod.PackedClause(bits=bits, levels=levels)
            tr = self.system.clause_i.shape[2]
            if self.plan is not None:
                return crossbar_sh.fused_impact_shmap(
                    literals, None, nonempty, class_i,
                    thresh=I_CSA_THRESHOLD, mesh=self.mesh,
                    impl=self.backend.name, interpret=self.spec.interpret,
                    shard_r=self.plan[0], shard_s=self.plan[1],
                    packed=packed, packed_tr=tr)
            return self.backend.fused_impact_packed(
                literals, packed, nonempty, class_i,
                thresh=I_CSA_THRESHOLD, tr=tr,
                interpret=self.spec.interpret)
        clause_i, nonempty, class_i = operands
        if self.plan is not None:
            return crossbar_sh.fused_impact_shmap(
                literals, clause_i, nonempty, class_i,
                thresh=I_CSA_THRESHOLD, mesh=self.mesh,
                impl=self.backend.name, interpret=self.spec.interpret,
                shard_r=self.plan[0], shard_s=self.plan[1])
        return self.backend.fused_impact(
            literals, clause_i, nonempty, class_i,
            thresh=I_CSA_THRESHOLD, interpret=self.spec.interpret)

    def _metered_expr(self, literals, valid, *operands):
        """Metered core -> (scores (B, m), per-lane summed clause currents
        (B,), per-lane summed class currents (B,)) — the ONE routing point
        between the shard_map lowering, the in-kernel fused meters, and
        the staged per-shard oracle, resolved from the compile-time spec.

        The three lowerings bill identically (pinned by the parity and
        property suites): per-lane meters are zero on invalid lanes and
        padding contributes zero current everywhere.

        A ``packing="2bit"`` session meters the QUANTIZED currents (what
        the packed cells draw): the fused mode rides the packed metered
        kernel, the staged oracle and the shard_map lowering dequantize
        the same codes — on an ideal (variability-free) system all of it
        is bit-identical to the unpacked meters.
        """
        if self._packed is not None:
            bits, levels, nonempty, class_i = operands
            packed = packing_mod.PackedClause(bits=bits, levels=levels)
            tr = self.system.clause_i.shape[2]
            if self.plan is not None:
                return crossbar_sh.fused_impact_shmap(
                    literals, None, nonempty, class_i,
                    thresh=I_CSA_THRESHOLD, mesh=self.mesh,
                    impl=self.backend.name, interpret=self.spec.interpret,
                    valid=valid, meter=True,
                    shard_r=self.plan[0], shard_s=self.plan[1],
                    packed=packed, packed_tr=tr)
            if self.spec.metering == "fused":
                scores, i_cl, i_cs = self.backend.fused_impact_packed_metered(
                    literals, packed, nonempty, class_i,
                    thresh=I_CSA_THRESHOLD, tr=tr,
                    interpret=self.spec.interpret)
                v = valid.astype(scores.dtype)
                return scores, i_cl * v, i_cs * v
            # Staged oracle on the dequantized currents.
            operands = (packing_mod.dequant_clause(bits, levels, tr),
                        nonempty, class_i)
        clause_i, nonempty, class_i = operands
        if self.plan is not None:
            # On a mesh both metered modes share the shard_map datapath:
            # its per-device stages materialize the partial currents
            # anyway, so the meters are psummed from what is already
            # computed — the same no-second-pass property the fused
            # kernel gives one device.
            return crossbar_sh.fused_impact_shmap(
                literals, clause_i, nonempty, class_i,
                thresh=I_CSA_THRESHOLD, mesh=self.mesh,
                impl=self.backend.name, interpret=self.spec.interpret,
                valid=valid, meter=True,
                shard_r=self.plan[0], shard_s=self.plan[1])
        if self.spec.metering == "fused":
            scores, i_cl, i_cs = self.backend.fused_impact_metered(
                literals, clause_i, nonempty, class_i,
                thresh=I_CSA_THRESHOLD, interpret=self.spec.interpret)
            # Meters are per-lane, so masking AFTER the fused pass is
            # exact: an invalid lane bills zero without touching any
            # other lane's currents.
            v = valid.astype(scores.dtype)
            return scores, i_cl * v, i_cs * v
        fired, i_clause = self.backend.impact_clause_bits(
            literals, clause_i, nonempty, thresh=I_CSA_THRESHOLD,
            interpret=self.spec.interpret)
        fired = jnp.logical_and(fired, valid[:, None])
        i_clause = i_clause * valid[:, None, None, None]
        scores, i_class = self.backend.impact_class_scores(
            fired, class_i, interpret=self.spec.interpret)
        return scores, i_clause.sum(axis=(1, 2, 3)), i_class.sum(axis=(1, 2))

    # -- co-resident traced expressions -------------------------------------
    def _co_lane_cols(self, model_ids):
        """(B, n) per-lane clause-column ownership mask (the CSA gating
        step of co-residency — see ``kernels.ref.coresident_lane_mask``)."""
        return kernels_ref.coresident_lane_mask(
            model_ids, self._clause_spans, self.system.n_clauses)

    def _co_pred(self, scores, model_ids):
        """Tenant-LOCAL argmax: restrict each lane's argmax to its own
        class span and rebase to span-local indices, so a co-resident
        lane predicts exactly what a standalone single-tenant session
        would."""
        lo = self._class_spans[model_ids, 0]
        hi = self._class_spans[model_ids, 1]
        col = jnp.arange(scores.shape[1], dtype=jnp.int32)[None, :]
        mask = jnp.logical_and(col >= lo[:, None], col < hi[:, None])
        masked = jnp.where(mask, scores, -jnp.inf)
        return jnp.argmax(masked, axis=-1).astype(jnp.int32) - lo

    def _co_scores_expr(self, literals, model_ids, *operands):
        """Co-resident twin of ``_scores_expr``: the same three routings
        (shard_map / packed / single-device) through the co-resident
        registry primitives, which gate fired bits to each lane's own
        clause-column span before the class stage."""
        if self._packed is not None:
            bits, levels, nonempty, class_i = operands
            packed = packing_mod.PackedClause(bits=bits, levels=levels)
            tr = self.system.clause_i.shape[2]
            if self.plan is not None:
                return crossbar_sh.fused_impact_shmap(
                    literals, None, nonempty, class_i,
                    thresh=I_CSA_THRESHOLD, mesh=self.mesh,
                    impl=self.backend.name, interpret=self.spec.interpret,
                    shard_r=self.plan[0], shard_s=self.plan[1],
                    packed=packed, packed_tr=tr,
                    lane_cols=self._co_lane_cols(model_ids))
            return self.backend.fused_impact_coresident_packed(
                literals, packed, nonempty, class_i, model_ids,
                self._clause_spans, thresh=I_CSA_THRESHOLD, tr=tr,
                interpret=self.spec.interpret)
        clause_i, nonempty, class_i = operands
        if self.plan is not None:
            return crossbar_sh.fused_impact_shmap(
                literals, clause_i, nonempty, class_i,
                thresh=I_CSA_THRESHOLD, mesh=self.mesh,
                impl=self.backend.name, interpret=self.spec.interpret,
                shard_r=self.plan[0], shard_s=self.plan[1],
                lane_cols=self._co_lane_cols(model_ids))
        return self.backend.fused_impact_coresident(
            literals, clause_i, nonempty, class_i, model_ids,
            self._clause_spans, thresh=I_CSA_THRESHOLD,
            interpret=self.spec.interpret)

    def _co_metered_expr(self, literals, valid, model_ids, *operands):
        """Metered co-resident core, mirroring ``_metered_expr``'s
        routing.  Both metering modes bill identically here: on a mesh
        the shard_map lowering meters the partial stages it materializes
        anyway (the lane mask rides ``lane_cols``); off-mesh the fused
        mode runs the co-resident registry primitive and masks invalid
        lanes after (exact — meters are per-lane), while the staged
        oracle masks fired bits before the class drive.  Valid lanes see
        the identical composition either way, and both per-lane meters
        are tenant-pure (foreign clause columns draw 0 A; the lane mask
        runs before the class drive)."""
        if self._packed is not None:
            bits, levels, nonempty, class_i = operands
            packed = packing_mod.PackedClause(bits=bits, levels=levels)
            tr = self.system.clause_i.shape[2]
            if self.plan is not None:
                return crossbar_sh.fused_impact_shmap(
                    literals, None, nonempty, class_i,
                    thresh=I_CSA_THRESHOLD, mesh=self.mesh,
                    impl=self.backend.name, interpret=self.spec.interpret,
                    valid=valid, meter=True,
                    shard_r=self.plan[0], shard_s=self.plan[1],
                    packed=packed, packed_tr=tr,
                    lane_cols=self._co_lane_cols(model_ids))
            if self.spec.metering == "fused":
                scores, i_cl, i_cs = (
                    self.backend.fused_impact_coresident_packed_metered(
                        literals, packed, nonempty, class_i, model_ids,
                        self._clause_spans, thresh=I_CSA_THRESHOLD, tr=tr,
                        interpret=self.spec.interpret))
                v = valid.astype(scores.dtype)
                return scores, i_cl * v, i_cs * v
            operands = (packing_mod.dequant_clause(bits, levels, tr),
                        nonempty, class_i)
        clause_i, nonempty, class_i = operands
        if self.plan is not None:
            return crossbar_sh.fused_impact_shmap(
                literals, clause_i, nonempty, class_i,
                thresh=I_CSA_THRESHOLD, mesh=self.mesh,
                impl=self.backend.name, interpret=self.spec.interpret,
                valid=valid, meter=True,
                shard_r=self.plan[0], shard_s=self.plan[1],
                lane_cols=self._co_lane_cols(model_ids))
        if self.spec.metering == "fused":
            scores, i_cl, i_cs = self.backend.fused_impact_coresident_metered(
                literals, clause_i, nonempty, class_i, model_ids,
                self._clause_spans, thresh=I_CSA_THRESHOLD,
                interpret=self.spec.interpret)
            v = valid.astype(scores.dtype)
            return scores, i_cl * v, i_cs * v
        fired, i_clause = self.backend.impact_clause_bits(
            literals, clause_i, nonempty, thresh=I_CSA_THRESHOLD,
            interpret=self.spec.interpret)
        fired = jnp.logical_and(fired, self._co_lane_cols(model_ids))
        fired = jnp.logical_and(fired, valid[:, None])
        i_clause = i_clause * valid[:, None, None, None]
        scores, i_class = self.backend.impact_class_scores(
            fired, class_i, interpret=self.spec.interpret)
        return scores, i_clause.sum(axis=(1, 2, 3)), i_class.sum(axis=(1, 2))

    def _ta_feedback_fn(self, lit2, fired2, sel, match, hi, lo, include):
        self._traces["ta_feedback"] += 1
        return self.backend.ta_feedback(lit2, fired2, sel, match, hi, lo,
                                        include,
                                        interpret=self.spec.interpret)

    def _predict_fn(self, literals, *args):
        self._traces["predict"] += 1
        if self.coresident is not None:
            model_ids, *operands = args
            scores = self._co_scores_expr(literals, model_ids, *operands)
            return self._co_pred(scores, model_ids), scores
        scores = self._scores_expr(literals, *args)
        return jnp.argmax(scores, axis=-1), scores

    def _infer_step_fn(self, literals, valid, *args):
        self._traces["infer_step"] += 1
        valid = valid.astype(bool)
        if self.coresident is not None:
            model_ids, *operands = args
            if not self.meters_energy:
                scores = self._co_scores_expr(literals, model_ids, *operands)
                zeros = jnp.zeros((literals.shape[0],), jnp.float32)
                return (jnp.where(valid, self._co_pred(scores, model_ids),
                                  -1), zeros, zeros)
            scores, i_cl, i_cs = self._co_metered_expr(
                literals, valid, model_ids, *operands)
            e_cl, e_cs = energy_mod.per_lane_read_energy(i_cl, i_cs)
            return (jnp.where(valid, self._co_pred(scores, model_ids), -1),
                    e_cl, e_cs)
        if not self.meters_energy:
            scores = self._scores_expr(literals, *args)
            zeros = jnp.zeros((literals.shape[0],), jnp.float32)
            return (jnp.where(valid, jnp.argmax(scores, axis=-1), -1),
                    zeros, zeros)
        scores, i_cl, i_cs = self._metered_expr(literals, valid, *args)
        e_cl, e_cs = energy_mod.per_lane_read_energy(i_cl, i_cs)
        return (jnp.where(valid, jnp.argmax(scores, axis=-1), -1),
                e_cl, e_cs)

    def _report_fn(self, literals, valid, *args):
        self._traces["infer_with_report"] += 1
        valid = valid.astype(bool)
        if self.coresident is not None:
            model_ids, *operands = args
            scores, i_cl_lane, i_cs_lane = self._co_metered_expr(
                literals, valid, model_ids, *operands)
            return (jnp.where(valid, self._co_pred(scores, model_ids), -1),
                    i_cl_lane.sum(), i_cs_lane.sum())
        scores, i_cl_lane, i_cs_lane = self._metered_expr(
            literals, valid, *args)
        # Sentinel invalid lanes like infer_step does: the staged and
        # fused lowerings see different scores on an excluded lane (one
        # zeroes its clause drive, the other doesn't), so its argmax is
        # meaningless — mask it instead of leaking a mode-dependent value.
        return (jnp.where(valid, jnp.argmax(scores, axis=-1), -1),
                i_cl_lane.sum(), i_cs_lane.sum())

    def __repr__(self) -> str:
        return (f"InferenceSession(backend={self.spec.backend!r}, "
                f"plan={self.plan}, metering={self.spec.metering!r}, "
                f"packing={self.spec.packing!r}, "
                f"capacity={self.spec.capacity}, "
                f"compiled={self.compiled_shapes()})")


def build_coresident(systems) -> tuple[Any, CoResidentPlan]:
    """Pack several small single-tile systems block-diagonally onto ONE
    shared crossbar grid -> ``(combined IMPACTSystem, CoResidentPlan)``.

    Tenant t's clause grid occupies literal rows ``[lit_lo, lit_hi)`` x
    clause columns ``[col_lo, col_hi)`` and its class grid clause rows
    ``[col_lo, col_hi)`` x class columns ``[cls_lo, cls_hi)``; every
    off-block cell holds 0 S / 0 A — a physically absent device — so
    cross-tenant current leakage is exactly zero by construction, not
    merely below a tolerance.  Member tile *padding* cells (rows/columns
    beyond each member's true dims) are dropped: only the real
    ``[:K_t, :n_t]`` / ``[:n_t, :M_t]`` regions are copied, which keeps
    score and argmax parity with each standalone session exact (padding
    rows float, padding columns never fire).

    Members must be single-tile (R = C = S = 1): co-residency is the
    many-small-models regime (IMBUE-style — several TM clause grids fit
    one crossbar's footprint); a model big enough to shard has the whole
    fabric to itself.  The combined grid must also still fit one tile of
    the first member's ``IMPACTConfig``.

    Compile with ``combined.compile(RuntimeSpec(coresident=plan, ...))``;
    tenant t's lanes pass ``model_ids == t``.
    """
    systems = list(systems)
    if not systems:
        raise ValueError("build_coresident needs at least one system")
    from .pipeline import IMPACTSystem  # avoid import cycle at module load

    for i, s in enumerate(systems):
        R, C = s.clause_i.shape[0], s.clause_i.shape[1]
        S = s.class_i.shape[0]
        if (R, C, S) != (1, 1, 1):
            raise ValueError(
                f"co-residency packs single-tile systems; member {i} has "
                f"a (R={R}, C={C}, S={S}) shard grid — a model that "
                f"large should own the fabric (shard it) instead of "
                f"co-residing")
    K_tot = sum(s.n_literals for s in systems)
    n_tot = sum(s.n_clauses for s in systems)
    M_tot = sum(s.n_classes for s in systems)
    cfg = systems[0].cfg
    if (K_tot > cfg.max_tile_rows or n_tot > cfg.max_tile_cols
            or n_tot > cfg.max_class_rows):
        raise ValueError(
            f"combined co-resident grid (K={K_tot}, n={n_tot}) does not "
            f"fit one tile (max_tile_rows={cfg.max_tile_rows}, "
            f"max_tile_cols={cfg.max_tile_cols}, "
            f"max_class_rows={cfg.max_class_rows}) — fewer residents per "
            f"fabric, or bigger tiles")

    clause_g = np.zeros((1, 1, K_tot, n_tot), np.float32)
    clause_i = np.zeros((1, 1, K_tot, n_tot), np.float32)
    nonempty = np.zeros((n_tot,), bool)
    class_g = np.zeros((1, n_tot, M_tot), np.float32)
    class_i = np.zeros((1, n_tot, M_tot), np.float32)
    spans = []
    k0 = c0 = m0 = 0
    prog = erase = 0.0
    for s in systems:
        K, n, M = s.n_literals, s.n_clauses, s.n_classes
        clause_g[0, 0, k0:k0 + K, c0:c0 + n] = np.asarray(
            s.clause_g[0, 0, :K, :n])
        clause_i[0, 0, k0:k0 + K, c0:c0 + n] = np.asarray(
            s.clause_i[0, 0, :K, :n])
        nonempty[c0:c0 + n] = np.asarray(s.nonempty[:n])
        class_g[0, c0:c0 + n, m0:m0 + M] = np.asarray(s.class_g[0, :n, :M])
        class_i[0, c0:c0 + n, m0:m0 + M] = np.asarray(s.class_i[0, :n, :M])
        spans.append(TenantSpan(lit_lo=k0, lit_hi=k0 + K,
                                col_lo=c0, col_hi=c0 + n,
                                cls_lo=m0, cls_hi=m0 + M))
        k0, c0, m0 = k0 + K, c0 + n, m0 + M
        prog += float(s.encode_stats.get("program_energy_j", 0.0))
        erase += float(s.encode_stats.get("erase_energy_j", 0.0))
    combined = IMPACTSystem(
        clause_g=jnp.asarray(clause_g), nonempty=jnp.asarray(nonempty),
        class_g=jnp.asarray(class_g), clause_i=jnp.asarray(clause_i),
        class_i=jnp.asarray(class_i), n_literals=K_tot, n_clauses=n_tot,
        n_classes=M_tot, cfg=cfg,
        encode_stats=dict(program_energy_j=prog, erase_energy_j=erase,
                          coresident_members=len(systems)),
        mesh=systems[0].mesh)
    return combined, CoResidentPlan(spans=tuple(spans))


def legacy_spec(*, impl: str | None = None, mesh=None,
                metering: str | None = None,
                capacity: int | None = None) -> RuntimeSpec:
    """Map the deprecated per-call kwargs onto a ``RuntimeSpec`` (the
    shims' forwarding table; see the migration table in the README)."""
    return RuntimeSpec(
        backend=impl if impl is not None else "pallas",
        topology=Topology(mesh=mesh),
        metering=metering if metering is not None else "staged",
        capacity=capacity)
