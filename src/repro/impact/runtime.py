"""Compiled-session runtime: ``RuntimeSpec`` -> ``InferenceSession``.

The paper's deployment story is a *fixed* fabricated system — tile
geometry, shard topology, and metering are decided once at programming
time, not per inference call.  This module gives the reproduction the
same shape: a frozen declarative **``RuntimeSpec``** (backend name, mesh
topology, metering mode, precision, interpret policy, slot capacity)
that ``IMPACTSystem.compile(spec)`` resolves ONCE into an immutable
**``InferenceSession``**:

* the backend is looked up in the registry (``kernels.backends``) at
  compile time — no per-call ``impl=`` string switches;
* the shard placement (``sharding.crossbar.shard_plan``: fully sharded,
  asymmetric R-only / S-only, or single-device) is resolved from the
  spec's topology at compile time — no per-call ``mesh=`` plumbing;
* every entry point (``predict`` / ``infer_step`` /
  ``infer_with_report``) is an AOT-lowered executable
  (``jax.jit(...).lower(...).compile()``) at the session's fixed shapes:
  ``capacity`` and ``batch_sizes`` compile at session build, other batch
  shapes compile once on first use and are cached — an executable can
  never retrace, which the session's trace counters
  (``session.trace_count``) pin in tests;
* results come back as a unified ``InferenceResult`` (predictions,
  scores, optional ``EnergyReport`` / per-lane energies) instead of
  per-entry-point tuple shapes.

The legacy per-call kwargs (``impl=``, ``mesh=``, ``meter=``,
``meter_energy=``) keep working through thin shims on ``IMPACTSystem``
and ``IMPACTEngine`` that emit ``SpecDeprecationWarning`` and forward to
a session cached on the system, so old call sites run unchanged (and
bit-identically) while the repo itself is held warning-clean by the
tier-1 filter in ``pytest.ini``.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import backends
from ..kernels import packing as packing_mod
from ..sharding import crossbar as crossbar_sh
from . import energy as energy_mod
from .energy import EnergyReport
from .yflash import I_CSA_THRESHOLD, T_READ, V_READ

Array = jax.Array

METERING_MODES = ("off", "staged", "fused")
PRECISIONS = ("float32",)
#: Clause-crossbar operand layouts: ``"none"`` streams f32 per-cell
#: currents, ``"2bit"`` packs the ternary cells into the
#: ``kernels.packing`` bitplane layout at session build (compile time)
#: — the executable's dominant operand shrinks ~16x and unpacking fuses
#: into the kernel on the packed backends.
PACKINGS = ("none", "2bit")

#: Canonical input dtypes of every session executable.  Callers may pass
#: bool / int / float {0,1} literals; the session casts ONCE before the
#: executable so AOT avals never fragment by caller dtype.
LITERAL_DTYPE = jnp.int8


class SpecDeprecationWarning(DeprecationWarning):
    """Per-call runtime-config kwargs (``impl=`` / ``mesh=`` / ``meter=``
    / ``meter_energy=``) are deprecated: encode them in a ``RuntimeSpec``
    and run through ``IMPACTSystem.compile(spec)``.  Tier-1 promotes this
    warning to an error for the repo's own callers (``pytest.ini``)."""


@dataclasses.dataclass(frozen=True)
class Topology:
    """Where the crossbar grid lives on the device mesh.

    ``mesh``: a jax Mesh with a ``model`` axis (and optional
    ``pod``/``data`` batch axes); ``None`` inherits the system-level mesh
    from ``build_system(..., mesh=...)``.  ``shard`` picks the placement
    of the (R, S) shard grid on the model axis — ``"auto"`` shards
    whatever divides (both, R-only, or S-only with the other operand
    replicated), ``"both"``/``"r"``/``"s"`` demand a placement (compile
    raises if the shard count doesn't divide), ``"none"`` forces the
    single-device kernels even on a meshed system.
    """
    mesh: Any = None
    shard: str = "auto"

    def __post_init__(self):
        if self.shard not in crossbar_sh.SHARD_MODES:
            raise ValueError(
                f"topology shard mode must be one of "
                f"{crossbar_sh.SHARD_MODES}, got {self.shard!r}")


@dataclasses.dataclass(frozen=True)
class RuntimeSpec:
    """Declarative, hashable description of ONE inference runtime.

    Resolved exactly once by ``IMPACTSystem.compile`` — everything that
    used to be a per-call kwarg is a field here:

    ==================  =============================================
    field               replaces
    ==================  =============================================
    ``backend``         ``impl="pallas" | "xla"`` (registry key)
    ``topology``        ``mesh=`` threading (+ asymmetric placement)
    ``metering``        ``meter=`` / ``meter_energy=``
    ``interpret``       ``interpret=`` (None = auto off-TPU)
    ``capacity``        the serving slot-table shape (``max_batch``)
    ``batch_sizes``     extra predict shapes to AOT-compile eagerly
    ``packing``         (new) clause-operand layout, see ``PACKINGS``
    ==================  =============================================

    ``metering="fused"`` accumulates the read-energy meters INSIDE the
    fused kernel (a second VMEM accumulator over the column currents the
    datapath already computes), so ``infer_with_report`` and per-request
    billing ride the fused single-pass path at serving speed;
    ``"staged"`` meters on the staged per-shard path — the slower oracle
    the fused meters are pinned against; ``"off"`` serves through the
    fused kernel at max throughput and bills nothing.  On a sharded
    topology both metered modes lower to the same ``shard_map`` datapath
    (its per-device stages materialize the partial currents anyway, and
    the per-lane meters are psummed exactly once).  ``precision`` is
    validated for forward compatibility (the analog model is float32 end
    to end today).

    ``packing="2bit"`` compiles the COMPRESSED datapath: the session
    quantizes the clause crossbar to the 2-bit bitplane layout once at
    build time, the executables take the packed codes + dequant levels
    as operands (~16x smaller than the f32 currents), and the packed
    backends unpack inside the kernel.  Argmax parity with the unpacked
    path holds on every backend and shard plan (the CSA decision bits
    survive quantization); ``"none"`` (default) is the f32 datapath.
    """
    backend: str = "pallas"
    topology: Topology = Topology()
    metering: str = "staged"
    precision: str = "float32"
    packing: str = "none"
    interpret: bool | None = None
    capacity: int | None = None
    batch_sizes: tuple[int, ...] = ()

    def __post_init__(self):
        if self.metering not in METERING_MODES:
            raise ValueError(f"metering must be one of {METERING_MODES}, "
                             f"got {self.metering!r}")
        if self.precision not in PRECISIONS:
            raise ValueError(f"precision must be one of {PRECISIONS}, "
                             f"got {self.precision!r}")
        if self.packing not in PACKINGS:
            raise ValueError(f"packing must be one of {PACKINGS}, "
                             f"got {self.packing!r}")
        if self.capacity is not None and self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        object.__setattr__(self, "batch_sizes",
                           tuple(int(b) for b in self.batch_sizes))
        if any(b < 1 for b in self.batch_sizes):
            raise ValueError(f"batch_sizes must be >= 1, "
                             f"got {self.batch_sizes}")


@dataclasses.dataclass(frozen=True)
class InferenceResult:
    """Unified result of every session entry point.

    ``predictions`` is always set (sentinel -1 on invalid lanes for
    ``infer_step``); ``scores`` rides the fused paths that materialise
    class currents; ``report`` is the batch-level ``EnergyReport`` from
    ``infer_with_report``; the per-lane energies (J) ride ``infer_step``
    so a serving scheduler can bill each request individually.
    """
    predictions: Array
    scores: Array | None = None
    report: EnergyReport | None = None
    e_clause_lanes: Array | None = None
    e_class_lanes: Array | None = None


class InferenceSession:
    """Immutable compiled runtime for one ``(IMPACTSystem, RuntimeSpec)``.

    Built by ``IMPACTSystem.compile(spec)`` (which caches sessions per
    spec — compiling the same spec twice returns the same session).  All
    spec resolution (backend lookup, mesh/shard-plan placement, metering
    mode) happens here, once; the entry points only look up an
    executable and run it.
    """

    def __init__(self, system, spec: RuntimeSpec):
        self.spec = spec
        self.system = system
        self.backend = backends.get_backend(spec.backend)
        top = spec.topology
        self.mesh = top.mesh if top.mesh is not None else system.mesh
        R, S = system.clause_i.shape[0], system.class_i.shape[0]
        self.plan = (crossbar_sh.shard_plan(self.mesh, R, S, top.shard)
                     if self.mesh is not None else None)
        if self.mesh is None and top.shard not in ("auto", "none"):
            raise ValueError(
                f"topology demands shard={top.shard!r} but neither the "
                f"spec nor the system provides a mesh")
        self._nonempty = system._nonempty_eff()
        # Compile-time packing: the quantized clause operand is built
        # ONCE here (concrete arrays), so every executable of this
        # session takes the 2-bit codes + levels instead of the f32
        # currents — the compressed layout is a property of the session,
        # not of any call.
        self._packed = (packing_mod.pack_clause_operand(system.clause_i)
                        if spec.packing == "2bit" else None)
        self._exes: dict[tuple[str, int], Any] = {}
        self._traces: collections.Counter = collections.Counter()
        # Programming-time compilation: the serving sweep and any
        # declared predict shapes are executables before the first
        # request arrives.
        if spec.capacity is not None:
            self._exe("infer_step", spec.capacity)
        for b in spec.batch_sizes:
            self._exe("predict", b)

    # -- properties ---------------------------------------------------------
    @property
    def capacity(self) -> int | None:
        return self.spec.capacity

    @property
    def meters_energy(self) -> bool:
        return self.spec.metering != "off"

    @property
    def trace_count(self) -> int:
        """Total number of times any entry point's python body was traced
        (== number of compiles).  Frozen after warmup: the retrace-guard
        tests assert this does not move across serving."""
        return int(sum(self._traces.values()))

    def compiled_shapes(self, entry: str | None = None) -> list[tuple]:
        return sorted(k for k in self._exes
                      if entry is None or k[0] == entry)

    def is_compiled(self, entry: str, batch: int) -> bool:
        return (entry, batch) in self._exes

    def warm(self, batch: int, entry: str = "infer_step") -> None:
        """Ensure the ``(entry, batch)`` executable exists (AOT compile
        only — nothing is executed, unlike the old warmup sweeps)."""
        self._exe(entry, batch)

    def cost_analysis(self, entry: str, batch: int) -> dict[str, float]:
        """XLA's cost analysis of the ``(entry, batch)`` executable,
        normalized to ``{"flops", "bytes_accessed"}`` floats (missing
        counters report 0.0 — some lowerings omit them).  Compiles the
        executable on demand like every other session access; feeding
        the analytic cost model (``impact.costmodel``) this way means
        predictions always price the exact executable that serves."""
        exe = self._exe(entry, batch)
        ca = exe.cost_analysis()
        # jax has returned both a bare dict and a one-element list of
        # dicts across versions; normalize either.
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        ca = ca or {}
        return dict(flops=float(ca.get("flops", 0.0)),
                    bytes_accessed=float(ca.get("bytes accessed", 0.0)))

    # -- entry points -------------------------------------------------------
    def predict(self, literals) -> InferenceResult:
        """Fast path: fused crossbar->CSA->class-sum scores + argmax."""
        lits = self._lits(literals)
        exe = self._exe("predict", lits.shape[0])
        preds, scores = exe(lits, *self._operands())
        return InferenceResult(predictions=preds, scores=scores)

    def infer_step(self, literals, valid) -> InferenceResult:
        """One scheduler sweep over a fixed-capacity slot buffer.

        ``valid`` (B,) marks occupied lanes; invalid lanes predict the
        sentinel -1 and bill exactly zero.  Per-lane read energies are
        zeros when the spec's metering is ``"off"`` (fused-kernel path).
        """
        lits = self._lits(literals)
        v = jnp.asarray(valid, jnp.bool_)
        exe = self._exe("infer_step", lits.shape[0])
        preds, e_cl, e_cs = exe(lits, v, *self._operands())
        return InferenceResult(predictions=preds, e_clause_lanes=e_cl,
                               e_class_lanes=e_cs)

    def infer_with_report(self, literals, valid=None) -> InferenceResult:
        """Metered inference with the paper's batch-level ``EnergyReport``
        — a single fused pass under ``metering="fused"``, the staged
        per-shard path under ``"staged"`` (same joules either way).
        ``valid`` (B,) bool marks real lanes in a padded batch; padding
        lanes are excluded from the energy/ops/datapoint accounting and
        predict the sentinel -1 (same contract as ``infer_step``)."""
        if not self.meters_energy:
            raise RuntimeError(
                "this session was compiled with metering='off' — "
                "infer_with_report needs RuntimeSpec(metering='fused') "
                "(single-pass, serving speed) or 'staged' (the oracle)")
        lits = self._lits(literals)
        B = lits.shape[0]
        v_np = (np.ones((B,), bool) if valid is None
                else np.asarray(valid, bool))
        exe = self._exe("infer_with_report", B)
        preds, i_cl_sum, i_cs_sum = exe(lits, jnp.asarray(v_np),
                                        *self._operands())
        sys_ = self.system
        e_clause = float(V_READ * i_cl_sum * T_READ)
        e_class = float(V_READ * i_cs_sum * T_READ)
        n_dp = int(v_np.sum())
        ops_xp = n_dp * (sys_.n_literals * sys_.n_clauses
                         + sys_.n_clauses * sys_.n_classes)
        report = EnergyReport(
            read_energy_j=e_clause + e_class,
            clause_energy_j=e_clause, class_energy_j=e_class,
            program_energy_j=sys_.encode_stats["program_energy_j"],
            erase_energy_j=sys_.encode_stats["erase_energy_j"],
            latency_s=sys_._grid_latency(), ops_crosspoint=ops_xp,
            datapoints=n_dp, area_mm2=sum(sys_.area_mm2().values()))
        return InferenceResult(predictions=preds, report=report)

    # -- compiled-function plumbing -----------------------------------------
    def _lits(self, literals) -> Array:
        return jnp.asarray(literals, LITERAL_DTYPE)

    def _operands(self) -> tuple[Array, ...]:
        """The weight-side executable operands: ``(clause_i, nonempty,
        class_i)`` unpacked, ``(bits, levels, nonempty, class_i)`` for a
        ``packing="2bit"`` session."""
        sys_ = self.system
        if self._packed is not None:
            return (self._packed.bits, self._packed.levels,
                    self._nonempty, sys_.class_i)
        return sys_.clause_i, self._nonempty, sys_.class_i

    def input_bytes(self, entry: str, batch: int) -> int:
        """Exact byte count of the ``(entry, batch)`` executable's input
        arrays per sweep (the HBM-resident operand footprint the sweep
        must stream).  Independent of XLA's ``cost_analysis`` counters —
        this is the layout-level number the packing gate compares."""
        n = batch * self.system.n_literals * jnp.dtype(LITERAL_DTYPE).itemsize
        if entry != "predict":
            n += batch * jnp.dtype(jnp.bool_).itemsize      # valid mask
        for op in self._operands():
            n += op.size * op.dtype.itemsize
        return int(n)

    def _exe(self, entry: str, batch: int):
        key = (entry, batch)
        exe = self._exes.get(key)
        if exe is None:
            exe = self._compile_entry(entry, batch)
            self._exes[key] = exe
        return exe

    def _compile_entry(self, entry: str, batch: int):
        sys_ = self.system
        lit = jax.ShapeDtypeStruct((batch, sys_.n_literals), LITERAL_DTYPE)
        valid = jax.ShapeDtypeStruct((batch,), jnp.bool_)
        consts = self._operands()
        if entry == "predict":
            lowered = jax.jit(self._predict_fn).lower(lit, *consts)
        elif entry == "infer_step":
            lowered = jax.jit(self._infer_step_fn).lower(lit, valid, *consts)
        elif entry == "infer_with_report":
            lowered = jax.jit(self._report_fn).lower(lit, valid, *consts)
        else:
            raise ValueError(f"unknown entry point {entry!r}")
        return lowered.compile()

    # The traced bodies below run ONLY inside ``.lower()`` — the trace
    # counter bumps are python side effects that count compilations.
    def _scores_expr(self, literals, *operands):
        if self._packed is not None:
            bits, levels, nonempty, class_i = operands
            packed = packing_mod.PackedClause(bits=bits, levels=levels)
            tr = self.system.clause_i.shape[2]
            if self.plan is not None:
                return crossbar_sh.fused_impact_shmap(
                    literals, None, nonempty, class_i,
                    thresh=I_CSA_THRESHOLD, mesh=self.mesh,
                    impl=self.backend.name, interpret=self.spec.interpret,
                    shard_r=self.plan[0], shard_s=self.plan[1],
                    packed=packed, packed_tr=tr)
            return self.backend.fused_impact_packed(
                literals, packed, nonempty, class_i,
                thresh=I_CSA_THRESHOLD, tr=tr,
                interpret=self.spec.interpret)
        clause_i, nonempty, class_i = operands
        if self.plan is not None:
            return crossbar_sh.fused_impact_shmap(
                literals, clause_i, nonempty, class_i,
                thresh=I_CSA_THRESHOLD, mesh=self.mesh,
                impl=self.backend.name, interpret=self.spec.interpret,
                shard_r=self.plan[0], shard_s=self.plan[1])
        return self.backend.fused_impact(
            literals, clause_i, nonempty, class_i,
            thresh=I_CSA_THRESHOLD, interpret=self.spec.interpret)

    def _metered_expr(self, literals, valid, *operands):
        """Metered core -> (scores (B, m), per-lane summed clause currents
        (B,), per-lane summed class currents (B,)) — the ONE routing point
        between the shard_map lowering, the in-kernel fused meters, and
        the staged per-shard oracle, resolved from the compile-time spec.

        The three lowerings bill identically (pinned by the parity and
        property suites): per-lane meters are zero on invalid lanes and
        padding contributes zero current everywhere.

        A ``packing="2bit"`` session meters the QUANTIZED currents (what
        the packed cells draw): the fused mode rides the packed metered
        kernel, the staged oracle and the shard_map lowering dequantize
        the same codes — on an ideal (variability-free) system all of it
        is bit-identical to the unpacked meters.
        """
        if self._packed is not None:
            bits, levels, nonempty, class_i = operands
            packed = packing_mod.PackedClause(bits=bits, levels=levels)
            tr = self.system.clause_i.shape[2]
            if self.plan is not None:
                return crossbar_sh.fused_impact_shmap(
                    literals, None, nonempty, class_i,
                    thresh=I_CSA_THRESHOLD, mesh=self.mesh,
                    impl=self.backend.name, interpret=self.spec.interpret,
                    valid=valid, meter=True,
                    shard_r=self.plan[0], shard_s=self.plan[1],
                    packed=packed, packed_tr=tr)
            if self.spec.metering == "fused":
                scores, i_cl, i_cs = self.backend.fused_impact_packed_metered(
                    literals, packed, nonempty, class_i,
                    thresh=I_CSA_THRESHOLD, tr=tr,
                    interpret=self.spec.interpret)
                v = valid.astype(scores.dtype)
                return scores, i_cl * v, i_cs * v
            # Staged oracle on the dequantized currents.
            operands = (packing_mod.dequant_clause(bits, levels, tr),
                        nonempty, class_i)
        clause_i, nonempty, class_i = operands
        if self.plan is not None:
            # On a mesh both metered modes share the shard_map datapath:
            # its per-device stages materialize the partial currents
            # anyway, so the meters are psummed from what is already
            # computed — the same no-second-pass property the fused
            # kernel gives one device.
            return crossbar_sh.fused_impact_shmap(
                literals, clause_i, nonempty, class_i,
                thresh=I_CSA_THRESHOLD, mesh=self.mesh,
                impl=self.backend.name, interpret=self.spec.interpret,
                valid=valid, meter=True,
                shard_r=self.plan[0], shard_s=self.plan[1])
        if self.spec.metering == "fused":
            scores, i_cl, i_cs = self.backend.fused_impact_metered(
                literals, clause_i, nonempty, class_i,
                thresh=I_CSA_THRESHOLD, interpret=self.spec.interpret)
            # Meters are per-lane, so masking AFTER the fused pass is
            # exact: an invalid lane bills zero without touching any
            # other lane's currents.
            v = valid.astype(scores.dtype)
            return scores, i_cl * v, i_cs * v
        fired, i_clause = self.backend.impact_clause_bits(
            literals, clause_i, nonempty, thresh=I_CSA_THRESHOLD,
            interpret=self.spec.interpret)
        fired = jnp.logical_and(fired, valid[:, None])
        i_clause = i_clause * valid[:, None, None, None]
        scores, i_class = self.backend.impact_class_scores(
            fired, class_i, interpret=self.spec.interpret)
        return scores, i_clause.sum(axis=(1, 2, 3)), i_class.sum(axis=(1, 2))

    def _predict_fn(self, literals, *operands):
        self._traces["predict"] += 1
        scores = self._scores_expr(literals, *operands)
        return jnp.argmax(scores, axis=-1), scores

    def _infer_step_fn(self, literals, valid, *operands):
        self._traces["infer_step"] += 1
        valid = valid.astype(bool)
        if not self.meters_energy:
            scores = self._scores_expr(literals, *operands)
            zeros = jnp.zeros((literals.shape[0],), jnp.float32)
            return (jnp.where(valid, jnp.argmax(scores, axis=-1), -1),
                    zeros, zeros)
        scores, i_cl, i_cs = self._metered_expr(literals, valid, *operands)
        e_cl, e_cs = energy_mod.per_lane_read_energy(i_cl, i_cs)
        return (jnp.where(valid, jnp.argmax(scores, axis=-1), -1),
                e_cl, e_cs)

    def _report_fn(self, literals, valid, *operands):
        self._traces["infer_with_report"] += 1
        valid = valid.astype(bool)
        scores, i_cl_lane, i_cs_lane = self._metered_expr(
            literals, valid, *operands)
        # Sentinel invalid lanes like infer_step does: the staged and
        # fused lowerings see different scores on an excluded lane (one
        # zeroes its clause drive, the other doesn't), so its argmax is
        # meaningless — mask it instead of leaking a mode-dependent value.
        return (jnp.where(valid, jnp.argmax(scores, axis=-1), -1),
                i_cl_lane.sum(), i_cs_lane.sum())

    def __repr__(self) -> str:
        return (f"InferenceSession(backend={self.spec.backend!r}, "
                f"plan={self.plan}, metering={self.spec.metering!r}, "
                f"packing={self.spec.packing!r}, "
                f"capacity={self.spec.capacity}, "
                f"compiled={self.compiled_shapes()})")


def legacy_spec(*, impl: str | None = None, mesh=None,
                metering: str | None = None,
                capacity: int | None = None) -> RuntimeSpec:
    """Map the deprecated per-call kwargs onto a ``RuntimeSpec`` (the
    shims' forwarding table; see the migration table in the README)."""
    return RuntimeSpec(
        backend=impl if impl is not None else "pallas",
        topology=Topology(mesh=mesh),
        metering=metering if metering is not None else "staged",
        capacity=capacity)
