from .costmodel import CostEstimate, SweepCostModel
from .energy import EnergyReport
from .pipeline import IMPACTConfig, IMPACTSystem, build_system
from .runtime import (CoResidentPlan, InferenceResult, InferenceSession,
                      RuntimeSpec, SpecDeprecationWarning, TenantSpan,
                      Topology, build_coresident)
from .tiles import (ClassTile, ClauseTile, encode_class_tile,
                    encode_clause_tile, weight_targets)
from .yflash import (DeviceVariation, G_HCS_BOOL, G_LCS, I_CSA_THRESHOLD,
                     erase_pulse, program_pulse, pulse_until, read_current)

__all__ = [
    "CostEstimate", "SweepCostModel",
    "EnergyReport", "IMPACTConfig", "IMPACTSystem", "build_system",
    "CoResidentPlan", "InferenceResult", "InferenceSession", "RuntimeSpec",
    "SpecDeprecationWarning", "TenantSpan", "Topology", "build_coresident",
    "ClassTile", "ClauseTile", "encode_class_tile", "encode_clause_tile",
    "weight_targets", "DeviceVariation", "G_HCS_BOOL", "G_LCS",
    "I_CSA_THRESHOLD", "erase_pulse", "program_pulse", "pulse_until",
    "read_current",
]
