"""Calibrated analytic sweep-cost model: predicted vs measured sweep time.

The byteprofile approach (HLO cost analysis paired with measured step
time) applied to the compiled-session runtime: every
``InferenceSession`` entry point is an AOT executable, so XLA's
``cost_analysis`` gives exact per-executable flops / bytes-accessed for
the *thing that actually serves* — no re-derivation from model dims.
An analytic host-time proxy built from those counters is **calibrated
once per session** (one measured warm-sweep wall time at a reference
batch) and then *predicts* every other batch shape; the
predicted/measured ratio is a CI assertion (``check_perf.py`` gates the
``predicted_vs_measured`` section of ``BENCH_throughput.json``), so a
p99 regression whose aggregate throughput still passes shows up as a
cost-model miss on the shape that regressed.

Two kinds of prediction live here, deliberately separate:

* **Host sweep time** (``predicted_s``): the calibrated linear model
  over ``flops + bytes_accessed``.  Calibration absorbs the
  machine-speed factor the same way the normalized throughput gate
  does, so the gated ratio tests *scaling fidelity* (does cost grow
  with batch the way the executable's counters say it should), not
  absolute speed.
* **Analog crossbar time** (``analog_latency_s``): the Fig. 14 cycle
  model (``energy.inference_latency`` through the system's (R, C)
  grid) — the floor the hardware twin imposes per sweep, independent
  of batch.  On CPU interpret mode the host term dominates by orders
  of magnitude; on a real accelerator the two converge, and
  ``predicted_s`` is their max.

The *uncalibrated* raw costs also carry an ordering invariant the gate
hard-fails on: the fused-metered kernel does strictly more work than
the unmetered fused kernel (a second VMEM meter accumulator), so
``raw(metered) >= raw(unmetered)`` must hold per batch.  A flip means
the cost model (or the lowering) lost the meter — exactly the
regression class aggregate samples/s cannot see.
"""
from __future__ import annotations

import dataclasses
from typing import Any

#: Generous predicted/measured acceptance band.  Calibration pins the
#: reference shape to ratio 1.0; other shapes drift with allocator /
#: threading nonlinearity the linear proxy ignores — the band only has
#: to catch order-of-magnitude breaks (a shape silently falling off its
#: compiled executable, a meter pass running twice, ...).
DEFAULT_BAND = (0.2, 5.0)


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """One executable's analytic cost + (optionally) its prediction."""
    entry: str
    batch: int
    flops: float
    bytes_accessed: float
    analog_latency_s: float

    @property
    def raw(self) -> float:
        """Uncalibrated host-cost proxy.  flops and bytes are summed at
        unit weight: on the CPU/interpret backends this benchmark runs
        on there is no measured flop:byte rate to split them, and the
        calibration factor absorbs the common scale anyway.  Guaranteed
        positive so calibration can divide by it."""
        return max(self.flops + self.bytes_accessed, 1.0)


class SweepCostModel:
    """Analytic cost model for ONE session entry point.

    ``estimate`` reads the executable's counters; ``calibrate`` fixes
    the seconds-per-raw-cost coefficient from a single measured warm
    sweep; ``predict_s`` prices any batch.  One instance per
    ``(session, entry)`` — different sessions (backend / metering mode)
    lower to different executables and calibrate independently.
    """

    def __init__(self, session, entry: str = "infer_step"):
        self.session = session
        self.entry = entry
        self._scale: float | None = None       # seconds per raw-cost unit
        self._ref: tuple[int, float] | None = None

    def estimate(self, batch: int) -> CostEstimate:
        ca = self.session.cost_analysis(self.entry, batch)
        return CostEstimate(
            entry=self.entry, batch=batch,
            flops=ca["flops"], bytes_accessed=ca["bytes_accessed"],
            analog_latency_s=self.session.system._grid_latency())

    def calibrate(self, batch: int, measured_s: float) -> None:
        """Fix the host coefficient: ``measured_s`` is one warm-sweep
        wall time of ``batch`` (compile excluded)."""
        if measured_s <= 0.0:
            raise ValueError(f"measured_s must be positive, "
                             f"got {measured_s}")
        self._scale = measured_s / self.estimate(batch).raw
        self._ref = (batch, measured_s)

    @property
    def calibration(self) -> dict[str, Any]:
        if self._scale is None:
            raise RuntimeError("cost model is not calibrated — call "
                               "calibrate(batch, measured_s) first")
        return dict(ref_batch=self._ref[0], ref_measured_s=self._ref[1],
                    seconds_per_unit=self._scale)

    def predict_s(self, batch: int) -> float:
        """Predicted sweep wall time: the calibrated host term, floored
        by the Fig. 14 analog crossbar latency."""
        if self._scale is None:
            raise RuntimeError("cost model is not calibrated — call "
                               "calibrate(batch, measured_s) first")
        est = self.estimate(batch)
        return max(est.raw * self._scale, est.analog_latency_s)


def bytes_per_sweep(session, entry: str, batch: int) -> dict[str, float]:
    """Per-sweep traffic counters for ONE session executable, the record
    the ``compressed`` bench section ratios int8-vs-packed on:

    * ``bytes_accessed`` / ``flops`` — XLA ``cost_analysis`` of the AOT
      executable: every byte the compiled program touches, including
      intermediates (what the compiler says the sweep costs);
    * ``input_bytes`` — the exact operand-array footprint
      (``session.input_bytes``): literals + the baked crossbar operands.
      Layout-level and deterministic — a packed clause operand shrinks
      this by construction, independent of how a given XLA version
      prices the kernel body.

    Both are recorded (and gated) because they fail differently: a
    packing regression that silently dequantizes outside the kernel
    keeps ``input_bytes`` small but blows up ``bytes_accessed``; an
    operand-layout regression does the reverse.
    """
    ca = session.cost_analysis(entry, batch)
    return dict(flops=float(ca["flops"]),
                bytes_accessed=float(ca["bytes_accessed"]),
                input_bytes=float(session.input_bytes(entry, batch)))


def _entry_record(model: SweepCostModel, batch: int, measured_s: float,
                  *, is_ref: bool) -> dict[str, Any]:
    est = model.estimate(batch)
    pred = model.predict_s(batch)
    return dict(
        flops=est.flops, bytes_accessed=est.bytes_accessed,
        analog_latency_s=est.analog_latency_s,
        predicted_s=pred, measured_s=measured_s,
        ratio_pred_over_meas=pred / measured_s,
        calibration_ref=is_ref)


def bench_section(system, bench: dict, *, batch_sizes,
                  band: tuple[float, float] = DEFAULT_BAND) -> dict:
    """Build the ``predicted_vs_measured`` section of
    ``BENCH_throughput.json`` from an already-measured bench payload.

    Reuses the sweep's own timings (``us_per_batch``) as the measured
    side and the sweep's own sessions (``system.compile`` caches per
    spec, so no recompilation happens here) as the predicted side:

    * ``predict/<backend>`` — one model per backend family of the
      throughput sweep, calibrated at the smallest batch;
    * ``infer_step/pallas-<mode>`` — one model per metering mode of the
      metered sweep (off / fused / staged lower to different
      executables), calibrated likewise;
    * ``orderings`` — the calibration-free raw-cost invariants, one per
      batch: metered-fused must cost at least unmetered-fused.

    ``check_perf.check_cost_model`` gates every entry's ratio against
    ``band`` and hard-fails any ordering below 1.0.
    """
    from .runtime import RuntimeSpec

    batch_sizes = list(batch_sizes)
    b_ref = batch_sizes[0]
    entries: dict[str, dict] = {}
    calibrations: dict[str, dict] = {}

    def run_family(family: str, spec: RuntimeSpec, entry: str,
                   measured_key) -> SweepCostModel:
        model = SweepCostModel(system.compile(spec), entry=entry)
        model.calibrate(b_ref, measured_key(b_ref))
        calibrations[family] = model.calibration
        for B in batch_sizes:
            entries[f"{family}_b{B}"] = _entry_record(
                model, B, measured_key(B), is_ref=B == b_ref)
        return model

    results = bench["results"]
    for impl in ("xla", "pallas"):
        run_family(
            f"predict/{impl}",
            RuntimeSpec(backend=impl, metering="off"), "predict",
            lambda B, impl=impl:
                results[f"{impl}_b{B}"]["us_per_batch"] / 1e6)

    metered = bench.get("metered", {}).get("results", {})
    models: dict[str, SweepCostModel] = {}
    for mode in ("off", "fused", "staged"):
        models[mode] = run_family(
            f"infer_step/pallas-{mode}",
            RuntimeSpec(backend="pallas", metering=mode), "infer_step",
            lambda B, mode=mode:
                metered[f"metered_{mode}_b{B}"]["us_per_batch"] / 1e6)

    # Calibration-free ordering invariants on the raw executable cost:
    # the in-kernel meter adds work, it can never remove it.
    orderings = {}
    for B in batch_sizes:
        raw_off = models["off"].estimate(B).raw
        orderings[f"metered_fused_over_off_b{B}"] = dict(
            raw_cost_ratio=models["fused"].estimate(B).raw / raw_off,
            must_be_at_least=1.0)
        # staged materializes every intermediate the fused kernel keeps
        # in VMEM; recorded for the record, not gated (a cleverer staged
        # lowering is allowed to get cheaper).
        orderings[f"staged_over_off_b{B}"] = dict(
            raw_cost_ratio=models["staged"].estimate(B).raw / raw_off)

    return dict(band=list(band), calibration=calibrations,
                entries=entries, orderings=orderings)
