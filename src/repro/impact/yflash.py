"""Y-Flash memristor digital twin.

Models the 180 nm two-terminal Y-Flash device of the paper [16-18]:

* program pulses (5 V) move conductance DOWN (toward LCS) — exponential
  decay with per-device time constant;
* erase pulses (8 V) move conductance UP (toward HCS) — exponential
  approach to a ceiling;
* cycle-to-cycle (C2C) noise: per-pulse multiplicative log-normal;
* device-to-device (D2D) spread: per-device log-normal scaling of the
  program/erase time constants and of the asymptotes.

Calibration anchors (paper figures/tables):
  - Boolean programming with 1 ms pulses: HCS 2.5 uS -> LCS < 1 nS in
    ~7 pulses on average (Fig. 10).
  - D2D test (200 us program / 100 us erase): 23-61 program pulses to LCS,
    15-51 erase pulses to HCS > 1 uS (Fig. 8).
  - C2C LCS mean 0.925 nS SD ~4.8 %; HCS mean 1.01 uS SD ~9.7 % (Fig. 7).
  - Read: V_R = 2 V, 5 ns; HCS read current ~4.5-5 uA; LCS read current
    ~1 nA nominal, ~3 nA average due to I-V nonlinearity (Fig. 5c).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array

# --- device constants (SI units) -------------------------------------------
G_LCS = 1e-9          # Boolean low-conductance state threshold (S)
G_HCS_BOOL = 2.4e-6   # Boolean high-conductance state threshold (S)
G_MIN = 0.25e-9       # programming floor (S)
G_MAX = 3.0e-6        # erasing ceiling (S)
G_RANGE_LO = 1e-9     # analog-mode usable range (S)
G_RANGE_HI = 2.5e-6
V_READ = 2.0          # read voltage (V)
T_READ = 5e-9         # read pulse width (s)
V_PROG = 5.0
V_ERASE = 8.0
TAU_PROG = 8.96e-4    # s — gives ~7 pulses HCS->LCS at 1 ms width
TAU_ERASE = 6.2e-3    # s — gives ~25 pulses LCS->1 uS at 100 us width
I_CSA_THRESHOLD = 4.1e-6   # A — clause CSA decision boundary
LCS_NONLINEARITY = 1.5     # low-G read current boost (Fig. 5c: ~3 nA vs 2 nA)
G_NONLIN_CUTOFF = 10e-9    # S — below this the nonlinearity applies

# Variability scales (calibrated against Figs. 7-8 statistics).
C2C_SIGMA = 0.048     # per-pulse log-normal sigma (LCS SD ~4.8 %)
C2C_SIGMA_HCS = 0.097
D2D_SIGMA_TAU = 0.22  # per-device tau spread -> 23-61 pulse D2D range
D2D_SIGMA_G = 0.04


@dataclasses.dataclass(frozen=True)
class DeviceVariation:
    """Per-device (D2D) multiplicative factors, sampled once per array."""
    tau_prog: Array   # scales the program time constant
    tau_erase: Array
    g_floor: Array    # scales G_MIN
    g_ceil: Array     # scales G_MAX

    @staticmethod
    def sample(key: Array, shape: tuple[int, ...]) -> "DeviceVariation":
        ks = jax.random.split(key, 4)
        ln = lambda k, s: jnp.exp(s * jax.random.normal(k, shape))
        return DeviceVariation(
            tau_prog=ln(ks[0], D2D_SIGMA_TAU),
            tau_erase=ln(ks[1], D2D_SIGMA_TAU),
            g_floor=ln(ks[2], D2D_SIGMA_G),
            g_ceil=ln(ks[3], D2D_SIGMA_G),
        )

    @staticmethod
    def none(shape: tuple[int, ...]) -> "DeviceVariation":
        one = jnp.ones(shape)
        return DeviceVariation(one, one, one, one)


jax.tree_util.register_dataclass(
    DeviceVariation, data_fields=["tau_prog", "tau_erase", "g_floor", "g_ceil"],
    meta_fields=[])


def program_pulse(g: Array, width: float, var: DeviceVariation,
                  key: Array | None = None) -> Array:
    """One 5 V program pulse: exponential decay toward the floor."""
    floor = G_MIN * var.g_floor
    decay = jnp.exp(-width / (TAU_PROG * var.tau_prog))
    if key is not None:
        decay = decay * jnp.exp(C2C_SIGMA * jax.random.normal(key, g.shape))
    return floor + (g - floor) * jnp.clip(decay, 0.0, 1.0)


def erase_pulse(g: Array, width: float, var: DeviceVariation,
                key: Array | None = None) -> Array:
    """One 8 V erase pulse: exponential approach to the ceiling."""
    ceil = G_MAX * var.g_ceil
    rate = 1.0 - jnp.exp(-width / (TAU_ERASE * var.tau_erase))
    if key is not None:
        rate = rate * jnp.exp(C2C_SIGMA_HCS * jax.random.normal(key, g.shape))
    return g + (ceil - g) * jnp.clip(rate, 0.0, 1.0)


def read_current(g: Array, v_read: float = V_READ) -> Array:
    """I = G*V with the paper's low-conductance nonlinearity (Fig. 5c)."""
    nl = jnp.where(g < G_NONLIN_CUTOFF, LCS_NONLINEARITY, 1.0)
    return g * v_read * nl


def pulse_until(g: Array, *, target_lo: Array, target_hi: Array,
                width_prog: float, width_erase: float,
                var: DeviceVariation, key: Array,
                max_pulses: int = 128, c2c: bool = True,
                ) -> tuple[Array, Array, Array]:
    """Vectorised program/erase loop: drive every cell into
    [target_lo, target_hi].  Returns (G, prog_pulse_counts, erase_pulse_counts).

    This is the primitive behind both the Boolean encode (Fig. 9-10) and the
    analog pre-tune / fine-tune phases (Figs. 6, 12).  ``c2c=False`` turns
    off the per-pulse cycle-to-cycle noise, making the trajectory a
    deterministic function of the start/target conductances — the ideal
    device twin used when all variability is disabled.
    """
    def cond(state):
        g, _, _, i, _ = state
        done = (g >= target_lo) & (g <= target_hi)
        return (~jnp.all(done)) & (i < max_pulses)

    def body(state):
        g, np_, ne_, i, k = state
        k, kp, ke = jax.random.split(k, 3)
        too_high = g > target_hi
        too_low = g < target_lo
        g_p = program_pulse(g, width_prog, var, kp if c2c else None)
        g_e = erase_pulse(g, width_erase, var, ke if c2c else None)
        g = jnp.where(too_high, g_p, jnp.where(too_low, g_e, g))
        return (g, np_ + too_high.astype(jnp.int32),
                ne_ + too_low.astype(jnp.int32), i + 1, k)

    zeros = jnp.zeros(g.shape, jnp.int32)
    g, n_prog, n_erase, _, _ = jax.lax.while_loop(
        cond, body, (g, zeros, zeros, jnp.int32(0), key))
    return g, n_prog, n_erase


def tune_adaptive(g: Array, target: Array, tol: Array, *,
                  var: DeviceVariation, key: Array,
                  widths: tuple[float, ...] = (500e-6, 50e-6, 5e-6),
                  max_pulses: int = 64, c2c: bool = True,
                  ) -> tuple[Array, Array, Array]:
    """Closed-loop programmer with per-pulse WIDTH SELECTION (beyond
    paper).  The paper's two-phase schedule applies one fixed width per
    phase; real lab programmers pick, per cell per step, the widest pulse
    whose predicted landing point is closest to the target — coarse pulses
    cover distance, fine pulses settle inside the band without the
    overshoot that costs the fixed-width controller ~20 accuracy points
    before fine-tuning (see benchmarks/fig13).

    Vectorised greedy: evaluate the deterministic landing point for every
    candidate width (program and erase), apply the per-cell argmin, repeat
    until all cells are within ``tol`` of ``target``.
    Returns (G, program_pulse_counts, erase_pulse_counts).
    """
    widths_arr = list(widths)

    def land_all(g):
        cands = []
        for w in widths_arr:
            cands.append(program_pulse(g, w, var))
            cands.append(erase_pulse(g, w, var))
        return jnp.stack(cands)                          # (2W, ...)

    def cond(state):
        g, _, _, i, _ = state
        return (~jnp.all(jnp.abs(g - target) <= tol)) & (i < max_pulses)

    def body(state):
        g, np_, ne_, i, k = state
        k, k1, k2 = jax.random.split(k, 3)
        cands = land_all(g)
        err = jnp.abs(cands - target)
        best = jnp.argmin(err, axis=0)                   # (2W index per cell)
        is_prog = (best % 2) == 0
        width = jnp.take(jnp.asarray(widths_arr), best // 2)
        # Re-apply the chosen move WITH C2C noise (unless ideal devices).
        # Each move type draws its OWN per-pulse sample at its own Fig. 7
        # sigma: program moves at C2C_SIGMA (LCS SD ~4.8 %), erase moves
        # at C2C_SIGMA_HCS (~9.7 %) — matching program_pulse/erase_pulse.
        ones = jnp.ones(g.shape)
        noise_p = (jnp.exp(C2C_SIGMA * jax.random.normal(k1, g.shape))
                   if c2c else ones)
        noise_e = (jnp.exp(C2C_SIGMA_HCS * jax.random.normal(k2, g.shape))
                   if c2c else ones)
        floor = G_MIN * var.g_floor
        ceil = G_MAX * var.g_ceil
        decay = jnp.exp(-width / (TAU_PROG * var.tau_prog)) * noise_p
        rate = (1.0 - jnp.exp(-width / (TAU_ERASE * var.tau_erase))) * noise_e
        g_prog = floor + (g - floor) * jnp.clip(decay, 0.0, 1.0)
        g_erase = g + (ceil - g) * jnp.clip(rate, 0.0, 1.0)
        done = jnp.abs(g - target) <= tol
        g_new = jnp.where(done, g, jnp.where(is_prog, g_prog, g_erase))
        return (g_new, np_ + (~done & is_prog).astype(jnp.int32),
                ne_ + (~done & ~is_prog).astype(jnp.int32), i + 1, k)

    zeros = jnp.zeros(g.shape, jnp.int32)
    g, n_prog, n_erase, _, _ = jax.lax.while_loop(
        cond, body, (g, zeros, zeros, jnp.int32(0), key))
    return g, n_prog, n_erase
