"""IMPACT crossbar tiles: clause tile (Boolean mode) + class tile (analog).

Both tiles store conductances and compute with Ohm + Kirchhoff exactly as in
the paper (Fig. 4).  Inputs arrive as voltages:

* clause tile rows:  literal 0 -> V_R, literal 1 -> floating 'Z' (0 V drive)
  — i.e. the multiplied operand is NOT(literal);
* class tile rows:   clause 1 -> V_R, clause 0 -> 'Z'.

Column read-out:

* clause tile: current-sense amplifier thresholds the column current at
  4.1 uA — "any (literal=0, include) pair present" => clause 0;
* class tile: column currents ARE the class-weighted sums (ADC), argmax in
  the digital domain.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import yflash
from .yflash import (DeviceVariation, G_HCS_BOOL, G_LCS, I_CSA_THRESHOLD,
                     V_READ, read_current)

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ClauseTile:
    """K x n Boolean-mode crossbar storing TA include/exclude actions."""
    g: Array                   # (K, n) conductances (S)
    nonempty: Array            # (n,) digital mask: clause has >=1 include

    def currents(self, literals: Array) -> Array:
        """Column currents for a batch of literal vectors (..., K) -> (..., n).

        Only literal==0 rows are driven at V_R; literal==1 rows float.
        """
        drive = (1.0 - literals.astype(jnp.float32))           # (..., K)
        return drive @ read_current(self.g)                    # (..., n)

    def clauses(self, literals: Array, *, mask_empty: bool = True) -> Array:
        """CSA decision: clause fires iff column current < 4.1 uA."""
        fired = self.currents(literals) < I_CSA_THRESHOLD
        if mask_empty:
            fired = jnp.logical_and(fired, self.nonempty)
        return fired


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ClassTile:
    """n x m analog-mode crossbar storing unipolar clause weights."""
    g: Array                   # (n, m) conductances (S)

    def currents(self, clauses: Array) -> Array:
        """(..., n) Boolean clauses -> (..., m) class column currents."""
        drive = clauses.astype(jnp.float32)
        return drive @ read_current(self.g)

    def scores(self, clauses: Array) -> Array:
        return self.currents(clauses)

    def predict(self, clauses: Array) -> Array:
        return jnp.argmax(self.currents(clauses), axis=-1)


# ---------------------------------------------------------------------------
# Encoding (Figs. 9-10): TA actions -> Boolean conductances
# ---------------------------------------------------------------------------

def n_unconverged(g: Array, target_lo: Array, target_hi: Array) -> int:
    """Count cells still outside [target_lo, target_hi] after a pulse loop.

    ``pulse_until`` gives up silently when ``max_pulses`` exhausts; encode
    callers surface this count in their stats so an impossible target (or
    an under-budgeted pulse loop) is a visible number, not a quiet
    mis-programmed tile."""
    return int(jnp.sum((g < target_lo) | (g > target_hi)))


def encode_clause_tile(include: Array, key: Array, *,
                       pulse_width: float = 1e-3,
                       variability: bool = True,
                       max_pulses: int = 64,
                       ) -> tuple[ClauseTile, dict]:
    """Program a clause tile from an include mask (K, n).

    All cells start erased at HCS; excluded cells are programmed to
    LCS < 1 nS with 1 ms pulses (paper Fig. 9d / Fig. 10); included cells
    are erased up to > 2.4 uS (mostly already there).
    Returns the tile and encode statistics (pulse histograms, energy inputs).
    """
    K, n = include.shape
    k_var, k_init, k_pulse = jax.random.split(key, 3)
    var = (DeviceVariation.sample(k_var, (K, n)) if variability
           else DeviceVariation.none((K, n)))
    # Freshly erased array: HCS with mild spread.  ``variability=False``
    # means IDEAL devices: uniform start, no C2C noise — encoding becomes a
    # deterministic per-cell function of the target, so the same logical
    # model maps to the same conductances under ANY tile split (the
    # invariance behind Fig. 14 scaling).
    g0 = (2.5e-6 * jnp.exp(0.05 * jax.random.normal(k_init, (K, n)))
          if variability else jnp.full((K, n), 2.5e-6))

    target_lo = jnp.where(include, G_HCS_BOOL, 0.0)
    target_hi = jnp.where(include, jnp.inf, G_LCS)
    g, n_prog, n_erase = yflash.pulse_until(
        g0, target_lo=target_lo, target_hi=target_hi,
        width_prog=pulse_width, width_erase=pulse_width,
        var=var, key=k_pulse, max_pulses=max_pulses, c2c=variability)

    stats = dict(prog_pulses=n_prog, erase_pulses=n_erase,
                 include_fraction=include.mean(),
                 pulse_width=pulse_width,
                 n_unconverged=n_unconverged(g, target_lo, target_hi))
    return ClauseTile(g=g, nonempty=include.any(axis=0)), stats


# ---------------------------------------------------------------------------
# Weight mapping (Figs. 6, 11-12): two-phase analog tuning
# ---------------------------------------------------------------------------

def weight_targets(weights_unipolar: Array, w_max: Array | int) -> Array:
    """Divide [G_RANGE_LO, G_RANGE_HI] into w_max uniform segments and map
    each integer weight to its segment conductance (paper Fig. 6/11)."""
    w_max = jnp.maximum(w_max, 1)
    frac = weights_unipolar.astype(jnp.float32) / w_max
    return yflash.G_RANGE_LO + frac * (yflash.G_RANGE_HI - yflash.G_RANGE_LO)


def encode_class_tile(weights_unipolar: Array, key: Array, *,
                      w_max: int | None = None,
                      pretune_tol_segments: float = 20.0,
                      finetune_tol_segments: float = 5.0,
                      pretune_width: float = 500e-6,
                      finetune_width: float = 50e-6,
                      variability: bool = True,
                      finetune: bool = True,
                      adaptive: bool = False,
                      max_pulses: int = 96,
                      ) -> tuple[ClassTile, dict]:
    """Program the class tile from unipolar integer weights (n, m).

    Pre-tune: 500 us pulses to within +/-20 segments of target;
    fine-tune: 50 us pulses to within +/-5 segments (paper Figs. 6, 12, 13).

    ``adaptive=True`` (beyond paper) replaces the fixed two-phase schedule
    with the closed-loop width-selecting controller
    (``yflash.tune_adaptive``) driving straight to the fine tolerance.
    """
    n, m = weights_unipolar.shape
    if w_max is None:
        w_max = int(jnp.max(weights_unipolar))
    seg = (yflash.G_RANGE_HI - yflash.G_RANGE_LO) / max(w_max, 1)
    target = weight_targets(weights_unipolar, w_max)

    k_var, k_init, k_pre, k_fine = jax.random.split(key, 4)
    var = (DeviceVariation.sample(k_var, (n, m)) if variability
           else DeviceVariation.none((n, m)))
    # Paper: all cells erased to HCS before mapping for a uniform transition.
    # Ideal devices (variability=False) start uniform and tune noiselessly —
    # see ``encode_clause_tile`` for why determinism matters.
    g0 = (2.5e-6 * jnp.exp(0.05 * jax.random.normal(k_init, (n, m)))
          if variability else jnp.full((n, m), 2.5e-6))

    if adaptive:
        tol = finetune_tol_segments * seg
        g2, p_a, e_a = yflash.tune_adaptive(
            g0, target, jnp.asarray(tol), var=var, key=k_pre,
            max_pulses=max_pulses, c2c=variability)
        stats = dict(pretune_prog=p_a, pretune_erase=e_a,
                     segment_size=seg, w_max=w_max, adaptive=True,
                     n_unconverged=int(jnp.sum(jnp.abs(g2 - target) > tol)))
        return ClassTile(g=g2), stats

    tol_pre = pretune_tol_segments * seg
    g1, p_pre, e_pre = yflash.pulse_until(
        g0, target_lo=target - tol_pre, target_hi=target + tol_pre,
        width_prog=pretune_width, width_erase=pretune_width,
        var=var, key=k_pre, max_pulses=max_pulses, c2c=variability)

    stats = dict(pretune_prog=p_pre, pretune_erase=e_pre,
                 segment_size=seg, w_max=w_max)
    if finetune:
        tol_fine = finetune_tol_segments * seg
        g2, p_f, e_f = yflash.pulse_until(
            g1, target_lo=target - tol_fine, target_hi=target + tol_fine,
            width_prog=finetune_width, width_erase=finetune_width,
            var=var, key=k_fine, max_pulses=max_pulses, c2c=variability)
        stats.update(finetune_prog=p_f, finetune_erase=e_f,
                     n_unconverged=n_unconverged(
                         g2, target - tol_fine, target + tol_fine))
    else:
        g2 = g1
        stats["n_unconverged"] = n_unconverged(
            g2, target - tol_pre, target + tol_pre)
    return ClassTile(g=g2), stats
