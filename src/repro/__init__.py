"""repro — IMPACT (Y-Flash CoTM) reproduction + multi-pod JAX framework.

See README.md for layout, DESIGN.md for the TPU adaptation map, and
EXPERIMENTS.md for the reproduction/dry-run/roofline/perf record.
"""
__version__ = "1.0.0"
