"""Public wrappers around the crossbar primitives.

Each wrapper resolves ``impl`` through the backend registry
(``kernels.backends``) and delegates: ``impl="pallas"`` runs the Pallas
kernels (interpret mode off-TPU), ``impl="xla"`` the pure-einsum oracles,
and any registered third backend slots in without touching these call
sites.  The padding / interpret plumbing that used to be copy-pasted
across the wrappers lives on the backend objects now (the shape-policy
hooks); oracles live in ``ref.py`` and every kernel backend is
exact-equality tested against them over shape sweeps and
hypothesis-generated inputs.

``fused_impact`` additionally routes to the ``shard_map`` lowering
(``sharding.crossbar``) when a mesh with a usable ``model`` axis is
passed — including the asymmetric R-only / S-only plans where the
non-dividing operand is replicated — falling back to the single-device
backend otherwise, so callers can pass a mesh unconditionally.
"""
from __future__ import annotations

import jax

from . import backends
from .backends import pad_axis as _pad_axis  # noqa: F401  (legacy import path)

Array = jax.Array


def clause_eval(literals: Array, include: Array,
                nonempty: Array | None = None, *, mode: str = "fired",
                impl: str = "pallas", interpret: bool | None = None,
                block_b: int = 128, block_n: int = 128,
                block_k: int = 512) -> Array:
    """Boolean clause outputs (B, N) bool, or violation counts int32.

    literals (B, K) bool/{0,1}; include (K, N) bool/{0,1};
    nonempty (N,) bool (defaults to ``include.any(0)``).
    """
    if nonempty is None:
        nonempty = include.astype(bool).any(axis=0)
    return backends.get_backend(impl).clause_eval(
        literals, include, nonempty, mode=mode, interpret=interpret,
        block_b=block_b, block_n=block_n, block_k=block_k)


def class_sum(clauses: Array, weights: Array, *, impl: str = "pallas",
              interpret: bool | None = None, block_b: int = 128,
              block_n: int = 512, block_m: int = 128) -> Array:
    """Class scores (B, M) int32 from clauses (B, N) and weights (N, M)."""
    return backends.get_backend(impl).class_sum(
        clauses, weights, interpret=interpret, block_b=block_b,
        block_n=block_n, block_m=block_m)


def fused_cotm(literals: Array, include: Array, weights: Array,
               nonempty: Array | None = None, *, impl: str = "pallas",
               interpret: bool | None = None, block_b: int = 128,
               block_n: int = 256) -> Array:
    """Fused literals -> class scores (B, M) int32 (clauses stay in VMEM).

    weights is (N, M) — i.e. the class-crossbar layout (paper stores W^T).
    """
    if nonempty is None:
        nonempty = include.astype(bool).any(axis=0)
    return backends.get_backend(impl).fused_cotm(
        literals, include, nonempty, weights, interpret=interpret,
        block_b=block_b, block_n=block_n)


def fused_impact(literals: Array, clause_i: Array, nonempty: Array,
                 class_i: Array, *, thresh: float, impl: str = "pallas",
                 interpret: bool | None = None, block_b: int = 128,
                 block_n: int = 256, mesh=None, meter: bool = False):
    """Fused analog IMPACT inference: literals -> class currents (B, M) f32.

    literals (B, K) bool/{0,1}; clause_i (R, C, tr, tc) f32 per-cell clause
    crossbar read currents in the ``IMPACTSystem`` shard layout; nonempty
    (C*tc,) digital mask; class_i (S, sr, M) f32 class crossbar currents.
    ``thresh`` is the CSA decision current (``yflash.I_CSA_THRESHOLD``).

    ``meter=True`` additionally returns the per-lane energy meters —
    ``(scores, summed clause-crossbar column currents (B,), summed
    class-crossbar column currents (B,))`` — accumulated inside the fused
    kernel (``Backend.fused_impact_metered``), so the Table 4 joules
    cost no staged second pass.  Padding rows/columns contribute exactly
    zero current to the meters.

    ``mesh``: a jax Mesh with a ``model`` axis distributes the R/S row
    shards across devices via ``sharding.crossbar`` (digital AND == psum
    of partial CSA bits, ADC + add == psum of partial class currents) and
    shards the batch over the data axes; with ``meter=True`` the per-lane
    meters are psummed alongside.  When only one of R/S divides the model
    axis, that operand shards and the other is replicated (asymmetric
    plan); when neither divides, the single-device backend runs, so
    callers can pass a mesh unconditionally.

    Padding is semantically neutral: padded literal rows drive 0 V (a
    floating row contributes no current), padded clause columns carry
    nonempty=0, padded class rows carry 0 S conductance.
    """
    R, C, tr, tc = clause_i.shape
    S = class_i.shape[0]
    assert nonempty.shape == (C * tc,), (nonempty.shape, C * tc)
    if mesh is not None:
        from ..sharding import crossbar as _crossbar  # lazy: avoids cycle
        plan = _crossbar.shard_plan(mesh, R, S)
        if plan is not None:
            return _crossbar.fused_impact_shmap(
                literals, clause_i, nonempty, class_i, thresh=thresh,
                mesh=mesh, impl=impl, interpret=interpret, meter=meter,
                shard_r=plan[0], shard_s=plan[1])
    backend = backends.get_backend(impl)
    if meter:
        return backend.fused_impact_metered(
            literals, clause_i, nonempty, class_i, thresh=thresh,
            interpret=interpret, block_b=block_b, block_n=block_n)
    return backend.fused_impact(
        literals, clause_i, nonempty, class_i, thresh=thresh,
        interpret=interpret, block_b=block_b, block_n=block_n)


def fused_impact_packed(literals: Array, packed, nonempty: Array,
                        class_i: Array, *, thresh: float, tr: int,
                        impl: str = "pallas-packed",
                        interpret: bool | None = None, block_b: int = 128,
                        block_n: int = 256, mesh=None, meter: bool = False):
    """``fused_impact`` on a bitplane-packed clause operand.

    ``packed`` is a ``kernels.packing.PackedClause`` — 2-bit codes
    ``(R, C, ceil(tr/4), tc)`` uint8 plus the ``(2,)`` dequant levels —
    and ``tr`` is the UNPACKED per-shard row count (the packed bits
    alone cannot recover it).  Routing mirrors ``fused_impact``: a mesh
    with a usable ``model`` axis rides the same psum lowering
    (``sharding.crossbar`` unpacks bitplanes per shard), otherwise the
    backend's packed kernel runs; ``meter=True`` returns the metered
    triple billed on the quantized currents.
    """
    R, C, tr4, tc = packed.bits.shape
    S = class_i.shape[0]
    assert nonempty.shape == (C * tc,), (nonempty.shape, C * tc)
    if mesh is not None:
        from ..sharding import crossbar as _crossbar  # lazy: avoids cycle
        plan = _crossbar.shard_plan(mesh, R, S)
        if plan is not None:
            return _crossbar.fused_impact_shmap(
                literals, None, nonempty, class_i, thresh=thresh,
                mesh=mesh, impl=impl, interpret=interpret, meter=meter,
                shard_r=plan[0], shard_s=plan[1], packed=packed,
                packed_tr=tr)
    backend = backends.get_backend(impl)
    if meter:
        return backend.fused_impact_packed_metered(
            literals, packed, nonempty, class_i, thresh=thresh, tr=tr,
            interpret=interpret, block_b=block_b, block_n=block_n)
    return backend.fused_impact_packed(
        literals, packed, nonempty, class_i, thresh=thresh, tr=tr,
        interpret=interpret, block_b=block_b, block_n=block_n)


def crossbar_mvm(drive: Array, g: Array, *, v_read: float = 2.0,
                 nonlin: float = 1.5, cutoff: float = 10e-9,
                 impl: str = "pallas", interpret: bool | None = None,
                 block_b: int = 128, block_n: int = 128,
                 block_k: int = 512) -> Array:
    """Analog crossbar column currents (B, N) f32."""
    return backends.get_backend(impl).crossbar_mvm(
        drive, g, v_read=v_read, nonlin=nonlin, cutoff=cutoff,
        interpret=interpret, block_b=block_b, block_n=block_n,
        block_k=block_k)
