"""Public jit'd wrappers around the Pallas kernels.

Responsibilities:

* accept arbitrary shapes/dtypes and pad to MXU-aligned tiles with
  *semantically neutral* padding (literal rows pad with 1 — a floating 'Z'
  row in the paper's crossbar contributes no current; clause columns pad
  with include=0/nonempty=0/weight=0);
* pick interpret mode automatically on non-TPU backends so the same call
  sites run in CI (CPU) and production (TPU);
* offer a pure-XLA fallback (``impl="xla"``) for A/B testing.

Oracles live in ``ref.py``; every wrapper here is exact-equality tested
against them over shape sweeps and hypothesis-generated inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import clause_eval as _clause_kernel
from . import class_sum as _class_kernel
from . import crossbar_mvm as _mvm_kernel
from . import fused_cotm as _fused_kernel
from . import fused_impact as _impact_kernel
from . import ref

Array = jax.Array


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x: Array, mult: int, axis: int, value) -> Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def clause_eval(literals: Array, include: Array,
                nonempty: Array | None = None, *, mode: str = "fired",
                impl: str = "pallas", interpret: bool | None = None,
                block_b: int = 128, block_n: int = 128,
                block_k: int = 512) -> Array:
    """Boolean clause outputs (B, N) bool, or violation counts int32.

    literals (B, K) bool/{0,1}; include (K, N) bool/{0,1};
    nonempty (N,) bool (defaults to ``include.any(0)``).
    """
    B, K = literals.shape
    N = include.shape[1]
    if nonempty is None:
        nonempty = include.astype(bool).any(axis=0)
    if impl == "xla":
        out = (ref.clause_viol_ref(literals, include) if mode == "viol"
               else ref.clause_eval_ref(literals, include, nonempty))
        return out
    if interpret is None:
        interpret = _interpret_default()

    block_k = min(block_k, max(128, -(-K // 128) * 128))
    lit = _pad_axis(_pad_axis(literals.astype(jnp.int8), block_b, 0, 1),
                    block_k, 1, 1)          # pad literals with 1 ('Z' rows)
    inc = _pad_axis(_pad_axis(include.astype(jnp.int8), block_k, 0, 0),
                    block_n, 1, 0)
    ne = _pad_axis(nonempty.astype(jnp.int8)[None, :], block_n, 1, 0)
    out = _clause_kernel.clause_eval(
        lit, inc, ne, mode=mode, block_b=block_b, block_n=block_n,
        block_k=block_k, interpret=interpret)[:B, :N]
    return out if mode == "viol" else out.astype(bool)


def class_sum(clauses: Array, weights: Array, *, impl: str = "pallas",
              interpret: bool | None = None, block_b: int = 128,
              block_n: int = 512, block_m: int = 128) -> Array:
    """Class scores (B, M) int32 from clauses (B, N) and weights (N, M)."""
    B, N = clauses.shape
    M = weights.shape[1]
    if impl == "xla":
        return ref.class_sum_ref(clauses, weights)
    if interpret is None:
        interpret = _interpret_default()

    block_n = min(block_n, max(128, -(-N // 128) * 128))
    cl = _pad_axis(_pad_axis(clauses.astype(jnp.int8), block_b, 0, 0),
                   block_n, 1, 0)
    w = _pad_axis(_pad_axis(weights.astype(jnp.int32), block_n, 0, 0),
                  block_m, 1, 0)
    out = _class_kernel.class_sum(
        cl, w, block_b=block_b, block_n=block_n, block_m=block_m,
        interpret=interpret)
    return out[:B, :M]


def fused_cotm(literals: Array, include: Array, weights: Array,
               nonempty: Array | None = None, *, impl: str = "pallas",
               interpret: bool | None = None, block_b: int = 128,
               block_n: int = 256) -> Array:
    """Fused literals -> class scores (B, M) int32 (clauses stay in VMEM).

    weights is (N, M) — i.e. the class-crossbar layout (paper stores W^T).
    """
    B, K = literals.shape
    N, M = weights.shape
    if nonempty is None:
        nonempty = include.astype(bool).any(axis=0)
    if impl == "xla":
        return ref.fused_cotm_ref(literals, include, weights, nonempty)
    if interpret is None:
        interpret = _interpret_default()

    block_n = min(block_n, max(128, -(-N // 128) * 128))
    lit = _pad_axis(_pad_axis(literals.astype(jnp.int8), block_b, 0, 1),
                    128, 1, 1)
    inc = _pad_axis(_pad_axis(include.astype(jnp.int8), 128, 0, 0),
                    block_n, 1, 0)
    ne = _pad_axis(nonempty.astype(jnp.int8)[None, :], block_n, 1, 0)
    w = _pad_axis(_pad_axis(weights.astype(jnp.int32), block_n, 0, 0),
                  128, 1, 0)
    out = _fused_kernel.fused_cotm(
        lit, inc, ne, w, block_b=block_b, block_n=block_n,
        interpret=interpret)
    return out[:B, :M]


def fused_impact(literals: Array, clause_i: Array, nonempty: Array,
                 class_i: Array, *, thresh: float, impl: str = "pallas",
                 interpret: bool | None = None, block_b: int = 128,
                 block_n: int = 256, mesh=None) -> Array:
    """Fused analog IMPACT inference: literals -> class currents (B, M) f32.

    literals (B, K) bool/{0,1}; clause_i (R, C, tr, tc) f32 per-cell clause
    crossbar read currents in the ``IMPACTSystem`` shard layout; nonempty
    (C*tc,) digital mask; class_i (S, sr, M) f32 class crossbar currents.
    ``thresh`` is the CSA decision current (``yflash.I_CSA_THRESHOLD``).

    ``mesh``: a jax Mesh with a ``model`` axis distributes the R/S row
    shards across devices via ``sharding.crossbar`` (digital AND == psum
    of partial CSA bits, ADC + add == psum of partial class currents) and
    shards the batch over the data axes.  Falls back to the single-device
    kernel below when the model axis is 1 or the shard counts don't
    divide it, so callers can pass a mesh unconditionally.

    Padding is semantically neutral: padded literal rows drive 0 V (a
    floating row contributes no current), padded clause columns carry
    nonempty=0, padded class rows carry 0 S conductance.
    """
    B, K = literals.shape
    R, C, tr, tc = clause_i.shape
    S, sr, M = class_i.shape
    n_clause = C * tc
    assert nonempty.shape == (n_clause,), (nonempty.shape, n_clause)
    if mesh is not None:
        from ..sharding import crossbar as _crossbar  # lazy: avoids cycle
        if _crossbar.shardable(mesh, R, S):
            return _crossbar.fused_impact_shmap(
                literals, clause_i, nonempty, class_i, thresh=thresh,
                mesh=mesh, impl=impl, interpret=interpret)
    if impl == "xla":
        return ref.fused_impact_ref(literals, clause_i, nonempty, class_i,
                                    thresh=thresh)
    if interpret is None:
        interpret = _interpret_default()

    # Unify the clause-column axis of both crossbars: the clause tile pads
    # n to C*tc, the class tile to S*sr; dead columns (>= n) fire 0.
    N = max(n_clause, S * sr)
    block_n = min(block_n, max(128, -(-N // 128) * 128))
    tr_pad = max(128, -(-tr // 128) * 128)

    lit = _pad_axis(literals.astype(jnp.float32), R * tr, 1, 1)
    drive = (1.0 - lit).reshape(B, R, tr).transpose(1, 0, 2)   # (R, B, tr)
    drive = _pad_axis(_pad_axis(drive, block_b, 1, 0.0), tr_pad, 2, 0.0)

    ccur = clause_i.astype(jnp.float32).transpose(0, 2, 1, 3)  # (R,tr,C,tc)
    ccur = ccur.reshape(R, tr, n_clause)
    ccur = _pad_axis(_pad_axis(ccur, tr_pad, 1, 0.0), block_n, 2, 0.0)
    if N > n_clause:
        ccur = _pad_axis(ccur, -(-N // block_n) * block_n, 2, 0.0)

    ne = _pad_axis(nonempty.astype(jnp.int8)[None, :],
                   -(-N // block_n) * block_n, 1, 0)

    wcur = class_i.astype(jnp.float32).reshape(S * sr, M)
    wcur = _pad_axis(_pad_axis(wcur, ne.shape[1], 0, 0.0), 128, 1, 0.0)

    out = _impact_kernel.fused_impact(
        drive, ccur, ne, wcur, thresh=thresh, block_b=block_b,
        block_n=block_n, interpret=interpret)
    return out[:B, :M]


def crossbar_mvm(drive: Array, g: Array, *, v_read: float = 2.0,
                 nonlin: float = 1.5, cutoff: float = 10e-9,
                 impl: str = "pallas", interpret: bool | None = None,
                 block_b: int = 128, block_n: int = 128,
                 block_k: int = 512) -> Array:
    """Analog crossbar column currents (B, N) f32."""
    B, K = drive.shape
    N = g.shape[1]
    if impl == "xla":
        return ref.crossbar_mvm_ref(drive, g, v_read=v_read, nonlin=nonlin,
                                    cutoff=cutoff)
    if interpret is None:
        interpret = _interpret_default()

    block_k = min(block_k, max(128, -(-K // 128) * 128))
    dr = _pad_axis(_pad_axis(drive.astype(jnp.float32), block_b, 0, 0.0),
                   block_k, 1, 0.0)
    # Pad conductances ABOVE the nonlinearity cutoff so padded cells do not
    # get the LCS boost; padded drive rows are 0 so they contribute nothing.
    gp = _pad_axis(_pad_axis(g.astype(jnp.float32), block_k, 0, 1.0),
                   block_n, 1, 1.0)
    out = _mvm_kernel.crossbar_mvm(
        dr, gp, v_read=v_read, nonlin=nonlin, cutoff=cutoff,
        block_b=block_b, block_n=block_n, block_k=block_k,
        interpret=interpret)
    return out[:B, :N]
