"""Pallas TPU kernel: analog crossbar matrix-vector multiply (digital twin).

Simulates the physics of a Y-Flash crossbar read: each cell contributes
``I = G * V_R * nl(G)`` where ``nl`` is the paper's low-conductance read
nonlinearity (Fig. 5c: LCS cells read ~3 nA instead of the ohmic 2 nA), and
driven rows sum onto columns by Kirchhoff's law.  Used by the variability
benchmarks to evaluate programmed conductance arrays at scale.

The nonlinearity is applied to the conductance block in VMEM right before
the MXU dot, so the "effective current matrix" is never materialized in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import _compat

Array = jax.Array

BLOCK_B = 128
BLOCK_N = 128
BLOCK_K = 512


def _mvm_kernel(drive_ref, g_ref, out_ref, acc_ref, *, n_k: int,
                v_read: float, nonlin: float, cutoff: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...]
    i_cell = g * v_read * jnp.where(g < cutoff, nonlin, 1.0)
    acc_ref[...] += jax.lax.dot_general(
        drive_ref[...], i_cell,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("v_read", "nonlin", "cutoff", "block_b",
                              "block_n", "block_k", "interpret"))
def crossbar_mvm(drive: Array, g: Array, *, v_read: float = 2.0,
                 nonlin: float = 1.5, cutoff: float = 10e-9,
                 block_b: int = BLOCK_B, block_n: int = BLOCK_N,
                 block_k: int = BLOCK_K, interpret: bool = False) -> Array:
    """drive (B, K) f32 row voltages (in V_R units), g (K, N) f32 S.

    Returns column currents (B, N) f32.
    """
    B, K = drive.shape
    K2, N = g.shape
    assert K == K2
    assert B % block_b == 0 and N % block_n == 0 and K % block_k == 0, (
        (B, K, N))
    n_k = K // block_k

    return pl.pallas_call(
        functools.partial(_mvm_kernel, n_k=n_k, v_read=v_read,
                          nonlin=nonlin, cutoff=cutoff),
        grid=(B // block_b, N // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda b, n, k: (b, k)),
            pl.BlockSpec((block_k, block_n), lambda b, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda b, n, k: (b, n)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b, block_n), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(drive, g)
