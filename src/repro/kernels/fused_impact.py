"""Pallas TPU kernel: fused ANALOG IMPACT inference (both crossbars).

Digital twin of the paper's two-crossbar datapath with the Fig. 14 modular
scaling baked into the tiling.  Where ``fused_cotm`` fuses the *logical*
CoTM (include mask + integer weights), this kernel fuses the *physical*
simulation — per-cell Y-Flash read currents, the CSA threshold, and the
digital periphery — in one VMEM residency:

    per clause-column chunk n:
        for each of the R literal row-shards r:
            I_col[r]  = drive[r] @ I_cell[r][:, n]     # Kirchhoff column sum
            partial_r = I_col[r] < I_CSA_THRESHOLD     # CSA latch
        fired   = AND_r partial_r  &  nonempty[n]      # digital AND (Fig. 14)
        scores += fired @ I_class[n, :]                # class column currents

The Boolean clause chunk ``fired`` never leaves VMEM: the (B, n_pad) clause
matrix — the largest intermediate of the un-fused path — is never
materialized in HBM.  The class crossbar's S row-shards are flattened onto
the clause-chunk axis, so the per-shard ADC + digital add is subsumed by
the chunk accumulation (exact: the class read is linear in the drive).

Layouts (prepared by ``ops.fused_impact``):
  drive   (R, B, tr)   f32   1 - literal, row-shard major; padding rows 0
  ccur    (R, tr, N)   f32   clause-cell read currents, columns flattened
  ne      (1, N)       int8  digital empty-clause mask
  wcur    (N, M)       f32   class-cell read currents, S shards flattened
  out     (B, M)       f32   class column currents (argmax = prediction)

R stays whole per block (the digital AND needs every shard's partial bit),
mirroring ``fused_cotm`` keeping K whole; this bounds R*tr at a few
thousand rows — exactly the regime of a physical crossbar column height.

``fused_impact_metered`` is the same datapath with in-kernel energy
metering: the paper (and IMBUE, arXiv:2305.12914) measure read energy as
``E = V_R * I_col * t_read`` summed over the very column currents the
inference already computes, so the metered kernel folds each chunk's
``I_col`` into a second VMEM accumulator while the CSA consumes it —
joules come out of the single fused pass with no staged second pass and
without ever materializing the (B, n_pad) clause matrix in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import _compat

Array = jax.Array

BLOCK_B = 128
BLOCK_N = 256


def _fused_impact_kernel(drive_ref, ccur_ref, ne_ref, wcur_ref, out_ref,
                         acc_ref, *, n_n: int, n_r: int, thresh: float):
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bb = drive_ref.shape[1]
    bn = ne_ref.shape[1]
    fired = jnp.broadcast_to(ne_ref[...] != 0, (bb, bn))
    for r in range(n_r):                       # static unroll over row shards
        i_col = jax.lax.dot_general(
            drive_ref[r], ccur_ref[r],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        fired = fired & (i_col < thresh)       # CSA + digital AND, in VMEM
    acc_ref[...] += jax.lax.dot_general(
        fired.astype(jnp.float32), wcur_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(n == n_n - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("thresh", "block_b", "block_n", "interpret"))
def fused_impact(drive: Array, ccur: Array, nonempty: Array, wcur: Array, *,
                 thresh: float, block_b: int = BLOCK_B,
                 block_n: int = BLOCK_N, interpret: bool = False) -> Array:
    """drive (R, B, tr) f32, ccur (R, tr, N) f32, nonempty (1, N) int8,
    wcur (N, M) f32 -> class currents (B, M) f32.

    B % block_b == 0, N % block_n == 0, tr % 128 == 0, M % 128 == 0 required
    (``ops.fused_impact`` pads arbitrary shapes and shard layouts).
    """
    R, B, tr = drive.shape
    R2, tr2, N = ccur.shape
    N2, M = wcur.shape
    assert R == R2 and tr == tr2 and N == N2 and nonempty.shape == (1, N)
    assert (B % block_b == 0 and N % block_n == 0 and tr % 128 == 0
            and M % 128 == 0), (B, R, tr, N, M)
    n_n = N // block_n

    return pl.pallas_call(
        functools.partial(_fused_impact_kernel, n_n=n_n, n_r=R,
                          thresh=thresh),
        grid=(B // block_b, n_n),
        in_specs=[
            pl.BlockSpec((R, block_b, tr), lambda b, n: (0, b, 0)),
            pl.BlockSpec((R, tr, block_n), lambda b, n: (0, 0, n)),
            pl.BlockSpec((1, block_n), lambda b, n: (0, n)),
            pl.BlockSpec((block_n, M), lambda b, n: (n, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, M), lambda b, n: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b, M), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(drive, ccur, nonempty, wcur)


#: Lane layout of the metered kernel's (B, METER_LANES) meter output:
#: lane 0 carries the summed clause-crossbar column currents, lane 1 the
#: summed class-crossbar column currents.  128 lanes (one VREG row) keep
#: the output MXU/VPU tile-aligned; the wrapper slices the two live lanes.
METER_LANE_CLAUSE = 0
METER_LANE_CLASS = 1
METER_LANES = 128


def _fused_impact_metered_kernel(drive_ref, ccur_ref, ne_ref, wcur_ref,
                                 out_ref, meter_ref, acc_ref, macc_ref, *,
                                 n_n: int, n_r: int, thresh: float):
    """The fused datapath + in-kernel energy meter.

    Identical clause/class compute to ``_fused_impact_kernel``; on top,
    each chunk's clause column currents are folded into a second VMEM
    accumulator (``macc_ref``) the moment the CSA consumes them.  The
    class-current meter needs no extra accumulation at all: the class
    read is linear, so the summed class column current is exactly the
    row-sum of the score accumulator — computed once in the epilogue.
    """
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        macc_ref[...] = jnp.zeros_like(macc_ref)

    bb = drive_ref.shape[1]
    bn = ne_ref.shape[1]
    fired = jnp.broadcast_to(ne_ref[...] != 0, (bb, bn))
    i_chunk = jnp.zeros((bb, 1), jnp.float32)
    for r in range(n_r):                       # static unroll over row shards
        i_col = jax.lax.dot_general(
            drive_ref[r], ccur_ref[r],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        fired = fired & (i_col < thresh)       # CSA + digital AND, in VMEM
        i_chunk += i_col.sum(axis=1, keepdims=True)
    # Every meter lane accumulates the same per-lane clause current (a
    # plain VPU broadcast-add — no per-chunk lane select); the epilogue
    # picks METER_LANE_CLAUSE.  Padded rows/columns carry 0 A by the
    # wrapper's neutral padding, so they add exactly zero here.
    macc_ref[...] += i_chunk
    acc_ref[...] += jax.lax.dot_general(
        fired.astype(jnp.float32), wcur_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(n == n_n - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...]
        lane = jax.lax.broadcasted_iota(jnp.int32, macc_ref.shape, 1)
        i_class = acc_ref[...].sum(axis=1, keepdims=True)
        meter_ref[...] = jnp.where(
            lane == METER_LANE_CLAUSE, macc_ref[...],
            jnp.where(lane == METER_LANE_CLASS, i_class, 0.0))


@functools.partial(
    jax.jit, static_argnames=("thresh", "block_b", "block_n", "interpret"))
def fused_impact_metered(drive: Array, ccur: Array, nonempty: Array,
                         wcur: Array, *, thresh: float,
                         block_b: int = BLOCK_B, block_n: int = BLOCK_N,
                         interpret: bool = False,
                         ) -> tuple[Array, Array]:
    """Metered variant of ``fused_impact``: same layouts and constraints,
    returns ``(class currents (B, M) f32, meters (B, METER_LANES) f32)``
    where meter lane ``METER_LANE_CLAUSE`` holds the per-lane summed
    clause-crossbar column current and ``METER_LANE_CLASS`` the per-lane
    summed class-crossbar column current — the quantities
    ``impact.energy.per_lane_read_energy`` converts to joules.  The
    backend plumbing (``PallasBackend.fused_impact_metered``) pads inputs
    and slices the live meter lanes back out.
    """
    R, B, tr = drive.shape
    R2, tr2, N = ccur.shape
    N2, M = wcur.shape
    assert R == R2 and tr == tr2 and N == N2 and nonempty.shape == (1, N)
    assert (B % block_b == 0 and N % block_n == 0 and tr % 128 == 0
            and M % 128 == 0), (B, R, tr, N, M)
    n_n = N // block_n

    return pl.pallas_call(
        functools.partial(_fused_impact_metered_kernel, n_n=n_n, n_r=R,
                          thresh=thresh),
        grid=(B // block_b, n_n),
        in_specs=[
            pl.BlockSpec((R, block_b, tr), lambda b, n: (0, b, 0)),
            pl.BlockSpec((R, tr, block_n), lambda b, n: (0, 0, n)),
            pl.BlockSpec((1, block_n), lambda b, n: (0, n)),
            pl.BlockSpec((block_n, M), lambda b, n: (n, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, M), lambda b, n: (b, 0)),
            pl.BlockSpec((block_b, METER_LANES), lambda b, n: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, M), jnp.float32),
            jax.ShapeDtypeStruct((B, METER_LANES), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_b, M), jnp.float32),
                        pltpu.VMEM((block_b, METER_LANES), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(drive, ccur, nonempty, wcur)


# -- bitplane-packed datapath -------------------------------------------------
#
# The clause crossbar is ternary at the device abstraction (HCS include /
# LCS exclude / dead), so streaming a float32 current per cell moves 16x
# more bytes than the information content.  The packed kernels consume
# the ``kernels.packing`` layout instead: 2-bit codes, four literal rows
# per byte, unpacked INSIDE the kernel — the f32 cell-current operand
# never exists in HBM.  Layouts (prepared by ``ops.fused_impact_packed``):
#
#   drive_p (R, 4, B, tr4)  f32   bitplane-major drive: plane j row q is
#                                 literal row 4q+j of shard r; pad rows 0
#   pbits   (R, tr4, N)     uint8 packed codes, columns flattened
#   levels  (1, 128)        f32   [i_lcs, i_hcs] in lanes 0/1 (VREG row)
#   ne / wcur / out               as in the unpacked kernel
#
# Column current = sum_j drive_p[r, j] @ dequant(plane_j), identical MACs
# to the unpacked kernel but ~4x fewer clause bytes through HBM/VMEM
# (uint8 codes vs f32 currents over 4x fewer rows).

_PLANES = 4
_CODE_BITS = 2
_CODE_MASK = 3


def _dequant_plane(codes32, j, i_lcs, i_hcs):
    plane = (codes32 >> (_CODE_BITS * j)) & _CODE_MASK
    return jnp.where(plane == 2, i_hcs,
                     jnp.where(plane == 1, i_lcs, 0.0)).astype(jnp.float32)


def _packed_column_current(drive_ref, pbits_ref, r, i_lcs, i_hcs):
    codes32 = pbits_ref[r].astype(jnp.int32)            # (tr4, bn)
    i_col = None
    for j in range(_PLANES):                            # static bitplane unroll
        cur = _dequant_plane(codes32, j, i_lcs, i_hcs)
        part = jax.lax.dot_general(
            drive_ref[r, j], cur,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        i_col = part if i_col is None else i_col + part
    return i_col


def _fused_impact_packed_kernel(drive_ref, pbits_ref, lvl_ref, ne_ref,
                                wcur_ref, out_ref, acc_ref, *, n_n: int,
                                n_r: int, thresh: float):
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lvl = lvl_ref[...]
    i_lcs, i_hcs = lvl[0, 0], lvl[0, 1]
    bb = drive_ref.shape[2]
    bn = ne_ref.shape[1]
    fired = jnp.broadcast_to(ne_ref[...] != 0, (bb, bn))
    for r in range(n_r):                       # static unroll over row shards
        i_col = _packed_column_current(drive_ref, pbits_ref, r, i_lcs, i_hcs)
        fired = fired & (i_col < thresh)       # CSA + digital AND, in VMEM
    acc_ref[...] += jax.lax.dot_general(
        fired.astype(jnp.float32), wcur_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(n == n_n - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...]


def _packed_specs(R, block_b, tr4, block_n, M):
    return [
        pl.BlockSpec((R, _PLANES, block_b, tr4), lambda b, n: (0, 0, b, 0)),
        pl.BlockSpec((R, tr4, block_n), lambda b, n: (0, 0, n)),
        pl.BlockSpec((1, 128), lambda b, n: (0, 0)),
        pl.BlockSpec((1, block_n), lambda b, n: (0, n)),
        pl.BlockSpec((block_n, M), lambda b, n: (n, 0)),
    ]


def _check_packed_shapes(drive, pbits, levels, nonempty, wcur,
                         block_b, block_n):
    R, P, B, tr4 = drive.shape
    R2, tr42, N = pbits.shape
    N2, M = wcur.shape
    assert P == _PLANES and R == R2 and tr4 == tr42 and N == N2
    assert nonempty.shape == (1, N) and levels.shape == (1, 128)
    assert pbits.dtype == jnp.uint8
    assert (B % block_b == 0 and N % block_n == 0 and tr4 % 128 == 0
            and M % 128 == 0), (B, R, tr4, N, M)
    return R, B, N, M


@functools.partial(
    jax.jit, static_argnames=("thresh", "block_b", "block_n", "interpret"))
def fused_impact_packed(drive: Array, pbits: Array, levels: Array,
                        nonempty: Array, wcur: Array, *, thresh: float,
                        block_b: int = BLOCK_B, block_n: int = BLOCK_N,
                        interpret: bool = False) -> Array:
    """drive (R, 4, B, tr4) f32, pbits (R, tr4, N) uint8, levels (1, 128)
    f32, nonempty (1, N) int8, wcur (N, M) f32 -> class currents (B, M).

    Same alignment contract as ``fused_impact`` with ``tr4`` (the packed
    row count) in place of ``tr``; ``ops.fused_impact_packed`` pads
    arbitrary shapes.
    """
    R, B, N, M = _check_packed_shapes(drive, pbits, levels, nonempty, wcur,
                                      block_b, block_n)
    n_n = N // block_n
    tr4 = drive.shape[3]

    return pl.pallas_call(
        functools.partial(_fused_impact_packed_kernel, n_n=n_n, n_r=R,
                          thresh=thresh),
        grid=(B // block_b, n_n),
        in_specs=_packed_specs(R, block_b, tr4, block_n, M),
        out_specs=pl.BlockSpec((block_b, M), lambda b, n: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b, M), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(drive, pbits, levels, nonempty, wcur)


# -- online TA feedback (arXiv:2408.09456 in-array updates) -------------------
#
# The feedback pass of the companion in-memory-learning paper reuses the
# clause-output datapath in reverse: the same (literal row x clause
# column) geometry that reads clause outputs accumulates, per TA cell,
# how often its literal was present/absent in the clauses selected for
# Type I/II feedback over one update batch.  Three matmuls on the
# doubled-batch feedback masks — identical contraction geometry to the
# clause read, so they share the MXU datapath and the VMEM residency
# pattern of the fused inference kernels:
#
#   present = lit^T     @ (sel & match & fired)       # Type Ia reward
#   absent  = (1-lit)^T @ (sel & match & fired)       # Type Ib penalty
#   inval   = (1-lit)^T @ (sel & ~match & fired)      # Type II inclusion
#   decay   = sum_b (sel & match & ~fired)            # Type Ib erasure
#   delta   = hi*present - lo*(absent + decay) + excl*inval
#
# The whole 2B contraction happens inside one block (like R staying whole
# in the inference kernels), so each (block_k, block_n) output tile is
# independent — no cross-chunk accumulator.  f32 MACs are exact for the
# integer mask counts involved (< 2**24).  Layouts (prepared by
# ``backends.PallasBackend.ta_feedback``):
#
#   litT          (K, B2)  f32   transposed doubled literals; pads 0
#   sel/match/fd  (B2, N)  f32   feedback masks; pads 0 (neutral: a padded
#                                row/column selects nothing)
#   hi/lo/excl   (K, N)    f32   per-TA draws + exclude mask; pads 0, so
#                                padded cells produce delta == 0
#   out          (K, N)    i32   TA state deltas


def _ta_feedback_kernel(litT_ref, sel_ref, match_ref, fired_ref, hi_ref,
                        lo_ref, excl_ref, out_ref):
    s = sel_ref[...]
    mt = match_ref[...]
    f = fired_ref[...]
    t1f = s * mt * f
    t1nf = s * mt * (1.0 - f)
    t2f = s * (1.0 - mt) * f
    litT = litT_ref[...]
    dot = lambda a, b: jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    present = dot(litT, t1f)
    absent = dot(1.0 - litT, t1f)
    inval = dot(1.0 - litT, t2f)
    decay = t1nf.sum(axis=0, keepdims=True)
    delta = (hi_ref[...] * present - lo_ref[...] * (absent + decay)
             + excl_ref[...] * inval)
    out_ref[...] = delta.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("block_k", "block_n", "interpret"))
def ta_feedback(litT: Array, sel: Array, match: Array, fired2: Array,
                hi: Array, lo: Array, excl: Array, *, block_k: int = 128,
                block_n: int = 128, interpret: bool = False) -> Array:
    """litT (K, B2) f32, sel/match/fired2 (B2, N) f32, hi/lo/excl (K, N)
    f32 -> ta_delta (K, N) int32.

    K % block_k == 0, N % block_n == 0, B2 % 128 == 0 required
    (``backends.PallasBackend.ta_feedback`` pads arbitrary shapes).
    """
    K, B2 = litT.shape
    B2b, N = sel.shape
    assert B2 == B2b and match.shape == sel.shape == fired2.shape
    assert hi.shape == lo.shape == excl.shape == (K, N)
    assert (K % block_k == 0 and N % block_n == 0 and B2 % 128 == 0), (
        K, B2, N)

    return pl.pallas_call(
        _ta_feedback_kernel,
        grid=(K // block_k, N // block_n),
        in_specs=[
            pl.BlockSpec((block_k, B2), lambda k, n: (k, 0)),
            pl.BlockSpec((B2, block_n), lambda k, n: (0, n)),
            pl.BlockSpec((B2, block_n), lambda k, n: (0, n)),
            pl.BlockSpec((B2, block_n), lambda k, n: (0, n)),
            pl.BlockSpec((block_k, block_n), lambda k, n: (k, n)),
            pl.BlockSpec((block_k, block_n), lambda k, n: (k, n)),
            pl.BlockSpec((block_k, block_n), lambda k, n: (k, n)),
        ],
        out_specs=pl.BlockSpec((block_k, block_n), lambda k, n: (k, n)),
        out_shape=jax.ShapeDtypeStruct((K, N), jnp.int32),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(litT, sel, match, fired2, hi, lo, excl)


def _fused_impact_packed_metered_kernel(drive_ref, pbits_ref, lvl_ref,
                                        ne_ref, wcur_ref, out_ref, meter_ref,
                                        acc_ref, macc_ref, *, n_n: int,
                                        n_r: int, thresh: float):
    """Packed datapath + the in-kernel energy meter: the meters bill the
    QUANTIZED column currents — the currents the packed cells actually
    draw — keeping the energy story consistent with the datapath."""
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        macc_ref[...] = jnp.zeros_like(macc_ref)

    lvl = lvl_ref[...]
    i_lcs, i_hcs = lvl[0, 0], lvl[0, 1]
    bb = drive_ref.shape[2]
    bn = ne_ref.shape[1]
    fired = jnp.broadcast_to(ne_ref[...] != 0, (bb, bn))
    i_chunk = jnp.zeros((bb, 1), jnp.float32)
    for r in range(n_r):                       # static unroll over row shards
        i_col = _packed_column_current(drive_ref, pbits_ref, r, i_lcs, i_hcs)
        fired = fired & (i_col < thresh)       # CSA + digital AND, in VMEM
        i_chunk += i_col.sum(axis=1, keepdims=True)
    macc_ref[...] += i_chunk
    acc_ref[...] += jax.lax.dot_general(
        fired.astype(jnp.float32), wcur_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(n == n_n - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...]
        lane = jax.lax.broadcasted_iota(jnp.int32, macc_ref.shape, 1)
        i_class = acc_ref[...].sum(axis=1, keepdims=True)
        meter_ref[...] = jnp.where(
            lane == METER_LANE_CLAUSE, macc_ref[...],
            jnp.where(lane == METER_LANE_CLASS, i_class, 0.0))


@functools.partial(
    jax.jit, static_argnames=("thresh", "block_b", "block_n", "interpret"))
def fused_impact_packed_metered(drive: Array, pbits: Array, levels: Array,
                                nonempty: Array, wcur: Array, *,
                                thresh: float, block_b: int = BLOCK_B,
                                block_n: int = BLOCK_N,
                                interpret: bool = False,
                                ) -> tuple[Array, Array]:
    """Metered variant of ``fused_impact_packed``: returns
    ``(class currents (B, M), meters (B, METER_LANES))`` with the same
    lane layout as ``fused_impact_metered``.
    """
    R, B, N, M = _check_packed_shapes(drive, pbits, levels, nonempty, wcur,
                                      block_b, block_n)
    n_n = N // block_n
    tr4 = drive.shape[3]

    return pl.pallas_call(
        functools.partial(_fused_impact_packed_metered_kernel, n_n=n_n,
                          n_r=R, thresh=thresh),
        grid=(B // block_b, n_n),
        in_specs=_packed_specs(R, block_b, tr4, block_n, M),
        out_specs=[
            pl.BlockSpec((block_b, M), lambda b, n: (b, 0)),
            pl.BlockSpec((block_b, METER_LANES), lambda b, n: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, M), jnp.float32),
            jax.ShapeDtypeStruct((B, METER_LANES), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_b, M), jnp.float32),
                        pltpu.VMEM((block_b, METER_LANES), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(drive, pbits, levels, nonempty, wcur)
