"""Pallas TPU kernel: class crossbar tile (weighted vote sum).

The paper's class crossbar sums weighted clause votes per class column via
Kirchhoff's law.  On TPU this is an int8 x int32 matmul accumulated in VMEM:

    scores = clauses @ W          # (B, N) x (N, M) -> (B, M) int32

M (the class count) is tiny (10 in the paper) — ``ops.class_sum`` pads it to
one 128-lane tile so the MXU stays aligned; the kernel grids over B and the
clause (N) axis and keeps the (bm, bn_cls) accumulator resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import _compat

Array = jax.Array

BLOCK_B = 128
BLOCK_N = 512   # clause-axis (contraction) block
BLOCK_M = 128   # class-axis block (paper: m=10, padded)


def _class_kernel(cl_ref, w_ref, out_ref, acc_ref, *, n_n: int):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        cl_ref[...], w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(n == n_n - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "block_m", "interpret"))
def class_sum(clauses: Array, weights: Array, *, block_b: int = BLOCK_B,
              block_n: int = BLOCK_N, block_m: int = BLOCK_M,
              interpret: bool = False) -> Array:
    """clauses (B, N) int8, weights (N, M) int32 -> scores (B, M) int32."""
    B, N = clauses.shape
    N2, M = weights.shape
    assert N == N2
    assert B % block_b == 0 and N % block_n == 0 and M % block_m == 0, (
        (B, N, M, block_b, block_n, block_m))
    n_n = N // block_n

    return pl.pallas_call(
        functools.partial(_class_kernel, n_n=n_n),
        grid=(B // block_b, M // block_m, n_n),
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda b, m, n: (b, n)),
            pl.BlockSpec((block_n, block_m), lambda b, m, n: (n, m)),
        ],
        out_specs=pl.BlockSpec((block_b, block_m), lambda b, m, n: (b, m)),
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_b, block_m), jnp.int32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(clauses, weights)
