"""Pallas TPU kernels for the IMPACT hot spots.

Layout (one module per kernel + shared wrappers/oracles):

* ``clause_eval.py``  — clause crossbar: binary matmul + CSA ``==0`` epilogue
* ``class_sum.py``    — class crossbar: weighted vote accumulation
* ``fused_cotm.py``   — both crossbars fused in one VMEM residency
* ``fused_impact.py`` — fused ANALOG path: cell currents + CSA + periphery
* ``crossbar_mvm.py`` — analog conductance MVM with read nonlinearity
* ``backends.py``     — pluggable backend registry (pallas / xla / ...)
* ``ops.py``          — public wrappers dispatching through the registry
* ``ref.py``          — pure-jnp oracles (the test ground truth)
"""
from . import backends, ops, ref
from .backends import (available_backends, get_backend, register_backend,
                       unregister_backend)
from .ops import (class_sum, clause_eval, crossbar_mvm, fused_cotm,
                  fused_impact)

__all__ = ["backends", "ops", "ref", "available_backends", "get_backend",
           "register_backend", "unregister_backend", "class_sum",
           "clause_eval", "crossbar_mvm", "fused_cotm", "fused_impact"]
