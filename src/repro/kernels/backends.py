"""Pluggable inference-backend registry.

A *backend* is one lowering of the crossbar primitives the IMPACT
runtime is built from — the Pallas kernels, the pure-einsum oracles, or
(future) a TPU-native / metered-fused lowering.  Dispatch used to be an
``if impl == "xla"`` string switch copy-pasted into every jitted entry
point; it now lives here, so a new backend slots in by registering an
object instead of touching call sites — ``MeteredPallasBackend``
(``"pallas-metered"``, the always-metered fused lowering) is the first
backend that arrived purely through this seam:

    class MyLowering(PallasBackend):
        name = "pallas-mine"
        ...
    register_backend(MyLowering())

Every backend also lowers ``fused_impact_metered`` — inference plus the
per-lane read-current meters (the Table 4 energy accounting) in one
call: the Pallas backends accumulate the meters inside the fused
kernel's VMEM residency, the reference backend uses the whole-array
metered oracle, and the base class composes the staged per-shard
primitives so any third backend meters correctly out of the box.

``kernels.ops`` keeps the public wrapper signatures (``impl=`` is simply
the registry key) and the compiled-session runtime (``impact.runtime``)
resolves a backend ONCE per ``RuntimeSpec`` instead of per call.

Two policies are shared across every op and hoisted here from the four
copies that used to live in ``ops.py``:

* **interpret resolution** (``Backend.resolve_interpret``): Pallas
  kernels run in interpret mode automatically off-TPU so the same call
  sites work in CI (CPU) and production (TPU); reference backends have
  no kernel to interpret and always resolve ``False``.
* **neutral padding** (``pad_axis`` + the per-op plumbing in
  ``PallasBackend``): arbitrary shapes are padded to MXU-aligned tiles
  with *semantically neutral* values (literal rows pad with 1 — a
  floating 'Z' row contributes no current; clause columns pad with
  include=0/nonempty=0/weight=0; conductances pad above the
  nonlinearity cutoff) and outputs are sliced back.

The staged analog compositions (``impact_clause_bits`` /
``impact_class_scores``) have a backend-generic default built from
``crossbar_mvm`` — the Fig. 14 per-shard unroll — which reference
backends override with their whole-array oracles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import clause_eval as _clause_kernel
from . import class_sum as _class_kernel
from . import crossbar_mvm as _mvm_kernel
from . import fused_cotm as _fused_kernel
from . import fused_impact as _impact_kernel
from . import packing
from . import ref

Array = jax.Array


def pad_axis(x: Array, mult: int, axis: int, value) -> Array:
    """Pad ``axis`` up to the next multiple of ``mult`` with ``value``."""
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


class Backend:
    """One lowering of the crossbar primitives.

    Subclass, set ``name``, implement the primitive ops, and
    ``register_backend`` an instance.  Instances are stateless
    singletons: jitted entry points pass the *name* through static
    arguments and resolve the object inside the trace, so registering a
    backend never invalidates jit caches.
    """

    name: str = ""
    #: True for oracle backends (pure jnp, no kernel, nothing to
    #: interpret) — used by tests and benchmarks to pick A/B sides.
    reference: bool = False

    # -- shape policy ------------------------------------------------------
    def resolve_interpret(self, interpret: bool | None) -> bool:
        """The ONE interpret-mode resolver (was copy-pasted per wrapper):
        ``None`` means "interpret off-TPU", so CI (CPU) and production
        (TPU) share call sites."""
        if interpret is None:
            return jax.default_backend() != "tpu"
        return bool(interpret)

    # -- primitive ops -----------------------------------------------------
    def clause_eval(self, literals: Array, include: Array, nonempty: Array,
                    *, mode: str = "fired", interpret: bool | None = None,
                    block_b: int = 128, block_n: int = 128,
                    block_k: int = 512) -> Array:
        raise NotImplementedError

    def class_sum(self, clauses: Array, weights: Array, *,
                  interpret: bool | None = None, block_b: int = 128,
                  block_n: int = 512, block_m: int = 128) -> Array:
        raise NotImplementedError

    def fused_cotm(self, literals: Array, include: Array, nonempty: Array,
                   weights: Array, *, interpret: bool | None = None,
                   block_b: int = 128, block_n: int = 256) -> Array:
        raise NotImplementedError

    def fused_impact(self, literals: Array, clause_i: Array, nonempty: Array,
                     class_i: Array, *, thresh: float,
                     interpret: bool | None = None, block_b: int = 128,
                     block_n: int = 256) -> Array:
        raise NotImplementedError

    def fused_impact_metered(self, literals: Array, clause_i: Array,
                             nonempty: Array, class_i: Array, *,
                             thresh: float, interpret: bool | None = None,
                             block_b: int = 128, block_n: int = 256,
                             ) -> tuple[Array, Array, Array]:
        """-> (scores (B, M), per-lane summed clause-crossbar column
        currents (B,), per-lane summed class-crossbar column currents
        (B,)) — inference plus the Table 4 energy meters in one pass.

        Default composition: the staged per-shard primitives, summing the
        column currents they already materialize.  Kernel backends
        override this with a fused lowering (``PallasBackend`` accumulates
        the meters inside the fused kernel's VMEM residency), but ANY
        registered backend supports ``RuntimeSpec(metering="fused")``
        through this fallback — correctness never depends on the
        override, only throughput does.
        """
        fired, i_col = self.impact_clause_bits(
            literals, clause_i, nonempty, thresh=thresh, interpret=interpret)
        scores, i_cls = self.impact_class_scores(fired, class_i,
                                                 interpret=interpret)
        return scores, i_col.sum(axis=(1, 2, 3)), i_cls.sum(axis=(1, 2))

    def crossbar_mvm(self, drive: Array, g: Array, *, v_read: float = 2.0,
                     nonlin: float = 1.5, cutoff: float = 10e-9,
                     interpret: bool | None = None, block_b: int = 128,
                     block_n: int = 128, block_k: int = 512) -> Array:
        raise NotImplementedError

    # -- bitplane-packed datapath (kernels.packing layout) -----------------
    def pack_clause_operand(self, clause_i: Array, *,
                            split: float | None = None,
                            ) -> packing.PackedClause:
        """Quantize a clause-current operand to the 2-bit packed layout.
        ``split=None`` classifies HCS/LCS at the device-population
        midpoint (``packing.population_split``)."""
        return packing.pack_clause_operand(clause_i, split=split)

    def fused_impact_packed(self, literals: Array,
                            packed: packing.PackedClause, nonempty: Array,
                            class_i: Array, *, thresh: float, tr: int,
                            interpret: bool | None = None,
                            block_b: int = 128, block_n: int = 256) -> Array:
        """``fused_impact`` on a packed clause operand.  ``tr`` is the
        UNPACKED per-shard row count (not recoverable from the packed
        bits — the shard row mapping needs it).

        Default composition: dequantize and delegate, so every
        registered backend accepts ``RuntimeSpec(packing="2bit")`` out of
        the box; ``PackedPallasBackend`` overrides with the kernel that
        unpacks in VMEM and never materializes the f32 operand.
        """
        clause_i = packing.dequant_clause(packed.bits, packed.levels, tr)
        return self.fused_impact(literals, clause_i, nonempty, class_i,
                                 thresh=thresh, interpret=interpret,
                                 block_b=block_b, block_n=block_n)

    def fused_impact_packed_metered(self, literals: Array,
                                    packed: packing.PackedClause,
                                    nonempty: Array, class_i: Array, *,
                                    thresh: float, tr: int,
                                    interpret: bool | None = None,
                                    block_b: int = 128, block_n: int = 256,
                                    ) -> tuple[Array, Array, Array]:
        """Metered packed datapath; meters bill the QUANTIZED currents
        (what the packed cells draw), same triple as
        ``fused_impact_metered``."""
        clause_i = packing.dequant_clause(packed.bits, packed.levels, tr)
        return self.fused_impact_metered(literals, clause_i, nonempty,
                                         class_i, thresh=thresh,
                                         interpret=interpret,
                                         block_b=block_b, block_n=block_n)

    # -- crossbar co-residency (block-diagonal multi-tenant grids) ---------
    def fused_impact_coresident(self, literals: Array, clause_i: Array,
                                nonempty: Array, class_i: Array,
                                model_ids: Array, clause_spans: Array, *,
                                thresh: float,
                                interpret: bool | None = None,
                                block_b: int = 128,
                                block_n: int = 256) -> Array:
        """``fused_impact`` on a block-diagonal co-resident grid with a
        per-lane tenant mask (``model_ids`` (B,) int32 indexing
        ``clause_spans`` (T, 2) ``[lo, hi)`` clause-column spans).

        A lane drives only its own tenant's literal rows, so foreign
        clause columns draw exactly 0 A — but 0 A is below the CSA
        threshold, so foreign nonempty columns would spuriously fire.
        The mask, applied between the clause and class stages, gates
        those bits off; with off-block cells at 0 A this makes
        cross-tenant leakage exactly zero by construction (see
        ``ref.coresident_lane_mask``).

        Default composition from the staged primitives, so every
        registered backend serves co-resident sweeps (the Pallas
        backends ride their ``crossbar_mvm`` kernels through it); the
        einsum oracle is ``ref.fused_impact_coresident_ref``.
        """
        fired, _ = self.impact_clause_bits(
            literals, clause_i, nonempty, thresh=thresh, interpret=interpret)
        fired = jnp.logical_and(
            fired, ref.coresident_lane_mask(model_ids, clause_spans,
                                            fired.shape[1]))
        scores, _ = self.impact_class_scores(fired, class_i,
                                             interpret=interpret)
        return scores

    def fused_impact_coresident_metered(
            self, literals: Array, clause_i: Array, nonempty: Array,
            class_i: Array, model_ids: Array, clause_spans: Array, *,
            thresh: float, interpret: bool | None = None,
            block_b: int = 128, block_n: int = 256,
            ) -> tuple[Array, Array, Array]:
        """Metered co-resident sweep, same triple as
        ``fused_impact_metered``.  Both per-lane meters are tenant-pure:
        the clause meter because foreign columns draw 0 A, the class
        meter because the lane mask runs before the class drive."""
        fired, i_col = self.impact_clause_bits(
            literals, clause_i, nonempty, thresh=thresh, interpret=interpret)
        fired = jnp.logical_and(
            fired, ref.coresident_lane_mask(model_ids, clause_spans,
                                            fired.shape[1]))
        scores, i_cls = self.impact_class_scores(fired, class_i,
                                                 interpret=interpret)
        return scores, i_col.sum(axis=(1, 2, 3)), i_cls.sum(axis=(1, 2))

    def fused_impact_coresident_packed(
            self, literals: Array, packed: packing.PackedClause,
            nonempty: Array, class_i: Array, model_ids: Array,
            clause_spans: Array, *, thresh: float, tr: int,
            interpret: bool | None = None, block_b: int = 128,
            block_n: int = 256) -> Array:
        """Co-resident sweep on a 2-bit packed clause operand:
        dequantize and delegate, so ``packing="2bit"`` composes with
        co-residency on every backend."""
        clause_i = packing.dequant_clause(packed.bits, packed.levels, tr)
        return self.fused_impact_coresident(
            literals, clause_i, nonempty, class_i, model_ids, clause_spans,
            thresh=thresh, interpret=interpret, block_b=block_b,
            block_n=block_n)

    def fused_impact_coresident_packed_metered(
            self, literals: Array, packed: packing.PackedClause,
            nonempty: Array, class_i: Array, model_ids: Array,
            clause_spans: Array, *, thresh: float, tr: int,
            interpret: bool | None = None, block_b: int = 128,
            block_n: int = 256) -> tuple[Array, Array, Array]:
        """Metered packed co-resident sweep (meters bill the quantized
        currents, like ``fused_impact_packed_metered``)."""
        clause_i = packing.dequant_clause(packed.bits, packed.levels, tr)
        return self.fused_impact_coresident_metered(
            literals, clause_i, nonempty, class_i, model_ids, clause_spans,
            thresh=thresh, interpret=interpret, block_b=block_b,
            block_n=block_n)

    # -- online training (arXiv:2408.09456 in-array TA updates) ------------
    def ta_feedback(self, lit2: Array, fired2: Array, sel: Array,
                    match: Array, hi: Array, lo: Array, include: Array, *,
                    interpret: bool | None = None, block_k: int = 128,
                    block_n: int = 128) -> Array:
        """CoTM Type I/II TA feedback deltas over one doubled update batch
        -> ta_delta (K, n) int32 (see ``ref.ta_feedback_ref`` for the full
        mask semantics).  All stochastic draws (``sel``/``hi``/``lo``) are
        precomputed operands, so every backend computes bit-identical
        deltas from the same inputs — the parity contract the online
        trainer's write path depends on.

        Default: the einsum oracle; ``PallasBackend`` overrides with the
        fused kernel that accumulates the three feedback matmuls in one
        VMEM residency of the clause-output datapath.
        """
        return ref.ta_feedback_ref(lit2, fired2, sel, match, hi, lo,
                                   include)

    # -- staged analog compositions (Fig. 14 per-shard unroll) -------------
    def impact_clause_bits(self, literals: Array, clause_i: Array,
                           nonempty: Array, *, thresh: float,
                           interpret: bool | None = None,
                           ) -> tuple[Array, Array]:
        """-> (fired (B, C*tc) bool, shard column currents (B, R, C, tc)).

        Default composition shared by every kernel backend: per-shard
        ``crossbar_mvm`` column currents, CSA threshold, digital AND
        over the R row shards, ``nonempty`` mask.
        """
        B = literals.shape[0]
        R, C, tr, tc = clause_i.shape
        lit = ref.pad_to(literals.astype(jnp.float32), R * tr, axis=1,
                         value=1)
        drive = (1.0 - lit).reshape(B, R, tr)
        cols = []
        for r in range(R):                      # static shard unroll
            cur = clause_i[r].transpose(1, 0, 2).reshape(tr, C * tc)
            cols.append(self.crossbar_mvm(drive[:, r], cur, v_read=1.0,
                                          cutoff=0.0, interpret=interpret))
        i_col = jnp.stack(cols, axis=1).reshape(B, R, C, tc)
        fired = jnp.all(i_col < thresh, axis=1).reshape(B, C * tc)
        return jnp.logical_and(fired, nonempty.astype(bool)), i_col

    def impact_class_scores(self, clauses: Array, class_i: Array, *,
                            interpret: bool | None = None,
                            ) -> tuple[Array, Array]:
        """-> (scores (B, m) = summed shard currents, currents (B, S, m))."""
        B = clauses.shape[0]
        S, sr, m = class_i.shape
        drive = ref.pad_to(clauses.astype(jnp.float32), S * sr, axis=1)
        drive = drive[:, :S * sr].reshape(B, S, sr)
        i_col = jnp.stack(
            [self.crossbar_mvm(drive[:, s], class_i[s], v_read=1.0,
                               cutoff=0.0, interpret=interpret)
             for s in range(S)],
            axis=1)                             # per-shard ADC
        return i_col.sum(axis=1), i_col         # digital add


class PallasBackend(Backend):
    """The production lowering: Pallas TPU kernels (interpret mode
    off-TPU), with the neutral-padding plumbing around each one."""

    name = "pallas"

    def clause_eval(self, literals, include, nonempty, *, mode="fired",
                    interpret=None, block_b=128, block_n=128, block_k=512):
        B, K = literals.shape
        N = include.shape[1]
        interpret = self.resolve_interpret(interpret)
        block_k = min(block_k, max(128, -(-K // 128) * 128))
        lit = pad_axis(pad_axis(literals.astype(jnp.int8), block_b, 0, 1),
                       block_k, 1, 1)      # pad literals with 1 ('Z' rows)
        inc = pad_axis(pad_axis(include.astype(jnp.int8), block_k, 0, 0),
                       block_n, 1, 0)
        ne = pad_axis(nonempty.astype(jnp.int8)[None, :], block_n, 1, 0)
        out = _clause_kernel.clause_eval(
            lit, inc, ne, mode=mode, block_b=block_b, block_n=block_n,
            block_k=block_k, interpret=interpret)[:B, :N]
        return out if mode == "viol" else out.astype(bool)

    def class_sum(self, clauses, weights, *, interpret=None, block_b=128,
                  block_n=512, block_m=128):
        B, N = clauses.shape
        M = weights.shape[1]
        interpret = self.resolve_interpret(interpret)
        block_n = min(block_n, max(128, -(-N // 128) * 128))
        cl = pad_axis(pad_axis(clauses.astype(jnp.int8), block_b, 0, 0),
                      block_n, 1, 0)
        w = pad_axis(pad_axis(weights.astype(jnp.int32), block_n, 0, 0),
                     block_m, 1, 0)
        out = _class_kernel.class_sum(
            cl, w, block_b=block_b, block_n=block_n, block_m=block_m,
            interpret=interpret)
        return out[:B, :M]

    def fused_cotm(self, literals, include, nonempty, weights, *,
                   interpret=None, block_b=128, block_n=256):
        B, K = literals.shape
        N, M = weights.shape
        interpret = self.resolve_interpret(interpret)
        block_n = min(block_n, max(128, -(-N // 128) * 128))
        lit = pad_axis(pad_axis(literals.astype(jnp.int8), block_b, 0, 1),
                       128, 1, 1)
        inc = pad_axis(pad_axis(include.astype(jnp.int8), 128, 0, 0),
                       block_n, 1, 0)
        ne = pad_axis(nonempty.astype(jnp.int8)[None, :], block_n, 1, 0)
        w = pad_axis(pad_axis(weights.astype(jnp.int32), block_n, 0, 0),
                     128, 1, 0)
        out = _fused_kernel.fused_cotm(
            lit, inc, ne, w, block_b=block_b, block_n=block_n,
            interpret=interpret)
        return out[:B, :M]

    def _fused_impact_operands(self, literals, clause_i, nonempty, class_i,
                               *, block_b, block_n):
        """Shared neutral-padding plumbing of the fused IMPACT kernels:
        -> (drive, ccur, ne, wcur, block_n) in the kernel layouts, with
        padded rows/columns contributing exactly zero current (floating
        'Z' literal rows, nonempty=0 clause columns, 0 A class cells) —
        which is what makes the in-kernel meters exact."""
        B, K = literals.shape
        R, C, tr, tc = clause_i.shape
        S, sr, M = class_i.shape
        n_clause = C * tc

        # Unify the clause-column axis of both crossbars: the clause tile
        # pads n to C*tc, the class tile to S*sr; dead columns (>= n)
        # fire 0.
        N = max(n_clause, S * sr)
        block_n = min(block_n, max(128, -(-N // 128) * 128))
        tr_pad = max(128, -(-tr // 128) * 128)

        lit = pad_axis(literals.astype(jnp.float32), R * tr, 1, 1)
        drive = (1.0 - lit).reshape(B, R, tr).transpose(1, 0, 2)
        drive = pad_axis(pad_axis(drive, block_b, 1, 0.0), tr_pad, 2, 0.0)

        ccur = clause_i.astype(jnp.float32).transpose(0, 2, 1, 3)
        ccur = ccur.reshape(R, tr, n_clause)
        ccur = pad_axis(pad_axis(ccur, tr_pad, 1, 0.0), block_n, 2, 0.0)
        if N > n_clause:
            ccur = pad_axis(ccur, -(-N // block_n) * block_n, 2, 0.0)

        ne = pad_axis(nonempty.astype(jnp.int8)[None, :],
                      -(-N // block_n) * block_n, 1, 0)

        wcur = class_i.astype(jnp.float32).reshape(S * sr, M)
        wcur = pad_axis(pad_axis(wcur, ne.shape[1], 0, 0.0), 128, 1, 0.0)
        return drive, ccur, ne, wcur, block_n

    def fused_impact(self, literals, clause_i, nonempty, class_i, *,
                     thresh, interpret=None, block_b=128, block_n=256):
        B, M = literals.shape[0], class_i.shape[2]
        interpret = self.resolve_interpret(interpret)
        drive, ccur, ne, wcur, block_n = self._fused_impact_operands(
            literals, clause_i, nonempty, class_i, block_b=block_b,
            block_n=block_n)
        out = _impact_kernel.fused_impact(
            drive, ccur, ne, wcur, thresh=thresh, block_b=block_b,
            block_n=block_n, interpret=interpret)
        return out[:B, :M]

    def fused_impact_metered(self, literals, clause_i, nonempty, class_i,
                             *, thresh, interpret=None, block_b=128,
                             block_n=256):
        """The tentpole lowering: scores AND both per-lane current meters
        from ONE fused kernel pass (second VMEM accumulator), no staged
        second pass.  Padding contributes exactly zero current, so the
        sliced meters equal the staged per-shard sums to f32 tolerance."""
        B, M = literals.shape[0], class_i.shape[2]
        interpret = self.resolve_interpret(interpret)
        drive, ccur, ne, wcur, block_n = self._fused_impact_operands(
            literals, clause_i, nonempty, class_i, block_b=block_b,
            block_n=block_n)
        out, meters = _impact_kernel.fused_impact_metered(
            drive, ccur, ne, wcur, thresh=thresh, block_b=block_b,
            block_n=block_n, interpret=interpret)
        return (out[:B, :M],
                meters[:B, _impact_kernel.METER_LANE_CLAUSE],
                meters[:B, _impact_kernel.METER_LANE_CLASS])

    def ta_feedback(self, lit2, fired2, sel, match, hi, lo, include, *,
                    interpret=None, block_k=128, block_n=128):
        B2, K = lit2.shape
        n = hi.shape[1]
        interpret = self.resolve_interpret(interpret)
        b2p = max(128, -(-B2 // 128) * 128)
        block_k = min(block_k, max(128, -(-K // 128) * 128))
        block_n = min(block_n, max(128, -(-n // 128) * 128))
        # Neutral padding: padded batch rows / clause columns carry sel=0
        # (they select nothing), padded TA rows carry hi=lo=excl=0 (their
        # delta is exactly 0) — so the sliced output equals the oracle's.
        litT = pad_axis(pad_axis(lit2.astype(jnp.float32).T,
                                 block_k, 0, 0.0), b2p, 1, 0.0)
        mask = lambda x: pad_axis(pad_axis(x.astype(jnp.float32),
                                           b2p, 0, 0.0), block_n, 1, 0.0)
        cell = lambda x: pad_axis(pad_axis(x.astype(jnp.float32),
                                           block_k, 0, 0.0),
                                  block_n, 1, 0.0)
        excl = jnp.logical_not(include.astype(bool))
        out = _impact_kernel.ta_feedback(
            litT, mask(sel), mask(match), mask(fired2), cell(hi), cell(lo),
            cell(excl), block_k=block_k, block_n=block_n,
            interpret=interpret)
        return out[:K, :n]

    def crossbar_mvm(self, drive, g, *, v_read=2.0, nonlin=1.5,
                     cutoff=10e-9, interpret=None, block_b=128,
                     block_n=128, block_k=512):
        B, K = drive.shape
        N = g.shape[1]
        interpret = self.resolve_interpret(interpret)
        block_k = min(block_k, max(128, -(-K // 128) * 128))
        dr = pad_axis(pad_axis(drive.astype(jnp.float32), block_b, 0, 0.0),
                      block_k, 1, 0.0)
        # Pad conductances ABOVE the nonlinearity cutoff so padded cells
        # do not get the LCS boost; padded drive rows are 0 so they
        # contribute nothing.
        gp = pad_axis(pad_axis(g.astype(jnp.float32), block_k, 0, 1.0),
                      block_n, 1, 1.0)
        out = _mvm_kernel.crossbar_mvm(
            dr, gp, v_read=v_read, nonlin=nonlin, cutoff=cutoff,
            block_b=block_b, block_n=block_n, block_k=block_k,
            interpret=interpret)
        return out[:B, :N]


class XLABackend(Backend):
    """Pure-einsum oracles (``kernels.ref``) for A/B parity runs and
    wall-clock-sensitive CPU callers; every test ground-truths against
    this backend."""

    name = "xla"
    reference = True

    def resolve_interpret(self, interpret):
        return False                      # nothing to interpret

    def clause_eval(self, literals, include, nonempty, *, mode="fired",
                    interpret=None, block_b=128, block_n=128, block_k=512):
        if mode == "viol":
            return ref.clause_viol_ref(literals, include)
        return ref.clause_eval_ref(literals, include, nonempty)

    def class_sum(self, clauses, weights, *, interpret=None, block_b=128,
                  block_n=512, block_m=128):
        return ref.class_sum_ref(clauses, weights)

    def fused_cotm(self, literals, include, nonempty, weights, *,
                   interpret=None, block_b=128, block_n=256):
        return ref.fused_cotm_ref(literals, include, weights, nonempty)

    def fused_impact(self, literals, clause_i, nonempty, class_i, *,
                     thresh, interpret=None, block_b=128, block_n=256):
        return ref.fused_impact_ref(literals, clause_i, nonempty, class_i,
                                    thresh=thresh)

    # fused_impact_metered is inherited: the base composition over THIS
    # backend's staged primitives is exactly the whole-array metered
    # oracle (``ref.fused_impact_metered_ref`` spells out the same
    # expression for direct use in tests).

    def crossbar_mvm(self, drive, g, *, v_read=2.0, nonlin=1.5,
                     cutoff=10e-9, interpret=None, block_b=128,
                     block_n=128, block_k=512):
        return ref.crossbar_mvm_ref(drive, g, v_read=v_read, nonlin=nonlin,
                                    cutoff=cutoff)

    def impact_clause_bits(self, literals, clause_i, nonempty, *, thresh,
                           interpret=None):
        return ref.impact_clause_bits_ref(literals, clause_i, nonempty,
                                          thresh=thresh)

    def impact_class_scores(self, clauses, class_i, *, interpret=None):
        return ref.impact_class_scores_ref(clauses, class_i)

    def fused_impact_packed(self, literals, packed, nonempty, class_i, *,
                            thresh, tr, interpret=None, block_b=128,
                            block_n=256):
        return ref.fused_impact_packed_ref(
            literals, packed.bits, packed.levels, nonempty, class_i,
            thresh=thresh, tr=tr)

    def fused_impact_packed_metered(self, literals, packed, nonempty,
                                    class_i, *, thresh, tr, interpret=None,
                                    block_b=128, block_n=256):
        return ref.fused_impact_packed_metered_ref(
            literals, packed.bits, packed.levels, nonempty, class_i,
            thresh=thresh, tr=tr)


class MeteredPallasBackend(PallasBackend):
    """The always-metered Pallas lowering: every fused inference runs the
    metered kernel, scores-only callers just drop the meters.

    ``RuntimeSpec(backend="pallas", metering="fused")`` already reaches
    the metered kernel through ``PallasBackend.fused_impact_metered``;
    this registered variant exists so the *unmetered* entry points
    (``predict``, benchmark sweeps) can ride the metered kernel too —
    the one-to-one A/B that prices the in-kernel meter on the identical
    call path (``benchmarks/impact_throughput.py`` records it as the
    ``metered_fused`` sample), and the registry's proof that a new
    lowering slots in by registration alone.
    """

    name = "pallas-metered"

    def fused_impact(self, literals, clause_i, nonempty, class_i, *,
                     thresh, interpret=None, block_b=128, block_n=256):
        scores, _, _ = self.fused_impact_metered(
            literals, clause_i, nonempty, class_i, thresh=thresh,
            interpret=interpret, block_b=block_b, block_n=block_n)
        return scores


class PackedPallasBackend(PallasBackend):
    """The compressed lowering: the fused kernel consumes bitplane-packed
    clause bits (``kernels.packing`` 2-bit layout) and unpacks them in
    VMEM — the f32 clause-current operand never exists in HBM, so the
    dominant sweep operand shrinks ~16x (f32 cell currents -> 2-bit
    codes) and total sweep input bytes drop well past 4x.

    Sessions built with ``RuntimeSpec(packing="2bit")`` pack ONCE at
    compile time and feed ``fused_impact_packed`` directly; the plain
    ``fused_impact`` entry points pack in-trace (constant-folded under
    jit for weight operands), so this backend is also a drop-in registry
    key for ``ops.*(impl="pallas-packed")``.
    """

    name = "pallas-packed"

    def _fused_impact_packed_operands(self, literals, packed, nonempty,
                                      class_i, *, tr, block_b, block_n):
        """Neutral-padding plumbing for the packed kernel layouts:
        -> (drive_p, pbits, levels, ne, wcur, block_n).  Padding packs to
        CODE_DEAD (0 A) and pads drive with 0, so padded rows/columns
        contribute exactly zero current — the meters stay exact."""
        B, K = literals.shape
        R, C, tr4, tc = packed.bits.shape
        S, sr, M = class_i.shape
        n_clause = C * tc

        N = max(n_clause, S * sr)
        block_n = min(block_n, max(128, -(-N // 128) * 128))
        tr4_pad = max(128, -(-tr4 // 128) * 128)

        # Bitplane-major drive: drive_p[r, j, b, q] = 1 - lit[b, r*tr+4q+j].
        lit = pad_axis(literals.astype(jnp.float32), R * tr, 1, 1)
        drive = (1.0 - lit).reshape(B, R, tr)
        drive = pad_axis(drive, packing.CELLS_PER_BYTE * tr4, 2, 0.0)
        drive = drive.reshape(B, R, tr4, packing.CELLS_PER_BYTE)
        drive = drive.transpose(1, 3, 0, 2)         # (R, 4, B, tr4)
        drive = pad_axis(pad_axis(drive, block_b, 2, 0.0), tr4_pad, 3, 0.0)

        pbits = packed.bits.transpose(0, 2, 1, 3).reshape(R, tr4, n_clause)
        pbits = pad_axis(pad_axis(pbits, tr4_pad, 1, 0), block_n, 2, 0)
        if N > n_clause:
            pbits = pad_axis(pbits, -(-N // block_n) * block_n, 2, 0)

        levels = jnp.zeros((1, 128), jnp.float32)
        levels = levels.at[0, :2].set(packed.levels.astype(jnp.float32))

        ne = pad_axis(nonempty.astype(jnp.int8)[None, :],
                      -(-N // block_n) * block_n, 1, 0)

        wcur = class_i.astype(jnp.float32).reshape(S * sr, M)
        wcur = pad_axis(pad_axis(wcur, ne.shape[1], 0, 0.0), 128, 1, 0.0)
        return drive, pbits, levels, ne, wcur, block_n

    def fused_impact_packed(self, literals, packed, nonempty, class_i, *,
                            thresh, tr, interpret=None, block_b=128,
                            block_n=256):
        B, M = literals.shape[0], class_i.shape[2]
        interpret = self.resolve_interpret(interpret)
        drive, pbits, levels, ne, wcur, block_n = (
            self._fused_impact_packed_operands(
                literals, packed, nonempty, class_i, tr=tr,
                block_b=block_b, block_n=block_n))
        out = _impact_kernel.fused_impact_packed(
            drive, pbits, levels, ne, wcur, thresh=thresh, block_b=block_b,
            block_n=block_n, interpret=interpret)
        return out[:B, :M]

    def fused_impact_packed_metered(self, literals, packed, nonempty,
                                    class_i, *, thresh, tr, interpret=None,
                                    block_b=128, block_n=256):
        B, M = literals.shape[0], class_i.shape[2]
        interpret = self.resolve_interpret(interpret)
        drive, pbits, levels, ne, wcur, block_n = (
            self._fused_impact_packed_operands(
                literals, packed, nonempty, class_i, tr=tr,
                block_b=block_b, block_n=block_n))
        out, meters = _impact_kernel.fused_impact_packed_metered(
            drive, pbits, levels, ne, wcur, thresh=thresh, block_b=block_b,
            block_n=block_n, interpret=interpret)
        return (out[:B, :M],
                meters[:B, _impact_kernel.METER_LANE_CLAUSE],
                meters[:B, _impact_kernel.METER_LANE_CLASS])

    def fused_impact(self, literals, clause_i, nonempty, class_i, *,
                     thresh, interpret=None, block_b=128, block_n=256):
        packed = self.pack_clause_operand(clause_i)
        return self.fused_impact_packed(
            literals, packed, nonempty, class_i, thresh=thresh,
            tr=clause_i.shape[2], interpret=interpret, block_b=block_b,
            block_n=block_n)

    def fused_impact_metered(self, literals, clause_i, nonempty, class_i,
                             *, thresh, interpret=None, block_b=128,
                             block_n=256):
        packed = self.pack_clause_operand(clause_i)
        return self.fused_impact_packed_metered(
            literals, packed, nonempty, class_i, thresh=thresh,
            tr=clause_i.shape[2], interpret=interpret, block_b=block_b,
            block_n=block_n)


# -- registry ---------------------------------------------------------------

_REGISTRY: dict[str, Backend] = {}

#: The primitive contract every registered backend must satisfy: the
#: ops the session/entry points may route to.  ``Backend`` supplies
#: working compositions for most, so subclasses only override what they
#: specialize — but a registrant that *deletes* one of these (sets it to
#: None, or shadows it with a non-callable) would fail at serving time;
#: ``register_backend`` refuses it up front, and the IMPACT004 lint rule
#: proves the same contract (plus signatures) statically.
REQUIRED_PRIMITIVES: tuple[str, ...] = (
    "resolve_interpret", "clause_eval", "class_sum",
    "fused_cotm", "fused_impact", "fused_impact_metered",
    "crossbar_mvm", "pack_clause_operand",
    "fused_impact_packed", "fused_impact_packed_metered",
    "fused_impact_coresident", "fused_impact_coresident_metered",
    "fused_impact_coresident_packed",
    "fused_impact_coresident_packed_metered",
    "impact_clause_bits", "impact_class_scores", "ta_feedback",
)


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    """Register a backend under ``backend.name``.  Registering is how a
    new lowering (TPU-native, metered-fused, ...) plugs into every entry
    point — ``RuntimeSpec(backend=<name>)`` and ``ops.*(impl=<name>)``
    resolve through here, so no call site changes."""
    if not backend.name:
        raise ValueError("backend must define a non-empty .name")
    missing = [p for p in REQUIRED_PRIMITIVES
               if not callable(getattr(backend, p, None))]
    if missing:
        raise TypeError(
            f"backend {backend.name!r} does not satisfy the primitive "
            f"contract: {', '.join(missing)} "
            f"{'is' if len(missing) == 1 else 'are'} missing or not "
            f"callable (see backends.REQUIRED_PRIMITIVES)")
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} is already registered "
                         f"(pass overwrite=True to replace it)")
    _REGISTRY[backend.name] = backend
    return backend


def unregister_backend(name: str) -> Backend:
    """Remove a registered backend (tests / plugin teardown)."""
    try:
        return _REGISTRY.pop(name)
    except KeyError:
        raise ValueError(f"backend {name!r} is not registered") from None


def get_backend(name: str | Backend) -> Backend:
    """Resolve a registry key (or pass a backend instance through)."""
    if isinstance(name, Backend):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{sorted(_REGISTRY)}") from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_backend(PallasBackend())
register_backend(XLABackend())
register_backend(MeteredPallasBackend())
register_backend(PackedPallasBackend())
