"""Pallas TPU kernel: clause crossbar tile (binary matmul + CSA epilogue).

The paper's clause crossbar computes, per column j, the current
``I_j = sum_i TA_inc[i,j] * (1-L[i]) * V_R`` and a current-sense amplifier
thresholds it at 4.1 uA (== "at least one (literal 0, include) pair").  On
TPU the same computation is an int8 MXU matmul with a ``== 0`` epilogue:

    viol  = (1 - L) @ TA_inc          # int8 x int8 -> int32 on the MXU
    fired = (viol == 0) & nonempty    # the CSA + empty-clause digital mask

The kernel keeps the int32 violation counts in a VMEM accumulator across the
K (literal) grid axis and only writes the 1-byte Boolean clause bits to HBM,
i.e. the "currents" never round-trip — exactly the in-memory-computing
property the paper gets from Kirchhoff's law.

``mode="viol"`` instead emits the raw violation counts; this is the partial
result exchanged between literal shards in the Fig. 14 multi-tile scheme
(psum of viol == the paper's digital AND of partial clauses).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import _compat

Array = jax.Array

# MXU-aligned default tiling: int8 min tile on TPU is (32, 128); we use
# 128-multiples everywhere so both MXU matmul dims are hardware aligned.
BLOCK_B = 128
BLOCK_N = 128
BLOCK_K = 512


def _clause_kernel(lit_ref, inc_ref, ne_ref, out_ref, acc_ref, *,
                   n_k: int, mode: str):
    """Grid (B/bm, N/bn, K/bk); acc_ref is a (bm, bn) int32 VMEM scratch."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    not_l = (1 - lit_ref[...]).astype(jnp.int8)
    acc_ref[...] += jax.lax.dot_general(
        not_l, inc_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        viol = acc_ref[...]
        if mode == "viol":
            out_ref[...] = viol
        else:
            fired = (viol == 0) & (ne_ref[...] != 0)
            out_ref[...] = fired.astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("mode", "block_b", "block_n", "block_k",
                              "interpret"))
def clause_eval(literals: Array, include: Array, nonempty: Array, *,
                mode: str = "fired", block_b: int = BLOCK_B,
                block_n: int = BLOCK_N, block_k: int = BLOCK_K,
                interpret: bool = False) -> Array:
    """literals (B, K) int8, include (K, N) int8, nonempty (1, N) int8.

    Returns fired (B, N) int8 (mode="fired") or viol (B, N) int32
    (mode="viol").  All dims must already be multiples of the block sizes
    (``ops.clause_eval`` pads arbitrary shapes).
    """
    B, K = literals.shape
    K2, N = include.shape
    assert K == K2 and nonempty.shape == (1, N)
    assert B % block_b == 0 and N % block_n == 0 and K % block_k == 0, (
        (B, K, N, block_b, block_n, block_k))
    n_k = K // block_k
    out_dtype = jnp.int32 if mode == "viol" else jnp.int8

    return pl.pallas_call(
        functools.partial(_clause_kernel, n_k=n_k, mode=mode),
        grid=(B // block_b, N // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda b, n, k: (b, k)),
            pl.BlockSpec((block_k, block_n), lambda b, n, k: (k, n)),
            pl.BlockSpec((1, block_n), lambda b, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda b, n, k: (b, n)),
        out_shape=jax.ShapeDtypeStruct((B, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_b, block_n), jnp.int32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(literals, include, nonempty)
