"""Pallas TPU kernel: fused CoTM inference (clause tile + class tile).

Beyond-paper optimization.  The paper wires two physical crossbars
back-to-back through CSA latches; the digital-twin equivalent of that wiring
is keeping the Boolean clauses in VMEM and never writing them to HBM:

    per clause-chunk n:
        viol   = (1 - L) @ inc[:, n]        # int8 MXU matmul, (bm, bn)
        fired  = (viol == 0) & nonempty[n]  # CSA epilogue, stays in VMEM
        scores += fired @ W[n, :]           # class tile partial sum

The class scores are linear in the clause bits, so chunking the clause axis
and accumulating the (bm, M) score block is exact.  One HBM round-trip for
the whole inference instead of two (the clause matrix (B, N) is never
materialized) — for the paper's 2048x500x10 MNIST shape this removes the
largest intermediate entirely.

Constraint: the literal axis K is kept whole per block (lit block (bm, K)),
which bounds K at a few thousand for VMEM residency — exactly the regime of
one physical crossbar tile.  Larger K goes through the sharded path
(``clause_eval(mode="viol")`` + psum) mirroring the paper's Fig. 14.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import _compat

Array = jax.Array

BLOCK_B = 128
BLOCK_N = 256


def _fused_kernel(lit_ref, inc_ref, ne_ref, w_ref, out_ref, acc_ref, *,
                  n_n: int):
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    not_l = (1 - lit_ref[...]).astype(jnp.int8)
    viol = jax.lax.dot_general(
        not_l, inc_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    fired = (viol == 0) & (ne_ref[...] != 0)
    acc_ref[...] += jax.lax.dot_general(
        fired.astype(jnp.int8), w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(n == n_n - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def fused_cotm(literals: Array, include: Array, nonempty: Array,
               weights: Array, *, block_b: int = BLOCK_B,
               block_n: int = BLOCK_N, interpret: bool = False) -> Array:
    """literals (B, K) int8, include (K, N) int8, nonempty (1, N) int8,
    weights (N, M) int32 -> scores (B, M) int32.

    B % block_b == 0, N % block_n == 0, K % 128 == 0, M % 128 == 0 required
    (``ops.fused_cotm`` pads arbitrary shapes).
    """
    B, K = literals.shape
    K2, N = include.shape
    N2, M = weights.shape
    assert K == K2 and N == N2 and nonempty.shape == (1, N)
    assert (B % block_b == 0 and N % block_n == 0 and K % 128 == 0
            and M % 128 == 0), (B, K, N, M)
    n_n = N // block_n

    return pl.pallas_call(
        functools.partial(_fused_kernel, n_n=n_n),
        grid=(B // block_b, n_n),
        in_specs=[
            pl.BlockSpec((block_b, K), lambda b, n: (b, 0)),
            pl.BlockSpec((K, block_n), lambda b, n: (0, n)),
            pl.BlockSpec((1, block_n), lambda b, n: (0, n)),
            pl.BlockSpec((block_n, M), lambda b, n: (n, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, M), lambda b, n: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_b, M), jnp.int32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(literals, include, nonempty, weights)
