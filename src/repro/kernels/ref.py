"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the shape/dtype sweep tests: each kernel in
``ops.py`` must ``assert_allclose`` against the function of the same name
here (exact equality for the integer/Boolean kernels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def clause_eval_ref(literals: Array, include: Array,
                    nonempty: Array | None = None) -> Array:
    """Boolean clause outputs.

    literals (B, K) {0,1}; include (K, N) {0,1} -> fired (B, N) bool with
    ``fired = (sum_k (1-L)*inc == 0) & nonempty``.
    """
    viol = clause_viol_ref(literals, include)
    fired = viol == 0
    if nonempty is not None:
        fired = jnp.logical_and(fired, nonempty.astype(bool))
    return fired


def clause_viol_ref(literals: Array, include: Array) -> Array:
    """Violation counts (the clause-crossbar column current), (B, N) int32."""
    not_l = (1 - literals.astype(jnp.int32))
    return not_l @ include.astype(jnp.int32)


def class_sum_ref(clauses: Array, weights: Array) -> Array:
    """clauses (B, N) {0,1}; weights (N, M) int -> scores (B, M) int32."""
    return clauses.astype(jnp.int32) @ weights.astype(jnp.int32)


def fused_cotm_ref(literals: Array, include: Array, weights: Array,
                   nonempty: Array | None = None) -> Array:
    """literals -> class scores without materializing clauses in HBM."""
    fired = clause_eval_ref(literals, include, nonempty)
    return class_sum_ref(fired, weights)


def pad_to(x: Array, size: int, axis: int, value=0) -> Array:
    """Pad ``axis`` up to an absolute ``size`` (no-op when already there).
    Shared by the oracles and ``impact.pipeline``."""
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def impact_clause_bits_ref(literals: Array, clause_i: Array,
                           nonempty: Array, *, thresh: float,
                           ) -> tuple[Array, Array]:
    """Analog clause stage on per-cell read currents (Fig. 14 row shards).

    literals (B, K) {0,1}; clause_i (R, C, tr, tc) f32 cell currents;
    nonempty (C*tc,) -> (fired (B, C*tc) bool, column currents (B,R,C,tc)).
    Only literal==0 rows are driven; a column's CSA reads "no violation"
    iff its current stays below ``thresh``; shard partials AND digitally.
    """
    B = literals.shape[0]
    R, C, tr, tc = clause_i.shape
    lit = pad_to(literals.astype(jnp.float32), R * tr, 1, 1)
    drive = (1.0 - lit).reshape(B, R, tr)
    i_col = jnp.einsum("brk,rckj->brcj", drive, clause_i)
    partial = i_col < thresh
    fired = jnp.all(partial, axis=1).reshape(B, C * tc)
    fired = jnp.logical_and(fired, nonempty.astype(bool))
    return fired, i_col


def impact_class_scores_ref(clauses: Array, class_i: Array,
                            ) -> tuple[Array, Array]:
    """Analog class stage: clauses (B, n) {0,1}; class_i (S, sr, M) f32
    cell currents -> (scores (B, M) f32 summed shard currents, per-shard
    column currents (B, S, M)).  Columns beyond S*sr (clause-tile padding)
    are dead by construction and dropped.
    """
    B = clauses.shape[0]
    S, sr, M = class_i.shape
    drive = pad_to(clauses.astype(jnp.float32), S * sr, 1, 0)
    drive = drive[:, :S * sr].reshape(B, S, sr)
    i_col = jnp.einsum("bsn,snm->bsm", drive, class_i)
    return i_col.sum(axis=1), i_col


def fused_impact_ref(literals: Array, clause_i: Array, nonempty: Array,
                     class_i: Array, *, thresh: float) -> Array:
    """Analog literals -> class currents, shard-structured oracle for the
    fused IMPACT kernel (clause bits never leave "VMEM" here either —
    they are just an intermediate)."""
    fired, _ = impact_clause_bits_ref(literals, clause_i, nonempty,
                                      thresh=thresh)
    scores, _ = impact_class_scores_ref(fired, class_i)
    return scores


def fused_impact_metered_ref(literals: Array, clause_i: Array,
                             nonempty: Array, class_i: Array, *,
                             thresh: float) -> tuple[Array, Array, Array]:
    """Oracle for the metered fused kernel: ``(scores (B, M), per-lane
    summed clause-crossbar column currents (B,), per-lane summed
    class-crossbar column currents (B,))``.

    The meters are the E = V_R * I * t_read quantities of the paper's
    Table 4 accounting, summed over every physical column of each
    crossbar (clause-tile leakage columns beyond ``n_clauses`` included —
    they are real cells drawing real current); ``impact.energy.
    per_lane_read_energy`` converts them to joules."""
    fired, i_col = impact_clause_bits_ref(literals, clause_i, nonempty,
                                          thresh=thresh)
    scores, i_cls = impact_class_scores_ref(fired, class_i)
    return scores, i_col.sum(axis=(1, 2, 3)), i_cls.sum(axis=(1, 2))


def fused_impact_packed_ref(literals: Array, bits: Array, levels: Array,
                            nonempty: Array, class_i: Array, *,
                            thresh: float, tr: int) -> Array:
    """Einsum oracle for the bitplane-packed datapath.

    ``bits`` (R, C, tr4, tc) uint8 2-bit codes (see ``kernels.packing``),
    ``levels`` (2,) f32 dequant currents.  Unpacks to per-cell currents
    and runs the exact shard-structured oracle — ground truth for the
    packed Pallas kernel, which must never diverge from "dequantize,
    then do what the int8 path does".
    """
    from . import packing
    clause_i = packing.dequant_clause(bits, levels, tr)
    return fused_impact_ref(literals, clause_i, nonempty, class_i,
                            thresh=thresh)


def fused_impact_packed_metered_ref(literals: Array, bits: Array,
                                    levels: Array, nonempty: Array,
                                    class_i: Array, *, thresh: float,
                                    tr: int) -> tuple[Array, Array, Array]:
    """Metered oracle on the quantized currents (the packed datapath's
    own energy truth: meters bill the currents the packed cells draw)."""
    from . import packing
    clause_i = packing.dequant_clause(bits, levels, tr)
    return fused_impact_metered_ref(literals, clause_i, nonempty, class_i,
                                    thresh=thresh)


def coresident_lane_mask(model_ids: Array, clause_spans: Array,
                         n: Array | int) -> Array:
    """Per-lane ownership mask over the combined clause columns.

    model_ids (B,) int32; clause_spans (T, 2) int32 rows of ``[lo, hi)``
    clause-column spans per resident tenant -> (B, n) bool, True exactly
    on lane b's own tenant's columns.

    Physically this is the CSA gating step of co-residency: a lane only
    drives its own tenant's literal rows (foreign literal slices float at
    1), so every *foreign* clause column sees exactly 0 A — but 0 A is
    below the CSA threshold, so a foreign nonempty column would read as
    "fired" and spuriously drive foreign class rows.  Masking fired bits
    to the lane's own span keeps the class stage — and hence the class
    meter — tenant-pure, making cross-tenant leakage exactly zero by
    construction rather than merely small.
    """
    lo = clause_spans[model_ids, 0][:, None]
    hi = clause_spans[model_ids, 1][:, None]
    col = jnp.arange(n, dtype=jnp.int32)[None, :]
    return jnp.logical_and(col >= lo, col < hi)


def fused_impact_coresident_ref(literals: Array, clause_i: Array,
                                nonempty: Array, class_i: Array,
                                model_ids: Array, clause_spans: Array, *,
                                thresh: float) -> Array:
    """Einsum oracle for the co-resident fused sweep.

    Identical to ``fused_impact_ref`` on a block-diagonal combined grid,
    plus the per-lane clause-column mask between the clause and class
    stages.  Scores land only in each lane's own tenant's class columns;
    every cross-tenant score entry is exactly 0.
    """
    fired, _ = impact_clause_bits_ref(literals, clause_i, nonempty,
                                      thresh=thresh)
    fired = jnp.logical_and(
        fired, coresident_lane_mask(model_ids, clause_spans,
                                    fired.shape[1]))
    scores, _ = impact_class_scores_ref(fired, class_i)
    return scores


def fused_impact_coresident_metered_ref(
        literals: Array, clause_i: Array, nonempty: Array, class_i: Array,
        model_ids: Array, clause_spans: Array, *, thresh: float,
        ) -> tuple[Array, Array, Array]:
    """Metered co-resident oracle: ``(scores, e_clause (B,), e_class (B,))``
    summed column currents per lane, same units as
    ``fused_impact_metered_ref``.

    Both meters are tenant-pure: the clause meter because foreign columns
    draw exactly 0 A (their literal rows float), the class meter because
    the lane mask zeroes foreign fired bits before they can drive class
    rows.  Off-block cells of the combined grid hold 0 A and never bill.
    """
    fired, i_col = impact_clause_bits_ref(literals, clause_i, nonempty,
                                          thresh=thresh)
    fired = jnp.logical_and(
        fired, coresident_lane_mask(model_ids, clause_spans,
                                    fired.shape[1]))
    scores, i_cls = impact_class_scores_ref(fired, class_i)
    return scores, i_col.sum(axis=(1, 2, 3)), i_cls.sum(axis=(1, 2))


def ta_feedback_ref(lit2: Array, fired2: Array, sel: Array, match: Array,
                    hi: Array, lo: Array, include: Array) -> Array:
    """CoTM Type I/II TA feedback deltas (arXiv:2408.09456 Algs. 1-2).

    Inputs are the per-(row, clause) feedback masks of one update batch,
    2B rows (true class + sampled negative per example, already doubled):

      lit2 (2B, K) int8     literal states (doubled along the batch axis);
      fired2 (2B, n) bool   clause outputs per row;
      sel (2B, n) bool      clause selected for feedback (prob (T -/+ v)/2T);
      match (2B, n) bool    weight sign agrees with the row polarity
                            (Type I when True, Type II when False);
      hi (K, n) int32       per-TA boost draw (1/s Bernoulli complement);
      lo (K, n) int32       per-TA 1/s penalty draw;
      include (K, n) bool   current TA include actions.

    Returns ta_delta (K, n) int32:

      +hi   for every selected matching FIRED clause whose literal is 1
            (Type Ia reward),
      -lo   for selected matching fired clauses with literal 0 AND for all
            literals of selected matching non-fired clauses (Type Ib
            erasure/decay),
      +1    on currently-excluded literals that are 0 in a selected
            NON-matching fired clause (Type II inclusion pressure).

    All terms are integer counts accumulated over the 2B rows; both this
    oracle and the Pallas kernel compute them with f32 matmuls, exact for
    counts far below 2**24.
    """
    t1 = jnp.logical_and(sel, match)
    t1f = jnp.logical_and(t1, fired2).astype(jnp.float32)        # (2B, n)
    t1nf = jnp.logical_and(t1, ~fired2).astype(jnp.float32)
    t2f = jnp.logical_and(jnp.logical_and(sel, ~match),
                          fired2).astype(jnp.float32)
    litT = lit2.astype(jnp.float32).T                            # (K, 2B)
    present = litT @ t1f                                         # (K, n)
    absent = (1.0 - litT) @ t1f
    inval = (1.0 - litT) @ t2f
    decay = t1nf.sum(axis=0, keepdims=True)                      # (1, n)
    excl = (~include.astype(bool)).astype(jnp.float32)
    delta = (hi.astype(jnp.float32) * present
             - lo.astype(jnp.float32) * (absent + decay)
             + excl * inval)
    return delta.astype(jnp.int32)


def crossbar_mvm_ref(drive: Array, g: Array, *, v_read: float = 2.0,
                     nonlin: float = 1.5, cutoff: float = 10e-9) -> Array:
    """Analog crossbar column currents with the Y-Flash low-G nonlinearity.

    drive (B, K) f32 (row voltages in units of V_R); g (K, N) f32
    conductances -> currents (B, N) f32:  I = drive @ (g * V_R * nl(g)).
    """
    nl = jnp.where(g < cutoff, nonlin, 1.0)
    return drive.astype(jnp.float32) @ (g * v_read * nl).astype(jnp.float32)
