"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the shape/dtype sweep tests: each kernel in
``ops.py`` must ``assert_allclose`` against the function of the same name
here (exact equality for the integer/Boolean kernels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def clause_eval_ref(literals: Array, include: Array,
                    nonempty: Array | None = None) -> Array:
    """Boolean clause outputs.

    literals (B, K) {0,1}; include (K, N) {0,1} -> fired (B, N) bool with
    ``fired = (sum_k (1-L)*inc == 0) & nonempty``.
    """
    viol = clause_viol_ref(literals, include)
    fired = viol == 0
    if nonempty is not None:
        fired = jnp.logical_and(fired, nonempty.astype(bool))
    return fired


def clause_viol_ref(literals: Array, include: Array) -> Array:
    """Violation counts (the clause-crossbar column current), (B, N) int32."""
    not_l = (1 - literals.astype(jnp.int32))
    return not_l @ include.astype(jnp.int32)


def class_sum_ref(clauses: Array, weights: Array) -> Array:
    """clauses (B, N) {0,1}; weights (N, M) int -> scores (B, M) int32."""
    return clauses.astype(jnp.int32) @ weights.astype(jnp.int32)


def fused_cotm_ref(literals: Array, include: Array, weights: Array,
                   nonempty: Array | None = None) -> Array:
    """literals -> class scores without materializing clauses in HBM."""
    fired = clause_eval_ref(literals, include, nonempty)
    return class_sum_ref(fired, weights)


def crossbar_mvm_ref(drive: Array, g: Array, *, v_read: float = 2.0,
                     nonlin: float = 1.5, cutoff: float = 10e-9) -> Array:
    """Analog crossbar column currents with the Y-Flash low-G nonlinearity.

    drive (B, K) f32 (row voltages in units of V_R); g (K, N) f32
    conductances -> currents (B, N) f32:  I = drive @ (g * V_R * nl(g)).
    """
    nl = jnp.where(g < cutoff, nonlin, 1.0)
    return drive.astype(jnp.float32) @ (g * v_read * nl).astype(jnp.float32)
