"""Bitplane packing for the ternary clause crossbar.

IMPACT's TA-action matrices are ternary at the device abstraction: a
crossbar cell is either a high-conductance include (HCS), a
low-conductance exclude (LCS), or absent/pruned (no current).  The int8
datapath nevertheless streams a float32 read current per cell every
sweep.  This module packs the clause crossbar into 2-bit codes — four
cells per byte along the literal-row (contraction) axis — plus two
scalar dequant levels, shrinking the dominant operand ~16x (f32 -> 2
bits) and the total sweep input bytes well past the 4x gate.

Layout contract (shared by the Pallas kernels, the einsum oracle, and
the shard_map lowering): bit-field ``j`` (shift ``2*j``) of packed row
``q`` holds the code of original row ``4*q + j``.  Codes:

* ``CODE_DEAD = 0`` — no device / pruned / padding; contributes 0 A.
* ``CODE_LCS  = 1`` — exclude cell; dequants to the mean LCS current.
* ``CODE_HCS  = 2`` — include cell; dequants to the mean HCS current.
* ``3`` is reserved.

Classification splits the bimodal device populations at the geometric
midpoint of the smallest and largest positive cell currents (decades
from either population — LCS leakage sits at nA, HCS reads at uA), so
this module needs no ``impact.yflash`` constants; callers may pass an
explicit ``split`` instead.  The CSA threshold is deliberately NOT the
default split: it is a *column*-level decision current, and a far-tail
HCS cell just below it would mis-bin as LCS and flip CSA bits.  Packing
is lossless on ideal (variability-free) systems, where every HCS/LCS
cell carries the identical current; on device-variability systems the
CSA decision bits are preserved (column currents sit decades away from
the threshold), so argmax parity survives quantization even though
per-cell currents collapse to their class means.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

CODE_DEAD = 0
CODE_LCS = 1
CODE_HCS = 2
CELLS_PER_BYTE = 4
_CODE_BITS = 2
_CODE_MASK = (1 << _CODE_BITS) - 1


class PackedClause(NamedTuple):
    """A packed clause crossbar: codes + dequantization levels.

    ``bits`` has shape ``(R, C, ceil(tr/4), tc)`` uint8 — the clause
    tile grid with the literal-row axis packed 4:1.  ``levels`` is a
    ``(2,)`` float32 array ``[i_lcs, i_hcs]`` of class-mean read
    currents.  NamedTuple => a pytree, so it flows through jit/shard_map
    as two ordinary operands.
    """

    bits: jnp.ndarray
    levels: jnp.ndarray


def packed_rows(n_rows: int) -> int:
    """Number of packed (byte) rows covering ``n_rows`` cell rows."""
    return -(-n_rows // CELLS_PER_BYTE)


def pack_ternary(codes):
    """Pack a ``(K, N)`` matrix of 2-bit codes into ``(ceil(K/4), N)`` uint8.

    Rows beyond K pad with ``CODE_DEAD``.
    """
    codes = jnp.asarray(codes)
    k, _ = codes.shape
    k4 = packed_rows(k)
    pad = k4 * CELLS_PER_BYTE - k
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)),
                        constant_values=CODE_DEAD)
    planes = codes.astype(jnp.uint8).reshape(k4, CELLS_PER_BYTE, -1)
    packed = jnp.zeros(planes.shape[::2], jnp.uint8)
    for j in range(CELLS_PER_BYTE):
        packed = packed | (planes[:, j] << (_CODE_BITS * j))
    return packed


def unpack_ternary(packed, n_rows: int):
    """Inverse of :func:`pack_ternary`: ``(K4, N)`` uint8 -> ``(n_rows, N)``."""
    packed = jnp.asarray(packed)
    planes = [(packed >> (_CODE_BITS * j)) & _CODE_MASK
              for j in range(CELLS_PER_BYTE)]
    full = jnp.stack(planes, axis=1).reshape(-1, packed.shape[1])
    return full[:n_rows].astype(jnp.uint8)


def population_split(currents):
    """Geometric midpoint of the smallest and largest positive currents.

    The Y-Flash cell populations are bimodal with a ~3-decade gap (LCS
    leakage ~nA, HCS reads ~uA); the log-midpoint lands in that gap for
    any physical device-variability spread, with no dependence on
    ``impact.yflash`` constants.  Degenerate single-population operands
    classify everything as HCS (split == the common value).
    """
    currents = jnp.asarray(currents, jnp.float32)
    hi = jnp.maximum(currents.max(), 0.0)
    lo = jnp.min(jnp.where(currents > 0.0, currents, hi))
    return jnp.sqrt(jnp.maximum(hi, 1e-30) * jnp.maximum(lo, 1e-30))


def classify_currents(currents, *, split=None):
    """Ternary codes for per-cell read currents.

    ``<= 0`` A is a dead/pruned cell, ``>= split`` is HCS, anything
    between is LCS leakage.  ``split=None`` (default) uses
    :func:`population_split`.
    """
    currents = jnp.asarray(currents)
    if split is None:
        split = population_split(currents)
    return jnp.where(
        currents <= 0.0, jnp.uint8(CODE_DEAD),
        jnp.where(currents >= split, jnp.uint8(CODE_HCS),
                  jnp.uint8(CODE_LCS)))


def quant_levels(currents, codes):
    """``[i_lcs, i_hcs]`` float32 — class-mean currents (0.0 for empty classes)."""
    currents = jnp.asarray(currents, jnp.float32)

    def mean_of(code):
        mask = (codes == code).astype(jnp.float32)
        n = jnp.maximum(mask.sum(), 1.0)
        return (currents * mask).sum() / n

    return jnp.stack([mean_of(CODE_LCS), mean_of(CODE_HCS)])


def dequant_codes(codes, levels):
    """Codes -> float32 currents via the two scalar levels."""
    codes = jnp.asarray(codes)
    return jnp.where(
        codes == CODE_HCS, levels[1],
        jnp.where(codes == CODE_LCS, levels[0], 0.0)).astype(jnp.float32)


def pack_clause_operand(clause_i, *, split=None) -> PackedClause:
    """Pack a ``(R, C, tr, tc)`` clause-current operand.

    Returns :class:`PackedClause` with ``bits`` of shape
    ``(R, C, ceil(tr/4), tc)`` — the row axis packed 4:1 — and the two
    dequant levels.  Traceable: a ``PackedPallasBackend`` can pack
    inside jit, and an ``InferenceSession`` packs concretely at compile
    time.
    """
    clause_i = jnp.asarray(clause_i, jnp.float32)
    r, c, tr, tc = clause_i.shape
    codes = classify_currents(clause_i, split=split)
    levels = quant_levels(clause_i, codes)
    tr4 = packed_rows(tr)
    pad = tr4 * CELLS_PER_BYTE - tr
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, 0), (0, pad), (0, 0)),
                        constant_values=CODE_DEAD)
    planes = codes.reshape(r, c, tr4, CELLS_PER_BYTE, tc)
    bits = jnp.zeros((r, c, tr4, tc), jnp.uint8)
    for j in range(CELLS_PER_BYTE):
        bits = bits | (planes[:, :, :, j] << (_CODE_BITS * j))
    return PackedClause(bits=bits, levels=levels)


def dequant_clause(bits, levels, tr: int):
    """Unpack ``(R, C, tr4, tc)`` bits back to ``(R, C, tr, tc)`` currents."""
    bits = jnp.asarray(bits)
    r, c, tr4, tc = bits.shape
    planes = [(bits >> (_CODE_BITS * j)) & _CODE_MASK
              for j in range(CELLS_PER_BYTE)]
    codes = jnp.stack(planes, axis=3).reshape(r, c, tr4 * CELLS_PER_BYTE, tc)
    return dequant_codes(codes[:, :, :tr], levels)


def packed_nbytes(packed: PackedClause) -> int:
    """Total bytes of the packed operand (codes + levels)."""
    return int(packed.bits.size * packed.bits.dtype.itemsize
               + packed.levels.size * packed.levels.dtype.itemsize)
