"""Version shims for the Pallas TPU API surface.

``pltpu.TPUCompilerParams`` was renamed ``CompilerParams`` upstream; pick
whichever this jax build provides so the kernels run on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
