"""Continuous-batching IMPACT inference front: crossbar serving under
request traffic.

The LM zoo's ``Engine`` serves autoregressive token streams; this engine
serves the other workload the paper targets — high-throughput CoTM
classification on the Y-Flash crossbar twin.

Scheduler design (the PR-2 rebuild):

* **Slot table, not flush-and-drain.**  A fixed-capacity ``SlotTable``
  (capacity = ``max_batch``) backs a persistent (capacity, K) literal
  buffer.  Free lanes hold all-1 literals (every crossbar row floats, so
  they draw no current); the validity mask is derived from occupancy.
  Each scheduler step admits queued requests into free lanes, runs ONE
  jitted crossbar sweep (``IMPACTSystem.infer_step`` — fixed shape, so
  admission patterns never retrace), then releases every lane that
  finished.  Classification completes in one sweep, so the table drains
  and refills between steps — a late arrival waits at most one sweep,
  never a whole flushed bucket (the head-of-line blocking the old
  flush-to-completion mode exhibits under mixed traffic).

* **Admission policy.**  ``target_occupancy`` (fraction of capacity) and
  ``max_wait_s`` trade latency for fuller sweeps: a step fires when
  occupancy reaches the target, when the oldest admitted request has
  waited ``max_wait_s``, or when the table is full.  The default
  ``target_occupancy=0.0`` fires on any occupancy (lowest latency).

* **Backpressure.**  ``queue_capacity`` bounds the admission queue;
  ``submit`` raises ``Backpressure`` when slots and queue are both full
  (``try_submit`` returns ``None`` instead) so load sheds at the edge
  rather than growing an unbounded backlog.

* **Per-request metering.**  Every request gets a ``RequestRecord`` with
  end-to-end latency (arrival -> completion, through the queue) and its
  own read-energy bill from the per-lane meters in ``infer_step``; step-
  level ``BatchStats`` carry occupancy and p50/p95/p99 of the requests
  they completed, and ``stats()``/``replay_trace`` aggregate tail
  percentiles across a run.

* **Flush mode kept for A/B.**  ``mode="flush"`` preserves the PR-1
  accumulate/pad-to-bucket scheduler (shape-bucketed jit) so benchmarks
  can measure continuous vs. flush-to-completion tail latency on the same
  arrival trace (``benchmarks/impact_throughput.py`` writes the
  comparison to ``BENCH_serve.json``).

Runtime configuration (PR-4): the engine takes a compiled
``InferenceSession`` — backend, mesh topology, metering mode, and the
slot-table shape are all resolved ONCE by ``IMPACTSystem.compile(spec)``
before the first request arrives, and the scheduler knows nothing about
impl/mesh/metering.  Passing a bare ``IMPACTSystem`` compiles the default
spec at ``max_batch`` as a convenience; the legacy ``impl=`` / ``mesh=``
/ ``meter_energy=`` kwargs keep working through a ``SpecDeprecationWarning``
shim that folds them into the spec.

Energy metering note: ``metering="fused"`` bills every request from the
meters the fused kernel accumulates in VMEM while it infers — per-lane
summed column currents ride the single fused pass, so metered serving
runs at (near-)unmetered fused throughput (``benchmarks/
impact_throughput.py`` prices the overhead as the ``metered_fused``
sample).  ``metering="staged"`` keeps the per-shard oracle path the
fused meters are pinned against; ``metering="off"`` serves the fused
kernel and bills nothing.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
import warnings
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..impact.energy import EnergyReport
from ..impact.pipeline import IMPACTSystem
from ..impact.runtime import (InferenceSession, SpecDeprecationWarning,
                              legacy_spec)
from .engine import (Backpressure, BatchingQueue, Request, SlotTable,
                     latency_percentiles)
from .tracing import Tracer

Array = jax.Array

DEFAULT_BUCKETS = (8, 32, 128, 512)


def aggregate_reports(reports: Sequence[EnergyReport]) -> EnergyReport:
    """Sum energy/op/datapoint accounting over per-batch reports; latency
    is the serial crossbar time of the whole run (batches stream through
    the same physical tiles).

    ``area_mm2`` is deliberately NOT carried over: ``tops_per_mm2``
    divides per-datapoint ops by ``latency_s``, so on a summed-latency
    aggregate it would shrink with the number of sweeps instead of
    describing the hardware — read it off the per-step reports (which
    carry the area), not the aggregate; the aggregate raises."""
    if not reports:
        raise ValueError("no reports to aggregate")
    return EnergyReport(
        read_energy_j=sum(r.read_energy_j for r in reports),
        clause_energy_j=sum(r.clause_energy_j for r in reports),
        class_energy_j=sum(r.class_energy_j for r in reports),
        program_energy_j=reports[0].program_energy_j,   # one-time encode
        erase_energy_j=reports[0].erase_energy_j,
        latency_s=sum(r.latency_s for r in reports),
        ops_crosspoint=sum(r.ops_crosspoint for r in reports),
        datapoints=sum(r.datapoints for r in reports),
        # Unlike the one-time encode cost above, write energy accrues per
        # window: an interleaved train+serve run's aggregate must carry
        # every update's pulse bill.
        write_energy_j=sum(r.write_energy_j for r in reports),
    )


@dataclasses.dataclass
class RequestRecord:
    """Per-request accounting: queue wait + service latency and the read
    energy this request's datapoint drew on the crossbar.  ``tenant``
    threads the owning tenant through the ledger (multi-tenant zoos);
    the single-tenant engine records everything under ``"default"``."""
    rid: int
    arrived: float
    admitted: float
    completed: float
    pred: int
    e_read_j: float = 0.0
    tenant: str = "default"

    @property
    def latency_s(self) -> float:
        return self.completed - self.arrived

    @property
    def queue_s(self) -> float:
        return self.admitted - self.arrived


@dataclasses.dataclass
class BatchStats:
    bucket: int           # kernel shape: slot capacity (continuous) / bucket
    n_valid: int
    latency_s: float      # wall time of this sweep
    samples_per_s: float
    cold: bool = False    # first sweep of this shape: includes jit compile
    occupancy: float = 0.0
    p50_s: float = 0.0    # end-to-end request-latency percentiles of the
    p95_s: float = 0.0    # requests completed by this step
    p99_s: float = 0.0


@dataclasses.dataclass
class _Lane:
    """Slot-table payload: the request plus its admission timestamp."""
    req: Request
    admitted: float


class IMPACTEngine:
    """Crossbar inference with a continuous-batching scheduler.

    ``submit`` enqueues a literal vector (raising ``Backpressure`` when the
    engine is saturated); ``step`` runs one scheduler iteration — admit
    into free slots, fire at most one crossbar sweep, release finished
    lanes — and returns completed ``(rid, prediction)`` pairs; ``run``
    drives a whole request burst to completion.

    The engine serves through a compiled ``InferenceSession``: backend,
    mesh topology, and metering are properties of the session's
    ``RuntimeSpec``, resolved before the first request — the scheduler
    only admits, sweeps, releases, and bills.  Per-lane energy
    attribution still sums exactly to the batch meter under sharding
    (the per-device partial currents are psummed before billing).

    ``mode="flush"`` selects the legacy flush-to-completion scheduler;
    its ``buckets`` pad each flushed batch up to a compiled shape.
    Kwargs are validated per mode — ``buckets`` in continuous mode and
    ``target_occupancy`` in flush mode are rejected instead of silently
    ignored.

    ``trace`` (a ``serve.tracing.Tracer``) records the scheduler
    timeline as Chrome-tracing spans: per-step ``admission`` / ``sweep``
    / ``release`` / ``billing`` regions on the scheduler track (lane ids
    and occupancy as span args) and the ``queued`` -> ``admitted`` ->
    ``sweep`` -> ``billed`` lifecycle on one track per request, cut from
    the same clock readings the ``RequestRecord`` ledger stores.  The
    tracer is re-clocked onto the engine's clock so an injected virtual
    clock traces deterministically.
    """

    def __init__(self, runtime: "InferenceSession | IMPACTSystem", *,
                 mode: str = "continuous", max_batch: int | None = None,
                 max_wait_s: float = 0.01,
                 buckets: Sequence[int] | None = None,
                 target_occupancy: float = 0.0,
                 queue_capacity: int | None = None,
                 clock: Callable[[], float] = time.time,
                 trace: Tracer | None = None,
                 impl: str | None = None, mesh=None,
                 meter_energy: bool | None = None):
        if mode not in ("continuous", "flush"):
            raise ValueError(f"mode must be 'continuous' or 'flush', "
                             f"got {mode!r}")
        # Per-mode kwarg validation: a knob the chosen scheduler never
        # reads is a configuration bug, not a default to shadow.
        if mode == "continuous" and buckets is not None:
            raise ValueError(
                "buckets only apply to mode='flush' (the continuous "
                "scheduler always sweeps the fixed slot-table shape); "
                f"got buckets={tuple(buckets)!r}")
        if mode == "flush" and target_occupancy != 0.0:
            raise ValueError(
                "target_occupancy only applies to mode='continuous' "
                "(flush fires on full/stale batches); got "
                f"target_occupancy={target_occupancy!r}")
        if not 0.0 <= target_occupancy <= 1.0:
            raise ValueError(f"target_occupancy must be in [0, 1], "
                             f"got {target_occupancy}")

        if isinstance(runtime, IMPACTSystem):
            # Convenience/legacy path: compile a session for this engine.
            legacy = sorted(k for k, v in dict(
                impl=impl, mesh=mesh, meter_energy=meter_energy).items()
                if v is not None)
            if legacy:
                warnings.warn(
                    f"IMPACTEngine({', '.join(legacy)}=...) is deprecated:"
                    f" encode runtime configuration in a RuntimeSpec and "
                    f"pass IMPACTEngine(system.compile(spec)) (see the "
                    f"README migration table)",
                    SpecDeprecationWarning, stacklevel=2)
            meter = meter_energy is None or meter_energy
            session = runtime.compile(legacy_spec(
                impl=impl, mesh=mesh,
                metering="staged" if meter else "off",
                capacity=128 if max_batch is None else max_batch))
        else:
            session = runtime
            if impl is not None or mesh is not None \
                    or meter_energy is not None:
                raise ValueError(
                    "impl/mesh/meter_energy cannot override a compiled "
                    "InferenceSession — encode them in its RuntimeSpec")
            if session.capacity is None:
                raise ValueError(
                    "IMPACTEngine needs a session compiled with "
                    "RuntimeSpec(capacity=...) — the slot-table sweep "
                    "shape is fixed at compile time")
            if max_batch is not None and max_batch != session.capacity:
                raise ValueError(
                    f"max_batch={max_batch} does not match the session's "
                    f"compiled capacity {session.capacity}")
        if session.coresident is not None:
            raise ValueError(
                "IMPACTEngine is the single-tenant front — a co-resident "
                "session routes per-lane model ids and needs the "
                "multi-tenant router (serve.zoo.ModelZoo)")
        self.session = session
        self.system = session.system
        self.impl = session.spec.backend
        self.mesh = session.mesh
        self.meter_energy = session.meters_energy
        self.mode = mode
        self.capacity = session.capacity
        max_batch = self.capacity
        self.max_wait_s = max_wait_s
        self.target_occupancy = target_occupancy
        self.queue_capacity = queue_capacity
        self.clock = clock
        if mode == "flush":
            # Buckets above max_batch are unreachable (a flush never
            # exceeds max_batch and max_batch itself is always a bucket)
            # — drop them so warmup() doesn't compile dead shapes.
            buckets = DEFAULT_BUCKETS if buckets is None else buckets
            self.buckets = sorted(b for b in set(int(b) for b in buckets)
                                  | {max_batch} if b <= max_batch)
        else:
            self.buckets = [max_batch]
        # The engine is the single-tenant special case of the model zoo:
        # one tenant ("default") owning the whole grid, its SLO class
        # carrying the engine's admission knobs.  Queue, slot table,
        # lane buffer, and all ledgers live on the zoo; the engine
        # exposes them as properties so existing callers (and the
        # flush-mode scheduler below) see one state.
        from .zoo import ModelZoo, SLOClass   # deferred: zoo imports us
        slo = SLOClass(name="default", priority=0,
                       target_occupancy=target_occupancy,
                       max_wait_s=max_wait_s,
                       queue_capacity=queue_capacity)
        self._zoo = ModelZoo(session, [("default", slo)], clock=clock,
                             trace=trace)

    # -- zoo-backed state (the engine IS a one-tenant zoo) -------------------
    @property
    def queue(self) -> BatchingQueue:
        return self._zoo.tenants[0].queue

    @property
    def table(self) -> SlotTable:
        return self._zoo.table

    @property
    def _lane_lits(self) -> np.ndarray:
        return self._zoo._lane_lits

    @property
    def batch_stats(self) -> list[BatchStats]:
        return self._zoo.batch_stats

    @property
    def reports(self) -> list[EnergyReport]:
        return self._zoo.reports

    @property
    def request_records(self) -> list[RequestRecord]:
        return self._zoo.request_records

    @property
    def _next_rid(self) -> int:
        return self._zoo._next_rid

    @property
    def _warm(self) -> set[int]:
        return self._zoo._warm

    @property
    def trace(self) -> Tracer | None:
        return self._zoo.trace

    @trace.setter
    def trace(self, tracer: Tracer | None) -> None:
        # One time source: span timestamps must be comparable with the
        # RequestRecord ledger, so the tracer rides the engine's clock
        # (attach_trace re-clocks it).
        self._zoo.attach_trace(tracer)

    def warmup(self) -> None:
        """Ensure every sweep shape this engine can fire is a compiled
        executable (the single slot-table shape in continuous mode —
        already compiled at session build; every bucket in flush mode) so
        no serving step pays compile latency.  AOT-compiles only; unlike
        the pre-session warmup no dummy traffic is executed or metered."""
        shapes = [self.capacity] if self.mode == "continuous" else self.buckets
        for b in shapes:
            self.session.warm(b)
            self._warm.add(b)

    # -- request plumbing ---------------------------------------------------
    def submit(self, literals: np.ndarray) -> int:
        """Enqueue one (K,) literal vector; returns the request id.  Raises
        ``ValueError`` on a mis-shaped request (the persistent slot-table
        buffer is compiled at (capacity, K) — admitting a wrong shape
        would corrupt it; a rejected submit leaves queue and table
        untouched) and ``Backpressure`` when every slot is occupied and
        the admission queue is at ``queue_capacity``."""
        return self._zoo.submit("default", literals)

    def try_submit(self, literals: np.ndarray) -> int | None:
        """``submit`` that signals backpressure as ``None`` instead of
        raising — the polling-loop idiom for load generators."""
        try:
            return self.submit(literals)
        except Backpressure:
            return None

    def bucket_for(self, n: int) -> int:
        """Smallest configured bucket >= n (largest bucket caps max_batch)."""
        i = bisect.bisect_left(self.buckets, n)
        return self.buckets[min(i, len(self.buckets) - 1)]

    @staticmethod
    def pad_to_bucket(batch: list[Request], bucket: int, n_literals: int,
                      ) -> tuple[Array, np.ndarray]:
        """Stack requests into (bucket, K) literals + validity mask.

        Padding lanes are all-1 literals: every crossbar row floats ('Z'),
        so they draw no current in the analog model.
        """
        lits = np.ones((bucket, n_literals), np.int8)
        valid = np.zeros((bucket,), bool)
        for i, r in enumerate(batch):
            lits[i] = r.tokens
            valid[i] = True
        return jnp.asarray(lits), valid

    # -- execution ----------------------------------------------------------
    def _execute(self, lits: Array, valid: np.ndarray, shape: int,
                 lanes: list[tuple[int, _Lane]]) -> list[tuple[int, int]]:
        """Fire one crossbar sweep and do all per-step accounting (on the
        zoo's shared ledger path, under the engine's one tenant)."""
        from .zoo import _ZooLane
        tenant = self._zoo.tenants[0]
        zlanes = [(i, _ZooLane(l.req, l.admitted, tenant))
                  for i, l in lanes]
        return self._zoo.execute_batch(lits, valid, shape, zlanes)

    def _step_flush(self, force: bool) -> list[tuple[int, int]]:
        if not (self.queue.ready() or (force and self.queue.pending)):
            return []
        t_take = self.clock()
        batch = self.queue.take()
        bucket = self.bucket_for(len(batch))
        lits, valid = self.pad_to_bucket(batch, bucket,
                                         self.system.n_literals)
        now = self.clock()
        lanes = [(i, _Lane(r, now)) for i, r in enumerate(batch)]
        if self.trace is not None:
            self.trace.span("admission", t_take, now, args=dict(
                lanes=list(range(len(batch))), bucket=bucket,
                occupancy=len(batch) / bucket))
        return self._execute(lits, valid, bucket, lanes)

    def step(self, *, force: bool = False) -> list[tuple[int, int]]:
        """One scheduler iteration; returns completed (rid, pred) pairs.
        ``force`` fires below the admission-policy thresholds (used to
        drain the tail of a run)."""
        if self.mode == "flush":
            return self._step_flush(force)
        return self._zoo.step(force=force)

    def run(self, literals: np.ndarray) -> tuple[np.ndarray, dict]:
        """Serve a (B, K) request burst to completion; returns predictions
        in submission order + statistics for THIS burst only (``stats()``
        with no arguments reports engine-lifetime aggregates)."""
        b0, r0, q0 = (len(self.batch_stats), len(self.reports),
                      len(self.request_records))
        rows = np.asarray(literals)
        rids: list[int] = []
        done: dict[int, int] = {}
        i = 0
        while len(done) < rows.shape[0]:
            while i < rows.shape[0]:        # submit until backpressure
                rid = self.try_submit(rows[i])
                if rid is None:
                    break
                rids.append(rid)
                i += 1
            done.update(self.step(force=not self.queue.ready()))
        preds = np.asarray([done[r] for r in rids])
        return preds, self.stats(since_batch=b0, since_report=r0,
                                 since_request=q0)

    def stats(self, *, since_batch: int = 0, since_report: int = 0,
              since_request: int = 0) -> dict:
        bs = self.batch_stats[since_batch:]
        total = sum(s.n_valid for s in bs)
        wall = sum(s.latency_s for s in bs)
        # Throughput from WARM batches only — a shape's first sweep pays
        # jit compile and would skew the serving-rate headline; fall back
        # to all batches when everything was cold (e.g. a single burst).
        warm = [s for s in bs if not s.cold] or bs
        w_total = sum(s.n_valid for s in warm)
        w_wall = sum(s.latency_s for s in warm)
        out = dict(
            mode=self.mode,
            batches=len(bs), samples=total, wall_s=wall,
            cold_batches=sum(s.cold for s in bs),
            samples_per_s=w_total / max(w_wall, 1e-9),
            mean_batch_latency_s=w_wall / max(len(warm), 1),
            mean_occupancy=(sum(s.occupancy for s in bs) / len(bs)
                            if bs else 0.0),
            buckets_used=sorted({s.bucket for s in bs}),
        )
        recs = self.request_records[since_request:]
        if recs:
            out["latency"] = latency_percentiles(
                [r.latency_s for r in recs])
            out["queue_wait"] = latency_percentiles(
                [r.queue_s for r in recs])
        reports = self.reports[since_report:]
        if reports:
            agg = aggregate_reports(reports)
            out["energy"] = agg
            out["energy_per_datapoint_j"] = agg.energy_per_datapoint_j
        return out


# -- arrival-trace replay (mixed-traffic benchmarking) ----------------------

def poisson_arrivals(n: int, rate_rps: float, seed: int = 0) -> np.ndarray:
    """Cumulative arrival offsets (seconds) of a seeded Poisson process.

    ``rate_rps`` must be positive (it is the mean arrival rate; zero or
    negative rates have no inter-arrival distribution) and ``n`` must be
    non-negative — both raise ``ValueError`` instead of returning NaN/
    empty-on-negative surprises from numpy."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_rps, size=n))

def replay_trace(engine: IMPACTEngine, literals: np.ndarray,
                 arrivals: np.ndarray, *,
                 trace_path: str | None = None) -> dict:
    """Replay an arrival trace through an engine in wall-clock time:
    request ``i`` is submitted once ``arrivals[i]`` seconds have elapsed,
    the scheduler steps continuously, and per-request end-to-end latency
    comes from the engine's ``RequestRecord`` ledger.  Works for both
    scheduler modes, so continuous vs. flush-to-completion is an equal-
    traffic A/B.  The engine must be on a wall clock (replay paces itself
    with real ``time.sleep``); a frozen injected clock raises instead of
    hanging.  Returns tail-latency percentiles + throughput.

    ``trace_path`` writes the run's Chrome-tracing timeline (loadable in
    ``chrome://tracing`` / Perfetto) on exit: the engine's attached
    ``Tracer`` if it has one, else a fresh tracer attached for this
    replay.  Shed requests appear as ``shed`` instant events on the
    scheduler track."""
    n = len(arrivals)
    if literals.shape[0] < n:
        raise ValueError(
            f"replay_trace needs one literal row per arrival: got "
            f"{literals.shape[0]} rows for {n} arrivals")
    tracer = engine.trace
    if trace_path is not None and tracer is None:
        tracer = Tracer(clock=engine.clock)
        engine.trace = tracer
    q0 = len(engine.request_records)
    shed = 0
    i = 0
    ndone = 0
    t0 = engine.clock()
    while ndone < n - shed:
        now = engine.clock() - t0
        while i < n and arrivals[i] <= now:
            if engine.try_submit(literals[i]) is None:
                shed += 1              # load shed at the backpressure edge
                if tracer is not None:
                    tracer.instant("shed", args=dict(offered_index=i))
            i += 1
        out = engine.step(force=i >= n)
        ndone += len(out)
        if not out:
            # Don't busy-spin while the scheduler defers (staleness /
            # occupancy windows): a sub-ms tick keeps the replay loop's
            # CPU off the latencies being measured.  When fully idle,
            # sleep toward the next arrival instead.
            idle = (not engine.queue.pending
                    and engine.table.occupancy == 0)
            gap = (arrivals[i] - (engine.clock() - t0)
                   if (idle and i < n) else 0.0)
            before = engine.clock()
            time.sleep(min(max(gap, 2e-4), 1e-3))
            if engine.clock() == before:
                raise RuntimeError(
                    "replay_trace requires a wall clock: the engine's "
                    "injected clock did not advance across a sleep — "
                    "construct the engine with clock=time.monotonic (or "
                    "another real clock) to replay traces")
    wall = engine.clock() - t0
    recs = engine.request_records[q0:]
    out = dict(mode=engine.mode, offered=n, shed=shed,
               completed=len(recs), wall_s=wall,
               samples_per_s=len(recs) / max(wall, 1e-9))
    out.update(latency_percentiles([r.latency_s for r in recs]))
    if trace_path is not None:
        tracer.write(trace_path)
        out["trace_path"] = str(trace_path)
    return out
