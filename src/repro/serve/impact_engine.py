"""Batched IMPACT inference front: crossbar serving under request traffic.

The LM zoo's ``Engine`` serves autoregressive token streams; this engine
serves the other workload the paper targets — high-throughput CoTM
classification on the Y-Flash crossbar twin.  Design:

* requests (one literal vector each) accumulate in the LM ``BatchingQueue``
  (same flush-on-full / flush-on-stale policy, so both fronts share the
  batching semantics that the load generators and tests exercise);
* a flushed batch is padded UP to a shape bucket and carries a validity
  mask — ``IMPACTSystem.predict`` jits once per bucket, not once per
  traffic pattern (padding literals with 1 drives no crossbar rows, so a
  padded lane cannot perturb real lanes; the validity mask keeps its
  fired-by-vacuity clause bits out of the energy meters);
* every batch is metered: wall-clock latency, samples/s, and the paper's
  energy accounting via ``infer_with_report``, aggregated over the run.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..impact.energy import EnergyReport
from ..impact.pipeline import IMPACTSystem
from .engine import BatchingQueue, Request

Array = jax.Array

DEFAULT_BUCKETS = (8, 32, 128, 512)


def aggregate_reports(reports: Sequence[EnergyReport]) -> EnergyReport:
    """Sum energy/op/datapoint accounting over per-batch reports; latency
    is the serial crossbar time of the whole run (batches stream through
    the same physical tiles)."""
    assert reports, "no reports to aggregate"
    return EnergyReport(
        read_energy_j=sum(r.read_energy_j for r in reports),
        clause_energy_j=sum(r.clause_energy_j for r in reports),
        class_energy_j=sum(r.class_energy_j for r in reports),
        program_energy_j=reports[0].program_energy_j,   # one-time encode
        erase_energy_j=reports[0].erase_energy_j,
        latency_s=sum(r.latency_s for r in reports),
        ops_crosspoint=sum(r.ops_crosspoint for r in reports),
        datapoints=sum(r.datapoints for r in reports),
    )


@dataclasses.dataclass
class BatchStats:
    bucket: int
    n_valid: int
    latency_s: float
    samples_per_s: float
    cold: bool = False     # first batch of this bucket: includes jit compile


class IMPACTEngine:
    """Batched crossbar inference with shape-bucketed jit.

    ``submit`` enqueues a literal vector; ``step`` flushes at most one
    ready batch and returns completed ``(rid, prediction)`` pairs;
    ``run`` drives a whole request list to completion.  ``impl`` selects
    the Pallas kernels (default) or the einsum oracles for A/B runs.

    Note the metering/kernel interaction: with ``meter_energy=True`` (the
    default) batches go through ``infer_with_report``, whose pallas impl
    is the STAGED per-shard kernel path — metering needs the column
    currents the fused kernel deliberately never materializes.  The fused
    ``fused_impact`` kernel serves when ``meter_energy=False`` (the
    max-throughput configuration).
    """

    def __init__(self, system: IMPACTSystem, *, impl: str = "pallas",
                 max_batch: int = 128, max_wait_s: float = 0.01,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 meter_energy: bool = True):
        self.system = system
        self.impl = impl
        # Buckets above max_batch are unreachable (a flush never exceeds
        # max_batch and max_batch itself is always a bucket) — drop them
        # so warmup() doesn't compile dead shapes.
        self.buckets = sorted(b for b in set(int(b) for b in buckets)
                              | {max_batch} if b <= max_batch)
        self.queue = BatchingQueue(max_batch=max_batch, max_wait_s=max_wait_s)
        self.meter_energy = meter_energy
        self.batch_stats: list[BatchStats] = []
        self.reports: list[EnergyReport] = []
        self._next_rid = 0
        self._warm: set[int] = set()

    def warmup(self) -> None:
        """Pre-compile every shape bucket so no serving batch pays jit
        latency (throughput stats then have no cold batches)."""
        ones = np.ones((1, self.system.n_literals), np.int8)
        n_reports = len(self.reports)
        for b in self.buckets:
            lits, valid = self.pad_to_bucket(
                [Request(-1, ones[0], max_new=0)], b,
                self.system.n_literals)
            jax.block_until_ready(self._infer(lits, valid))
            self._warm.add(b)
        del self.reports[n_reports:]       # warmup lanes are not traffic

    # -- request plumbing ---------------------------------------------------
    def submit(self, literals: np.ndarray) -> int:
        """Enqueue one (K,) literal vector; returns the request id."""
        lits = np.asarray(literals)
        assert lits.shape == (self.system.n_literals,), lits.shape
        rid = self._next_rid
        self._next_rid += 1
        self.queue.add(Request(rid, lits.astype(np.int8), max_new=0))
        return rid

    def bucket_for(self, n: int) -> int:
        """Smallest configured bucket >= n (largest bucket caps max_batch)."""
        i = bisect.bisect_left(self.buckets, n)
        return self.buckets[min(i, len(self.buckets) - 1)]

    @staticmethod
    def pad_to_bucket(batch: list[Request], bucket: int, n_literals: int,
                      ) -> tuple[Array, np.ndarray]:
        """Stack requests into (bucket, K) literals + validity mask.

        Padding lanes are all-1 literals: every crossbar row floats ('Z'),
        so they draw no current in the analog model.
        """
        lits = np.ones((bucket, n_literals), np.int8)
        valid = np.zeros((bucket,), bool)
        for i, r in enumerate(batch):
            lits[i] = r.tokens
            valid[i] = True
        return jnp.asarray(lits), valid

    # -- execution ----------------------------------------------------------
    def _infer(self, lits: Array, valid: np.ndarray) -> Array:
        if self.meter_energy:
            preds, report = self.system.infer_with_report(
                lits, impl=self.impl, valid=valid)
            self.reports.append(report)
            return preds
        return self.system.predict(lits, impl=self.impl)

    def step(self, *, force: bool = False) -> list[tuple[int, int]]:
        """Flush at most one batch; returns completed (rid, pred) pairs."""
        if not (self.queue.ready() or (force and self.queue.pending)):
            return []
        batch = self.queue.take()
        bucket = self.bucket_for(len(batch))
        lits, valid = self.pad_to_bucket(batch, bucket,
                                         self.system.n_literals)
        cold = bucket not in self._warm
        self._warm.add(bucket)
        t0 = time.time()
        preds = np.asarray(jax.block_until_ready(self._infer(lits, valid)))
        dt = time.time() - t0
        self.batch_stats.append(BatchStats(
            bucket=bucket, n_valid=len(batch), latency_s=dt,
            samples_per_s=len(batch) / max(dt, 1e-9), cold=cold))
        return [(r.rid, int(preds[i])) for i, r in enumerate(batch)
                if valid[i]]

    def run(self, literals: np.ndarray) -> tuple[np.ndarray, dict]:
        """Serve a (B, K) request burst to completion; returns predictions
        in submission order + statistics for THIS burst only (``stats()``
        with no arguments reports engine-lifetime aggregates)."""
        b0, r0 = len(self.batch_stats), len(self.reports)
        rids = [self.submit(row) for row in np.asarray(literals)]
        done: dict[int, int] = {}
        while len(done) < len(rids):
            out = self.step(force=not self.queue.ready())
            done.update(out)
        preds = np.asarray([done[r] for r in rids])
        return preds, self.stats(since_batch=b0, since_report=r0)

    def stats(self, *, since_batch: int = 0, since_report: int = 0) -> dict:
        bs = self.batch_stats[since_batch:]
        total = sum(s.n_valid for s in bs)
        wall = sum(s.latency_s for s in bs)
        # Throughput from WARM batches only — a bucket's first batch pays
        # jit compile and would skew the serving-rate headline; fall back
        # to all batches when everything was cold (e.g. a single burst).
        warm = [s for s in bs if not s.cold] or bs
        w_total = sum(s.n_valid for s in warm)
        w_wall = sum(s.latency_s for s in warm)
        out = dict(
            batches=len(bs), samples=total, wall_s=wall,
            cold_batches=sum(s.cold for s in bs),
            samples_per_s=w_total / max(w_wall, 1e-9),
            mean_batch_latency_s=w_wall / max(len(warm), 1),
            buckets_used=sorted({s.bucket for s in bs}),
        )
        reports = self.reports[since_report:]
        if reports:
            agg = aggregate_reports(reports)
            out["energy"] = agg
            out["energy_per_datapoint_j"] = agg.energy_per_datapoint_j
        return out
