"""Serving: prefill/decode engine, request batching + continuous-batching
slot table, IMPACT crossbar inference, Chrome-tracing observability."""
from .engine import (Backpressure, BatchingQueue, Engine, Request,
                     ServeConfig, SlotTable, latency_percentiles)
from .impact_engine import (BatchStats, IMPACTEngine, RequestRecord,
                            aggregate_reports, poisson_arrivals,
                            replay_trace)
from .tracing import REQUEST_PHASES, Tracer, validate_events

__all__ = ["Engine", "ServeConfig", "BatchingQueue", "Request",
           "SlotTable", "Backpressure", "latency_percentiles",
           "IMPACTEngine", "BatchStats", "RequestRecord",
           "aggregate_reports", "poisson_arrivals", "replay_trace",
           "Tracer", "validate_events", "REQUEST_PHASES"]
