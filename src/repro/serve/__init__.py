"""Serving: prefill/decode engine + request batching."""
from .engine import BatchingQueue, Engine, Request, ServeConfig

__all__ = ["Engine", "ServeConfig", "BatchingQueue", "Request"]
