"""Serving: prefill/decode engine, request batching + continuous-batching
slot table, IMPACT crossbar inference, the multi-tenant model zoo, and
Chrome-tracing observability."""
from .engine import (Backpressure, BatchingQueue, Engine, Request,
                     ServeConfig, SlotTable, latency_percentiles)
from .impact_engine import (BatchStats, IMPACTEngine, RequestRecord,
                            aggregate_reports, poisson_arrivals,
                            replay_trace)
from .tracing import (PID_TENANT_BASE, REQUEST_PHASES, Tracer,
                      validate_events)
from .zoo import ModelZoo, SLOClass, TenantState, replay_zoo_trace

__all__ = ["Engine", "ServeConfig", "BatchingQueue", "Request",
           "SlotTable", "Backpressure", "latency_percentiles",
           "IMPACTEngine", "BatchStats", "RequestRecord",
           "aggregate_reports", "poisson_arrivals", "replay_trace",
           "ModelZoo", "SLOClass", "TenantState", "replay_zoo_trace",
           "Tracer", "validate_events", "REQUEST_PHASES",
           "PID_TENANT_BASE"]
