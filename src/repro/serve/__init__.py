"""Serving: prefill/decode engine, request batching, IMPACT inference."""
from .engine import BatchingQueue, Engine, Request, ServeConfig
from .impact_engine import BatchStats, IMPACTEngine, aggregate_reports

__all__ = ["Engine", "ServeConfig", "BatchingQueue", "Request",
           "IMPACTEngine", "BatchStats", "aggregate_reports"]
