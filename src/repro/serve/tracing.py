"""Chrome-tracing span emitter for the serving engines.

The CI perf gates see *aggregates* (samples/s, p95); diagnosing a tail
regression needs the *timeline* those aggregates summarize.  This module
is a zero-dependency (stdlib ``json`` only) emitter of the Chrome Trace
Event Format — the JSON *array* flavour that ``chrome://tracing`` and
Perfetto load directly — so one serving run can be opened as a flame
graph: a ``scheduler`` track with per-step ``admission`` / ``sweep`` /
``release`` / ``billing`` spans, and one track per request with its
``queued`` -> ``admitted`` -> ``sweep`` -> ``billed`` lifecycle, cut
from the same ``RequestRecord`` / ``BatchStats`` timestamps the latency
ledger reports (so span durations reconcile with the ledger by
construction).

Design notes:

* **Timestamps are engine-clock seconds.**  Every span carries the raw
  reading of the engine's injectable ``clock`` — a virtual test clock
  traces exactly like a wall clock.  ``to_json`` rebases on the first
  event and converts to the microseconds the trace viewers expect.
* **B/E duration events.**  Spans are emitted as balanced
  begin/end pairs per track (``ph: "B"``/``"E"``), which Perfetto nests
  by timestamp; ``instant`` marks zero-width occurrences (e.g. a shed
  request) and ``counter`` emits occupancy-style counter tracks.
* **Per-request spans are emitted at completion** from the record's
  timestamps, never half-open across scheduler steps — a written trace
  always balances, even if the engine still holds queued work.
* **Threading model.**  ``pid`` 0 is the engine (scheduler tid 0);
  ``pid`` 1 holds one tid per request (tid == rid).  Metadata events
  name both so the viewer shows "scheduler" / "req N" tracks.  Multi-
  tenant producers (``serve.zoo``) claim one pid per tenant from
  ``PID_TENANT_BASE`` up via ``name_process`` — one Perfetto track
  group per tenant, request tids nested under it.

The emitter is engine-agnostic on purpose: ``serve.impact_engine``
threads it through the crossbar scheduler and ``serve.engine`` through
the LM continuous-batching front, and every later timeline producer
(TPU lane, multi-tenant zoo, online training) appends to the same span
vocabulary.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Any, Callable, Iterator

PID_ENGINE = 0
PID_REQUESTS = 1
#: First pid available to per-tenant request tracks (``serve.zoo``): the
#: zoo names pid ``PID_TENANT_BASE + model_id`` after each tenant.
PID_TENANT_BASE = 2

#: Span names of the per-request lifecycle, in timeline order.
REQUEST_PHASES = ("queued", "admitted", "sweep", "billed")


@dataclasses.dataclass
class Tracer:
    """Collects trace events in memory; ``write`` renders one loadable
    ``.trace.json``.  All ``ts`` arguments are seconds on the owning
    engine's clock (``clock`` is only the default source when a caller
    omits ``ts``)."""

    clock: Callable[[], float] = time.time
    cat: str = "serve"

    def __post_init__(self):
        self.events: list[dict[str, Any]] = []
        self._named: set[tuple[int, int | None]] = set()
        self._pid_names: dict[int, str] = {}

    def __len__(self) -> int:
        return len(self.events)

    # -- naming ------------------------------------------------------------
    def name_process(self, pid: int, name: str) -> None:
        """Claim a custom name for a process track (e.g. one per tenant:
        ``name_process(PID_TENANT_BASE + t, f"tenant {tid}")``).  Must be
        called before the first event on that pid; later calls on an
        already-emitted pid are ignored (metadata is emitted once)."""
        self._pid_names[pid] = name

    def _ensure_named(self, pid: int, tid: int) -> None:
        """Emit process/thread metadata once per track so the viewer
        labels the engine and request rows."""
        if (pid, None) not in self._named:
            self._named.add((pid, None))
            name = self._pid_names.get(
                pid, "engine" if pid == PID_ENGINE else "requests")
            self.events.append(dict(name="process_name", ph="M", pid=pid,
                                    tid=0, args=dict(name=name)))
        if (pid, tid) not in self._named:
            self._named.add((pid, tid))
            name = ("scheduler" if pid == PID_ENGINE and tid == 0
                    else f"req {tid}" if pid >= PID_REQUESTS
                    else f"tid {tid}")
            self.events.append(dict(name="thread_name", ph="M", pid=pid,
                                    tid=tid, args=dict(name=name)))

    # -- span primitives ----------------------------------------------------
    def begin(self, name: str, *, ts: float | None = None, tid: int = 0,
              pid: int = PID_ENGINE, args: dict | None = None) -> None:
        self._ensure_named(pid, tid)
        ev = dict(name=name, ph="B", ts=self.clock() if ts is None else ts,
                  pid=pid, tid=tid, cat=self.cat)
        if args:
            ev["args"] = args
        self.events.append(ev)

    def end(self, name: str, *, ts: float | None = None, tid: int = 0,
            pid: int = PID_ENGINE, args: dict | None = None) -> None:
        ev = dict(name=name, ph="E", ts=self.clock() if ts is None else ts,
                  pid=pid, tid=tid, cat=self.cat)
        if args:
            ev["args"] = args
        self.events.append(ev)

    def span(self, name: str, t_begin: float, t_end: float, *, tid: int = 0,
             pid: int = PID_ENGINE, args: dict | None = None) -> None:
        """One closed [t_begin, t_end] span as a balanced B/E pair."""
        self.begin(name, ts=t_begin, tid=tid, pid=pid, args=args)
        self.end(name, ts=t_end, tid=tid, pid=pid)

    def instant(self, name: str, *, ts: float | None = None, tid: int = 0,
                pid: int = PID_ENGINE, args: dict | None = None) -> None:
        self._ensure_named(pid, tid)
        ev = dict(name=name, ph="i", s="t",
                  ts=self.clock() if ts is None else ts,
                  pid=pid, tid=tid, cat=self.cat)
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, value: float, *,
                ts: float | None = None, pid: int = PID_ENGINE) -> None:
        """Counter track (e.g. slot-table occupancy over time)."""
        self._ensure_named(pid, 0)
        self.events.append(dict(
            name=name, ph="C", ts=self.clock() if ts is None else ts,
            pid=pid, tid=0, cat=self.cat, args={name: float(value)}))

    @contextlib.contextmanager
    def region(self, name: str, *, tid: int = 0, pid: int = PID_ENGINE,
               args: dict | None = None) -> Iterator[None]:
        """Live span around a code region, timed on the tracer's clock."""
        self.begin(name, tid=tid, pid=pid, args=args)
        try:
            yield
        finally:
            self.end(name, tid=tid, pid=pid)

    # -- request lifecycle ---------------------------------------------------
    def request_spans(self, *, rid: int, arrived: float, admitted: float,
                      sweep_start: float, sweep_end: float, billed: float,
                      lane: int, shape: int, args: dict | None = None,
                      pid: int = PID_REQUESTS) -> None:
        """The per-request lifecycle as four contiguous spans on the
        request's own track.  ``queued`` + ``admitted`` + ``sweep`` is
        exactly ``RequestRecord.latency_s`` (same clock readings); the
        ``billed`` epilogue prices the host-side accounting after the
        sweep returned.  ``pid`` selects the track group — the default
        single-tenant "requests" process, or a per-tenant pid named via
        ``name_process`` (the multi-tenant zoo)."""
        extra = dict(lane=lane, shape=shape)
        if args:
            extra.update(args)
        self.span("queued", arrived, admitted, tid=rid, pid=pid,
                  args=dict(rid=rid))
        self.span("admitted", admitted, sweep_start, tid=rid,
                  pid=pid, args=dict(lane=lane))
        self.span("sweep", sweep_start, sweep_end, tid=rid,
                  pid=pid, args=extra)
        self.span("billed", sweep_end, billed, tid=rid, pid=pid)

    # -- rendering -----------------------------------------------------------
    def to_json(self) -> list[dict[str, Any]]:
        """Render the event array: timestamps rebased on the earliest
        event and scaled to microseconds, events sorted by time (stable,
        so a B emitted before an E at the same instant stays nested)."""
        timed = [e for e in self.events if "ts" in e]
        meta = [dict(e, ts=0.0) for e in self.events if "ts" not in e]
        base = min((e["ts"] for e in timed), default=0.0)
        out = meta + [dict(e, ts=(e["ts"] - base) * 1e6)
                      for e in timed]
        out.sort(key=lambda e: e["ts"])
        return out

    def write(self, path) -> None:
        """Write one Chrome-tracing JSON array, loadable by
        ``chrome://tracing`` and https://ui.perfetto.dev."""
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


def validate_events(events: list[dict]) -> None:
    """Structural validity of a rendered event array — what a trace
    viewer needs to load it: every event carries name/ph/ts/pid/tid,
    timestamps are globally monotonic (the writer sorts), and B/E pairs
    balance (and properly nest) per (pid, tid) track.  Raises
    ``ValueError`` on the first violation; used by the tests and by
    ``Tracer.write`` consumers that want a loadability check without a
    browser."""
    last_ts = float("-inf")
    stacks: dict[tuple[int, int], list[str]] = {}
    for e in events:
        for field in ("name", "ph", "pid", "tid"):
            if field not in e:
                raise ValueError(f"event missing {field!r}: {e}")
        if e["ph"] == "M":
            continue
        if "ts" not in e:
            raise ValueError(f"timed event missing ts: {e}")
        if e["ts"] < last_ts:
            raise ValueError(
                f"non-monotonic ts: {e['ts']} after {last_ts} ({e})")
        last_ts = e["ts"]
        key = (e["pid"], e["tid"])
        if e["ph"] == "B":
            stacks.setdefault(key, []).append(e["name"])
        elif e["ph"] == "E":
            stack = stacks.get(key)
            if not stack:
                raise ValueError(f"E without matching B on track {key}: {e}")
            top = stack.pop()
            if top != e["name"]:
                raise ValueError(
                    f"interleaved spans on track {key}: E {e['name']!r} "
                    f"closes B {top!r}")
    open_spans = {k: v for k, v in stacks.items() if v}
    if open_spans:
        raise ValueError(f"unbalanced B/E pairs per tid: {open_spans}")
