"""Multi-tenant model zoo: a tenant-aware router over shared crossbars.

One ``IMPACTEngine`` serves one compiled model; a production deployment
serves *many* — per-user personalized CoTMs, per-domain classifiers, A/B
variants.  ``ModelZoo`` generalizes the engine's continuous-batching
scheduler across tenants:

* **Crossbar co-residency.**  Resident tenants' clause grids are packed
  block-diagonally onto ONE shared grid (``impact.runtime.
  build_coresident``) and served by ONE co-resident ``InferenceSession``:
  every scheduler sweep classifies a *mixed* batch — each slot-table
  lane carries a per-lane model id selecting its tenant's literal/weight
  slices — so tail tenants ride a warm shared sweep instead of paying a
  cold compile, and N tenants cost one fused launch, not N.  Off-block
  cells hold 0 A and each lane's fired bits are gated to its own clause
  columns, so cross-tenant current leakage is exactly zero by
  construction and every request's energy bill is tenant-pure.

* **Per-tenant SLO classes.**  Each tenant carries an ``SLOClass``:
  ``priority`` orders admission into free lanes (lower admits first),
  ``target_occupancy`` / ``max_wait_s`` set its firing policy (a sweep
  fires when ANY admitted lane's class is satisfied — a gold-class
  arrival fires immediately even if bulk traffic would have batched),
  and ``queue_capacity`` bounds its private admission queue (the shed
  policy: ``Backpressure`` past ``queue_capacity + free slots``,
  per-tenant, so one tenant's burst cannot starve another's queue).

* **Eviction / warm pools keyed by traffic.**  ``max_resident`` caps how
  many tenants co-reside on the shared grid.  Standby tenants are served
  by small dedicated sessions from a bounded warm pool
  (``standby_pool``), evicted by traffic EWMA when the pool overflows;
  ``rebalance()`` re-picks the resident set from the same EWMA and
  rebuilds the co-resident session — promotion is a data migration
  (re-programming the shared fabric), so it requires an idle slot table.

* **Tenant-threaded observability.**  ``RequestRecord`` carries the
  tenant id, so the latency/energy ledger and ``stats()`` aggregate per
  tenant and per SLO class for free; with a ``Tracer`` attached, each
  tenant gets its own Chrome-tracing process track (``tracing.
  PID_TENANT_BASE + index``) holding its requests' lifecycle spans.

``IMPACTEngine`` is now the single-tenant special case: it constructs a
one-tenant zoo (no co-resident plan — the lone model owns the grid) and
exposes the zoo's queue/table/ledgers as its own, so every existing test,
benchmark, and example runs unmodified.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..impact.runtime import InferenceSession, RuntimeSpec, build_coresident
from .engine import Backpressure, BatchingQueue, Request, SlotTable, \
    latency_percentiles
from .impact_engine import BatchStats, RequestRecord, aggregate_reports
from .tracing import PID_REQUESTS, PID_TENANT_BASE, Tracer

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """Service-level class of one tenant.

    ``priority`` orders admission (lower first); ``target_occupancy`` /
    ``max_wait_s`` are this class's firing policy (same semantics as the
    single-tenant engine knobs: fire when occupancy reaches the target
    or an admitted request of this class has waited ``max_wait_s``);
    ``queue_capacity`` bounds the tenant's private queue (None =
    unbounded, no shedding)."""
    name: str = "standard"
    priority: int = 1
    target_occupancy: float = 0.0
    max_wait_s: float = 0.01
    queue_capacity: int | None = None

    def __post_init__(self):
        if not 0.0 <= self.target_occupancy <= 1.0:
            raise ValueError(f"target_occupancy must be in [0, 1], "
                             f"got {self.target_occupancy}")
        if self.max_wait_s < 0.0:
            raise ValueError(f"max_wait_s must be >= 0, "
                             f"got {self.max_wait_s}")
        if self.queue_capacity is not None and self.queue_capacity < 0:
            raise ValueError(f"queue_capacity must be >= 0, "
                             f"got {self.queue_capacity}")


#: Default SLO class of the single-tenant engine shim.
DEFAULT_SLO = SLOClass(name="default", priority=0)


@dataclasses.dataclass
class TenantState:
    """One tenant's routing state inside the zoo."""
    tid: str
    slo: SLOClass
    index: int                  # stable registration index (trace pid)
    n_literals: int
    queue: BatchingQueue
    system: Any = None          # member IMPACTSystem (standby / rebalance)
    model_id: int = -1          # index into the co-resident plan; -1 standby
    lit_lo: int = 0             # literal-row offset in the shared buffer
    submitted: int = 0
    shed: int = 0
    completed: int = 0
    traffic: float = 0.0        # arrival EWMA (eviction / rebalance key)

    @property
    def resident(self) -> bool:
        return self.model_id >= 0


@dataclasses.dataclass
class _ZooLane:
    """Slot-table payload: request + admission timestamp + owning tenant."""
    req: Request
    admitted: float
    tenant: TenantState


class ModelZoo:
    """Tenant-aware continuous-batching router over ONE co-resident
    session (plus a bounded warm pool of standby sessions).

    Build with ``ModelZoo.build(tenants, spec, ...)`` (packs the member
    systems block-diagonally and compiles the shared session) or
    construct directly from an existing session for the single-tenant
    case (what ``IMPACTEngine`` does).

    ``submit(tid, literals)`` enqueues into the tenant's private queue;
    ``step()`` admits across tenants in (priority, FIFO) order, fires at
    most one co-resident sweep over the shared slot table plus any due
    standby sweeps, and returns completed ``(rid, prediction)`` pairs
    (predictions are tenant-LOCAL class indices).
    """

    def __init__(self, session: InferenceSession,
                 tenants: Sequence[tuple[str, SLOClass]], *,
                 plan=None, clock: Callable[[], float] = time.time,
                 trace: Tracer | None = None,
                 standby_capacity: int = 8, standby_pool: int = 2):
        if session.capacity is None:
            raise ValueError(
                "ModelZoo needs a session compiled with "
                "RuntimeSpec(capacity=...) — the shared slot-table sweep "
                "shape is fixed at compile time")
        plan = plan if plan is not None else session.coresident
        if plan is None and len(tenants) != 1:
            raise ValueError(
                f"{len(tenants)} tenants need a CoResidentPlan (compile "
                f"the session with RuntimeSpec(coresident=...) or use "
                f"ModelZoo.build); only a single tenant may own the "
                f"whole grid")
        if plan is not None and len(tenants) != plan.n_tenants:
            raise ValueError(
                f"{len(tenants)} tenants do not match the co-resident "
                f"plan's {plan.n_tenants} spans")
        self.session = session
        self.plan = plan
        self.clock = clock
        self.capacity = session.capacity
        self.max_resident = len(tenants)
        self._standby_capacity = standby_capacity
        self._standby_pool = standby_pool
        # Spec template for standby sessions and rebalances: the shared
        # session's spec minus its plan/shape bindings.
        self._base_spec = dataclasses.replace(
            session.spec, coresident=None, capacity=None, batch_sizes=())

        self.tenants: list[TenantState] = []
        self._by_tid: dict[str, TenantState] = {}
        for i, (tid, slo) in enumerate(tenants):
            span = plan.spans[i] if plan is not None else None
            self._register(
                tid, slo, model_id=i,
                lit_lo=span.lit_lo if span is not None else 0,
                n_literals=(span.lit_hi - span.lit_lo
                            if span is not None
                            else session.system.n_literals))

        self.table = SlotTable(self.capacity)
        self._lane_lits = np.ones(
            (self.capacity, session.system.n_literals), np.int8)
        self._lane_mid = np.zeros((self.capacity,), np.int32)
        self.batch_stats: list[BatchStats] = []
        self.reports: list = []
        self.request_records: list[RequestRecord] = []
        self._next_rid = 0
        self._warm: set[int] = {b for (_, b)
                                in session.compiled_shapes("infer_step")}
        self._standby_sessions: dict[str, InferenceSession] = {}
        self._standby_warm: set[tuple[str, int]] = set()
        self.resident_sweeps = 0
        self.standby_sweeps = 0
        self.trace: Tracer | None = None
        self.attach_trace(trace)

    # -- construction --------------------------------------------------------
    @classmethod
    def build(cls, tenants: Sequence[tuple[str, Any, SLOClass]],
              spec: RuntimeSpec | None = None, *,
              capacity: int | None = None, max_resident: int | None = None,
              standby_capacity: int = 8, standby_pool: int = 2,
              clock: Callable[[], float] = time.time,
              trace: Tracer | None = None) -> "ModelZoo":
        """Build a zoo from ``(tid, IMPACTSystem, SLOClass)`` triples.

        The first ``max_resident`` tenants (all, when None) co-reside:
        their systems are packed block-diagonally and compiled into one
        shared session from ``spec`` (default: staged-metered pallas);
        the rest register as standby tenants served from the warm pool.
        ``capacity`` overrides ``spec.capacity`` (one of the two must
        set the slot-table shape).
        """
        tenants = list(tenants)
        if not tenants:
            raise ValueError("ModelZoo.build needs at least one tenant")
        spec = RuntimeSpec() if spec is None else spec
        cap = capacity if capacity is not None else spec.capacity
        if cap is None:
            raise ValueError("ModelZoo.build needs a slot-table shape: "
                             "pass capacity= or a spec with capacity set")
        n_res = (len(tenants) if max_resident is None
                 else max(1, min(max_resident, len(tenants))))
        residents, standby = tenants[:n_res], tenants[n_res:]
        combined, plan = build_coresident([s for _, s, _ in residents])
        session = combined.compile(dataclasses.replace(
            spec, coresident=plan, capacity=cap, batch_sizes=()))
        zoo = cls(session, [(tid, slo) for tid, _, slo in residents],
                  plan=plan, clock=clock, trace=trace,
                  standby_capacity=standby_capacity,
                  standby_pool=standby_pool)
        zoo.max_resident = n_res
        for (tid, _, _), t in zip(residents, zoo.tenants):
            t.system = tenants[t.index][1]
        for tid, system, slo in standby:
            zoo.add_standby(tid, system, slo)
        return zoo

    def _register(self, tid: str, slo: SLOClass, *, model_id: int,
                  lit_lo: int, n_literals: int,
                  system=None) -> TenantState:
        if tid in self._by_tid:
            raise ValueError(f"duplicate tenant id {tid!r}")
        resident = model_id >= 0
        t = TenantState(
            tid=tid, slo=slo, index=len(self.tenants),
            n_literals=n_literals, system=system, model_id=model_id,
            lit_lo=lit_lo,
            queue=BatchingQueue(
                max_batch=(self.capacity if resident
                           else self._standby_capacity),
                max_wait_s=slo.max_wait_s, clock=self.clock))
        self.tenants.append(t)
        self._by_tid[tid] = t
        return t

    def add_standby(self, tid: str, system, slo: SLOClass) -> TenantState:
        """Register a standby tenant: served from the bounded warm pool
        of dedicated sessions until ``rebalance()`` promotes it."""
        t = self._register(tid, slo, model_id=-1, lit_lo=0,
                           n_literals=system.n_literals, system=system)
        self._name_tenant_track(t)
        return t

    def attach_trace(self, trace: Tracer | None) -> None:
        """Attach (or replace) the Chrome-tracing emitter.  The tracer is
        re-clocked onto the zoo's clock, and in multi-tenant zoos each
        tenant claims its own process track."""
        if trace is not None:
            trace.clock = self.clock
            for t in self.tenants:
                self._name_tenant_track(t, trace)
        self.trace = trace

    def _name_tenant_track(self, t: TenantState,
                           trace: Tracer | None = None) -> None:
        trace = trace if trace is not None else self.trace
        if trace is not None and len(self.tenants) > 1:
            trace.name_process(PID_TENANT_BASE + t.index,
                               f"tenant {t.tid}")

    def _pid_for(self, t: TenantState) -> int:
        # The single-tenant zoo keeps the engine's "requests" track so
        # existing traces are byte-compatible; multi-tenant zoos give
        # each tenant its own process group.
        if len(self.tenants) == 1:
            return PID_REQUESTS
        return PID_TENANT_BASE + t.index

    # -- request plumbing ----------------------------------------------------
    def tenant(self, tid: str) -> TenantState:
        t = self._by_tid.get(tid)
        if t is None:
            raise KeyError(f"unknown tenant {tid!r} "
                           f"(registered: {sorted(self._by_tid)})")
        return t

    @property
    def pending(self) -> int:
        return sum(len(t.queue.pending) for t in self.tenants)

    def submit(self, tid: str, literals: np.ndarray) -> int:
        """Enqueue one (K_t,) literal vector for tenant ``tid``; returns
        the zoo-global request id.  Raises ``ValueError`` on a mis-shaped
        request and ``Backpressure`` per the tenant's shed policy
        (pending >= ``slo.queue_capacity`` + free sweep lanes)."""
        t = self.tenant(tid)
        lits = np.asarray(literals)
        # NOT an assert: shape validation guards the shared lane buffer
        # and must survive ``python -O``.
        if lits.shape != (t.n_literals,):
            raise ValueError(
                f"literals shape {lits.shape} does not match tenant "
                f"{tid!r}'s compiled request shape ({t.n_literals},)")
        cap = t.slo.queue_capacity
        if cap is not None:
            # A resident tenant can absorb (free slots + queue_capacity)
            # before its next sweep; a standby tenant's next sweep is one
            # standby batch.  Beyond that, shed at the edge.
            free = (self.table.free if t.resident
                    else self._standby_capacity)
            if len(t.queue.pending) >= cap + free:
                raise Backpressure(
                    f"tenant {tid!r}: {self.table.occupancy}/"
                    f"{self.table.capacity} slots busy and "
                    f"{len(t.queue.pending)} requests queued "
                    f"(queue_capacity={cap})")
        t.submitted += 1
        t.traffic += 1.0
        rid = self._next_rid
        self._next_rid += 1
        # Stamp arrival on the zoo's clock so staleness checks and
        # latency records never mix time sources.
        t.queue.add(Request(rid, lits.astype(np.int8), max_new=0,
                            arrived=self.clock()))
        return rid

    def try_submit(self, tid: str, literals: np.ndarray) -> int | None:
        try:
            return self.submit(tid, literals)
        except Backpressure:
            self.tenant(tid).shed += 1
            return None

    def warmup(self) -> None:
        """AOT-compile the shared sweep shape (usually already compiled
        at session build)."""
        self.session.warm(self.capacity)
        self._warm.add(self.capacity)

    # -- scheduling ----------------------------------------------------------
    def _admission_order(self) -> list[TenantState]:
        return sorted((t for t in self.tenants if t.resident),
                      key=lambda t: (t.slo.priority, t.index))

    def _should_fire(self, now: float, occ: int) -> bool:
        # A sweep fires when ANY admitted lane's SLO class is satisfied:
        # its occupancy target is met (target_occupancy <= 1, so a full
        # table always fires) or it has waited its class's max_wait_s
        # since ADMISSION.  Reduces exactly to the single-tenant engine
        # policy when every lane shares one class.
        for _, lane in self.table.occupied():
            slo = lane.tenant.slo
            if occ >= self.capacity * slo.target_occupancy:
                return True
            if (now - lane.admitted) >= slo.max_wait_s:
                return True
        return False

    def step(self, *, force: bool = False) -> list[tuple[int, int]]:
        """One scheduler iteration across every tenant: admit into the
        shared table by (priority, FIFO), fire at most one co-resident
        sweep, then any due standby sweeps.  Returns completed
        ``(rid, tenant-local prediction)`` pairs; ``force`` fires below
        the SLO thresholds (tail drain)."""
        out = self._step_resident(force)
        out += self._step_standby(force)
        return out

    def _step_resident(self, force: bool) -> list[tuple[int, int]]:
        now = self.clock()
        admitted = []
        for t in self._admission_order():
            free = self.table.free
            if free == 0:
                break
            for req in t.queue.take_n(free):
                s = self.table.admit(_ZooLane(req, now, t))
                # Only the tenant's own literal rows are driven; foreign
                # slices stay 1 (floating rows, 0 A by construction).
                self._lane_lits[s, t.lit_lo:t.lit_lo + t.n_literals] = \
                    req.tokens
                self._lane_mid[s] = t.model_id
                admitted.append(s)
        if admitted and self.trace is not None:
            self.trace.span("admission", now, self.clock(), args=dict(
                lanes=admitted, occupancy=self.table.occupancy))
        occ = self.table.occupancy
        if occ == 0:
            return []
        if not (force or self._should_fire(now, occ)):
            return []
        lanes = list(self.table.occupied())
        out = self.execute_batch(jnp.asarray(self._lane_lits),
                                 self.table.valid_mask(), self.capacity,
                                 lanes)
        t_rel = self.clock()
        for i, _ in lanes:
            self.table.release(i)
            self._lane_lits[i] = 1
        if self.trace is not None:
            self.trace.span("release", t_rel, self.clock(), args=dict(
                lanes=[i for i, _ in lanes],
                occupancy=self.table.occupancy))
        return out

    def _step_standby(self, force: bool) -> list[tuple[int, int]]:
        out: list[tuple[int, int]] = []
        for t in sorted((t for t in self.tenants if not t.resident),
                        key=lambda t: (t.slo.priority, t.index)):
            q = t.queue
            if not q.pending or not (force or q.ready()):
                continue
            sess = self._standby_session(t)
            batch = q.take_n(self._standby_capacity)
            now = self.clock()
            lanes = [(i, _ZooLane(r, now, t)) for i, r in enumerate(batch)]
            lits = np.ones((self._standby_capacity, t.n_literals), np.int8)
            valid = np.zeros((self._standby_capacity,), bool)
            for i, r in enumerate(batch):
                lits[i] = r.tokens
                valid[i] = True
            key = (t.tid, self._standby_capacity)
            cold = key not in self._standby_warm
            self._standby_warm.add(key)
            out += self._run_sweep(sess, jnp.asarray(lits), valid,
                                   self._standby_capacity, lanes,
                                   model_ids=None, cold=cold, standby=True)
        return out

    def _standby_session(self, t: TenantState) -> InferenceSession:
        """The tenant's warm-pool session, compiling (and evicting the
        coldest-traffic tenant's session) on demand."""
        sess = self._standby_sessions.get(t.tid)
        if sess is None:
            if len(self._standby_sessions) >= self._standby_pool:
                victim = min(self._standby_sessions,
                             key=lambda tid: self._by_tid[tid].traffic)
                del self._standby_sessions[victim]
                self._standby_warm = {
                    k for k in self._standby_warm if k[0] != victim}
            sess = t.system.compile(dataclasses.replace(
                self._base_spec, capacity=self._standby_capacity))
            self._standby_sessions[t.tid] = sess
        return sess

    # -- execution -----------------------------------------------------------
    def execute_batch(self, lits: Array, valid: np.ndarray, shape: int,
                      lanes: list[tuple[int, _ZooLane]],
                      ) -> list[tuple[int, int]]:
        """Fire one sweep of the SHARED session over the slot-table shape
        (also the flush-mode entry of the single-tenant engine shim)."""
        cold = shape not in self._warm
        self._warm.add(shape)
        mids = (self._lane_mid if (self.plan is not None
                                   and shape == self.capacity) else None)
        return self._run_sweep(self.session, lits, valid, shape, lanes,
                               model_ids=mids, cold=cold, standby=False)

    def _run_sweep(self, session: InferenceSession, lits: Array,
                   valid: np.ndarray, shape: int,
                   lanes: list[tuple[int, _ZooLane]], *,
                   model_ids, cold: bool,
                   standby: bool) -> list[tuple[int, int]]:
        """One crossbar sweep + all per-step accounting (ledgers, energy
        billing, tenant-threaded trace spans)."""
        occupancy = len(lanes) / shape
        t0 = self.clock()
        if self.trace is not None:
            args = dict(shape=shape, n_valid=len(lanes),
                        occupancy=occupancy, cold=cold,
                        lanes=[i for i, _ in lanes])
            if standby:
                args["standby_tenant"] = lanes[0][1].tenant.tid
            self.trace.begin("sweep", ts=t0, args=args)
        if model_ids is not None:
            res = session.infer_step(lits, valid, model_ids=model_ids)
        else:
            res = session.infer_step(lits, valid)
        preds = np.asarray(jax.block_until_ready(res.predictions))
        # float64 before the per-request clause+class add so the request
        # bills sum to the (float64) batch meter, not to f32 rounding.
        e_cl = np.asarray(res.e_clause_lanes, np.float64)
        e_cs = np.asarray(res.e_class_lanes, np.float64)
        t1 = self.clock()
        dt = t1 - t0
        if self.trace is not None:
            self.trace.end("sweep", ts=t1)
            self.trace.begin("billing", ts=t1,
                             args=dict(n_requests=len(lanes)))
        recs = [RequestRecord(
            rid=lane.req.rid, arrived=lane.req.arrived,
            admitted=lane.admitted, completed=t1, pred=int(preds[i]),
            e_read_j=float(e_cl[i] + e_cs[i]),
            tenant=lane.tenant.tid) for i, lane in lanes]
        self.request_records.extend(recs)
        for _, lane in lanes:
            lane.tenant.completed += 1
        pct = latency_percentiles([r.latency_s for r in recs])
        self.batch_stats.append(BatchStats(
            bucket=shape, n_valid=len(recs), latency_s=dt,
            samples_per_s=len(recs) / max(dt, 1e-9), cold=cold,
            occupancy=occupancy,
            p50_s=pct.get("p50_s", 0.0), p95_s=pct.get("p95_s", 0.0),
            p99_s=pct.get("p99_s", 0.0)))
        if standby:
            self.standby_sweeps += 1
        else:
            self.resident_sweeps += 1
        if session.meters_energy:
            self.reports.append(session.system.step_report(e_cl, e_cs,
                                                           len(recs)))
        if self.trace is not None:
            t2 = self.clock()
            self.trace.end("billing", ts=t2)
            for (i, lane), r in zip(lanes, recs):
                self.trace.request_spans(
                    rid=r.rid, arrived=r.arrived, admitted=r.admitted,
                    sweep_start=t0, sweep_end=t1, billed=t2, lane=i,
                    shape=shape, pid=self._pid_for(lane.tenant),
                    args=dict(e_read_j=r.e_read_j, pred=r.pred,
                              tenant=r.tenant))
        return [(r.rid, r.pred) for r in recs]

    # -- eviction / rebalance ------------------------------------------------
    def rebalance(self, decay: float = 0.5) -> bool:
        """Re-pick the resident set by traffic EWMA and rebuild the
        co-resident session when it changes (returns True).  Promotion
        re-programs the shared fabric, so the slot table must be idle;
        traffic counters decay by ``decay`` each call, making the EWMA
        window the rebalance cadence."""
        if any(t.system is None for t in self.tenants):
            # Low-level construction (e.g. the single-tenant engine shim)
            # has no member systems to re-pack.
            for t in self.tenants:
                t.traffic *= decay
            return False
        ranked = sorted(self.tenants,
                        key=lambda t: (-t.traffic, t.index))
        want = sorted(ranked[:self.max_resident], key=lambda t: t.index)
        have = [t for t in self.tenants if t.resident]
        if [t.tid for t in want] == [t.tid for t in have]:
            for t in self.tenants:
                t.traffic *= decay
            return False
        # Validate BEFORE mutating: a busy-table raise must leave the
        # traffic EWMAs untouched, or the retry re-ranks on corrupted
        # counters (each failed attempt would decay them again).
        if self.table.occupancy:
            raise RuntimeError(
                "rebalance() re-programs the shared crossbar and needs "
                "an idle slot table — drain in-flight lanes first "
                "(step(force=True))")
        for t in self.tenants:
            t.traffic *= decay
        combined, plan = build_coresident([t.system for t in want])
        self.session = combined.compile(dataclasses.replace(
            self._base_spec, coresident=plan, capacity=self.capacity))
        self.plan = plan
        for t in self.tenants:
            t.model_id = -1
            t.queue.max_batch = self._standby_capacity
        for mid, t in enumerate(want):
            span = plan.spans[mid]
            t.model_id = mid
            t.lit_lo = span.lit_lo
            t.queue.max_batch = self.capacity
            # A promoted tenant rides the shared sweep now; its dedicated
            # session leaves the warm pool.
            self._standby_sessions.pop(t.tid, None)
            self._standby_warm = {
                k for k in self._standby_warm if k[0] != t.tid}
        self.table = SlotTable(self.capacity)
        self._lane_lits = np.ones(
            (self.capacity, combined.n_literals), np.int8)
        self._lane_mid = np.zeros((self.capacity,), np.int32)
        self._warm = {b for (_, b)
                      in self.session.compiled_shapes("infer_step")}
        return True

    # -- aggregation ---------------------------------------------------------
    def drain(self) -> list[tuple[int, int]]:
        """Step until every queue and the slot table are empty (forcing
        once nothing more can batch up)."""
        out: list[tuple[int, int]] = []
        while self.pending or self.table.occupancy:
            done = self.step(force=not any(t.queue.ready()
                                           for t in self.tenants
                                           if t.queue.pending))
            out += done
        return out

    def stats(self) -> dict:
        """Zoo-lifetime aggregates plus per-tenant and per-SLO-class
        breakdowns (latency percentiles, energy bills, shed counts) and
        the resident/standby sweep counters the co-residency benchmark
        compares against N independent engines."""
        bs = self.batch_stats
        warm = [s for s in bs if not s.cold] or bs
        w_total = sum(s.n_valid for s in warm)
        w_wall = sum(s.latency_s for s in warm)
        out = dict(
            tenants=len(self.tenants),
            resident=[t.tid for t in self.tenants if t.resident],
            standby=[t.tid for t in self.tenants if not t.resident],
            batches=len(bs), samples=sum(s.n_valid for s in bs),
            wall_s=sum(s.latency_s for s in bs),
            cold_batches=sum(s.cold for s in bs),
            samples_per_s=w_total / max(w_wall, 1e-9),
            mean_occupancy=(sum(s.occupancy for s in bs) / len(bs)
                            if bs else 0.0),
            sweeps=dict(resident=self.resident_sweeps,
                        standby=self.standby_sweeps),
        )
        recs = self.request_records
        if recs:
            out["latency"] = latency_percentiles(
                [r.latency_s for r in recs])
        if self.reports:
            agg = aggregate_reports(self.reports)
            out["energy"] = agg
            out["energy_per_datapoint_j"] = agg.energy_per_datapoint_j
        by_tenant = {}
        for t in self.tenants:
            t_recs = [r for r in recs if r.tenant == t.tid]
            d = dict(slo=t.slo.name, resident=t.resident,
                     submitted=t.submitted, shed=t.shed,
                     completed=len(t_recs),
                     e_read_j=float(sum(r.e_read_j for r in t_recs)))
            if t_recs:
                d["latency"] = latency_percentiles(
                    [r.latency_s for r in t_recs])
            by_tenant[t.tid] = d
        out["per_tenant"] = by_tenant
        by_slo: dict[str, list[float]] = {}
        slo_meta: dict[str, SLOClass] = {}
        for t in self.tenants:
            slo_meta[t.slo.name] = t.slo
            by_slo.setdefault(t.slo.name, []).extend(
                r.latency_s for r in recs if r.tenant == t.tid)
        out["per_slo"] = {
            name: dict(priority=slo_meta[name].priority,
                       **latency_percentiles(lat))
            for name, lat in by_slo.items() if lat}
        return out


def replay_zoo_trace(zoo: ModelZoo, requests: Sequence[tuple[str, Any]],
                     arrivals: np.ndarray, *,
                     trace_path: str | None = None) -> dict:
    """Replay a mixed-tenant arrival trace through the zoo in wall-clock
    time (the multi-tenant twin of ``impact_engine.replay_trace``):
    ``requests[i]`` is ``(tenant_id, literal_row)``, submitted once
    ``arrivals[i]`` seconds have elapsed.  Returns tail-latency
    percentiles + throughput + the zoo's per-tenant/per-SLO stats;
    ``trace_path`` writes the Chrome-tracing timeline (one Perfetto
    process track per tenant)."""
    n = len(arrivals)
    if len(requests) < n:
        raise ValueError(
            f"replay_zoo_trace needs one request per arrival: got "
            f"{len(requests)} requests for {n} arrivals")
    tracer = zoo.trace
    if trace_path is not None and tracer is None:
        tracer = Tracer(clock=zoo.clock)
        zoo.attach_trace(tracer)
    q0 = len(zoo.request_records)
    shed = 0
    i = 0
    ndone = 0
    t0 = zoo.clock()
    while ndone < n - shed:
        now = zoo.clock() - t0
        while i < n and arrivals[i] <= now:
            tid, row = requests[i]
            if zoo.try_submit(tid, row) is None:
                shed += 1
                if tracer is not None:
                    tracer.instant("shed", args=dict(offered_index=i,
                                                     tenant=tid))
            i += 1
        out = zoo.step(force=i >= n)
        ndone += len(out)
        if not out:
            idle = zoo.pending == 0 and zoo.table.occupancy == 0
            gap = (arrivals[i] - (zoo.clock() - t0)
                   if (idle and i < n) else 0.0)
            before = zoo.clock()
            time.sleep(min(max(gap, 2e-4), 1e-3))
            if zoo.clock() == before:
                raise RuntimeError(
                    "replay_zoo_trace requires a wall clock: the zoo's "
                    "injected clock did not advance across a sleep — "
                    "construct the zoo with clock=time.monotonic (or "
                    "another real clock) to replay traces")
    wall = zoo.clock() - t0
    recs = zoo.request_records[q0:]
    out = dict(offered=n, shed=shed, completed=len(recs), wall_s=wall,
               samples_per_s=len(recs) / max(wall, 1e-9))
    out.update(latency_percentiles([r.latency_s for r in recs]))
    out["zoo"] = zoo.stats()
    if trace_path is not None:
        tracer.write(trace_path)
        out["trace_path"] = str(trace_path)
    return out
