"""Serving engine: batched prefill + decode with KV/recurrent caches.

One engine drives every family in the zoo — attention models carry KV
caches (MLA: compressed latents; zamba2: ring buffers + SSM states; rwkv6:
O(1) recurrent state).  The jitted ``prefill`` and ``decode_step``
functions are the same entry points the multi-pod dry-run lowers, so what
serves here is exactly what was proven to shard.

Request batching: ``generate`` takes equal-length prompt batches (the
benchmark/test regime).  ``BatchingQueue`` provides the accumulate-and-
flush front; ``SlotTable`` + ``Engine.serve_continuous`` provide the
continuous-batching front — a fixed-capacity lane table where finished
requests release their slot and new requests are admitted between decode
steps, so a late arrival never waits out the whole in-flight batch.  The
same ``SlotTable`` drives the IMPACT crossbar front
(``serve.impact_engine``): both engines share admission, release, and
per-request latency semantics.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from functools import partial
from typing import Any, Callable, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .tracing import PID_REQUESTS, Tracer

Array = jax.Array


class Backpressure(RuntimeError):
    """Raised when an engine cannot accept more work: every slot is
    occupied and the admission queue is at capacity.  Callers shed load or
    retry after a ``step``; ``try_submit`` converts it to ``None``."""


def latency_percentiles(latencies_s: Sequence[float]) -> dict[str, float]:
    """Tail-latency summary (p50/p95/p99/mean/max seconds) of a sample."""
    if len(latencies_s) == 0:
        return {}
    a = np.asarray(latencies_s, dtype=float)
    return {
        "p50_s": float(np.percentile(a, 50)),
        "p95_s": float(np.percentile(a, 95)),
        "p99_s": float(np.percentile(a, 99)),
        "mean_s": float(a.mean()),
        "max_s": float(a.max()),
        "n": int(a.size),
    }


class SlotTable:
    """Fixed-capacity lane table for continuous batching.

    Each slot holds one in-flight request (any payload).  ``admit`` places
    a payload in the lowest free slot (stable lane indices keep device-side
    state — KV-cache lanes, literal buffers — aligned with the table);
    ``release`` frees it; ``valid_mask`` derives the per-lane validity
    vector from occupancy, which is exactly the mask the padded kernels
    consume.  ``compact`` densifies occupied lanes into a prefix and
    returns the (src, dst) moves so callers can permute device buffers the
    same way.
    """

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self.slots: list[Any | None] = [None] * capacity
        self._n = 0

    @property
    def occupancy(self) -> int:
        return self._n

    @property
    def free(self) -> int:
        return self.capacity - self._n

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def occupied(self) -> Iterator[tuple[int, Any]]:
        return ((i, s) for i, s in enumerate(self.slots) if s is not None)

    def admit(self, item: Any) -> int:
        """Place ``item`` in the lowest free slot; raises Backpressure when
        the table is full."""
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = item
                self._n += 1
                return i
        raise Backpressure(f"all {self.capacity} slots occupied")

    def release(self, i: int) -> Any:
        item = self.slots[i]
        if item is None:
            raise KeyError(f"slot {i} is already free")
        self.slots[i] = None
        self._n -= 1
        return item

    def valid_mask(self) -> np.ndarray:
        return np.array([s is not None for s in self.slots], dtype=bool)

    def compact(self) -> list[tuple[int, int]]:
        """Move occupied slots into a dense prefix (stable order); returns
        the (src, dst) lane moves applied."""
        moves: list[tuple[int, int]] = []
        dst = 0
        for src in range(self.capacity):
            if self.slots[src] is None:
                continue
            if src != dst:
                self.slots[dst] = self.slots[src]
                self.slots[src] = None
                moves.append((src, dst))
            dst += 1
        return moves


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0        # 0 => greedy
    eos_id: int | None = None


def _scatter_cache(cache, cache_axes, new_cache, src_rows, dst_rows):
    """Write lane ``src_rows[j]`` of ``new_cache`` into lane ``dst_rows[j]``
    of ``cache`` on every leaf.  The batch axis is not leading on every
    leaf (layer-stacked KV leaves are (layers, batch, ...)), so each leaf's
    lane axis is located via the model's ``cache_axes`` pytree."""
    leaves, treedef = jax.tree.flatten(cache)
    new_leaves = jax.tree.leaves(new_cache)
    ax_leaves = jax.tree.leaves(cache_axes,
                                is_leaf=lambda x: isinstance(x, tuple))
    if not len(leaves) == len(new_leaves) == len(ax_leaves):
        raise ValueError(
            f"cache pytrees disagree: {len(leaves)} cache leaves vs "
            f"{len(new_leaves)} new-cache leaves vs {len(ax_leaves)} "
            f"cache_axes leaves — the model's cache_axes() no longer "
            f"mirrors its cache structure")
    src = jnp.asarray(src_rows)
    dst = jnp.asarray(dst_rows)
    out = []
    for c, n, ax in zip(leaves, new_leaves, ax_leaves):
        b = ax.index("batch")
        pre = (slice(None),) * b
        out.append(c.at[pre + (dst,)].set(n[pre + (src,)]))
    return jax.tree.unflatten(treedef, out)


class Engine:
    """LM serving engine.  ``trace`` (a ``serve.tracing.Tracer``)
    records the decode timeline as Chrome-tracing spans: ``prefill`` /
    ``decode`` regions on the scheduler track and one ``request`` span
    (arrival -> completion, slot id as an arg) per request in
    ``serve_continuous`` — the same span vocabulary as the IMPACT
    crossbar engine, so both fronts open in the same viewer."""

    def __init__(self, model, params, cfg: ServeConfig, *,
                 trace: Tracer | None = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.trace = trace
        self._prefill = jax.jit(
            lambda p, toks, pos: model.prefill(p, toks, pos, cfg.max_len))
        self._decode = jax.jit(
            lambda p, cache, toks, pos: model.decode_step(
                p, cache, toks, pos),
            donate_argnums=(1,))

    def _sample(self, logits: Array, key: Array) -> Array:
        """logits (B, 1, V) or (B, 1, C, V) -> next tokens (B, 1[, C])."""
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: Array, n_tokens: int, *,
                 seed: int = 0) -> tuple[Array, dict]:
        """prompts (B, S[, C]) -> (generated (B, n_tokens[, C]), stats)."""
        B, S = prompts.shape[:2]
        t0 = time.time()
        pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        logits, cache = self._prefill(self.params, prompts, pos)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0
        if self.trace is not None:
            self.trace.span("prefill", t0, t0 + t_prefill,
                            args=dict(batch=B, seq=S))

        key = jax.random.PRNGKey(seed)
        tok = self._sample(logits, key)
        out = [tok]
        t0 = time.time()
        for i in range(n_tokens - 1):
            key, sub = jax.random.split(key)
            p = jnp.full((B, 1), S + i, jnp.int32)
            logits, cache = self._decode(self.params, cache, tok, p)
            tok = self._sample(logits, sub)
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
        if self.trace is not None:
            self.trace.span("decode", t0, t0 + t_decode,
                            args=dict(batch=B, n_tokens=n_tokens))
        gen = jnp.concatenate(out, axis=1)
        stats = dict(
            prefill_s=t_prefill, decode_s=t_decode,
            tokens=B * n_tokens,
            decode_tok_per_s=B * max(n_tokens - 1, 1) / max(t_decode, 1e-9))
        return gen, stats

    # -- continuous batching ------------------------------------------------
    def _is_eos(self, tok: np.ndarray) -> bool:
        if self.cfg.eos_id is None:
            return False
        return int(np.asarray(tok).ravel()[0]) == self.cfg.eos_id

    def serve_continuous(self, requests: list["Request"], *,
                         capacity: int = 4, seed: int = 0,
                         ) -> tuple[dict[int, np.ndarray], dict]:
        """Continuous-batching decode: a ``SlotTable`` of ``capacity`` lanes
        where a request releases its slot the step it finishes (``max_new``
        or EOS) and queued requests are admitted into freed lanes between
        decode steps — no flush-and-drain, so short requests never wait out
        long co-batched ones.

        Admission prefills the newcomers as a full-capacity batch (one
        compiled prefill shape) and lane-scatters their cache into the live
        cache at the admitted slots.  Prompts must share one length (the
        equal-length regime ``generate`` serves); per-request end-to-end
        latency percentiles come back in the stats.

        Returns ({rid: generated tokens (n_i, ...)}, stats).
        """
        if not requests:
            raise ValueError("serve_continuous needs at least one request")
        S = requests[0].tokens.shape[0]
        if not all(r.tokens.shape[0] == S for r in requests):
            raise ValueError(
                "serve_continuous requires equal-length prompts (the "
                "compiled prefill shape is shared across admissions)")
        axes = self.model.cache_axes()
        table = SlotTable(capacity)
        pending = collections.deque(requests)
        trail = requests[0].tokens.shape[1:]
        tok = np.zeros((capacity, 1) + trail, np.int32)
        pos = np.zeros((capacity,), np.int32)
        n_gen = np.zeros((capacity,), np.int32)
        key = jax.random.PRNGKey(seed)
        cache = None
        out: dict[int, list[np.ndarray]] = {}
        lat: dict[int, float] = {}
        t0 = time.time()
        steps = 0

        def finish(slot: int, req: Request) -> None:
            table.release(slot)
            done = time.time()
            lat[req.rid] = done - req.arrived
            if self.trace is not None:
                self.trace.span("request", req.arrived, done, tid=req.rid,
                                pid=PID_REQUESTS,
                                args=dict(rid=req.rid, slot=slot))

        while pending or table.occupancy:
            free = table.free_slots()
            if pending and free:
                k = min(len(free), len(pending))
                reqs = [pending.popleft() for _ in range(k)]
                t_adm = time.time()
                # Full-capacity prefill batch (rows >= k repeat the last
                # newcomer so the prefill jit sees exactly one shape);
                # only rows < k are scattered into lanes.
                ptoks = np.stack([reqs[min(i, k - 1)].tokens
                                  for i in range(capacity)])
                ppos = np.broadcast_to(np.arange(S)[None], (capacity, S))
                key, sub = jax.random.split(key)
                logits, new_cache = self._prefill(
                    self.params, jnp.asarray(ptoks), jnp.asarray(ppos))
                first = np.asarray(self._sample(logits, sub))
                slots = [table.admit(r) for r in reqs]
                if self.trace is not None:
                    self.trace.span("prefill", t_adm, time.time(),
                                    args=dict(admitted=k, slots=slots,
                                              occupancy=table.occupancy))
                base = cache if cache is not None else new_cache
                cache = _scatter_cache(base, axes, new_cache,
                                       np.arange(k), np.asarray(slots))
                for i, (s, r) in enumerate(zip(slots, reqs)):
                    out[r.rid] = [first[i]]
                    tok[s] = first[i]
                    pos[s] = S
                    n_gen[s] = 1
                    if n_gen[s] >= r.max_new or self._is_eos(first[i]):
                        finish(s, r)
            if table.occupancy:
                t_dec = time.time()
                key, sub = jax.random.split(key)
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray(tok),
                    jnp.asarray(pos)[:, None])
                nxt = np.asarray(self._sample(logits, sub))
                steps += 1
                if self.trace is not None:
                    self.trace.span("decode_step", t_dec, time.time(),
                                    args=dict(step=steps,
                                              occupancy=table.occupancy))
                for s, r in list(table.occupied()):
                    out[r.rid].append(nxt[s])
                    tok[s] = nxt[s]
                    pos[s] += 1
                    n_gen[s] += 1
                    if n_gen[s] >= r.max_new or self._is_eos(nxt[s]):
                        finish(s, r)
        gen = {rid: np.concatenate(toks, axis=0) for rid, toks in out.items()}
        stats = dict(decode_steps=steps, wall_s=time.time() - t0,
                     requests=len(requests), capacity=capacity,
                     latency=latency_percentiles(list(lat.values())))
        return gen, stats


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray
    max_new: int
    arrived: float = dataclasses.field(default_factory=time.time)


class BatchingQueue:
    """Request accumulator: flushes when full or stale.  ``clock`` is the
    same injectable time source the owning engine stamps requests with, so
    staleness is measured on one clock."""

    def __init__(self, max_batch: int = 8, max_wait_s: float = 0.05,
                 clock: Callable[[], float] = time.time):
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.clock = clock
        self.pending: list[Request] = []

    def add(self, req: Request):
        self.pending.append(req)

    def ready(self) -> bool:
        if not self.pending:
            return False
        if len(self.pending) >= self.max_batch:
            return True
        return (self.clock() - self.pending[0].arrived) >= self.max_wait_s

    def take(self) -> list[Request]:
        batch, self.pending = (self.pending[:self.max_batch],
                               self.pending[self.max_batch:])
        return batch

    def take_n(self, n: int) -> list[Request]:
        """Dequeue up to ``n`` requests FIFO (continuous-batching admission
        takes exactly as many as there are free slots)."""
        batch, self.pending = self.pending[:n], self.pending[n:]
        return batch

    @staticmethod
    def pad(batch: list[Request], pad_id: int = 0):
        """Right-align prompts into (B, S_max) + validity mask."""
        s_max = max(r.tokens.shape[0] for r in batch)
        toks = np.full((len(batch), s_max), pad_id, np.int32)
        mask = np.zeros((len(batch), s_max), bool)
        for i, r in enumerate(batch):
            s = r.tokens.shape[0]
            toks[i, s_max - s:] = r.tokens
            mask[i, s_max - s:] = True
        return jnp.asarray(toks), jnp.asarray(mask)
