"""Serving engine: batched prefill + decode with KV/recurrent caches.

One engine drives every family in the zoo — attention models carry KV
caches (MLA: compressed latents; zamba2: ring buffers + SSM states; rwkv6:
O(1) recurrent state).  The jitted ``prefill`` and ``decode_step``
functions are the same entry points the multi-pod dry-run lowers, so what
serves here is exactly what was proven to shard.

Request batching: ``generate`` takes equal-length prompt batches (the
benchmark/test regime).  ``BatchingQueue`` provides the production front:
requests accumulate until ``max_batch`` or ``max_wait_s`` and are padded to
a shared length with a validity mask (continuous batching — slot reuse on
completion — is scoped in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0        # 0 => greedy
    eos_id: int | None = None


class Engine:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(
            lambda p, toks, pos: model.prefill(p, toks, pos, cfg.max_len))
        self._decode = jax.jit(
            lambda p, cache, toks, pos: model.decode_step(
                p, cache, toks, pos),
            donate_argnums=(1,))

    def _sample(self, logits: Array, key: Array) -> Array:
        """logits (B, 1, V) or (B, 1, C, V) -> next tokens (B, 1[, C])."""
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: Array, n_tokens: int, *,
                 seed: int = 0) -> tuple[Array, dict]:
        """prompts (B, S[, C]) -> (generated (B, n_tokens[, C]), stats)."""
        B, S = prompts.shape[:2]
        t0 = time.time()
        pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        logits, cache = self._prefill(self.params, prompts, pos)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        key = jax.random.PRNGKey(seed)
        tok = self._sample(logits, key)
        out = [tok]
        t0 = time.time()
        for i in range(n_tokens - 1):
            key, sub = jax.random.split(key)
            p = jnp.full((B, 1), S + i, jnp.int32)
            logits, cache = self._decode(self.params, cache, tok, p)
            tok = self._sample(logits, sub)
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
        gen = jnp.concatenate(out, axis=1)
        stats = dict(
            prefill_s=t_prefill, decode_s=t_decode,
            tokens=B * n_tokens,
            decode_tok_per_s=B * max(n_tokens - 1, 1) / max(t_decode, 1e-9))
        return gen, stats


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray
    max_new: int
    arrived: float = dataclasses.field(default_factory=time.time)


class BatchingQueue:
    """Request accumulator: flushes when full or stale."""

    def __init__(self, max_batch: int = 8, max_wait_s: float = 0.05):
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.pending: list[Request] = []

    def add(self, req: Request):
        self.pending.append(req)

    def ready(self) -> bool:
        if not self.pending:
            return False
        if len(self.pending) >= self.max_batch:
            return True
        return (time.time() - self.pending[0].arrived) >= self.max_wait_s

    def take(self) -> list[Request]:
        batch, self.pending = (self.pending[:self.max_batch],
                               self.pending[self.max_batch:])
        return batch

    @staticmethod
    def pad(batch: list[Request], pad_id: int = 0):
        """Right-align prompts into (B, S_max) + validity mask."""
        s_max = max(r.tokens.shape[0] for r in batch)
        toks = np.full((len(batch), s_max), pad_id, np.int32)
        mask = np.zeros((len(batch), s_max), bool)
        for i, r in enumerate(batch):
            s = r.tokens.shape[0]
            toks[i, s_max - s:] = r.tokens
            mask[i, s_max - s:] = True
        return jnp.asarray(toks), jnp.asarray(mask)
