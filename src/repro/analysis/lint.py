"""Layer-2 contract lint: repo-specific rules over the Python source.

Stdlib ``ast`` only — no jax, no third-party imports — so the CI hygiene
job can run this before (and regardless of) any jax install.  Each rule
is a bug class that has actually recurred in this repo's history:

``IMPACT001``
    Bare ``assert`` on a runtime serving path (``src/repro/serve/`` or
    ``impact/runtime.py``).  ``python -O`` strips asserts, so a guard
    written as one silently vanishes in optimized deployments — the
    ``submit`` shape check was fixed exactly this way in PR 6, yet the
    same pattern re-landed in three more files.  Raise a real exception.

``IMPACT002``
    Direct ``time.time()`` / ``time.monotonic()`` where the engine's
    injectable clock is in scope (the enclosing function takes a
    ``clock`` argument or references ``.clock``, or the enclosing class
    carries one).  A hard-coded wall clock next to an injected one
    breaks frozen-clock tests and skews the latency ledger.

``IMPACT003``
    Energy-bill arithmetic on the per-lane energy arrays
    (``e_clause_lanes`` / ``e_class_lanes``) without an f64 cast before
    summation.  Bills accumulate ~1e-11 J terms over many sweeps; in
    f32 the partial sums quantize and tenant bills drift from the batch
    meter.  The convention (cast via ``np.float64`` first) was enforced
    by nothing until this rule.

``IMPACT004``
    Backend registry conformance: every class handed to
    ``register_backend`` must implement or inherit the full primitive
    contract of the in-file ``Backend`` base (``fused_impact``,
    ``*_metered``, ``*_packed``, ``*_coresident*``, the staged
    compositions) with matching signatures — positional parameter names
    equal, keyword-only names a superset.  A near-miss signature turns
    into a ``TypeError`` at serve time; this catches it at lint time.

``IMPACT005``
    Deprecated shim kwargs (``meter_energy=`` anywhere; ``impl=`` /
    ``mesh=`` / ``meter=`` on ``predict`` / ``infer_step`` /
    ``infer_with_report`` / ``IMPACTEngine`` calls) outside the shim
    modules themselves.  The shims exist so OLD external callers keep
    working; repo code reaching back through them regresses the PR 4
    migration.

Waivers are per-line and auditable: append ``# lint: waive IMPACTnnn``
(optionally with a trailing reason) to the offending line or the line
directly above it.  Waived findings are returned with ``waived=True``
so the driver can count them; they never fail the gate.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re

#: rule id -> one-line description (the README table is generated from
#: the same text).
RULES: dict[str, str] = {
    "IMPACT001": "bare `assert` on a runtime serving path (stripped "
                 "under python -O) — raise a real exception",
    "IMPACT002": "direct time.time()/time.monotonic() where the "
                 "injectable clock is in scope",
    "IMPACT003": "energy-lane arithmetic without an f64 cast before "
                 "summation",
    "IMPACT004": "register_backend class does not conform to the "
                 "Backend primitive contract",
    "IMPACT005": "deprecated per-call shim kwarg outside the shims",
}

#: IMPACT001/002/003 apply on the runtime serving paths only.
RUNTIME_SCOPE_PREFIXES = ("src/repro/serve/",)
RUNTIME_SCOPE_FILES = ("src/repro/impact/runtime.py",)

#: IMPACT005 exempts the modules that DEFINE the deprecation shims
#: (they forward the deprecated kwargs by design).
SHIM_FILES = (
    "src/repro/impact/__init__.py",
    "src/repro/impact/pipeline.py",
    "src/repro/impact/runtime.py",
    "src/repro/serve/impact_engine.py",
)

_WAIVER_RE = re.compile(r"#\s*lint:\s*waive\s+(IMPACT\d{3})\b")

_LANE_NAMES = frozenset({"e_clause_lanes", "e_class_lanes"})
_DEPRECATED_ANYWHERE = frozenset({"meter_energy"})
_DEPRECATED_TARGETED = frozenset({"impl", "mesh", "meter"})
_SHIMMED_CALLEES = frozenset({"predict", "infer_step", "infer_with_report",
                              "IMPACTEngine"})


@dataclasses.dataclass(frozen=True)
class LintFinding:
    rule: str
    path: str
    line: int
    message: str
    waived: bool = False

    def __str__(self) -> str:
        tag = " (waived)" if self.waived else ""
        return f"{self.path}:{self.line}: {self.rule}{tag} {self.message}"


def _norm(path: str) -> str:
    return path.replace("\\", "/")


def in_runtime_scope(path: str) -> bool:
    p = _norm(path)
    return (any(p.startswith(pre) for pre in RUNTIME_SCOPE_PREFIXES)
            or p in RUNTIME_SCOPE_FILES)


def _parse_waivers(text: str) -> dict[int, set[str]]:
    waivers: dict[int, set[str]] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        for m in _WAIVER_RE.finditer(line):
            waivers.setdefault(i, set()).add(m.group(1))
    return waivers


def _callee_name(func: ast.expr) -> str | None:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _walk_scoped(tree: ast.Module):
    """Yield ``(node, enclosing_function, enclosing_class)`` for every
    node, where the enclosures are the nearest FunctionDef / ClassDef."""
    def rec(node, fn, cls):
        for child in ast.iter_child_nodes(node):
            c_fn, c_cls = fn, cls
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                c_fn = child
            elif isinstance(child, ast.ClassDef):
                c_cls, c_fn = child, None
            yield child, c_fn, c_cls
            yield from rec(child, c_fn, c_cls)
    yield from rec(tree, None, None)


# -- IMPACT001 ---------------------------------------------------------------

def _rule_impact001(tree, path):
    if not in_runtime_scope(path):
        return []
    return [LintFinding(
        "IMPACT001", path, node.lineno,
        "bare assert on a serving path — python -O strips it; raise "
        "ValueError/RuntimeError instead")
        for node, _fn, _cls in _walk_scoped(tree)
        if isinstance(node, ast.Assert)]


# -- IMPACT002 ---------------------------------------------------------------

def _is_wall_clock_call(node) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in ("time", "monotonic")
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id == "time")


def _mentions_clock(node) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr == "clock"
               for n in ast.walk(node))


def _fn_has_clock(fn) -> bool:
    a = fn.args
    names = [x.arg for x in a.posonlyargs + a.args + a.kwonlyargs]
    return "clock" in names or _mentions_clock(fn)


def _rule_impact002(tree, path):
    if not in_runtime_scope(path):
        return []
    clocked_classes = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and _mentions_clock(node):
            clocked_classes.add(node)
    findings = []
    for node, fn, cls in _walk_scoped(tree):
        if not _is_wall_clock_call(node) or fn is None:
            continue
        if _fn_has_clock(fn) or (cls is not None and cls in clocked_classes):
            findings.append(LintFinding(
                "IMPACT002", path, node.lineno,
                f"time.{node.func.attr}() bypasses the injectable clock "
                f"in scope here — use the injected clock"))
    return findings


# -- IMPACT003 ---------------------------------------------------------------

def _has_f64(node) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in ("float64", "double"):
            return True
        if isinstance(n, ast.Name) and n.id == "float64":
            return True
        if isinstance(n, ast.Constant) and n.value == "float64":
            return True
    return False


def _lane_attr_refs(node):
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in _LANE_NAMES:
            yield n
        elif isinstance(n, ast.Name) and n.id in _LANE_NAMES:
            yield n


def _is_sum_site(node) -> bool:
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        return True
    if isinstance(node, ast.Call):
        name = _callee_name(node.func)
        return name == "sum"
    return False


def _rule_impact003(tree, path):
    if not in_runtime_scope(path):
        return []
    findings = []
    seen: set[int] = set()
    for fn in (n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))):
        tainted: set[str] = set()
        blessed: set[str] = set()
        for stmt in ast.walk(fn):
            if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and any(True for _ in _lane_attr_refs(stmt.value))):
                tainted.add(stmt.targets[0].id)
                if _has_f64(stmt.value):
                    blessed.add(stmt.targets[0].id)
        dirty_names = tainted - blessed
        for site in ast.walk(fn):
            if not _is_sum_site(site) or site.lineno in seen:
                continue
            direct = any(isinstance(r, ast.Attribute)
                         for r in _lane_attr_refs(site))
            via_name = any(isinstance(n, ast.Name) and n.id in dirty_names
                           for n in ast.walk(site))
            if (direct or via_name) and not _has_f64(site):
                seen.add(site.lineno)
                findings.append(LintFinding(
                    "IMPACT003", path, site.lineno,
                    "energy-lane arithmetic without an f64 cast — bill "
                    "sums must go through np.float64 before accumulation"))
    return findings


# -- IMPACT004 ---------------------------------------------------------------

def _method_defs(cls) -> dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _pos_names(fn) -> list[str]:
    return [a.arg for a in fn.args.posonlyargs + fn.args.args]


def _kwonly_names(fn) -> set[str]:
    return {a.arg for a in fn.args.kwonlyargs}


def _mro_chain(cls, classes):
    """In-file MRO approximation: the class, then each resolvable base
    depth-first.  Returns (chain, fully_resolved)."""
    chain, resolved = [], True
    stack = [cls]
    while stack:
        c = stack.pop(0)
        if c in chain:
            continue
        chain.append(c)
        for b in c.bases:
            if isinstance(b, ast.Name) and b.id in classes:
                stack.append(classes[b.id])
            else:
                resolved = False
    return chain, resolved


def _rule_impact004(tree, path):
    classes = {n.name: n for n in ast.walk(tree)
               if isinstance(n, ast.ClassDef)}
    base = classes.get("Backend")
    if base is None:
        return []
    contract = {name: fn for name, fn in _method_defs(base).items()
                if not name.startswith("_")}
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _callee_name(node.func) == "register_backend"
                and node.args):
            continue
        arg = node.args[0]
        cls_name = None
        if isinstance(arg, ast.Call):
            cls_name = _callee_name(arg.func)
        elif isinstance(arg, ast.Name):
            cls_name = arg.id
        cls = classes.get(cls_name)
        if cls is None or cls is base:
            continue
        chain, resolved = _mro_chain(cls, classes)
        methods: dict[str, ast.FunctionDef] = {}
        for c in chain:
            for name, fn in _method_defs(c).items():
                methods.setdefault(name, fn)
        if resolved and base in chain:
            missing = sorted(set(contract) - set(methods))
        elif resolved:
            # chain never reaches Backend: nothing is inherited.
            missing = sorted(set(contract) - set(methods))
        else:
            missing = []      # unresolvable import-time base: can't prove
        for name in missing:
            findings.append(LintFinding(
                "IMPACT004", path, node.lineno,
                f"registered backend {cls_name!r} is missing primitive "
                f"{name!r} from the Backend contract"))
        # Signature conformance of every in-file override.
        for c in chain:
            if c is base:
                continue
            for name, fn in _method_defs(c).items():
                ref = contract.get(name)
                if ref is None:
                    continue
                if _pos_names(fn) != _pos_names(ref):
                    findings.append(LintFinding(
                        "IMPACT004", path, fn.lineno,
                        f"{c.name}.{name} positional signature "
                        f"{_pos_names(fn)} != Backend contract "
                        f"{_pos_names(ref)}"))
                elif not _kwonly_names(fn) >= _kwonly_names(ref):
                    lost = sorted(_kwonly_names(ref) - _kwonly_names(fn))
                    findings.append(LintFinding(
                        "IMPACT004", path, fn.lineno,
                        f"{c.name}.{name} drops keyword-only params "
                        f"{lost} from the Backend contract"))
    # One finding per (line, message).
    uniq = {(f.line, f.message): f for f in findings}
    return list(uniq.values())


# -- IMPACT005 ---------------------------------------------------------------

def _rule_impact005(tree, path):
    if _norm(path) in SHIM_FILES:
        return []
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = _callee_name(node.func)
        for kw in node.keywords:
            if kw.arg in _DEPRECATED_ANYWHERE:
                findings.append(LintFinding(
                    "IMPACT005", path, node.lineno,
                    f"deprecated shim kwarg {kw.arg}= — encode it in a "
                    f"RuntimeSpec instead"))
            elif (kw.arg in _DEPRECATED_TARGETED
                    and callee in _SHIMMED_CALLEES):
                findings.append(LintFinding(
                    "IMPACT005", path, node.lineno,
                    f"deprecated shim kwarg {kw.arg}= on {callee}() — "
                    f"encode it in a RuntimeSpec instead"))
    return findings


_ALL_RULES = (_rule_impact001, _rule_impact002, _rule_impact003,
              _rule_impact004, _rule_impact005)


# -- driver ------------------------------------------------------------------

def lint_source(text: str, path: str) -> list[LintFinding]:
    """Lint one file's source.  ``path`` must be repo-relative (posix)
    — the rules scope by it.  Waived findings come back with
    ``waived=True``; syntax errors surface as an un-waivable finding."""
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [LintFinding("SYNTAX", path, e.lineno or 0,
                            f"could not parse: {e.msg}")]
    waivers = _parse_waivers(text)
    findings: list[LintFinding] = []
    for rule in _ALL_RULES:
        for f in rule(tree, _norm(path)):
            lines = (f.line, f.line - 1)
            waived = any(f.rule in waivers.get(ln, ()) for ln in lines)
            findings.append(dataclasses.replace(f, waived=waived))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def iter_target_files(root) -> list[pathlib.Path]:
    root = pathlib.Path(root)
    return sorted((root / "src" / "repro").rglob("*.py"))


def lint_tree(root) -> list[LintFinding]:
    """Lint every ``src/repro`` Python file under ``root``."""
    root = pathlib.Path(root)
    findings: list[LintFinding] = []
    for p in iter_target_files(root):
        rel = p.relative_to(root).as_posix()
        findings.extend(lint_source(p.read_text(), rel))
    return findings
