"""Pallas VMEM working-set estimator, derived from the kernels' BlockSpecs.

A TPU core has ~16 MiB of VMEM; a Pallas kernel whose per-grid-step
blocks (double-buffered by the pipeline) plus scratch accumulators
exceed it OOMs at compile time *on the TPU* — which CPU CI, running the
same kernels in interpret mode, can never see.  This module prices the
working set STATICALLY, by mirroring the exact padding/tiling math of
``kernels.backends`` (``_fused_impact_operands`` /
``_fused_impact_packed_operands``) and the BlockSpecs of
``kernels.fused_impact`` / ``kernels.crossbar_mvm``, so a block-shape or
grid-geometry change that blows VMEM fails the IR-audit gate before any
TPU exists to OOM (the static half of the ROADMAP's autotuning item).

The block constants are imported from the kernel modules themselves —
change ``BLOCK_B``/``BLOCK_N`` there and this estimate moves with it.

Estimates are per-core upper bounds: a sharded topology only shrinks
per-device operands, and interpret mode has no VMEM at all, so the
estimate is conservative in both directions that matter.
"""
from __future__ import annotations

import dataclasses

# The kernels package re-exports same-named entry FUNCTIONS
# (kernels.fused_impact is the function, not the module), so bind the
# block constants by module path.
from ..kernels.crossbar_mvm import (BLOCK_B as _MVM_BLOCK_B,
                                    BLOCK_K as _MVM_BLOCK_K,
                                    BLOCK_N as _MVM_BLOCK_N)
from ..kernels.fused_impact import (BLOCK_B as _FUSED_BLOCK_B,
                                    BLOCK_N as _FUSED_BLOCK_N,
                                    METER_LANES as _METER_LANES)

#: ~VMEM per TensorCore on current TPUs (v4/v5e: 16 MiB; v5p: ~32).
DEFAULT_VMEM_BUDGET_BYTES = 16 * 1024 * 1024

#: Pallas pipelines in/out blocks double-buffered (copy next while
#: computing current); scratch accumulators are single-buffered.
PIPELINE_BUFFERS = 2

_F32 = 4
_I32 = 4
_I8 = 1


def _ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class WorkingSet:
    """Per-grid-step VMEM footprint of one kernel variant.

    ``blocks`` are the single-buffered in/out block sizes in bytes
    (the pipeline holds ``PIPELINE_BUFFERS`` copies of each), ``scratch``
    the VMEM scratch accumulators; ``total_bytes`` is the budgeted sum.
    """
    variant: str
    blocks: dict[str, int]
    scratch: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return (PIPELINE_BUFFERS * sum(self.blocks.values())
                + sum(self.scratch.values()))


def fused_working_set(*, R: int, tr: int, n_clause: int, class_rows: int,
                      M: int, metered: bool,
                      block_b: int | None = None,
                      block_n: int | None = None) -> WorkingSet:
    """Working set of the fused IMPACT kernel (unpacked f32 operands),
    mirroring ``PallasBackend._fused_impact_operands`` padding."""
    block_b = block_b or _FUSED_BLOCK_B
    block_n = block_n or _FUSED_BLOCK_N
    N = max(n_clause, class_rows)
    block_n = min(block_n, max(128, _ceil_to(N, 128)))
    tr_pad = max(128, _ceil_to(tr, 128))
    m_pad = _ceil_to(M, 128)
    blocks = {
        "drive": R * block_b * tr_pad * _F32,
        "ccur": R * tr_pad * block_n * _F32,
        "nonempty": block_n * _I8,
        "wcur": block_n * m_pad * _F32,
        "out": block_b * m_pad * _F32,
    }
    scratch = {"acc": block_b * m_pad * _F32}
    if metered:
        blocks["meter_out"] = block_b * _METER_LANES * _F32
        scratch["macc"] = block_b * _METER_LANES * _F32
    return WorkingSet("fused_impact_metered" if metered else "fused_impact",
                      blocks, scratch)


def packed_working_set(*, R: int, tr4: int, n_clause: int, class_rows: int,
                       M: int, metered: bool,
                       block_b: int | None = None,
                       block_n: int | None = None) -> WorkingSet:
    """Working set of the bitplane-packed fused kernel, mirroring
    ``PackedPallasBackend._fused_impact_packed_operands`` padding.
    ``tr4`` is the packed per-shard row count (4 cells/byte)."""
    block_b = block_b or _FUSED_BLOCK_B
    block_n = block_n or _FUSED_BLOCK_N
    N = max(n_clause, class_rows)
    block_n = min(block_n, max(128, _ceil_to(N, 128)))
    tr4_pad = max(128, _ceil_to(tr4, 128))
    m_pad = _ceil_to(M, 128)
    blocks = {
        "drive": R * 4 * block_b * tr4_pad * _F32,
        "pbits": R * tr4_pad * block_n * _I8,
        "levels": 128 * _F32,
        "nonempty": block_n * _I8,
        "wcur": block_n * m_pad * _F32,
        "out": block_b * m_pad * _F32,
    }
    scratch = {"acc": block_b * m_pad * _F32}
    if metered:
        blocks["meter_out"] = block_b * _METER_LANES * _F32
        scratch["macc"] = block_b * _METER_LANES * _F32
    return WorkingSet(
        "fused_impact_packed_metered" if metered else "fused_impact_packed",
        blocks, scratch)


def mvm_working_set(*, k_rows: int, block_b: int | None = None,
                    block_n: int | None = None,
                    block_k: int | None = None) -> WorkingSet:
    """Working set of one staged ``crossbar_mvm`` call over ``k_rows``
    drive rows (the Fig. 14 per-shard unroll runs one such kernel per
    crossbar stage; each call's footprint is independent)."""
    block_b = block_b or _MVM_BLOCK_B
    block_n = block_n or _MVM_BLOCK_N
    block_k = min(block_k or _MVM_BLOCK_K,
                  max(128, _ceil_to(k_rows, 128)))
    blocks = {
        "drive": block_b * block_k * _F32,
        "g": block_k * block_n * _F32,
        "out": block_b * block_n * _F32,
    }
    scratch = {"acc": block_b * block_n * _F32}
    return WorkingSet("crossbar_mvm", blocks, scratch)


def ta_feedback_working_set(*, K: int, n_clause: int, batch2: int,
                            block_k: int | None = None,
                            block_n: int | None = None) -> WorkingSet:
    """Working set of the ``ta_feedback`` training kernel, mirroring
    ``PallasBackend.ta_feedback`` padding.  ``batch2`` is the DOUBLED
    feedback row count (positive + negative target copies, 2B); the
    grid tiles (K, n) while every block streams the full batch2 axis,
    so batch2 — not K or n — is the VMEM lever at serving batch sizes.
    No scratch: each (block_k, block_n) output tile is one matmul
    accumulation, written directly."""
    block_k = min(block_k or 128, max(128, _ceil_to(K, 128)))
    block_n = min(block_n or 128, max(128, _ceil_to(n_clause, 128)))
    b2p = max(128, _ceil_to(batch2, 128))
    blocks = {
        "litT": block_k * b2p * _F32,
        "sel": b2p * block_n * _F32,
        "match": b2p * block_n * _F32,
        "fired2": b2p * block_n * _F32,
        "hi": block_k * block_n * _F32,
        "lo": block_k * block_n * _F32,
        "excl": block_k * block_n * _F32,
        "out": block_k * block_n * _I32,
    }
    return WorkingSet("ta_feedback", blocks, {})


def session_working_set(session, entry: str,
                        batch: int | None = None) -> WorkingSet | None:
    """The VMEM working set of the kernel variant the ``(session,
    entry)`` pair actually lowers to, following the routing of
    ``InferenceSession._scores_expr`` / ``_metered_expr``:

    * reference (oracle) backends run no kernel -> ``None``;
    * co-resident sessions and ``metering="staged"`` entries ride the
      staged ``crossbar_mvm`` compositions -> the larger of the clause /
      class stage calls;
    * ``packing="2bit"`` on the ``pallas-packed`` backend -> the packed
      kernel; on other Pallas backends the session dequantizes outside
      and runs the unpacked kernel;
    * ``metering="fused"`` entries (and everything on the always-metered
      ``pallas-metered`` backend) -> the metered kernel variant;
    * the ``ta_feedback`` training entry -> the feedback-delta kernel
      (``batch`` is its compiled DOUBLED row count, from
      ``compiled_shapes``).
    """
    backend = session.backend
    if getattr(backend, "reference", False):
        return None
    spec = session.spec
    sys_ = session.system
    R, C, tr, tc = sys_.clause_i.shape
    S, sr, M = sys_.class_i.shape
    n_clause = C * tc

    if entry == "ta_feedback":
        return ta_feedback_working_set(K=sys_.n_literals,
                                       n_clause=sys_.n_clauses,
                                       batch2=batch or 128)

    metered_entry = (entry in ("infer_step", "infer_with_report")
                     and spec.metering != "off")
    staged = metered_entry and spec.metering == "staged"
    metered_kernel = ((metered_entry and spec.metering == "fused")
                      or backend.name == "pallas-metered")

    if session.coresident is not None or staged:
        # Staged per-shard unroll: one crossbar_mvm per clause row-shard
        # (tr drive rows) + one per class row-shard (sr drive rows).
        clause = mvm_working_set(k_rows=tr)
        klass = mvm_working_set(k_rows=sr)
        return clause if clause.total_bytes >= klass.total_bytes else klass

    if spec.packing == "2bit" and backend.name == "pallas-packed":
        tr4 = session._packed.bits.shape[2]
        return packed_working_set(R=R, tr4=tr4, n_clause=n_clause,
                                  class_rows=S * sr, M=M,
                                  metered=metered_kernel)
    return fused_working_set(R=R, tr=tr, n_clause=n_clause,
                             class_rows=S * sr, M=M, metered=metered_kernel)
