"""Layer 1: StableHLO audit of compiled session executables.

``InferenceSession`` keeps the lowered StableHLO text of every AOT
executable (``session.ir_text(entry, batch)``); this module walks that
text and proves three datapath invariants *of the artifact XLA will
actually run*, not of the python that generated it:

* **Precision ladder** — the analog datapath is f32 end to end and the
  energy/billing ladder widens to f64 only on the host (numpy, after
  device transfer).  So a session executable must contain NO f64 type
  anywhere (an in-graph f64 means billing math leaked into the
  executable, or a numpy float64 constant got traced in), and no
  f16/bf16 (a sub-f32 meter accumulation silently loses billing
  precision at serving batch sizes).
* **Host isolation** — executables must be pure device programs: no
  ``custom_call`` (the lowering target of ``io_callback`` /
  ``pure_callback`` / ``debug.print``), no infeed/outfeed/send/recv.
  A host callback in the sweep loop would serialize every scheduler
  sweep on the python GIL.
* **VMEM budget** — the Pallas working set priced by ``analysis.vmem``
  must fit ``RuntimeSpec.vmem_budget_bytes`` (default 16 MiB/core).

It also fingerprints each executable (op histogram + operand bytes) so
CI can diff the lowered artifact against a committed baseline: a jax
upgrade or refactor that reroutes a session through a different kernel
variant shows up as a fingerprint drift even when numerics still pass.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Iterable

from . import vmem

# -- findings ---------------------------------------------------------------

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class AuditFinding:
    """One violation in one executable's lowered IR."""
    check: str            # "precision" | "host_io" | "vmem" | "fingerprint"
    severity: str         # one of SEVERITIES
    entry: str            # session entry point ("predict", ...)
    batch: int
    message: str
    line: int | None = None   # 1-based line in the IR text, when line-anchored

    def __str__(self) -> str:
        where = f"{self.entry}@{self.batch}"
        if self.line is not None:
            where += f":{self.line}"
        return f"[{self.check}] {where}: {self.message}"


@dataclasses.dataclass(frozen=True)
class AuditReport:
    """Every finding plus the per-executable evidence the gate records."""
    findings: tuple[AuditFinding, ...]
    fingerprints: dict[str, dict[str, Any]]      # "entry@batch" -> fingerprint
    vmem_bytes: dict[str, int]                   # "entry@batch" -> working set
    vmem_budget_bytes: int

    @property
    def ok(self) -> bool:
        return not any(f.severity == "error" for f in self.findings)

    def to_json(self) -> dict[str, Any]:
        return {
            "ok": self.ok,
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "fingerprints": self.fingerprints,
            "vmem_bytes": self.vmem_bytes,
            "vmem_budget_bytes": self.vmem_budget_bytes,
        }


# -- precision ladder -------------------------------------------------------

# StableHLO glues the dtype token to the dims with 'x'
# (tensor<8x10xf64>) or opens with it (tensor<f64>), so a plain \b
# boundary never fires — allow either an 'x' or a true non-word char
# before the token.  The guard keeps identifiers (my_f64_helper) out.
_F64_RE = re.compile(r"(?:(?<=x)|(?<![0-9a-zA-Z_]))f64\b")
_BF16_RE = re.compile(r"(?:(?<=x)|(?<![0-9a-zA-Z_]))bf16\b")
# f16 but not bf16: the 'b' of xbf16 fails both lookbehinds.
_F16_RE = re.compile(r"(?:(?<=x)|(?<![0-9a-zA-Z_]))f16\b")

_HOST_IO_RE = re.compile(
    r"stablehlo\.(custom_call|infeed|outfeed|send|recv)\b|"
    r"\b(io_callback|pure_callback|python_callback|CustomCall)\b")


def scan_precision(ir_text: str, *, entry: str = "?",
                   batch: int = 0) -> list[AuditFinding]:
    """Flag every IR line carrying an f64 / bf16 / f16 type."""
    findings = []
    for i, line in enumerate(ir_text.splitlines(), start=1):
        if _F64_RE.search(line):
            findings.append(AuditFinding(
                "precision", "error", entry, batch,
                "f64 type in executable — billing/energy widening must "
                "stay host-side (numpy), the device program is f32",
                line=i))
        elif _BF16_RE.search(line):
            findings.append(AuditFinding(
                "precision", "error", entry, batch,
                "bf16 type in executable — sub-f32 meter accumulation "
                "loses billing precision", line=i))
        elif _F16_RE.search(line):
            findings.append(AuditFinding(
                "precision", "error", entry, batch,
                "f16 type in executable — sub-f32 meter accumulation "
                "loses billing precision", line=i))
    return findings


def scan_host_io(ir_text: str, *, entry: str = "?",
                 batch: int = 0) -> list[AuditFinding]:
    """Flag host round-trips: custom_call/callback/infeed/outfeed."""
    findings = []
    for i, line in enumerate(ir_text.splitlines(), start=1):
        m = _HOST_IO_RE.search(line)
        if m:
            findings.append(AuditFinding(
                "host_io", "error", entry, batch,
                f"host round-trip op ({m.group(0)}) in executable — "
                "sweeps must be pure device programs", line=i))
    return findings


# -- fingerprints -----------------------------------------------------------

# Only structural dialect ops count toward the histogram; module
# attributes like mhlo.num_partitions must not (they look like op names
# to a broad regex but are metadata).
_OP_RE = re.compile(r"\b((?:stablehlo|func)\.[a-z_]+)\b")


def fingerprint_text(ir_text: str) -> dict[str, Any]:
    """Histogram of StableHLO ops — a cheap structural hash of the
    lowering.  Two executables with the same fingerprint route through
    the same kernel composition even if constants differ."""
    hist: dict[str, int] = {}
    for m in _OP_RE.finditer(ir_text):
        op = m.group(1)
        hist[op] = hist.get(op, 0) + 1
    return {"ops": dict(sorted(hist.items())), "n_ops": sum(hist.values())}


def diff_fingerprints(baseline: dict[str, Any],
                      current: dict[str, Any]) -> list[str]:
    """Human-readable op-histogram deltas (empty list == match)."""
    deltas = []
    b_ops, c_ops = baseline.get("ops", {}), current.get("ops", {})
    for op in sorted(set(b_ops) | set(c_ops)):
        b, c = b_ops.get(op, 0), c_ops.get(op, 0)
        if b != c:
            deltas.append(f"{op}: {b} -> {c}")
    return deltas


# -- the session-level audit ------------------------------------------------

def _keys(session, entry, batch) -> Iterable[tuple[str, int]]:
    if entry is not None and batch is not None:
        return [(entry, int(batch))]
    keys = session.compiled_shapes(entry)
    if not keys:
        raise ValueError(
            "session has no compiled executables to audit — call "
            "session.warm(batch, entry) (or set capacity/batch_sizes on "
            "the spec) first")
    return keys


def audit_session(session, entry: str | None = None,
                  batch: int | None = None, *,
                  baselines: dict[str, dict[str, Any]] | None = None,
                  ) -> AuditReport:
    """Audit the session's compiled executables (all of them by default,
    or one ``(entry, batch)`` pair).

    ``baselines`` maps ``"entry@batch"`` to a committed fingerprint; a
    mismatch is a *warning* (drift is evidence, not automatically a
    bug — ``check_static.py --update-baselines`` re-records it).
    """
    findings: list[AuditFinding] = []
    fingerprints: dict[str, dict[str, Any]] = {}
    vmem_bytes: dict[str, int] = {}
    budget = (session.spec.vmem_budget_bytes
              or vmem.DEFAULT_VMEM_BUDGET_BYTES)

    for e, b in _keys(session, entry, batch):
        ir = session.ir_text(e, b)
        tag = f"{e}@{b}"
        findings += scan_precision(ir, entry=e, batch=b)
        findings += scan_host_io(ir, entry=e, batch=b)
        fingerprints[tag] = fingerprint_text(ir)

        ws = vmem.session_working_set(session, e, b)
        if ws is not None:
            vmem_bytes[tag] = ws.total_bytes
            if ws.total_bytes > budget:
                findings.append(AuditFinding(
                    "vmem", "error", e, b,
                    f"{ws.variant} working set {ws.total_bytes} B exceeds "
                    f"the VMEM budget {budget} B "
                    f"(blocks x{vmem.PIPELINE_BUFFERS} + scratch)"))

        if baselines is not None:
            base = baselines.get(tag)
            if base is None:
                findings.append(AuditFinding(
                    "fingerprint", "warning", e, b,
                    "no committed fingerprint baseline for this "
                    "executable — run check_static.py --update-baselines"))
            else:
                deltas = diff_fingerprints(base, fingerprints[tag])
                if deltas:
                    findings.append(AuditFinding(
                        "fingerprint", "warning", e, b,
                        "lowered-op histogram drifted from baseline: "
                        + "; ".join(deltas[:8])
                        + ("; ..." if len(deltas) > 8 else "")))

    return AuditReport(findings=tuple(findings), fingerprints=fingerprints,
                       vmem_bytes=vmem_bytes, vmem_budget_bytes=budget)


def audit_ir_text(ir_text: str, *, entry: str = "hlo",
                  batch: int = 0) -> list[AuditFinding]:
    """Audit a bare StableHLO dump (no session): precision + host IO.
    This is the ``check_static.py --hlo FILE`` path and what the tests
    feed known-bad toy modules through."""
    return (scan_precision(ir_text, entry=entry, batch=batch)
            + scan_host_io(ir_text, entry=entry, batch=batch))
