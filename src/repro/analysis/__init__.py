"""Static-analysis subsystem: prove the datapath's invariants before it
runs.

Two layers, both driven by ``benchmarks/check_static.py`` and run in CI:

* **Layer 1 — IR audit** (``analysis.ir_audit`` + ``analysis.vmem``):
  walks the lowered StableHLO of every AOT ``InferenceSession``
  executable and statically verifies the precision ladder (no f64
  widening, no sub-f32 meter accumulation), the absence of host
  callbacks / infeed / outfeed, a Pallas VMEM working-set estimate
  against the spec's budget, and executable fingerprints against
  committed baselines.  Entry point: ``InferenceSession.audit()``.

* **Layer 2 — contract lint** (``analysis.lint``, stdlib ``ast`` only —
  importable without jax): repo-specific rules ``IMPACT001``-``005``
  distilled from recurring bug classes, with per-line waiver comments
  (``# lint: waive IMPACTnnn -- reason``).

``lint`` deliberately has no jax dependency so the CI hygiene job can
run it before any jax install; importing THIS package pulls ``ir_audit``
lazily for the same reason.
"""
from __future__ import annotations

from . import lint  # stdlib-only, always safe

__all__ = ["lint", "ir_audit", "vmem"]


def __getattr__(name):
    # ir_audit / vmem import jax; load them only when actually used so
    # ``repro.analysis.lint`` works in jax-free environments (the CI
    # hygiene job).
    if name in ("ir_audit", "vmem"):
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
