"""Chunked linear attention with per-channel decay — shared SSM engine.

Both assigned recurrent families reduce to the same state-space recurrence

    S_t = diag(w_t) S_{t-1} + k_t (x) v_t          S in R^{Dk x Dv}
    o_t = q_t . S_{t-1} + bonus                    (RWKV6: strict + u-bonus)
    o_t = q_t . S_t                                (Mamba2: inclusive, w scalar)

TPU adaptation: instead of a length-S sequential scan (VPU-bound outer
products), sequences are processed in chunks of 16: within a chunk the
pairwise decay ratios become an (c, c) masked matmul on the MXU, and only
one (Dk, Dv) state hand-off per chunk is sequential.  Decay products are
evaluated as ``exp(L_t - L_i)`` around a mid-chunk normalizer in f32 —
with ``log w`` clamped to [-4, 0] and c = 16 every factor stays finite
(|exponent| <= 32 per factor, products of valid pairs <= 1).

``chunked_la`` (training/prefill) and ``la_step`` (single-token decode) are
the only two entry points; RWKV6 uses per-channel decay + u-bonus, Mamba2
uses a per-head scalar decay broadcast over channels + inclusive diagonal.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array

LOG_W_MIN = -4.0    # decay clamp; see module docstring for the numerics


def chunked_la(q: Array, k: Array, v: Array, log_w: Array, *,
               u: Array | None = None, inclusive: bool = False,
               chunk: int = 16,
               initial_state: Array | None = None) -> tuple[Array, Array]:
    """q, k, log_w (B, S, H, Dk); v (B, S, H, Dv); u (H, Dk) or None.

    Returns (o (B, S, H, Dv), final_state (B, H, Dk, Dv)).
    S % chunk == 0 required (configs pick chunk sizes that divide).
    """
    B, S, H, Dk = q.shape
    Dv = v.shape[-1]
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        # Zero-pad the tail: k=v=0 adds nothing to the state, log_w=0
        # (w=1) leaves it untouched; padded q rows are sliced off below.
        pz = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        o, s_final = chunked_la(pz(q), pz(k), pz(v), pz(log_w), u=u,
                                inclusive=inclusive, chunk=c,
                                initial_state=initial_state)
        return o[:, :S], s_final
    nc = S // c

    def resh(a):
        return (a.reshape(B, nc, c, H, a.shape[-1])
                 .transpose(1, 0, 3, 2, 4).astype(jnp.float32))

    qc, kc, vc, lw = resh(q), resh(k), resh(v), resh(log_w)
    lw = jnp.clip(lw, LOG_W_MIN, 0.0)
    l_inc = jnp.cumsum(lw, axis=-2)                     # (nc,B,H,c,Dk)
    l_exc = l_inc - lw
    l_last = l_inc[..., -1:, :]                         # (nc,B,H,1,Dk)
    l_q = l_inc if inclusive else l_exc
    mid = l_inc[..., c // 2, :][..., None, :]           # normalizer

    q_state = qc * jnp.exp(l_q)                         # vs incoming state
    q_n = qc * jnp.exp(l_q - mid)
    k_n = kc * jnp.exp(mid - l_inc)
    k_state = kc * jnp.exp(l_last - l_inc)              # into outgoing state

    att = jnp.einsum("nbhtd,nbhsd->nbhts", q_n, k_n)    # (nc,B,H,c,c)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    mask = (t_idx >= s_idx) if inclusive else (t_idx > s_idx)
    att = jnp.where(mask, att, 0.0)
    o_intra = jnp.einsum("nbhts,nbhsv->nbhtv", att, vc)

    if u is not None:
        diag = jnp.einsum("nbhtd,nbhtd->nbht",
                          qc * u.astype(jnp.float32)[None, None, :, None, :],
                          kc)
        o_intra = o_intra + diag[..., None] * vc

    if initial_state is None:
        s0 = jnp.zeros((B, H, Dk, Dv), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_step(s, xs):
        q_st, k_st, v_ch, decay = xs
        o_inter = jnp.einsum("bhtd,bhdv->bhtv", q_st, s)
        s_new = (s * jnp.exp(decay[..., 0, :])[..., None]
                 + jnp.einsum("bhtd,bhtv->bhdv", k_st, v_ch))
        return s_new, o_inter

    s_final, o_inter = jax.lax.scan(chunk_step, s0,
                                    (q_state, k_state, vc, l_last))
    o = o_intra + o_inter                               # (nc,B,H,c,Dv)
    o = o.transpose(1, 0, 3, 2, 4).reshape(B, S, H, Dv)
    return o.astype(q.dtype), s_final


def la_step(state: Array, q: Array, k: Array, v: Array, log_w: Array, *,
            u: Array | None = None,
            inclusive: bool = False) -> tuple[Array, Array]:
    """Single-token recurrence.  state (B, H, Dk, Dv);
    q, k, log_w (B, H, Dk); v (B, H, Dv).  Returns (o (B,H,Dv), new state).
    """
    s = state.astype(jnp.float32)
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    w = jnp.exp(jnp.clip(log_w.astype(jnp.float32), LOG_W_MIN, 0.0))
    kv = kf[..., :, None] * vf[..., None, :]            # (B,H,Dk,Dv)
    if inclusive:
        s_new = s * w[..., None] + kv
        o = jnp.einsum("bhd,bhdv->bhv", qf, s_new)
    else:
        bonus = kv * u.astype(jnp.float32)[None, :, :, None]
        o = jnp.einsum("bhd,bhdv->bhv", qf, s + bonus)
        s_new = s * w[..., None] + kv
    return o.astype(q.dtype), s_new
