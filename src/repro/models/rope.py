"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE splits the rotary half-dim into (temporal, height, width) sections,
each rotated by its own position stream; plain text positions set all three
streams equal, recovering standard RoPE exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rope_freqs(head_dim: int, theta: float) -> Array:
    """(head_dim//2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_angles(positions: Array, head_dim: int, theta: float) -> Array:
    """positions (..., S) int -> angles (..., S, head_dim//2) f32."""
    inv = rope_freqs(head_dim, theta)
    return positions.astype(jnp.float32)[..., None] * inv


def mrope_angles(positions: Array, head_dim: int, theta: float,
                 sections: tuple[int, int, int]) -> Array:
    """positions (3, B, S) -> angles (B, S, head_dim//2).

    ``sections`` are half-dim section sizes (t, h, w); sum == head_dim//2.
    """
    assert positions.shape[0] == 3
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    inv = rope_freqs(head_dim, theta)                       # (half,)
    ang = positions.astype(jnp.float32)[..., None] * inv    # (3, B, S, half)
    section_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections),
        total_repeat_length=head_dim // 2)                  # (half,)
    pick = jax.nn.one_hot(section_id, 3, dtype=jnp.float32)  # (half, 3)
    return jnp.einsum("tbsh,ht->bsh", ang, pick)


def apply_rope(x: Array, angles: Array) -> Array:
    """x (B, S, H, D) with D even; angles (B, S, D//2) -> rotated x.

    Uses the split-half convention (Llama/NeoX style).
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)   # (B, S, 1, half)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)
