"""Attention: GQA (chunked flash-style) and DeepSeek MLA, train + decode.

Memory discipline: full (S, S) score matrices are never materialized.
Training/prefill attention is a scan over query chunks with an inner
online-softmax scan over key chunks (the flash-attention recurrence in pure
XLA), so peak logits memory is (B, H, cq, ck) regardless of sequence length
— this is what lets prefill_32k lower within HBM.

MLA decode uses the "absorbed" formulation: the per-head up-projections are
folded into the query/output so scores are taken directly against the
(B, S, r) compressed KV cache — the cache stays rank-compressed end to end.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .. import compat
from .base import P, ShardCtx, dense, rms_norm
from .config import ModelConfig
from .rope import apply_rope, mrope_angles, rope_angles

Array = jax.Array


# ---------------------------------------------------------------------------
# Parameter declarations
# ---------------------------------------------------------------------------

def decls_gqa(cfg: ModelConfig) -> dict:
    d, hq, hkv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                      cfg.resolved_head_dim)
    decls = {
        "wq": P((d, hq, hd), ("embed", "heads", None)),
        "wk": P((d, hkv, hd), ("embed", "kv", None)),
        "wv": P((d, hkv, hd), ("embed", "kv", None)),
        "wo": P((hq, hd, d), ("heads", None, "embed")),
    }
    if cfg.qk_norm:
        decls["q_gamma"] = P((hd,), (None,), init="zeros")
        decls["k_gamma"] = P((hd,), (None,), init="zeros")
    return decls


def decls_mla(cfg: ModelConfig) -> dict:
    assert cfg.mla is not None
    d, hq, m = cfg.d_model, cfg.n_heads, cfg.mla
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": P((d, hq, qk), ("embed", "heads", None)),
        "w_dkv": P((d, m.kv_lora_rank), ("embed", None)),
        "w_kr": P((d, m.qk_rope_head_dim), ("embed", None)),
        "kv_norm": P((m.kv_lora_rank,), (None,), init="zeros"),
        "w_uk": P((m.kv_lora_rank, hq, m.qk_nope_head_dim),
                  (None, "heads", None)),
        "w_uv": P((m.kv_lora_rank, hq, m.v_head_dim),
                  (None, "heads", None)),
        "wo": P((hq, m.v_head_dim, d), ("heads", None, "embed")),
    }


# ---------------------------------------------------------------------------
# Chunked causal attention (flash-style online softmax in XLA)
# ---------------------------------------------------------------------------

import functools

from .base import NULL_CTX


def chunked_attention(q: Array, k: Array, v: Array, *, scale: float,
                      q_chunk: int, k_chunk: int, causal: bool = True,
                      q_offset: int = 0, ctx: ShardCtx = NULL_CTX) -> Array:
    """q (B, Sq, H, D), k/v (B, Sk, H, Dk/Dv) -> (B, Sq, H, Dv).

    Flash-attention recurrence in pure XLA: scan over query chunks with an
    inner online-softmax scan over key chunks; peak logits memory is
    (B, H, cq, ck) regardless of sequence length.  The whole computation is
    a checkpoint (backward recomputes chunk internals from q/k/v).

    Callers pre-expand GQA KV heads to H == Hq: a SINGLE flat head axis is
    the only layout GSPMD shards 16-ways (perf iteration 2: the (Hkv, G)
    split layout silently replicated every chunk across the model axis —
    1.37 TB/step of all-gathers on deepseek train_4k).  Every loop-carried
    tensor is sharding-constrained so the annotation survives remat.

    When the (flattened) head count does NOT divide the model axis
    (starcoder2's 24, qwen2-vl's 12 on a 16-wide axis), head-sharded TP is
    impossible and attention would run fully replicated (16x the compute).
    Fallback: CONTEXT PARALLELISM over query chunks (perf iteration 6) —
    the q-chunk grid is sharded over the model axis and all chunks advance
    through the k-scan together (q chunks are independent), so attention
    compute scales with the full mesh again at the cost of replicating
    K/V (already needed) and a (nq/16, B, H, cq, ck) logits transient.

    Pads ragged sequence lengths up to the chunk grid; padded key rows sit
    beyond every real query position, so the causal mask kills them.
    """
    Sq, Sk = q.shape[1], k.shape[1]
    H = q.shape[2]
    model_size = ctx.mesh.shape.get("model", 1) if ctx.mesh else 1
    cp_mode = (model_size > 1 and H % model_size != 0
               and Sq >= 2 * model_size)
    if cp_mode:
        # pick a q_chunk that makes the chunk-grid divisible by the axis
        q_chunk = min(q_chunk, max(Sq // model_size, 1))
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    pad_q = (-Sq) % q_chunk
    pad_k = (-Sk) % k_chunk

    def pad1(x, p):
        return jnp.pad(x, ((0, 0), (0, p)) + ((0, 0),) * (x.ndim - 2))

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def attn(q: Array, k: Array, v: Array) -> Array:
        B, Sqp, H, D = q.shape
        Skp = k.shape[1]
        Dv = v.shape[-1]
        nq, nk = Sqp // q_chunk, Skp // k_chunk
        c_head = lambda x: ctx.constrain(x, None, "batch", None, "heads",
                                         None)
        qg = c_head(q.reshape(B, nq, q_chunk, H, D)
                     .transpose(1, 0, 2, 3, 4).astype(jnp.bfloat16))
        kg = c_head(k.reshape(B, nk, k_chunk, H, D)
                     .transpose(1, 0, 2, 3, 4).astype(jnp.bfloat16))
        vg = c_head(v.reshape(B, nk, k_chunk, H, Dv)
                     .transpose(1, 0, 2, 3, 4).astype(jnp.bfloat16))
        if cp_mode and nq % model_size == 0:
            return _attn_context_parallel(qg, kg, vg, nq, nk, B, H, D, Dv)

        def q_step(_, qi):
            qc, q_idx = qi                               # (B,cq,H,D)
            qc = ctx.constrain(qc, "batch", None, "heads", None)

            def k_step(carry, ki):
                m, l, acc = carry
                kc, vc, k_idx = ki
                kc = ctx.constrain(kc, "batch", None, "heads", None)
                logits = jnp.einsum(
                    "bqhd,bkhd->bhqk", qc, kc,
                    preferred_element_type=jnp.float32) * scale
                logits = ctx.constrain(logits, "batch", "heads", None,
                                       None)
                if causal:
                    qpos = (q_offset + q_idx * q_chunk
                            + jax.lax.broadcasted_iota(
                                jnp.int32, (q_chunk, k_chunk), 0))
                    kpos = (k_idx * k_chunk
                            + jax.lax.broadcasted_iota(
                                jnp.int32, (q_chunk, k_chunk), 1))
                    logits = jnp.where(qpos >= kpos, logits, -jnp.inf)
                m_new = jnp.maximum(m, logits.max(axis=-1))
                # Guard fully-masked rows (m_new == -inf) against NaN.
                m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
                p = jnp.exp(logits - m_safe[..., None])
                corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe,
                                         -jnp.inf))
                l_new = l * corr + p.sum(axis=-1)
                acc_new = (acc * corr[..., None]
                           + jnp.einsum("bhqk,bkhd->bhqd",
                                        p.astype(jnp.bfloat16), vc,
                                        preferred_element_type=jnp.float32))
                acc_new = ctx.constrain(acc_new, "batch", "heads", None,
                                        None)
                return (m_new, l_new, acc_new), None

            shape = (B, H, q_chunk)
            init = (jnp.full(shape, -jnp.inf, jnp.float32),
                    jnp.zeros(shape, jnp.float32),
                    ctx.constrain(jnp.zeros(shape + (Dv,), jnp.float32),
                                  "batch", "heads", None, None))
            (m, l, acc), _ = jax.lax.scan(
                k_step, init, (kg, vg, jnp.arange(nk)))
            out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,H,cq,Dv)
            return None, out.transpose(0, 2, 1, 3)        # (B,cq,H,Dv)

        _, out = jax.lax.scan(q_step, None, (qg, jnp.arange(nq)))
        # out (nq, B, cq, H, Dv) -> (B, Sqp, H, Dv)
        out = out.transpose(1, 0, 2, 3, 4).reshape(B, Sqp, H, Dv)
        return out.astype(q.dtype)

    def _attn_context_parallel(qg, kg, vg, nq, nk, B, H, D, Dv):
        """All q chunks advance together; the nq grid is model-sharded
        (and the batch dim keeps its data sharding)."""
        c_cp = lambda x: ctx.constrain(
            x, *(("seq", "batch") + (None,) * (x.ndim - 2)))
        qg = c_cp(qg)                                     # (nq,B,cq,H,D)

        def k_step(carry, ki):
            m, l, acc = carry
            kc, vc, k_idx = ki
            logits = jnp.einsum(
                "nbqhd,bkhd->nbhqk", qg, kc,
                preferred_element_type=jnp.float32) * scale
            logits = c_cp(logits)
            if causal:
                qpos = (q_offset
                        + jax.lax.broadcasted_iota(
                            jnp.int32, (nq, q_chunk, k_chunk), 0) * q_chunk
                        + jax.lax.broadcasted_iota(
                            jnp.int32, (nq, q_chunk, k_chunk), 1))
                kpos = (k_idx * k_chunk
                        + jax.lax.broadcasted_iota(
                            jnp.int32, (nq, q_chunk, k_chunk), 2))
                mask = (qpos >= kpos)[:, None, None, :, :]
                logits = jnp.where(mask, logits, -jnp.inf)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(logits - m_safe[..., None])
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            l_new = l * corr + p.sum(axis=-1)
            acc_new = (acc * corr[..., None]
                       + jnp.einsum("nbhqk,bkhd->nbhqd",
                                    p.astype(jnp.bfloat16), vc,
                                    preferred_element_type=jnp.float32))
            return (m_new, c_cp(l_new), c_cp(acc_new)), None

        shape = (nq, B, H, q_chunk)
        init = (jnp.full(shape, -jnp.inf, jnp.float32),
                jnp.zeros(shape, jnp.float32),
                c_cp(jnp.zeros(shape + (Dv,), jnp.float32)))
        (m, l, acc), _ = jax.lax.scan(k_step, init,
                                      (kg, vg, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (nq,B,H,cq,Dv)
        out = out.transpose(1, 0, 3, 2, 4).reshape(B, nq * q_chunk, H, Dv)
        return out.astype(qg.dtype)

    out = attn(pad1(q, pad_q), pad1(k, pad_k), pad1(v, pad_k))
    return out[:, :Sq]


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     cache_len: Array, *, scale: float,
                     ctx: ShardCtx = None) -> Array:
    """One-token attention against a KV cache.

    q (B, 1, Hq, D); caches (B, Smax, Hkv, D); cache_len () or (B,) —
    number of valid cache entries INCLUDING the current token.

    When the KV heads cannot shard over the model axis but the head_dim
    can (llama/qwen3/grok GQA on a 16-wide axis), the cache is hd-sharded
    and GSPMD's dot handling degrades to replicate-then-repartition of
    every per-step chunk (the "involuntary full rematerialization"
    warning; ~60 GiB/step on llama3 decode_32k).  The shard_map path makes
    the math explicit: partial logits over local head_dim slices + one
    psum of (B, H, S) — perf iteration 5.
    """
    B, _, Hq, D = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv

    mesh = ctx.mesh if ctx is not None else None
    model_size = mesh.shape.get("model", 1) if mesh is not None else 1
    use_shard_map = (mesh is not None and model_size > 1
                     and Hkv % model_size != 0 and D % model_size == 0)

    def _attn(qg, kc, vc, length, axis=None):
        contract = (jnp.einsum("bhgd,bkhd->bhgk", qg, kc,
                               preferred_element_type=jnp.float32) * scale)
        if axis is not None:
            contract = jax.lax.psum(contract, axis)
        pos = jax.lax.broadcasted_iota(jnp.int32, (qg.shape[0], Smax), 1)
        valid = pos < jnp.reshape(length, (-1, 1))
        logits = jnp.where(valid[:, None, None, :], contract, -jnp.inf)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhgk,bkhd->bhgd", p.astype(jnp.bfloat16), vc,
                          preferred_element_type=jnp.float32)

    qg = q.reshape(B, Hkv, G, D).astype(jnp.bfloat16)
    if use_shard_map:
        from jax.sharding import PartitionSpec as PS
        dp = tuple(n for n in ("pod", "data") if n in mesh.shape)
        dp_size = 1
        for n in dp:
            dp_size *= mesh.shape[n]
        bspec = dp if (dp and B % dp_size == 0) else None
        out = compat.shard_map(
            lambda qq, kk, vv, ln: _attn(qq, kk, vv, ln, axis="model"),
            mesh=mesh,
            in_specs=(PS(bspec, None, None, "model"),
                      PS(bspec, None, None, "model"),
                      PS(bspec, None, None, "model"),
                      PS(bspec)),
            out_specs=PS(bspec, None, None, "model"),
            check_vma=False,
        )(qg, k_cache.astype(jnp.bfloat16), v_cache.astype(jnp.bfloat16),
          jnp.broadcast_to(jnp.reshape(cache_len, (-1,)), (B,)))
    else:
        out = _attn(qg, k_cache.astype(jnp.bfloat16),
                    v_cache.astype(jnp.bfloat16), cache_len)
    return out.reshape(B, 1, Hq, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def _angles(cfg: ModelConfig, positions: Array, head_dim: int) -> Array:
    if cfg.rope_style == "mrope":
        return mrope_angles(positions, head_dim, cfg.rope_theta,
                            cfg.mrope_sections)
    return rope_angles(positions, head_dim, cfg.rope_theta)


def _pad_seq(x: Array, max_len: int) -> Array:
    pad = max_len - x.shape[1]
    if pad <= 0:
        return x[:, :max_len]
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, pad)
    return jnp.pad(x, widths)


def gqa_forward(p: dict, x: Array, positions: Array, cfg: ModelConfig,
                ctx: ShardCtx, *, cache: dict | None = None,
                fill_len: int | None = None) -> tuple:
    """x (B, S, d) -> (out (B, S, d), updated cache or None).

    ``positions`` is (B, S) int32, or (3, B, S) for M-RoPE.
    With ``cache`` set, S must be 1 (decode) and the cache dict holds
    {"k": (B, Smax, Hkv, D), "v": ..., "len": (B,)} — "len" counts tokens
    already in the cache BEFORE this call.  With ``fill_len`` set (prefill),
    the full-sequence K/V are padded to that length and returned as a fresh
    cache.
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    scale = 1.0 / math.sqrt(hd)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    q = ctx.constrain(q, "batch", None, "heads", None)
    k = ctx.constrain(k, "batch", None, "kv", None)
    v = ctx.constrain(v, "batch", None, "kv", None)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_gamma"])
        k = rms_norm(k, p["k_gamma"])

    if cfg.rope_style != "none":
        ang = _angles(cfg, positions, hd)
        q = apply_rope(q, ang)
        k = apply_rope(k, ang)

    if cache is None:
        g = cfg.n_heads // cfg.n_kv_heads
        k_full = jnp.repeat(k, g, axis=2) if g > 1 else k
        v_full = jnp.repeat(v, g, axis=2) if g > 1 else v
        out = chunked_attention(q, k_full, v_full, scale=scale,
                                q_chunk=min(cfg.attn_chunk_q, S),
                                k_chunk=min(cfg.attn_chunk_k, S), ctx=ctx)
        new_cache = None
        if fill_len is not None:
            new_cache = dict(
                k=_pad_seq(k.astype(jnp.bfloat16), fill_len),
                v=_pad_seq(v.astype(jnp.bfloat16), fill_len),
                len=jnp.full((B,), S, jnp.int32))
    else:
        idx = cache["len"]                                # (B,) int32
        k_cache = jax.vmap(
            lambda c, upd, i: jax.lax.dynamic_update_slice(c, upd, (i, 0, 0))
        )(cache["k"], k.astype(cache["k"].dtype), idx)
        v_cache = jax.vmap(
            lambda c, upd, i: jax.lax.dynamic_update_slice(c, upd, (i, 0, 0))
        )(cache["v"], v.astype(cache["v"].dtype), idx)
        out = decode_attention(q, k_cache, v_cache, idx + 1, scale=scale,
                               ctx=ctx)
        new_cache = dict(k=k_cache, v=v_cache, len=idx + 1)

    out = ctx.constrain(out, "batch", None, "heads", None)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return ctx.constrain(out, "batch", "seq", None), new_cache


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_forward(p: dict, x: Array, positions: Array, cfg: ModelConfig,
                ctx: ShardCtx, *, cache: dict | None = None,
                fill_len: int | None = None) -> tuple:
    """Multi-head latent attention; cache holds the COMPRESSED kv stream:
    {"ckv": (B, Smax, r), "kr": (B, Smax, rope_dim), "len": (B,)}."""
    m = cfg.mla
    B, S, _ = x.shape
    hq = cfg.n_heads
    nope, rdim = m.qk_nope_head_dim, m.qk_rope_head_dim
    scale = 1.0 / math.sqrt(nope + rdim)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q = ctx.constrain(q, "batch", None, "heads", None)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    ckv = rms_norm(dense(x, p["w_dkv"]), p["kv_norm"])    # (B, S, r)
    kr = dense(x, p["w_kr"])                              # (B, S, rdim)

    ang = rope_angles(positions, rdim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, ang)
    kr = apply_rope(kr[:, :, None, :], ang)[:, :, 0, :]   # single shared head

    if cache is None:
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"].astype(x.dtype))
        v = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"].astype(x.dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                      (B, S, hq, rdim))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(qf, k, v, scale=scale,
                                q_chunk=min(cfg.attn_chunk_q, S),
                                k_chunk=min(cfg.attn_chunk_k, S), ctx=ctx)
        new_cache = None
        if fill_len is not None:
            new_cache = dict(
                ckv=_pad_seq(ckv.astype(jnp.bfloat16), fill_len),
                kr=_pad_seq(kr.astype(jnp.bfloat16), fill_len),
                len=jnp.full((B,), S, jnp.int32))
    else:
        # Absorbed decode: fold w_uk into q, w_uv into the output.
        idx = cache["len"]
        ckv_cache = jax.vmap(
            lambda c, upd, i: jax.lax.dynamic_update_slice(c, upd, (i, 0))
        )(cache["ckv"], ckv.astype(cache["ckv"].dtype), idx)
        kr_cache = jax.vmap(
            lambda c, upd, i: jax.lax.dynamic_update_slice(c, upd, (i, 0))
        )(cache["kr"], kr.astype(cache["kr"].dtype), idx)
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope,
                           p["w_uk"].astype(x.dtype))     # (B,1,H,r)
        logits = (jnp.einsum("bshr,btr->bhst", q_abs,
                             ckv_cache.astype(x.dtype),
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshk,btk->bhst", q_rope,
                               kr_cache.astype(x.dtype),
                               preferred_element_type=jnp.float32)) * scale
        Smax = ckv_cache.shape[1]
        pos = jax.lax.broadcasted_iota(jnp.int32, (B, Smax), 1)
        valid = pos < (idx + 1)[:, None]
        logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
        pr = jax.nn.softmax(logits, axis=-1)
        o_r = jnp.einsum("bhst,btr->bshr", pr.astype(x.dtype),
                         ckv_cache.astype(x.dtype))       # (B,1,H,r)
        out = jnp.einsum("bshr,rhk->bshk", o_r, p["w_uv"].astype(x.dtype))
        new_cache = dict(ckv=ckv_cache, kr=kr_cache, len=idx + 1)

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return ctx.constrain(out, "batch", "seq", None), new_cache


def attn_decls(cfg: ModelConfig) -> dict:
    return decls_mla(cfg) if cfg.mla is not None else decls_gqa(cfg)


def attn_forward(p: dict, x: Array, positions: Array, cfg: ModelConfig,
                 ctx: ShardCtx, *, cache: dict | None = None,
                 fill_len: int | None = None) -> tuple:
    fn = mla_forward if cfg.mla is not None else gqa_forward
    return fn(p, x, positions, cfg, ctx, cache=cache, fill_len=fill_len)


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16) -> dict:
    """Abstract per-layer cache structure (shapes only via eval_shape)."""
    if cfg.mla is not None:
        m = cfg.mla
        return dict(
            ckv=jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            kr=jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
            len=jnp.zeros((batch,), jnp.int32))
    hd = cfg.resolved_head_dim
    return dict(
        k=jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        v=jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        len=jnp.zeros((batch,), jnp.int32))
