"""Model substrate: declarative parameter trees + sharding-aware layers.

Every model in this zoo declares its parameters as a nested dict of ``P``
leaves (shape, logical axes, init).  From one declaration tree we derive:

* ``init_tree``      — materialized parameters (smoke tests, real training);
* ``abstract_tree``  — ``ShapeDtypeStruct`` stand-ins (the multi-pod dry-run
  lowers against these; nothing is ever allocated);
* ``axes_tree``      — logical-axis tuples consumed by ``sharding.rules`` to
  build ``NamedSharding``s per mesh.

Logical axes used across the zoo (resolution to mesh axes happens in
``repro.sharding``):

  "batch"   data-parallel batch            -> ("pod", "data")
  "vocab"   embedding/output vocab         -> "model"
  "embed"   d_model                        -> replicated (or "data" for ZeRO-3)
  "heads"   attention heads                -> "model" (if divisible)
  "kv"      KV heads                       -> "model" (if divisible)
  "mlp"     feed-forward hidden            -> "model"
  "experts" MoE expert index               -> "model" (expert parallelism)
  "layers"  scan-stacked layer index       -> never sharded
  "seq"     sequence (activations only)    -> "model" under sequence parallelism
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class P:
    """Declaration of one parameter tensor."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]      # logical axis names, len == ndim
    dtype: Any = jnp.float32
    init: str = "normal"              # normal | zeros | ones | small
    scale: float | None = None        # stddev override for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_p(x) -> bool:
    return isinstance(x, P)


def _leaves_with_path(tree: PyTree):
    return jax.tree_util.tree_flatten_with_path(tree, is_leaf=_is_p)


def init_tree(decls: PyTree, key: Array, dtype=None) -> PyTree:
    """Materialize parameters; per-leaf keys derived from the tree path
    via a STABLE hash (python's ``hash`` is salted per process, which
    would make inits irreproducible across runs)."""
    import zlib
    flat, treedef = _leaves_with_path(decls)

    def make(path, p: P) -> Array:
        k = key
        for part in str(jax.tree_util.keystr(path)).split("'"):
            if part and part not in ("[", "]", "[']", "']["):
                k = jax.random.fold_in(k, zlib.crc32(part.encode()))
        dt = dtype or p.dtype
        if p.init == "zeros":
            return jnp.zeros(p.shape, dt)
        if p.init == "ones":
            return jnp.ones(p.shape, dt)
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        std = p.scale if p.scale is not None else 1.0 / math.sqrt(fan_in)
        if p.init == "small":
            std = 0.02
        return (std * jax.random.normal(k, p.shape, jnp.float32)).astype(dt)

    leaves = [make(path, p) for path, p in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_tree(decls: PyTree, dtype=None) -> PyTree:
    """ShapeDtypeStruct stand-ins — no allocation (dry-run path)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype or p.dtype),
        decls, is_leaf=_is_p)


def axes_tree(decls: PyTree) -> PyTree:
    """The logical-axis tree, same structure as the parameters."""
    return jax.tree.map(lambda p: p.axes, decls, is_leaf=_is_p)


def count_params(decls: PyTree) -> int:
    flat, _ = _leaves_with_path(decls)
    return sum(math.prod(p.shape) for _, p in flat)


# ---------------------------------------------------------------------------
# Sharding context threaded through model code
# ---------------------------------------------------------------------------

class ShardCtx:
    """Resolves logical axes -> PartitionSpec and applies constraints.

    ``mesh=None`` (single-device smoke tests) makes every method a no-op.
    Divisibility-checked: a logical axis only maps to a mesh axis if the
    dimension divides evenly; otherwise that dim is replicated.  This is what
    lets one rule table serve all 10 architectures (e.g. kv=2 GQA heads
    simply replicate on a 16-way model axis).
    """

    def __init__(self, mesh, rules: dict[str, Any] | None = None):
        self.mesh = mesh
        self.rules = rules or {}

    def _axis_size(self, entry) -> int:
        if entry is None or self.mesh is None:
            return 1
        names = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for nm in names:
            size *= self.mesh.shape.get(nm, 1)
        return size

    def spec(self, shape: tuple[int, ...],
             axes: tuple[str | None, ...]):
        from jax.sharding import PartitionSpec
        if self.mesh is None:
            return PartitionSpec()
        entries = []
        used: set = set()
        for dim, ax in zip(shape, axes):
            entry = self.rules.get(ax) if ax else None
            if entry is not None:
                names = entry if isinstance(entry, tuple) else (entry,)
                if any(nm in used for nm in names):
                    entry = None
            if entry is not None and dim % self._axis_size(entry) != 0:
                entry = None
            if entry is not None:
                names = entry if isinstance(entry, tuple) else (entry,)
                used.update(names)
            entries.append(entry)
        return PartitionSpec(*entries)

    def sharding(self, shape, axes):
        from jax.sharding import NamedSharding
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(shape, axes))

    def constrain(self, x: Array, *axes: str | None) -> Array:
        """Sharding constraint on an activation (no-op without a mesh)."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, self.sharding(x.shape, tuple(axes)))

    def param_shardings(self, decls: PyTree) -> PyTree:
        return jax.tree.map(
            lambda p: self.sharding(p.shape, p.axes), decls, is_leaf=_is_p)


NULL_CTX = ShardCtx(None)


# ---------------------------------------------------------------------------
# Functional layers
# ---------------------------------------------------------------------------

def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    """Mixed-precision RMSNorm: the variance REDUCTION runs in f32 (a
    (B, S, 1) output the fuser keeps internal) but the data path stays in
    x.dtype end-to-end — full-width f32 copies of the residual stream
    otherwise become the payload of every SP all-gather riding on the
    norm output (perf iteration 4)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + gamma.astype(x.dtype))


def layer_norm(x: Array, gamma: Array, beta: Array,
               eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    out = (x - mu.astype(x.dtype)) * inv
    return out * gamma.astype(x.dtype) + beta.astype(x.dtype)


ACTIVATIONS: dict[str, Callable[[Array], Array]] = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def dense(x: Array, w: Array) -> Array:
    """x (..., d_in) @ w (d_in, ...) -> x.dtype.

    No f32 preferred_element_type: the TPU MXU accumulates bf16 matmuls in
    f32 internally and rounds once on output, while an explicit f32 output
    doubles the bytes of every sharded-contraction all-reduce riding on
    the result (perf iteration 3: -50% TP collective traffic)."""
    return jax.lax.dot_general(
        x, w.astype(x.dtype),
        (((x.ndim - 1,), (0,)), ((), ())))
