"""CoTM readout head — the paper's technique as a first-class LM feature.

Attaches to any backbone in the zoo: pooled hidden states are booleanized
(thermometer encoding over standardized features, original + negated bits,
exactly the paper's data-preparation step) and classified by the CoTM
clause/class computation.  Inference uses the Pallas kernels (clause
crossbar + class crossbar); training uses the CoTM feedback from
``repro.core.train`` on frozen backbone features.

This is the honest integration point for a *discriminative Boolean
classifier* into a generative stack (sequence classification / reranking);
see DESIGN.md §Arch-applicability for why it does not replace the LM head.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.booleanize import booleanize
from ..core.cotm import CoTMConfig, CoTMParams, include_mask
from ..core.train import train_step_batch
from ..kernels import ops
from .config import TMHeadConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TMHead:
    cfg: TMHeadConfig
    d_features: int

    @property
    def cotm_cfg(self) -> CoTMConfig:
        return CoTMConfig(
            n_literals=2 * self.d_features * self.cfg.bits_per_feature,
            n_clauses=self.cfg.n_clauses,
            n_classes=self.cfg.n_classes,
            n_states=self.cfg.n_states,
            threshold=self.cfg.threshold)

    def init(self, key: Array) -> CoTMParams:
        return self.cotm_cfg.init(key)

    def booleanize(self, features: Array) -> Array:
        """features (B, d) -> literals (B, 2*d*bits) bool.

        Features are squashed to (0, 1) with a logistic over their own
        scale so thermometer thresholds are calibration-free.
        """
        f32 = features.astype(jnp.float32)
        mu = f32.mean(axis=-1, keepdims=True)
        sd = f32.std(axis=-1, keepdims=True) + 1e-6
        squashed = jax.nn.sigmoid((f32 - mu) / sd)
        return booleanize(squashed, n_bits=self.cfg.bits_per_feature)

    def scores(self, params: CoTMParams, features: Array, *,
               impl: str = "pallas") -> Array:
        """Class scores via the fused clause+class kernel."""
        lits = self.booleanize(features)
        inc = include_mask(params.ta_state, self.cotm_cfg.n_states)
        return ops.fused_cotm(lits, inc, params.weights.T, impl=impl)

    def predict(self, params: CoTMParams, features: Array, *,
                impl: str = "pallas") -> Array:
        return jnp.argmax(self.scores(params, features, impl=impl), axis=-1)

    def train_step(self, params: CoTMParams, features: Array,
                   labels: Array, key: Array) -> CoTMParams:
        """One CoTM feedback step on frozen backbone features."""
        lits = self.booleanize(features)
        return train_step_batch(params, lits, labels, key, self.cotm_cfg)


def pool_features(hidden: Array, mask: Array | None = None) -> Array:
    """Mean-pool (B, S, d) -> (B, d) over valid positions."""
    if mask is None:
        return hidden.mean(axis=1)
    m = mask.astype(hidden.dtype)[..., None]
    return (hidden * m).sum(axis=1) / jnp.maximum(m.sum(axis=1), 1.0)
