"""Decoder-only transformer assembly (dense / moe / vlm / audio families).

Layers are stacked on a leading "layers" axis and executed with
``jax.lax.scan`` (small HLO, fast multi-pod compiles) with per-layer
``jax.checkpoint`` rematerialization.  Heterogeneous leading layers (e.g.
DeepSeek's dense first layer) sit outside the scan.

The modality frontends for the [vlm]/[audio] architectures are STUBS per
the assignment: ``qwen2-vl`` consumes precomputed patch embeddings
(concatenated before the text tokens, M-RoPE positions supplied by the
caller) and ``musicgen`` consumes EnCodec token streams (``n_codebooks``
parallel vocabularies, embedded and summed, one output head per codebook).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .attention import attn_decls, attn_forward, init_attn_cache
from .base import (NULL_CTX, P, ShardCtx, abstract_tree, axes_tree,
                   count_params, dense, init_tree, layer_norm, rms_norm)
from .config import ModelConfig
from .ffn import decls_mlp, decls_moe, mlp_forward, moe_forward

Array = jax.Array


def _stack(decls: Any, n: int) -> Any:
    """Add a leading stacked-layer axis to every declaration in the tree."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, ("layers",) + p.axes, p.dtype,
                    p.init, p.scale),
        decls, is_leaf=lambda x: isinstance(x, P))


def _norm_decl(cfg: ModelConfig) -> dict:
    if cfg.norm == "layer":
        return {"gamma": P((cfg.d_model,), (None,), init="ones"),
                "beta": P((cfg.d_model,), (None,), init="zeros")}
    return {"gamma": P((cfg.d_model,), (None,), init="zeros")}


def _norm(p: dict, x: Array, cfg: ModelConfig) -> Array:
    if cfg.norm == "layer":
        return layer_norm(x, p["gamma"], p["beta"])
    return rms_norm(x, p["gamma"])


class TransformerLM:
    """Functional LM; every method takes explicit params."""

    def __init__(self, cfg: ModelConfig, ctx: ShardCtx = NULL_CTX):
        self.cfg = cfg
        self.ctx = ctx

    # -- declarations -------------------------------------------------------
    def _block_decls(self, moe_layer: bool) -> dict:
        cfg = self.cfg
        d = {
            "ln1": _norm_decl(cfg),
            "ln2": _norm_decl(cfg),
            "attn": attn_decls(cfg),
        }
        if moe_layer:
            d["moe"] = decls_moe(cfg)
        else:
            ff = cfg.d_ff
            if cfg.moe is not None and cfg.moe.d_ff_dense:
                ff = cfg.moe.d_ff_dense
            d["mlp"] = decls_mlp(cfg.d_model, ff, cfg.mlp_gated)
        return d

    def decls(self) -> dict:
        cfg = self.cfg
        n_front = cfg.moe.first_dense_layers if cfg.moe else 0
        decls: dict[str, Any] = {
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                       scale=1.0),
            "final_norm": _norm_decl(cfg),
            "layers": _stack(self._block_decls(cfg.moe is not None),
                             cfg.n_layers - n_front),
        }
        if cfg.modality == "audio" and cfg.n_codebooks > 1:
            decls["embed"] = P((cfg.n_codebooks, cfg.vocab, cfg.d_model),
                               (None, "vocab", "embed"), scale=1.0)
        if n_front:
            decls["front"] = [self._block_decls(False)
                              for _ in range(n_front)]
        if not cfg.tie_embeddings:
            shape = (cfg.d_model, cfg.vocab)
            if cfg.modality == "audio" and cfg.n_codebooks > 1:
                decls["lm_head"] = P((cfg.n_codebooks,) + shape,
                                     (None, "embed", "vocab"))
            else:
                decls["lm_head"] = P(shape, ("embed", "vocab"))
        return decls

    def init(self, key: Array):
        return init_tree(self.decls(), key)

    def abstract(self, dtype=None):
        return abstract_tree(self.decls(), dtype)

    def axes(self):
        return axes_tree(self.decls())

    def n_params(self) -> int:
        return count_params(self.decls())

    # -- blocks --------------------------------------------------------------
    def _block(self, p: dict, x: Array, positions: Array, *,
               moe_layer: bool, cache: dict | None = None,
               fill_len: int | None = None):
        cfg, ctx = self.cfg, self.ctx
        h, new_cache = attn_forward(p["attn"], _norm(p["ln1"], x, cfg),
                                    positions, cfg, ctx, cache=cache,
                                    fill_len=fill_len)
        x = x + h
        aux = jnp.zeros((), jnp.float32)
        if moe_layer:
            h, aux = moe_forward(p["moe"], _norm(p["ln2"], x, cfg), cfg, ctx)
        else:
            h = mlp_forward(p["mlp"], _norm(p["ln2"], x, cfg), cfg.act, ctx)
        return x + h, aux, new_cache

    # -- embedding / head ----------------------------------------------------
    def embed(self, params, tokens: Array,
              extra_embeds: Array | None = None) -> Array:
        cfg = self.cfg
        emb = params["embed"]
        if cfg.modality == "audio" and cfg.n_codebooks > 1:
            # tokens (B, S, n_codebooks) -> summed codebook embeddings.
            x = sum(jnp.take(emb[c], tokens[..., c], axis=0)
                    for c in range(cfg.n_codebooks))
        else:
            x = jnp.take(emb, tokens, axis=0)
        x = x.astype(cfg.dtype)
        if cfg.tie_embeddings:
            x = x * math.sqrt(cfg.d_model)
        if extra_embeds is not None:
            # vlm stub: precomputed patch embeddings prepended to the text.
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        return self.ctx.constrain(x, "batch", "seq", None)

    def logits(self, params, x: Array) -> Array:
        cfg = self.cfg
        x = _norm(params["final_norm"], x, cfg)
        if cfg.tie_embeddings:
            out = jnp.einsum("bsd,vd->bsv", x,
                             params["embed"].astype(x.dtype))
        elif cfg.modality == "audio" and cfg.n_codebooks > 1:
            out = jnp.einsum("bsd,cdv->bscv", x,
                             params["lm_head"].astype(x.dtype))
        else:
            out = jnp.einsum("bsd,dv->bsv", x,
                             params["lm_head"].astype(x.dtype))
        return self.ctx.constrain(out.astype(jnp.float32),
                                  *(("batch", None, None, "vocab")
                                    if out.ndim == 4
                                    else ("batch", None, "vocab")))

    # -- full forward ---------------------------------------------------------
    def forward(self, params, tokens: Array, positions: Array,
                extra_embeds: Array | None = None) -> tuple[Array, Array]:
        """-> (logits, aux_loss)."""
        cfg = self.cfg
        x = self.embed(params, tokens, extra_embeds)

        for p_front in params.get("front", []):
            def front_blk(p, h):
                out, aux, _ = self._block(p, h, positions, moe_layer=False)
                return out, aux
            if cfg.remat:
                front_blk = jax.checkpoint(front_blk)
            x, _ = front_blk(p_front, x)

        moe_layer = cfg.moe is not None

        def body(carry, layer_params):
            h, aux = carry
            out, a, _ = self._block(layer_params, h, positions,
                                    moe_layer=moe_layer)
            return (out, aux + a), None

        if cfg.remat:
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), params["layers"])
        else:
            aux = jnp.zeros((), jnp.float32)
            n_scan = jax.tree.leaves(params["layers"])[0].shape[0]
            for i in range(n_scan):
                layer = jax.tree.map(lambda a: a[i], params["layers"])
                (x, aux), _ = body((x, aux), layer)
        return self.logits(params, x), aux

    # -- loss ------------------------------------------------------------------
    def loss(self, params, batch: dict) -> tuple[Array, dict]:
        """Next-token CE.  batch: tokens (B, S[, C]), optional loss_mask,
        positions, extra_embeds."""
        cfg = self.cfg
        tokens = batch["tokens"]
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.arange(tokens.shape[1])[None, :]
        logits, aux = self.forward(params, tokens, positions,
                                   batch.get("extra_embeds"))
        if batch.get("extra_embeds") is not None:
            logits = logits[:, -tokens.shape[1]:]   # text positions only
        targets = tokens[:, 1:]
        logits = logits[:, :-1]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        if mask is not None:
            mask = mask[:, 1:].astype(jnp.float32)
            if nll.ndim == 3:                       # audio codebooks
                mask = mask[..., None]
            ce = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        else:
            ce = nll.mean()
        # z-loss keeps the softmax normalizer bounded (stability at scale).
        zl = 1e-4 * jnp.square(jax.nn.logsumexp(logits, axis=-1)).mean()
        return ce + zl + aux, {"ce": ce, "aux": aux, "zloss": zl}

    # -- serving ----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        one = init_attn_cache(cfg, batch, max_len, dtype)
        n_front = cfg.moe.first_dense_layers if cfg.moe else 0
        cache = {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (cfg.n_layers - n_front,) + a.shape).copy(), one)}
        if n_front:
            cache["front"] = [jax.tree.map(jnp.copy, one)
                              for _ in range(n_front)]
        return cache

    def cache_axes(self):
        """Logical axes for the cache pytree (for shardings)."""
        cfg = self.cfg

        def leaf_axes(path_leaf):
            name, arr = path_leaf
            if name == "len":
                return ("batch",)
            if name in ("ckv", "kr"):
                # Compressed-latent cache: shard the latent dim over model
                # (no head axis exists to shard).
                return ("batch", None, "head_dim")
            # KV heads shard over model when they divide it; otherwise the
            # head_dim picks up the model axis (ShardCtx used-set fallback).
            return ("batch", None, "kv", "head_dim")

        one = {k: leaf_axes((k, None))
               for k in (("ckv", "kr", "len") if cfg.mla else ("k", "v",
                                                               "len"))}
        stacked = {k: ("layers",) + v for k, v in one.items()}
        cache_axes = {"layers": stacked}
        n_front = cfg.moe.first_dense_layers if cfg.moe else 0
        if n_front:
            cache_axes["front"] = [one for _ in range(n_front)]
        return cache_axes

    def prefill(self, params, tokens: Array, positions: Array,
                max_len: int, extra_embeds: Array | None = None):
        """Process a full prompt, returning (last-position logits, cache
        padded to max_len)."""
        cfg = self.cfg
        x = self.embed(params, tokens, extra_embeds)

        new_front = []
        for p_front in params.get("front", []):
            x, _, c = self._block(p_front, x, positions, moe_layer=False,
                                  fill_len=max_len)
            new_front.append(c)

        moe_layer = cfg.moe is not None

        def body(h, layer_params):
            out, _, c = self._block(layer_params, h, positions,
                                    moe_layer=moe_layer, fill_len=max_len)
            return out, c

        x, layer_cache = jax.lax.scan(body, x, params["layers"])
        cache = {"layers": layer_cache}
        if new_front:
            cache["front"] = new_front
        logits = self.logits(params, x[:, -1:])
        return logits, cache

    def decode_step(self, params, cache, tokens: Array,
                    positions: Array) -> tuple[Array, dict]:
        """One decode step: tokens (B, 1[, C]) -> (logits (B, 1, V[, C]),
        updated cache)."""
        cfg = self.cfg
        x = self.embed(params, tokens)

        new_front = []
        for p_front, c_front in zip(params.get("front", []),
                                    cache.get("front", [])):
            x, _, c = self._block(p_front, x, positions, moe_layer=False,
                                  cache=c_front)
            new_front.append(c)

        moe_layer = cfg.moe is not None

        def body(h, xs):
            layer_params, layer_cache = xs
            out, _, new_cache = self._block(layer_params, h, positions,
                                            moe_layer=moe_layer,
                                            cache=layer_cache)
            return out, new_cache

        x, new_layer_cache = jax.lax.scan(
            body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layer_cache}
        if new_front:
            new_cache["front"] = new_front
        return self.logits(params, x), new_cache
