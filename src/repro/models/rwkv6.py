"""RWKV6 "Finch" — attention-free LM with data-dependent decay.

Faithful structure per arXiv:2404.05892: token-shift ddlerp mixes with a
shared LoRA, data-dependent per-channel decay ``w_t = exp(-exp(w0 + lora))``,
u-bonus for the current token, per-head group norm, squared-ReLU channel
mix.  The wkv recurrence runs on the shared chunked-linear-attention engine
(``ssm_common``) — matmul form on the MXU for train/prefill, O(1)-state
``la_step`` for decode (this is the family that runs the long_500k cell).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .base import (NULL_CTX, P, ShardCtx, abstract_tree, axes_tree,
                   count_params, dense, init_tree, layer_norm)
from .config import ModelConfig
from .ssm_common import chunked_la, la_step
from .transformer import _stack  # same stacked-layer machinery

Array = jax.Array

N_MIX = 5  # r, w, k, v, g ddlerp streams


def _shift(x: Array, x_prev: Array | None = None) -> Array:
    """Token shift: previous token's features (zeros / carried at t=0)."""
    pad = jnp.zeros_like(x[:, :1]) if x_prev is None else x_prev[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


class RWKV6LM:
    def __init__(self, cfg: ModelConfig, ctx: ShardCtx = NULL_CTX):
        assert cfg.ssm is not None and cfg.ssm.kind == "rwkv6"
        self.cfg = cfg
        self.ctx = ctx
        self.head_dim = cfg.ssm.head_dim
        self.n_heads_ssm = cfg.d_model // self.head_dim

    # -- declarations --------------------------------------------------------
    def _block_decls(self) -> dict:
        cfg = self.cfg
        d, ff = cfg.d_model, cfg.d_ff
        H, hd = self.n_heads_ssm, self.head_dim
        lr = 32
        lw = cfg.ssm.decay_lora
        return {
            "ln1": {"gamma": P((d,), (None,), init="ones"),
                    "beta": P((d,), (None,), init="zeros")},
            "ln2": {"gamma": P((d,), (None,), init="ones"),
                    "beta": P((d,), (None,), init="zeros")},
            "tm": {
                "mu_x": P((d,), (None,), init="zeros"),
                "mu": P((N_MIX, d), (None, None), init="zeros"),
                "lora_a": P((d, N_MIX * lr), ("embed", None), scale=0.02),
                "lora_b": P((N_MIX, lr, d), (None, None, "embed"),
                            scale=0.02),
                "w0": P((d,), (None,), init="zeros"),
                "wa": P((d, lw), ("embed", None), scale=0.02),
                "wb": P((lw, d), (None, "embed"), scale=0.02),
                "wr": P((d, H, hd), ("embed", "heads", None)),
                "wk": P((d, H, hd), ("embed", "heads", None)),
                "wv": P((d, H, hd), ("embed", "heads", None)),
                "wg": P((d, H, hd), ("embed", "heads", None)),
                "u": P((H, hd), ("heads", None), init="small"),
                "ln_x": {"gamma": P((H, hd), ("heads", None), init="ones"),
                         "beta": P((H, hd), ("heads", None), init="zeros")},
                "wo": P((H, hd, d), ("heads", None, "embed")),
            },
            "cm": {
                "mu_k": P((d,), (None,), init="zeros"),
                "mu_r": P((d,), (None,), init="zeros"),
                "wk": P((d, ff), ("embed", "mlp")),
                "wv": P((ff, d), ("mlp", "embed")),
                "wr": P((d, d), ("embed", None)),
            },
        }

    def decls(self) -> dict:
        cfg = self.cfg
        return {
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                       scale=1.0),
            "ln0": {"gamma": P((cfg.d_model,), (None,), init="ones"),
                    "beta": P((cfg.d_model,), (None,), init="zeros")},
            "final_norm": {"gamma": P((cfg.d_model,), (None,), init="ones"),
                           "beta": P((cfg.d_model,), (None,), init="zeros")},
            "lm_head": P((cfg.d_model, cfg.vocab), ("embed", "vocab")),
            "layers": _stack(self._block_decls(), cfg.n_layers),
        }

    def init(self, key):
        return init_tree(self.decls(), key)

    def abstract(self, dtype=None):
        return abstract_tree(self.decls(), dtype)

    def axes(self):
        return axes_tree(self.decls())

    def n_params(self) -> int:
        return count_params(self.decls())

    # -- time mix -------------------------------------------------------------
    def _ddlerp(self, tm: dict, x: Array, xx: Array) -> tuple[Array, ...]:
        """Data-dependent lerp producing the 5 mixed streams (r,w,k,v,g)."""
        B, S, d = x.shape
        lr = tm["lora_a"].shape[1] // N_MIX
        base = x + xx * tm["mu_x"].astype(x.dtype)
        s = jnp.tanh(dense(base, tm["lora_a"])).reshape(B, S, N_MIX, lr)
        s = jnp.einsum("bsml,mld->bsmd", s, tm["lora_b"].astype(x.dtype))
        mixed = (x[:, :, None, :]
                 + xx[:, :, None, :] * (tm["mu"].astype(x.dtype) + s))
        return tuple(mixed[:, :, i, :] for i in range(N_MIX))

    def _time_mix_proj(self, tm: dict, x: Array, xx: Array):
        """Shared projection path for train and decode (S axis kept)."""
        cfg = self.cfg
        H, hd = self.n_heads_ssm, self.head_dim
        x_r, x_w, x_k, x_v, x_g = self._ddlerp(tm, x, xx)
        proj = lambda t, w: jnp.einsum("bsd,dhk->bshk", t,
                                       w.astype(x.dtype))
        r, k, v = proj(x_r, tm["wr"]), proj(x_k, tm["wk"]), proj(x_v, tm["wv"])
        g = jax.nn.silu(proj(x_g, tm["wg"]))
        log_w = -jnp.exp(
            tm["w0"].astype(jnp.float32)
            + jnp.einsum("bsd,dl,le->bse", x_w.astype(jnp.float32),
                         tm["wa"].astype(jnp.float32),
                         tm["wb"].astype(jnp.float32)))
        log_w = log_w.reshape(*log_w.shape[:2], H, hd)
        return r, k, v, g, log_w

    def _time_mix_out(self, tm: dict, o: Array, g: Array, x_dtype) -> Array:
        """Per-head group norm, gate, output projection."""
        o32 = o.astype(jnp.float32)
        mu = o32.mean(-1, keepdims=True)
        var = o32.var(-1, keepdims=True)
        o32 = (o32 - mu) * jax.lax.rsqrt(var + 1e-5)
        o32 = (o32 * tm["ln_x"]["gamma"] + tm["ln_x"]["beta"])
        o = (o32.astype(x_dtype) * g)
        return jnp.einsum("bshk,hkd->bsd", o, tm["wo"].astype(x_dtype))

    # -- blocks ----------------------------------------------------------------
    def _block(self, p: dict, x: Array, state: dict | None):
        """state: {"x_tm": (B,d), "x_cm": (B,d), "s": (B,H,hd,hd)} or None."""
        cfg, ctx = self.cfg, self.ctx
        tm, cm = p["tm"], p["cm"]
        new_state = {}

        # --- time mix ---
        xn = layer_norm(x, p["ln1"]["gamma"], p["ln1"]["beta"])
        x_prev = None if state is None else state["x_tm"]
        xx = _shift(xn, x_prev) - xn
        r, k, v, g, log_w = self._time_mix_proj(tm, xn, xx)
        r = ctx.constrain(r, "batch", None, "heads", None)
        if state is None:
            o, s_final = chunked_la(r, k, v, log_w,
                                    u=tm["u"].astype(jnp.float32),
                                    inclusive=False, chunk=cfg.ssm.chunk)
            new_state["s"] = s_final
            new_state["x_tm"] = xn[:, -1]
        else:
            o1, s_new = la_step(state["s"], r[:, 0], k[:, 0], v[:, 0],
                                log_w[:, 0],
                                u=tm["u"].astype(jnp.float32),
                                inclusive=False)
            o = o1[:, None]
            new_state["s"] = s_new
            new_state["x_tm"] = xn[:, -1]
        x = x + self._time_mix_out(tm, o, g, x.dtype)
        x = ctx.constrain(x, "batch", "seq", None)

        # --- channel mix ---
        xn = layer_norm(x, p["ln2"]["gamma"], p["ln2"]["beta"])
        x_prev = None if state is None else state["x_cm"]
        xx = _shift(xn, x_prev) - xn
        xk = xn + xx * cm["mu_k"].astype(x.dtype)
        xr = xn + xx * cm["mu_r"].astype(x.dtype)
        h = jnp.square(jax.nn.relu(dense(xk, cm["wk"])))
        h = ctx.constrain(h, "batch", None, "mlp")
        out = jax.nn.sigmoid(dense(xr, cm["wr"])) * dense(h, cm["wv"])
        new_state["x_cm"] = xn[:, -1]
        x = x + out
        return ctx.constrain(x, "batch", "seq", None), new_state

    # -- LM interface -----------------------------------------------------------
    def forward(self, params, tokens: Array, positions=None,
                extra_embeds=None) -> tuple[Array, Array]:
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        x = layer_norm(x, params["ln0"]["gamma"], params["ln0"]["beta"])
        x = self.ctx.constrain(x, "batch", "seq", None)

        def body(h, layer_params):
            out, _ = self._block(layer_params, h, None)
            return out, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        x = layer_norm(x, params["final_norm"]["gamma"],
                       params["final_norm"]["beta"])
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm_head"].astype(x.dtype))
        logits = self.ctx.constrain(logits.astype(jnp.float32),
                                    "batch", None, "vocab")
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch: dict) -> tuple[Array, dict]:
        logits, _ = self.forward(params, batch["tokens"])
        targets = batch["tokens"][:, 1:]
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        nll = -jnp.take_along_axis(
            logp, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
        ce = nll.mean()
        zl = 1e-4 * jnp.square(jax.nn.logsumexp(logits[:, :-1],
                                                axis=-1)).mean()
        return ce + zl, {"ce": ce, "aux": jnp.zeros(()), "zloss": zl}

    # -- serving ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int = 0, dtype=jnp.bfloat16):
        """Recurrent state — O(1) in sequence length (max_len unused)."""
        cfg = self.cfg
        H, hd = self.n_heads_ssm, self.head_dim
        one = dict(
            x_tm=jnp.zeros((batch, cfg.d_model), dtype),
            x_cm=jnp.zeros((batch, cfg.d_model), dtype),
            s=jnp.zeros((batch, H, hd, hd), jnp.float32))
        return {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(),
            one)}

    def cache_axes(self):
        return {"layers": dict(
            x_tm=("layers", "batch", None),
            x_cm=("layers", "batch", None),
            s=("layers", "batch", "heads", None, None))}

    def prefill(self, params, tokens: Array, positions=None,
                max_len: int = 0, extra_embeds=None):
        """Full-prompt pass returning (last logits, recurrent state cache)."""
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        x = layer_norm(x, params["ln0"]["gamma"], params["ln0"]["beta"])

        def body(h, layer_params):
            out, st = self._block(layer_params, h, None)
            return out, st

        x, states = jax.lax.scan(body, x, params["layers"])
        x = layer_norm(x, params["final_norm"]["gamma"],
                       params["final_norm"]["beta"])
        logits = jnp.einsum("bsd,dv->bsv", x[:, -1:],
                            params["lm_head"].astype(x.dtype))
        states = dict(states)
        states["x_tm"] = states["x_tm"].astype(jnp.bfloat16)
        states["x_cm"] = states["x_cm"].astype(jnp.bfloat16)
        return logits.astype(jnp.float32), {"layers": states}

    def decode_step(self, params, cache, tokens: Array,
                    positions=None) -> tuple[Array, dict]:
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        x = layer_norm(x, params["ln0"]["gamma"], params["ln0"]["beta"])

        def body(h, xs):
            layer_params, layer_state = xs
            out, new_state = self._block(layer_params, h, layer_state)
            return out, new_state

        x, new_states = jax.lax.scan(body, x,
                                     (params["layers"], cache["layers"]))
        x = layer_norm(x, params["final_norm"]["gamma"],
                       params["final_norm"]["beta"])
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm_head"].astype(x.dtype))
        return logits.astype(jnp.float32), {"layers": new_states}
