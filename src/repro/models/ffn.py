"""Feed-forward layers: gated dense MLP and mixture-of-experts.

MoE dispatch is sort-based (no (tokens, E, C) one-hot einsums, which inflate
FLOPs by orders of magnitude): entries are ranked within their expert via an
argsort + running-count, dropped beyond capacity, scatter-added into an
(B, E, C, d) buffer, processed by batched expert matmuls, and gathered back.
Compiled FLOPs therefore track ACTIVE expert compute (x capacity factor),
which is what the roofline's MODEL_FLOPS/HLO_FLOPs ratio checks.

Sharding: the ShardCtx rule table sends "experts" to the model axis when the
expert count divides it (expert parallelism — deepseek's 64), and otherwise
falls through to sharding the expert hidden dim (tensor parallelism inside
each expert — grok's 8).  Both use the same constraint strings here.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import compat
from .base import ACTIVATIONS, P, ShardCtx, dense
from .config import ModelConfig, MoEConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# Dense gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def decls_mlp(d_model: int, d_ff: int, gated: bool = True) -> dict:
    decls = {
        "w_up": P((d_model, d_ff), ("embed", "mlp")),
        "w_down": P((d_ff, d_model), ("mlp", "embed")),
    }
    if gated:
        decls["w_gate"] = P((d_model, d_ff), ("embed", "mlp"))
    return decls


def mlp_forward(p: dict, x: Array, act: str, ctx: ShardCtx) -> Array:
    if "w_gate" in p:
        h = ACTIVATIONS[act](dense(x, p["w_gate"])) * dense(x, p["w_up"])
    else:
        h = ACTIVATIONS[act](dense(x, p["w_up"]))
    h = ctx.constrain(h, "batch", None, "mlp")
    out = dense(h, p["w_down"])
    return ctx.constrain(out, "batch", "seq", None)


# ---------------------------------------------------------------------------
# Mixture of experts
# ---------------------------------------------------------------------------

def decls_moe(cfg: ModelConfig) -> dict:
    moe = cfg.moe
    d, f = cfg.d_model, moe.d_ff_expert
    decls = {
        "router": P((d, moe.n_experts), ("embed", None), scale=0.02),
        "w_gate": P((moe.n_experts, d, f), ("experts", "embed", "moe_mlp")),
        "w_up": P((moe.n_experts, d, f), ("experts", "embed", "moe_mlp")),
        "w_down": P((moe.n_experts, f, d), ("experts", "moe_mlp", "embed")),
    }
    if moe.n_shared:
        decls["shared"] = decls_mlp(d, moe.n_shared * f)
    return decls


def _capacity(tokens_per_group: int, moe: MoEConfig) -> int:
    c = math.ceil(tokens_per_group * moe.top_k * moe.capacity_factor
                  / moe.n_experts)
    return max(min(c, tokens_per_group * moe.top_k), 1)


MOE_GROUP_TOKENS = 4096   # dispatch-group size: bounds the (G,E,C,d) buffers


def _ep_sharded(cfg: ModelConfig, ctx: ShardCtx) -> bool:
    """True when experts divide the model axis (expert parallelism) and we
    can take the shard_map fast path (local-expert combine + psum)."""
    if ctx.mesh is None:
        return False
    model_size = ctx.mesh.shape.get("model", 1)
    return model_size > 1 and cfg.moe.n_experts % model_size == 0


def moe_forward(p: dict, x: Array, cfg: ModelConfig,
                ctx: ShardCtx) -> tuple[Array, Array]:
    """x (B, S, d) -> (out (B, S, d), aux load-balance loss scalar).

    Dispatch groups are <=4096-token sequence slices (GShard-style
    per-group capacity): the (G, E, C, d) expert buffers stay bounded at
    long prefill lengths, and groups remain local to their data shard so
    the only cross-shard traffic is the expert combine.

    Combine paths (hillclimb iteration 1, see EXPERIMENTS.md §Perf):
    * EP (E %% model == 0): shard_map — every model shard runs its local
      experts and contributes a PARTIAL combined output; one psum of
      (B, S, d) replaces the (B, E, C, d) all-gather (~30x fewer link
      bytes for deepseek).
    * otherwise (grok's 8 experts on a 16-wide axis): expert-hidden-dim
      tensor parallelism through plain GSPMD.
    """
    moe = cfg.moe
    B, S, d = x.shape
    routed = _routed_ep if _ep_sharded(cfg, ctx) else _routed
    if S > MOE_GROUP_TOKENS and S % MOE_GROUP_TOKENS == 0:
        n = S // MOE_GROUP_TOKENS
        out, aux = routed(p, x.reshape(B * n, MOE_GROUP_TOKENS, d), cfg,
                          ctx)
        out = out.reshape(B, S, d)
    else:
        out, aux = routed(p, x, cfg, ctx)
    if moe.n_shared:
        out = out + mlp_forward(p["shared"], x, cfg.act, ctx)
    return ctx.constrain(out, "batch", "seq", None), aux


def _dispatch_plan(x: Array, router: Array, moe: MoEConfig):
    """Shared routing math: top-k, capacity ranks, slot ids.

    Returns (probs (B,S,E) f32, top_p, top_e, keep, slot) with
    slot = e*C + rank (E*C = drop bin)."""
    B, S, d = x.shape
    E, K = moe.n_experts, moe.top_k
    C = _capacity(S, moe)
    T = S * K
    logits = jnp.einsum("bsd,de->bse", x, router.astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    e_flat = top_e.reshape(B, T)
    order = jnp.argsort(e_flat, axis=1, stable=True)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=1)
    counts = jax.vmap(lambda e: jnp.zeros((E,), jnp.int32).at[e].add(1))(
        e_flat)
    starts = jnp.cumsum(counts, axis=1) - counts
    rank_sorted = (jnp.arange(T)[None, :]
                   - jnp.take_along_axis(starts, e_sorted, axis=1))
    inv = jnp.argsort(order, axis=1)
    rank = jnp.take_along_axis(rank_sorted, inv, axis=1).reshape(B, S, K)
    keep = rank < C
    slot = jnp.where(keep, top_e * C + rank, E * C)
    return probs, top_p, top_e, keep, slot, C


def _routed_ep(p: dict, x: Array, cfg: ModelConfig,
               ctx: ShardCtx) -> tuple[Array, Array]:
    """Expert-parallel fast path: shard_map over (data..., model)."""
    moe = cfg.moe
    B, S, d = x.shape
    E, K = moe.n_experts, moe.top_k
    mesh = ctx.mesh
    model_size = mesh.shape.get("model", 1)
    e_loc = E // model_size
    dp = tuple(n for n in ("pod", "data") if n in mesh.shape)
    P = jax.sharding.PartitionSpec

    def local_moe(xb, router, w_gate, w_up, w_down):
        # xb (B_loc, S, d) replicated over model; w_* (E_loc, ...) local.
        probs, top_p, top_e, keep, slot, C = _dispatch_plan(xb, router, moe)
        Bl = xb.shape[0]
        buf = jnp.zeros((Bl, E * C + 1, d), xb.dtype)
        scatter_g = jax.vmap(lambda bg, sg, ug: bg.at[sg].add(ug))
        for j in range(K):
            buf = scatter_g(buf, slot[:, :, j],
                            xb * keep[:, :, j:j + 1].astype(xb.dtype))
        # My experts: [lo, lo + e_loc) on the model axis.
        midx = jax.lax.axis_index("model")
        lo = midx * e_loc
        my = jax.lax.dynamic_slice_in_dim(
            buf[:, :E * C].reshape(Bl, E, C, d), lo, e_loc, axis=1)
        h = (ACTIVATIONS[cfg.act](
                jnp.einsum("becd,edf->becf", my, w_gate.astype(xb.dtype)))
             * jnp.einsum("becd,edf->becf", my, w_up.astype(xb.dtype)))
        out_loc = jnp.einsum("becf,efd->becd", h,
                             w_down.astype(xb.dtype))   # (Bl,e_loc,C,d)
        out_flat = jnp.concatenate(
            [out_loc.reshape(Bl, e_loc * C, d),
             jnp.zeros((Bl, 1, d), xb.dtype)], axis=1)
        # Partial combine: only slots belonging to my experts contribute.
        gather_g = jax.vmap(lambda og, sg: og[sg])
        out = jnp.zeros((Bl, S, d), xb.dtype)
        for j in range(K):
            sj = slot[:, :, j]
            mine = (sj >= lo * C) & (sj < (lo + e_loc) * C) & keep[:, :, j]
            sj_loc = jnp.where(mine, sj - lo * C, e_loc * C)
            gathered = gather_g(out_flat, sj_loc)
            w = (top_p[:, :, j] * mine).astype(xb.dtype)
            out = out + gathered * w[:, :, None]
        out = jax.lax.psum(out, "model")
        me = probs.mean(axis=(0, 1))
        assign = jax.nn.one_hot(top_e[..., 0], E).mean(axis=(0, 1))
        aux = moe.aux_loss_weight * E * jnp.sum(me * assign)
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return out, aux

    fn = compat.shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(dp if dp else None, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(dp if dp else None, None, None), P()),
        check_vma=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _routed(p: dict, x: Array, cfg: ModelConfig,
            ctx: ShardCtx) -> tuple[Array, Array]:
    moe = cfg.moe
    B, S, d = x.shape
    E, K = moe.n_experts, moe.top_k
    # Dispatch is group-local: undo sequence parallelism here (one SP
    # all-gather, the Megatron MoE pattern) so routing/scatter/gather all
    # stay on the data shard.
    x = ctx.constrain(x, "batch", None, None)
    probs, top_p, top_e, keep, slot, C = _dispatch_plan(x, p["router"], moe)

    # --- dispatch: scatter tokens into the (B, E*C, d) buffer -------------
    # vmapped over groups => a batched scatter GSPMD shards along the
    # (data-parallel) group dim instead of replicating the updates.
    buf = jnp.zeros((B, E * C + 1, d), x.dtype)
    scatter_g = jax.vmap(lambda bg, sg, ug: bg.at[sg].add(ug))
    for j in range(K):
        buf = scatter_g(buf, slot[:, :, j],
                        x * keep[:, :, j:j + 1].astype(x.dtype))
    buf = buf[:, :E * C, :].reshape(B, E, C, d)
    buf = ctx.constrain(buf, "batch", "experts", None, None)

    # --- expert FFN (batched over E) ---------------------------------------
    h = (ACTIVATIONS[cfg.act](
            jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(x.dtype)))
         * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype)))
    h = ctx.constrain(h, "batch", "experts", None, "moe_mlp")
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    # EP combine: gather needs every expert's rows -> all-gather over model.
    out_buf = ctx.constrain(out_buf, "batch", None, None, None)
    out_flat = out_buf.reshape(B, E * C, d)
    out_flat = jnp.concatenate(
        [out_flat, jnp.zeros((B, 1, d), x.dtype)], axis=1)  # drop bin

    # --- combine: gather own slots, weight by router probs ----------------
    gather_g = jax.vmap(lambda og, sg: og[sg])
    out = jnp.zeros((B, S, d), x.dtype)
    for j in range(K):
        gathered = gather_g(out_flat, slot[:, :, j])       # (B, S, d)
        w = (top_p[:, :, j] * keep[:, :, j]).astype(x.dtype)
        out = out + gathered * w[:, :, None]

    # --- aux load-balance loss (Switch/GShard style) -----------------------
    me = probs.mean(axis=(0, 1))                           # (E,)
    assign = jax.nn.one_hot(top_e[..., 0], E).mean(axis=(0, 1))
    aux = moe.aux_loss_weight * E * jnp.sum(me * assign)
    return out, aux
