"""Model zoo: one builder for all 10 assigned architectures.

``build(cfg, ctx)`` dispatches on family:

* dense / moe / vlm / audio -> ``TransformerLM``
* ssm (rwkv6)               -> ``RWKV6LM``
* hybrid (mamba2 + shared attention) -> ``Zamba2LM``

All three expose the same functional interface: ``decls/init/abstract/axes``,
``forward``, ``loss``, ``init_cache``/``cache_axes``/``decode_step``.
"""
from .base import NULL_CTX, P, ShardCtx, abstract_tree, axes_tree, init_tree
from .config import (MLAConfig, MoEConfig, ModelConfig, SHAPES, ShapeSpec,
                     SSMConfig, TMHeadConfig)
from .rwkv6 import RWKV6LM
from .tm_head import TMHead, pool_features
from .transformer import TransformerLM
from .zamba2 import Zamba2LM


def build(cfg: ModelConfig, ctx: ShardCtx = NULL_CTX):
    if cfg.ssm is not None and cfg.hybrid_attn_every > 0:
        return Zamba2LM(cfg, ctx)
    if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        return RWKV6LM(cfg, ctx)
    return TransformerLM(cfg, ctx)


__all__ = [
    "build", "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig",
    "TMHeadConfig", "ShapeSpec", "SHAPES", "TransformerLM", "RWKV6LM",
    "Zamba2LM", "TMHead", "pool_features", "ShardCtx", "NULL_CTX", "P",
    "abstract_tree", "axes_tree", "init_tree",
]
