"""Unified model configuration for the 10 assigned architectures.

One frozen dataclass drives every family (dense / moe / vlm / audio / ssm /
hybrid); family-specific sub-configs are optional fields.  Exact published
dimensions live in ``repro.configs.<arch_id>``.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0              # shared (always-on) experts
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    first_dense_layers: int = 0    # leading layers that use a dense FFN
    d_ff_dense: int | None = None  # FFN width of those dense layers


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"        # mamba2 | rwkv6
    state_dim: int = 64         # N (mamba2) / head size (rwkv6)
    head_dim: int = 64          # P per SSM head
    expand: int = 2             # d_inner = expand * d_model (mamba2)
    n_groups: int = 1           # B/C groups (mamba2)
    conv_width: int = 4
    chunk: int = 128            # chunked-scan block length
    decay_lora: int = 64        # rwkv6 data-dependent decay LoRA rank


@dataclasses.dataclass(frozen=True)
class TMHeadConfig:
    """CoTM readout head (the paper's technique as an LM feature)."""
    n_classes: int = 10
    n_clauses: int = 500
    bits_per_feature: int = 1
    n_states: int = 128
    threshold: int = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    act: str = "silu"                    # MLP activation
    mlp_gated: bool = True               # SwiGLU/GeGLU vs plain MLP
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_style: str = "rope"             # rope | mrope | none
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    norm: str = "rms"                    # rms | layer
    tie_embeddings: bool = False
    logit_softcap: float | None = None
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    hybrid_attn_every: int = 0           # zamba2: shared attn block period
    modality: str = "text"               # text | vision_stub | audio_stub
    n_codebooks: int = 1                 # audio: EnCodec streams
    tm_head: TMHeadConfig | None = None
    # --- numerics / execution ---
    dtype: Any = "bfloat16"              # compute dtype
    param_dtype: Any = "float32"
    remat: bool = True                   # checkpoint each scan layer
    scan_layers: bool = True
    attn_chunk_q: int = 512
    attn_chunk_k: int = 2048
    # --- training memory policy (used by launch/train + dryrun) ---
    zero3: bool = False                  # shard params over "data" too
    opt_moment_dtype: Any = "float32"    # bf16 for the very largest models
    grad_accum_dtype: Any = "float32"    # bf16 halves the accumulator

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def smoke(self) -> "ModelConfig":
        """A reduced same-family config for CPU smoke tests."""
        changes: dict[str, Any] = dict(
            n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128, vocab=256, head_dim=16,
            attn_chunk_q=32, attn_chunk_k=32,
            remat=False, zero3=False,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_ff_expert=64,
                n_shared=min(self.moe.n_shared, 1),
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                d_ff_dense=128 if self.moe.d_ff_dense else None)
        if self.mla is not None:
            changes["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                                       qk_rope_head_dim=8, v_head_dim=16)
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=16, chunk=16, decay_lora=8)
            changes["n_layers"] = 4 if self.hybrid_attn_every else 2
        if self.hybrid_attn_every:
            changes["hybrid_attn_every"] = 2
        if self.mrope_sections and self.rope_style == "mrope":
            changes["mrope_sections"] = (4, 2, 2)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode
    accum: int = 1               # gradient-accumulation microbatches (train)

    def smoke(self) -> "ShapeSpec":
        return dataclasses.replace(self, seq_len=min(self.seq_len, 64),
                                   global_batch=min(self.global_batch, 2),
                                   accum=1)


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train", accum=8),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
