"""Mamba2 (SSD) block — the recurrent core of the Zamba2 hybrid.

Structure per arXiv:2405.21060 / Zamba2 (arXiv:2411.15242): fused in_proj
producing (z gate | x | B | C | dt), short causal depthwise conv over
(x, B, C), per-head scalar decay ``a_t = exp(-exp(A_log) * dt_t)``, SSD
recurrence ``S_t = a_t S_{t-1} + (dt_t x_t) (x) B_t``, ``y_t = C_t . S_t``
+ D-skip, gated RMSNorm, out_proj.

The recurrence runs on ``ssm_common.chunked_la`` (inclusive diagonal,
scalar decay broadcast over the state channel axis) — i.e. the exact SSD
"chunked" algorithm, MXU matmuls within chunks, one (N, P) state hand-off
per chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import P, ShardCtx, dense, rms_norm
from .config import ModelConfig
from .ssm_common import chunked_la, la_step

Array = jax.Array


def mamba_dims(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.state_dim
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.state_dim + n_heads
    return dict(d_inner=d_inner, n_heads=n_heads, conv_ch=conv_ch,
                d_in_proj=d_in_proj)


def decls_mamba(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    dims = mamba_dims(cfg)
    d = cfg.d_model
    return {
        "in_proj": P((d, dims["d_in_proj"]), ("embed", "mlp")),
        "conv_w": P((s.conv_width, dims["conv_ch"]), (None, "mlp"),
                    init="small"),
        "conv_b": P((dims["conv_ch"],), ("mlp",), init="zeros"),
        "dt_bias": P((dims["n_heads"],), ("heads",), init="zeros"),
        "a_log": P((dims["n_heads"],), ("heads",), init="zeros"),
        "d_skip": P((dims["n_heads"],), ("heads",), init="ones"),
        "norm": P((dims["d_inner"],), ("mlp",), init="zeros"),
        "out_proj": P((dims["d_inner"], d), ("mlp", "embed")),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv via shifted adds.  x (B, S, C); w (W, C)."""
    W = w.shape[0]
    out = x * w[-1].astype(x.dtype)
    for j in range(W - 1):
        shift = W - 1 - j
        shifted = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :-shift]
        out = out + shifted * w[j].astype(x.dtype)
    return out + b.astype(x.dtype)


def _split_proj(cfg: ModelConfig, zxbcdt: Array):
    s = cfg.ssm
    dims = mamba_dims(cfg)
    di, gN = dims["d_inner"], s.n_groups * s.state_dim
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + dims["conv_ch"]]
    dt = zxbcdt[..., di + dims["conv_ch"]:]
    return z, xbc, dt, di, gN


def mamba_forward(p: dict, x: Array, cfg: ModelConfig, ctx: ShardCtx, *,
                  state: dict | None = None) -> tuple[Array, dict]:
    """x (B, S, d) -> (out (B, S, d), new state dict for decode).

    state (decode, S==1): {"conv": (B, W-1, conv_ch), "s": (B, H, N, P)}.
    """
    s = cfg.ssm
    dims = mamba_dims(cfg)
    B, S, _ = x.shape
    H, Pd, N, G = dims["n_heads"], s.head_dim, s.state_dim, s.n_groups

    zxbcdt = dense(x, p["in_proj"])
    zxbcdt = ctx.constrain(zxbcdt, "batch", None, "mlp")
    z, xbc, dt, di, gN = _split_proj(cfg, zxbcdt)

    new_state: dict = {}
    if state is None:
        # Carry the conv tail so a prefill can hand off to decode.
        tail = xbc[:, -(s.conv_width - 1):]
        pad = s.conv_width - 1 - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        new_state["conv"] = tail
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    else:
        window = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc],
                                 axis=1)                     # (B, W, C)
        xbc = (jnp.einsum("bwc,wc->bc", window,
                          p["conv_w"].astype(xbc.dtype))
               + p["conv_b"].astype(xbc.dtype))[:, None]
        new_state["conv"] = window[:, 1:]
    xbc = jax.nn.silu(xbc)

    xs = xbc[..., :di].reshape(B, S, H, Pd)
    Bm = xbc[..., di:di + gN].reshape(B, S, G, N)
    Cm = xbc[..., di + gN:].reshape(B, S, G, N)
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=2)                         # (B,S,H,N)
    Cm = jnp.repeat(Cm, rep, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    log_a = -jnp.exp(p["a_log"].astype(jnp.float32)) * dt     # <= 0
    v = xs * dt[..., None].astype(xs.dtype)                   # (B,S,H,P)
    log_w = jnp.broadcast_to(log_a[..., None], (B, S, H, N))

    if state is None:
        y, s_final = chunked_la(Cm, Bm, v, log_w, inclusive=True,
                                chunk=s.chunk)
        new_state["s"] = s_final
    else:
        y1, s_new = la_step(state["s"], Cm[:, 0], Bm[:, 0], v[:, 0],
                            log_w[:, 0], inclusive=True)
        y = y1[:, None]
        new_state["s"] = s_new

    y = y + xs * p["d_skip"].astype(xs.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(z)
    y = ctx.constrain(y, "batch", None, "mlp")
    out = dense(y, p["out_proj"])
    return ctx.constrain(out, "batch", "seq", None), new_state


def init_mamba_state(cfg: ModelConfig, batch: int,
                     dtype=jnp.bfloat16) -> dict:
    s = cfg.ssm
    dims = mamba_dims(cfg)
    return dict(
        conv=jnp.zeros((batch, s.conv_width - 1, dims["conv_ch"]), dtype),
        s=jnp.zeros((batch, dims["n_heads"], s.state_dim, s.head_dim),
                    jnp.float32))
