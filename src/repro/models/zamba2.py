"""Zamba2 hybrid: Mamba2 backbone + ONE shared full-attention block.

Per arXiv:2411.15242 the attention block's weights are SHARED across all of
its invocations (every ``hybrid_attn_every`` mamba layers); its input is the
concat of the current hidden state and the original embeddings (2*d wide),
projected back to d by the output projection.  Adaptations recorded in
DESIGN.md: per-invocation LoRA deltas on the shared weights are omitted,
and decode uses a RING-BUFFER KV cache (window 8192) per invocation so the
long_500k cell fits HBM — the Mamba2 state carries long-range information,
the shared-attention window carries local syntax (the standard hybrid
serving trade-off).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .attention import chunked_attention
from .base import (NULL_CTX, P, ShardCtx, abstract_tree, axes_tree,
                   count_params, count_params as _cp, dense, init_tree,
                   rms_norm)
from .config import ModelConfig
from .ffn import decls_mlp, mlp_forward
from .mamba2 import decls_mamba, init_mamba_state, mamba_forward
from .rope import apply_rope, rope_angles
from .transformer import _stack

Array = jax.Array

ATTN_WINDOW = 8192     # decode ring-buffer length per shared-block invocation


class Zamba2LM:
    def __init__(self, cfg: ModelConfig, ctx: ShardCtx = NULL_CTX):
        assert cfg.ssm is not None and cfg.hybrid_attn_every > 0
        self.cfg = cfg
        self.ctx = ctx
        self.d_concat = 2 * cfg.d_model
        self.attn_head_dim = self.d_concat // cfg.n_heads
        self.n_invocations = cfg.n_layers // cfg.hybrid_attn_every

    # -- declarations ----------------------------------------------------------
    def _shared_decls(self) -> dict:
        cfg = self.cfg
        dc, hq, hd = self.d_concat, cfg.n_heads, self.attn_head_dim
        return {
            "ln_in": P((dc,), (None,), init="zeros"),
            "wq": P((dc, hq, hd), ("embed", "heads", None)),
            "wk": P((dc, hq, hd), ("embed", "heads", None)),
            "wv": P((dc, hq, hd), ("embed", "heads", None)),
            "wo": P((hq, hd, cfg.d_model), ("heads", None, "embed")),
            "ln_mlp": P((cfg.d_model,), (None,), init="zeros"),
            "mlp": decls_mlp(cfg.d_model, cfg.d_ff),
        }

    def _mamba_block_decls(self) -> dict:
        return {"ln": P((self.cfg.d_model,), (None,), init="zeros"),
                "mamba": decls_mamba(self.cfg)}

    def decls(self) -> dict:
        cfg = self.cfg
        return {
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                       scale=1.0),
            "final_norm": P((cfg.d_model,), (None,), init="zeros"),
            "lm_head": P((cfg.d_model, cfg.vocab), ("embed", "vocab")),
            "shared_attn": self._shared_decls(),
            "layers": _stack(self._mamba_block_decls(), cfg.n_layers),
        }

    def init(self, key):
        return init_tree(self.decls(), key)

    def abstract(self, dtype=None):
        return abstract_tree(self.decls(), dtype)

    def axes(self):
        return axes_tree(self.decls())

    def n_params(self) -> int:
        return count_params(self.decls())

    # -- shared attention block ---------------------------------------------------
    def _shared_attn(self, p: dict, x: Array, x0: Array, positions: Array,
                     cache: dict | None = None,
                     fill_window: int | None = None):
        """Full-attention block on concat(x, x0); returns (delta_x, cache)."""
        cfg, ctx = self.cfg, self.ctx
        hd = self.attn_head_dim
        scale = 1.0 / math.sqrt(hd)
        xc = jnp.concatenate([x, x0], axis=-1)
        xc = rms_norm(xc, p["ln_in"])

        proj = lambda w: jnp.einsum("bsd,dhk->bshk", xc, w.astype(x.dtype))
        q, k, v = proj(p["wq"]), proj(p["wk"]), proj(p["wv"])
        q = ctx.constrain(q, "batch", None, "heads", None)
        k = ctx.constrain(k, "batch", None, "heads", None)
        ang = rope_angles(positions, hd, cfg.rope_theta)
        q, k = apply_rope(q, ang), apply_rope(k, ang)

        new_cache = None
        if cache is None:
            S = x.shape[1]
            o = chunked_attention(q, k, v, scale=scale,
                                  q_chunk=min(cfg.attn_chunk_q, S),
                                  k_chunk=min(cfg.attn_chunk_k, S),
                                  ctx=ctx)
            if fill_window is not None:
                # Ring-buffer fill: keep the last min(W, S) positions at
                # their pos % W slots.
                W = fill_window
                n_keep = min(W, S)
                keep_pos = jnp.arange(S - n_keep, S)
                slots = keep_pos % W
                B = x.shape[0]
                mk = jnp.zeros((B, W) + k.shape[2:], jnp.bfloat16)
                mk = mk.at[:, slots].set(
                    k[:, -n_keep:].astype(jnp.bfloat16))
                mv = jnp.zeros((B, W) + v.shape[2:], jnp.bfloat16)
                mv = mv.at[:, slots].set(
                    v[:, -n_keep:].astype(jnp.bfloat16))
                pos_buf = jnp.full((B, W), -10 ** 9, jnp.int32)
                pos_buf = pos_buf.at[:, slots].set(
                    jnp.broadcast_to(keep_pos, (B, n_keep)))
                new_cache = dict(k=mk, v=mv, pos=pos_buf,
                                 len=jnp.full((B,), S, jnp.int32))
        else:
            # Ring buffer: slot = pos % W; valid entries are the last
            # min(len, W) positions.
            W = cache["k"].shape[1]
            pos = cache["len"]                              # (B,) tokens so far
            slot = pos % W
            upd = lambda c, u: jax.vmap(
                lambda cc, uu, i: jax.lax.dynamic_update_slice(
                    cc, uu, (i, 0, 0)))(c, u.astype(c.dtype), slot)
            k_cache = upd(cache["k"], k)
            v_cache = upd(cache["v"], v)
            slot_pos = cache["pos"].at[jnp.arange(pos.shape[0]), slot].set(
                pos)
            valid = (slot_pos <= pos[:, None]) & (
                slot_pos > (pos[:, None] - W))
            qh = q[:, 0].astype(jnp.bfloat16)               # (B,H,hd)
            logits = jnp.einsum("bhd,bkhd->bhk", qh,
                                k_cache.astype(jnp.bfloat16),
                                preferred_element_type=jnp.float32) * scale
            logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
            pr = jax.nn.softmax(logits, axis=-1)
            o = jnp.einsum("bhk,bkhd->bhd", pr.astype(jnp.bfloat16),
                           v_cache.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32)[:, None]
            o = o.astype(x.dtype)
            new_cache = dict(k=k_cache, v=v_cache, pos=slot_pos,
                             len=pos + 1)

        o = ctx.constrain(o, "batch", None, "heads", None)
        h = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
        x = x + h
        x = x + mlp_forward(p["mlp"], rms_norm(x, p["ln_mlp"]), cfg.act,
                            self.ctx)
        return x, new_cache

    # -- forward ---------------------------------------------------------------------
    def forward(self, params, tokens: Array, positions=None,
                extra_embeds=None) -> tuple[Array, Array]:
        cfg = self.cfg
        every = cfg.hybrid_attn_every
        if positions is None:
            positions = jnp.arange(tokens.shape[1])[None, :]
        x0 = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        x0 = self.ctx.constrain(x0, "batch", "seq", None)
        x = x0

        def mamba_body(h, layer_params):
            out, _ = mamba_forward(
                layer_params["mamba"],
                rms_norm(h, layer_params["ln"]), cfg, self.ctx)
            return h + out, None

        if cfg.remat:
            mamba_body = jax.checkpoint(mamba_body)

        # Scan mamba layers group-by-group; shared attention in between.
        stacked = params["layers"]
        n_groups = cfg.n_layers // every
        rem = cfg.n_layers - n_groups * every
        for g in range(n_groups):
            group = jax.tree.map(
                lambda a: jax.lax.slice_in_dim(a, g * every, (g + 1) * every),
                stacked)
            x, _ = jax.lax.scan(mamba_body, x, group)
            x, _ = self._shared_attn(params["shared_attn"], x, x0, positions)
        if rem:
            tail = jax.tree.map(
                lambda a: jax.lax.slice_in_dim(a, n_groups * every,
                                               cfg.n_layers), stacked)
            x, _ = jax.lax.scan(mamba_body, x, tail)

        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm_head"].astype(x.dtype))
        logits = self.ctx.constrain(logits.astype(jnp.float32),
                                    "batch", None, "vocab")
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch: dict) -> tuple[Array, dict]:
        logits, _ = self.forward(params, batch["tokens"])
        targets = batch["tokens"][:, 1:]
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        nll = -jnp.take_along_axis(
            logp, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
        ce = nll.mean()
        zl = 1e-4 * jnp.square(jax.nn.logsumexp(logits[:, :-1],
                                                axis=-1)).mean()
        return ce + zl, {"ce": ce, "aux": jnp.zeros(()), "zloss": zl}

    # -- serving -----------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        W = min(ATTN_WINDOW, max_len)
        hq, hd = cfg.n_heads, self.attn_head_dim
        one_m = init_mamba_state(cfg, batch, dtype)
        attn_one = dict(
            k=jnp.zeros((batch, W, hq, hd), dtype),
            v=jnp.zeros((batch, W, hq, hd), dtype),
            pos=jnp.full((batch, W), -10 ** 9, jnp.int32),
            len=jnp.zeros((batch,), jnp.int32))
        return {
            "mamba": jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (cfg.n_layers,) + a.shape).copy(), one_m),
            "attn": jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (self.n_invocations,) + a.shape).copy(), attn_one),
            "x0": jnp.zeros((batch, cfg.d_model), dtype),
        }

    def cache_axes(self):
        return {
            "mamba": dict(conv=("layers", "batch", None, "mlp"),
                          s=("layers", "batch", "heads", None, None)),
            "attn": dict(k=(None, "batch", None, "heads", "head_dim"),
                         v=(None, "batch", None, "heads", "head_dim"),
                         pos=(None, "batch", None),
                         len=(None, "batch")),
            "x0": ("batch", None),
        }

    def prefill(self, params, tokens: Array, positions: Array,
                max_len: int, extra_embeds=None):
        """Full-prompt pass -> (last logits, {mamba states, attn ring
        caches, x0 tail})."""
        cfg = self.cfg
        every = cfg.hybrid_attn_every
        W = min(ATTN_WINDOW, max_len)
        if positions is None:
            positions = jnp.arange(tokens.shape[1])[None, :]
        x0 = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        x = x0

        def mamba_body(h, layer_params):
            out, st = mamba_forward(
                layer_params["mamba"], rms_norm(h, layer_params["ln"]),
                cfg, self.ctx)
            return h + out, st

        stacked = params["layers"]
        n_groups = cfg.n_layers // every
        rem = cfg.n_layers - n_groups * every
        mamba_states, attn_caches = [], []
        for g in range(n_groups):
            sl = lambda a: jax.lax.slice_in_dim(a, g * every,
                                                (g + 1) * every)
            x, st = jax.lax.scan(mamba_body, x, jax.tree.map(sl, stacked))
            mamba_states.append(st)
            x, c = self._shared_attn(params["shared_attn"], x, x0,
                                     positions, fill_window=W)
            attn_caches.append(c)
        if rem:
            sl = lambda a: jax.lax.slice_in_dim(a, n_groups * every,
                                                cfg.n_layers)
            x, st = jax.lax.scan(mamba_body, x, jax.tree.map(sl, stacked))
            mamba_states.append(st)

        cache = {
            "mamba": jax.tree.map(lambda *a: jnp.concatenate(a, axis=0),
                                  *mamba_states),
            "attn": jax.tree.map(lambda *a: jnp.stack(a, axis=0),
                                 *attn_caches),
            "x0": x0[:, -1],
        }
        x = rms_norm(x[:, -1:], params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm_head"].astype(x.dtype))
        return logits.astype(jnp.float32), cache

    def decode_step(self, params, cache, tokens: Array,
                    positions: Array) -> tuple[Array, dict]:
        cfg = self.cfg
        every = cfg.hybrid_attn_every
        x0 = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        x = x0

        def mamba_body(h, xs):
            layer_params, layer_state = xs
            out, new_state = mamba_forward(
                layer_params["mamba"], rms_norm(h, layer_params["ln"]),
                cfg, self.ctx, state=layer_state)
            return h + out, new_state

        stacked, states = params["layers"], cache["mamba"]
        n_groups = cfg.n_layers // every
        rem = cfg.n_layers - n_groups * every
        new_mamba, new_attn = [], []
        for g in range(n_groups):
            sl = lambda a: jax.lax.slice_in_dim(a, g * every,
                                                (g + 1) * every)
            x, ns = jax.lax.scan(mamba_body, x,
                                 (jax.tree.map(sl, stacked),
                                  jax.tree.map(sl, states)))
            new_mamba.append(ns)
            attn_cache_g = jax.tree.map(lambda a: a[g], cache["attn"])
            x, nc = self._shared_attn(params["shared_attn"], x,
                                      x0, positions, cache=attn_cache_g)
            new_attn.append(nc)
        if rem:
            sl = lambda a: jax.lax.slice_in_dim(a, n_groups * every,
                                                cfg.n_layers)
            x, ns = jax.lax.scan(mamba_body, x,
                                 (jax.tree.map(sl, stacked),
                                  jax.tree.map(sl, states)))
            new_mamba.append(ns)

        new_cache = {
            "mamba": jax.tree.map(
                lambda *a: jnp.concatenate(a, axis=0), *new_mamba),
            "attn": jax.tree.map(lambda *a: jnp.stack(a, axis=0),
                                 *new_attn),
            "x0": x0[:, 0] if x0.ndim == 3 else x0,
        }
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm_head"].astype(x.dtype))
        return logits.astype(jnp.float32), new_cache
