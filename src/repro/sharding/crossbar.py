"""Distributed lowering of the fused analog IMPACT crossbar.

The paper's Fig. 14 modular scaling IS a ``psum`` decomposition (see
``rules.py``): partial clauses from the R literal row-shards are combined
by a digital AND, and partial class currents from the S class row-shards
are digitised per shard (ADC) and summed digitally.  This module makes
that correspondence executable: a ``shard_map`` over the ``model`` mesh
axis places ``R // model`` clause row-shards and ``S // model`` class
row-shards on each device, the batch is sharded over the data axes
(``("pod", "data")`` when present), and

* the digital AND becomes ``psum`` of per-device partial CSA violation
  bits (a column fires iff NO shard on ANY device sees current above the
  CSA threshold);
* the per-shard ADC + digital adder tree becomes ``psum`` of per-device
  partial class currents (exact — the class read is linear in the drive).

Each device runs the existing Pallas ``crossbar_mvm`` kernel on its local
shards (``impl="xla"`` swaps in the einsum oracle for A/B parity runs),
so the single-device kernels and the distributed lowering share one
numerical core.  ``kernels.ops.fused_impact`` routes here when a mesh is
passed and ``shardable`` holds; otherwise it falls back to the
single-device fused kernel, so call sites never have to branch.

Parity contract (enforced in ``tests/test_crossbar_sharding.py``): CSA
bits and argmax predictions are EXACTLY equal to the single-device kernel
and the einsum oracle on ideal devices; raw class-current scores are
float sums whose association order changes under ``psum``, so they agree
to tight rtol.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import compat
from ..kernels import ops, ref
from .rules import crossbar_rules

Array = jax.Array


def model_size(mesh) -> int:
    """Size of the ``model`` axis (1 when absent or no mesh)."""
    if mesh is None:
        return 1
    return int(mesh.shape.get("model", 1))


def data_axes(mesh) -> tuple[str, ...]:
    """The batch axes of ``mesh`` actually present, in rule-table order."""
    if mesh is None:
        return ()
    return tuple(a for a in crossbar_rules(mesh)["batch"]
                 if a in mesh.shape)


def shardable(mesh, n_row_shards: int, n_class_shards: int) -> bool:
    """True when the (R, S) shard grid can be placed on ``mesh``'s model
    axis: both shard counts must divide the axis so every device holds an
    equal, non-empty slice (the fallback for indivisible grids is the
    single-device kernel — correctness never depends on the mesh)."""
    m = model_size(mesh)
    return (m > 1 and n_row_shards % m == 0 and n_class_shards % m == 0)


def _local_column_currents(drive_loc: Array, ci_loc: Array, *, impl: str,
                           interpret: bool | None) -> Array:
    """Per-shard clause-crossbar column currents on ONE device.

    drive_loc (B, R_loc, tr) f32; ci_loc (R_loc, C, tr, tc) f32 cell read
    currents -> (B, R_loc, C*tc) f32.  Runs the same Pallas ``crossbar_mvm``
    kernel (or einsum oracle) per local shard as the single-device staged
    path, so per-shard currents are bit-identical across lowerings.
    """
    R_loc, C, tr, tc = ci_loc.shape
    cols = []
    for r in range(R_loc):                      # static local-shard unroll
        cur = ci_loc[r].transpose(1, 0, 2).reshape(tr, C * tc)
        cols.append(ops.crossbar_mvm(drive_loc[:, r], cur, v_read=1.0,
                                     cutoff=0.0, impl=impl,
                                     interpret=interpret))
    return jnp.stack(cols, axis=1)


def fused_impact_shmap(literals: Array, clause_i: Array, nonempty: Array,
                       class_i: Array, *, thresh: float, mesh,
                       impl: str = "pallas", interpret: bool | None = None,
                       valid: Array | None = None, meter: bool = False):
    """Sharded analog inference: literals (B, K) -> class currents (B, M).

    Same contract as ``ops.fused_impact`` (which is the normal entry
    point — it calls here when ``shardable`` holds).  With ``meter=True``
    additionally returns per-lane summed clause / class crossbar currents
    (B,) f32 — the quantities ``impact.energy.per_lane_read_energy``
    converts to joules — computed with the same valid-lane masking as the
    single-device staged path, so per-request bills sum to the batch
    meter under sharding.
    """
    B, K = literals.shape
    R, C, tr, tc = clause_i.shape
    S, sr, M = class_i.shape
    n = C * tc
    assert nonempty.shape == (n,), (nonempty.shape, n)
    assert shardable(mesh, R, S), (mesh, R, S)

    dp = data_axes(mesh)
    n_data = math.prod(mesh.shape[a] for a in dp) if dp else 1
    # Batch shards over the data axes only when it divides them; an
    # indivisible batch replicates (every data shard computes the full
    # batch) rather than failing — the model axis still shards.
    bspec = dp if (dp and B % n_data == 0) else None

    lit = ref.pad_to(literals.astype(jnp.float32), R * tr, axis=1, value=1)
    drive = (1.0 - lit).reshape(B, R, tr)       # padding rows float ('Z')
    ne = nonempty.astype(jnp.int8)
    vmask = (jnp.ones((B,), bool) if valid is None
             else valid.astype(bool))

    def local_fn(drive_loc, ci_loc, ne_loc, wi_loc, valid_loc):
        # drive_loc (B_loc, R_loc, tr); ci_loc (R_loc, C, tr, tc);
        # wi_loc (S_loc, sr, M); everything else replicated over "model".
        i_col = _local_column_currents(drive_loc, ci_loc, impl=impl,
                                       interpret=interpret)
        # Partial CSA bits: count of local shards whose column current
        # trips the sense amp; the cross-device psum is Fig. 14's digital
        # AND (a clause fires iff the total violation count is zero).
        viol = (i_col >= thresh).astype(jnp.int32).sum(axis=1)
        viol = jax.lax.psum(viol, "model")
        fired = jnp.logical_and(viol == 0, ne_loc.astype(bool)[None, :])
        fired = jnp.logical_and(fired, valid_loc[:, None])

        # Class stage: this device drives only its local S_loc row-shards
        # of the class crossbar with the matching slice of clause bits.
        S_loc = wi_loc.shape[0]
        drv = ref.pad_to(fired.astype(jnp.float32), S * sr, axis=1)
        drv = drv[:, :S * sr].reshape(-1, S, sr)
        lo = jax.lax.axis_index("model") * S_loc
        mine = jax.lax.dynamic_slice_in_dim(drv, lo, S_loc, axis=1)
        i_cls = jnp.stack(
            [ops.crossbar_mvm(mine[:, s], wi_loc[s], v_read=1.0, cutoff=0.0,
                              impl=impl, interpret=interpret)
             for s in range(S_loc)], axis=1)    # (B_loc, S_loc, M)
        # Per-shard ADC + digital add == psum of partial class currents.
        scores = jax.lax.psum(i_cls.sum(axis=1), "model")
        if not meter:
            return (scores,)
        i_col = i_col * valid_loc[:, None, None].astype(i_col.dtype)
        i_cl_lane = jax.lax.psum(i_col.sum(axis=(1, 2)), "model")
        i_cs_lane = jax.lax.psum(i_cls.sum(axis=(1, 2)), "model")
        return scores, i_cl_lane, i_cs_lane

    out_specs = ((P(bspec, None),) if not meter
                 else (P(bspec, None), P(bspec), P(bspec)))
    fn = compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, "model", None), P("model", None, None, None),
                  P(None), P("model", None, None), P(bspec)),
        out_specs=out_specs, check_vma=False)
    out = fn(drive, clause_i.astype(jnp.float32), ne,
             class_i.astype(jnp.float32), vmask)
    return out[0] if not meter else out
