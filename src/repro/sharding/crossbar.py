"""Distributed lowering of the fused analog IMPACT crossbar.

The paper's Fig. 14 modular scaling IS a ``psum`` decomposition (see
``rules.py``): partial clauses from the R literal row-shards are combined
by a digital AND, and partial class currents from the S class row-shards
are digitised per shard (ADC) and summed digitally.  This module makes
that correspondence executable: a ``shard_map`` over the ``model`` mesh
axis places clause row-shards and/or class row-shards on each device, the
batch is sharded over the data axes (``("pod", "data")`` when present),
and

* the digital AND becomes ``psum`` of per-device partial CSA violation
  bits (a column fires iff NO shard on ANY device sees current above the
  CSA threshold);
* the per-shard ADC + digital adder tree becomes ``psum`` of per-device
  partial class currents (exact — the class read is linear in the drive).

**Asymmetric plans.**  R and S need not both divide the model axis: when
only one does, that operand shards and the other crossbar is REPLICATED —
every device evaluates the replicated stage in full (its inputs are fully
known on-device after the other stage's psum), so no combine is needed
for it.  ``shard_plan`` picks the placement; ``(True, True)`` is the
PR-3 fully-sharded grid, ``(True, False)`` / ``(False, True)`` are the
R-only / S-only asymmetric plans, and ``None`` means no usable plan
(fall back to the single-device kernel — correctness never depends on
the mesh).

Each device runs the existing Pallas ``crossbar_mvm`` kernel on its local
shards (``impl="xla"`` swaps in the einsum oracle for A/B parity runs),
so the single-device kernels and the distributed lowering share one
numerical core.  ``kernels.ops.fused_impact`` routes here when a mesh is
passed and a plan exists; the compiled-session runtime
(``impact.runtime``) resolves the plan ONCE at ``compile()`` time from
``RuntimeSpec.topology`` instead of re-deriving it per call.

**Energy metering.**  ``meter=True`` psums the per-lane summed column
currents of both crossbars across the model axis — the partial stages
each device materializes anyway, billed exactly once (a replicated
operand's currents are already the full quantity on every device, so
its psum is skipped).  This one lowering backs BOTH metering modes of a
sharded ``RuntimeSpec`` (``"staged"`` and ``"fused"``): on a mesh the
currents exist per device regardless, so there is no staged-vs-fused
distinction to make — the in-kernel fused meter is a single-device
specialization, pinned equal to this path by the parity suites.

Parity contract (enforced in ``tests/test_crossbar_sharding.py``): CSA
bits and argmax predictions are EXACTLY equal to the single-device kernel
and the einsum oracle on ideal devices; raw class-current scores are
float sums whose association order changes under ``psum``, so they agree
to tight rtol.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import compat
from ..kernels import ops, ref
from .rules import crossbar_rules

Array = jax.Array

#: Topology shard modes accepted by ``shard_plan`` / ``Topology.shard``.
SHARD_MODES = ("auto", "both", "r", "s", "none")


def model_size(mesh) -> int:
    """Size of the ``model`` axis (1 when absent or no mesh)."""
    if mesh is None:
        return 1
    return int(mesh.shape.get("model", 1))


def data_axes(mesh) -> tuple[str, ...]:
    """The batch axes of ``mesh`` actually present, in rule-table order."""
    if mesh is None:
        return ()
    return tuple(a for a in crossbar_rules(mesh)["batch"]
                 if a in mesh.shape)


def shard_plan(mesh, n_row_shards: int, n_class_shards: int,
               mode: str = "auto") -> tuple[bool, bool] | None:
    """Resolve the (shard_r, shard_s) placement of an (R, S) grid on
    ``mesh``'s model axis, or ``None`` when nothing can shard.

    ``mode``: ``"auto"`` shards whichever of R / S divides the axis
    (both when both do); ``"both"`` / ``"r"`` / ``"s"`` demand that
    placement and raise ``ValueError`` when the shard count doesn't
    divide the axis (compile-time validation for explicit topologies);
    ``"none"`` always returns ``None`` (force single-device).
    """
    if mode not in SHARD_MODES:
        raise ValueError(f"shard mode must be one of {SHARD_MODES}, "
                         f"got {mode!r}")
    m = model_size(mesh)
    if mode == "none":
        return None
    if m <= 1:
        if mode == "auto":
            return None
        raise ValueError(
            f"shard mode {mode!r} demands a sharded placement but the "
            f"mesh has no model axis larger than 1 (model={m})")
    r_ok = n_row_shards % m == 0
    s_ok = n_class_shards % m == 0
    if mode == "auto":
        return (r_ok, s_ok) if (r_ok or s_ok) else None
    want_r = mode in ("both", "r")
    want_s = mode in ("both", "s")
    if (want_r and not r_ok) or (want_s and not s_ok):
        raise ValueError(
            f"shard mode {mode!r} needs "
            f"{'R=' + str(n_row_shards) if want_r and not r_ok else ''}"
            f"{' and ' if want_r and not r_ok and want_s and not s_ok else ''}"
            f"{'S=' + str(n_class_shards) if want_s and not s_ok else ''} "
            f"to divide the model axis ({m} devices)")
    return (want_r, want_s)


def shardable(mesh, n_row_shards: int, n_class_shards: int) -> bool:
    """True when ANY shard plan exists for the (R, S) grid on ``mesh`` —
    fully sharded or asymmetric (one operand replicated)."""
    return shard_plan(mesh, n_row_shards, n_class_shards) is not None


def _local_column_currents(drive_loc: Array, ci_loc: Array, *, impl: str,
                           interpret: bool | None) -> Array:
    """Per-shard clause-crossbar column currents on ONE device.

    drive_loc (B, R_loc, tr) f32; ci_loc (R_loc, C, tr, tc) f32 cell read
    currents -> (B, R_loc, C*tc) f32.  Runs the same Pallas ``crossbar_mvm``
    kernel (or einsum oracle) per local shard as the single-device staged
    path, so per-shard currents are bit-identical across lowerings.
    """
    R_loc, C, tr, tc = ci_loc.shape
    cols = []
    for r in range(R_loc):                      # static local-shard unroll
        cur = ci_loc[r].transpose(1, 0, 2).reshape(tr, C * tc)
        cols.append(ops.crossbar_mvm(drive_loc[:, r], cur, v_read=1.0,
                                     cutoff=0.0, impl=impl,
                                     interpret=interpret))
    return jnp.stack(cols, axis=1)


def _local_column_currents_packed(drive_loc: Array, pb_loc: Array,
                                  lv_loc: Array, *, impl: str,
                                  interpret: bool | None) -> Array:
    """Packed-operand twin of ``_local_column_currents``.

    drive_loc (B, R_loc, 4, tr4) bitplane-major drive; pb_loc
    (R_loc, C, tr4, tc) uint8 packed codes; lv_loc (2,) dequant levels
    -> (B, R_loc, C*tc) f32.  Each bitplane is dequantized on-device and
    driven through the same ``crossbar_mvm`` kernel, so the psum
    structure above this function is untouched by packing.
    """
    R_loc, C, tr4, tc = pb_loc.shape
    cols = []
    for r in range(R_loc):                      # static local-shard unroll
        codes = pb_loc[r].transpose(1, 0, 2).reshape(tr4, C * tc)
        codes = codes.astype(jnp.int32)
        i_col = None
        for j in range(4):                      # static bitplane unroll
            plane = (codes >> (2 * j)) & 3
            cur = jnp.where(plane == 2, lv_loc[1],
                            jnp.where(plane == 1, lv_loc[0], 0.0))
            part = ops.crossbar_mvm(drive_loc[:, r, j],
                                    cur.astype(jnp.float32), v_read=1.0,
                                    cutoff=0.0, impl=impl,
                                    interpret=interpret)
            i_col = part if i_col is None else i_col + part
        cols.append(i_col)
    return jnp.stack(cols, axis=1)


def fused_impact_shmap(literals: Array, clause_i: Array | None,
                       nonempty: Array, class_i: Array, *, thresh: float,
                       mesh, impl: str = "pallas",
                       interpret: bool | None = None,
                       valid: Array | None = None, meter: bool = False,
                       shard_r: bool = True, shard_s: bool = True,
                       packed=None, packed_tr: int | None = None,
                       lane_cols: Array | None = None):
    """Sharded analog inference: literals (B, K) -> class currents (B, M).

    Same contract as ``ops.fused_impact`` (which is the normal entry
    point — it calls here when ``shard_plan`` finds a placement).
    ``(shard_r, shard_s)`` is that placement: a False entry replicates
    the corresponding crossbar on every device and skips its psum (the
    replicated stage computes identical values everywhere).  With
    ``meter=True`` additionally returns per-lane summed clause / class
    crossbar currents (B,) f32 — the quantities
    ``impact.energy.per_lane_read_energy`` converts to joules — computed
    with the same valid-lane masking as the single-device staged path,
    so per-request bills sum to the batch meter under every plan.

    ``packed`` (a ``kernels.packing.PackedClause``) swaps the clause
    operand for the 2-bit bitplane layout: the codes shard over the
    model axis exactly like the f32 currents (same axis-0 placement, so
    the packed operands ride the same psum lowering) and each device
    dequantizes only its local shards.  ``packed_tr`` is the unpacked
    per-shard row count; ``clause_i`` must be None in packed mode.

    ``lane_cols`` (B, C*tc) bool is the co-residency tenant mask (see
    ``kernels.ref.coresident_lane_mask``): ANDed into the fired bits
    AFTER the cross-device violation psum and BEFORE the class drive,
    so a lane's spuriously-fired foreign columns (0 A < CSA threshold)
    never reach foreign class rows.  It shards over the batch axes like
    ``valid`` and is replicated over ``model``, which composes with all
    four shard plans unchanged — the clause psum is mask-independent and
    the class psum sees already-masked drives.
    """
    B, K = literals.shape
    if packed is not None:
        assert clause_i is None and packed_tr is not None
        R, C, tr4, tc = packed.bits.shape
        tr = packed_tr
    else:
        R, C, tr, tc = clause_i.shape
    S, sr, M = class_i.shape
    n = C * tc
    m = model_size(mesh)
    assert nonempty.shape == (n,), (nonempty.shape, n)
    assert shard_r or shard_s, "no-op plan: use the single-device kernel"
    assert not shard_r or R % m == 0, (R, m)
    assert not shard_s or S % m == 0, (S, m)

    dp = data_axes(mesh)
    n_data = math.prod(mesh.shape[a] for a in dp) if dp else 1
    # Batch shards over the data axes only when it divides them; an
    # indivisible batch replicates (every data shard computes the full
    # batch) rather than failing — the model axis still shards.
    bspec = dp if (dp and B % n_data == 0) else None

    lit = ref.pad_to(literals.astype(jnp.float32), R * tr, axis=1, value=1)
    drive = (1.0 - lit).reshape(B, R, tr)       # padding rows float ('Z')
    rspec = "model" if shard_r else None
    if packed is not None:
        # Bitplane-major drive (B, R, 4, tr4): plane j row q drives
        # literal row 4q+j of shard r; rows past tr pad with 0 V.
        drive = ref.pad_to(drive, 4 * tr4, axis=2, value=0.0)
        drive = drive.reshape(B, R, tr4, 4).transpose(0, 1, 3, 2)
        clause_op = packed.bits
        levels = packed.levels.astype(jnp.float32)
        drive_spec = P(bspec, rspec, None, None)
    else:
        clause_op = clause_i.astype(jnp.float32)
        levels = jnp.zeros((2,), jnp.float32)   # unused, keeps one wiring
        drive_spec = P(bspec, rspec, None)
    ne = nonempty.astype(jnp.int8)
    vmask = (jnp.ones((B,), bool) if valid is None
             else valid.astype(bool))
    lcols = (jnp.ones((B, n), bool) if lane_cols is None
             else lane_cols.astype(bool))        # all-ones keeps one wiring

    def local_fn(drive_loc, ci_loc, ne_loc, wi_loc, valid_loc, lv_loc,
                 lc_loc):
        # drive_loc (B_loc, R_loc, tr) — or (B_loc, R_loc, 4, tr4)
        # packed; ci_loc (R_loc, C, tr, tc) f32 — or (R_loc, C, tr4, tc)
        # uint8 packed codes with lv_loc the dequant levels; wi_loc
        # (S_loc, sr, M); R_loc/S_loc are full R/S for a replicated
        # operand; everything else replicated over "model".
        if packed is not None:
            i_col = _local_column_currents_packed(drive_loc, ci_loc, lv_loc,
                                                  impl=impl,
                                                  interpret=interpret)
        else:
            i_col = _local_column_currents(drive_loc, ci_loc, impl=impl,
                                           interpret=interpret)
        # Partial CSA bits: count of local shards whose column current
        # trips the sense amp; with R sharded, the cross-device psum is
        # Fig. 14's digital AND (a clause fires iff the total violation
        # count is zero); with R replicated the local count is already
        # total, identical on every device.
        viol = (i_col >= thresh).astype(jnp.int32).sum(axis=1)
        if shard_r:
            viol = jax.lax.psum(viol, "model")
        fired = jnp.logical_and(viol == 0, ne_loc.astype(bool)[None, :])
        fired = jnp.logical_and(fired, valid_loc[:, None])
        fired = jnp.logical_and(fired, lc_loc)  # co-residency tenant mask

        # Class stage: with S sharded, this device drives only its local
        # S_loc row-shards with the matching slice of clause bits and
        # the per-shard ADC + digital add is the psum below; with S
        # replicated it drives the whole class crossbar (fired is fully
        # known on-device) and no combine is needed.
        S_loc = wi_loc.shape[0]
        drv = ref.pad_to(fired.astype(jnp.float32), S * sr, axis=1)
        drv = drv[:, :S * sr].reshape(-1, S, sr)
        if shard_s:
            lo = jax.lax.axis_index("model") * S_loc
            mine = jax.lax.dynamic_slice_in_dim(drv, lo, S_loc, axis=1)
        else:
            mine = drv
        i_cls = jnp.stack(
            [ops.crossbar_mvm(mine[:, s], wi_loc[s], v_read=1.0, cutoff=0.0,
                              impl=impl, interpret=interpret)
             for s in range(S_loc)], axis=1)    # (B_loc, S_loc, M)
        scores = i_cls.sum(axis=1)
        if shard_s:
            scores = jax.lax.psum(scores, "model")
        if not meter:
            return (scores,)
        # Per-lane meters: psum exactly the partial stages — a
        # replicated stage's currents are already the full quantity on
        # every device, so psumming them would bill m-fold.
        i_col = i_col * valid_loc[:, None, None].astype(i_col.dtype)
        i_cl_lane = i_col.sum(axis=(1, 2))
        if shard_r:
            i_cl_lane = jax.lax.psum(i_cl_lane, "model")
        i_cs_lane = i_cls.sum(axis=(1, 2))
        if shard_s:
            i_cs_lane = jax.lax.psum(i_cs_lane, "model")
        return scores, i_cl_lane, i_cs_lane

    out_specs = ((P(bspec, None),) if not meter
                 else (P(bspec, None), P(bspec), P(bspec)))
    fn = compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(drive_spec,
                  P(rspec, None, None, None),
                  P(None),
                  P("model" if shard_s else None, None, None),
                  P(bspec),
                  P(None),
                  P(bspec, None)),
        out_specs=out_specs, check_vma=False)
    out = fn(drive, clause_op, ne, class_i.astype(jnp.float32), vmask,
             levels, lcols)
    return out[0] if not meter else out
