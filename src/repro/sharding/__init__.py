"""Distribution: logical-axis rule tables (see rules.py docstring)."""
from .rules import act_rules, merged_rules, opt_rules, param_rules

__all__ = ["param_rules", "opt_rules", "act_rules", "merged_rules"]
