"""Distribution: logical-axis rule tables (see rules.py docstring) and the
shard_map lowering of the IMPACT crossbar grid (crossbar.py).

``crossbar`` is intentionally not imported here: it pulls in
``kernels.ops`` (which lazily imports it back), so eager re-export would
make package import order load-bearing.  Import it explicitly:
``from repro.sharding import crossbar``.
"""
from .rules import (act_rules, crossbar_rules, merged_rules, opt_rules,
                    param_rules)

__all__ = ["param_rules", "opt_rules", "act_rules", "merged_rules",
           "crossbar_rules"]
