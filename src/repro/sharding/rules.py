"""Logical-axis -> mesh-axis rule tables.

One table serves all 10 architectures because ``ShardCtx`` applies rules
with divisibility fallbacks per tensor (e.g. "experts" -> "model" only when
the expert count divides the model axis; otherwise the expert hidden dim
picks up "model" — grok's 8 experts get tensor parallelism inside each
expert, deepseek's 64 get expert parallelism, from the same table).

Three tables:

* ``param_rules``    — weights.  ``zero3=True`` additionally shards the
  d_model ("embed") dims over the data axes (ZeRO-3 / FSDP; grok-314b).
* ``opt_rules``      — optimizer moments: always ZeRO (sharded over data),
  regardless of the param posture (ZeRO-1 when params are replicated).
* ``act_rules``      — activations: batch over (pod, data), sequence over
  "model" at layer boundaries (Megatron-style sequence parallelism: the
  model-axis all-reduce of TP decomposes into reduce-scatter + all-gather
  around the norm), heads/mlp/experts over "model" inside blocks.

The Fig. 14 correspondence (see DESIGN.md): sharding the literal axis over
"model" and psumming violation counts IS the paper's partial-clause digital
AND; sharding the clause axis and psumming partial class sums IS the ADC +
digital adder tree.  This is no longer just documentation: the IMPACT
crossbar path has a real ``shard_map`` lowering in ``sharding/crossbar.py``
(``fused_impact_shmap``), reached through ``kernels.ops.fused_impact(...,
mesh=...)`` and ``IMPACTSystem.predict/infer_step/infer_with_report``.
``crossbar_rules`` below is its logical-axis table: the R literal
row-shards and S class row-shards ride the "model" axis, the batch rides
the data axes, and the two digital combine steps are the two psums.
"""
from __future__ import annotations

from typing import Any

DP_SINGLE = ("data",)
DP_MULTI = ("pod", "data")


def _dp(mesh) -> tuple[str, ...]:
    return DP_MULTI if "pod" in mesh.shape else DP_SINGLE


def param_rules(mesh, *, zero3: bool = False) -> dict[str, Any]:
    dp = _dp(mesh)
    return {
        "vocab": "model",
        "embed": dp if zero3 else None,
        "heads": "model",
        "kv": "model",
        "head_dim": "model",   # fallback when kv/heads don't divide model
        "mlp": "model",
        "experts": "model",
        "moe_mlp": "model",
        "layers": None,
        "batch": dp,
    }


def opt_rules(mesh) -> dict[str, Any]:
    """Optimizer state: always fully ZeRO-sharded over the data axes."""
    rules = param_rules(mesh, zero3=True)
    return rules


def act_rules(mesh, *, seq_parallel: bool = True) -> dict[str, Any]:
    dp = _dp(mesh)
    return {
        "batch": dp,
        "seq": "model" if seq_parallel else None,
        "heads": "model",
        "kv": "model",
        "head_dim": "model",
        "mlp": "model",
        "experts": "model",
        "moe_mlp": "model",
        "vocab": "model",
    }


def crossbar_rules(mesh) -> dict[str, Any]:
    """Fig. 14 -> mesh axes for the IMPACT crossbar grid (consumed by
    ``sharding/crossbar.py``): the literal row-shard axis (R) and the
    class row-shard axis (S) both map onto "model" — the digital AND of
    partial clauses is the psum of per-device CSA violation bits, the
    per-shard ADC + digital add is the psum of partial class currents —
    while the batch maps onto the data axes like every activation."""
    return {
        "batch": _dp(mesh),
        "literal_shard": "model",
        "class_shard": "model",
    }


def merged_rules(mesh, *, zero3: bool = False,
                 seq_parallel: bool = True) -> dict[str, Any]:
    """One table usable for both params and activations (model code paths
    call ``ctx.constrain`` with activation tags and ``param_shardings``
    with param tags; the tag sets only overlap on compatible entries)."""
    rules = act_rules(mesh, seq_parallel=seq_parallel)
    rules.update({k: v for k, v in param_rules(mesh, zero3=zero3).items()
                  if k not in rules})
    return rules
