"""Launch layer: mesh construction, input specs, dry-run, train/serve CLIs.

NOTE: do NOT import ``dryrun`` from here — it mutates XLA_FLAGS at import
time (512 host devices) and must only ever run as its own process.
"""
from .mesh import make_crossbar_mesh, make_debug_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_debug_mesh", "make_crossbar_mesh"]
