import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

__doc__ = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the appropriate step function is jitted against
ShapeDtypeStruct stand-ins (zero allocation) with full production
shardings, compiled for the 16x16 (single-pod, 256 chips) or 2x16x16
(two-pod, 512 chips) mesh of host devices, and the compiled artifact is
mined for the roofline inputs:

* ``memory_analysis``  -> bytes per device (proves the cell fits HBM)
* ``cost_analysis``    -> HLO FLOPs / bytes accessed
* optimized HLO text   -> collective inventory (launch/hlo.py)

Results land in ``artifacts/dryrun/<mesh>/<arch>__<shape>.json``; the
roofline benchmark and EXPERIMENTS.md read from there.

Run one cell (subprocess-friendly):
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single
Run everything:  --all [--mesh both]
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import ARCH_IDS, SHAPES, cells, get_config
from repro.launch import specs as specs_mod
from repro.launch.hlo import analyze_hlo, collective_summary, parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.models import ShardCtx, build
from repro.sharding.rules import merged_rules, opt_rules, param_rules
from repro.train.optimizer import AdamWConfig, init_state
from repro.train.step import make_train_step

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts"


def _shardings_for(tree, axes_tree, ctx: ShardCtx):
    """NamedShardings for an abstract pytree given a logical-axes tree."""
    return jax.tree.map(
        lambda sds, ax: NamedSharding(ctx.mesh, ctx.spec(sds.shape,
                                                         tuple(ax))),
        tree, axes_tree)


def _replicated(tree, mesh):
    return jax.tree.map(
        lambda _: NamedSharding(mesh, PartitionSpec()), tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               *, compile_: bool = True, mutate_cfg=None) -> dict:
    cfg = get_config(arch)
    if mutate_cfg is not None:
        cfg = mutate_cfg(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    rules = merged_rules(mesh, zero3=cfg.zero3)
    ctx = ShardCtx(mesh, rules)
    model = build(cfg, ctx)

    p_ctx = ShardCtx(mesh, param_rules(mesh, zero3=cfg.zero3))
    o_ctx = ShardCtx(mesh, opt_rules(mesh))
    t0 = time.time()

    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=jnp.dtype(cfg.opt_moment_dtype))
        params_abs = model.abstract(jnp.float32)
        param_sh = _shardings_for(params_abs, model.axes(), p_ctx)
        opt_sh = _shardings_for(params_abs, model.axes(), o_ctx)
        state_abs = jax.eval_shape(
            lambda p: init_state(p, opt_cfg), params_abs)
        state_sh = type(state_abs)(
            step=NamedSharding(mesh, PartitionSpec()),
            params=param_sh, m=opt_sh, v=opt_sh)
        batch_abs = specs_mod.train_batch_specs(cfg, shape)
        batch_sh = _shardings_for(batch_abs,
                                  specs_mod.train_batch_axes(cfg), ctx)
        step_fn = make_train_step(model, opt_cfg, grad_shardings=opt_sh)
        lowered = jax.jit(
            step_fn,
            in_shardings=(state_sh, batch_sh, None),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        ).lower(state_abs, batch_abs, jax.ShapeDtypeStruct((), jnp.int32))

    elif shape.kind == "prefill":
        params_abs = model.abstract(jnp.bfloat16)
        param_sh = _shardings_for(params_abs, model.axes(), p_ctx)
        batch_abs = specs_mod.prefill_specs(cfg, shape)
        batch_sh = _shardings_for(batch_abs, specs_mod.prefill_axes(cfg),
                                  ctx)
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        cache_sh = _shardings_for(cache_abs, model.cache_axes(), ctx)

        def prefill_step(params, batch):
            return model.prefill(params, batch["tokens"],
                                 batch.get("positions"), shape.seq_len,
                                 batch.get("extra_embeds"))
        lowered = jax.jit(
            prefill_step,
            in_shardings=(param_sh, batch_sh),
            out_shardings=(NamedSharding(mesh, PartitionSpec()), cache_sh),
        ).lower(params_abs, batch_abs)

    else:  # decode
        params_abs = model.abstract(jnp.bfloat16)
        param_sh = _shardings_for(params_abs, model.axes(), p_ctx)
        d = specs_mod.decode_specs(cfg, shape, model)
        cache_sh = _shardings_for(d["cache"], model.cache_axes(), ctx)
        dec_axes = specs_mod.decode_axes(cfg)
        tok_sh = _shardings_for(
            {"tokens": d["tokens"], "positions": d["positions"]},
            dec_axes, ctx)

        def serve_step(params, cache, tokens, positions):
            return model.decode_step(params, cache, tokens, positions)
        lowered = jax.jit(
            serve_step,
            in_shardings=(param_sh, cache_sh, tok_sh["tokens"],
                          tok_sh["positions"]),
            out_shardings=(NamedSharding(mesh, PartitionSpec()), cache_sh),
            donate_argnums=(1,),
        ).lower(params_abs, d["cache"], d["tokens"], d["positions"])

    t_lower = time.time() - t0
    record = dict(arch=arch, shape=shape_name,
                  mesh="2x16x16" if multi_pod else "16x16",
                  kind=shape.kind, lower_s=round(t_lower, 1),
                  n_params=model.n_params())
    if not compile_:
        record["compiled"] = False
        return record

    t0 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t0, 1)

    try:
        mem = compiled.memory_analysis()
        record["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:                              # pragma: no cover
        record["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        record["cost"] = {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))
                          and k in ("flops", "bytes accessed",
                                    "transcendentals", "optimal_seconds")}
    except Exception as e:                              # pragma: no cover
        record["cost"] = {"error": str(e)}
    try:
        hlo = compiled.as_text()
        ops = parse_collectives(hlo)
        record["collectives"] = collective_summary(ops)
        record["hlo_bytes"] = len(hlo)
        # Execution-weighted analysis: while-loop trip counts propagated
        # through the call graph (cost_analysis visits each body once).
        record["weighted"] = analyze_hlo(hlo)
    except Exception as e:                              # pragma: no cover
        record["collectives"] = {"error": str(e)}
    record["compiled"] = True
    return record


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: pathlib.Path | None = None, mutate_cfg=None) -> dict:
    multi = mesh_kind == "multi"
    ok_map = cells(arch)
    if not ok_map[shape_name]:
        record = dict(arch=arch, shape=shape_name,
                      mesh="2x16x16" if multi else "16x16",
                      skipped="long_500k requires sub-quadratic attention; "
                              "full-attention arch (see DESIGN.md)")
    else:
        try:
            record = lower_cell(arch, shape_name, multi,
                                mutate_cfg=mutate_cfg)
        except Exception as e:
            record = dict(arch=arch, shape=shape_name,
                          mesh="2x16x16" if multi else "16x16",
                          error=f"{type(e).__name__}: {e}",
                          traceback=traceback.format_exc()[-4000:])
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        path = out_dir / f"{arch}__{shape_name}.json"
        path.write_text(json.dumps(record, indent=1))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS / "dryrun"))
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = ([(a, s) for a in ARCH_IDS for s in SHAPES] if args.all
            else [(args.arch, args.shape)])
    for mesh_kind in meshes:
        out_dir = pathlib.Path(args.out) / (
            "2x16x16" if mesh_kind == "multi" else "16x16")
        for arch, shape_name in todo:
            rec = run_cell(arch, shape_name, mesh_kind, out_dir)
            status = ("SKIP" if "skipped" in rec
                      else "ERR " if "error" in rec else "OK  ")
            print(f"[{status}] {rec['mesh']:8s} {arch:24s} {shape_name:12s}"
                  f" lower={rec.get('lower_s', '-')}s"
                  f" compile={rec.get('compile_s', '-')}s"
                  f" flops={rec.get('cost', {}).get('flops', '-')}")
            if "error" in rec:
                print(rec.get("traceback", "")[-2000:])


if __name__ == "__main__":
    main()
