"""Optimized-HLO analysis: collective inventory + byte accounting.

``compiled.cost_analysis()`` has no collective traffic, so the roofline's
collective term is derived here by parsing the post-SPMD optimized HLO:
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op is collected with its operand bytes and replica-group
fan-out, and converted to per-device link bytes with ring-algorithm
factors:

    all-gather       (P-1)/P * output_bytes
    reduce-scatter   (P-1)/P * input_bytes
    all-reduce       2 (P-1)/P * input_bytes      (RS + AG)
    all-to-all       (P-1)/P * input_bytes
    collective-permute     input_bytes

Ops inside while-loop bodies (the scan over layers / microbatches) execute
once per iteration; HLO text does not annotate trip counts, so the parser
reports RAW per-program bytes and the caller scales loop-carried traffic by
the known scan trip counts (layers x accum) — see ``benchmarks/roofline``.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    size = _DTYPE_BYTES.get(dtype)
    if size is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * size


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    out_bytes: int
    in_bytes: int
    group_size: int
    line: str

    @property
    def link_bytes(self) -> float:
        """Per-device bytes over the interconnect (ring algorithm)."""
        p = max(self.group_size, 1)
        frac = (p - 1) / p
        if self.kind == "all-gather":
            return frac * self.out_bytes
        if self.kind == "reduce-scatter":
            return frac * self.in_bytes
        if self.kind == "all-reduce":
            return 2.0 * frac * self.in_bytes
        if self.kind == "all-to-all":
            return frac * self.in_bytes
        return float(self.in_bytes)      # collective-permute


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                kind = c
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rhs:
            continue  # counted at -start
        shapes = _SHAPE_RE.findall(rhs)
        if not shapes:
            continue
        # First shape(s) before the op name = output; shapes inside the
        # parens = operands.
        paren = rhs.index("(")
        out_shapes = _SHAPE_RE.findall(rhs[:paren])
        in_shapes = _SHAPE_RE.findall(rhs[paren:])
        out_b = sum(_shape_bytes(d, s) for d, s in out_shapes)
        in_b = sum(_shape_bytes(d, s) for d, s in in_shapes)

        g = _GROUPS_RE.search(rhs)
        if g:
            first = g.group(1).split("},{")[0]
            group_size = len([x for x in re.split("[,{}]", first) if x])
        else:
            gi = _GROUPS_IOTA_RE.search(rhs)
            group_size = int(gi.group(2)) if gi else 1
        ops.append(CollectiveOp(kind=kind, out_bytes=out_b, in_bytes=in_b,
                                group_size=group_size, line=stripped[:160]))
    return ops


# ---------------------------------------------------------------------------
# Full-module analysis with while-loop trip-count propagation
# ---------------------------------------------------------------------------
#
# XLA's cost_analysis() visits every computation ONCE — a 30-layer scan body
# counts as one layer.  Honest roofline terms need each op weighted by how
# many times it executes, so we build the call graph (while bodies with
# known_trip_count, fusions, calls, conditionals) and propagate execution
# multipliers from ENTRY.

_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\)"
    r".*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)", re.S)
_TRIP_RE = re.compile(r'known_trip_count.*?"n"\s*:\s*"?(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(
    r"(?:branch_computations=\{([^}]*)\}"
    r"|true_computation=%?([\w.\-]+), false_computation=%?([\w.\-]+))")
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_NAME_RE = re.compile(r"^%([\w.\-]+)\s*=")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_FREE_OPS = ("parameter(", "constant(", "tuple(", "get-tuple-element(",
             "bitcast(", "after-all(", "partition-id(", "iota(")


def _symtab(lines: list[str]) -> dict[str, tuple[int, list[int] | None]]:
    """name -> (total output bytes, dims if a single array else None).

    Scheduled HLO references operands by NAME ONLY, so operand sizes must
    be resolved against their defining lines.
    """
    tab: dict[str, tuple[int, list[int] | None]] = {}
    for line in lines:
        m = _LHS_NAME_RE.match(line)
        if not m:
            continue
        try:
            eq = line.index("=")
            op_paren = line.index("(", eq)
        except ValueError:
            op_paren = len(line)
        lhs = line[:op_paren]
        shapes = _SHAPE_RE.findall(lhs[lhs.index("=") + 1:])
        total = sum(_shape_bytes(d, s) for d, s in shapes)
        dims = ([int(x) for x in shapes[0][1].split(",") if x]
                if len(shapes) == 1 else None)
        tab[m.group(1)] = (total, dims)
    return tab


def _operand_names(line: str) -> list[str]:
    try:
        eq = line.index("=")
        start = line.index("(", eq)
    except ValueError:
        return []
    depth = 0
    end = start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND_RE.findall(line[start:end + 1])


def _operand_bytes(line: str, tab) -> int:
    return sum(tab.get(n, (0, None))[0] for n in _operand_names(line))


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        if line and not line[0].isspace():
            m = _COMP_HEADER_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
            if line.startswith("}"):
                cur = None
                continue
        if cur is not None and "=" in line:
            comps[cur].append(line.strip())
    return comps


def _entry_name(text: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    return m.group(1) if m else None


def _dot_flops(line: str, tab) -> int:
    eq = line.index("=")
    paren = line.index("(", eq)
    out_shapes = _SHAPE_RE.findall(line[:paren])
    if not out_shapes:
        return 0
    out_elems = 1
    for d in out_shapes[-1][1].split(","):
        if d:
            out_elems *= int(d)
    operands = _operand_names(line)
    lhs_dims = tab.get(operands[0], (0, None))[1] if operands else None
    if lhs_dims is None:
        return 0
    m = _DOT_CONTRACT_RE.search(line)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2 * out_elems * contract


def _line_out_bytes(line: str) -> int:
    try:
        eq = line.index("=")
        paren = line.index("(", eq)
    except ValueError:
        paren = len(line)
    return sum(_shape_bytes(d, s)
               for d, s in _SHAPE_RE.findall(line[:paren]))


def analyze_hlo(text: str) -> dict:
    """Execution-weighted per-device flops / HBM-traffic / collective bytes.

    flops: dot ops only (2*M*N*K), weighted by how often their computation
    runs.  bytes: operand+output sizes of top-level ops in executed (non-
    fused) computations — the post-fusion kernel-boundary HBM-traffic
    model.  collectives: ring link-bytes, execution-weighted.
    """
    comps = _split_computations(text)
    entry = _entry_name(text)
    if entry is None or entry not in comps:
        return {"error": "no entry computation"}

    # Call graph + which computations are fusion bodies (no HBM traffic).
    edges: dict[str, list[tuple[str, float]]] = {c: [] for c in comps}
    fused: set[str] = set()
    for name, lines in comps.items():
        for line in lines:
            if " while(" in line:
                m = _WHILE_RE.search(line)
                t = _TRIP_RE.search(line)
                trip = float(t.group(1)) if t else 1.0
                if m:
                    edges[name].append((m.group(2), trip))
                    edges[name].append((m.group(1), trip + 1))
            for m in _CALLS_RE.finditer(line):
                edges[name].append((m.group(1), 1.0))
                fused.add(m.group(1))
            for m in _TO_APPLY_RE.finditer(line):
                edges[name].append((m.group(1), 1.0))
                fused.add(m.group(1))
            m = _BRANCHES_RE.search(line)
            if m:
                if m.group(1):
                    for b in m.group(1).split(","):
                        b = b.strip().lstrip("%")
                        if b:
                            edges[name].append((b, 1.0))
                else:
                    edges[name].append((m.group(2), 1.0))
                    edges[name].append((m.group(3), 1.0))

    mult: dict[str, float] = {c: 0.0 for c in comps}
    mult[entry] = 1.0
    # Propagate in topological-ish order via repeated relaxation (call
    # graphs are DAGs; depth is small).
    for _ in range(64):
        changed = False
        new = {c: 0.0 for c in comps}
        new[entry] = 1.0
        for src in comps:
            m_src = mult.get(src, 0.0)
            if m_src == 0.0:
                continue
            for dst, w in edges[src]:
                if dst in new:
                    new[dst] += m_src * w
        for c in comps:
            if abs(new[c] - mult[c]) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break

    flops = 0.0
    bytes_traffic = 0.0
    coll_bytes = 0.0
    coll_f32_bytes = 0.0
    coll_by_kind: dict[str, float] = {}
    _CONTROL = (" while(", " conditional(", " call(")
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        in_fusion = name in fused
        tab = _symtab(lines)
        for line in lines:
            if " dot(" in line:
                flops += m * _dot_flops(line, tab)
            if in_fusion:
                continue
            if any(f in line for f in _FREE_OPS):
                continue
            if any(c in line for c in _CONTROL):
                continue   # bodies accounted separately
            if "-done(" in line or "-update(" in line:
                continue   # async second halves: counted at -start
            kind = None
            for c in _COLLECTIVES:
                if re.search(rf"\b{c}(-start)?\(", line):
                    kind = c
                    break
            out_b = _line_out_bytes(line)
            in_b = _operand_bytes(line, tab)
            if kind is not None:
                g = _GROUPS_RE.search(line)
                if g:
                    first = g.group(1).split("},{")[0]
                    gs = len([x for x in re.split("[,{}]", first) if x])
                else:
                    gi = _GROUPS_IOTA_RE.search(line)
                    gs = int(gi.group(2)) if gi else 1
                op = CollectiveOp(kind=kind, out_bytes=out_b, in_bytes=in_b,
                                  group_size=gs, line=line[:120])
                lb = m * op.link_bytes
                coll_bytes += lb
                coll_by_kind[kind] = coll_by_kind.get(kind, 0.0) + lb
                if re.search(r"=\s*\(?f32\[", line):
                    coll_f32_bytes += lb
                continue
            bytes_traffic += m * (out_b + in_b)
    return {
        "flops_weighted": flops,
        "hbm_bytes_weighted": bytes_traffic,
        "collective_link_bytes_weighted": coll_bytes,
        # XLA-CPU FloatNormalization upcasts bf16 collectives to f32; a
        # TPU ships them in bf16.  Estimate: halve the f32 share (slight
        # overcorrection for genuinely-f32 optimizer reductions).
        "collective_link_bytes_tpu_est": coll_bytes - 0.5 * coll_f32_bytes,
        "collective_f32_bytes_weighted": coll_f32_bytes,
        "collective_by_kind_weighted": coll_by_kind,
        "n_computations": len(comps),
    }


def collective_summary(ops: list[CollectiveOp]) -> dict:
    by_kind: dict[str, dict] = defaultdict(lambda: dict(count=0, bytes=0.0))
    for op in ops:
        by_kind[op.kind]["count"] += 1
        by_kind[op.kind]["bytes"] += op.link_bytes
    total = sum(v["bytes"] for v in by_kind.values())
    return {"total_link_bytes": total, "by_kind": dict(by_kind)}
