"""Input specifications: ShapeDtypeStruct stand-ins for every model input.

This is the dry-run's contract: for each (arch, shape) cell we produce the
exact pytree the lowered step function consumes — weak-type-correct,
shardable, and never allocated.  The same builders produce REAL (small)
arrays for smoke tests via ``concrete=True`` with a reduced spec.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, ShapeSpec

VLM_PATCH_TOKENS = 256   # qwen2-vl stub: patch embeddings per sample


def _arr(shape, dtype, concrete: bool, fill: int = 0):
    if concrete:
        if jnp.issubdtype(dtype, jnp.integer):
            return jnp.full(shape, fill, dtype)
        return jnp.zeros(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec, *,
                      concrete: bool = False) -> dict:
    """Batch pytree with a leading grad-accumulation axis."""
    A = shape.accum
    B = shape.global_batch // A
    assert B * A == shape.global_batch, (shape.global_batch, A)
    S = shape.seq_len
    batch: dict[str, Any] = {}
    if cfg.modality == "audio":
        batch["tokens"] = _arr((A, B, S, cfg.n_codebooks), jnp.int32,
                               concrete)
    elif cfg.modality == "vlm":
        s_text = S - VLM_PATCH_TOKENS
        batch["tokens"] = _arr((A, B, s_text), jnp.int32, concrete)
        batch["extra_embeds"] = _arr((A, B, VLM_PATCH_TOKENS, cfg.d_model),
                                     jnp.bfloat16, concrete)
        batch["positions"] = _arr((A, 3, B, S), jnp.int32, concrete)
    else:
        batch["tokens"] = _arr((A, B, S), jnp.int32, concrete)
    return batch


def prefill_specs(cfg: ModelConfig, shape: ShapeSpec, *,
                  concrete: bool = False) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch: dict[str, Any] = {}
    if cfg.modality == "audio":
        batch["tokens"] = _arr((B, S, cfg.n_codebooks), jnp.int32, concrete)
        batch["positions"] = _arr((B, S), jnp.int32, concrete)
    elif cfg.modality == "vlm":
        s_text = S - VLM_PATCH_TOKENS
        batch["tokens"] = _arr((B, s_text), jnp.int32, concrete)
        batch["extra_embeds"] = _arr((B, VLM_PATCH_TOKENS, cfg.d_model),
                                     jnp.bfloat16, concrete)
        batch["positions"] = _arr((3, B, S), jnp.int32, concrete)
    else:
        batch["tokens"] = _arr((B, S), jnp.int32, concrete)
        batch["positions"] = _arr((B, S), jnp.int32, concrete)
    return batch


def decode_specs(cfg: ModelConfig, shape: ShapeSpec, model, *,
                 concrete: bool = False) -> dict:
    """Decode step inputs: one new token + the cache at seq_len capacity."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.modality == "audio":
        tokens = _arr((B, 1, cfg.n_codebooks), jnp.int32, concrete)
    else:
        tokens = _arr((B, 1), jnp.int32, concrete)
    if cfg.rope_style == "mrope":
        positions = _arr((3, B, 1), jnp.int32, concrete)
    else:
        positions = _arr((B, 1), jnp.int32, concrete)
    if concrete:
        cache = model.init_cache(B, S)
    else:
        cache = jax.eval_shape(lambda: model.init_cache(B, S))
    return {"tokens": tokens, "positions": positions, "cache": cache}


def train_batch_axes(cfg: ModelConfig) -> dict:
    """Logical axes for the train batch (leading accum axis unsharded)."""
    if cfg.modality == "audio":
        return {"tokens": (None, "batch", None, None)}
    if cfg.modality == "vlm":
        return {"tokens": (None, "batch", None),
                "extra_embeds": (None, "batch", None, None),
                "positions": (None, None, "batch", None)}
    return {"tokens": (None, "batch", None)}


def prefill_axes(cfg: ModelConfig) -> dict:
    if cfg.modality == "audio":
        return {"tokens": ("batch", None, None),
                "positions": ("batch", None)}
    if cfg.modality == "vlm":
        return {"tokens": ("batch", None),
                "extra_embeds": ("batch", None, None),
                "positions": (None, "batch", None)}
    return {"tokens": ("batch", None), "positions": ("batch", None)}


def decode_axes(cfg: ModelConfig) -> dict:
    tok = (("batch", None, None) if cfg.modality == "audio"
           else ("batch", None))
    pos = ((None, "batch", None) if cfg.rope_style == "mrope"
           else ("batch", None))
    return {"tokens": tok, "positions": pos}


def synth_tokens(cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0) -> jnp.ndarray:
    """Synthetic token stream with learnable n-gram structure (data
    pipeline stand-in for real corpora in this offline container)."""
    rng = np.random.default_rng(seed)
    # Markov chain over a small state machine mapped into the vocab.
    n_states = min(cfg.vocab, 64)
    trans = rng.dirichlet(np.ones(n_states) * 0.1, size=n_states)
    toks = np.zeros((batch, seq), np.int32)
    state = rng.integers(0, n_states, size=batch)
    for t in range(seq):
        toks[:, t] = state
        nxt = [rng.choice(n_states, p=trans[s]) for s in state]
        state = np.asarray(nxt)
    toks = toks % cfg.vocab
    if cfg.modality == "audio":
        return jnp.asarray(
            np.stack([np.roll(toks, c, axis=1) % cfg.vocab
                      for c in range(cfg.n_codebooks)], axis=-1))
    return jnp.asarray(toks)
