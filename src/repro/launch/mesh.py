"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — smoke tests must keep seeing one
CPU device; only ``dryrun.py`` forces 512 host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for CI tests (requires the host-device XLA flag)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_crossbar_mesh(n_model: int | None = None):
    """(data, model) mesh over ALL local devices for the sharded IMPACT
    crossbar (``sharding.crossbar``): ``n_model`` devices hold the R/S
    row-shard slices (default: every device), the remainder form the data
    axis for batch sharding.  ``n_model`` must divide the device count."""
    n_dev = jax.device_count()
    n_model = n_dev if n_model is None else n_model
    if n_dev % n_model:
        raise ValueError(f"n_model={n_model} does not divide the "
                         f"{n_dev} local devices")
    return jax.make_mesh((n_dev // n_model, n_model), ("data", "model"))
