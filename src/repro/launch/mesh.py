"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — smoke tests must keep seeing one
CPU device; only ``dryrun.py`` forces 512 host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for CI tests (requires the host-device XLA flag)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
