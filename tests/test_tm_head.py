"""TM readout head: learns from frozen backbone features, kernel parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import TMHead, build, pool_features
from repro.models.config import TMHeadConfig


def _features(n, d, n_classes, seed=0):
    """Class-clustered synthetic 'backbone features'."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_classes, d)) * 2.0
    y = rng.integers(0, n_classes, n)
    x = centers[y] + rng.normal(size=(n, d)) * 0.5
    return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)


def test_tm_head_learns_feature_classification():
    d, m = 32, 4
    head = TMHead(TMHeadConfig(n_classes=m, n_clauses=64,
                               bits_per_feature=2, n_states=64,
                               threshold=16), d_features=d)
    x, y = _features(512, d, m)
    params = head.init(jax.random.key(0))
    key = jax.random.key(1)
    for ep in range(15):
        for b in range(0, 512, 64):
            key, k = jax.random.split(key)
            params = head.train_step(params, x[b:b + 64], y[b:b + 64], k)
    acc = float((head.predict(params, x) == y).mean())
    assert acc > 0.85, acc


def test_kernel_and_xla_impl_agree():
    d, m = 16, 3
    head = TMHead(TMHeadConfig(n_classes=m, n_clauses=32), d_features=d)
    x, _ = _features(64, d, m, seed=3)
    params = head.init(jax.random.key(2))
    s_pallas = np.asarray(head.scores(params, x, impl="pallas"))
    s_xla = np.asarray(head.scores(params, x, impl="xla"))
    np.testing.assert_array_equal(s_pallas, s_xla)


def test_tm_head_on_backbone_features():
    """End-to-end: pool a real (smoke) backbone's hidden states and
    classify sequences with the TM head."""
    cfg = get_config("starcoder2-3b").smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    # Build 2-class "sequences": class = which vocab half dominates.
    rng = np.random.default_rng(0)
    B, S = 96, 48
    y = rng.integers(0, 2, B)
    toks = np.where(
        (rng.random((B, S)) < 0.95) == y[:, None].astype(bool),
        rng.integers(cfg.vocab // 2, cfg.vocab, (B, S)),
        rng.integers(0, cfg.vocab // 2, (B, S))).astype(np.int32)
    emb = np.asarray(params["embed"])[toks]          # (B, S, d) frozen
    feats = pool_features(jnp.asarray(emb))
    head = TMHead(TMHeadConfig(n_classes=2, n_clauses=128,
                               bits_per_feature=6, threshold=24),
                  d_features=cfg.d_model)
    hp = head.init(jax.random.key(1))
    key = jax.random.key(2)
    for ep in range(60):
        key, k = jax.random.split(key)
        hp = head.train_step(hp, feats, jnp.asarray(y), k)
    acc = float((head.predict(hp, feats) == jnp.asarray(y)).mean())
    assert acc > 0.9, acc
