"""Shared test fixtures.  NOTE: no XLA_FLAGS here — tests must see the
single real CPU device; multi-device tests spawn subprocesses."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture()
def rng():
    # function-scoped: each test gets a FRESH deterministic stream
    # (a shared session stream makes outcomes depend on test order).
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
