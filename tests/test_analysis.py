"""The static-analysis subsystem: every lint rule fires on a known-bad
fixture and stays quiet on the shipped tree; the IR audit flags injected
f64 widening, host callbacks, VMEM-busting budgets and fingerprint
drift, and passes the real compiled sessions clean.
"""
import importlib.util
import json
import pathlib
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import ir_audit, lint, vmem
from repro.core import CoTMConfig
from repro.core.cotm import CoTMParams
from repro.impact import IMPACTConfig, RuntimeSpec, build_system
from repro.kernels import backends

REPO = pathlib.Path(__file__).resolve().parent.parent
SERVE = "src/repro/serve/fixture.py"          # runtime-scoped path


def _lint(src: str, path: str = SERVE):
    return lint.lint_source(textwrap.dedent(src), path)


def _rules(findings, *, waived=False):
    return [f.rule for f in findings if f.waived == waived]


# -- layer 2: the lint rules -------------------------------------------------

def test_impact001_bare_assert_fires_in_scope_only():
    src = """
    def admit(reqs):
        assert reqs, "no requests"
        return reqs
    """
    assert _rules(_lint(src)) == ["IMPACT001"]
    assert _rules(_lint(src, "src/repro/kernels/fixture.py")) == []
    raised = """
    def admit(reqs):
        if not reqs:
            raise ValueError("no requests")
        return reqs
    """
    assert _rules(_lint(raised)) == []


def test_impact002_wall_clock_fires_only_with_injectable_clock():
    clocked = """
    import time

    class Engine:
        def __init__(self, clock=time.time):
            self.clock = clock

        def step(self):
            return time.monotonic()
    """
    assert _rules(_lint(clocked)) == ["IMPACT002"]
    unclocked = """
    import time

    def stamp():
        return time.time()
    """
    assert _rules(_lint(unclocked)) == []


def test_impact003_energy_sum_needs_f64_cast():
    dirty = """
    def bill(res):
        return sum(res.e_clause_lanes)
    """
    assert _rules(_lint(dirty)) == ["IMPACT003"]
    blessed = """
    import numpy as np

    def bill(res):
        return sum(np.asarray(res.e_clause_lanes, np.float64))
    """
    assert _rules(_lint(blessed)) == []
    tainted_name = """
    def bill(res):
        lanes = res.e_class_lanes
        total = lanes + lanes
        return total
    """
    assert _rules(_lint(tainted_name)) == ["IMPACT003"]


def test_impact004_backend_conformance():
    bad = """
    class Backend:
        def fused_impact(self, literals, clause_i, *, thresh):
            raise NotImplementedError

        def crossbar_mvm(self, drive, g):
            raise NotImplementedError

    def register_backend(b):
        pass

    class Partial(Backend):
        def fused_impact(self, literals, *, thresh):   # wrong arity
            return literals

    class Rogue:
        name = "rogue"

    register_backend(Partial())
    register_backend(Rogue())
    """
    path = "src/repro/kernels/fixture.py"
    rules = _rules(_lint(bad, path))
    # Partial: signature mismatch; Rogue: misses both primitives.
    assert rules.count("IMPACT004") == 3
    good = """
    class Backend:
        def fused_impact(self, literals, clause_i, *, thresh):
            raise NotImplementedError

    def register_backend(b):
        pass

    class Mine(Backend):
        def fused_impact(self, literals, clause_i, *, thresh,
                         interpret=None):
            return literals

    register_backend(Mine())
    """
    assert _rules(_lint(good, path)) == []


def test_impact005_shim_kwargs_outside_shims():
    src = """
    def run(session, lits, mesh):
        session.predict(lits, impl="pallas")
        session.infer_step(lits, None, meter=True)
        helper(lits, meter_energy=True)
        other(lits, impl="not-a-shimmed-callee")
    """
    assert _rules(_lint(src, "src/repro/impact/ops.py")) \
        == ["IMPACT005"] * 3
    # The shim modules themselves are exempt by design.
    assert _rules(_lint(src, "src/repro/impact/pipeline.py")) == []


def test_waiver_suppresses_but_is_counted():
    src = """
    def admit(reqs):
        assert reqs  # lint: waive IMPACT001 checked by caller
        return reqs
    """
    findings = _lint(src)
    assert _rules(findings) == []
    assert _rules(findings, waived=True) == ["IMPACT001"]


def test_syntax_error_is_an_unwaivable_finding():
    assert _rules(_lint("def broken(:\n")) == ["SYNTAX"]


def test_shipped_tree_is_lint_clean():
    findings = [f for f in lint.lint_tree(REPO) if not f.waived]
    assert findings == [], "\n".join(str(f) for f in findings)


# -- layer 1: IR audit on text -----------------------------------------------

F64_HLO = """\
module @jit_f attributes {mhlo.num_partitions = 1 : i32} {
  func.func public @main(%arg0: tensor<8x10xf32>) -> tensor<8x10xf64> {
    %0 = stablehlo.convert %arg0 : (tensor<8x10xf32>) -> tensor<8x10xf64>
    return %0 : tensor<8x10xf64>
  }
}
"""

CLEAN_HLO = """\
module @jit_f attributes {mhlo.num_partitions = 1 : i32} {
  func.func public @main(%arg0: tensor<8x10xf32>) -> tensor<8x10xf32> {
    %0 = stablehlo.add %arg0, %arg0 : tensor<8x10xf32>
    return %0 : tensor<8x10xf32>
  }
}
"""


def test_precision_scan_flags_every_wide_and_narrow_type():
    assert [f.check for f in ir_audit.scan_precision(F64_HLO)] \
        == ["precision"] * 3
    assert ir_audit.scan_precision(CLEAN_HLO) == []
    narrow = CLEAN_HLO.replace("tensor<8x10xf32>", "tensor<8x10xbf16>")
    msgs = [f.message for f in ir_audit.scan_precision(narrow)]
    assert msgs and all("bf16" in m for m in msgs)
    half = CLEAN_HLO.replace("tensor<8x10xf32>", "tensor<f16>")
    msgs = [f.message for f in ir_audit.scan_precision(half)]
    assert msgs and all("f16" in m and "bf16" not in m for m in msgs)


def test_host_io_scan():
    assert ir_audit.scan_host_io(CLEAN_HLO) == []
    bad = CLEAN_HLO.replace(
        "stablehlo.add %arg0, %arg0",
        'stablehlo.custom_call @xla_python_cpu_callback(%arg0)')
    findings = ir_audit.scan_host_io(bad)
    assert [f.check for f in findings] == ["host_io"]


def test_fingerprint_counts_ops_not_module_attributes():
    fp = ir_audit.fingerprint_text(CLEAN_HLO)
    assert fp["ops"] == {"func.func": 1, "stablehlo.add": 1}
    assert "mhlo.num_partitions" not in fp["ops"]
    drift = ir_audit.fingerprint_text(
        CLEAN_HLO.replace("stablehlo.add", "stablehlo.multiply"))
    deltas = ir_audit.diff_fingerprints(fp, drift)
    assert any("stablehlo.add" in d for d in deltas)
    assert ir_audit.diff_fingerprints(fp, fp) == []


def test_f64_widened_toy_executable_is_flagged():
    """A REAL lowered artifact with injected f64 widening (x64 mode), not
    just a crafted string, must trip the precision scan."""
    with jax.experimental.enable_x64():
        lowered = jax.jit(
            lambda x: jnp.asarray(x, jnp.float64) * 2.0,
        ).lower(jax.ShapeDtypeStruct((8,), jnp.float32))
        text = lowered.as_text()
    findings = ir_audit.audit_ir_text(text)
    assert any(f.check == "precision" and "f64" in f.message
               for f in findings)


# -- the VMEM estimator ------------------------------------------------------

def test_vmem_estimates_are_positive_and_ordered():
    ws = vmem.fused_working_set(R=1, tr=64, n_clause=32, class_rows=32,
                                M=4, metered=False)
    wm = vmem.fused_working_set(R=1, tr=64, n_clause=32, class_rows=32,
                                M=4, metered=True)
    assert 0 < ws.total_bytes < vmem.DEFAULT_VMEM_BUDGET_BYTES
    assert wm.total_bytes > ws.total_bytes          # meters cost VMEM
    assert wm.variant == "fused_impact_metered"
    # At realistic shard sizes the packed kernel's working set beats the
    # f32 one (the 1-byte pbits block replaces the 4-byte ccur block); at
    # tiny padded shapes the 4-bitplane drive dominates, so compare at a
    # full 512-row shard (tr4 = 512/4 = 128).
    big = vmem.fused_working_set(R=1, tr=512, n_clause=512, class_rows=512,
                                 M=4, metered=False)
    packed = vmem.packed_working_set(R=1, tr4=128, n_clause=512,
                                     class_rows=512, M=4, metered=False)
    assert packed.total_bytes < big.total_bytes     # 2-bit beats f32
    mvm = vmem.mvm_working_set(k_rows=64)
    assert 0 < mvm.total_bytes < ws.total_bytes


# -- session-level audit -----------------------------------------------------

@pytest.fixture(scope="module")
def small_system():
    K, n, m, n_states = 64, 32, 4, 64
    cfg = CoTMConfig(n_literals=K, n_clauses=n, n_classes=m,
                     n_states=n_states)
    rng = np.random.default_rng(0)
    ta = np.where(rng.random((K, n)) < 0.1, n_states + 1, n_states)
    w = rng.integers(-20, 20, (m, n))
    params = CoTMParams(ta_state=jnp.asarray(ta, jnp.int32),
                        weights=jnp.asarray(w, jnp.int32))
    return build_system(params, cfg, jax.random.key(0),
                        IMPACTConfig(variability=False, finetune=False))


def test_session_executables_pass_the_audit(small_system):
    session = small_system.compile(RuntimeSpec(
        backend="pallas", metering="fused", batch_sizes=(8,), capacity=8))
    report = session.audit()
    assert report.ok, [str(f) for f in report.findings]
    assert set(report.fingerprints) == {"predict@8", "infer_step@8"}
    assert all(v > 0 for v in report.vmem_bytes.values())
    # The IR itself honors the precision ladder.
    ir = session.ir_text("predict", 8)
    assert "f64" not in ir and "custom_call" not in ir
    # Round-trips through JSON (the check_static report artifact).
    json.dumps(report.to_json())


def test_vmem_busting_spec_is_flagged(small_system):
    session = small_system.compile(RuntimeSpec(
        backend="pallas", metering="fused", batch_sizes=(8,),
        vmem_budget_bytes=1024))
    report = session.audit()
    assert not report.ok
    assert any(f.check == "vmem" and f.severity == "error"
               for f in report.findings)


def test_fingerprint_drift_is_detected(small_system):
    session = small_system.compile(RuntimeSpec(
        backend="pallas", metering="off", batch_sizes=(8,)))
    base = dict(session.audit().fingerprints)
    clean = session.audit(baselines=base)
    assert not any(f.check == "fingerprint" for f in clean.findings)
    perturbed = {k: {"ops": {"stablehlo.add": 1}, "n_ops": 1}
                 for k in base}
    drifted = session.audit(baselines=perturbed)
    assert any(f.check == "fingerprint" and f.severity == "warning"
               for f in drifted.findings)
    assert drifted.ok            # drift warns, never errors
    missing = session.audit(baselines={})
    assert any("no committed fingerprint baseline" in f.message
               for f in missing.findings)


def test_audit_compiles_on_demand_without_new_traces(small_system):
    session = small_system.compile(RuntimeSpec(
        backend="pallas", metering="off", batch_sizes=(4,)))
    before = session.trace_count
    session.audit("predict", 4)        # already compiled: no retrace
    assert session.trace_count == before
    report = session.audit("predict", 16)  # new shape: compiles once
    assert "predict@16" in report.fingerprints
    with pytest.raises(ValueError, match="no compiled executables"):
        ir_audit.audit_session(session, "infer_with_report", None)


def test_spec_validates_vmem_budget():
    with pytest.raises(ValueError, match="vmem_budget_bytes"):
        RuntimeSpec(vmem_budget_bytes=0)


def test_register_backend_enforces_primitive_contract():
    class Gutted(backends.Backend):
        name = "gutted-fixture"
        fused_impact = None            # deletes an inherited primitive

    with pytest.raises(TypeError, match="fused_impact"):
        backends.register_backend(Gutted())
    assert "gutted-fixture" not in backends.available_backends()
    missing = [p for p in backends.REQUIRED_PRIMITIVES
               if not callable(getattr(backends.Backend, p, None))]
    assert missing == []               # base class satisfies its contract


# -- the check_static driver -------------------------------------------------

def _load_check_static():
    path = REPO / "benchmarks" / "check_static.py"
    spec = importlib.util.spec_from_file_location("check_static", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_static_lint_only_exit_codes(tmp_path, capsys):
    check_static = _load_check_static()
    assert check_static.main(["--lint-only", "--root", str(REPO)]) == 0
    bad = tmp_path / "src" / "repro" / "serve"
    bad.mkdir(parents=True)
    (bad / "engine.py").write_text(
        "def admit(reqs):\n    assert reqs\n    return reqs\n")
    assert check_static.main(["--lint-only", "--root", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "::error file=src/repro/serve/engine.py" in out
    assert "IMPACT001" in out


def test_check_static_hlo_mode(tmp_path, capsys):
    check_static = _load_check_static()
    good = tmp_path / "clean.mlir"
    good.write_text(CLEAN_HLO)
    assert check_static.main(["--hlo", str(good)]) == 0
    bad = tmp_path / "f64.mlir"
    bad.write_text(F64_HLO)
    assert check_static.main(["--hlo", str(bad)]) == 1
    assert "STATIC GATE FAILED" in capsys.readouterr().out


def test_committed_fingerprint_baselines_exist():
    path = REPO / "benchmarks" / "baselines" / "IR_fingerprints.json"
    baselines = json.loads(path.read_text())
    assert set(baselines) >= {"fused", "staged", "packed", "oracle"}
    for tag, per_exe in baselines.items():
        for key, fp in per_exe.items():
            assert fp["n_ops"] > 0, (tag, key)
