"""Crossbar tile encoding fidelity (paper Figs. 9, 11-13)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoTMConfig, to_unipolar
from repro.core.ref import clause_outputs_ref
from repro.impact import yflash
from repro.impact.tiles import (ClassTile, ClauseTile, encode_class_tile,
                                encode_clause_tile, weight_targets)


def test_clause_tile_reproduces_software_clauses(rng):
    K, n, B = 128, 64, 32
    include = jnp.asarray(rng.random((K, n)) < 0.05)
    tile, stats = encode_clause_tile(include, jax.random.key(0))
    lits = jnp.asarray(rng.random((B, K)) < 0.5)
    got = np.asarray(tile.clauses(lits))
    want = clause_outputs_ref(np.asarray(lits), np.asarray(include))
    assert (got == want).mean() == 1.0


def test_clause_tile_worst_case_margin(rng):
    """Paper Fig. 5c: 1024 excluded cells driven at V_R must NOT trip the
    CSA; one included cell driven must trip it."""
    K = 2048
    include = jnp.zeros((K, 1), bool)
    tile, _ = encode_clause_tile(include, jax.random.key(1))
    lits = jnp.concatenate([jnp.zeros((1, K // 2), bool),
                            jnp.ones((1, K // 2), bool)], axis=1)
    current = float(tile.currents(lits)[0, 0])
    assert current < yflash.I_CSA_THRESHOLD, current  # no false trip

    include2 = jnp.zeros((K, 1), bool).at[0, 0].set(True)
    tile2, _ = encode_clause_tile(include2, jax.random.key(2))
    lits2 = jnp.zeros((1, K), bool)          # literal 0 everywhere
    current2 = float(tile2.currents(lits2)[0, 0])
    assert current2 > yflash.I_CSA_THRESHOLD, current2


def test_weight_targets_monotone():
    w = jnp.arange(0, 420)
    t = np.asarray(weight_targets(w, 419))
    assert (np.diff(t) > 0).all()
    assert t.min() >= yflash.G_RANGE_LO * 0.999
    assert t.max() <= yflash.G_RANGE_HI * 1.001


def test_class_tile_preserves_argmax(rng):
    """Analog weight mapping must keep the winning class (Fig. 13:
    96.2% accuracy after pre-tune alone).

    The paper's tolerance band is +/-5 SEGMENTS per cell, i.e. ~+/-5
    weight units regardless of weight scale — so argmax survives exactly
    when score margins clear the resulting ~sqrt(2*n_fired)*3 unit noise
    floor.  Trained CoTMs have such margins (that is Fig. 13's regime);
    i.i.d. random weights do not, they are mostly near-ties.  Model the
    trained regime with class-distinctive weight blocks."""
    n, m, B = 128, 10, 64
    w = rng.integers(-10, 10, (m, n))
    for i in range(m):
        w[i, i * (n // m):(i + 1) * (n // m)] += 120
    w_uni, _ = to_unipolar(jnp.asarray(w, jnp.int32))
    tile, stats = encode_class_tile(w_uni.T, jax.random.key(3))
    clauses = jnp.asarray(rng.random((B, n)) < 0.3)
    got = np.asarray(tile.predict(clauses))
    want = np.argmax(np.asarray(clauses, np.int64)
                     @ np.asarray(w_uni.T, np.int64), -1)
    agreement = (got == want).mean()
    assert agreement >= 0.9, agreement


def test_finetune_improves_mapping(rng):
    """Fig. 13b: fine-tuning reduces conductance error vs target."""
    n, m = 64, 10
    w = jnp.asarray(rng.integers(0, 300, (n, m)), jnp.int32)
    target = np.asarray(weight_targets(w, int(w.max())))
    t_pre, _ = encode_class_tile(w, jax.random.key(4), finetune=False)
    t_fine, _ = encode_class_tile(w, jax.random.key(4), finetune=True)
    err_pre = np.abs(np.asarray(t_pre.g) - target).mean()
    err_fine = np.abs(np.asarray(t_fine.g) - target).mean()
    assert err_fine <= err_pre * 1.05, (err_pre, err_fine)


def test_encode_reports_convergence(rng):
    """Converged encodes must report n_unconverged == 0 on every path."""
    include = jnp.asarray(rng.random((64, 32)) < 0.05)
    _, s_cl = encode_clause_tile(include, jax.random.key(6))
    assert s_cl["n_unconverged"] == 0
    w = jnp.asarray(rng.integers(0, 100, (32, 4)), jnp.int32)
    for kwargs in (dict(finetune=True), dict(finetune=False),
                   dict(adaptive=True)):
        _, s = encode_class_tile(w, jax.random.key(7), **kwargs)
        assert s["n_unconverged"] == 0, (kwargs, s["n_unconverged"])


def test_encode_surfaces_nonconvergence(rng):
    """Regression: an impossible target used to be returned as-is with no
    signal — pulse loops give up at max_pulses and the tile silently
    mis-programs.  encode_stats must now carry the unconverged count."""
    # Boolean path: excluded cells must reach G <= 1e-12 S, far below the
    # programming floor G_MIN — no pulse budget can get there.
    K, n = 16, 8
    include = jnp.zeros((K, n), bool)
    _, stats = encode_clause_tile(include, jax.random.key(8))
    assert stats["n_unconverged"] == 0  # sanity: the real target converges
    import repro.impact.tiles as tiles_mod
    old = tiles_mod.G_LCS
    tiles_mod.G_LCS = 1e-12
    try:
        _, stats_bad = encode_clause_tile(include, jax.random.key(8),
                                          max_pulses=4)
    finally:
        tiles_mod.G_LCS = old
    assert stats_bad["n_unconverged"] == K * n, stats_bad["n_unconverged"]

    # Analog adaptive path: a near-zero tolerance band under C2C noise
    # leaves cells outside tolerance when max_pulses exhausts.
    w = jnp.asarray(rng.integers(0, 100, (32, 4)), jnp.int32)
    _, s_ad = encode_class_tile(w, jax.random.key(9), adaptive=True,
                                finetune_tol_segments=1e-6, max_pulses=4)
    assert s_ad["n_unconverged"] > 0, s_ad["n_unconverged"]


def test_adaptive_controller_beats_two_phase(rng):
    """Beyond paper: the width-selecting closed-loop programmer reaches a
    tighter mapping with fewer pulses than the fixed two-phase schedule."""
    n, m = 64, 10
    w = jnp.asarray(rng.integers(0, 300, (n, m)), jnp.int32)
    target = np.asarray(weight_targets(w, int(w.max())))
    t_two, s_two = encode_class_tile(w, jax.random.key(5), finetune=True)
    t_ad, s_ad = encode_class_tile(w, jax.random.key(5), adaptive=True)
    err_two = np.abs(np.asarray(t_two.g) - target).mean()
    err_ad = np.abs(np.asarray(t_ad.g) - target).mean()
    pulses_two = float((s_two["pretune_prog"] + s_two["pretune_erase"]
                        + s_two["finetune_prog"]
                        + s_two["finetune_erase"]).mean())
    pulses_ad = float((s_ad["pretune_prog"] + s_ad["pretune_erase"]).mean())
    assert err_ad <= err_two * 1.1, (err_two, err_ad)
    assert pulses_ad <= pulses_two, (pulses_two, pulses_ad)
