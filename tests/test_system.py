"""End-to-end system test: the paper's full pipeline at MNIST-like scale.

Train CoTM on synthetic digit glyphs -> map onto Y-Flash crossbars with
full variability -> verify hardware inference tracks software accuracy and
the Pallas kernels reproduce the digital-twin decisions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CoTMConfig, booleanize, include_mask, predict,
                        to_unipolar, train_epochs)
from repro.data.synthetic import digits
from repro.impact import build_system
from repro.kernels import ops


@pytest.fixture(scope="module")
def mnist_like():
    # Paper dims (K=1568, n=500, m=10); 7 epochs on 6k synthetic glyphs
    # instead of the paper's 25 on 60k MNIST (test-time budget) — the
    # full-budget run lives in benchmarks/table5 (see artifacts).
    cfg = CoTMConfig(n_literals=1568, n_clauses=500, n_classes=10,
                     n_states=128, threshold=96, specificity=8.0)
    x_tr, y_tr = digits(6000, seed=1, jitter=2)
    x_te, y_te = digits(500, seed=2, jitter=2)
    lit_tr = booleanize(jnp.asarray(x_tr))
    lit_te = booleanize(jnp.asarray(x_te))
    params = train_epochs(cfg.init(jax.random.key(0)), lit_tr,
                          jnp.asarray(y_tr), jax.random.key(1), cfg,
                          epochs=7, batch_size=32)
    return cfg, params, lit_te, jnp.asarray(y_te)


@pytest.mark.slow
def test_software_accuracy(mnist_like):
    cfg, params, lits, labels = mnist_like
    acc = float((predict(params, lits, cfg) == labels).mean())
    assert acc > 0.8, acc    # paper: 96.3% at 500 clauses / 25 epochs


@pytest.mark.slow
def test_hardware_tracks_software(mnist_like):
    cfg, params, lits, labels = mnist_like
    sw_acc = float((predict(params, lits, cfg) == labels).mean())
    system = build_system(params, cfg, jax.random.key(7))
    hw_acc = float((system.predict(lits) == labels).mean())
    assert hw_acc >= sw_acc - 0.03, (sw_acc, hw_acc)


@pytest.mark.slow
def test_pallas_kernels_match_software_decisions(mnist_like):
    cfg, params, lits, labels = mnist_like
    inc = include_mask(params.ta_state, cfg.n_states)
    scores = ops.fused_cotm(lits[:128], inc, params.weights.T)
    sw = predict(params, lits[:128], cfg)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(scores, -1)),
                                  np.asarray(sw))
