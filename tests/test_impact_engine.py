"""Batched IMPACT serving on compiled sessions: queue/bucket behavior,
parity with direct inference, per-mode kwarg validation, and energy
aggregation."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoTMConfig
from repro.core.cotm import CoTMParams
from repro.impact import (IMPACTConfig, InferenceSession, RuntimeSpec,
                          build_system)
from repro.serve import IMPACTEngine, aggregate_reports


def spec(backend="xla", *, meter=True, capacity=None, **kw):
    return RuntimeSpec(backend=backend,
                       metering="staged" if meter else "off",
                       capacity=capacity, **kw)


@pytest.fixture(scope="module")
def small_system():
    K, n, m, n_states = 64, 32, 4, 64
    cfg = CoTMConfig(n_literals=K, n_clauses=n, n_classes=m,
                     n_states=n_states)
    rng = np.random.default_rng(0)
    ta = np.where(rng.random((K, n)) < 0.1, n_states + 1, n_states)
    w = rng.integers(-20, 20, (m, n))
    params = CoTMParams(ta_state=jnp.asarray(ta, jnp.int32),
                        weights=jnp.asarray(w, jnp.int32))
    system = build_system(params, cfg, jax.random.key(0),
                          IMPACTConfig(variability=False, finetune=False))
    lits = rng.random((40, K)) < 0.5
    return system, lits


def test_engine_matches_direct_predict(small_system):
    system, lits = small_system
    direct = np.asarray(
        system.compile(spec()).predict(jnp.asarray(lits)).predictions)
    eng = IMPACTEngine(system.compile(spec(capacity=16)))
    preds, stats = eng.run(lits)
    np.testing.assert_array_equal(preds, direct)
    assert stats["samples"] == lits.shape[0]
    assert stats["samples_per_s"] > 0


def test_engine_pallas_parity(small_system):
    system, lits = small_system
    eng_x = IMPACTEngine(system.compile(spec("xla", capacity=16)))
    eng_p = IMPACTEngine(system.compile(spec("pallas", capacity=16)))
    p_x, _ = eng_x.run(lits)
    p_p, _ = eng_p.run(lits)
    np.testing.assert_array_equal(p_x, p_p)


def test_engine_fused_serving_path(small_system):
    """metering='off' + backend='pallas' is the max-throughput config
    that actually serves through the fused kernel — it must agree with
    the metered (staged) engine and report no energy."""
    system, lits = small_system
    fused = IMPACTEngine(
        system.compile(spec("pallas", meter=False, capacity=16)))
    staged = IMPACTEngine(system.compile(spec("pallas", capacity=16)))
    p_f, s_f = fused.run(lits)
    p_s, _ = staged.run(lits)
    np.testing.assert_array_equal(p_f, p_s)
    assert "energy" not in s_f and fused.reports == []


def test_run_stats_are_per_burst(small_system):
    """run() reports the burst it served, not engine lifetime; lifetime
    aggregates stay available via stats()."""
    system, lits = small_system
    eng = IMPACTEngine(system.compile(spec(capacity=8)))
    _, s1 = eng.run(lits[:16])
    _, s2 = eng.run(lits[16:32])
    assert s1["samples"] == 16 and s2["samples"] == 16
    assert s2["energy"].datapoints == 16
    life = eng.stats()
    assert life["samples"] == 32 and life["energy"].datapoints == 32


def test_slot_padding_is_neutral(small_system):
    """A lone request swept in the full slot table must predict the same
    as the full-batch path (free lanes draw no current)."""
    system, lits = small_system
    direct = np.asarray(
        system.compile(spec()).predict(jnp.asarray(lits[:1])).predictions)
    eng = IMPACTEngine(system.compile(spec(capacity=8)), max_wait_s=0.0)
    rid = eng.submit(lits[0])
    out = dict(eng.step(force=True))
    assert out[rid] == int(direct[0])
    assert eng.batch_stats[0].bucket == 8
    assert eng.batch_stats[0].n_valid == 1


def test_bucket_selection():
    eng = IMPACTEngine.__new__(IMPACTEngine)   # bucket_for only reads buckets
    eng.buckets = [8, 32, 128]
    assert eng.bucket_for(1) == 8
    assert eng.bucket_for(8) == 8
    assert eng.bucket_for(9) == 32
    assert eng.bucket_for(1000) == 128     # capped at max bucket


def test_per_mode_kwarg_validation(small_system):
    """A knob the chosen scheduler never reads is rejected, not silently
    shadowed: buckets are flush-only, target_occupancy continuous-only
    (regression — buckets used to be accepted and ignored in continuous
    mode)."""
    system, _ = small_system
    sess = system.compile(spec(capacity=8))
    with pytest.raises(ValueError, match="buckets only apply"):
        IMPACTEngine(sess, buckets=(8,))
    with pytest.raises(ValueError, match="target_occupancy only applies"):
        IMPACTEngine(sess, mode="flush", target_occupancy=0.5)
    with pytest.raises(ValueError, match="max_batch"):
        IMPACTEngine(sess, max_batch=32)       # capacity is compiled: 8
    with pytest.raises(ValueError, match="cannot override"):
        IMPACTEngine(sess, impl="xla")
    with pytest.raises(ValueError, match="capacity"):
        IMPACTEngine(system.compile(spec()))   # no serving shape compiled


def test_submit_rejects_misshaped_request(small_system):
    """A mis-shaped request raises ValueError — a real exception, not a
    bare assert (``python -O`` strips asserts, and a wrong-shape row
    admitted into the persistent (capacity, K) lane buffer corrupts
    co-resident lanes).  A rejected submit must leave the engine
    untouched: no queue entry, no slot, no burned request id."""
    system, lits = small_system
    eng = IMPACTEngine(system.compile(spec(capacity=8)))
    for bad in (lits[:2],              # batched: (2, K)
                lits[0][: 32],         # truncated: (K/2,)
                lits[0][None, :]):     # leading axis: (1, K)
        with pytest.raises(ValueError, match="shape"):
            eng.submit(bad)
    assert eng.queue.pending == []
    assert eng.table.occupancy == 0
    assert eng.request_records == []
    assert eng.submit(lits[0]) == 0    # first accepted request is rid 0


def test_flush_on_full_and_stale(small_system):
    system, lits = small_system
    eng = IMPACTEngine(system.compile(spec(capacity=4)), mode="flush",
                       max_wait_s=10.0)
    for i in range(3):
        eng.submit(lits[i])
    assert eng.step() == []                # 3 < max_batch, not stale
    eng.submit(lits[3])
    assert len(eng.step()) == 4            # flush on full
    eng.submit(lits[4])
    eng.queue.pending[0].arrived = time.time() - 11.0
    assert len(eng.step()) == 1            # flush on stale


def test_energy_aggregation(small_system):
    system, lits = small_system
    eng = IMPACTEngine(system.compile(spec(capacity=8)))
    _, stats = eng.run(lits)
    agg = stats["energy"]
    assert agg.datapoints == lits.shape[0]
    assert agg.read_energy_j > 0
    assert stats["energy_per_datapoint_j"] > 0
    # aggregate == sum of the per-batch reports
    np.testing.assert_allclose(
        agg.read_energy_j, sum(r.read_energy_j for r in eng.reports))
    assert agg.program_energy_j == eng.reports[0].program_energy_j


def test_continuous_sessions_are_never_cold(small_system):
    """The compiled-session contract: the slot-table sweep shape is an
    executable before the first request, so a continuous engine has no
    cold batches even without warmup()."""
    system, lits = small_system
    eng = IMPACTEngine(system.compile(spec(capacity=8)))
    _, stats = eng.run(lits[:8])
    assert stats["cold_batches"] == 0
    assert stats["energy"].datapoints == 8


def test_flush_warmup_removes_cold_batches(small_system):
    """Flush buckets below capacity compile on demand; the first batch of
    an unwarmed bucket is flagged cold and excluded from samples_per_s,
    and warmup() pre-compiles so nothing is cold.  Each engine gets a
    FRESH (uncached) session so the second engine can't ride the first
    one's compiles."""
    system, lits = small_system

    def fresh_session():
        return InferenceSession(system, spec(capacity=8))

    cold_eng = IMPACTEngine(fresh_session(), mode="flush", buckets=(4,),
                            max_wait_s=0.0)
    cold_eng.submit(lits[0])
    cold_eng.step(force=True)
    assert [s.bucket for s in cold_eng.batch_stats] == [4]
    assert cold_eng.stats()["cold_batches"] == 1

    warm_eng = IMPACTEngine(fresh_session(), mode="flush", buckets=(4,),
                            max_wait_s=0.0)
    warm_eng.warmup()
    assert warm_eng.session.is_compiled("infer_step", 4)
    assert warm_eng.reports == []          # warmup compiles, never sweeps
    warm_eng.submit(lits[0])
    warm_eng.step(force=True)
    assert warm_eng.stats()["cold_batches"] == 0


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_engine_bills_from_fused_meters(small_system, backend):
    """The serving acceptance: an engine on a metering='fused' session
    bills every request from the in-kernel meters — same predictions and
    (to f32 tolerance) the same per-request joules as the staged-oracle
    engine, with per-request bills still summing exactly to the batch
    meter."""
    system, lits = small_system
    eng_st = IMPACTEngine(system.compile(
        RuntimeSpec(backend=backend, metering="staged", capacity=8)))
    eng_fu = IMPACTEngine(system.compile(
        RuntimeSpec(backend=backend, metering="fused", capacity=8)))
    assert eng_fu.meter_energy
    p_st, s_st = eng_st.run(lits)
    p_fu, s_fu = eng_fu.run(lits)
    np.testing.assert_array_equal(p_fu, p_st)
    bills_st = {r.rid: r.e_read_j for r in eng_st.request_records}
    bills_fu = {r.rid: r.e_read_j for r in eng_fu.request_records}
    assert all(b > 0 for b in bills_fu.values())
    np.testing.assert_allclose(
        [bills_fu[r] for r in sorted(bills_fu)],
        [bills_st[r] for r in sorted(bills_st)], rtol=1e-5)
    # f64 lane-sum == batch meter, on the fused path too
    np.testing.assert_allclose(sum(bills_fu.values()),
                               s_fu["energy"].read_energy_j, rtol=1e-9)
    np.testing.assert_allclose(s_fu["energy"].read_energy_j,
                               s_st["energy"].read_energy_j, rtol=1e-5)


def test_aggregate_reports_requires_nonempty():
    with pytest.raises(ValueError, match="no reports"):
        aggregate_reports([])


def test_padding_lanes_not_billed(small_system):
    """An all-1 pad lane fires every nonempty clause (vacuous truth), so
    without the validity mask it would draw phantom class-tile current;
    the metered report must bill exactly the real lanes."""
    system, lits = small_system
    res = system.compile(spec()).infer_with_report(jnp.asarray(lits[:1]))
    eng = IMPACTEngine(system.compile(spec(capacity=8)), max_wait_s=0.0)
    eng.submit(lits[0])
    eng.step(force=True)
    (padded_report,) = eng.reports
    assert padded_report.datapoints == 1
    np.testing.assert_allclose(padded_report.read_energy_j,
                               res.report.read_energy_j, rtol=1e-6)
    np.testing.assert_allclose(padded_report.class_energy_j,
                               res.report.class_energy_j, rtol=1e-6)
