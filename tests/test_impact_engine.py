"""Batched IMPACT serving: queue/bucket behavior, parity with direct
inference, and energy aggregation."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoTMConfig
from repro.core.cotm import CoTMParams
from repro.impact import IMPACTConfig, build_system
from repro.serve import IMPACTEngine, aggregate_reports


@pytest.fixture(scope="module")
def small_system():
    K, n, m, n_states = 64, 32, 4, 64
    cfg = CoTMConfig(n_literals=K, n_clauses=n, n_classes=m,
                     n_states=n_states)
    rng = np.random.default_rng(0)
    ta = np.where(rng.random((K, n)) < 0.1, n_states + 1, n_states)
    w = rng.integers(-20, 20, (m, n))
    params = CoTMParams(ta_state=jnp.asarray(ta, jnp.int32),
                        weights=jnp.asarray(w, jnp.int32))
    system = build_system(params, cfg, jax.random.key(0),
                          IMPACTConfig(variability=False, finetune=False))
    lits = rng.random((40, K)) < 0.5
    return system, lits


def test_engine_matches_direct_predict(small_system):
    system, lits = small_system
    direct = np.asarray(system.predict(jnp.asarray(lits), impl="xla"))
    eng = IMPACTEngine(system, impl="xla", max_batch=16, buckets=(4, 16))
    preds, stats = eng.run(lits)
    np.testing.assert_array_equal(preds, direct)
    assert stats["samples"] == lits.shape[0]
    assert stats["samples_per_s"] > 0


def test_engine_pallas_parity(small_system):
    system, lits = small_system
    eng_x = IMPACTEngine(system, impl="xla", max_batch=16)
    eng_p = IMPACTEngine(system, impl="pallas", max_batch=16)
    p_x, _ = eng_x.run(lits)
    p_p, _ = eng_p.run(lits)
    np.testing.assert_array_equal(p_x, p_p)


def test_engine_fused_serving_path(small_system):
    """meter_energy=False + impl='pallas' is the max-throughput config
    that actually serves through the fused kernel — it must agree with
    the metered (staged) engine and report no energy."""
    system, lits = small_system
    fused = IMPACTEngine(system, impl="pallas", max_batch=16,
                         meter_energy=False)
    staged = IMPACTEngine(system, impl="pallas", max_batch=16)
    p_f, s_f = fused.run(lits)
    p_s, _ = staged.run(lits)
    np.testing.assert_array_equal(p_f, p_s)
    assert "energy" not in s_f and fused.reports == []


def test_run_stats_are_per_burst(small_system):
    """run() reports the burst it served, not engine lifetime; lifetime
    aggregates stay available via stats()."""
    system, lits = small_system
    eng = IMPACTEngine(system, impl="xla", max_batch=8, buckets=(8,))
    _, s1 = eng.run(lits[:16])
    _, s2 = eng.run(lits[16:32])
    assert s1["samples"] == 16 and s2["samples"] == 16
    assert s2["energy"].datapoints == 16
    life = eng.stats()
    assert life["samples"] == 32 and life["energy"].datapoints == 32


def test_bucket_padding_is_neutral(small_system):
    """A lone request padded up to the smallest bucket must predict the
    same as the full-batch path (padding lanes draw no current)."""
    system, lits = small_system
    direct = np.asarray(system.predict(jnp.asarray(lits[:1]), impl="xla"))
    eng = IMPACTEngine(system, impl="xla", max_batch=8, buckets=(8,),
                       max_wait_s=0.0)
    rid = eng.submit(lits[0])
    out = dict(eng.step(force=True))
    assert out[rid] == int(direct[0])
    assert eng.batch_stats[0].bucket == 8
    assert eng.batch_stats[0].n_valid == 1


def test_bucket_selection():
    eng = IMPACTEngine.__new__(IMPACTEngine)   # bucket_for only reads buckets
    eng.buckets = [8, 32, 128]
    assert eng.bucket_for(1) == 8
    assert eng.bucket_for(8) == 8
    assert eng.bucket_for(9) == 32
    assert eng.bucket_for(1000) == 128     # capped at max bucket


def test_flush_on_full_and_stale(small_system):
    system, lits = small_system
    eng = IMPACTEngine(system, impl="xla", mode="flush", max_batch=4,
                       max_wait_s=10.0)
    for i in range(3):
        eng.submit(lits[i])
    assert eng.step() == []                # 3 < max_batch, not stale
    eng.submit(lits[3])
    assert len(eng.step()) == 4            # flush on full
    eng.submit(lits[4])
    eng.queue.pending[0].arrived = time.time() - 11.0
    assert len(eng.step()) == 1            # flush on stale


def test_energy_aggregation(small_system):
    system, lits = small_system
    eng = IMPACTEngine(system, impl="xla", max_batch=8, meter_energy=True)
    _, stats = eng.run(lits)
    agg = stats["energy"]
    assert agg.datapoints == lits.shape[0]
    assert agg.read_energy_j > 0
    assert stats["energy_per_datapoint_j"] > 0
    # aggregate == sum of the per-batch reports
    np.testing.assert_allclose(
        agg.read_energy_j, sum(r.read_energy_j for r in eng.reports))
    assert agg.program_energy_j == eng.reports[0].program_energy_j


def test_warmup_removes_cold_batches(small_system):
    """Throughput stats must not be skewed by per-bucket jit compile:
    the first batch of an unwarmed bucket is flagged cold and excluded
    from samples_per_s; warmup() pre-compiles so nothing is cold."""
    system, lits = small_system
    cold_eng = IMPACTEngine(system, impl="xla", max_batch=8, buckets=(8,))
    _, cold_stats = cold_eng.run(lits[:8])
    assert cold_stats["cold_batches"] == 1

    warm_eng = IMPACTEngine(system, impl="xla", max_batch=8, buckets=(8,))
    warm_eng.warmup()
    assert warm_eng.reports == []          # warmup traffic is not metered
    _, warm_stats = warm_eng.run(lits[:8])
    assert warm_stats["cold_batches"] == 0
    assert warm_stats["energy"].datapoints == 8


def test_aggregate_reports_requires_nonempty():
    with pytest.raises(AssertionError):
        aggregate_reports([])


def test_padding_lanes_not_billed(small_system):
    """An all-1 pad lane fires every nonempty clause (vacuous truth), so
    without the validity mask it would draw phantom class-tile current;
    the metered report must bill exactly the real lanes."""
    system, lits = small_system
    _, ref_report = system.infer_with_report(jnp.asarray(lits[:1]),
                                             impl="xla")
    eng = IMPACTEngine(system, impl="xla", max_batch=8, buckets=(8,),
                       meter_energy=True)
    eng.submit(lits[0])
    eng.step(force=True)
    (padded_report,) = eng.reports
    assert padded_report.datapoints == 1
    np.testing.assert_allclose(padded_report.read_energy_j,
                               ref_report.read_energy_j, rtol=1e-6)
    np.testing.assert_allclose(padded_report.class_energy_j,
                               ref_report.class_energy_j, rtol=1e-6)
