"""Compiled-session runtime: RuntimeSpec validation, backend registry
pluggability, compile-once semantics (retrace guard), InferenceResult
contents, and exact-parity deprecation shims for the old per-call kwargs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.impact import (IMPACTConfig, InferenceResult, InferenceSession,
                          RuntimeSpec, SpecDeprecationWarning, Topology,
                          build_system)
from repro.core import CoTMConfig
from repro.core.cotm import CoTMParams
from repro.kernels import backends
from repro.serve import IMPACTEngine


@pytest.fixture(scope="module")
def small_system():
    K, n, m, n_states = 64, 32, 4, 64
    cfg = CoTMConfig(n_literals=K, n_clauses=n, n_classes=m,
                     n_states=n_states)
    rng = np.random.default_rng(0)
    ta = np.where(rng.random((K, n)) < 0.1, n_states + 1, n_states)
    w = rng.integers(-20, 20, (m, n))
    params = CoTMParams(ta_state=jnp.asarray(ta, jnp.int32),
                        weights=jnp.asarray(w, jnp.int32))
    system = build_system(params, cfg, jax.random.key(0),
                          IMPACTConfig(variability=False, finetune=False))
    lits = rng.random((40, K)) < 0.5
    return system, lits


# -- backend registry --------------------------------------------------------

def test_registry_contents_and_errors():
    assert {"pallas", "xla", "pallas-metered"} \
        <= set(backends.available_backends())
    assert backends.get_backend("xla").reference
    assert not backends.get_backend("pallas").reference
    assert not backends.get_backend("pallas-metered").reference
    assert isinstance(backends.get_backend("pallas-metered"),
                      backends.PallasBackend)
    with pytest.raises(ValueError, match="unknown backend"):
        backends.get_backend("mythical")
    with pytest.raises(ValueError, match="already registered"):
        backends.register_backend(backends.XLABackend())
    with pytest.raises(ValueError, match="non-empty"):
        backends.register_backend(backends.Backend())


def test_registered_backend_plugs_into_sessions(small_system):
    """A third backend slots into every entry point by registration alone
    — no call-site changes (the registry acceptance criterion).  This one
    delegates to the oracle, so outputs must match the xla session."""
    system, lits = small_system

    class ShadowXLA(backends.XLABackend):
        name = "xla-shadow-test"

    backends.register_backend(ShadowXLA())
    try:
        shadow = system.compile(RuntimeSpec(backend="xla-shadow-test",
                                            capacity=8))
        plain = system.compile(RuntimeSpec(backend="xla", capacity=8))
        np.testing.assert_array_equal(
            np.asarray(shadow.predict(lits[:8]).predictions),
            np.asarray(plain.predict(lits[:8]).predictions))
        r_s = shadow.infer_with_report(lits[:8]).report
        r_p = plain.infer_with_report(lits[:8]).report
        np.testing.assert_allclose(r_s.read_energy_j, r_p.read_energy_j)
    finally:
        backends.unregister_backend("xla-shadow-test")
    assert "xla-shadow-test" not in backends.available_backends()
    with pytest.raises(ValueError, match="not registered"):
        backends.unregister_backend("xla-shadow-test")


def test_interpret_resolver_policy():
    """The shared shape-policy hook: None means interpret off-TPU for
    kernel backends; reference backends have nothing to interpret."""
    pallas = backends.get_backend("pallas")
    on_tpu = jax.default_backend() == "tpu"
    assert pallas.resolve_interpret(None) == (not on_tpu)
    assert pallas.resolve_interpret(True) is True
    assert pallas.resolve_interpret(False) is False
    assert backends.get_backend("xla").resolve_interpret(None) is False


# -- spec validation ---------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError, match="metering"):
        RuntimeSpec(metering="always")
    # every declared metering mode is a valid spec
    for mode in ("off", "staged", "fused"):
        assert RuntimeSpec(metering=mode).metering == mode
    with pytest.raises(ValueError, match="precision"):
        RuntimeSpec(precision="bf16")
    with pytest.raises(ValueError, match="packing"):
        RuntimeSpec(packing="4bit")
    for packing in ("none", "2bit"):
        assert RuntimeSpec(packing=packing).packing == packing
    with pytest.raises(ValueError, match="capacity"):
        RuntimeSpec(capacity=0)
    with pytest.raises(ValueError, match="batch_sizes"):
        RuntimeSpec(batch_sizes=(0,))
    with pytest.raises(ValueError, match="shard mode"):
        Topology(shard="diagonal")
    # specs are hashable values: equal fields => equal keys
    assert RuntimeSpec(backend="xla") == RuntimeSpec(backend="xla")
    assert hash(RuntimeSpec()) == hash(RuntimeSpec())


def test_compile_validates_spec(small_system):
    system, _ = small_system
    with pytest.raises(ValueError, match="unknown backend"):
        system.compile(RuntimeSpec(backend="mythical"))
    with pytest.raises(ValueError, match="mesh"):
        system.compile(RuntimeSpec(topology=Topology(shard="both")))


def test_compile_caches_per_spec(small_system):
    """compile() is idempotent: the same spec (as a value, not an object)
    resolves to the SAME session, so sessions are safe to re-derive."""
    system, _ = small_system
    a = system.compile(RuntimeSpec(backend="xla", capacity=8))
    b = system.compile(RuntimeSpec(backend="xla", capacity=8))
    assert a is b
    assert isinstance(a, InferenceSession)
    assert a is not system.compile(RuntimeSpec(backend="xla", capacity=4))


# -- packed sessions ---------------------------------------------------------

def test_packed_session_parity_and_input_bytes(small_system):
    """packing='2bit' compiles the packed executable: argmax parity with
    the unpacked session, operand footprint down >= 4x (the layout-level
    half of the perf gate's compressed section), and the spec value
    surfaces in repr for debuggability."""
    system, lits = small_system
    base = system.compile(RuntimeSpec(backend="pallas", metering="off"))
    packed = system.compile(RuntimeSpec(backend="pallas-packed",
                                        packing="2bit", metering="off"))
    np.testing.assert_array_equal(
        np.asarray(packed.predict(lits[:16]).predictions),
        np.asarray(base.predict(lits[:16]).predictions))
    ratio = base.input_bytes("predict", 16) / packed.input_bytes("predict", 16)
    assert ratio >= 4.0, ratio
    assert "packing='2bit'" in repr(packed)
    assert "packing='none'" in repr(base)


def test_packing_is_backend_agnostic(small_system):
    """packing='2bit' is a spec value, not a pallas-packed privilege: the
    base-class dequant fallback serves it on every backend, and all
    backends agree on argmax (they consume the same quantized operand,
    so scores differ only by float association)."""
    system, lits = small_system
    preds = {
        impl: np.asarray(
            system.compile(RuntimeSpec(backend=impl, packing="2bit",
                                       metering="off"))
            .predict(lits[:16]).predictions)
        for impl in ("xla", "pallas", "pallas-packed")}
    np.testing.assert_array_equal(preds["xla"], preds["pallas"])
    np.testing.assert_array_equal(preds["xla"], preds["pallas-packed"])


def test_packed_session_metered_report(small_system):
    """Metering on a packed session works end to end and bills positive
    joules (the quantized currents, not zeros)."""
    system, lits = small_system
    rep = system.compile(RuntimeSpec(backend="pallas-packed",
                                     packing="2bit", metering="fused")) \
        .infer_with_report(lits[:8]).report
    assert rep.read_energy_j > 0
    assert rep.datapoints == 8


# -- compile-once semantics (the retrace guard) ------------------------------

def test_session_precompiles_spec_shapes(small_system):
    system, _ = small_system
    sess = system.compile(RuntimeSpec(backend="xla", capacity=8,
                                      batch_sizes=(4, 12)))
    assert sess.is_compiled("infer_step", 8)
    assert sess.is_compiled("predict", 4)
    assert sess.is_compiled("predict", 12)
    assert sess.trace_count == 3
    assert sess.capacity == 8 and sess.meters_energy


def test_retrace_guard_across_serving(small_system):
    """The compile-once acceptance test: after session build (+ declared
    shapes), repeated predict calls, arbitrary admission patterns, and
    whole engine sweeps trigger ZERO new traces — pinned by the
    session's trace counters (each counter bumps exactly when a python
    body is traced for compilation)."""
    system, lits = small_system
    sess = system.compile(RuntimeSpec(backend="xla", capacity=8,
                                      batch_sizes=(4,)))
    built = sess.trace_count                   # capacity + batch_sizes
    assert built == 2

    # repeated predict at a compiled shape: no new traces
    for i in range(3):
        sess.predict(lits[i:i + 4])
    assert sess.trace_count == built

    # a NEW batch shape compiles exactly once, then caches
    sess.predict(lits[:6])
    assert sess.trace_count == built + 1
    sess.predict(lits[6:12])
    assert sess.trace_count == built + 1

    # every admission pattern reuses the one slot-table executable
    buf = np.ones((8, system.n_literals), np.int8)
    for k in (1, 3, 8, 2):
        valid = np.zeros((8,), bool)
        valid[:k] = True
        buf[:k] = lits[:k]
        sess.infer_step(buf, valid)
    assert sess.trace_count == built + 1

    # engine sweeps (admit/release/partial tails) ride the same
    # executable: a full burst adds zero traces
    eng = IMPACTEngine(sess)
    preds, stats = eng.run(lits[:20])
    assert stats["cold_batches"] == 0
    assert sess.trace_count == built + 1

    # metered report at a fresh shape is the only remaining compile
    sess.infer_with_report(lits[:5])
    assert sess.trace_count == built + 2
    sess.infer_with_report(lits[5:10])
    assert sess.trace_count == built + 2


def test_session_canonicalizes_caller_dtypes(small_system):
    """bool / int8 / float {0,1} literals hit the SAME executable — the
    session casts once instead of letting caller dtypes fragment the
    AOT cache (and the results agree exactly)."""
    system, lits = small_system
    sess = system.compile(RuntimeSpec(backend="xla"))
    base = np.asarray(sess.predict(lits[:8]).predictions)   # np.bool_
    tc = sess.trace_count
    np.testing.assert_array_equal(
        np.asarray(sess.predict(lits[:8].astype(np.int8)).predictions),
        base)
    np.testing.assert_array_equal(
        np.asarray(sess.predict(lits[:8].astype(np.float32)).predictions),
        base)
    np.testing.assert_array_equal(
        np.asarray(sess.predict(jnp.asarray(lits[:8])).predictions), base)
    assert sess.trace_count == tc


# -- InferenceResult ---------------------------------------------------------

def test_inference_result_contents(small_system):
    system, lits = small_system
    sess = system.compile(RuntimeSpec(backend="xla", capacity=8))
    pred = sess.predict(lits[:8])
    assert isinstance(pred, InferenceResult)
    assert pred.scores.shape == (8, system.n_classes)
    assert pred.report is None and pred.e_clause_lanes is None
    np.testing.assert_array_equal(
        np.asarray(pred.predictions),
        np.asarray(jnp.argmax(pred.scores, axis=-1)))

    valid = np.ones((8,), bool)
    step = sess.infer_step(np.asarray(lits[:8], np.int8), valid)
    assert step.e_clause_lanes.shape == (8,)
    assert step.e_class_lanes.shape == (8,)
    assert step.report is None

    rep = sess.infer_with_report(lits[:8])
    assert rep.report.datapoints == 8
    assert rep.report.read_energy_j > 0
    with pytest.raises(dataclasses.FrozenInstanceError):
        rep.report = None


def test_metering_off_blocks_reports_and_zeros_lanes(small_system):
    system, lits = small_system
    sess = system.compile(RuntimeSpec(backend="xla", metering="off",
                                      capacity=8))
    assert not sess.meters_energy
    step = sess.infer_step(np.asarray(lits[:8], np.int8),
                           np.ones((8,), bool))
    np.testing.assert_array_equal(np.asarray(step.e_clause_lanes), 0.0)
    with pytest.raises(RuntimeError, match="metering"):
        sess.infer_with_report(lits[:8])


# -- deprecation shims: old kwargs forward, warn, and agree exactly ----------

def test_predict_shim_parity_and_warning(small_system):
    system, lits = small_system
    want = np.asarray(system.compile(RuntimeSpec(backend="xla"))
                      .predict(lits[:8]).predictions)
    with pytest.warns(SpecDeprecationWarning, match="predict"):
        old = system.predict(jnp.asarray(lits[:8]), impl="xla")
    np.testing.assert_array_equal(np.asarray(old), want)
    # the bare call (no kwargs) is NOT deprecated: default-spec session
    bare = system.predict(jnp.asarray(lits[:8]))
    np.testing.assert_array_equal(
        np.asarray(bare),
        np.asarray(system.compile().predict(lits[:8]).predictions))


def test_infer_step_shim_parity_and_warning(small_system):
    system, lits = small_system
    buf = np.ones((8, system.n_literals), np.int8)
    buf[:3] = lits[:3]
    valid = np.zeros((8,), bool)
    valid[:3] = True
    sess = system.compile(RuntimeSpec(backend="xla", capacity=8))
    want = sess.infer_step(buf, valid)
    with pytest.warns(SpecDeprecationWarning, match="infer_step"):
        p, e_cl, e_cs = system.infer_step(jnp.asarray(buf), valid,
                                          impl="xla", meter=True)
    np.testing.assert_array_equal(np.asarray(p),
                                  np.asarray(want.predictions))
    np.testing.assert_array_equal(np.asarray(e_cl),
                                  np.asarray(want.e_clause_lanes))
    np.testing.assert_array_equal(np.asarray(e_cs),
                                  np.asarray(want.e_class_lanes))
    # bare call preserves the old meter=False default: zero energies
    p0, z_cl, z_cs = system.infer_step(jnp.asarray(buf), valid)
    np.testing.assert_array_equal(np.asarray(p0),
                                  np.asarray(want.predictions))
    np.testing.assert_array_equal(np.asarray(z_cl), 0.0)


def test_infer_with_report_shim_parity_and_warning(small_system):
    system, lits = small_system
    want = system.compile(RuntimeSpec(backend="xla")) \
        .infer_with_report(lits[:8])
    with pytest.warns(SpecDeprecationWarning, match="infer_with_report"):
        preds, report = system.infer_with_report(jnp.asarray(lits[:8]),
                                                 impl="xla")
    np.testing.assert_array_equal(np.asarray(preds),
                                  np.asarray(want.predictions))
    assert report.read_energy_j == want.report.read_energy_j
    assert report.datapoints == want.report.datapoints
    assert report.latency_s == want.report.latency_s


def test_engine_shim_parity_and_warning(small_system):
    system, lits = small_system
    sess = system.compile(RuntimeSpec(backend="xla", metering="off",
                                      capacity=16))
    want, _ = IMPACTEngine(sess).run(lits)
    with pytest.warns(SpecDeprecationWarning, match="IMPACTEngine"):
        legacy = IMPACTEngine(system, impl="xla", max_batch=16,
                              meter_energy=False)
    got, stats = legacy.run(lits)
    np.testing.assert_array_equal(got, want)
    assert legacy.session is sess      # same spec => same cached session
    # a bare IMPACTEngine(system) is the supported convenience form
    conv = IMPACTEngine(system, max_batch=16)
    assert conv.capacity == 16 and conv.meter_energy
