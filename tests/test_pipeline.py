"""End-to-end IMPACT system: accuracy preservation + Fig. 14 tiling
invariance (the paper's multi-crossbar scaling scheme)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoTMConfig, predict, train_epochs
from repro.data.synthetic import prototype
from repro.impact import IMPACTConfig, build_system


@pytest.fixture(scope="module")
def trained():
    cfg = CoTMConfig(n_literals=128, n_clauses=64, n_classes=4,
                     n_states=64, threshold=16, specificity=4.0)
    x, y = prototype(768, n_classes=4, n_features=64, flip=0.05)
    lits = jnp.asarray(np.concatenate([x, 1 - x], -1).astype(bool))
    labels = jnp.asarray(y)
    params = train_epochs(cfg.init(jax.random.key(0)), lits, labels,
                          jax.random.key(1), cfg, epochs=8, batch_size=64)
    sw_acc = float((predict(params, lits, cfg) == labels).mean())
    return cfg, params, lits, labels, sw_acc


def test_software_baseline_accuracy(trained):
    *_, sw_acc = trained
    assert sw_acc > 0.9, sw_acc


def test_impact_preserves_software_accuracy(trained):
    """Hardware mapping under full C2C/D2D variability must track the
    software model (the paper's central §4 claim: 96.31% hw vs 96.3% sw)."""
    cfg, params, lits, labels, sw_acc = trained
    system = build_system(params, cfg, jax.random.key(2))
    hw_acc = float((system.predict(lits) == labels).mean())
    assert hw_acc >= sw_acc - 0.03, (sw_acc, hw_acc)


def test_fig14_tile_split_invariance(trained):
    """Splitting literals/clauses across tiles (partial clauses combined
    by digital AND; partial class sums summed after ADC) must give
    identical predictions with variability disabled."""
    cfg, params, lits, labels, _ = trained
    base_cfg = IMPACTConfig(variability=False, finetune=False,
                            max_tile_rows=2048, max_tile_cols=512,
                            max_class_rows=2048)
    split_cfg = IMPACTConfig(variability=False, finetune=False,
                             max_tile_rows=32, max_tile_cols=16,
                             max_class_rows=16)
    sys_one = build_system(params, cfg, jax.random.key(3), base_cfg)
    sys_many = build_system(params, cfg, jax.random.key(3), split_cfg)
    p1 = np.asarray(sys_one.predict(lits[:128]))
    p2 = np.asarray(sys_many.predict(lits[:128]))
    np.testing.assert_array_equal(p1, p2)
    assert sys_many.clause_g.shape[0] > 1     # literals actually split
    assert sys_many.class_g.shape[0] > 1      # clauses actually split


def test_energy_report(trained):
    cfg, params, lits, labels, _ = trained
    system = build_system(params, cfg, jax.random.key(4))
    preds, report = system.infer_with_report(lits[:64])
    assert report.read_energy_j > 0
    assert report.energy_per_datapoint_j > 0
    assert report.gops > 0
    assert report.tops_per_w > 0
    # energy per datapoint should be in the paper's pJ regime (loose).
    e_pj = report.energy_per_datapoint_j * 1e12
    assert 0.1 < e_pj < 1e4, e_pj
