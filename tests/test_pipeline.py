"""End-to-end IMPACT system: accuracy preservation + Fig. 14 tiling
invariance (the paper's multi-crossbar scaling scheme)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoTMConfig, predict, train_epochs
from repro.data.synthetic import prototype
from repro.impact import EnergyReport, IMPACTConfig, build_system
from repro.impact.energy import T_COLUMN, inference_latency, tile_area_mm2


@pytest.fixture(scope="module")
def trained():
    cfg = CoTMConfig(n_literals=128, n_clauses=64, n_classes=4,
                     n_states=64, threshold=16, specificity=4.0)
    x, y = prototype(768, n_classes=4, n_features=64, flip=0.05)
    lits = jnp.asarray(np.concatenate([x, 1 - x], -1).astype(bool))
    labels = jnp.asarray(y)
    params = train_epochs(cfg.init(jax.random.key(0)), lits, labels,
                          jax.random.key(1), cfg, epochs=8, batch_size=64)
    sw_acc = float((predict(params, lits, cfg) == labels).mean())
    return cfg, params, lits, labels, sw_acc


def test_software_baseline_accuracy(trained):
    *_, sw_acc = trained
    assert sw_acc > 0.9, sw_acc


def test_impact_preserves_software_accuracy(trained):
    """Hardware mapping under full C2C/D2D variability must track the
    software model (the paper's central §4 claim: 96.31% hw vs 96.3% sw)."""
    cfg, params, lits, labels, sw_acc = trained
    system = build_system(params, cfg, jax.random.key(2))
    hw_acc = float((system.predict(lits) == labels).mean())
    assert hw_acc >= sw_acc - 0.03, (sw_acc, hw_acc)


def test_fig14_tile_split_invariance(trained):
    """Splitting literals/clauses across tiles (partial clauses combined
    by digital AND; partial class sums summed after ADC) must give
    identical predictions with variability disabled."""
    cfg, params, lits, labels, _ = trained
    base_cfg = IMPACTConfig(variability=False, finetune=False,
                            max_tile_rows=2048, max_tile_cols=512,
                            max_class_rows=2048)
    split_cfg = IMPACTConfig(variability=False, finetune=False,
                             max_tile_rows=32, max_tile_cols=16,
                             max_class_rows=16)
    sys_one = build_system(params, cfg, jax.random.key(3), base_cfg)
    sys_many = build_system(params, cfg, jax.random.key(3), split_cfg)
    p1 = np.asarray(sys_one.predict(lits[:128]))
    p2 = np.asarray(sys_many.predict(lits[:128]))
    np.testing.assert_array_equal(p1, p2)
    assert sys_many.clause_g.shape[0] > 1     # literals actually split
    assert sys_many.class_g.shape[0] > 1      # clauses actually split


def test_energy_report(trained):
    cfg, params, lits, labels, _ = trained
    system = build_system(params, cfg, jax.random.key(4))
    preds, report = system.infer_with_report(lits[:64])
    assert report.read_energy_j > 0
    assert report.energy_per_datapoint_j > 0
    assert report.gops > 0
    assert report.tops_per_w > 0
    # energy per datapoint should be in the paper's pJ regime (loose).
    e_pj = report.energy_per_datapoint_j * 1e12
    assert 0.1 < e_pj < 1e4, e_pj


# --- Fig. 14 multi-tile latency model (regression for the (R, C)-blind
# accounting that hardcoded clause_tiles_parallel=1 and one tile's cols) --


def test_multi_tile_latency_counts_whole_grid(trained):
    """C > 1 system: latency streams ALL n_clauses columns through the
    grid's C parallel column-tiles — ceil(n/C) cycles + one class-read
    cycle — not one tile's column count (the old model reported
    min(tc, n) = tc cycles regardless of how columns spread over the
    grid, so GOPS silently mis-scaled for C > 1)."""
    cfg, params, lits, labels, _ = trained
    split = IMPACTConfig(variability=False, finetune=False,
                         max_tile_cols=24, max_class_rows=32)
    system = build_system(params, cfg, jax.random.key(5), split)
    R, C, tr, tc = system.clause_g.shape
    assert C == 3 and cfg.n_clauses == 64
    _, report = system.infer_with_report(lits[:16])
    want = -(-cfg.n_clauses // C) * T_COLUMN + T_COLUMN   # 22 cycles + 1
    assert report.latency_s == pytest.approx(want)
    # the old one-tile accounting (min(tc, n) = 24 cycles) must NOT match
    assert abs(report.latency_s - (tc * T_COLUMN + T_COLUMN)) > 1e-12
    assert report.gops == pytest.approx(
        (cfg.n_literals * cfg.n_clauses + cfg.n_clauses * cfg.n_classes)
        / want / 1e9)
    # step_report (the serving-path meter) uses the same grid model
    step = system.step_report(np.zeros(4), np.zeros(4), 4)
    assert step.latency_s == pytest.approx(want)


def test_table4_single_tile_latency_unchanged():
    """Paper layout (500x1568 clause tile, C=1): 500 columns stream
    sequentially at 5 ns + one class read — 2.505 us, pinned so the
    Table 4 GOPS anchor cannot drift."""
    lat = inference_latency(n_clause_cols=500, n_class_cols=10,
                            clause_tiles_parallel=1)
    assert lat == pytest.approx(500 * T_COLUMN + T_COLUMN)
    assert lat == pytest.approx(2.505e-6)


# --- tops_per_mm2 (was an unconditional 0.0 stub) -------------------------


def test_tops_per_mm2_from_system_area(trained):
    """System-level reports carry the occupied-area and report a real
    TOPS/mm^2; area-less reports refuse instead of rendering 0.0."""
    cfg, params, lits, labels, _ = trained
    system = build_system(params, cfg, jax.random.key(4))
    _, report = system.infer_with_report(lits[:64])
    area = sum(system.area_mm2().values())
    assert report.area_mm2 == pytest.approx(area)
    want = (2 * report.ops_crosspoint / report.datapoints
            / report.latency_s) / 1e12 / area
    assert report.tops_per_mm2 == pytest.approx(want)
    assert report.tops_per_mm2 > 0
    bare = dataclasses.replace(report, area_mm2=None)
    with pytest.raises(ValueError, match="area"):
        bare.tops_per_mm2


def test_tops_per_mm2_table4_anchor():
    """Paper dims (K=1568, n=500, m=10) under the Table 4 conventions
    (MAC-equivalents = 2/crosspoint; occupied area at 3.159 um^2/device):
    ~0.25 TOPS/mm^2, the same order as the paper's Table 6 entry (0.17,
    which uses the measured GOPS)."""
    ops_dp = 1568 * 500 + 500 * 10
    lat = inference_latency(500, 10, 1)
    area = tile_area_mm2(1568, 500) + tile_area_mm2(500, 10)
    rep = EnergyReport(read_energy_j=1.0, clause_energy_j=0.5,
                       class_energy_j=0.5, program_energy_j=0.0,
                       erase_energy_j=0.0, latency_s=lat,
                       ops_crosspoint=ops_dp, datapoints=1, area_mm2=area)
    assert rep.tops_per_mm2 == pytest.approx(2 * ops_dp / lat / 1e12 / area)
    assert 0.2 < rep.tops_per_mm2 < 0.3, rep.tops_per_mm2


def test_tops_per_mm2_empty_aggregate_reports_zero():
    """An empty aggregate (0 latency, 0 datapoints) reports 0.0 under the
    same convention as the gops / tops_per_w guards — not
    ZeroDivisionError (regression: the latency_s division was the one
    unguarded denominator in EnergyReport).  The area-less refusal still
    wins over the empty-aggregate shortcut."""
    empty = EnergyReport(read_energy_j=0.0, clause_energy_j=0.0,
                         class_energy_j=0.0, program_energy_j=0.0,
                         erase_energy_j=0.0, latency_s=0.0,
                         ops_crosspoint=0.0, datapoints=0, area_mm2=1.0)
    assert empty.tops_per_mm2 == 0.0
    assert empty.gops == 0.0 and empty.tops_per_w == 0.0   # same convention
    with pytest.raises(ValueError, match="area"):
        dataclasses.replace(empty, area_mm2=None).tops_per_mm2
