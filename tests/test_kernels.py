"""Pallas kernel sweeps: every kernel vs its pure-jnp oracle.

Kernels run in interpret mode on CPU (the kernel body executes in Python),
so these are exact-semantics checks of the TPU kernels' block/grid logic,
including the padding paths in ``ops``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref

SHAPES = [
    # (B, K, N, M) — mix of aligned and ragged
    (1, 128, 128, 10),
    (8, 300, 77, 3),
    (37, 512, 500, 10),       # paper's clause/class dims (cropped)
    (128, 1568, 500, 10),     # paper MNIST shape
    (5, 130, 257, 17),
]


def _inputs(B, K, N, M, seed=0, density=0.05):
    rng = np.random.default_rng(seed)
    lit = rng.random((B, K)) < 0.5
    inc = rng.random((K, N)) < density
    w = rng.integers(-50, 420, (N, M)).astype(np.int32)
    return jnp.asarray(lit), jnp.asarray(inc), jnp.asarray(w)


@pytest.mark.parametrize("B,K,N,M", SHAPES)
def test_clause_eval_matches_oracle(B, K, N, M):
    lit, inc, _ = _inputs(B, K, N, M)
    ne = inc.any(axis=0)
    got = ops.clause_eval(lit, inc, ne)
    want = ref.clause_eval_ref(lit, inc, ne)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,K,N,M", SHAPES)
def test_clause_viol_matches_oracle(B, K, N, M):
    lit, inc, _ = _inputs(B, K, N, M)
    got = ops.clause_eval(lit, inc, mode="viol")
    want = ref.clause_viol_ref(lit, inc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,K,N,M", SHAPES)
def test_class_sum_matches_oracle(B, K, N, M):
    lit, inc, w = _inputs(B, K, N, M)
    clauses = ref.clause_eval_ref(lit, inc)
    got = ops.class_sum(clauses, w)
    want = ref.class_sum_ref(clauses, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,K,N,M", SHAPES)
def test_fused_cotm_matches_oracle(B, K, N, M):
    lit, inc, w = _inputs(B, K, N, M)
    got = ops.fused_cotm(lit, inc, w)
    want = ref.fused_cotm_ref(lit, inc, w, inc.any(0))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,K,N,M", SHAPES[:3])
def test_crossbar_mvm_matches_oracle(B, K, N, M):
    rng = np.random.default_rng(1)
    drive = jnp.asarray(rng.random((B, K)), jnp.float32)
    g = jnp.asarray(10.0 ** rng.uniform(-9, -5.6, (K, N)), jnp.float32)
    got = ops.crossbar_mvm(drive, g)
    want = ref.crossbar_mvm_ref(drive, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 20), K=st.integers(1, 300), N=st.integers(1, 200),
    M=st.integers(1, 16), density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2 ** 16),
)
def test_fused_cotm_hypothesis(B, K, N, M, density, seed):
    rng = np.random.default_rng(seed)
    lit = jnp.asarray(rng.random((B, K)) < 0.5)
    inc = jnp.asarray(rng.random((K, N)) < density)
    w = jnp.asarray(rng.integers(-128, 421, (N, M)).astype(np.int32))
    got = ops.fused_cotm(lit, inc, w)
    want = ref.fused_cotm_ref(lit, inc, w, inc.any(0))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 16), K=st.integers(1, 256), N=st.integers(1, 160),
    seed=st.integers(0, 2 ** 16),
)
def test_clause_eval_hypothesis(B, K, N, seed):
    rng = np.random.default_rng(seed)
    lit = jnp.asarray(rng.random((B, K)) < rng.random())
    inc = jnp.asarray(rng.random((K, N)) < rng.random())
    got = ops.clause_eval(lit, inc)
    want = ref.clause_eval_ref(lit, inc, inc.any(0))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int8_dtype_inputs():
    """Kernels accept int8/bool/int32 literal encodings identically."""
    lit, inc, w = _inputs(16, 256, 128, 10)
    a = ops.fused_cotm(lit, inc, w)
    b = ops.fused_cotm(lit.astype(jnp.int8), inc.astype(jnp.int8), w)
    c = ops.fused_cotm(lit.astype(jnp.int32), inc.astype(jnp.int32), w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_block_size_invariance():
    """Different BlockSpec tilings must not change results."""
    lit, inc, w = _inputs(64, 640, 384, 10)
    base = ops.fused_cotm(lit, inc, w)
    for bb, bn in [(128, 128), (256, 384)]:
        got = ops.fused_cotm(lit, inc, w, block_b=bb, block_n=bn)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(base))
    base2 = ops.clause_eval(lit, inc)
    for bk in [128, 256, 640]:
        got = ops.clause_eval(lit, inc, block_k=bk)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(base2))
