"""Checkpointing + fault-tolerant runtime: atomicity, resume, restarts."""
import json
import os
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.train import (AdamWConfig, CheckpointManager, RuntimeConfig,
                         SimulatedFailure, TrainLoop, init_state,
                         make_train_step)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(7, t)
    restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, t))
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)


def test_atomic_publish_ignores_partial(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(1, t)
    # Simulate a crash mid-save: stray .tmp directory + torn step dir
    (tmp_path / "step_2.tmp").mkdir()
    torn = tmp_path / "step_3"
    torn.mkdir()
    (torn / "garbage.npy").write_bytes(b"xx")   # no manifest
    assert mgr.latest_step() == 1
    _, step = mgr.restore(t)
    assert step == 1


def test_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(5, t, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def _loop(tmp_path, fail_at=None, max_steps=12):
    cfg = get_config("starcoder2-3b").smoke()
    model = build(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1)
    state = init_state(model.init(jax.random.key(0)), opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg))
    tok = jax.random.randint(jax.random.key(1), (1, 2, 32), 0, cfg.vocab)

    def data():
        while True:
            yield {"tokens": tok}

    rt = RuntimeConfig(ckpt_dir=str(tmp_path), max_steps=max_steps,
                       save_every=4, fail_at_step=fail_at,
                       heartbeat_every=4)
    return TrainLoop(step, state, data(), rt)


def test_resume_after_failure_bit_exact(tmp_path):
    # Uninterrupted run -> reference final state.
    ref = _loop(tmp_path / "ref").run(seed=0)

    # Crash at step 9 (after the step-8 checkpoint), then resume.
    loop1 = _loop(tmp_path / "ft", fail_at=9)
    with pytest.raises(SimulatedFailure):
        loop1.run(seed=0)
    loop1.mgr.wait()
    assert loop1.mgr.latest_step() == 8

    loop2 = _loop(tmp_path / "ft")          # fresh process, auto-resume
    final = loop2.run(seed=0)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        ref.params, final.params)


def test_heartbeat_written(tmp_path):
    loop = _loop(tmp_path, max_steps=8)
    loop.run(seed=0)
    hb = json.loads((tmp_path / "HEARTBEAT").read_text())
    assert hb["step"] == 8


def test_straggler_detection(tmp_path):
    loop = _loop(tmp_path, max_steps=10)
    events = []
    loop.on_straggler = lambda step, dt: events.append((step, dt))
    # Inject artificial delay into one step via a wrapper.
    orig = loop.train_step
    slow = {"n": 0}

    def wrapped(state, batch, seed):
        import time
        slow["n"] += 1
        if slow["n"] == 8:
            time.sleep(1.5)
        return orig(state, batch, seed)

    loop.train_step = wrapped
    loop.run(seed=0)
    assert loop.straggler_events >= 1
    assert events and events[0][1] > 1.0
