"""Hypothesis shim: use the real library when installed, else a minimal
deterministic fallback so the property tests still collect and run.

The fallback implements only what this suite uses — ``@given`` with keyword
strategies built from ``integers``/``floats``/``booleans``/``sampled_from``
— and replays a fixed number of deterministically seeded examples per test
(seeded from the test name, so outcomes are stable across runs and
independent of test order).  ``@settings`` keeps its call signature but only
``max_examples`` is honoured, capped so tier-1 stays fast without shrinking
support.  Real-hypothesis features (shrinking, the example database,
``assume``) are simply absent; install ``hypothesis`` to get them back.
"""
from __future__ import annotations

import functools
import inspect
import math
import zlib

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random

    _FALLBACK_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
            # Log-uniform when the range spans decades (device conductances
            # and pulse widths), uniform otherwise — mirrors how hypothesis
            # explores wide float ranges enough for these tests.
            def draw(rng: random.Random) -> float:
                if min_value > 0 and max_value / min_value > 1e3:
                    lo, hi = math.log(min_value), math.log(max_value)
                    return math.exp(rng.uniform(lo, hi))
                return rng.uniform(min_value, max_value)

            return _Strategy(draw)

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            pool = list(elements)
            return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

    strategies = _Strategies()

    def given(**strats):
        for name, s in strats.items():
            if not isinstance(s, _Strategy):
                raise TypeError(f"unsupported strategy for {name!r}: {s!r}")

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_max_examples",
                                _FALLBACK_MAX_EXAMPLES),
                        _FALLBACK_MAX_EXAMPLES)
                base = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = random.Random(base + i)
                    drawn = {k: s.example(rng) for k, s in strats.items()}
                    fn(*args, **kwargs, **drawn)

            # pytest resolves test parameters by signature; hide the drawn
            # params so only real fixtures (if any) are requested.
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in strats
            ])
            del wrapper.__wrapped__
            wrapper.hypothesis_fallback = True
            return wrapper

        return deco

    def settings(*, max_examples: int | None = None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn

        return deco


st = strategies

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st", "strategies"]
