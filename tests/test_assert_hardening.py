"""Serving-path input validation must survive ``python -O``.

The IMPACT001 lint rule bans bare ``assert`` on serving/runtime paths:
``-O`` strips asserts, so an assert-guarded precondition silently
admits the bad input in an optimized deployment.  These tests pin each
converted site twice — the ValueError fires in-process, AND a
``python -O`` subprocess proves the check is a real raise, not a
stripped assert.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.serve import engine as engine_mod
from repro.serve import impact_engine as ie
from repro.serve import zoo as zoo_mod

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_aggregate_reports_rejects_empty():
    with pytest.raises(ValueError, match="no reports"):
        ie.aggregate_reports([])


def test_replay_trace_rejects_short_literals():
    # Validation is up front: the engine is never touched, so a None
    # engine proves the raise happens before any serving work.
    with pytest.raises(ValueError, match="one literal row per arrival"):
        ie.replay_trace(None, np.zeros((2, 4), bool), np.zeros(5))


def test_replay_zoo_trace_rejects_short_requests():
    with pytest.raises(ValueError, match="one request per arrival"):
        zoo_mod.replay_zoo_trace(None, [], np.zeros(3))


def test_serve_continuous_rejects_empty_and_ragged():
    with pytest.raises(ValueError, match="at least one request"):
        engine_mod.Engine.serve_continuous(None, [])
    reqs = [engine_mod.Request(rid=0, tokens=np.zeros((4,), np.int32),
                               max_new=1),
            engine_mod.Request(rid=1, tokens=np.zeros((6,), np.int32),
                               max_new=1)]
    with pytest.raises(ValueError, match="equal-length prompts"):
        engine_mod.Engine.serve_continuous(None, reqs)


def test_scatter_cache_rejects_mismatched_pytrees():
    cache = [np.zeros((4, 2)), np.zeros((4, 2))]
    new = [np.zeros((4, 2))]                       # one leaf short
    axes = [(0,), (0,)]
    with pytest.raises(ValueError, match="cache pytrees disagree"):
        engine_mod._scatter_cache(cache, axes, new,
                                  np.array([0]), np.array([1]))


# The -O proof: one subprocess (jax import is the expensive part, so all
# sites share it) running under optimized semantics, where a bare assert
# would be compiled away and each call below would sail through.
_O_SCRIPT = textwrap.dedent("""
    import sys
    assert not __debug__, "script must run under python -O"
    import numpy as np
    from repro.serve import engine as engine_mod
    from repro.serve import impact_engine as ie
    from repro.serve import zoo as zoo_mod

    def expect(fn, *args):
        try:
            fn(*args)
        except ValueError:
            return
        raise SystemExit(f"no ValueError from {fn.__name__} under -O")

    expect(ie.aggregate_reports, [])
    expect(ie.replay_trace, None, np.zeros((2, 4), bool), np.zeros(5))
    expect(zoo_mod.replay_zoo_trace, None, [], np.zeros(3))
    expect(engine_mod.Engine.serve_continuous, None, [])
    expect(engine_mod._scatter_cache,
           [np.zeros((4, 2))] * 2, [(0,), (0,)], [np.zeros((4, 2))],
           np.array([0]), np.array([1]))
    print("all serving-path validations held under -O")
""")


def test_validations_survive_python_dash_o():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    res = subprocess.run(
        [sys.executable, "-O", "-c", _O_SCRIPT],
        capture_output=True, text=True, env=env, cwd=str(REPO))
    assert res.returncode == 0, res.stdout + res.stderr
    assert "held under -O" in res.stdout
