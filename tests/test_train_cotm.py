"""CoTM training: invariants + learnability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (CoTMConfig, CoTMParams, predict, train_epochs,
                        train_step_batch, train_step_sequential)
from repro.data.synthetic import prototype


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_ta_states_stay_in_bounds(seed):
    cfg = CoTMConfig(n_literals=24, n_clauses=16, n_classes=3, n_states=8)
    key = jax.random.key(seed)
    params = cfg.init(key)
    rng = np.random.default_rng(seed)
    lits = jnp.asarray(rng.random((32, 24)) < 0.5)
    labels = jnp.asarray(rng.integers(0, 3, 32), jnp.int32)
    for i in range(5):
        params = train_step_batch(params, lits, labels,
                                  jax.random.fold_in(key, i), cfg)
    ta = np.asarray(params.ta_state)
    assert ta.min() >= 1 and ta.max() <= 2 * cfg.n_states


def _learn(step_fn, seed=0, epochs=12):
    cfg = CoTMConfig(n_literals=64, n_clauses=40, n_classes=4,
                     n_states=64, threshold=16, specificity=4.0)
    x, y = prototype(512, n_classes=4, n_features=32, flip=0.05, seed=seed)
    lits = jnp.asarray(np.concatenate([x, 1 - x], -1).astype(bool))
    labels = jnp.asarray(y)
    params = cfg.init(jax.random.key(seed))
    key = jax.random.key(seed + 1)
    for ep in range(epochs):
        for b in range(0, 512, 64):
            key, k = jax.random.split(key)
            params = step_fn(params, lits[b:b + 64], labels[b:b + 64],
                             k, cfg)
    acc = float((predict(params, lits, cfg) == labels).mean())
    return acc


def test_batch_training_learns():
    assert _learn(train_step_batch) > 0.9


@pytest.mark.slow
def test_sequential_training_learns():
    assert _learn(train_step_sequential, epochs=4) > 0.9


def test_train_epochs_api():
    cfg = CoTMConfig(n_literals=32, n_clauses=20, n_classes=3,
                     n_states=32, threshold=8)
    x, y = prototype(192, n_classes=3, n_features=16, flip=0.05)
    lits = jnp.asarray(np.concatenate([x, 1 - x], -1).astype(bool))
    params = train_epochs(cfg.init(jax.random.key(0)), lits,
                          jnp.asarray(y), jax.random.key(1), cfg,
                          epochs=6, batch_size=32)
    acc = float((predict(params, lits, cfg) == jnp.asarray(y)).mean())
    assert acc > 0.85
