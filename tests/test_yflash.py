"""Y-Flash device twin: pulse dynamics + calibration vs the paper's
figures (Figs. 7, 8, 10)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.impact import yflash
from repro.impact.yflash import (DeviceVariation, erase_pulse, program_pulse,
                                 pulse_until, read_current)


@settings(max_examples=30, deadline=None)
@given(g0=st.floats(1e-9, 2.5e-6), width=st.floats(1e-5, 1e-3))
def test_program_monotone_decreasing(g0, width):
    var = DeviceVariation.none(())
    g = jnp.asarray(g0)
    g1 = program_pulse(g, width, var)
    assert float(g1) <= g0 + 1e-15
    assert float(g1) >= yflash.G_MIN * 0.99


@settings(max_examples=30, deadline=None)
@given(g0=st.floats(2.5e-10, 2.5e-6), width=st.floats(1e-5, 1e-3))
def test_erase_monotone_increasing(g0, width):
    var = DeviceVariation.none(())
    g1 = erase_pulse(jnp.asarray(g0), width, var)
    assert float(g1) >= g0 - 1e-15
    assert float(g1) <= yflash.G_MAX * 1.01


def test_boolean_encode_pulse_budget():
    """Fig. 10: 1 ms program pulses drive HCS -> LCS in ~7 pulses mean."""
    key = jax.random.key(0)
    g0 = 2.5e-6 * jnp.ones((64, 64))
    var = DeviceVariation.sample(jax.random.key(1), (64, 64))
    g, n_prog, _ = pulse_until(
        g0, target_lo=jnp.zeros((64, 64)),
        target_hi=jnp.full((64, 64), yflash.G_LCS),
        width_prog=1e-3, width_erase=1e-3, var=var, key=key)
    mean_pulses = float(n_prog.mean())
    assert 4 <= mean_pulses <= 11, mean_pulses
    assert float(g.max()) <= yflash.G_LCS


def test_d2d_pulse_range_matches_fig8():
    """Fig. 8: 200us programming needs ~23-61 pulses to LCS across
    devices."""
    key = jax.random.key(2)
    n = 100
    g0 = 2.5e-6 * jnp.ones((n,))
    var = DeviceVariation.sample(jax.random.key(3), (n,))
    _, n_prog, _ = pulse_until(
        g0, target_lo=jnp.zeros((n,)), target_hi=jnp.full((n,), 1e-9),
        width_prog=200e-6, width_erase=100e-6, var=var, key=key,
        max_pulses=256)
    lo, hi = float(n_prog.min()), float(n_prog.max())
    assert 10 <= lo <= 40 and 35 <= hi <= 120, (lo, hi)


def test_c2c_variability_scale():
    """Fig. 7: repeated program/erase cycles show bounded, non-zero
    conductance spread.  (The paper's 4.8%/9.7% SDs come from a
    tolerance-band programming controller; this first-crossing protocol
    has wider spread, so the bounds here check the ORDER of the noise.)"""
    key = jax.random.key(4)
    var = DeviceVariation.none(())
    lcs_vals, hcs_vals = [], []
    g = jnp.asarray(2.5e-6)
    for i in range(60):
        # Fresh key per PULSE (not per cycle): C2C noise is i.i.d. across
        # pulses; reusing one key correlates the whole cycle and inflates
        # the first-crossing spread with heavy-tailed outliers.
        for _ in range(40):
            key, kp = jax.random.split(key)
            g = program_pulse(g, 200e-6, var, kp)
            if float(g) < 1e-9:
                break
        lcs_vals.append(float(g))
        for _ in range(40):
            key, ke = jax.random.split(key)
            g = erase_pulse(g, 100e-6, var, ke)
            if float(g) > 1e-6:
                break
        hcs_vals.append(float(g))
    lcs, hcs = np.asarray(lcs_vals), np.asarray(hcs_vals)
    assert 0.005 <= lcs.std() / lcs.mean() <= 0.6
    assert 0.005 <= hcs.std() / hcs.mean() <= 0.6


def test_tune_adaptive_erase_uses_hcs_sigma():
    """Regression: tune_adaptive's erase moves must draw per-pulse C2C
    noise at C2C_SIGMA_HCS (9.7 %, Fig. 7 HCS) — not the program sigma
    (4.8 %) it once shared.  One widest-width erase step from a common
    start pins the realized-rate log-spread to the HCS sigma."""
    n = 8192
    g0 = 1e-7 * jnp.ones((n,))
    target = jnp.full((n,), yflash.G_MAX * 0.9)
    var = DeviceVariation.none((n,))
    g1, n_prog, n_erase = yflash.tune_adaptive(
        g0, target, jnp.full((n,), 1e-12), var=var,
        key=jax.random.key(5), max_pulses=1)
    # From 1e-7 S toward 2.7e-6 S every cell's best move is the widest
    # (500 us) erase pulse.
    assert int(n_prog.sum()) == 0 and int(n_erase.sum()) == n
    rate_det = 1.0 - np.exp(-500e-6 / yflash.TAU_ERASE)
    realized = (np.asarray(g1) - 1e-7) / (yflash.G_MAX - 1e-7)
    spread = float(np.std(np.log(realized / rate_det)))
    assert 0.085 <= spread <= 0.11, spread


def test_tune_adaptive_program_sigma_pinned():
    """Companion pin: program moves keep the LCS sigma (4.8 %) — guards
    against over-correcting the erase fix onto the program path."""
    n = 8192
    g0 = 1e-6 * jnp.ones((n,))
    target = jnp.full((n,), yflash.G_MIN)
    var = DeviceVariation.none((n,))
    g1, n_prog, n_erase = yflash.tune_adaptive(
        g0, target, jnp.full((n,), 1e-12), var=var,
        key=jax.random.key(6), max_pulses=1)
    assert int(n_erase.sum()) == 0 and int(n_prog.sum()) == n
    decay_det = np.exp(-500e-6 / yflash.TAU_PROG)
    realized = (np.asarray(g1) - yflash.G_MIN) / (1e-6 - yflash.G_MIN)
    spread = float(np.std(np.log(realized / decay_det)))
    assert 0.04 <= spread <= 0.06, spread


def test_read_nonlinearity():
    """Fig. 5c: sub-cutoff conductances read ~1.5x ohmic current."""
    g_low, g_high = jnp.asarray(1e-9), jnp.asarray(1e-6)
    assert np.isclose(float(read_current(g_low)), 1e-9 * 2.0 * 1.5)
    assert np.isclose(float(read_current(g_high)), 1e-6 * 2.0)
