"""Booleanization properties (the paper's data-preparation step)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import booleanize, n_literals, with_negations
from repro.core.booleanize import thermometer_thresholds, threshold_bits


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2 ** 16), F=st.integers(1, 30),
       bits=st.integers(1, 5))
def test_negation_pairing(seed, F, bits):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random((7, F)), jnp.float32)
    lits = booleanize(x, n_bits=bits)
    assert lits.shape == (7, n_literals(F, bits))
    half = lits.shape[-1] // 2
    np.testing.assert_array_equal(np.asarray(lits[..., half:]),
                                  ~np.asarray(lits[..., :half]))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2 ** 16), bits=st.integers(1, 6))
def test_thermometer_monotone(seed, bits):
    """More bits set for larger feature values (thermometer code)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.sort(rng.random(16)), jnp.float32)[None, :]
    t = thermometer_thresholds(bits)
    b = np.asarray(threshold_bits(x, t)).reshape(16, bits)
    counts = b.sum(-1)
    assert (np.diff(counts) >= 0).all()


def test_thresholds_strictly_inside():
    t = np.asarray(thermometer_thresholds(5, 0.0, 1.0))
    assert (t > 0).all() and (t < 1).all()
    assert (np.diff(t) > 0).all()
