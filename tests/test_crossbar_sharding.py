"""Sharded fused analog crossbar: the shard_map lowering of
``sharding/crossbar.py`` against the single-device Pallas kernel and the
einsum oracle — fully sharded (R and S both on the model axis) AND the
asymmetric R-only / S-only plans where the non-dividing operand is
replicated.

Parity contract (same convention as test_fused_impact): CSA bits and
argmax predictions are EXACTLY equal across lowerings on ideal devices —
column currents sit decades from the CSA decision boundary — while raw
class-current scores are float sums whose association order changes under
``psum``, so they get an allclose with tight rtol.

The multi-device sweeps need >= 2 devices and are exercised in CI with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the multi-device
leg, every PR); on a single-device host they skip, and a subprocess
smoke test keeps one real 8-device parity + billing run in the tier-1
lane (with ``JAX_PLATFORMS=cpu`` pinned — see the comment at the call).
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.impact import RuntimeSpec, Topology
from repro.impact.yflash import I_CSA_THRESHOLD
from repro.kernels import ops, ref
from repro.launch.mesh import make_crossbar_mesh
from repro.serve import IMPACTEngine
from repro.sharding import crossbar

from test_fused_impact import _make_system

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >= 2 devices (XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


def _mesh_or_skip(n_model: int):
    if jax.device_count() % n_model:
        pytest.skip(f"{jax.device_count()} devices not divisible by "
                    f"n_model={n_model}")
    return make_crossbar_mesh(n_model=n_model)


# (B, K, n, M, R, tr, C, tc, S, sr, n_model) — R > 1 AND S > 1 grids per
# the acceptance criteria, ragged shapes, shards-per-device > 1, and a
# full-width model axis (R == S == n_model == 8).
SHARD_SHAPES = [
    (16, 300, 120, 7, 4, 80, 3, 40, 4, 30, 2),     # 2 shards/device
    (16, 300, 120, 7, 4, 80, 3, 40, 4, 30, 4),     # 1 shard/device
    (8, 520, 500, 10, 4, 130, 2, 256, 2, 250, 2),  # class pad >> clause pad
    (4, 64, 33, 4, 8, 8, 3, 11, 8, 5, 8),          # tiny ragged, full axis
]

# Asymmetric layouts: exactly one of R / S divides the model axis, so the
# plan shards that operand and replicates the other (the lifted PR-3
# restriction).
ASYM_SHAPES = [
    # R-only: R=4 % 2 == 0, S=3 % 2 != 0 -> plan (True, False)
    (8, 300, 120, 7, 4, 80, 3, 40, 3, 40, 2, (True, False)),
    # S-only: R=3 % 2 != 0, S=4 % 2 == 0 -> plan (False, True)
    (8, 300, 126, 7, 3, 100, 3, 42, 4, 32, 2, (False, True)),
    # R-only on a wider axis, shards-per-device > 1
    (16, 512, 96, 5, 8, 64, 2, 48, 3, 32, 4, (True, False)),
]


class FakeMesh:
    def __init__(self, **axes):
        self.shape = dict(axes)


def test_shard_plan_and_gate():
    """The placement resolver that routes between the shard_map lowering
    (fully sharded or asymmetric) and the single-device fallback."""
    m = FakeMesh(data=2, model=4)
    assert crossbar.shard_plan(m, 4, 8) == (True, True)
    assert crossbar.shard_plan(m, 3, 4) == (False, True)   # S-only
    assert crossbar.shard_plan(m, 4, 6) == (True, False)   # R-only
    assert crossbar.shard_plan(m, 3, 6) is None            # neither
    assert crossbar.shard_plan(None, 4, 4) is None
    assert crossbar.shard_plan(FakeMesh(data=8), 4, 4) is None  # no model
    assert crossbar.shard_plan(FakeMesh(data=4, model=1), 4, 4) is None
    assert crossbar.shard_plan(m, 3, 6, mode="none") is None
    # an explicitly demanded placement must never silently no-op: a mesh
    # without a usable model axis raises instead of falling back
    for degenerate in (FakeMesh(data=8), FakeMesh(data=4, model=1)):
        with pytest.raises(ValueError, match="model axis"):
            crossbar.shard_plan(degenerate, 4, 4, mode="both")
    # explicit modes validate at resolution time
    assert crossbar.shard_plan(m, 4, 6, mode="r") == (True, False)
    with pytest.raises(ValueError, match="divide the model axis"):
        crossbar.shard_plan(m, 4, 6, mode="both")
    with pytest.raises(ValueError, match="divide the model axis"):
        crossbar.shard_plan(m, 3, 4, mode="r")
    with pytest.raises(ValueError, match="shard mode"):
        crossbar.shard_plan(m, 4, 4, mode="diagonal")
    assert crossbar.shardable(m, 4, 6)          # any plan counts
    assert not crossbar.shardable(m, 3, 6)
    assert crossbar.data_axes(FakeMesh(pod=2, data=2, model=2)) == \
        ("pod", "data")
    assert crossbar.data_axes(FakeMesh(model=2)) == ()


def test_model_axis_of_one_falls_back_single_device():
    """A degenerate (1, 1) mesh must route through the single-device
    kernel bit-for-bit (this covers the fallback on tier-1's one CPU)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    lit, sys_ = _make_system(8, 150, 60, 5, 2, 80, 2, 32, 2, 32, seed=5)
    want = ops.fused_impact(lit, sys_.clause_i, sys_.nonempty, sys_.class_i,
                            thresh=I_CSA_THRESHOLD)
    got = ops.fused_impact(lit, sys_.clause_i, sys_.nonempty, sys_.class_i,
                           thresh=I_CSA_THRESHOLD, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@multi_device
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("B,K,n,M,R,tr,C,tc,S,sr,n_model", SHARD_SHAPES)
def test_shmap_matches_single_device_and_oracle(B, K, n, M, R, tr, C, tc,
                                                S, sr, n_model, impl):
    """The acceptance sweep: shard_map fused inference over a >= 2-device
    model axis vs the single-device Pallas kernel vs the einsum oracle."""
    mesh = _mesh_or_skip(n_model)
    lit, sys_ = _make_system(B, K, n, M, R, tr, C, tc, S, sr, seed=7)
    want = ref.fused_impact_ref(lit, sys_.clause_i, sys_.nonempty,
                                sys_.class_i, thresh=I_CSA_THRESHOLD)
    single = ops.fused_impact(lit, sys_.clause_i, sys_.nonempty,
                              sys_.class_i, thresh=I_CSA_THRESHOLD)
    got = ops.fused_impact(lit, sys_.clause_i, sys_.nonempty, sys_.class_i,
                           thresh=I_CSA_THRESHOLD, impl=impl, mesh=mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(single),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(got, -1)),
                                  np.asarray(jnp.argmax(want, -1)))
    np.testing.assert_array_equal(np.asarray(jnp.argmax(got, -1)),
                                  np.asarray(jnp.argmax(single, -1)))


@multi_device
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("B,K,n,M,R,tr,C,tc,S,sr,n_model,plan", ASYM_SHAPES)
def test_asymmetric_plan_matches_single_device_and_oracle(
        B, K, n, M, R, tr, C, tc, S, sr, n_model, plan, impl):
    """R-only / S-only plans (the other operand replicated) stay
    score-allclose and argmax-exact vs the oracle and the single-device
    kernel — the lifted both-must-divide restriction."""
    mesh = _mesh_or_skip(n_model)
    assert crossbar.shard_plan(mesh, R, S) == plan
    lit, sys_ = _make_system(B, K, n, M, R, tr, C, tc, S, sr, seed=21)
    want = ref.fused_impact_ref(lit, sys_.clause_i, sys_.nonempty,
                                sys_.class_i, thresh=I_CSA_THRESHOLD)
    single = ops.fused_impact(lit, sys_.clause_i, sys_.nonempty,
                              sys_.class_i, thresh=I_CSA_THRESHOLD)
    got = ops.fused_impact(lit, sys_.clause_i, sys_.nonempty, sys_.class_i,
                           thresh=I_CSA_THRESHOLD, impl=impl, mesh=mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(single),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(got, -1)),
                                  np.asarray(jnp.argmax(want, -1)))


@multi_device
@pytest.mark.parametrize("shard,plan", [("r", (True, False)),
                                        ("s", (False, True))])
def test_topology_forces_asymmetric_plan(shard, plan):
    """RuntimeSpec(topology=Topology(shard='r'|'s')) pins the placement
    at compile time even when both operands could shard; predictions
    stay parity with the unsharded session."""
    mesh = _mesh_or_skip(2)
    lit, sys_ = _make_system(8, 300, 120, 7, 4, 80, 3, 40, 4, 30, seed=23)
    forced = sys_.compile(RuntimeSpec(
        backend="xla", topology=Topology(mesh=mesh, shard=shard)))
    assert forced.plan == plan
    base = sys_.compile(RuntimeSpec(backend="xla"))
    np.testing.assert_array_equal(
        np.asarray(forced.predict(lit).predictions),
        np.asarray(base.predict(lit).predictions))


@multi_device
def test_indivisible_batch_replicates():
    """B that doesn't divide the data axis still shards the model axis
    (the batch replicates instead of failing)."""
    mesh = _mesh_or_skip(2)            # data axis = device_count // 2 > 1
    B = mesh.shape["data"] * 2 + 1     # never divisible by the data axis
    lit, sys_ = _make_system(B, 300, 120, 7, 4, 80, 3, 40, 4, 30, seed=9)
    want = ref.fused_impact_ref(lit, sys_.clause_i, sys_.nonempty,
                                sys_.class_i, thresh=I_CSA_THRESHOLD)
    got = ops.fused_impact(lit, sys_.clause_i, sys_.nonempty, sys_.class_i,
                           thresh=I_CSA_THRESHOLD, impl="xla", mesh=mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@multi_device
def test_no_plan_falls_back_exactly():
    """R=3, S=3 over a model axis of 2: no plan exists, so the wrapper
    must take the single-device kernel path bit-for-bit (same code path
    => exact)."""
    mesh = _mesh_or_skip(2)
    lit, sys_ = _make_system(8, 150, 60, 5, 3, 64, 2, 32, 3, 20, seed=11)
    assert crossbar.shard_plan(mesh, 3, 3) is None
    want = ops.fused_impact(lit, sys_.clause_i, sys_.nonempty, sys_.class_i,
                            thresh=I_CSA_THRESHOLD)
    got = ops.fused_impact(lit, sys_.clause_i, sys_.nonempty, sys_.class_i,
                           thresh=I_CSA_THRESHOLD, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@multi_device
@pytest.mark.parametrize("metering", ["staged", "fused"])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("R,tr,S,sr", [
    (4, 80, 4, 30),      # fully sharded plan
    (4, 80, 3, 40),      # asymmetric R-only plan
])
def test_metered_infer_step_parity_under_sharding(backend, metering,
                                                  R, tr, S, sr):
    """Sharded metered sweep == single-device metered path: same preds
    (sentinel -1 on free lanes), same per-lane energy bills, free lanes
    billed exactly zero — for the fully sharded AND asymmetric plans (a
    replicated stage's currents must not be psummed into m-fold bills),
    and for BOTH metering modes (a sharded topology lowers them to the
    same psummed datapath; single-device 'fused' runs the in-kernel
    meters — the four (plan, mode) corners of the acceptance sweep)."""
    mesh = _mesh_or_skip(2)
    B, K = 8, 300
    lit, sys_ = _make_system(B, K, 120, 7, R, tr, 3, 40, S, sr, seed=13)
    buf = np.ones((B, K), np.int8)
    buf[:5] = np.asarray(lit[:5])
    valid = np.zeros((B,), bool)
    valid[:5] = True
    s_one = sys_.compile(RuntimeSpec(backend=backend, metering=metering,
                                     capacity=B))
    s_mesh = sys_.compile(RuntimeSpec(
        backend=backend, metering=metering, capacity=B,
        topology=Topology(mesh=mesh)))
    assert s_mesh.plan == (True, S % 2 == 0)
    r1 = s_one.infer_step(buf, valid)
    rm = s_mesh.infer_step(buf, valid)
    p_1, p_m = np.asarray(r1.predictions), np.asarray(rm.predictions)
    np.testing.assert_array_equal(p_1, p_m)
    assert (p_m[5:] == -1).all(), p_m
    np.testing.assert_allclose(np.asarray(rm.e_clause_lanes),
                               np.asarray(r1.e_clause_lanes), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(rm.e_class_lanes),
                               np.asarray(r1.e_class_lanes), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(rm.e_clause_lanes)[5:], 0.0)
    np.testing.assert_array_equal(np.asarray(rm.e_class_lanes)[5:], 0.0)


@multi_device
@pytest.mark.parametrize("shard", ["both", "r", "s", "none"])
def test_fused_metering_bills_identically_across_shard_plans(shard):
    """RuntimeSpec(metering='fused') under all four forced shard plans
    (both / R-only / S-only / none): per-lane meters agree with the
    single-device staged oracle — the ISSUE acceptance sweep.  'none'
    forces the single-device in-kernel meters even on a meshed system;
    the sharded plans psum the meters with replicated operands billed
    exactly once."""
    mesh = _mesh_or_skip(2)
    B, K = 8, 300
    lit, sys_ = _make_system(B, K, 120, 7, 4, 80, 3, 40, 4, 30, seed=15)
    buf = np.ones((B, K), np.int8)
    buf[:6] = np.asarray(lit[:6])
    valid = np.zeros((B,), bool)
    valid[:6] = True
    oracle = sys_.compile(RuntimeSpec(backend="xla", metering="staged",
                                      capacity=B)).infer_step(buf, valid)
    sess = sys_.compile(RuntimeSpec(
        backend="xla", metering="fused", capacity=B,
        topology=Topology(mesh=mesh, shard=shard)))
    want_plan = {"both": (True, True), "r": (True, False),
                 "s": (False, True), "none": None}[shard]
    assert sess.plan == want_plan
    got = sess.infer_step(buf, valid)
    np.testing.assert_array_equal(np.asarray(got.predictions),
                                  np.asarray(oracle.predictions))
    np.testing.assert_allclose(np.asarray(got.e_clause_lanes),
                               np.asarray(oracle.e_clause_lanes), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got.e_class_lanes),
                               np.asarray(oracle.e_class_lanes), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(got.e_clause_lanes)[6:], 0.0)


@multi_device
@pytest.mark.parametrize("shard", ["both", "r", "s", "none"])
def test_packed_sessions_across_shard_plans(shard):
    """packing='2bit' under all four forced shard plans: the packed
    clause operand rides the same psum lowering (bits shard on the
    R axis like the currents they encode; the dequant levels replicate),
    so predictions AND per-lane energy bills match the single-device
    packed kernel — the compressed-datapath acceptance sweep."""
    mesh = _mesh_or_skip(2)
    B, K = 8, 300
    lit, sys_ = _make_system(B, K, 120, 7, 4, 80, 3, 40, 4, 30, seed=41)
    buf = np.ones((B, K), np.int8)
    buf[:6] = np.asarray(lit[:6])
    valid = np.zeros((B,), bool)
    valid[:6] = True
    single = sys_.compile(RuntimeSpec(
        backend="pallas-packed", packing="2bit", metering="fused",
        capacity=B)).infer_step(buf, valid)
    sess = sys_.compile(RuntimeSpec(
        backend="xla", packing="2bit", metering="fused", capacity=B,
        topology=Topology(mesh=mesh, shard=shard)))
    want_plan = {"both": (True, True), "r": (True, False),
                 "s": (False, True), "none": None}[shard]
    assert sess.plan == want_plan
    got = sess.infer_step(buf, valid)
    np.testing.assert_array_equal(np.asarray(got.predictions),
                                  np.asarray(single.predictions))
    assert (np.asarray(got.predictions)[6:] == -1).all()
    np.testing.assert_allclose(np.asarray(got.e_clause_lanes),
                               np.asarray(single.e_clause_lanes),
                               rtol=1e-4, atol=0.0)
    np.testing.assert_allclose(np.asarray(got.e_class_lanes),
                               np.asarray(single.e_class_lanes),
                               rtol=1e-4, atol=0.0)
    np.testing.assert_array_equal(np.asarray(got.e_clause_lanes)[6:], 0.0)


@multi_device
def test_packed_predict_parity_on_mesh():
    """Unmetered packed predict from a sharded topology matches the
    unpacked einsum oracle on argmax (quantization preserves the CSA
    decisions; sharding preserves the quantized physics)."""
    mesh = _mesh_or_skip(2)
    lit, sys_ = _make_system(16, 300, 120, 7, 4, 80, 3, 40, 4, 30, seed=43)
    sharded = sys_.compile(RuntimeSpec(
        backend="xla", packing="2bit", metering="off",
        topology=Topology(mesh=mesh)))
    assert sharded.plan == (True, True)
    base = sys_.compile(RuntimeSpec(backend="xla", metering="off"))
    np.testing.assert_array_equal(
        np.asarray(sharded.predict(lit).predictions),
        np.asarray(base.predict(lit).predictions))


@multi_device
def test_engine_on_sharded_mesh_bills_exactly():
    """IMPACTEngine serving from a sharded session: predictions match the
    single-device direct path and per-request energy attribution still
    sums exactly to the batch meter (ISSUE acceptance)."""
    mesh = _mesh_or_skip(2)
    lit, sys_ = _make_system(24, 300, 120, 7, 4, 80, 3, 40, 4, 30, seed=17)
    session = sys_.compile(RuntimeSpec(
        backend="xla", capacity=8, topology=Topology(mesh=mesh)))
    eng = IMPACTEngine(session)
    assert eng.mesh is mesh            # engine inherits the session mesh
    preds, stats = eng.run(np.asarray(lit))
    direct = np.asarray(
        sys_.compile(RuntimeSpec(backend="xla")).predict(lit).predictions)
    np.testing.assert_array_equal(preds, direct)
    recs = eng.request_records
    assert len(recs) == 24 and all(r.e_read_j > 0 for r in recs)
    np.testing.assert_allclose(sum(r.e_read_j for r in recs),
                               stats["energy"].read_energy_j, rtol=1e-6)
    # per-STEP reports carry the area and a real TOPS/mm^2; the summed-
    # latency aggregate refuses (the ratio would shrink with sweep count)
    assert all(r.tops_per_mm2 > 0 for r in eng.reports)
    with pytest.raises(ValueError, match="area"):
        stats["energy"].tops_per_mm2


SMOKE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.impact import RuntimeSpec, Topology
    from repro.impact.yflash import I_CSA_THRESHOLD
    from repro.kernels import ops, ref
    from repro.launch.mesh import make_crossbar_mesh
    from repro.serve import IMPACTEngine
    from repro.sharding import crossbar
    import sys
    sys.path.insert(0, {tests_dir!r})
    from test_fused_impact import _make_system

    mesh = make_crossbar_mesh(n_model=2)      # (4 data, 2 model)
    lit, base = _make_system(16, 200, 60, 5, 2, 100, 2, 32, 2, 32, seed=7)
    want = ref.fused_impact_ref(lit, base.clause_i, base.nonempty,
                                base.class_i, thresh=I_CSA_THRESHOLD)
    got = ops.fused_impact(lit, base.clause_i, base.nonempty, base.class_i,
                           thresh=I_CSA_THRESHOLD, impl="xla", mesh=mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(got, -1)),
                                  np.asarray(jnp.argmax(want, -1)))

    # asymmetric R-only plan (S=3 does not divide the model axis)
    lit_a, asym = _make_system(8, 200, 60, 5, 2, 100, 2, 32, 3, 20, seed=9)
    assert crossbar.shard_plan(mesh, 2, 3) == (True, False)
    want_a = ref.fused_impact_ref(lit_a, asym.clause_i, asym.nonempty,
                                  asym.class_i, thresh=I_CSA_THRESHOLD)
    got_a = ops.fused_impact(lit_a, asym.clause_i, asym.nonempty,
                             asym.class_i, thresh=I_CSA_THRESHOLD,
                             impl="xla", mesh=mesh)
    np.testing.assert_allclose(np.asarray(got_a), np.asarray(want_a),
                               rtol=1e-6)

    session = base.compile(RuntimeSpec(backend="xla", capacity=16,
                                       topology=Topology(mesh=mesh)))
    eng = IMPACTEngine(session)
    preds, stats = eng.run(np.asarray(lit))
    direct = base.compile(RuntimeSpec(backend="xla")).predict(lit)
    np.testing.assert_array_equal(preds, np.asarray(direct.predictions))
    np.testing.assert_allclose(
        sum(r.e_read_j for r in eng.request_records),
        stats["energy"].read_energy_j, rtol=1e-6)

    # fused metering on the mesh == staged single-device oracle (per-lane
    # bills psummed once; free lanes bill zero)
    buf = np.ones((16, 200), np.int8)
    buf[:9] = np.asarray(lit[:9], np.int8)
    vd = np.zeros((16,), bool); vd[:9] = True
    st = base.compile(RuntimeSpec(backend="xla", metering="staged",
                                  capacity=16)).infer_step(buf, vd)
    fu = base.compile(RuntimeSpec(backend="xla", metering="fused",
                                  capacity=16,
                                  topology=Topology(mesh=mesh))
                      ).infer_step(buf, vd)
    np.testing.assert_array_equal(np.asarray(fu.predictions),
                                  np.asarray(st.predictions))
    np.testing.assert_allclose(np.asarray(fu.e_clause_lanes),
                               np.asarray(st.e_clause_lanes), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(fu.e_class_lanes),
                               np.asarray(st.e_class_lanes), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(fu.e_clause_lanes)[9:], 0.0)

    # packed (2-bit) operands ride the same psum lowering: sharded packed
    # session == single-device packed kernel, preds and lane bills alike
    pk_one = base.compile(RuntimeSpec(backend="pallas-packed",
                                      packing="2bit", metering="fused",
                                      capacity=16)).infer_step(buf, vd)
    pk_mesh = base.compile(RuntimeSpec(backend="xla", packing="2bit",
                                       metering="fused", capacity=16,
                                       topology=Topology(mesh=mesh))
                           ).infer_step(buf, vd)
    np.testing.assert_array_equal(np.asarray(pk_mesh.predictions),
                                  np.asarray(pk_one.predictions))
    np.testing.assert_allclose(np.asarray(pk_mesh.e_clause_lanes),
                               np.asarray(pk_one.e_clause_lanes),
                               rtol=1e-4, atol=0.0)
    print("SHARDED_SMOKE_OK", jax.device_count())
""")


def test_sharded_smoke_on_forced_host_devices():
    """One real 8-device run in the tier-1 lane (subprocess, because the
    XLA host-device flag must be set before jax initialises): parity of
    the shard_map lowering vs the oracle — including an asymmetric
    R-only plan — plus session-engine billing and a fused-metering
    sweep billed against the staged single-device oracle.  The full
    sweeps run in-process in the CI multi-device leg."""
    tests_dir = str(pathlib.Path(__file__).resolve().parent)
    r = subprocess.run(
        [sys.executable, "-c", SMOKE.format(tests_dir=tests_dir)],
        # JAX_PLATFORMS=cpu matters: without it, a host with libtpu
        # installed spends ~8 min of TPU-metadata retries in the scrubbed
        # subprocess env before falling back to CPU.
        env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
             "HOME": os.environ.get("HOME", "/root"),
             "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=900)
    assert "SHARDED_SMOKE_OK" in r.stdout, (r.stdout[-2000:],
                                            r.stderr[-3000:])
