"""Continuous-batching scheduler: slot-table invariants, mid-flight
admission neutrality, backpressure, admission policy, and the tail-latency
claim (continuous < flush-to-completion p95 under a seeded Poisson arrival
trace) — all on compiled ``InferenceSession`` runtimes."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoTMConfig
from repro.core.cotm import CoTMParams
from repro.impact import IMPACTConfig, RuntimeSpec, build_system
from repro.serve import (Backpressure, IMPACTEngine, SlotTable,
                         latency_percentiles, poisson_arrivals,
                         replay_trace)


def spec(backend="xla", *, meter=True, capacity=None):
    return RuntimeSpec(backend=backend,
                       metering="staged" if meter else "off",
                       capacity=capacity)


@pytest.fixture(scope="module")
def small_system():
    K, n, m, n_states = 64, 32, 4, 64
    cfg = CoTMConfig(n_literals=K, n_clauses=n, n_classes=m,
                     n_states=n_states)
    rng = np.random.default_rng(0)
    ta = np.where(rng.random((K, n)) < 0.1, n_states + 1, n_states)
    w = rng.integers(-20, 20, (m, n))
    params = CoTMParams(ta_state=jnp.asarray(ta, jnp.int32),
                        weights=jnp.asarray(w, jnp.int32))
    system = build_system(params, cfg, jax.random.key(0),
                          IMPACTConfig(variability=False, finetune=False))
    lits = rng.random((80, K)) < 0.5
    return system, lits


# -- SlotTable ---------------------------------------------------------------

def test_slot_table_admit_release_mask():
    t = SlotTable(4)
    assert t.occupancy == 0 and t.free == 4
    a = t.admit("a")
    b = t.admit("b")
    assert (a, b) == (0, 1)                   # lowest free slot, stable
    np.testing.assert_array_equal(t.valid_mask(), [True, True, False, False])
    assert t.release(a) == "a"
    assert t.free_slots() == [0, 2, 3]
    assert t.admit("c") == 0                  # freed lane is reused
    assert dict(t.occupied()) == {0: "c", 1: "b"}
    with pytest.raises(KeyError):
        t.release(3)                          # double-free / free-free


def test_slot_table_full_raises_backpressure():
    t = SlotTable(2)
    t.admit(1)
    t.admit(2)
    with pytest.raises(Backpressure):
        t.admit(3)
    t.release(0)
    assert t.admit(3) == 0                    # release makes room again


def test_slot_table_compact():
    t = SlotTable(5)
    for x in "abcd":
        t.admit(x)
    t.release(0)
    t.release(2)
    moves = t.compact()
    assert moves == [(1, 0), (3, 1)]          # stable order, dense prefix
    np.testing.assert_array_equal(
        t.valid_mask(), [True, True, False, False, False])
    assert [t.slots[i] for i in range(2)] == ["b", "d"]


def test_slot_table_rejects_bad_capacity():
    with pytest.raises(ValueError):
        SlotTable(0)


# -- mid-flight admission neutrality ----------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_admission_never_perturbs_inflight_lanes(small_system, backend):
    """A lane admitted mid-flight must not change any other lane's class
    scores or energy bill — free lanes are all-1 literals (rows float, no
    current), so a sweep with {A} and a sweep with {A, B} agree exactly on
    A.  This is the slot-table form of the padding-neutrality argument."""
    system, lits = small_system
    session = system.compile(spec(backend, capacity=8))
    cap = 8
    buf = np.ones((cap, system.n_literals), np.int8)
    buf[0] = lits[0]
    valid = np.zeros((cap,), bool)
    valid[0] = True
    solo = session.infer_step(buf, valid)
    p_solo = np.asarray(solo.predictions)
    # admit three more requests into free lanes, A untouched
    for j, row in enumerate(lits[1:4], start=1):
        buf[j] = row
        valid[j] = True
    co = session.infer_step(buf, valid)
    assert np.asarray(co.predictions)[0] == p_solo[0]
    np.testing.assert_allclose(np.asarray(co.e_clause_lanes)[0],
                               np.asarray(solo.e_clause_lanes)[0],
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(co.e_class_lanes)[0],
                               np.asarray(solo.e_class_lanes)[0],
                               rtol=1e-6)
    # and the free lanes metered exactly zero in the solo sweep
    np.testing.assert_array_equal(np.asarray(solo.e_clause_lanes)[1:], 0.0)
    np.testing.assert_array_equal(np.asarray(solo.e_class_lanes)[1:], 0.0)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("meter", [False, True])
def test_invalid_lanes_predict_sentinel(small_system, backend, meter):
    """Free lanes (all-1 literals) fire every nonempty clause, so their
    argmax would look like a real class; ``infer_step`` must return the
    sentinel -1 for ``valid == False`` lanes on BOTH the fused
    (metering='off') and staged (metering='staged') paths, while valid
    lanes keep matching the direct predict path."""
    system, lits = small_system
    session = system.compile(spec(backend, meter=meter, capacity=8))
    cap = 8
    buf = np.ones((cap, system.n_literals), np.int8)
    buf[:3] = lits[:3]
    valid = np.zeros((cap,), bool)
    valid[:3] = True
    preds = np.asarray(session.infer_step(buf, valid).predictions)
    assert (preds[3:] == -1).all(), preds
    direct = np.asarray(session.predict(jnp.asarray(lits[:3])).predictions)
    np.testing.assert_array_equal(preds[:3], direct)


def test_engine_release_refill_reuses_lanes(small_system):
    """Released lanes are reset to the currentless all-1 pattern and
    refilled on the next step; predictions across refills match the
    direct path."""
    system, lits = small_system
    session = system.compile(spec(meter=False, capacity=4))
    direct = np.asarray(session.predict(jnp.asarray(lits[:12])).predictions)
    eng = IMPACTEngine(session)
    done = {}
    for i in range(12):
        eng.submit(lits[i])
    while len(done) < 12:
        done.update(eng.step(force=True))
        # between sweeps the table fully drains (single-sweep workload)
        assert eng.table.occupancy == 0
        assert (eng._lane_lits == 1).all()
    assert [done[i] for i in range(12)] == list(direct)
    assert len(eng.batch_stats) == 3           # 12 requests / 4 lanes


# -- backpressure ------------------------------------------------------------

def test_engine_backpressure_and_recovery(small_system):
    system, lits = small_system
    eng = IMPACTEngine(system.compile(spec(meter=False, capacity=4)),
                       queue_capacity=2)
    # free slots (4) + queue capacity (2) absorb 6 submissions
    for i in range(6):
        eng.submit(lits[i])
    with pytest.raises(Backpressure):
        eng.submit(lits[6])
    assert eng.try_submit(lits[6]) is None
    done = eng.step(force=True)                # sweep frees 4 lanes
    assert len(done) == 4
    assert eng.try_submit(lits[6]) is not None  # room again


def test_engine_unbounded_queue_never_sheds(small_system):
    system, lits = small_system
    eng = IMPACTEngine(system.compile(spec(meter=False, capacity=4)))
    for row in lits:
        eng.submit(row)                        # queue_capacity=None
    assert len(eng.queue.pending) == len(lits)


# -- admission policy --------------------------------------------------------

def test_target_occupancy_defers_sparse_sweeps(small_system):
    """With target_occupancy=1.0 and a long max_wait, a partially filled
    table holds; filling it (or forcing) fires the sweep."""
    system, lits = small_system
    eng = IMPACTEngine(system.compile(spec(meter=False, capacity=4)),
                       max_wait_s=30.0, target_occupancy=1.0)
    for i in range(3):
        eng.submit(lits[i])
    assert eng.step() == []                    # 3/4 occupied, not stale
    assert eng.table.occupancy == 3            # admitted but held in-flight
    eng.submit(lits[3])
    assert len(eng.step()) == 4                # full table fires


def test_injected_clock_drives_staleness_and_latency(small_system):
    """The engine stamps arrivals, measures staleness, and records
    latencies on ONE injectable clock — a virtual clock makes the
    admission policy and the latency ledger fully deterministic."""
    system, lits = small_system
    t = [100.0]
    eng = IMPACTEngine(system.compile(spec(meter=False, capacity=4)),
                       max_wait_s=0.5, target_occupancy=1.0,
                       clock=lambda: t[0])
    eng.submit(lits[0])
    assert eng.step() == []                    # 1/4 lanes, fresh on t
    t[0] += 1.0                                # virtual second elapses
    out = eng.step()                           # now stale: fires
    assert len(out) == 1
    (rec,) = eng.request_records
    assert rec.arrived == 100.0 and rec.completed == 101.0
    assert rec.latency_s == pytest.approx(1.0)
    assert rec.queue_s == 0.0     # admitted into a free lane on step 1,
                                  # then held in-flight by the policy


def test_staleness_clock_starts_at_admission_not_arrival(small_system):
    """The staleness window is measured from ADMISSION, as the policy
    documents — not from arrival.  A request that sat queued behind a
    full table must not fire a premature partial sweep the moment it
    finally wins a lane (queue wait is backpressure's job); the window
    restarts when the lane is granted."""
    system, lits = small_system
    t = [100.0]
    eng = IMPACTEngine(system.compile(spec(meter=False, capacity=2)),
                       max_wait_s=0.5, target_occupancy=1.0,
                       clock=lambda: t[0])
    for i in range(3):
        eng.submit(lits[i])
    assert len(eng.step()) == 2       # full table fires; 3rd still queued
    t[0] = 100.9                      # 3rd has now *arrived* 0.9s ago
    assert eng.step() == []           # admitted at 100.9: fresh, holds
    assert eng.table.occupancy == 1
    t[0] = 101.5                      # 0.6s since ADMISSION: stale
    out = eng.step()
    assert len(out) == 1
    rec = eng.request_records[-1]
    assert rec.arrived == 100.0 and rec.admitted == 100.9
    assert rec.queue_s == pytest.approx(0.9)


def test_max_wait_fires_stale_partial_sweep(small_system):
    system, lits = small_system
    eng = IMPACTEngine(system.compile(spec(meter=False, capacity=4)),
                       max_wait_s=0.02, target_occupancy=1.0)
    eng.submit(lits[0])
    assert eng.step() == []                    # fresh: policy holds it
    time.sleep(0.03)
    out = eng.step()                           # stale: fires despite 1/4
    assert len(out) == 1
    assert eng.batch_stats[-1].occupancy == 0.25


# -- per-request accounting --------------------------------------------------

def test_per_request_energy_attribution(small_system):
    """Each request carries its own read-energy bill; the bills sum to the
    batch meters and a solo request's bill equals the reference report."""
    system, lits = small_system
    session = system.compile(spec(capacity=8))
    ref = session.infer_with_report(jnp.asarray(lits[:1])).report
    eng = IMPACTEngine(session)
    preds, stats = eng.run(lits[:20])
    recs = eng.request_records
    assert len(recs) == 20
    assert all(r.e_read_j > 0 for r in recs)
    np.testing.assert_allclose(sum(r.e_read_j for r in recs),
                               stats["energy"].read_energy_j, rtol=1e-9)
    # solo-request bill == single-sample reference report
    solo = IMPACTEngine(session)
    solo.submit(lits[0])
    solo.step(force=True)
    np.testing.assert_allclose(solo.request_records[0].e_read_j,
                               ref.read_energy_j, rtol=1e-6)


def test_request_latency_percentiles_in_stats(small_system):
    system, lits = small_system
    eng = IMPACTEngine(system.compile(spec(meter=False, capacity=8)))
    _, stats = eng.run(lits[:24])
    lat = stats["latency"]
    assert lat["n"] == 24
    assert 0 < lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"] <= lat["max_s"]
    assert stats["queue_wait"]["n"] == 24
    # per-step percentiles ride on BatchStats too
    assert all(s.p95_s >= s.p50_s > 0 for s in eng.batch_stats)


def test_latency_percentiles_helper():
    assert latency_percentiles([]) == {}
    out = latency_percentiles([0.1] * 99 + [1.0])
    assert out["p50_s"] == pytest.approx(0.1)
    assert out["max_s"] == 1.0 and out["n"] == 100


# -- tail latency under mixed traffic ---------------------------------------

def test_continuous_beats_flush_p95_under_poisson(small_system):
    """The PR-2 acceptance invariant: under a seeded Poisson arrival trace,
    continuous batching shows lower p95 per-request latency than
    flush-to-completion at equal offered load.  Flush holds late arrivals
    for a whole accumulate/flush cycle (max_wait_s staleness), continuous
    admits them into the next sweep.

    The expected margin is ~6x (sweep-time p95 vs a 60 ms staleness
    window), but this is wall-clock measurement on a possibly shared
    runner, so one retry absorbs a freak scheduler stall (the strict gate
    runs in the perf-smoke CI job on the full benchmark trace)."""
    system, lits = small_system
    arrivals = poisson_arrivals(60, rate_rps=250.0, seed=3)
    session = system.compile(spec(meter=False, capacity=16))

    def replay_pair():
        cont = IMPACTEngine(session, max_wait_s=0.0)
        cont.warmup()
        r_cont = replay_trace(cont, lits, arrivals)
        flush = IMPACTEngine(session, mode="flush", buckets=(16,),
                             max_wait_s=0.06)
        flush.warmup()
        r_flush = replay_trace(flush, lits, arrivals)
        assert r_cont["completed"] == r_flush["completed"] == 60
        return r_cont, r_flush

    r_cont, r_flush = replay_pair()
    if not r_cont["p95_s"] < r_flush["p95_s"]:     # pragma: no cover
        r_cont, r_flush = replay_pair()
    assert r_cont["p95_s"] < r_flush["p95_s"], (r_cont, r_flush)
