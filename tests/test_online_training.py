"""Online in-memory TA training: kernel/oracle parity, learnability
under live traffic, billing reconciliation, and trainer input contracts.

The acceptance bar for the ``ta_feedback`` primitive is EXACT parity:
all stochastic feedback draws are precomputed operands, so the Pallas
kernel and the einsum oracle must produce bit-identical TA deltas —
and two trainers differing only in backend must walk bit-identical TA
trajectories.  The serving seam is exercised end to end: updates mutate
the deployed conductances in place, the compiled serving executables
pick them up WITHOUT a retrace, and per-request read bills keep
reconciling with the batch meter afterwards.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cotm import CoTMConfig, predict as digital_predict
from repro.core.train import train_step_batch
from repro.data.synthetic import prototype
from repro.impact import RuntimeSpec
from repro.impact.pipeline import IMPACTConfig, build_system
from repro.serve.tracing import Tracer, validate_events
from repro.train import OnlineTrainer

from test_fused_impact import _make_system

# (B2, K, n, M, R, tr, C, tc, S, sr): doubled-batch feedback shapes over
# ragged / multi-shard grids (the grid only matters through the session
# plumbing — ta_feedback itself is grid-free).
PARITY_SHAPES = [
    (16, 70, 33, 4, 1, 70, 1, 33, 1, 33),
    (64, 256, 128, 8, 2, 128, 2, 64, 2, 64),
    (32, 300, 190, 6, 3, 100, 2, 95, 2, 95),
]


def _feedback_operands(rng, B2, K, n):
    lit2 = jnp.asarray(rng.integers(0, 2, (B2, K)).astype(np.int8))
    fired2 = jnp.asarray(rng.integers(0, 2, (B2, n)).astype(bool))
    sel = jnp.asarray(rng.integers(0, 2, (B2, n)).astype(bool))
    match = jnp.asarray(rng.integers(0, 2, (B2, n)).astype(bool))
    hi = jnp.asarray(rng.integers(0, 2, (K, n)).astype(np.int32))
    lo = jnp.asarray(rng.integers(0, 2, (K, n)).astype(np.int32))
    include = jnp.asarray(rng.integers(0, 2, (K, n)).astype(bool))
    return lit2, fired2, sel, match, hi, lo, include


@pytest.mark.parametrize("shape", PARITY_SHAPES)
@pytest.mark.parametrize("packing", ["none", "2bit"])
def test_ta_feedback_session_parity_sweep(shape, packing):
    """Compiled ``ta_feedback`` entries agree EXACTLY across backends,
    shard grids, and packing modes (packing changes the serving operand
    layout, never the feedback deltas)."""
    B2, K, n, M, R, tr, C, tc, S, sr = shape
    _, sys_ = _make_system(4, K, n, M, R, tr, C, tc, S, sr, seed=B2)
    ops = _feedback_operands(np.random.default_rng(B2), B2, K, n)
    backend = "pallas-packed" if packing == "2bit" else "pallas"
    oracle = sys_.compile(RuntimeSpec(backend="xla")).ta_feedback(*ops)
    kernel = sys_.compile(RuntimeSpec(backend=backend, packing=packing,
                                      interpret=True)).ta_feedback(*ops)
    np.testing.assert_array_equal(np.asarray(kernel), np.asarray(oracle))
    assert kernel.dtype == jnp.int32


def _prototype_problem(seed=3, n_train=512, n_holdout=128):
    cfg = CoTMConfig(n_literals=64, n_clauses=40, n_classes=4,
                     n_states=64, threshold=16, specificity=4.0)
    x, y = prototype(n_train + n_holdout, n_classes=4, n_features=32,
                     flip=0.05, seed=seed)
    lits = jnp.asarray(np.concatenate([x, 1 - x], -1).astype(bool))
    labels = jnp.asarray(y)
    return cfg, (lits[:n_train], labels[:n_train]), \
        (lits[n_train:], labels[n_train:])


def _deployed(cfg, tr_l, tr_y, *, backend="xla", variability=False,
              pretrain_batches=8, seed=0):
    """Digitally pre-train one epoch (a half-trained deployment), then
    encode the model into a system + compiled session."""
    params = cfg.init(jax.random.key(seed))
    key = jax.random.key(seed + 1)
    for b in range(pretrain_batches):
        key, k = jax.random.split(key)
        params = train_step_batch(params, tr_l[b * 64:(b + 1) * 64],
                                  tr_y[b * 64:(b + 1) * 64], k, cfg)
    system = build_system(params, cfg, jax.random.key(seed + 2),
                          IMPACTConfig(variability=variability,
                                       finetune=variability))
    session = system.compile(RuntimeSpec(backend=backend, interpret=True))
    return params, system, session


@pytest.mark.parametrize("variability", [False, True])
def test_online_trainer_ta_trajectory_parity(variability):
    """Two trainers differing ONLY in backend (oracle vs Pallas kernel)
    walk bit-identical TA/weight trajectories and bill identical write
    energy — under ideal AND noisy devices (the noise draws are keyed,
    so parity must survive them too)."""
    cfg, (tr_l, tr_y), _ = _prototype_problem()
    states = {}
    for backend in ("xla", "pallas"):
        params, _, session = _deployed(cfg, tr_l, tr_y, backend=backend,
                                       variability=variability)
        trainer = OnlineTrainer(session, params, cfg,
                                key=jax.random.key(11),
                                variability=variability)
        for step in range(3):
            trainer.update(tr_l[step * 64:(step + 1) * 64],
                           tr_y[step * 64:(step + 1) * 64],
                           key=jax.random.key(100 + step))
        states[backend] = trainer
    a, b = states["xla"], states["pallas"]
    np.testing.assert_array_equal(np.asarray(a.params.ta_state),
                                  np.asarray(b.params.ta_state))
    np.testing.assert_array_equal(np.asarray(a.params.weights),
                                  np.asarray(b.params.weights))
    assert a.write_energy_j == b.write_energy_j
    assert [r["n_flips"] for r in a.records] == \
        [r["n_flips"] for r in b.records]


def test_interleaved_train_serve_improves_and_reconciles():
    """The whole tentpole in one run: updates interleave with serving
    sweeps through the SAME compiled session; held-out accuracy improves,
    the serving executable is never retraced, per-request read bills
    keep reconciling with the batch meter at 1e-9, serving reports bill
    zero write energy, and the Chrome trace carries balanced
    train_update spans between the serving spans."""
    cfg, (tr_l, tr_y), (ho_l, ho_y) = _prototype_problem()
    params, system, session = _deployed(cfg, tr_l, tr_y)
    trace = Tracer()
    trainer = OnlineTrainer(session, params, cfg, key=jax.random.key(7),
                            variability=False, trace=trace)
    acc0 = trainer.evaluate(ho_l, ho_y)
    session.warm(64, "infer_step")
    traces0 = dict(session._traces)

    for epoch in range(4):
        for b in range(0, 512, 64):
            # serving sweep ... (live traffic between updates)
            t0 = trace.clock()
            res = session.infer_step(np.asarray(tr_l[b:b + 64], np.int8),
                                     np.ones((64,), bool))
            trace.span("serve_sweep", t0, trace.clock())
            e_lanes = (np.asarray(res.e_clause_lanes, np.float64)
                       + np.asarray(res.e_class_lanes, np.float64))
            # ... whose per-request bills reconcile with the batch meter
            # at 1e-9 (the lane fold is the billing ledger)
            batch_rep = system.step_report(
                np.asarray(res.e_clause_lanes, np.float64),
                np.asarray(res.e_class_lanes, np.float64), 64)
            np.testing.assert_allclose(batch_rep.read_energy_j,
                                       e_lanes.sum(), rtol=1e-9, atol=0.0)
            # the one-shot report path measures the same physics (f32
            # device accumulation order differs) and bills zero writes
            rep = session.infer_with_report(tr_l[b:b + 64]).report
            np.testing.assert_allclose(rep.read_energy_j, e_lanes.sum(),
                                       rtol=1e-5, atol=1e-30)
            assert rep.write_energy_j == 0.0
            assert batch_rep.write_energy_j == 0.0
            # ... then one update sweep on the same fabric
            trainer.update(tr_l[b:b + 64], tr_y[b:b + 64])

    acc1 = trainer.evaluate(ho_l, ho_y)
    assert acc1 > acc0, (acc0, acc1)
    # conductance swaps propagated WITHOUT retracing the serving entries
    assert dict(session._traces)["infer_step"] == traces0["infer_step"]
    assert dict(session._traces)["predict"] == traces0["predict"]
    # the serving path now agrees with the trainer's digital twin (up to
    # the write-hysteresis band on the class tile)
    dp = np.asarray(digital_predict(trainer.params, ho_l, cfg))
    ap = np.asarray(session.predict(ho_l).predictions)
    assert (dp == ap).mean() > 0.7
    # balanced, loadable trace with one span per update
    events = trace.to_json()
    validate_events(events)
    spans = [e for e in events if e["name"] == "train_update"]
    assert len(spans) == 2 * len(trainer.records)       # B/E pairs
    assert all(s["ph"] in ("B", "E") for s in spans)


def test_trainer_write_meter_identity_f64():
    """The f64 sum of per-update write bills equals the running meter
    and the aggregated report lane EXACTLY (same accumulation order)."""
    from repro.serve.impact_engine import aggregate_reports
    cfg, (tr_l, tr_y), _ = _prototype_problem()
    params, _, session = _deployed(cfg, tr_l, tr_y, variability=True)
    trainer = OnlineTrainer(session, params, cfg, key=jax.random.key(3),
                            variability=True)
    for step in range(4):
        trainer.update(tr_l[step * 64:(step + 1) * 64],
                       tr_y[step * 64:(step + 1) * 64])
    per_update = sum(r["write_energy_j"] for r in trainer.records)
    assert per_update == trainer.write_energy_j
    assert aggregate_reports(trainer.reports).write_energy_j \
        == trainer.write_energy_j
    assert trainer.write_energy_j > 0.0


def test_trainer_rejects_packed_and_coresident_sessions():
    from repro.impact import build_coresident
    cfg, (tr_l, tr_y), _ = _prototype_problem()
    params, system, _ = _deployed(cfg, tr_l, tr_y)
    packed = system.compile(RuntimeSpec(backend="pallas-packed",
                                        packing="2bit", interpret=True))
    with pytest.raises(ValueError, match="unpacked"):
        OnlineTrainer(packed, params, cfg, key=jax.random.key(0))
    combined, plan = build_coresident([system, system])
    co = combined.compile(RuntimeSpec(backend="xla", coresident=plan))
    with pytest.raises(ValueError, match="single-tenant"):
        OnlineTrainer(co, params, cfg, key=jax.random.key(0))
