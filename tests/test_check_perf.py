"""The CI perf gate's edge cases — stdlib-only, like the gate itself.

check_perf.py is loaded by file path (``benchmarks`` is a script
directory, not a package on PYTHONPATH), and every check is exercised on
minimal synthetic payloads: the gate must *fail*, never crash, on
degenerate runs (zero completed requests, missing sections, ordering
flips)."""
import importlib.util
import pathlib

import pytest

_PATH = (pathlib.Path(__file__).resolve().parent.parent
         / "benchmarks" / "check_perf.py")
_spec = importlib.util.spec_from_file_location("check_perf", _PATH)
check_perf = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_perf)


# -- check_serve -------------------------------------------------------------

def _serve(p95_c=0.01, p95_f=0.05, shed=0):
    return dict(n_requests=80,
                continuous=dict(p95_s=p95_c, shed=shed, completed=80),
                flush=dict(p95_s=p95_f, completed=80),
                p95_ratio_flush_over_continuous=p95_f / p95_c)


def test_check_serve_happy_path():
    assert check_perf.check_serve(_serve()) == []


def test_check_serve_flags_inverted_p95_and_shed():
    fails = check_perf.check_serve(_serve(p95_c=0.06, shed=3))
    assert len(fails) == 2
    assert any("not below" in f for f in fails)
    assert any("shed 3" in f for f in fails)


def test_check_serve_zero_completed_is_gate_failure_not_crash():
    """Regression: a BENCH_serve.json from a run that completed nothing
    has no p95_s at all — the gate used to crash with KeyError instead
    of failing."""
    empty = dict(n_requests=80,
                 continuous=dict(completed=0, shed=80),
                 flush=dict(completed=0, shed=80))
    fails = check_perf.check_serve(empty)
    assert len(fails) == 2                      # one per scheduler mode
    for f in fails:
        assert "no p95_s" in f and "completed=0" in f
    # One-sided degenerate runs fail on the empty side only.
    one = _serve()
    one["flush"] = dict(completed=0)
    (fail,) = check_perf.check_serve(one)
    assert "flush" in fail


# -- check_metered -----------------------------------------------------------

def _metered(ratio=0.9, parity=True):
    return dict(metered=dict(
        parity_ok=parity,
        ratio_fused_metered_over_unmetered={"b8": ratio, "b32": ratio},
        ratio_fused_metered_over_staged={"b8": 1.5}))


def test_check_metered_section_is_mandatory():
    (fail,) = check_perf.check_metered({})
    assert "missing" in fail


def test_check_metered_parity_and_ratio_floor():
    assert check_perf.check_metered(_metered()) == []
    fails = check_perf.check_metered(_metered(ratio=0.1, parity=False))
    assert len(fails) == 3                      # 2 batch floors + parity
    assert any("parity_ok" in f for f in fails)
    assert sum("fell to" in f for f in fails) == 2


# -- check_compressed --------------------------------------------------------

def _compressed(ratio_ba=4.8, ratio_ib=11.0, parity=True, n_eff=353,
                prune_parity=True):
    return dict(compressed=dict(
        parity_ok=parity,
        results={"int8_b8": {}, "packed_b8": {}},
        cost_analysis={"b8": dict(
            int8=dict(flops=1e6, bytes_accessed=1e8, input_bytes=4e6),
            packed=dict(flops=1e6, bytes_accessed=1e8 / ratio_ba,
                        input_bytes=4e6 / ratio_ib),
            ratio_bytes_accessed=ratio_ba,
            ratio_input_bytes=ratio_ib)},
        pruning=dict(n_clauses=500, n_effective=n_eff, n_never_fired=147,
                     n_duplicates=0, calibration_batch=64,
                     energy_per_effective_clause_j=2e-13,
                     packed_parity_on_calibration=prune_parity)))


def test_check_compressed_section_is_mandatory():
    (fail,) = check_perf.check_compressed({})
    assert "missing" in fail


def test_check_compressed_happy_path():
    assert check_perf.check_compressed(_compressed()) == []
    # the 4x floor is inclusive
    assert check_perf.check_compressed(
        _compressed(ratio_ba=4.0, ratio_ib=4.0)) == []


def test_check_compressed_gates_both_byte_ratios():
    """bytes_accessed and input_bytes fail independently — they catch
    different regressions (out-of-kernel dequant vs operand layout)."""
    (fail,) = check_perf.check_compressed(_compressed(ratio_ba=3.9))
    assert "ratio_bytes_accessed" in fail
    (fail,) = check_perf.check_compressed(_compressed(ratio_ib=2.0))
    assert "ratio_input_bytes" in fail
    fails = check_perf.check_compressed(
        _compressed(ratio_ba=1.0, ratio_ib=1.0))
    assert len(fails) == 2


def test_check_compressed_missing_ratio_is_a_failure_not_crash():
    payload = _compressed()
    del payload["compressed"]["cost_analysis"]["b8"]["ratio_input_bytes"]
    (fail,) = check_perf.check_compressed(payload)
    assert "ratio_input_bytes" in fail and "missing" in fail
    payload["compressed"]["cost_analysis"] = {}
    fails = check_perf.check_compressed(payload)
    assert any("no cost_analysis" in f for f in fails)


def test_check_compressed_parity_and_pruning_invariants():
    fails = check_perf.check_compressed(_compressed(parity=False))
    assert any("parity_ok" in f for f in fails)
    fails = check_perf.check_compressed(_compressed(n_eff=0))
    assert any("effective" in f for f in fails)
    fails = check_perf.check_compressed(_compressed(prune_parity=False))
    assert any("calibration" in f for f in fails)


# -- check_cost_model --------------------------------------------------------

def _pvm(ratio=1.2, ordering=1.01):
    return dict(predicted_vs_measured=dict(
        band=[0.2, 5.0],
        calibration={},
        entries={"predict/xla_b8": dict(
            ratio_pred_over_meas=ratio, calibration_ref=True)},
        orderings={
            "metered_fused_over_off_b8": dict(
                raw_cost_ratio=ordering, must_be_at_least=1.0),
            "staged_over_off_b8": dict(raw_cost_ratio=0.3)}))


def test_check_cost_model_section_is_mandatory():
    (fail,) = check_perf.check_cost_model({})
    assert "missing" in fail


def test_check_cost_model_happy_path():
    assert check_perf.check_cost_model(_pvm()) == []


def test_check_cost_model_band_violations():
    (lo,) = check_perf.check_cost_model(_pvm(ratio=0.05))
    assert "outside band" in lo
    (hi,) = check_perf.check_cost_model(_pvm(ratio=50.0))
    assert "outside band" in hi
    # Band edges are inclusive.
    assert check_perf.check_cost_model(_pvm(ratio=0.2)) == []
    assert check_perf.check_cost_model(_pvm(ratio=5.0)) == []


def test_check_cost_model_hard_fails_ordering_flip():
    """A metered executable pricing below the unmetered one is a sign
    flip (the lowering lost the meter) — hard failure regardless of how
    good every ratio looks."""
    (fail,) = check_perf.check_cost_model(_pvm(ordering=0.97))
    assert "meter" in fail
    # The un-floored staged record never fails, however low.
    assert check_perf.check_cost_model(_pvm()) == []


def test_check_cost_model_empty_entries_fail():
    pvm = _pvm()
    pvm["predicted_vs_measured"]["entries"] = {}
    fails = check_perf.check_cost_model(pvm)
    assert any("no entries" in f for f in fails)


# -- check_train -------------------------------------------------------------

def _train(exact=True, acc_before=0.30, acc_after=0.81, floor=0.55,
           rel_err=0.0, read_rel=0.0, serving_w=0.0, agg=None,
           meter=1e-3):
    return dict(
        acc_floor=floor,
        parity=dict(exact=exact, n_steps=3),
        online=dict(acc_before=acc_before, acc_after=acc_after,
                    n_updates=16),
        write_meter=dict(per_update_sum_j=meter, running_meter_j=meter,
                         aggregate_j=meter if agg is None else agg,
                         rel_err=rel_err),
        read_billing=dict(max_rel_err=read_rel),
        serving_only=dict(write_energy_j=serving_w))


def test_check_train_happy_path():
    assert check_perf.check_train(_train()) == []
    # the accuracy floor is inclusive
    assert check_perf.check_train(_train(acc_after=0.55)) == []


def test_check_train_parity_is_exact_not_a_tolerance():
    fails = check_perf.check_train(_train(exact=False))
    assert any("bit-exactness" in f for f in fails)


def test_check_train_accuracy_floor_and_improvement():
    fails = check_perf.check_train(_train(acc_after=0.50))
    assert any("below the floor" in f for f in fails)
    # clearing the floor without improving on deployment accuracy still
    # fails — online training must actually help
    fails = check_perf.check_train(
        _train(acc_before=0.80, acc_after=0.70, floor=0.55))
    assert any("did not improve" in f for f in fails)
    fails = check_perf.check_train(_train(acc_after=None))
    assert any("missing" in f for f in fails)


def test_check_train_write_meter_identities():
    fails = check_perf.check_train(_train(rel_err=1e-6))
    assert any("per-update write bills" in f for f in fails)
    fails = check_perf.check_train(_train(agg=2e-3))
    assert any("aggregated report" in f for f in fails)
    fails = check_perf.check_train(_train(read_rel=1e-6))
    assert any("read bills" in f for f in fails)


def test_check_train_serving_only_must_bill_exactly_zero():
    fails = check_perf.check_train(_train(serving_w=1e-30))
    assert any("serving-only" in f for f in fails)
    fails = check_perf.check_train(_train(serving_w=None))
    assert any("serving-only" in f for f in fails)


# -- check_throughput --------------------------------------------------------

def test_check_throughput_floor_and_missing_keys(capsys):
    base = dict(normalized={"xla_b8": 1.0, "xla_b32": 2.0},
                machine=dict(cpu_count=8))
    cur_ok = dict(normalized={"xla_b8": 1.0, "xla_b32": 1.9},
                  machine=dict(cpu_count=8))
    assert check_perf.check_throughput(cur_ok, base, 0.30) == []
    cur_bad = dict(normalized={"xla_b8": 1.0},
                   machine=dict(cpu_count=4))
    fails = check_perf.check_throughput(cur_bad, base, 0.30)
    assert any("missing" in f for f in fails)
    assert "WARNING" in capsys.readouterr().out   # cpu-count mismatch
    fails = check_perf.check_throughput(
        dict(normalized={"xla_b8": 1.0, "xla_b32": 1.0}), base, 0.30)
    assert any("floor" in f for f in fails)
