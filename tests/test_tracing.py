"""Chrome-tracing observability: emitted traces must be loadable (valid
event array, monotonic timestamps, balanced B/E pairs per track) and
their span durations must reconcile exactly with the RequestRecord /
BatchStats latency ledger they are cut from."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoTMConfig
from repro.core.cotm import CoTMParams
from repro.impact import IMPACTConfig, RuntimeSpec, build_system
from repro.serve import (IMPACTEngine, REQUEST_PHASES, Tracer,
                         poisson_arrivals, replay_trace, validate_events)
from repro.serve.tracing import PID_ENGINE, PID_REQUESTS


def spec(backend="xla", *, meter=True, capacity=None):
    return RuntimeSpec(backend=backend,
                       metering="staged" if meter else "off",
                       capacity=capacity)


@pytest.fixture(scope="module")
def small_system():
    K, n, m, n_states = 64, 32, 4, 64
    cfg = CoTMConfig(n_literals=K, n_clauses=n, n_classes=m,
                     n_states=n_states)
    rng = np.random.default_rng(0)
    ta = np.where(rng.random((K, n)) < 0.1, n_states + 1, n_states)
    w = rng.integers(-20, 20, (m, n))
    params = CoTMParams(ta_state=jnp.asarray(ta, jnp.int32),
                        weights=jnp.asarray(w, jnp.int32))
    system = build_system(params, cfg, jax.random.key(0),
                          IMPACTConfig(variability=False, finetune=False))
    lits = rng.random((40, K)) < 0.5
    return system, lits


def _spans(events, *, pid, tid=None, name=None):
    """[(name, tid, b_ts, e_ts, b_args)] for balanced B/E pairs."""
    open_, out = {}, []
    for e in events:
        if e.get("pid") != pid or e["ph"] not in ("B", "E"):
            continue
        if tid is not None and e["tid"] != tid:
            continue
        if name is not None and e["name"] != name:
            continue
        key = (e["tid"], e["name"])
        if e["ph"] == "B":
            open_[key] = e
        else:
            b = open_.pop(key)
            out.append((e["name"], e["tid"], b["ts"], e["ts"],
                        b.get("args", {})))
    assert not open_, open_
    return out


# -- validator ---------------------------------------------------------------

def test_validate_events_catches_broken_traces():
    tr = Tracer()
    tr.span("ok", 1.0, 2.0)
    validate_events(tr.to_json())            # balanced: passes

    tr = Tracer()
    tr.begin("dangling", ts=1.0)
    with pytest.raises(ValueError, match="unbalanced"):
        validate_events(tr.to_json())

    tr = Tracer()
    tr.end("orphan", ts=1.0)
    with pytest.raises(ValueError, match="without matching B"):
        validate_events(tr.to_json())

    # Interleaved spans on ONE track (A-B-A-B) are not a flame graph.
    tr = Tracer()
    tr.begin("a", ts=1.0)
    tr.begin("b", ts=2.0)
    tr.end("a", ts=3.0)
    tr.end("b", ts=4.0)
    with pytest.raises(ValueError, match="interleaved"):
        validate_events(tr.to_json())

    with pytest.raises(ValueError, match="non-monotonic"):
        validate_events([
            dict(name="x", ph="i", s="t", ts=2.0, pid=0, tid=0),
            dict(name="y", ph="i", s="t", ts=1.0, pid=0, tid=0)])
    with pytest.raises(ValueError, match="missing"):
        validate_events([dict(name="x", ph="i", ts=0.0, pid=0)])


def test_to_json_rebases_sorts_and_scales():
    """Rendered timestamps are microseconds since the earliest event,
    sorted, with metadata pinned at ts=0."""
    t = [1000.0]
    tr = Tracer(clock=lambda: t[0])
    tr.span("late", 1000.5, 1000.75)
    tr.span("early", 1000.0, 1000.25)        # emitted second, starts first
    ev = tr.to_json()
    validate_events(ev)
    timed = [e for e in ev if e["ph"] != "M"]
    assert timed[0]["name"] == "early" and timed[0]["ts"] == 0.0
    assert timed[-1]["name"] == "late" and timed[-1]["ts"] == pytest.approx(
        0.75e6)
    assert all(e["ts"] == 0.0 for e in ev if e["ph"] == "M")
    # json round-trip: the array is what a viewer loads
    validate_events(json.loads(json.dumps(ev)))


# -- IMPACT engine integration ----------------------------------------------

def test_engine_burst_trace_is_valid_and_reconciles(small_system):
    """A burst through the continuous scheduler yields a loadable trace
    whose per-request lifecycle spans sum EXACTLY to the RequestRecord
    ledger and whose scheduler sweep span matches BatchStats.latency_s
    — same clock readings, zero tolerance beyond float/us rounding."""
    system, lits = small_system
    tr = Tracer()
    eng = IMPACTEngine(system.compile(spec(capacity=8)), trace=tr)
    eng.run(lits[:20])
    ev = tr.to_json()
    validate_events(ev)

    # Scheduler track: one sweep span per recorded batch, equal duration.
    sweeps = _spans(ev, pid=PID_ENGINE, tid=0, name="sweep")
    assert len(sweeps) == len(eng.batch_stats)
    for (_, _, b, e, args), st in zip(sweeps, eng.batch_stats):
        assert (e - b) / 1e6 == pytest.approx(st.latency_s, abs=1e-6)
        assert args["shape"] == st.bucket
        assert args["n_valid"] == st.n_valid
        assert args["occupancy"] == pytest.approx(st.occupancy)

    # Request tracks: the documented 4-phase lifecycle, contiguous, and
    # queued+admitted+sweep == the ledger's end-to-end latency.
    recs = {r.rid: r for r in eng.request_records}
    assert len(recs) == 20
    for rid, rec in recs.items():
        phases = {n: (b, e) for n, _, b, e, _ in
                  _spans(ev, pid=PID_REQUESTS, tid=rid)}
        assert tuple(phases) == REQUEST_PHASES or \
            set(phases) == set(REQUEST_PHASES)
        for a, b in zip(REQUEST_PHASES, REQUEST_PHASES[1:]):
            assert phases[a][1] == phases[b][0]          # contiguous
        lat_us = phases["sweep"][1] - phases["queued"][0]
        assert lat_us / 1e6 == pytest.approx(rec.latency_s, abs=1e-6)


def test_flush_trace_carries_bucket_shape(small_system):
    """Flush-mode sweeps run at bucketed shapes; the trace must say
    which bucket each sweep was padded to."""
    system, lits = small_system
    tr = Tracer()
    eng = IMPACTEngine(system.compile(spec(capacity=8)), mode="flush",
                       buckets=(2, 4, 8), max_wait_s=0.0, trace=tr)
    for i in range(3):
        eng.submit(lits[i])
    eng.step(force=True)
    ev = tr.to_json()
    validate_events(ev)
    (sweep,) = _spans(ev, pid=PID_ENGINE, tid=0, name="sweep")
    assert sweep[4]["shape"] == 4              # 3 requests -> bucket 4
    assert sweep[4]["n_valid"] == 3
    assert eng.batch_stats[-1].bucket == 4


def test_trace_rides_injected_virtual_clock(small_system):
    """The tracer is re-clocked onto the engine's injected clock, so a
    virtual-time run traces deterministically (and the admission span
    vocabulary shows up where the policy acted)."""
    system, lits = small_system
    t = [100.0]
    tr = Tracer()
    eng = IMPACTEngine(system.compile(spec(meter=False, capacity=4)),
                       max_wait_s=0.5, target_occupancy=1.0,
                       clock=lambda: t[0], trace=tr)
    assert tr.clock() == 100.0                 # re-clocked at attach
    eng.submit(lits[0])
    assert eng.step() == []                    # fresh: holds
    t[0] = 101.0
    assert len(eng.step()) == 1                # stale: fires
    ev = tr.to_json()
    validate_events(ev)
    (rec,) = eng.request_records
    phases = {n: (b, e) for n, _, b, e, _ in
              _spans(ev, pid=PID_REQUESTS, tid=rec.rid)}
    assert phases["queued"] == (0.0, 0.0)      # arrived==admitted==100.0
    assert phases["sweep"][0] == pytest.approx(1.0e6)   # fired at 101.0
    names = {e["name"] for e in ev if e["ph"] == "B"}
    assert {"admission", "sweep", "billing", "release"} <= names


def test_replay_trace_writes_loadable_chrome_json(small_system, tmp_path):
    """The acceptance artifact: replay_trace(trace_path=...) writes a
    Chrome-tracing JSON array that loads, covers every completed request
    with a balanced lifecycle, and marks shed requests as instants."""
    system, lits = small_system
    n = 24
    eng = IMPACTEngine(system.compile(spec(meter=False, capacity=4)),
                       max_wait_s=0.0, queue_capacity=4)
    eng.warmup()
    arrivals = poisson_arrivals(n, 800.0, seed=3)
    path = tmp_path / "serve.trace.json"
    out = replay_trace(eng, lits[:n], arrivals, trace_path=str(path))
    assert out["trace_path"] == str(path)
    with open(path) as f:
        ev = json.load(f)
    validate_events(ev)
    done_rids = {r.rid for r in eng.request_records}
    assert out["completed"] == len(done_rids) == n - out["shed"]
    for rid in done_rids:
        names = [nm for nm, *_ in _spans(ev, pid=PID_REQUESTS, tid=rid)]
        assert sorted(names) == sorted(REQUEST_PHASES)
    sheds = [e for e in ev if e["name"] == "shed"]
    assert len(sheds) == out["shed"]
    assert all(e["ph"] == "i" for e in sheds)


# -- LM engine integration ---------------------------------------------------

def test_lm_engine_emits_same_span_vocabulary():
    """The LM front emits prefill/decode + per-request spans through the
    same Tracer, so both engines open in one viewer."""
    from repro.configs import get_config
    from repro.models import build
    from repro.serve import Engine, Request, ServeConfig

    cfg = get_config("qwen3-8b").smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    tr = Tracer()
    eng = Engine(model, params, ServeConfig(max_len=64, temperature=0.0),
                 trace=tr)
    prompts = jax.random.randint(jax.random.key(1), (3, 8), 0, cfg.vocab)
    eng.generate(prompts, 3)
    reqs = [Request(i, np.asarray(prompts[i]), max_new=3) for i in range(3)]
    eng.serve_continuous(reqs, capacity=2, seed=0)
    ev = tr.to_json()
    validate_events(ev)
    names = {e["name"] for e in ev if e["ph"] == "B"}
    assert {"prefill", "decode", "decode_step", "request"} <= names
    assert len(_spans(ev, pid=PID_REQUESTS, name="request")) == 3
