"""train.compression: clause pruning (in-process) and int8 gradient
compression (subprocess with 8 host devices — the main test process must
keep seeing the single real CPU device)."""
import pathlib
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.impact import RuntimeSpec
from repro.impact.yflash import I_CSA_THRESHOLD
from repro.kernels import ref
from repro.train.compression import PruneStats, prune_clauses

from test_fused_impact import _make_system

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


# -- clause pruning ----------------------------------------------------------

def _calib_system(seed=0):
    """System + calibration batch with a CRAFTED duplicate clause column
    (column 5 copies column 3's cells) and a literal mix that leaves some
    clauses never firing — both pruning reductions exercised at once."""
    lit, sys_ = _make_system(64, 100, 80, 6, 2, 64, 2, 50, 2, 50, seed=seed)
    ci = np.asarray(sys_.clause_i).copy()
    cg = np.asarray(sys_.clause_g).copy()
    ci[:, 0, :, 5] = ci[:, 0, :, 3]
    cg[:, 0, :, 5] = cg[:, 0, :, 3]
    import dataclasses as _dc
    sys_ = _dc.replace(sys_, clause_i=jnp.asarray(ci),
                       clause_g=jnp.asarray(cg))
    return lit, sys_


def test_prune_clauses_stats_and_parity():
    lit, sys_ = _calib_system()
    pruned, stats = prune_clauses(sys_, lit)
    assert isinstance(stats, PruneStats)
    n_nonempty = int(np.asarray(sys_._nonempty_eff()).sum())
    # every nonempty column is accounted for exactly once
    assert stats.n_effective + stats.n_never_fired + stats.n_duplicates \
        == n_nonempty
    assert stats.n_duplicates >= 1          # the crafted copy was merged
    assert stats.n_never_fired >= 1
    assert 0 < stats.n_effective < n_nonempty
    assert stats.calibration_batch == 64
    assert stats.energy_per_effective_clause_j > 0
    # the record rides the system for downstream benchmarks
    import dataclasses as _dc
    assert pruned.encode_stats["pruning"] == _dc.asdict(stats)
    # prediction parity on the calibration batch (exact: a never-fired
    # clause contributes nothing there; the merged duplicate's class rows
    # were summed and its currents are identical to the survivor's)
    np.testing.assert_array_equal(
        np.asarray(pruned.compile(RuntimeSpec(backend="xla"))
                   .predict(lit).predictions),
        np.asarray(sys_.compile(RuntimeSpec(backend="xla"))
                   .predict(lit).predictions))


def test_prune_erases_retired_columns_physically():
    """Retired columns stop existing at the device level: currents and
    conductances zeroed, nonempty cleared — so they draw no leakage and
    the energy meter bills strictly less than the unpruned system."""
    lit, sys_ = _calib_system(seed=1)
    pruned, stats = prune_clauses(sys_, lit)
    ne_old = np.asarray(sys_._nonempty_eff())
    ne_new = np.asarray(pruned._nonempty_eff())
    dead = ne_old & ~ne_new
    assert dead.sum() == stats.n_never_fired + stats.n_duplicates
    C, tc = sys_.clause_i.shape[1], sys_.clause_i.shape[3]
    dead_cols = dead.reshape(C, tc)
    assert (np.asarray(pruned.clause_i)
            .transpose(1, 3, 0, 2)[dead_cols] == 0).all()
    assert (np.asarray(pruned.clause_g)
            .transpose(1, 3, 0, 2)[dead_cols] == 0).all()
    def clause_joules(s):
        _, i_cl, _ = ref.fused_impact_metered_ref(
            lit, s.clause_i, s._nonempty_eff(), s.class_i,
            thresh=I_CSA_THRESHOLD)
        return float(np.asarray(i_cl).sum())

    assert clause_joules(pruned) < clause_joules(sys_)


def test_prune_without_merge_keeps_duplicates():
    lit, sys_ = _calib_system(seed=2)
    _, merged = prune_clauses(sys_, lit)
    pruned, stats = prune_clauses(sys_, lit, merge_duplicates=False)
    assert stats.n_duplicates == 0
    assert stats.n_effective == merged.n_effective + merged.n_duplicates
    # class crossbar untouched without the merge
    np.testing.assert_array_equal(np.asarray(pruned.class_i),
                                  np.asarray(sys_.class_i))


def test_prune_degenerate_nothing_fires():
    """All-zero literals violate every clause (drive = 1 everywhere), so
    nothing fires: every nonempty column retires and the re-anchored
    energy figure reports 0.0 instead of dividing by zero."""
    lit, sys_ = _make_system(8, 100, 50, 4, 2, 64, 1, 64, 1, 64, seed=3)
    zeros = jnp.zeros_like(lit)
    pruned, stats = prune_clauses(sys_, zeros)
    assert stats.n_effective == 0
    assert stats.energy_per_effective_clause_j == 0.0
    assert not bool(np.asarray(pruned._nonempty_eff()).any())
    scores = np.asarray(pruned.compile(RuntimeSpec(backend="xla"))
                        .predict(lit).scores)
    np.testing.assert_array_equal(scores, 0.0)


def test_prune_stacks_with_packing():
    """The two compressions compose: a pruned system compiled with
    packing='2bit' stays argmax-parity with the unpruned oracle on the
    calibration batch."""
    lit, sys_ = _calib_system(seed=4)
    pruned, _ = prune_clauses(sys_, lit)
    np.testing.assert_array_equal(
        np.asarray(pruned.compile(RuntimeSpec(backend="pallas-packed",
                                              packing="2bit"))
                   .predict(lit).predictions),
        np.asarray(sys_.compile(RuntimeSpec(backend="xla"))
                   .predict(lit).predictions))


# -- int8 gradient compression ----------------------------------------------

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    import functools
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.compat import shard_map as _shard_map
    shard_map = functools.partial(_shard_map, check_vma=False)
    from repro.train.compression import int8_psum, compressed_grad_allreduce

    mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
    rng = np.random.default_rng(0)

    # --- int8_psum approximates the exact psum, all shards agree ---
    x = jnp.asarray(rng.normal(size=(8, 64, 33)), jnp.float32)
    f = shard_map(lambda v: int8_psum(v[0], "data")[None],
                  mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    got = np.asarray(f(x))
    want = np.asarray(x.sum(0))
    rel = np.abs(got[0] - want).max() / (np.abs(want).max() + 1e-9)
    assert rel < 0.02, rel
    assert np.allclose(got, got[0:1]), "shards disagree"

    # --- error feedback keeps cumulative bias bounded ---
    fstep = shard_map(
        lambda gg, ee: compressed_grad_allreduce(gg[0], ee[0], "data"),
        mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P(), P("data")))
    e = jnp.zeros((8, 1, 128))
    acc_c = np.zeros(128); acc_t = np.zeros(128)
    for i in range(30):
        gi = jnp.asarray(rng.normal(size=(8, 1, 128)), jnp.float32) * 0.01
        tot, e = fstep(gi, e)
        acc_c += np.asarray(tot).reshape(128)
        acc_t += np.asarray(gi.sum(0)).reshape(128)
    drift = np.abs(acc_c - acc_t).max() / (np.abs(acc_t).max() + 1e-9)
    assert drift < 0.05, drift
    print("COMPRESSION_OK", rel, drift)
""")


@pytest.mark.slow
def test_int8_allreduce_and_error_feedback():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                       capture_output=True, text=True, timeout=600)
    assert "COMPRESSION_OK" in r.stdout, (r.stdout[-2000:],
                                          r.stderr[-3000:])
