"""int8 gradient compression: runs in a subprocess with 8 host devices
(the main test process must keep seeing the single real CPU device)."""
import pathlib
import subprocess
import sys
import textwrap

import pytest

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    import functools
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.compat import shard_map as _shard_map
    shard_map = functools.partial(_shard_map, check_vma=False)
    from repro.train.compression import int8_psum, compressed_grad_allreduce

    mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
    rng = np.random.default_rng(0)

    # --- int8_psum approximates the exact psum, all shards agree ---
    x = jnp.asarray(rng.normal(size=(8, 64, 33)), jnp.float32)
    f = shard_map(lambda v: int8_psum(v[0], "data")[None],
                  mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    got = np.asarray(f(x))
    want = np.asarray(x.sum(0))
    rel = np.abs(got[0] - want).max() / (np.abs(want).max() + 1e-9)
    assert rel < 0.02, rel
    assert np.allclose(got, got[0:1]), "shards disagree"

    # --- error feedback keeps cumulative bias bounded ---
    fstep = shard_map(
        lambda gg, ee: compressed_grad_allreduce(gg[0], ee[0], "data"),
        mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P(), P("data")))
    e = jnp.zeros((8, 1, 128))
    acc_c = np.zeros(128); acc_t = np.zeros(128)
    for i in range(30):
        gi = jnp.asarray(rng.normal(size=(8, 1, 128)), jnp.float32) * 0.01
        tot, e = fstep(gi, e)
        acc_c += np.asarray(tot).reshape(128)
        acc_t += np.asarray(gi.sum(0)).reshape(128)
    drift = np.abs(acc_c - acc_t).max() / (np.abs(acc_t).max() + 1e-9)
    assert drift < 0.05, drift
    print("COMPRESSION_OK", rel, drift)
""")


@pytest.mark.slow
def test_int8_allreduce_and_error_feedback():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                       capture_output=True, text=True, timeout=600)
    assert "COMPRESSION_OK" in r.stdout, (r.stdout[-2000:],
                                          r.stderr[-3000:])
