"""Calibrated sweep-cost model: executable cost analysis is real, the
calibration contract holds, and the metered-vs-unmetered raw-cost
ordering the perf gate hard-fails on is true of the actual lowerings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoTMConfig
from repro.core.cotm import CoTMParams
from repro.impact import (IMPACTConfig, RuntimeSpec, SweepCostModel,
                          build_system)
from repro.impact.costmodel import bench_section


@pytest.fixture(scope="module")
def small_system():
    K, n, m, n_states = 64, 32, 4, 64
    cfg = CoTMConfig(n_literals=K, n_clauses=n, n_classes=m,
                     n_states=n_states)
    rng = np.random.default_rng(0)
    ta = np.where(rng.random((K, n)) < 0.1, n_states + 1, n_states)
    w = rng.integers(-20, 20, (m, n))
    params = CoTMParams(ta_state=jnp.asarray(ta, jnp.int32),
                        weights=jnp.asarray(w, jnp.int32))
    return build_system(params, cfg, jax.random.key(0),
                        IMPACTConfig(variability=False, finetune=False))


def test_session_cost_analysis_is_populated(small_system):
    """Every (backend, metering) executable reports nonzero flops and
    bytes — the model prices real XLA counters, not fallbacks."""
    for backend in ("xla", "pallas"):
        for metering in ("off", "fused", "staged"):
            sess = small_system.compile(
                RuntimeSpec(backend=backend, metering=metering))
            ca = sess.cost_analysis("predict", 8)
            assert ca["flops"] > 0, (backend, metering)
            assert ca["bytes_accessed"] > 0, (backend, metering)


def test_estimate_raw_monotone_in_batch(small_system):
    """More lanes can never cost less: raw executable cost is
    nondecreasing in batch for every backend."""
    for backend in ("xla", "pallas"):
        m = SweepCostModel(small_system.compile(
            RuntimeSpec(backend=backend, metering="off")), entry="predict")
        raws = [m.estimate(B).raw for B in (4, 8, 16, 32)]
        assert all(a <= b for a, b in zip(raws, raws[1:])), (backend, raws)
        assert m.estimate(4).analog_latency_s > 0


def test_calibration_contract(small_system):
    """The reference shape predicts its own measurement exactly; an
    uncalibrated model refuses to predict; bad measurements are
    rejected."""
    m = SweepCostModel(small_system.compile(
        RuntimeSpec(backend="pallas", metering="fused")))
    with pytest.raises(RuntimeError, match="not calibrated"):
        m.predict_s(8)
    with pytest.raises(ValueError, match="positive"):
        m.calibrate(8, 0.0)
    m.calibrate(8, 2e-3)
    assert m.predict_s(8) == pytest.approx(2e-3)
    assert m.calibration["ref_batch"] == 8
    # Scaling follows the raw-cost ratio (possibly floored by the analog
    # latency, which at these host timescales never binds).
    want = 2e-3 * m.estimate(32).raw / m.estimate(8).raw
    assert m.predict_s(32) == pytest.approx(want)


def test_analog_floor_binds_when_host_term_vanishes(small_system):
    """With a vanishing measured host time, the prediction floors at the
    Fig. 14 crossbar latency instead of promising impossible speed."""
    m = SweepCostModel(small_system.compile(
        RuntimeSpec(backend="xla", metering="off")), entry="predict")
    m.calibrate(8, 1e-15)
    assert m.predict_s(8) == pytest.approx(
        m.estimate(8).analog_latency_s)


def test_metered_fused_costs_at_least_unmetered(small_system):
    """The ordering invariant check_perf hard-fails on: the fused-metered
    executable (meter accumulators ride the kernel) can never price
    below the unmetered fused kernel."""
    off = SweepCostModel(small_system.compile(
        RuntimeSpec(backend="pallas", metering="off")))
    fused = SweepCostModel(small_system.compile(
        RuntimeSpec(backend="pallas", metering="fused")))
    for B in (8, 32):
        assert fused.estimate(B).raw >= off.estimate(B).raw, B


def test_bench_section_shape_and_gateability(small_system):
    """bench_section produces exactly what check_perf.check_cost_model
    gates: a band, per-entry ratios with a ratio==1 calibration ref per
    family, and floored ordering records."""
    bs = (8, 32)
    results = {f"{impl}_b{B}": dict(us_per_batch=50.0 + B,
                                    samples_per_s=1.0)
               for impl in ("xla", "pallas") for B in bs}
    metered = {f"metered_{mode}_b{B}": dict(us_per_batch=60.0 + B,
                                            samples_per_s=1.0)
               for mode in ("off", "fused", "staged") for B in bs}
    sec = bench_section(small_system,
                        dict(results=results,
                             metered=dict(results=metered)),
                        batch_sizes=bs)
    lo, hi = sec["band"]
    assert 0.0 < lo < 1.0 < hi
    families = {"predict/xla", "predict/pallas", "infer_step/pallas-off",
                "infer_step/pallas-fused", "infer_step/pallas-staged"}
    assert set(sec["calibration"]) == families
    assert set(sec["entries"]) == {f"{f}_b{B}" for f in families
                                   for B in bs}
    for fam in families:
        ref = sec["entries"][f"{fam}_b{bs[0]}"]
        assert ref["calibration_ref"] is True
        assert ref["ratio_pred_over_meas"] == pytest.approx(1.0)
        assert ref["predicted_s"] > 0 and ref["flops"] > 0
    for B in bs:
        o = sec["orderings"][f"metered_fused_over_off_b{B}"]
        assert o["raw_cost_ratio"] >= o["must_be_at_least"] == 1.0
        assert "must_be_at_least" not in \
            sec["orderings"][f"staged_over_off_b{B}"]
