"""The CI hygiene gate's rules — stdlib-only, loaded by file path like
check_perf (``benchmarks`` is a script directory, not a package).

The rule set must flag generated files (bytecode, ``artifacts/`` JSON,
``*.trace.json`` timelines) while leaving the COMMITTED benchmark
baselines under ``benchmarks/baselines/`` alone — that distinction is
the whole point of the path-anchored ``artifacts/`` rule.
"""
import importlib.util
import pathlib
import subprocess

_PATH = (pathlib.Path(__file__).resolve().parent.parent
         / "benchmarks" / "check_hygiene.py")
_spec = importlib.util.spec_from_file_location("check_hygiene", _PATH)
check_hygiene = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_hygiene)


def test_clean_paths_pass():
    clean = [
        "src/repro/kernels/packing.py",
        "benchmarks/baselines/BENCH_throughput.json",  # committed baseline
        "benchmarks/check_hygiene.py",
        ".github/workflows/ci.yml",
        "artifacts/README",                 # not .json
        "docs/trace.json.md",               # not *.trace.json
        "src/repro/impact/artifacts_helper.py",  # 'artifacts/' only at root
    ]
    assert check_hygiene.find_violations(clean) == []


def test_generated_paths_flagged():
    bad = [
        "src/repro/__pycache__/ops.cpython-310.pyc",
        "__pycache__/x.py",
        "src/repro/kernels/ops.pyc",
        "artifacts/BENCH_throughput.json",
        "artifacts/nested/BENCH_serve.json",
        "artifacts/SERVE_continuous.trace.json",
        "somewhere/else/SERVE_flush.trace.json",
    ]
    got = check_hygiene.find_violations(bad)
    assert [p for p, _ in got] == bad
    labels = dict(got)
    assert "bytecode" in labels["src/repro/kernels/ops.pyc"]
    assert "artifact" in labels["artifacts/BENCH_throughput.json"]
    assert "tracing" in labels["somewhere/else/SERVE_flush.trace.json"]


def test_gitignore_gaps():
    """Every policed artifact class must have its ignore line; comments
    and surrounding noise don't count as coverage."""
    full = list(check_hygiene.REQUIRED_IGNORES)
    assert check_hygiene.gitignore_gaps(full) == []
    assert check_hygiene.gitignore_gaps(
        full + ["# noise", "", "  *.tmp  "]) == []
    missing_traces = [p for p in full if p != "*.trace.json"]
    assert check_hygiene.gitignore_gaps(missing_traces) == ["*.trace.json"]
    assert check_hygiene.gitignore_gaps(["# *.trace.json"]) == full


def test_this_repo_gitignore_covers_required():
    """The regression that motivated the check: three SERVE_*.trace.json
    files sat tracked because .gitignore never matched traces.  The real
    .gitignore must cover every policed class."""
    repo = pathlib.Path(__file__).resolve().parent.parent
    lines = (repo / ".gitignore").read_text().splitlines()
    assert check_hygiene.gitignore_gaps(lines) == []


def test_this_repo_tracks_no_serve_traces():
    repo = pathlib.Path(__file__).resolve().parent.parent
    res = subprocess.run(["git", "-C", str(repo), "ls-files",
                          "artifacts/"], capture_output=True, text=True)
    if res.returncode != 0:
        import pytest
        pytest.skip("not a git checkout")
    assert [p for p in res.stdout.splitlines()
            if p.endswith(".trace.json")] == []


def test_main_against_a_real_repo(tmp_path, monkeypatch, capsys):
    """End to end on a throwaway git repo: clean tree exits 0; a tracked
    artifact flips the exit code and prints a ::error annotation; a
    .gitignore coverage gap flips it independently."""
    subprocess.run(["git", "init", "-q", str(tmp_path)], check=True)
    (tmp_path / "ok.py").write_text("x = 1\n")
    (tmp_path / ".gitignore").write_text(
        "\n".join(check_hygiene.REQUIRED_IGNORES) + "\n")
    subprocess.run(["git", "-C", str(tmp_path), "add", "ok.py",
                    ".gitignore"], check=True)
    monkeypatch.chdir(tmp_path)
    assert check_hygiene.main() == 0
    assert "passed" in capsys.readouterr().out

    (tmp_path / ".gitignore").write_text("*.pyc\n")   # coverage gap
    assert check_hygiene.main() == 1
    out = capsys.readouterr().out
    assert "::error file=.gitignore::missing ignore pattern" in out
    (tmp_path / ".gitignore").write_text(
        "\n".join(check_hygiene.REQUIRED_IGNORES) + "\n")

    art = tmp_path / "artifacts"
    art.mkdir()
    (art / "BENCH_throughput.json").write_text("{}")
    subprocess.run(["git", "-C", str(tmp_path), "add", "-f",
                    "artifacts/BENCH_throughput.json"], check=True)
    assert check_hygiene.main() == 1
    out = capsys.readouterr().out
    assert "::error file=artifacts/BENCH_throughput.json::" in out
    assert "FAILED" in out


def test_this_repo_is_clean():
    """The gate the hygiene CI job runs, run here too: the actual tree
    must never track a generated file."""
    repo = pathlib.Path(__file__).resolve().parent.parent
    res = subprocess.run(["git", "-C", str(repo), "ls-files"],
                        capture_output=True, text=True)
    if res.returncode != 0:
        import pytest
        pytest.skip("not a git checkout")
    paths = [ln for ln in res.stdout.splitlines() if ln]
    assert check_hygiene.find_violations(paths) == []
