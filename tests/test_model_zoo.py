"""Multi-tenant model zoo: co-resident builder/session parity, the
tenant-aware router (priority admission, per-class SLO firing, per-tenant
shed), tenant-pure billing, per-tenant trace tracks, standby/eviction/
rebalance, and the single-tenant engine shim."""
import time

import numpy as np
import pytest

from repro.impact import (CoResidentPlan, IMPACTConfig, RuntimeSpec,
                          TenantSpan, build_coresident)
from repro.serve import (Backpressure, IMPACTEngine, ModelZoo, SLOClass,
                         Tracer, poisson_arrivals, replay_trace,
                         replay_zoo_trace, validate_events)
from repro.serve.tracing import PID_REQUESTS, PID_TENANT_BASE

from test_fused_impact import _make_system


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def member_systems(n_tenants=3, K=12, n=6, seed0=0):
    """Small single-tile members with distinct class counts (so a routing
    bug that mixes tenants cannot silently agree)."""
    return [_make_system(4, K, n, 3 + i, 1, K, 1, n, 1, K, seed=seed0 + i)[1]
            for i in range(n_tenants)]


def standalone_pred(system, row):
    sess = system.compile(RuntimeSpec(backend="xla", metering="staged",
                                      capacity=1))
    return int(np.asarray(sess.predict(row[None, :]).predictions)[0])


def random_rows(systems, rng):
    return [rng.integers(0, 2, size=s.n_literals).astype(np.int8)
            for s in systems]


# -- co-resident builder ------------------------------------------------------

def test_build_coresident_block_diagonal_dims():
    systems = member_systems(3)
    combined, plan = build_coresident(systems)
    assert combined.n_literals == sum(s.n_literals for s in systems)
    assert combined.n_clauses == sum(s.n_clauses for s in systems)
    assert combined.n_classes == sum(s.n_classes for s in systems)
    assert plan.n_tenants == 3
    # spans tile the combined grid in order, without overlap
    assert plan.spans[0].lit_lo == 0
    for a, b in zip(plan.spans, plan.spans[1:]):
        assert b.lit_lo == a.lit_hi
        assert b.col_lo == a.col_hi
        assert b.cls_lo == a.cls_hi
    last = plan.spans[-1]
    assert (last.lit_hi, last.col_hi, last.cls_hi) == (
        combined.n_literals, combined.n_clauses, combined.n_classes)
    # off-block cells are exactly zero (no cross-tenant current paths)
    ci = np.array(combined.clause_i[0, 0])
    cs = np.array(combined.class_i[0])
    for i, sp in enumerate(plan.spans):
        blk = ci[sp.lit_lo:sp.lit_hi, sp.col_lo:sp.col_hi].copy()
        ci[sp.lit_lo:sp.lit_hi, sp.col_lo:sp.col_hi] = 0.0
        cs[sp.col_lo:sp.col_hi, sp.cls_lo:sp.cls_hi] = 0.0
        assert blk.any()
    assert not ci.any() and not cs.any()
    assert combined.encode_stats["coresident_members"] == 3


def test_build_coresident_rejects_sharded_members():
    systems = member_systems(1, K=12, n=6)
    sharded = _make_system(4, 24, 12, 3, 2, 12, 2, 6, 1, 24)[1]
    with pytest.raises(ValueError, match="single-tile"):
        build_coresident([systems[0], sharded])


def test_build_coresident_rejects_oversized_grid():
    big = member_systems(1, K=12, n=6)[0]
    n_fit = big.cfg.max_tile_cols // big.n_clauses
    with pytest.raises(ValueError, match="does not fit"):
        build_coresident([big] * (n_fit + 1))


def test_coresident_plan_validates_spans():
    with pytest.raises(ValueError):
        TenantSpan(0, 0, 0, 4, 0, 2)            # empty literal span
    with pytest.raises(ValueError, match="at least one tenant"):
        CoResidentPlan(spans=())
    a = TenantSpan(0, 4, 0, 2, 0, 2)
    overlap = TenantSpan(2, 8, 2, 4, 2, 4)      # literal overlap with a
    with pytest.raises(ValueError):
        CoResidentPlan(spans=(a, overlap))


# -- co-resident session parity ----------------------------------------------

@pytest.mark.parametrize("backend,packing", [
    ("xla", "none"), ("pallas", "none"), ("pallas-packed", "2bit")])
def test_coresident_session_matches_standalone(backend, packing):
    systems = member_systems(3)
    combined, plan = build_coresident(systems)
    sess = combined.compile(RuntimeSpec(
        backend=backend, packing=packing, metering="staged", capacity=6,
        coresident=plan))
    rng = np.random.default_rng(1)
    rows = random_rows(systems, rng)
    lits = np.ones((6, combined.n_literals), np.int8)
    mids = np.zeros((6,), np.int32)
    valid = np.zeros((6,), bool)
    for i, (sp, row) in enumerate(zip(plan.spans, rows)):
        lits[i, sp.lit_lo:sp.lit_hi] = row
        mids[i] = i
        valid[i] = True
    res = sess.infer_step(lits, valid, model_ids=mids)
    preds = np.asarray(res.predictions)
    for i, (s, row) in enumerate(zip(systems, rows)):
        assert preds[i] == standalone_pred(s, row)  # tenant-LOCAL classes
    assert (preds[3:] == -1).all()                  # invalid-lane sentinel
    e = np.asarray(res.e_clause_lanes) + np.asarray(res.e_class_lanes)
    assert (e[3:] == 0.0).all()                     # padded lanes bill zero


def test_coresident_session_requires_model_ids():
    systems = member_systems(2)
    combined, plan = build_coresident(systems)
    sess = combined.compile(RuntimeSpec(backend="xla", capacity=4,
                                        coresident=plan))
    lits = np.ones((4, combined.n_literals), np.int8)
    with pytest.raises(ValueError, match="model_ids"):
        sess.infer_step(lits, np.ones((4,), bool))
    plain = systems[0].compile(RuntimeSpec(backend="xla", capacity=4))
    with pytest.raises(ValueError, match="co-resident"):
        plain.infer_step(np.ones((4, systems[0].n_literals), np.int8),
                         np.ones((4,), bool),
                         model_ids=np.zeros((4,), np.int32))


# -- zoo routing --------------------------------------------------------------

def make_zoo(n_tenants=3, *, capacity=6, clock=None, trace=None,
             slos=None, max_resident=None, standby_capacity=4,
             standby_pool=2, backend="xla"):
    systems = member_systems(n_tenants)
    if slos is None:
        slos = [SLOClass(name="standard", priority=1, max_wait_s=0.0)
                for _ in systems]
    zoo = ModelZoo.build(
        [(f"t{i}", s, slo) for i, (s, slo) in enumerate(zip(systems, slos))],
        RuntimeSpec(backend=backend, metering="staged"),
        capacity=capacity, max_resident=max_resident,
        standby_capacity=standby_capacity, standby_pool=standby_pool,
        clock=clock if clock is not None else time.monotonic, trace=trace)
    return zoo, systems


def test_zoo_serves_all_tenants_with_parity():
    zoo, systems = make_zoo(3)
    rng = np.random.default_rng(2)
    want = {}
    for rep in range(3):
        rows = random_rows(systems, rng)
        for t, row in zip(zoo.tenants, rows):
            want[zoo.submit(t.tid, row)] = standalone_pred(
                systems[t.index], row)
    got = dict(zoo.drain())
    assert got == want
    st = zoo.stats()
    assert st["sweeps"]["standby"] == 0
    for t in zoo.tenants:
        assert st["per_tenant"][t.tid]["completed"] == 3


def test_zoo_priority_orders_admission():
    clk = FakeClock()
    gold = SLOClass(name="gold", priority=0, max_wait_s=0.0)
    std = SLOClass(name="standard", priority=1, max_wait_s=0.0)
    # capacity 2 < offered 3: the gold tenant must win a lane even though
    # it registered (and submitted) last.
    zoo, systems = make_zoo(3, capacity=2, clock=clk,
                            slos=[std, std, gold])
    rng = np.random.default_rng(3)
    rows = random_rows(systems, rng)
    for t, row in zip(zoo.tenants, rows):
        zoo.submit(t.tid, row)
    done = zoo.step(force=True)
    by_tenant = {zoo.request_records[-len(done) + i].tenant
                 for i in range(len(done))}
    assert "t2" in by_tenant                  # gold admitted first
    assert len(done) == 2
    done2 = zoo.step(force=True)
    assert len(done2) == 1                    # leftover standard request


def test_zoo_slo_firing_policy():
    clk = FakeClock()
    gold = SLOClass(name="gold", priority=0, max_wait_s=0.0)
    bulk = SLOClass(name="bulk", priority=1, target_occupancy=1.0,
                    max_wait_s=10.0)
    zoo, systems = make_zoo(2, capacity=6, clock=clk, slos=[bulk, gold])
    rng = np.random.default_rng(4)
    rows = random_rows(systems, rng)
    # A lone bulk request neither meets its occupancy target nor goes
    # stale: the sweep defers.
    zoo.submit("t0", rows[0])
    assert zoo.step() == []
    assert zoo.table.occupancy == 1
    # One gold arrival satisfies ITS class (max_wait 0) -> the shared
    # sweep fires, carrying the bulk lane along.
    zoo.submit("t1", rows[1])
    done = zoo.step()
    assert len(done) == 2


def test_zoo_per_tenant_shed_isolation():
    clk = FakeClock()
    bounded = SLOClass(name="bounded", priority=1, max_wait_s=10.0,
                       target_occupancy=1.0, queue_capacity=1)
    open_ = SLOClass(name="open", priority=1, max_wait_s=10.0,
                     target_occupancy=1.0)
    zoo, systems = make_zoo(2, capacity=3, clock=clk,
                            slos=[bounded, open_])
    rng = np.random.default_rng(5)
    row0 = rng.integers(0, 2, size=systems[0].n_literals).astype(np.int8)
    row1 = rng.integers(0, 2, size=systems[1].n_literals).astype(np.int8)
    # Partially fill the shared table with the unbounded tenant (a full
    # table would satisfy target_occupancy=1 and fire).
    zoo.submit("t1", row1)
    zoo.submit("t1", row1)
    zoo.step()                                # admits, defers (no SLO met)
    assert zoo.table.free == 1
    # Bounded tenant absorbs queue_capacity + free slots = 2 ...
    assert zoo.try_submit("t0", row0) is not None
    assert zoo.try_submit("t0", row0) is not None
    with pytest.raises(Backpressure):
        zoo.submit("t0", row0)
    # ... while the unbounded tenant keeps queueing.
    assert zoo.try_submit("t1", row1) is not None
    assert zoo.tenant("t0").shed == 0         # raise path doesn't count
    assert zoo.try_submit("t0", row0) is None
    assert zoo.tenant("t0").shed == 1


def test_zoo_submit_validates_shape_and_tenant():
    zoo, systems = make_zoo(2)
    with pytest.raises(KeyError, match="unknown tenant"):
        zoo.submit("nope", np.ones((systems[0].n_literals,), np.int8))
    with pytest.raises(ValueError, match="shape"):
        zoo.submit("t0", np.ones((systems[0].n_literals + 1,), np.int8))


def test_zoo_billing_is_tenant_pure():
    zoo, systems = make_zoo(3)
    rng = np.random.default_rng(6)
    for _ in range(4):
        for t, row in zip(zoo.tenants, random_rows(systems, rng)):
            zoo.submit(t.tid, row)
        zoo.drain()
    st = zoo.stats()
    bill = sum(v["e_read_j"] for v in st["per_tenant"].values())
    meter = st["energy"].read_energy_j
    assert bill == pytest.approx(meter, rel=1e-9)
    # each tenant's bill equals its standalone bill on the same rows
    assert all(v["e_read_j"] > 0 for v in st["per_tenant"].values())


def test_zoo_trace_per_tenant_tracks():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    zoo, systems = make_zoo(3, clock=clk, trace=tr)
    rng = np.random.default_rng(7)
    for t, row in zip(zoo.tenants, random_rows(systems, rng)):
        clk.t += 0.001
        zoo.submit(t.tid, row)
    clk.t += 0.001
    zoo.step(force=True)
    events = tr.to_json()
    validate_events(events)
    pids = {e["pid"] for e in events if e.get("ph") != "M"}
    # scheduler track + one process track per tenant, none on the shared
    # single-tenant "requests" pid
    assert {PID_TENANT_BASE + t.index for t in zoo.tenants} <= pids
    assert PID_REQUESTS not in pids
    names = {e["args"]["name"] for e in events
             if e.get("ph") == "M" and e["name"] == "process_name"}
    assert {"tenant t0", "tenant t1", "tenant t2"} <= names


# -- standby pool / rebalance -------------------------------------------------

def test_zoo_standby_serving_and_promotion():
    zoo, systems = make_zoo(4, capacity=6, max_resident=2,
                            standby_capacity=4, standby_pool=1)
    assert [t.tid for t in zoo.tenants if t.resident] == ["t0", "t1"]
    rng = np.random.default_rng(8)
    rows = random_rows(systems, rng)
    # standby tenants answer correctly from their dedicated sessions
    for tid, sysi in (("t2", 2), ("t3", 3)):
        rid = zoo.submit(tid, rows[sysi])
        got = dict(zoo.drain())[rid]
        assert got == standalone_pred(systems[sysi], rows[sysi])
    assert zoo.stats()["sweeps"]["standby"] == 2
    # pool of 1: serving t3 evicted t2's session
    assert set(zoo._standby_sessions) == {"t3"}
    # heavy t2 traffic then rebalance: t2 joins the resident set
    for _ in range(20):
        zoo.submit("t2", rows[2])
        zoo.drain()
    assert zoo.rebalance() is True
    assert zoo.tenant("t2").resident
    assert len([t for t in zoo.tenants if t.resident]) == 2
    rid = zoo.submit("t2", rows[2])
    assert dict(zoo.drain())[rid] == standalone_pred(systems[2], rows[2])


def test_zoo_rebalance_requires_idle_table():
    clk = FakeClock()
    never = SLOClass(name="bulk", priority=1, target_occupancy=1.0,
                     max_wait_s=10.0)
    zoo, systems = make_zoo(3, capacity=6, max_resident=2, clock=clk,
                            slos=[never] * 3)
    rng = np.random.default_rng(9)
    rows = random_rows(systems, rng)
    for _ in range(8):
        zoo.submit("t2", rows[2])
    zoo.step(force=True)
    zoo.submit("t0", rows[0])
    zoo.step()                                 # admitted, sweep deferred
    assert zoo.table.occupancy == 1
    with pytest.raises(RuntimeError, match="idle"):
        zoo.rebalance()
    zoo.step(force=True)
    assert zoo.rebalance() is True


def test_zoo_failed_rebalance_preserves_traffic():
    """Regression: a busy-table rebalance used to decay every tenant's
    traffic EWMA before raising, so each failed attempt corrupted the
    ranking its own retry depends on.  The raise must be state-free."""
    clk = FakeClock()
    never = SLOClass(name="bulk", priority=1, target_occupancy=1.0,
                     max_wait_s=10.0)
    zoo, systems = make_zoo(3, capacity=6, max_resident=2, clock=clk,
                            slos=[never] * 3)
    rng = np.random.default_rng(10)
    rows = random_rows(systems, rng)
    for _ in range(8):
        zoo.submit("t2", rows[2])
    zoo.step(force=True)
    zoo.submit("t0", rows[0])
    zoo.step()                                 # admitted, sweep deferred
    assert zoo.table.occupancy == 1
    before = {t.tid: t.traffic for t in zoo.tenants}
    with pytest.raises(RuntimeError, match="idle"):
        zoo.rebalance()
    assert {t.tid: t.traffic for t in zoo.tenants} == before
    # A no-change rebalance still decays (the EWMA window is the cadence).
    zoo.step(force=True)
    assert zoo.rebalance() is True
    after = {t.tid: t.traffic for t in zoo.tenants}
    assert zoo.rebalance() is False
    assert all(t.traffic < after[t.tid] or after[t.tid] == 0.0
               for t in zoo.tenants)


def test_zoo_coresident_fewer_sweeps_than_per_tenant_engines():
    n_tenants, reps = 4, 3
    zoo, systems = make_zoo(n_tenants)
    rng = np.random.default_rng(10)
    for _ in range(reps):
        for t, row in zip(zoo.tenants, random_rows(systems, rng)):
            zoo.submit(t.tid, row)
        zoo.drain()
    # One shared sweep per round vs one sweep per tenant per round.
    assert zoo.resident_sweeps == reps
    assert zoo.resident_sweeps < n_tenants * reps


# -- replay + satellites ------------------------------------------------------

def test_replay_zoo_trace_mixed_traffic(tmp_path):
    zoo, systems = make_zoo(3)
    rng = np.random.default_rng(11)
    n = 24
    reqs = []
    for i in range(n):
        t = zoo.tenants[int(rng.integers(len(zoo.tenants)))]
        reqs.append((t.tid, rng.integers(
            0, 2, size=t.n_literals).astype(np.int8)))
    path = tmp_path / "zoo.trace.json"
    out = replay_zoo_trace(zoo, reqs, poisson_arrivals(n, 400.0, seed=1),
                           trace_path=str(path))
    assert out["completed"] + out["shed"] == n
    assert out["zoo"]["per_tenant"].keys() == {"t0", "t1", "t2"}
    import json
    validate_events(json.loads(path.read_text()))


def test_replay_zoo_trace_frozen_clock_raises():
    clk = FakeClock()
    zoo, systems = make_zoo(2, clock=clk)
    reqs = [("t0", np.ones((systems[0].n_literals,), np.int8))] * 2
    never = SLOClass(name="bulk", priority=1, target_occupancy=1.0,
                     max_wait_s=10.0)
    for t in zoo.tenants:
        t.slo = never                      # force the replay loop to idle
    with pytest.raises(RuntimeError, match="time.monotonic"):
        replay_zoo_trace(zoo, reqs, np.array([0.0, 10.0]))


def test_poisson_arrivals_rejects_bad_args():
    with pytest.raises(ValueError, match="rate_rps"):
        poisson_arrivals(10, 0.0)
    with pytest.raises(ValueError, match="rate_rps"):
        poisson_arrivals(10, -1.0)
    with pytest.raises(ValueError, match="n must be"):
        poisson_arrivals(-1, 5.0)
    assert poisson_arrivals(0, 5.0).shape == (0,)


def test_replay_trace_frozen_clock_names_the_fix():
    system = member_systems(1)[0]
    clk = FakeClock()
    eng = IMPACTEngine(system.compile(RuntimeSpec(backend="xla",
                                                  capacity=4)),
                       target_occupancy=1.0, max_wait_s=10.0, clock=clk)
    lits = np.ones((2, system.n_literals), np.int8)
    with pytest.raises(RuntimeError, match="time.monotonic"):
        replay_trace(eng, lits, np.array([0.0, 10.0]))


# -- single-tenant engine shim ------------------------------------------------

def test_engine_is_one_tenant_zoo():
    system = member_systems(1)[0]
    eng = IMPACTEngine(system.compile(RuntimeSpec(backend="xla",
                                                  metering="staged",
                                                  capacity=4)))
    assert len(eng._zoo.tenants) == 1
    assert eng._zoo.tenants[0].slo.name == "default"
    rid = eng.submit(np.ones((system.n_literals,), np.int8))
    assert rid == 0
    (rid2, pred), = eng.step(force=True)
    assert rid2 == rid
    assert eng.request_records[0].tenant == "default"
    assert eng._zoo.standby_sweeps == 0


def test_engine_rejects_coresident_session():
    systems = member_systems(2)
    combined, plan = build_coresident(systems)
    sess = combined.compile(RuntimeSpec(backend="xla", capacity=4,
                                        coresident=plan))
    with pytest.raises(ValueError, match="ModelZoo"):
        IMPACTEngine(sess)
