"""Chunked linear-attention engine: exactness vs the sequential
recurrence, step/parallel agreement, chunk-size invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.models.ssm_common import LOG_W_MIN, chunked_la, la_step


def _naive(q, k, v, lw, u=None, inclusive=False):
    q, k, v, lw = (np.asarray(a, np.float64) for a in (q, k, v, lw))
    lw = np.clip(lw, LOG_W_MIN, 0.0)
    w = np.exp(lw)
    B, S, H, Dk = q.shape
    Dv = v.shape[-1]
    o = np.zeros((B, S, H, Dv))
    for b in range(B):
        for h in range(H):
            Sm = np.zeros((Dk, Dv))
            for t in range(S):
                kv = np.outer(k[b, t, h], v[b, t, h])
                if inclusive:
                    Sm = w[b, t, h][:, None] * Sm + kv
                    o[b, t, h] = q[b, t, h] @ Sm
                else:
                    o[b, t, h] = q[b, t, h] @ (
                        Sm + np.asarray(u, np.float64)[h][:, None] * kv)
                    Sm = w[b, t, h][:, None] * Sm + kv
    return o


def _rand(seed, B=2, S=37, H=2, Dk=8, Dv=6):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, H, Dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, Dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, Dv)), jnp.float32)
    lw = jnp.asarray(-np.exp(rng.normal(size=(B, S, H, Dk))), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, Dk)), jnp.float32)
    return q, k, v, lw, u


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2 ** 16), inclusive=st.booleans(),
       S=st.integers(1, 50))
def test_chunked_matches_naive(seed, inclusive, S):
    q, k, v, lw, u = _rand(seed, S=S)
    uu = None if inclusive else u
    o, _ = chunked_la(q, k, v, lw, u=uu, inclusive=inclusive, chunk=16)
    o_ref = _naive(q, k, v, lw, u=uu, inclusive=inclusive)
    np.testing.assert_allclose(np.asarray(o), o_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("inclusive", [False, True])
def test_step_matches_parallel(inclusive):
    q, k, v, lw, u = _rand(7, S=32)
    uu = None if inclusive else u
    o_par, s_par = chunked_la(q, k, v, lw, u=uu, inclusive=inclusive,
                              chunk=8)
    state = jnp.zeros_like(s_par)
    outs = []
    for t in range(32):
        ot, state = la_step(state, q[:, t], k[:, t], v[:, t], lw[:, t],
                            u=uu, inclusive=inclusive)
        outs.append(np.asarray(ot))
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(o_par),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_par),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("inclusive", [False, True])
def test_chunk_size_invariance(inclusive):
    q, k, v, lw, u = _rand(11, S=48)
    uu = None if inclusive else u
    outs = []
    for c in (4, 8, 16, 48):
        o, s = chunked_la(q, k, v, lw, u=uu, inclusive=inclusive, chunk=c)
        outs.append((np.asarray(o), np.asarray(s)))
    for o, s in outs[1:]:
        np.testing.assert_allclose(o, outs[0][0], rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(s, outs[0][1], rtol=2e-4, atol=2e-4)


def test_initial_state_continuation():
    """Processing [first half] then [second half from saved state] must
    equal one full pass."""
    q, k, v, lw, u = _rand(13, S=32)
    o_full, s_full = chunked_la(q, k, v, lw, inclusive=True, chunk=8)
    o1, s1 = chunked_la(q[:, :16], k[:, :16], v[:, :16], lw[:, :16],
                        inclusive=True, chunk=8)
    o2, s2 = chunked_la(q[:, 16:], k[:, 16:], v[:, 16:], lw[:, 16:],
                        inclusive=True, chunk=8, initial_state=s1)
    np.testing.assert_allclose(
        np.concatenate([np.asarray(o1), np.asarray(o2)], 1),
        np.asarray(o_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


def test_extreme_decay_stability():
    """log w at the clamp boundary must not produce inf/nan."""
    B, S, H, Dk, Dv = 1, 64, 1, 4, 4
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, H, Dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, Dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, Dv)), jnp.float32)
    lw = jnp.full((B, S, H, Dk), -100.0)     # clamped to LOG_W_MIN
    o, s = chunked_la(q, k, v, lw, inclusive=True, chunk=16)
    assert np.isfinite(np.asarray(o)).all()
    assert np.isfinite(np.asarray(s)).all()
