"""Energy-invariant property suite: the metering contract every lowering
must satisfy, for STAGED and FUSED metering alike.

The meters are physical quantities (E = V_R * I_col * t_read summed over
crossbar columns), so they obey invariants no implementation detail may
break:

* **non-negativity** — conductances and drives are non-negative, so no
  lane can ever bill negative joules;
* **invalid/padding lanes bill exactly zero** — a free slot-table lane
  (all-1 literals: every row floats) and a valid=False lane both draw no
  billable current;
* **batch-split additivity** — lanes are physically independent columns
  of the same crossbar, so serving a batch in one sweep or in two
  sub-batches bills each datapoint identically and the totals agree in
  f64;
* **f64 lane-sum == batch meter** — per-request attribution must sum
  exactly to the batch-level ``EnergyReport`` (the scheduler's billing
  ledger is audited against the paper's Table 4 accounting);
* **staged == fused** — the in-kernel fused meters and the staged
  per-shard oracle measure the same currents (tight f32 tolerance).

Runs through the compiled-session runtime over hypothesis-generated
shapes / seeds / (R, S) shard layouts (via ``_hypothesis_compat``, so
the suite executes with or without hypothesis installed).  The
reference backend drives the wide sweep; a narrower Pallas sweep pins
the kernel lowerings to the same contract.  Multi-device shard plans
are covered by ``test_crossbar_sharding.py``.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.impact import EnergyReport, RuntimeSpec
from repro.impact.energy import report_from_lane_energies
from repro.serve.impact_engine import aggregate_reports

from test_fused_impact import _make_system

METERINGS = ("staged", "fused")


def _grid(B, K, n, M, R, S, seed, density):
    """Random (R, C, S) shard factorization of a (K, n, M) system."""
    tr = -(-K // R)
    C = 1 + seed % 3
    tc = -(-n // C)
    sr = -(-n // S)
    return _make_system(B, K, n, M, R, tr, C, tc, S, sr,
                        seed=seed, density=density)


def _step(session, lit, n_valid):
    """One slot-table sweep with ``n_valid`` occupied lanes (the rest are
    free: all-1 literals, valid=False) -> (result, valid mask)."""
    B, K = lit.shape
    buf = np.ones((B, K), np.int8)
    buf[:n_valid] = np.asarray(lit[:n_valid], np.int8)
    valid = np.zeros((B,), bool)
    valid[:n_valid] = True
    return session.infer_step(buf, valid), valid


def _assert_invariants(sys_, session, lit, n_valid):
    res, valid = _step(session, lit, n_valid)
    e_cl = np.asarray(res.e_clause_lanes, np.float64)
    e_cs = np.asarray(res.e_class_lanes, np.float64)

    # non-negative everywhere
    assert (e_cl >= 0).all() and (e_cs >= 0).all(), (e_cl, e_cs)
    # invalid / padding lanes bill exactly zero
    np.testing.assert_array_equal(e_cl[~valid], 0.0)
    np.testing.assert_array_equal(e_cs[~valid], 0.0)
    assert (np.asarray(res.predictions)[~valid] == -1).all()
    # a valid lane that drives at least one row draws real (if only
    # leakage) clause-crossbar current; an all-1 lane floats every row
    # and legitimately bills zero
    driven = (np.asarray(lit[:n_valid]) == 0).any(axis=1)
    assert (e_cl[:n_valid][driven] > 0.0).all()

    # f64 lane-sum == batch meter (the billing-ledger audit)
    report = sys_.step_report(e_cl, e_cs, n_valid)
    assert report.read_energy_j == e_cl.sum() + e_cs.sum()
    assert report.clause_energy_j == e_cl.sum()
    assert report.class_energy_j == e_cs.sum()
    # ...and the one-shot report path measures the same physics
    rep = session.infer_with_report(lit).report
    full, _ = _step(session, lit, lit.shape[0])
    lane_sum = (np.asarray(full.e_clause_lanes, np.float64).sum()
                + np.asarray(full.e_class_lanes, np.float64).sum())
    np.testing.assert_allclose(rep.read_energy_j, lane_sum, rtol=1e-5,
                               atol=1e-30)
    assert rep.datapoints == lit.shape[0]

    # batch-split additivity: two half sweeps bill each lane identically
    if n_valid >= 2:
        h = n_valid // 2
        ra, _ = _step(session, lit[:h], h)
        rb, _ = _step(session, lit[h:n_valid], n_valid - h)
        split_cl = np.concatenate([np.asarray(ra.e_clause_lanes, np.float64),
                                   np.asarray(rb.e_clause_lanes, np.float64)])
        split_cs = np.concatenate([np.asarray(ra.e_class_lanes, np.float64),
                                   np.asarray(rb.e_class_lanes, np.float64)])
        np.testing.assert_allclose(split_cl, e_cl[:n_valid], rtol=1e-6,
                                   atol=1e-30)
        np.testing.assert_allclose(split_cs, e_cs[:n_valid], rtol=1e-6,
                                   atol=1e-30)
        np.testing.assert_allclose(split_cl.sum() + split_cs.sum(),
                                   e_cl.sum() + e_cs.sum(), rtol=1e-6,
                                   atol=1e-30)
    return e_cl, e_cs


@settings(max_examples=10, deadline=None)
@given(B=st.integers(2, 12), K=st.integers(4, 96), n=st.integers(2, 48),
       M=st.integers(2, 8), R=st.integers(1, 3), S=st.integers(1, 3),
       metering=st.sampled_from(METERINGS),
       density=st.floats(0.0, 0.3), seed=st.integers(0, 2 ** 16))
def test_meter_invariants_property(B, K, n, M, R, S, metering, density,
                                   seed):
    """The wide sweep (reference backend): every invariant over random
    shapes, shard layouts, occupancies, and both metering modes."""
    lit, sys_ = _grid(B, K, n, M, R, S, seed, density)
    session = sys_.compile(RuntimeSpec(backend="xla", metering=metering,
                                       capacity=B))
    _assert_invariants(sys_, session, lit, n_valid=1 + seed % B)


@settings(max_examples=5, deadline=None)
@given(B=st.integers(2, 8), K=st.integers(4, 64), n=st.integers(2, 32),
       M=st.integers(2, 6), R=st.integers(1, 2), S=st.integers(1, 2),
       metering=st.sampled_from(METERINGS), seed=st.integers(0, 2 ** 16))
def test_meter_invariants_property_pallas(B, K, n, M, R, S, metering, seed):
    """The kernel lowerings obey the same contract (narrower sweep —
    interpret mode is slow; the staged/fused parity suites carry the
    exhaustive shapes)."""
    lit, sys_ = _grid(B, K, n, M, R, S, seed, density=0.15)
    session = sys_.compile(RuntimeSpec(backend="pallas", metering=metering,
                                       capacity=B))
    _assert_invariants(sys_, session, lit, n_valid=1 + seed % B)


@settings(max_examples=8, deadline=None)
@given(B=st.integers(2, 10), K=st.integers(4, 96), n=st.integers(2, 48),
       M=st.integers(2, 8), R=st.integers(1, 3), S=st.integers(1, 3),
       density=st.floats(0.0, 0.3), seed=st.integers(0, 2 ** 16))
def test_staged_equals_fused_property(B, K, n, M, R, S, density, seed):
    """Mode parity as a property: the fused in-kernel meters and the
    staged per-shard oracle bill the same joules lane by lane (tight f32
    tolerance), with exact argmax agreement on valid lanes."""
    lit, sys_ = _grid(B, K, n, M, R, S, seed, density)
    n_valid = 1 + seed % B
    staged, valid = _step(sys_.compile(RuntimeSpec(
        backend="xla", metering="staged", capacity=B)), lit, n_valid)
    fused, _ = _step(sys_.compile(RuntimeSpec(
        backend="xla", metering="fused", capacity=B)), lit, n_valid)
    np.testing.assert_array_equal(np.asarray(staged.predictions),
                                  np.asarray(fused.predictions))
    np.testing.assert_allclose(np.asarray(fused.e_clause_lanes),
                               np.asarray(staged.e_clause_lanes),
                               rtol=1e-5, atol=1e-30)
    np.testing.assert_allclose(np.asarray(fused.e_class_lanes),
                               np.asarray(staged.e_class_lanes),
                               rtol=1e-5, atol=1e-30)


# --- EnergyReport empty-aggregate guards (regression) -----------------------

def _empty_report(**kw):
    base = dict(read_energy_j=0.0, clause_energy_j=0.0, class_energy_j=0.0,
                program_energy_j=0.0, erase_energy_j=0.0, latency_s=0.0,
                ops_crosspoint=0.0, datapoints=0)
    base.update(kw)
    return EnergyReport(**base)


def test_empty_report_metrics_do_not_raise():
    """gops and tops_per_w guard their denominators like
    energy_per_datapoint_j always has — an empty aggregate reports 0.0
    instead of ZeroDivisionError."""
    empty = _empty_report()
    assert empty.energy_per_datapoint_j == 0.0
    assert empty.gops == 0.0
    assert empty.tops_per_w == 0.0
    # read_energy_j == 0 with real ops/latency: still no raise
    idle = _empty_report(latency_s=1e-6, ops_crosspoint=1e6, datapoints=4)
    assert idle.tops_per_w == 0.0
    assert idle.gops > 0.0
    # the area-less aggregate still refuses tops_per_mm2 loudly
    with pytest.raises(ValueError, match="area"):
        empty.tops_per_mm2


def test_empty_lane_fold_and_aggregate_guards():
    """Folding zero lanes (an all-idle sweep) and aggregating such
    reports must stay finite end to end."""
    rep = report_from_lane_energies(
        np.zeros((0,)), np.zeros((0,)), program_energy_j=0.0,
        erase_energy_j=0.0, latency_s=0.0, ops_per_datapoint=0.0,
        datapoints=0)
    assert rep.read_energy_j == 0.0
    assert rep.gops == 0.0 and rep.tops_per_w == 0.0
    agg = aggregate_reports([rep, rep])
    assert agg.datapoints == 0
    assert agg.gops == 0.0 and agg.tops_per_w == 0.0
    assert agg.energy_per_datapoint_j == 0.0


def test_report_with_valid_mask_sentinels_and_agrees_across_modes():
    """infer_with_report under a validity mask: excluded lanes predict
    the sentinel -1 in BOTH metering modes (their scores are
    mode-dependent garbage — staged zeroes the drive, fused doesn't),
    valid lanes agree exactly, and the meters bill only the real lanes."""
    lit, sys_ = _grid(8, 48, 16, 4, 2, 2, seed=7, density=0.15)
    valid = np.zeros((8,), bool)
    valid[:5] = True
    reports = {}
    for metering in METERINGS:
        res = sys_.compile(RuntimeSpec(backend="xla", metering=metering,
                                       capacity=8)) \
            .infer_with_report(lit, valid=valid)
        preds = np.asarray(res.predictions)
        assert (preds[5:] == -1).all(), preds
        reports[metering] = (preds, res.report)
    np.testing.assert_array_equal(reports["staged"][0], reports["fused"][0])
    rs, rf = reports["staged"][1], reports["fused"][1]
    assert rs.datapoints == rf.datapoints == 5
    np.testing.assert_allclose(rf.read_energy_j, rs.read_energy_j,
                               rtol=1e-5, atol=1e-30)


def test_unprogrammed_grid_bills_leakage_only():
    """density=0: no clause is programmed, nonempty masks every column —
    class meters are exactly zero (no clause fires, no class row driven)
    while clause meters only carry LCS leakage."""
    lit, sys_ = _grid(6, 32, 12, 4, 2, 2, seed=3, density=0.0)
    for metering in METERINGS:
        session = sys_.compile(RuntimeSpec(backend="xla", metering=metering,
                                           capacity=6))
        res, valid = _step(session, lit, 6)
        assert (np.asarray(res.e_class_lanes) == 0.0).all()
        assert (np.asarray(res.e_clause_lanes) >= 0.0).all()


# -- co-resident (multi-tenant) billing identity ------------------------------

def _coresident_setup(n_tenants=3, metering="staged", seed=0):
    from repro.impact import build_coresident
    systems = [_make_system(4, 12, 6, 3 + i, 1, 12, 1, 6, 1, 12,
                            seed=seed + i, density=0.2)[1]
               for i in range(n_tenants)]
    combined, plan = build_coresident(systems)
    session = combined.compile(RuntimeSpec(
        backend="xla", metering=metering, capacity=2 * n_tenants,
        coresident=plan))
    rng = np.random.default_rng(seed)
    B = 2 * n_tenants
    lits = np.ones((B, combined.n_literals), np.int8)
    mids = np.zeros((B,), np.int32)
    valid = np.zeros((B,), bool)
    rows = []
    for i in range(B - 1):                    # leave the last lane padded
        t = i % n_tenants
        sp = plan.spans[t]
        row = rng.integers(0, 2, size=sp.lit_hi - sp.lit_lo).astype(np.int8)
        lits[i, sp.lit_lo:sp.lit_hi] = row
        mids[i] = t
        valid[i] = True
        rows.append((t, row))
    return systems, plan, session, lits, mids, valid, rows


@pytest.mark.parametrize("metering", METERINGS)
def test_coresident_tenant_bills_sum_to_batch_meter(metering):
    """Multi-tenant billing identity: the f64 sum of every tenant's lane
    bills equals the shared batch meter, and padded/invalid lanes bill
    exactly zero — co-residency never invents or loses joules."""
    systems, plan, session, lits, mids, valid, rows = \
        _coresident_setup(metering=metering)
    res = session.infer_step(lits, valid, model_ids=mids)
    e_cl = np.asarray(res.e_clause_lanes, np.float64)
    e_cs = np.asarray(res.e_class_lanes, np.float64)
    np.testing.assert_array_equal(e_cl[~valid], 0.0)
    np.testing.assert_array_equal(e_cs[~valid], 0.0)
    assert (np.asarray(res.predictions)[~valid] == -1).all()
    per_tenant = {t: 0.0 for t in range(len(systems))}
    for i, (t, _) in enumerate(rows):
        per_tenant[t] += e_cl[i] + e_cs[i]
    batch_meter = e_cl.sum() + e_cs.sum()
    np.testing.assert_allclose(sum(per_tenant.values()), batch_meter,
                               rtol=1e-12, atol=0.0)
    # the one-shot report path audits the same joules
    rep = session.infer_with_report(lits, valid=valid,
                                    model_ids=mids).report
    np.testing.assert_allclose(rep.read_energy_j, batch_meter, rtol=1e-5,
                               atol=1e-30)
    assert rep.datapoints == int(valid.sum())


# -- online-training write-energy invariants ---------------------------------
#
# The write meter obeys the same physical contract as the read meters:
# pulse trains are counted, not estimated, so zero pulses bill exactly
# zero joules, bills are non-negative, and the f64 sum of per-update
# bills equals the running batch meter and the aggregated report lane.

def _online_trainer(seed, *, K=12, n=8, m=3, B=6, variability=True):
    import jax
    from repro.core.cotm import CoTMConfig
    from repro.impact.pipeline import IMPACTConfig, build_system
    from repro.train import OnlineTrainer
    cfg = CoTMConfig(n_literals=K, n_clauses=n, n_classes=m, n_states=16,
                     threshold=4)
    params = cfg.init(jax.random.key(seed))
    system = build_system(params, cfg, jax.random.key(seed + 1),
                          IMPACTConfig(variability=False, finetune=False))
    session = system.compile(RuntimeSpec(backend="xla"))
    trainer = OnlineTrainer(session, params, cfg, key=jax.random.key(seed + 2),
                            variability=variability, max_pulses=32)
    rng = np.random.default_rng(seed)
    lits = jnp.asarray(rng.integers(0, 2, (B, K)).astype(np.int8))
    labels = jnp.asarray(rng.integers(0, m, (B,)).astype(np.int32))
    return trainer, lits, labels


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2 ** 16), variability=st.sampled_from([False, True]),
       steps=st.integers(1, 3))
def test_write_energy_invariants_property(seed, variability, steps):
    """Non-negativity, zero-pulses-zero-joules, and the f64 per-update /
    batch-meter / aggregate-lane identity, over random systems, seeds,
    and ideal vs. noisy write paths."""
    trainer, lits, labels = _online_trainer(seed, variability=variability)
    for _ in range(steps):
        r = trainer.update(lits, labels)
        assert r["write_energy_j"] >= 0.0
        if r["prog_pulses"] + r["erase_pulses"] == 0:
            assert r["write_energy_j"] == 0.0
        else:
            assert r["write_energy_j"] > 0.0
    total = sum(r["write_energy_j"] for r in trainer.records)
    assert total == trainer.write_energy_j
    agg = aggregate_reports(trainer.reports)
    assert agg.write_energy_j == trainer.write_energy_j


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 8), cols=st.integers(1, 8),
       seed=st.integers(0, 2 ** 16), width=st.floats(1e-6, 1e-3))
def test_in_band_cells_never_pulse_or_bill(rows, cols, seed, width):
    """The foundation of the zero-write identity: cells already inside
    their target band draw no pulses, keep their conductance bit-exact,
    and ``encode_energy`` bills them exactly (0.0, 0.0) J."""
    import jax
    from repro.impact import yflash
    from repro.impact.energy import encode_energy
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.uniform(yflash.G_MIN, yflash.G_MAX, (rows, cols)))
    var = yflash.DeviceVariation.sample(jax.random.key(seed), (rows, cols))
    g1, n_p, n_e = yflash.pulse_until(
        g, target_lo=jnp.zeros_like(g), target_hi=jnp.full_like(g, jnp.inf),
        width_prog=width, width_erase=width, var=var,
        key=jax.random.key(seed + 1), max_pulses=16)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g))
    assert int(n_p.sum()) == 0 and int(n_e.sum()) == 0
    assert encode_energy(n_p, n_e, width, width) == (0.0, 0.0)


def test_zero_pulse_update_bills_exactly_zero():
    """A feedback sweep whose votes are saturated at +/-T draws p=0 on
    every row: no TA moves, no weight changes, the write path sees only
    trivial [0, inf) bands — zero pulses, exactly 0.0 J, even with the
    noisy write path enabled."""
    import jax
    from repro.core.cotm import CoTMConfig, CoTMParams
    from repro.impact.pipeline import IMPACTConfig, build_system
    from repro.train import OnlineTrainer
    cfg = CoTMConfig(n_literals=12, n_clauses=24, n_classes=3, n_states=16,
                     threshold=4)
    # Deep-excluded TAs (state 1): every clause is empty, so under the
    # training semantics every clause fires; class 0 carries +3 weights
    # and the rest -3, so label-0 batches saturate every vote at +/-T.
    params = CoTMParams(
        ta_state=jnp.ones((12, 24), jnp.int32),
        weights=jnp.broadcast_to(
            jnp.where(jnp.arange(3)[:, None] == 0, 3, -3),
            (3, 24)).astype(jnp.int32))
    system = build_system(params, cfg, jax.random.key(0),
                          IMPACTConfig(variability=False, finetune=False))
    session = system.compile(RuntimeSpec(backend="xla"))
    trainer = OnlineTrainer(session, params, cfg, key=jax.random.key(1),
                            variability=True)
    rng = np.random.default_rng(2)
    lits = jnp.asarray(rng.integers(0, 2, (6, 12)).astype(np.int8))
    r = trainer.update(lits, jnp.zeros((6,), jnp.int32))
    assert r["n_flips"] == 0 and r["n_weight_cells"] == 0
    assert r["prog_pulses"] == 0 and r["erase_pulses"] == 0
    assert r["write_energy_j"] == 0.0
    assert trainer.write_energy_j == 0.0


def test_serving_only_bills_zero_write_energy():
    """Pure inference never touches the write meter: every serving report
    and any aggregate of serving reports carries write_energy_j == 0.0
    exactly."""
    lit, sys_ = _grid(6, 32, 12, 4, 2, 2, seed=5, density=0.15)
    reports = []
    for metering in METERINGS:
        session = sys_.compile(RuntimeSpec(backend="xla", metering=metering,
                                           capacity=6))
        rep = session.infer_with_report(lit).report
        assert rep.write_energy_j == 0.0
        reports.append(rep)
    assert aggregate_reports(reports).write_energy_j == 0.0


def test_coresident_lane_bills_match_standalone_sessions():
    """Tenant purity: each lane's bill on the shared grid equals the bill
    the SAME row draws on its tenant's standalone session (up to f32
    accumulation order: the shared grid reduces over the combined column
    range, whose extra terms are exact zeros summed in a different
    order) — cross-tenant current leakage is exactly zero by
    construction: foreign literal rows float, and foreign clause columns
    are CSA-gated before the class stage."""
    systems, plan, session, lits, mids, valid, rows = _coresident_setup()
    res = session.infer_step(lits, valid, model_ids=mids)
    e = (np.asarray(res.e_clause_lanes, np.float64)
         + np.asarray(res.e_class_lanes, np.float64))
    solo = {t: s.compile(RuntimeSpec(backend="xla", metering="staged",
                                     capacity=1))
            for t, s in enumerate(systems)}
    for i, (t, row) in enumerate(rows):
        ref = solo[t].infer_step(row[None, :], np.ones((1,), bool))
        ref_e = (np.asarray(ref.e_clause_lanes, np.float64)
                 + np.asarray(ref.e_class_lanes, np.float64))[0]
        np.testing.assert_allclose(e[i], ref_e, rtol=1e-6, atol=1e-30)
