"""Serving: prefill+decode must agree with the full forward pass; engine
and batching driver behave."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build
from repro.serve import BatchingQueue, Engine, Request, ServeConfig


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 33
    if cfg.modality == "audio":
        toks = jax.random.randint(jax.random.key(2), (B, S,
                                                      cfg.n_codebooks),
                                  0, cfg.vocab)
    else:
        toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab)
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    if cfg.rope_style == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S)[None, None, :], (3, B, S))
    logits_full, _ = model.forward(params, toks, pos)

    lp, cache = model.prefill(params, toks[:, :S - 1], pos[..., :S - 1], 96)
    scale = float(jnp.abs(logits_full[:, S - 2]).max()) + 1e-6
    rel_prefill = float(jnp.abs(lp[:, 0]
                                - logits_full[:, S - 2]).max()) / scale
    # MoE capacity dropping depends on sequence length, so prefill(S-1)
    # can legitimately route differently than forward(S).
    tol = 0.35 if cfg.moe is not None else 0.05
    assert rel_prefill < tol, (arch, rel_prefill)

    dpos = (jnp.full((3, B, 1), S - 1, jnp.int32)
            if cfg.rope_style == "mrope"
            else jnp.full((B, 1), S - 1, jnp.int32))
    ld, _ = model.decode_step(params, cache, toks[:, S - 1:S], dpos)
    scale = float(jnp.abs(logits_full[:, S - 1]).max()) + 1e-6
    rel = float(jnp.abs(ld[:, 0] - logits_full[:, S - 1]).max()) / scale
    if cfg.n_layers * (3 if cfg.hybrid_attn_every else 1) > 8:
        # Deep stacks (zamba2: 81 sequential mamba layers) amplify bf16
        # op-order differences between the fused-forward and step-decode
        # paths; the serving-relevant property is the decoded
        # distribution's top-1 (exact agreement holds in f32 — verified:
        # rel 1e-3 with dtype=float32).
        np.testing.assert_array_equal(
            np.asarray(jnp.argmax(ld[:, 0], -1)),
            np.asarray(jnp.argmax(logits_full[:, S - 1], -1)))
        assert rel < 0.5, (arch, rel)
    else:
        assert rel < tol + 0.08, (arch, rel)


def test_engine_generate_greedy_deterministic():
    cfg = get_config("qwen3-8b").smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    eng = Engine(model, params, ServeConfig(max_len=64, temperature=0.0))
    prompts = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    g1, s1 = eng.generate(prompts, 6)
    g2, _ = eng.generate(prompts, 6)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    assert g1.shape == (2, 6)
    assert s1["decode_tok_per_s"] > 0


def test_engine_long_decode_recurrent():
    """RWKV6 decodes with O(1) state — generate far past the prompt."""
    cfg = get_config("rwkv6-7b").smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    eng = Engine(model, params, ServeConfig(max_len=8, temperature=0.7))
    prompts = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)
    gen, _ = eng.generate(prompts, 24, seed=3)   # 3x the "max_len"
    assert gen.shape == (2, 24)
    assert (np.asarray(gen) >= 0).all()


def test_continuous_lm_serving_matches_generate():
    """Continuous batching (SlotTable lanes + mid-flight cache scatter)
    must produce, per request, exactly the greedy tokens the flush-style
    ``generate`` produces — admitting a request into a freed lane cannot
    perturb co-resident lanes."""
    cfg = get_config("qwen3-8b").smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    eng = Engine(model, params, ServeConfig(max_len=64, temperature=0.0))
    prompts = jax.random.randint(jax.random.key(1), (5, 8), 0, cfg.vocab)
    ref, _ = eng.generate(prompts, 4)

    reqs = [Request(i, np.asarray(prompts[i]), max_new=4) for i in range(5)]
    gen, stats = eng.serve_continuous(reqs, capacity=2, seed=0)
    assert set(gen) == set(range(5))
    for i in range(5):
        np.testing.assert_array_equal(np.asarray(gen[i]).ravel(),
                                      np.asarray(ref[i]).ravel())
    # 5 requests x 4 tokens through 2 lanes: slots were reused, and
    # per-request latency percentiles are reported
    assert stats["capacity"] == 2
    assert stats["latency"]["n"] == 5
    assert stats["decode_steps"] >= 9


def test_continuous_lm_mixed_lengths_release_early():
    """Requests with different max_new release their slot at different
    steps; a short request admitted beside a long one finishes first and
    its lane serves a later request."""
    cfg = get_config("qwen3-8b").smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    eng = Engine(model, params, ServeConfig(max_len=64, temperature=0.0))
    prompts = jax.random.randint(jax.random.key(4), (3, 8), 0, cfg.vocab)
    ref, _ = eng.generate(prompts, 6)
    max_new = [2, 6, 3]
    reqs = [Request(i, np.asarray(prompts[i]), max_new=max_new[i])
            for i in range(3)]
    gen, _ = eng.serve_continuous(reqs, capacity=2, seed=0)
    for i in range(3):
        np.testing.assert_array_equal(
            np.asarray(gen[i]).ravel(),
            np.asarray(ref[i]).ravel()[:max_new[i]])


def test_batching_queue():
    q = BatchingQueue(max_batch=2, max_wait_s=10.0)
    assert not q.ready()
    q.add(Request(1, np.arange(5, dtype=np.int32), 4))
    assert not q.ready()                      # not full, not stale
    q.add(Request(2, np.arange(3, dtype=np.int32), 4))
    assert q.ready()                          # full
    batch = q.take()
    toks, mask = BatchingQueue.pad(batch)
    assert toks.shape == (2, 5)
    assert bool(mask[0].all()) and int(mask[1].sum()) == 3
    # right-aligned padding
    np.testing.assert_array_equal(np.asarray(toks[1, 2:]), np.arange(3))
