"""CoTM inference vs the literal numpy oracle + algebraic properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (CoTMConfig, CoTMParams, class_scores, clause_outputs,
                        include_mask, predict, to_unipolar, violation_counts)
from repro.core.ref import (class_scores_ref, clause_outputs_ref,
                            predict_ref, violation_counts_ref)


def _random_model(rng, K=64, n=32, m=4, density=0.1):
    cfg = CoTMConfig(n_literals=K, n_clauses=n, n_classes=m)
    ta = rng.integers(1, 2 * cfg.n_states + 1, (K, n)).astype(np.int32)
    # sparsify includes like a trained model (paper Fig. 10: 2.3% include)
    mask = rng.random((K, n)) < density
    ta = np.where(mask, ta, np.minimum(ta, cfg.n_states))
    w = rng.integers(-40, 40, (m, n)).astype(np.int32)
    return cfg, CoTMParams(ta_state=jnp.asarray(ta), weights=jnp.asarray(w))


def test_inference_matches_oracle(rng):
    cfg, params = _random_model(rng)
    lits = rng.random((16, cfg.n_literals)) < 0.5
    inc = np.asarray(include_mask(params.ta_state, cfg.n_states))
    got_c = np.asarray(clause_outputs(jnp.asarray(lits), jnp.asarray(inc)))
    want_c = clause_outputs_ref(lits, inc)
    np.testing.assert_array_equal(got_c, want_c)

    got_v = np.asarray(violation_counts(jnp.asarray(lits), jnp.asarray(inc)))
    np.testing.assert_array_equal(got_v, violation_counts_ref(lits, inc))

    got_p = np.asarray(predict(params, jnp.asarray(lits), cfg))
    want_p = predict_ref(lits, inc, np.asarray(params.weights))
    np.testing.assert_array_equal(got_p, want_p)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 16), K=st.integers(2, 100),
       n=st.integers(1, 60), m=st.integers(2, 8),
       density=st.floats(0.0, 0.6))
def test_inference_matches_oracle_hypothesis(seed, K, n, m, density):
    rng = np.random.default_rng(seed)
    cfg, params = _random_model(rng, K, n, m, density)
    lits = rng.random((4, K)) < rng.random()
    inc = np.asarray(include_mask(params.ta_state, cfg.n_states))
    got = np.asarray(clause_outputs(jnp.asarray(lits), jnp.asarray(inc)))
    np.testing.assert_array_equal(got, clause_outputs_ref(lits, inc))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_unipolar_shift_preserves_argmax(seed):
    """The paper's W' = W + |W_min| transform (Fig. 6) must preserve the
    classification decision for any clause pattern."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.integers(-100, 100, (6, 40)), jnp.int32)
    clauses = jnp.asarray(rng.random((8, 40)) < 0.4)
    w_uni, shift = to_unipolar(w)
    assert int(jnp.min(w_uni)) >= 0
    s_signed = class_scores(clauses, w)
    s_uni = class_scores(clauses, w_uni)
    # Shift adds the same constant (shift * #fired) to every class.
    np.testing.assert_array_equal(
        np.argmax(np.asarray(s_signed), -1),
        np.argmax(np.asarray(s_uni), -1))


def test_empty_clause_semantics(rng):
    """Empty clauses (no includes) vote 1 in training, 0 at inference."""
    K, n = 8, 4
    inc = jnp.zeros((K, n), bool)
    lits = jnp.asarray(rng.random((5, K)) < 0.5)
    assert not np.asarray(clause_outputs(lits, inc, training=False)).any()
    assert np.asarray(clause_outputs(lits, inc, training=True)).all()
