"""2-bit ternary clause packing: pack/unpack round-trips (including
non-multiple-of-4 row counts), data-driven classification of the bimodal
Y-Flash current populations, and the packed fused kernel against the
packed einsum oracle.

Parity contract (same convention as test_fused_impact): quantization
collapses per-cell currents to their class means, so packed-vs-unpacked
raw scores only agree loosely — but CSA bits and argmax are EXACT on
these systems because column currents sit decades from the decision
boundary.  Packed kernel vs packed ORACLE is a tight allclose: both
consume the identical quantized currents.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.impact.yflash import I_CSA_THRESHOLD
from repro.kernels import backends, ops, packing, ref

from test_fused_impact import SHARD_SHAPES, _make_system


# -- pack / unpack round trip ------------------------------------------------

@pytest.mark.parametrize("K", [1, 2, 3, 4, 5, 7, 8, 127, 128, 130])
def test_pack_unpack_roundtrip(K):
    """Every row count round-trips, multiple of 4 or not."""
    rng = np.random.default_rng(K)
    codes = rng.integers(0, 3, (K, 33)).astype(np.uint8)
    packed = packing.pack_ternary(codes)
    assert packed.shape == (packing.packed_rows(K), 33)
    assert packed.dtype == jnp.uint8
    np.testing.assert_array_equal(
        np.asarray(packing.unpack_ternary(packed, K)), codes)


@settings(max_examples=25, deadline=None)
@given(K=st.integers(1, 200), N=st.integers(1, 40),
       seed=st.integers(0, 2 ** 16))
def test_pack_unpack_roundtrip_property(K, N, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 3, (K, N)).astype(np.uint8)
    got = packing.unpack_ternary(packing.pack_ternary(codes), K)
    np.testing.assert_array_equal(np.asarray(got), codes)


def test_bitfield_layout_contract():
    """Bit-field j of packed row q is original row 4q+j — the layout the
    Pallas kernel's in-register unpack assumes."""
    codes = np.asarray([[1], [2], [0], [1], [2]], np.uint8)  # K=5
    packed = np.asarray(packing.pack_ternary(codes))
    assert packed.shape == (2, 1)
    assert packed[0, 0] == (1 << 0) | (2 << 2) | (0 << 4) | (1 << 6)
    assert packed[1, 0] == 2                   # row 4, padding rows DEAD


# -- classification + quantization -------------------------------------------

def test_population_split_lands_between_regimes():
    """The geometric midpoint sits decades from both device populations,
    including far-tail HCS cells BELOW the CSA column threshold (the case
    that rules out using the CSA threshold as the split)."""
    rng = np.random.default_rng(0)
    hcs = 5e-6 * (1 + 0.05 * rng.standard_normal(200))
    hcs[0] = 4.0e-6                 # -5 sigma tail, below I_CSA_THRESHOLD
    lcs = 2.7e-9 * (1 + 0.05 * rng.standard_normal(200))
    cur = jnp.asarray(np.concatenate([hcs, lcs, [0.0]]), jnp.float32)
    split = float(packing.population_split(cur))
    assert lcs.max() < split < hcs.min()
    codes = np.asarray(packing.classify_currents(cur))
    assert (codes[:200] == packing.CODE_HCS).all()   # tail cell included
    assert (codes[200:400] == packing.CODE_LCS).all()
    assert codes[400] == packing.CODE_DEAD


def test_quant_levels_and_dequant():
    cur = jnp.asarray([0.0, 2e-9, 4e-9, 5e-6, 7e-6], jnp.float32)
    codes = packing.classify_currents(cur)
    levels = packing.quant_levels(cur, codes)
    np.testing.assert_allclose(np.asarray(levels), [3e-9, 6e-6], rtol=1e-6)
    deq = np.asarray(packing.dequant_codes(codes, levels))
    np.testing.assert_allclose(deq, [0.0, 3e-9, 3e-9, 6e-6, 6e-6],
                               rtol=1e-6)
    # single-population operand: split == the common value, all HCS
    flat = jnp.full((4,), 5e-6, jnp.float32)
    assert (np.asarray(packing.classify_currents(flat))
            == packing.CODE_HCS).all()


@pytest.mark.parametrize("tr", [32, 33, 150])          # incl. tr % 4 != 0
def test_pack_clause_operand_roundtrip(tr):
    """(R, C, tr, tc) operand packs 4:1 on the row axis and dequants back
    to the class-mean currents with codes preserved exactly."""
    lit, sys_ = _make_system(4, 100, 50, 10, 2, tr, 2, 32, 1, 64, seed=5)
    packed = backends.get_backend("pallas-packed") \
        .pack_clause_operand(sys_.clause_i)
    R, C, _, tc = sys_.clause_i.shape
    assert packed.bits.shape == (R, C, packing.packed_rows(tr), tc)
    assert packed.bits.dtype == jnp.uint8
    deq = packing.dequant_clause(packed.bits, packed.levels, tr)
    assert deq.shape == sys_.clause_i.shape
    np.testing.assert_array_equal(
        np.asarray(packing.classify_currents(deq)),
        np.asarray(packing.classify_currents(sys_.clause_i)))
    # the packed operand is ~16x smaller than the f32 currents it encodes
    assert packing.packed_nbytes(packed) * 8 \
        < sys_.clause_i.size * sys_.clause_i.dtype.itemsize


# -- packed oracle vs unpacked oracle ----------------------------------------

@pytest.mark.parametrize("B,K,n,M,R,tr,C,tc,S,sr", SHARD_SHAPES)
def test_packed_oracle_argmax_parity(B, K, n, M, R, tr, C, tc, S, sr):
    """Quantization to class means preserves every CSA decision and the
    argmax across the shard-layout sweep."""
    lit, sys_ = _make_system(B, K, n, M, R, tr, C, tc, S, sr, seed=31)
    packed = packing.pack_clause_operand(sys_.clause_i)
    want = ref.fused_impact_ref(lit, sys_.clause_i, sys_.nonempty,
                                sys_.class_i, thresh=I_CSA_THRESHOLD)
    got = ref.fused_impact_packed_ref(lit, packed.bits, packed.levels,
                                      sys_.nonempty, sys_.class_i,
                                      thresh=I_CSA_THRESHOLD, tr=tr)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(got, -1)),
                                  np.asarray(jnp.argmax(want, -1)))


# -- packed Pallas kernel vs packed oracle -----------------------------------

@pytest.mark.parametrize("B,K,n,M,R,tr,C,tc,S,sr", SHARD_SHAPES)
def test_packed_kernel_matches_packed_oracle(B, K, n, M, R, tr, C, tc,
                                             S, sr):
    """The in-kernel 2-bit unpack computes the same quantized physics as
    the dequant-then-einsum oracle: tight allclose + exact argmax."""
    lit, sys_ = _make_system(B, K, n, M, R, tr, C, tc, S, sr, seed=33)
    packed = packing.pack_clause_operand(sys_.clause_i)
    want = ref.fused_impact_packed_ref(lit, packed.bits, packed.levels,
                                       sys_.nonempty, sys_.class_i,
                                       thresh=I_CSA_THRESHOLD, tr=tr)
    got = ops.fused_impact_packed(lit, packed, sys_.nonempty, sys_.class_i,
                                  thresh=I_CSA_THRESHOLD, tr=tr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(got, -1)),
                                  np.asarray(jnp.argmax(want, -1)))


@pytest.mark.parametrize("B,K,n,M,R,tr,C,tc,S,sr", SHARD_SHAPES[:3])
def test_packed_metered_matches_packed_oracle(B, K, n, M, R, tr, C, tc,
                                              S, sr):
    """The metered packed kernel bills the QUANTIZED currents — meters
    match the packed metered oracle, scores match the unmetered kernel."""
    lit, sys_ = _make_system(B, K, n, M, R, tr, C, tc, S, sr, seed=35)
    packed = packing.pack_clause_operand(sys_.clause_i)
    want = ref.fused_impact_packed_metered_ref(
        lit, packed.bits, packed.levels, sys_.nonempty, sys_.class_i,
        thresh=I_CSA_THRESHOLD, tr=tr)
    got = ops.fused_impact_packed(lit, packed, sys_.nonempty, sys_.class_i,
                                  thresh=I_CSA_THRESHOLD, tr=tr, meter=True)
    plain = ops.fused_impact_packed(lit, packed, sys_.nonempty,
                                    sys_.class_i, thresh=I_CSA_THRESHOLD,
                                    tr=tr)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(got[0], -1)),
                                  np.asarray(jnp.argmax(want[0], -1)))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(plain),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                               rtol=1e-5)


def test_every_backend_serves_the_packed_operand():
    """The base-class default (dequant + delegate) makes packing a spec
    value every registered backend accepts — xla, pallas, and the packed
    kernel all agree on argmax."""
    B, K, n, M, R, tr, C, tc, S, sr = SHARD_SHAPES[1]
    lit, sys_ = _make_system(B, K, n, M, R, tr, C, tc, S, sr, seed=37)
    packed = packing.pack_clause_operand(sys_.clause_i)
    preds = {}
    for impl in ("xla", "pallas", "pallas-packed"):
        scores = ops.fused_impact_packed(
            lit, packed, sys_.nonempty, sys_.class_i,
            thresh=I_CSA_THRESHOLD, tr=tr, impl=impl)
        preds[impl] = np.asarray(jnp.argmax(scores, -1))
    np.testing.assert_array_equal(preds["pallas-packed"], preds["xla"])
    np.testing.assert_array_equal(preds["pallas"], preds["xla"])
