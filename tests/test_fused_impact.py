"""Fused analog IMPACT kernel: parity vs the einsum oracle across shard
layouts, plus the golden digital==analog end-to-end equivalence (Fig. 4).

The sweep inputs live in the PHYSICAL current regime (HCS reads ~5 uA,
LCS ~3 nA, CSA threshold 4.1 uA): column currents sit decades away from
the decision boundary, so CSA bits and argmax must be EXACTLY equal
between implementations; raw scores are float sums whose association
order differs, so they get an allclose with tight rtol.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import CoTMConfig, predict, train_epochs
from repro.core.cotm import clause_outputs, include_mask
from repro.data.synthetic import prototype
from repro.impact import IMPACTConfig, RuntimeSpec, build_system
from repro.impact.pipeline import IMPACTSystem
from repro.impact.yflash import I_CSA_THRESHOLD, read_current
from repro.kernels import backends, ops, ref

# (B, K, n, M, R, tr, C, tc, S, sr) — mix of single-tile, R>1/S>1 shard
# splits, ragged (non-multiple-of-block) shapes, and unequal clause-axis
# paddings between the clause tile (C*tc) and class tile (S*sr).
SHARD_SHAPES = [
    (4, 100, 50, 10, 1, 128, 1, 64, 1, 64),
    (37, 300, 77, 3, 2, 150, 3, 30, 5, 16),       # R>1, S>1, ragged
    (8, 520, 500, 10, 3, 200, 2, 256, 1, 2048),   # class pad >> clause pad
    (1, 1568, 500, 10, 1, 2048, 1, 512, 1, 2048), # paper MNIST layout
    (16, 64, 33, 4, 2, 32, 3, 11, 4, 9),          # tiny ragged everything
]


def _make_system(B, K, n, M, R, tr, C, tc, S, sr, seed=0, density=0.05):
    """Synthetic programmed system in the physical current regime."""
    rng = np.random.default_rng(seed)
    lit = jnp.asarray(rng.random((B, K)) < 0.5)
    include = rng.random((R * tr, C * tc)) < density
    include[K:, :] = False                   # literal padding rows
    include[:, n:] = False                   # clause padding columns
    g = np.where(include, 2.5e-6 * (1 + 0.05 * rng.standard_normal(include.shape)),
                 0.9e-9 * (1 + 0.05 * rng.standard_normal(include.shape)))
    clause_g = jnp.asarray(g.reshape(R, tr, C, tc).transpose(0, 2, 1, 3),
                           jnp.float32)
    nonempty = jnp.asarray(include[:, :C * tc].any(axis=0))
    wg = rng.uniform(1e-9, 2.5e-6, (S, sr, M))
    wg[:, :, :] *= (np.arange(S * sr).reshape(S, sr, 1) < n)  # pad rows dead
    class_g = jnp.asarray(wg, jnp.float32)
    system = IMPACTSystem(
        clause_g=clause_g, nonempty=nonempty, class_g=class_g,
        clause_i=read_current(clause_g), class_i=read_current(class_g),
        n_literals=K, n_clauses=n, n_classes=M, cfg=IMPACTConfig(),
        encode_stats=dict(program_energy_j=0.0, erase_energy_j=0.0))
    return lit, system


@pytest.mark.parametrize("B,K,n,M,R,tr,C,tc,S,sr", SHARD_SHAPES)
def test_fused_impact_matches_oracle(B, K, n, M, R, tr, C, tc, S, sr):
    lit, sys_ = _make_system(B, K, n, M, R, tr, C, tc, S, sr)
    want = ref.fused_impact_ref(lit, sys_.clause_i, sys_.nonempty,
                                sys_.class_i, thresh=I_CSA_THRESHOLD)
    got = ops.fused_impact(lit, sys_.clause_i, sys_.nonempty, sys_.class_i,
                           thresh=I_CSA_THRESHOLD)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(got, -1)),
                                  np.asarray(jnp.argmax(want, -1)))


@pytest.mark.parametrize("B,K,n,M,R,tr,C,tc,S,sr", SHARD_SHAPES)
def test_clause_bits_parity(B, K, n, M, R, tr, C, tc, S, sr):
    """Staged pallas clause stage == einsum oracle, bit-exact."""
    lit, sys_ = _make_system(B, K, n, M, R, tr, C, tc, S, sr, seed=1)
    f_p, i_p = sys_.clause_bits(lit, impl="pallas")
    f_x, i_x = sys_.clause_bits(lit, impl="xla")
    np.testing.assert_array_equal(np.asarray(f_p), np.asarray(f_x))
    # f32 chunked accumulation over up to R*tr rows reassociates the sum:
    # worst-case relative drift ~n_rows * eps_f32 (~2e-4 at 2048 rows).
    np.testing.assert_allclose(np.asarray(i_p), np.asarray(i_x), rtol=1e-3)


@pytest.mark.parametrize("B,K,n,M,R,tr,C,tc,S,sr", SHARD_SHAPES[:3])
def test_class_scores_parity(B, K, n, M, R, tr, C, tc, S, sr):
    lit, sys_ = _make_system(B, K, n, M, R, tr, C, tc, S, sr, seed=2)
    fired, _ = sys_.clause_bits(lit, impl="xla")
    s_p, i_p = sys_.class_scores(fired, impl="pallas")
    s_x, i_x = sys_.class_scores(fired, impl="xla")
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_x), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(i_p), np.asarray(i_x), rtol=1e-6)


@pytest.mark.parametrize("B,K,n,M,R,tr,C,tc,S,sr", SHARD_SHAPES)
def test_system_predict_parity(B, K, n, M, R, tr, C, tc, S, sr):
    lit, sys_ = _make_system(B, K, n, M, R, tr, C, tc, S, sr, seed=3)
    np.testing.assert_array_equal(
        np.asarray(sys_.compile(RuntimeSpec(backend="pallas"))
                   .predict(lit).predictions),
        np.asarray(sys_.compile(RuntimeSpec(backend="xla"))
                   .predict(lit).predictions))


@pytest.mark.parametrize("B,K,n,M,R,tr,C,tc,S,sr", SHARD_SHAPES)
def test_fused_metered_matches_staged_and_oracle(B, K, n, M, R, tr, C, tc,
                                                 S, sr):
    """The tentpole parity contract: the in-kernel fused meters == the
    staged per-shard meters == the einsum oracle, across the shard-layout
    sweep.  Argmax is exact; currents are f32 sums whose association
    order differs across the three lowerings (the fused kernel chunks
    columns, the staged path chunks shards), so they get a tight rtol.
    """
    lit, sys_ = _make_system(B, K, n, M, R, tr, C, tc, S, sr, seed=6)
    args = (lit, sys_.clause_i, sys_.nonempty, sys_.class_i)
    want = ref.fused_impact_metered_ref(*args, thresh=I_CSA_THRESHOLD)
    fused = ops.fused_impact(*args, thresh=I_CSA_THRESHOLD, meter=True)
    # the staged meters: per-shard currents the pre-tentpole metered path
    # materialized, summed per lane (now the oracle the kernel is pinned
    # against)
    bk = backends.get_backend("pallas")
    fired, i_col = bk.impact_clause_bits(lit, sys_.clause_i, sys_.nonempty,
                                         thresh=I_CSA_THRESHOLD)
    s_scores, i_cls = bk.impact_class_scores(fired, sys_.class_i)
    staged = (s_scores, i_col.sum(axis=(1, 2, 3)), i_cls.sum(axis=(1, 2)))

    for got in (fused, staged):
        np.testing.assert_array_equal(
            np.asarray(jnp.argmax(got[0], -1)),
            np.asarray(jnp.argmax(want[0], -1)))
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                                   rtol=1e-6)
        # clause meter reassociates up to R*tr*C*tc f32 terms
        np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                                   rtol=1e-3)
        np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                                   rtol=1e-5)
    np.testing.assert_allclose(np.asarray(fused[1]), np.asarray(staged[1]),
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(fused[2]), np.asarray(staged[2]),
                               rtol=1e-5)


@pytest.mark.parametrize("B,K,n,M,R,tr,C,tc,S,sr", SHARD_SHAPES)
def test_packed_backend_argmax_parity_with_int8(B, K, n, M, R, tr, C, tc,
                                                S, sr):
    """The compressed-datapath acceptance sweep: ``packing="2bit"``
    through the ``pallas-packed`` backend agrees on argmax with the int8
    fused kernel AND the einsum oracle across every shard layout — the
    quantized clause operand preserves all CSA decisions."""
    lit, sys_ = _make_system(B, K, n, M, R, tr, C, tc, S, sr, seed=41)
    preds = {}
    for backend, packing in (("pallas", "none"),
                             ("pallas-packed", "2bit"),
                             ("xla", "none")):
        sess = sys_.compile(RuntimeSpec(backend=backend, packing=packing,
                                        metering="off"))
        preds[backend] = np.asarray(sess.predict(lit).predictions)
    np.testing.assert_array_equal(preds["pallas-packed"], preds["pallas"])
    np.testing.assert_array_equal(preds["pallas-packed"], preds["xla"])


def test_packed_session_fused_metering_matches_staged():
    """Packed sessions bill the QUANTIZED currents: the in-kernel packed
    meters must agree with the staged path (which dequantizes the same
    operand) lane for lane."""
    lit, sys_ = _make_system(16, 300, 77, 3, 2, 150, 3, 30, 5, 16, seed=43)
    buf = np.ones((16, 300), np.int8)
    buf[:11] = np.asarray(lit[:11], np.int8)
    valid = np.zeros((16,), bool)
    valid[:11] = True
    sessions = {
        mode: sys_.compile(RuntimeSpec(backend="pallas-packed",
                                       packing="2bit", metering=mode,
                                       capacity=16))
        for mode in ("fused", "staged")}
    res = {mode: s.infer_step(buf, valid) for mode, s in sessions.items()}
    np.testing.assert_array_equal(np.asarray(res["fused"].predictions),
                                  np.asarray(res["staged"].predictions))
    np.testing.assert_allclose(np.asarray(res["fused"].e_clause_lanes),
                               np.asarray(res["staged"].e_clause_lanes),
                               rtol=1e-4, atol=0.0)
    np.testing.assert_allclose(np.asarray(res["fused"].e_class_lanes),
                               np.asarray(res["staged"].e_class_lanes),
                               rtol=1e-4, atol=0.0)
    np.testing.assert_array_equal(
        np.asarray(res["fused"].e_clause_lanes)[11:], 0.0)


def test_metered_backend_scores_identical_to_unmetered():
    """The registered ``pallas-metered`` lowering is the SAME datapath
    with meters riding along: plain fused_impact scores through it are
    bit-identical to the unmetered kernel."""
    lit, sys_ = _make_system(16, 100, 50, 10, 2, 64, 1, 64, 2, 32, seed=8)
    args = (lit, sys_.clause_i, sys_.nonempty, sys_.class_i)
    np.testing.assert_array_equal(
        np.asarray(ops.fused_impact(*args, thresh=I_CSA_THRESHOLD,
                                    impl="pallas-metered")),
        np.asarray(ops.fused_impact(*args, thresh=I_CSA_THRESHOLD,
                                    impl="pallas")))


def test_all_empty_clause_columns():
    """A tile with NO programmed clause must fire nothing and score zero
    (every column current is pure LCS leakage, masked by nonempty)."""
    B, K, n, M = 8, 96, 40, 5
    lit, sys_ = _make_system(B, K, n, M, 2, 64, 1, 64, 1, 64,
                             seed=4, density=0.0)
    assert not bool(sys_.nonempty.any())
    for impl in ("pallas", "xla"):
        fired, _ = sys_.clause_bits(lit, impl=impl)
        assert not bool(fired.any()), impl
        scores = (ops.fused_impact(lit, sys_.clause_i, sys_.nonempty,
                                   sys_.class_i, thresh=I_CSA_THRESHOLD)
                  if impl == "pallas" else
                  ref.fused_impact_ref(lit, sys_.clause_i, sys_.nonempty,
                                       sys_.class_i,
                                       thresh=I_CSA_THRESHOLD))
        np.testing.assert_array_equal(np.asarray(scores),
                                      np.zeros((B, M), np.float32))


@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 24), K=st.integers(1, 200), n=st.integers(1, 90),
       M=st.integers(1, 12), R=st.integers(1, 3), S=st.integers(1, 3),
       density=st.floats(0.0, 0.4), seed=st.integers(0, 2 ** 16))
def test_fused_impact_property(B, K, n, M, R, S, density, seed):
    """Property sweep: random shard factorizations stay oracle-exact."""
    tr = -(-K // R)
    C = 1 + seed % 3
    tc = -(-n // C)
    sr = -(-n // S)
    lit, sys_ = _make_system(B, K, n, M, R, tr, C, tc, S, sr,
                             seed=seed, density=density)
    want = ref.fused_impact_ref(lit, sys_.clause_i, sys_.nonempty,
                                sys_.class_i, thresh=I_CSA_THRESHOLD)
    got = ops.fused_impact(lit, sys_.clause_i, sys_.nonempty, sys_.class_i,
                           thresh=I_CSA_THRESHOLD)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(got, -1)),
                                  np.asarray(jnp.argmax(want, -1)))


# --- golden end-to-end: digital CoTM == analog IMPACT (paper Fig. 4) -------

@pytest.fixture(scope="module")
def golden_trained():
    cfg = CoTMConfig(n_literals=128, n_clauses=64, n_classes=4,
                     n_states=64, threshold=16, specificity=4.0)
    x, y = prototype(768, n_classes=4, n_features=64, flip=0.05)
    lits = jnp.asarray(np.concatenate([x, 1 - x], -1).astype(bool))
    params = train_epochs(cfg.init(jax.random.key(0)), lits,
                          jnp.asarray(y), jax.random.key(1), cfg,
                          epochs=8, batch_size=64)
    return cfg, params, lits


@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_golden_analog_matches_digital(golden_trained, backend):
    """Ideal devices (variability=False) + fine-tuned weight mapping must
    reproduce the digital CoTM decisions exactly — clause bits AND
    predictions (the Fig. 4 crossbar/logic equivalence)."""
    cfg, params, lits = golden_trained
    system = build_system(params, cfg, jax.random.key(2),
                          IMPACTConfig(variability=False, finetune=True))
    dig_pred = np.asarray(predict(params, lits, cfg))
    inc = include_mask(params.ta_state, cfg.n_states)
    dig_clauses = np.asarray(clause_outputs(lits, inc))

    session = system.compile(RuntimeSpec(backend=backend))
    ana_pred = np.asarray(session.predict(lits).predictions)
    fired, _ = system.clause_bits(lits, impl=backend)
    np.testing.assert_array_equal(
        np.asarray(fired)[:, :cfg.n_clauses], dig_clauses)
    np.testing.assert_array_equal(ana_pred, dig_pred)


def test_infer_with_report_consistent_across_backends(golden_trained):
    """Energy metering (staged oracle mode): both backends must report
    the same physics (same currents => same joules) and the same preds."""
    cfg, params, lits = golden_trained
    system = build_system(params, cfg, jax.random.key(2),
                          IMPACTConfig(variability=False, finetune=True))
    res_p = system.compile(RuntimeSpec(backend="pallas")) \
        .infer_with_report(lits[:64])
    res_x = system.compile(RuntimeSpec(backend="xla")) \
        .infer_with_report(lits[:64])
    rep_p, rep_x = res_p.report, res_x.report
    np.testing.assert_array_equal(np.asarray(res_p.predictions),
                                  np.asarray(res_x.predictions))
    assert rep_p.read_energy_j > 0
    np.testing.assert_allclose(rep_p.read_energy_j, rep_x.read_energy_j,
                               rtol=1e-5)
    np.testing.assert_allclose(rep_p.clause_energy_j, rep_x.clause_energy_j,
                               rtol=1e-5)


@pytest.mark.parametrize("backend", ["pallas", "xla"])
def test_fused_metering_report_matches_staged(golden_trained, backend):
    """metering='fused' on a TRAINED system: the single-pass in-kernel
    report carries the same joules / preds / accounting as the staged
    oracle session (the Table 4 anchors ride on this equality)."""
    cfg, params, lits = golden_trained
    system = build_system(params, cfg, jax.random.key(2),
                          IMPACTConfig(variability=False, finetune=True))
    staged = system.compile(RuntimeSpec(backend=backend,
                                        metering="staged")) \
        .infer_with_report(lits[:64])
    fused = system.compile(RuntimeSpec(backend=backend,
                                       metering="fused")) \
        .infer_with_report(lits[:64])
    np.testing.assert_array_equal(np.asarray(fused.predictions),
                                  np.asarray(staged.predictions))
    rs, rf = staged.report, fused.report
    assert rf.read_energy_j > 0
    np.testing.assert_allclose(rf.clause_energy_j, rs.clause_energy_j,
                               rtol=1e-4)
    np.testing.assert_allclose(rf.class_energy_j, rs.class_energy_j,
                               rtol=1e-4)
    assert rf.datapoints == rs.datapoints
    assert rf.latency_s == rs.latency_s
    assert rf.ops_crosspoint == rs.ops_crosspoint
