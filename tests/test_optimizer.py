"""AdamW from scratch: convergence, clipping, moment dtypes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train import AdamWConfig, apply_updates, init_state


def test_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, weight_decay=0.0,
                      grad_clip=100.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    state = init_state({"w": jnp.zeros(3)}, cfg)
    for _ in range(300):
        g = {"w": 2 * (state.params["w"] - target)}
        state, _ = apply_updates(state, g, cfg)
    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.asarray(target), atol=1e-2)


def test_gradient_clipping():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=1, grad_clip=1.0)
    state = init_state({"w": jnp.zeros(4)}, cfg)
    huge = {"w": jnp.full(4, 1e6)}
    state2, metrics = apply_updates(state, huge, cfg)
    assert float(metrics["grad_norm"]) > 1e5
    # update magnitude bounded by lr despite the huge gradient
    assert float(jnp.abs(state2.params["w"]).max()) < 2 * cfg.lr


def test_moment_dtype_bf16():
    cfg = AdamWConfig(moment_dtype=jnp.bfloat16)
    state = init_state({"w": jnp.zeros((8, 8))}, cfg)
    assert state.m["w"].dtype == jnp.bfloat16
    assert state.v["w"].dtype == jnp.bfloat16
    state2, _ = apply_updates(state, {"w": jnp.ones((8, 8))}, cfg)
    assert state2.m["w"].dtype == jnp.bfloat16
    assert state2.params["w"].dtype == jnp.float32   # master stays f32


def test_warmup_schedule():
    cfg = AdamWConfig(lr=1e-2, warmup_steps=10)
    assert float(cfg.schedule(jnp.asarray(1))) < 1e-2 * 0.2
    assert np.isclose(float(cfg.schedule(jnp.asarray(10))), 1e-2)
    assert np.isclose(float(cfg.schedule(jnp.asarray(100))), 1e-2)
