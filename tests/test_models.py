"""Per-architecture smoke tests: reduced configs, forward/train/decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, cells, get_config
from repro.models import build
from repro.train import AdamWConfig, init_state, make_train_step


def _batch(cfg, B=2, S=64, key=0):
    k = jax.random.key(key)
    if cfg.modality == "audio":
        tokens = jax.random.randint(k, (B, S, cfg.n_codebooks), 0,
                                    cfg.vocab)
    else:
        tokens = jax.random.randint(k, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.rope_style == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None, :], (3, B, S))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_config(arch).smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    loss, metrics = model.loss(params, _batch(cfg))
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch).smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    B = 2
    cache = model.init_cache(B, max_len=32)
    tok = (jnp.zeros((B, 1, cfg.n_codebooks), jnp.int32)
           if cfg.modality == "audio" else jnp.zeros((B, 1), jnp.int32))
    pos = (jnp.zeros((3, B, 1), jnp.int32) if cfg.rope_style == "mrope"
           else jnp.zeros((B, 1), jnp.int32))
    logits, cache2 = model.decode_step(params, cache, tok, pos)
    assert jnp.isfinite(logits).all(), arch
    assert logits.shape[:2] == (B, 1)
    # cache structure preserved
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(cache2))


@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v2-lite-16b",
                                  "rwkv6-7b", "zamba2-7b"])
def test_train_step_reduces_loss(arch):
    """A few optimizer steps on repeated data must reduce the loss."""
    cfg = get_config(arch).smoke()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=1, weight_decay=0.0)
    state = init_state(params, opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg))
    b = _batch(cfg, B=2, S=32)
    batch = jax.tree.map(lambda a: a[None], b)   # accum axis of 1
    losses = []
    for i in range(8):
        state, metrics = step(state, batch, i * 0 + 1)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses


def test_param_counts_match_published():
    expected = {
        "grok-1-314b": (300e9, 330e9),
        "deepseek-v2-lite-16b": (14e9, 17e9),
        "qwen2-vl-2b": (1.4e9, 2.3e9),     # backbone only (frontend stub)
        "musicgen-large": (2.0e9, 3.5e9),  # backbone only
        "llama3-8b": (7.5e9, 8.5e9),
        "qwen3-8b": (7.5e9, 8.7e9),
        "gemma-7b": (8.0e9, 9.0e9),
        "starcoder2-3b": (2.8e9, 3.5e9),
        "rwkv6-7b": (7.0e9, 8.2e9),
        "zamba2-7b": (6.3e9, 7.7e9),
    }
    for arch, (lo, hi) in expected.items():
        n = build(get_config(arch)).n_params()
        assert lo <= n <= hi, (arch, n)


def test_cell_assignment():
    """40 assigned cells: 32 runnable + 8 long_500k skips."""
    flat = [(a, s, ok) for a in ARCH_IDS for s, ok in cells(a).items()]
    assert len(flat) == 40
    assert sum(ok for _, _, ok in flat) == 34 - 2  # 32 runnable
    skips = [(a, s) for a, s, ok in flat if not ok]
    assert all(s == "long_500k" for _, s in skips)
    assert len(skips) == 8
