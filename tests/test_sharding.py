"""Sharding rules: divisibility fallbacks, spec construction, and an
actual tiny-mesh pjit in a subprocess."""
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.models.base import ShardCtx

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


class FakeMesh:
    """Quacks enough like a Mesh for ShardCtx.spec (shape lookups)."""
    def __init__(self, **axes):
        self.shape = dict(axes)


def _rules():
    return {
        "batch": ("pod", "data"),
        "heads": "model",
        "kv": "model",
        "head_dim": "model",
        "mlp": "model",
        "experts": "model",
        "vocab": "model",
        "layers": None,
    }


def test_divisible_axes_shard():
    ctx = ShardCtx(FakeMesh(pod=2, data=16, model=16), _rules())
    spec = ctx.spec((4096, 32, 128), ("embed", "heads", None))
    assert tuple(spec) == (None, "model", None)
    spec = ctx.spec((256, 4096), ("batch", None))
    assert tuple(spec) == (("pod", "data"), None)


def test_indivisible_axis_falls_back_to_replication():
    ctx = ShardCtx(FakeMesh(data=16, model=16), _rules())
    # kv=2 cannot shard 16 ways -> head_dim picks up the model axis.
    spec = ctx.spec((128, 32768, 2, 128),
                    ("batch", None, "kv", "head_dim"))
    assert tuple(spec) == (("pod", "data"), None, None, "model") or \
        tuple(spec) == (("data",), None, None, "model") or \
        tuple(spec)[2:] == (None, "model")


def test_axis_never_used_twice():
    ctx = ShardCtx(FakeMesh(data=16, model=16), _rules())
    # experts=64 grabs "model"; moe hidden must then replicate.
    rules = dict(_rules(), moe_mlp="model")
    ctx = ShardCtx(FakeMesh(data=16, model=16), rules)
    spec = ctx.spec((64, 2048, 1408), ("experts", "embed", "moe_mlp"))
    assert tuple(spec) == ("model", None, None)
    # experts=8 does NOT divide 16 -> hidden gets the axis instead.
    spec = ctx.spec((8, 6144, 32768), ("experts", "embed", "moe_mlp"))
    assert tuple(spec) == (None, None, "model")


def test_batch_one_replicates():
    ctx = ShardCtx(FakeMesh(data=16, model=16), _rules())
    spec = ctx.spec((1, 524288), ("batch", None))
    assert tuple(spec) == (None, None)


def test_null_ctx_noop():
    from repro.models.base import NULL_CTX
    import jax.numpy as jnp
    x = jnp.zeros((4, 4))
    assert NULL_CTX.constrain(x, "batch", None) is x


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models import ShardCtx, build
    from repro.sharding.rules import merged_rules, param_rules, opt_rules
    from repro.train import AdamWConfig, init_state, make_train_step

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("llama3-8b").smoke()
    rules = merged_rules(mesh)
    ctx = ShardCtx(mesh, rules)
    model = build(cfg, ctx)
    params = model.init(jax.random.key(0))
    p_sh = ShardCtx(mesh, param_rules(mesh)).param_shardings(
        jax.tree.map(lambda a: a, model.decls(), is_leaf=lambda x: hasattr(x, "axes")))
    params = jax.tree.map(jax.device_put, params, p_sh)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1)
    state = init_state(params, opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg))
    toks = jax.random.randint(jax.random.key(1), (1, 4, 64), 0, cfg.vocab)
    losses = []
    for i in range(4):
        state, m = step(state, {"tokens": toks}, 1)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    # result matches single-device execution
    print("PJIT_OK", losses[0], losses[-1])
""")


@pytest.mark.slow
def test_pjit_train_step_on_debug_mesh():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                       capture_output=True, text=True, timeout=600)
    assert "PJIT_OK" in r.stdout, (r.stdout[-2000:], r.stderr[-3000:])


CP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.attention import chunked_attention, decode_attention
    from repro.models.base import ShardCtx, NULL_CTX
    from repro.sharding.rules import merged_rules

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ShardCtx(mesh, merged_rules(mesh))
    rng = np.random.default_rng(0)
    B, S, H, D = 4, 256, 6, 16     # H % 4 != 0 -> context-parallel mode
    q, k, v = (jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
               for _ in range(3))
    ref = chunked_attention(q, k, v, scale=0.25, q_chunk=64, k_chunk=64,
                            ctx=NULL_CTX)
    got = jax.jit(lambda a, b, c: chunked_attention(
        a, b, c, scale=0.25, q_chunk=64, k_chunk=64, ctx=ctx),
        in_shardings=(NamedSharding(mesh, P("data")),) * 3)(q, k, v)
    assert float(jnp.abs(got - ref).max()) < 2e-2

    B2, Smax, Hkv, hd = 4, 64, 2, 16   # head_dim-sharded decode cache
    q2 = jnp.asarray(rng.normal(size=(B2, 1, 4, hd)), jnp.float32)
    kc, vc = (jnp.asarray(rng.normal(size=(B2, Smax, Hkv, hd)),
                          jnp.float32) for _ in range(2))
    ln = jnp.full((B2,), 33, jnp.int32)
    ref2 = decode_attention(q2, kc, vc, ln, scale=0.25, ctx=None)
    got2 = jax.jit(lambda a, b, c, d: decode_attention(
        a, b, c, d, scale=0.25, ctx=ctx))(q2, kc, vc, ln)
    assert float(jnp.abs(got2 - ref2).max()) < 2e-2
    print("CP_AND_DECODE_OK")
""")


@pytest.mark.slow
def test_context_parallel_and_sharded_decode_numerics():
    """The perf-iteration attention paths (context-parallel q chunks,
    shard_map'd hd-sharded decode) must match the serial reference on a
    real multi-device mesh."""
    r = subprocess.run([sys.executable, "-c", CP_SCRIPT],
                       env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                       capture_output=True, text=True, timeout=600)
    assert "CP_AND_DECODE_OK" in r.stdout, (r.stdout[-2000:],
                                            r.stderr[-3000:])
