"""Table 4 reproduction: energy / area / GOPS metrics of IMPACT.

Paper anchors: programming 139 nJ/pulse, erase 0.8 pJ/pulse, read LCS
3.2e-5 pJ / HCS 0.05 pJ, 67.99 pJ/datapoint (clause tile 500x1568),
16.22 pJ/datapoint (class tile 10x500), 5.76 pJ/column worst case,
413.6 GOPS, areas 2.477 / 0.016 mm^2.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, trained_mnist_cotm

from repro.impact import (IMPACTConfig, RuntimeSpec, build_system,
                          energy as energy_mod)
from repro.impact.yflash import (G_HCS_BOOL, I_CSA_THRESHOLD, T_READ, V_READ,
                                 read_current)

PAPER = {
    "program_nj_per_pulse": 139.0,
    "erase_pj_per_pulse": 0.8,
    "read_hcs_pj": 0.05,
    "read_lcs_pj": 3.2e-5,
    "clause_pj_per_datapoint": 67.99,
    "class_pj_per_datapoint": 16.22,
    "energy_per_op_pj": 5.76,
    "gops": 413.6,
    "area_clause_mm2": 2.477,
    "area_class_mm2": 0.016,
}


def main() -> None:
    cfg, params, lits, labels, sw_acc = trained_mnist_cotm()
    t0 = time.time()
    system = build_system(params, cfg, jax.random.key(3))
    t_build = (time.time() - t0) * 1e6

    # Per-pulse energies (model constants vs paper).
    emit("table4/program_nJ_per_pulse", t_build,
         f"ours={energy_mod.E_PROGRAM_PULSE * 1e9:.1f};paper="
         f"{PAPER['program_nj_per_pulse']}")
    emit("table4/erase_pJ_per_pulse", 0.0,
         f"ours={energy_mod.E_ERASE_PULSE * 1e12:.2f};paper="
         f"{PAPER['erase_pj_per_pulse']}")
    # Single-cell read energies.
    e_hcs = float(V_READ * read_current(jnp.asarray(2.5e-6)) * T_READ)
    e_lcs = float(V_READ * read_current(jnp.asarray(1e-9)) * T_READ)
    emit("table4/read_HCS_pJ", 0.0,
         f"ours={e_hcs * 1e12:.3f};paper={PAPER['read_hcs_pj']}")
    emit("table4/read_LCS_pJ", 0.0,
         f"ours={e_lcs * 1e12:.1e};paper={PAPER['read_lcs_pj']}")

    # Worst case column op: 2048 cells all HCS, all driven.
    g_col = jnp.full((2048, 1), 2.5e-6)
    i_col = float(read_current(g_col).sum() * 1.0)
    e_col = i_col * V_READ * T_READ
    emit("table4/energy_per_op_pJ_worstcase", 0.0,
         f"ours={e_col * 1e12:.2f};paper={PAPER['energy_per_op_pj']};"
         "note=ideal-sum; paper measures 5.76 with parasitic sublinearity")

    # Inference energy per datapoint on the trained system: the staged
    # oracle measurement, then the in-kernel fused meters re-measuring
    # the same physics from a single fused pass.
    staged = system.compile(RuntimeSpec(metering="staged"))
    t0 = time.time()
    res = staged.infer_with_report(lits[:512])
    dt = (time.time() - t0) * 1e6 / 512
    preds, report = res.predictions, res.report
    hw_acc = float((np.asarray(preds) == labels[:512]).mean())
    emit("table4/clause_pJ_per_datapoint", dt,
         f"ours={report.clause_energy_j / 512 * 1e12:.2f};"
         f"paper={PAPER['clause_pj_per_datapoint']}")
    emit("table4/class_pJ_per_datapoint", dt,
         f"ours={report.class_energy_j / 512 * 1e12:.2f};"
         f"paper={PAPER['class_pj_per_datapoint']}")
    emit("table4/gops", dt,
         f"ours={report.gops:.1f};paper={PAPER['gops']}")
    emit("table4/tops_per_w", dt, f"ours={report.tops_per_w:.2f};paper=24.56")

    # metering="fused": the Table 4 anchors must come out of the fused
    # kernel's VMEM meters too — same joules, one pass, no staged rerun.
    fused = system.compile(RuntimeSpec(metering="fused"))
    t0 = time.time()
    res_f = fused.infer_with_report(lits[:512])
    dt_f = (time.time() - t0) * 1e6 / 512
    rep_f = res_f.report
    np.testing.assert_array_equal(np.asarray(res_f.predictions),
                                  np.asarray(preds))
    np.testing.assert_allclose(rep_f.clause_energy_j,
                               report.clause_energy_j, rtol=1e-4)
    np.testing.assert_allclose(rep_f.class_energy_j,
                               report.class_energy_j, rtol=1e-4)
    np.testing.assert_allclose(rep_f.tops_per_w, report.tops_per_w,
                               rtol=1e-4)
    emit("table4/clause_pJ_per_datapoint_fused", dt_f,
         f"ours={rep_f.clause_energy_j / 512 * 1e12:.2f};"
         f"staged={report.clause_energy_j / 512 * 1e12:.2f};"
         f"paper={PAPER['clause_pj_per_datapoint']}")
    emit("table4/class_pJ_per_datapoint_fused", dt_f,
         f"ours={rep_f.class_energy_j / 512 * 1e12:.2f};"
         f"staged={report.class_energy_j / 512 * 1e12:.2f};"
         f"paper={PAPER['class_pj_per_datapoint']}")
    emit("table4/tops_per_w_fused", dt_f,
         f"ours={rep_f.tops_per_w:.2f};paper=24.56")

    areas = system.area_mm2()
    emit("table4/area_clause_mm2", 0.0,
         f"ours={areas['clause']:.3f};paper={PAPER['area_clause_mm2']}")
    emit("table4/area_class_mm2", 0.0,
         f"ours={areas['class_']:.4f};paper={PAPER['area_class_mm2']}")
    emit("table4/accuracy", 0.0,
         f"sw={sw_acc:.3f};hw={hw_acc:.3f};paper=0.963")


if __name__ == "__main__":
    main()
