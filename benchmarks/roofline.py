"""Roofline analysis: three terms per (arch x shape x mesh) cell.

Sources (from the dry-run artifacts in ``artifacts/dryrun``):

* ``cost``        — compiled.cost_analysis() verbatim (brief-literal; NOTE:
  XLA visits each while body once, so scan-over-layers flops appear /L).
* ``weighted``    — execution-weighted reanalysis of the optimized HLO
  (launch/hlo.py): dot flops, fusion-boundary HBM traffic and collective
  link bytes multiplied by known_trip_count through the call graph.  The
  flops and collective terms are authoritative; the HBM term is an UPPER
  bound on TPU (XLA-CPU materializes f32 attention intermediates that a
  TPU flash fusion keeps in VMEM), so we also report an analytic floor
  (params + caches + layer-boundary activations).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

  compute    t_c = flops_per_device / 197e12
  memory     t_m = hbm_bytes_per_device / 819e9
  collective t_x = link_bytes_per_device / 50e9

MODEL_FLOPS = 6 N_act D (train) / 2 N_act D (inference) + explicit
attention terms; the ratio MODEL_FLOPS / HLO_flops exposes remat recompute,
causal-mask waste and replicated attention (heads % 16 != 0).

``impact_roofline`` is the IMPACT-session variant: it places every
compiled session executable on the same v5e roofline from XLA's
cost-analysis counters and records the achieved fraction against the
measured throughput sweep (``benchmarks/impact_throughput.py`` embeds
it as the ``roofline`` section of ``BENCH_throughput.json``;
``check_perf.py`` requires the section but does not gate its values).
"""
from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

ARTIFACTS = pathlib.Path(__file__).resolve().parents[1] / "artifacts"

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / link


def n_active(cfg) -> float:
    """Active (per-token matmul) params: excludes the embedding gather and
    scales routed experts by top_k/E (x capacity factor)."""
    from repro.models import build
    model = build(cfg)
    n = float(model.n_params())
    n -= cfg.vocab * cfg.d_model * (cfg.n_codebooks
                                    if cfg.modality == "audio" else 1)
    if cfg.moe is not None:
        m = cfg.moe
        routed = (cfg.n_layers - m.first_dense_layers) * 3 * \
            m.n_experts * cfg.d_model * m.d_ff_expert
        active_frac = min(1.0, m.top_k * m.capacity_factor / m.n_experts)
        n -= routed * (1.0 - active_frac)
    return n


def attn_dims(cfg):
    """(L_attn, H, qk_dim, v_dim) for the full-attention component."""
    if cfg.ssm is not None and cfg.hybrid_attn_every == 0:
        return 0, 0, 0, 0                     # rwkv6: attention-free
    if cfg.hybrid_attn_every:
        L = cfg.n_layers // cfg.hybrid_attn_every
        hd = 2 * cfg.d_model // cfg.n_heads
        return L, cfg.n_heads, hd, hd
    if cfg.mla is not None:
        return (cfg.n_layers, cfg.n_heads,
                cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim,
                cfg.mla.v_head_dim)
    return cfg.n_layers, cfg.n_heads, cfg.resolved_head_dim, \
        cfg.resolved_head_dim


def model_flops(cfg, shape, n_chips: int) -> float:
    """Useful (model) flops per device per step."""
    N = n_active(cfg)
    B, S = shape.global_batch, shape.seq_len
    L, H, qk, vd = attn_dims(cfg)
    if shape.kind == "train":
        tokens = B * S
        mm = 6.0 * N * tokens
        attn = 3.0 * tokens * (2 * (S / 2) * H * (qk + vd)) * L
    elif shape.kind == "prefill":
        tokens = B * S
        mm = 2.0 * N * tokens
        attn = tokens * (2 * (S / 2) * H * (qk + vd)) * L
    else:  # decode: one token per sequence against an S-token context
        tokens = B
        mm = 2.0 * N * tokens
        attn = tokens * (2 * S * H * (qk + vd)) * L
    return (mm + attn) / n_chips


def analytic_memory_floor(cfg, shape, n_chips: int) -> float:
    """Per-device HBM bytes that MUST move: params (bf16) once + cache
    read/write (decode) or boundary activations (train/prefill)."""
    from repro.models import build
    model = build(cfg)
    n = float(model.n_params())
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        # fwd+bwd touch params ~3x (f32 master+grad) + boundary acts.
        acts = cfg.n_layers * B * S * cfg.d_model * 2
        return (3 * 4 * n + acts) / n_chips
    if shape.kind == "prefill":
        acts = cfg.n_layers * B * S * cfg.d_model * 2
        return (2 * n + acts) / n_chips
    # decode: whole cache is read once per step + params.
    import jax
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache))
    return (2 * n + cache_bytes) / n_chips


def load_cells(mesh_dir: str):
    d = ARTIFACTS / "dryrun" / mesh_dir
    out = []
    for p in sorted(d.glob("*.json")):
        out.append(json.loads(p.read_text()))
    return out


def analyze(mesh_dir: str = "16x16"):
    from repro.configs import get_config
    from repro.models.config import SHAPES
    n_chips = 512 if mesh_dir == "2x16x16" else 256
    rows = []
    for rec in load_cells(mesh_dir):
        if "skipped" in rec or "error" in rec:
            rows.append(dict(arch=rec["arch"], shape=rec["shape"],
                             skipped=rec.get("skipped",
                                             rec.get("error", ""))[:60]))
            continue
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        w = rec.get("weighted", {})
        flops = w.get("flops_weighted", 0.0)
        hbm = w.get("hbm_bytes_weighted", 0.0)
        coll = w.get("collective_link_bytes_weighted", 0.0)
        t_c = flops / PEAK_FLOPS
        t_m = hbm / HBM_BW
        mf = model_flops(cfg, shape, n_chips)
        floor = analytic_memory_floor(cfg, shape, n_chips)
        t_m_floor = floor / HBM_BW
        t_x = coll / LINK_BW
        terms = {"compute": t_c, "memory(floor)": t_m_floor,
                 "collective": t_x}
        dominant = max(terms, key=terms.get)
        rows.append(dict(
            arch=rec["arch"], shape=rec["shape"], kind=rec["kind"],
            flops=flops, hbm_upper=hbm, hbm_floor=floor, coll=coll,
            t_compute=t_c, t_mem_upper=t_m, t_mem_floor=t_m_floor,
            t_coll=t_x, dominant=dominant,
            model_flops=mf,
            useful_ratio=(mf / flops if flops else 0.0),
            cost_flops=rec.get("cost", {}).get("flops", 0.0),
            cost_bytes=rec.get("cost", {}).get("bytes accessed", 0.0),
            mem_args_gib=rec.get("memory", {}).get(
                "argument_size_in_bytes", 0) / 2**30,
            mem_temp_gib=rec.get("memory", {}).get(
                "temp_size_in_bytes", 0) / 2**30,
        ))
    return rows


# -- IMPACT session roofline -------------------------------------------------

def impact_roofline(system, throughput: dict, *, batch_sizes,
                    entry: str = "predict") -> dict:
    """Roofline placement of the compiled IMPACT sessions: per
    (backend, batch) executable, XLA's own flops / bytes_accessed
    counters -> arithmetic intensity, the TPU-v5e roofline bound on
    samples/s, and the achieved fraction against the measured sweep.

    Recorded, NOT gated: CI runs the kernels in interpret mode on CPU,
    so achieved fractions are tiny and only the *shape* of the record
    (intensity, bound side) is meaningful there.  On a real TPU the
    same record becomes the optimization scoreboard.  ``throughput`` is
    the ``results`` dict of ``throughput_sweep`` (measured samples/s
    looked up per ``{impl}_b{B}`` key; missing keys record null).
    """
    from repro.impact import RuntimeSpec
    rows = {}
    for impl in ("xla", "pallas"):
        session = system.compile(RuntimeSpec(backend=impl, metering="off"))
        for B in batch_sizes:
            ca = session.cost_analysis(entry, B)
            flops, nbytes = ca["flops"], ca["bytes_accessed"]
            t_c = flops / PEAK_FLOPS
            t_m = nbytes / HBM_BW
            t_bound = max(t_c, t_m)
            measured = throughput.get(f"{impl}_b{B}", {}).get("samples_per_s")
            rows[f"{impl}_b{B}"] = dict(
                flops=flops, bytes_accessed=nbytes,
                operand_bytes=session.input_bytes(entry, B),
                intensity_flops_per_byte=(flops / nbytes if nbytes else 0.0),
                bound_side=("compute" if t_c >= t_m else "memory"),
                roofline_bound_samples_per_s=(B / t_bound if t_bound
                                              else 0.0),
                measured_samples_per_s=measured,
                achieved_fraction=(measured * t_bound / B
                                   if measured and t_bound else None),
            )
    return dict(peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW, entry=entry,
                sessions=rows)


LEVERS = {
    "compute": "raise MFU: cut causal-mask waste / replicated attention "
               "(shard head_dim or context), larger chunk matmuls",
    "memory(floor)": "raise arithmetic intensity: quantize cache/params, "
                     "fuse reads, bigger per-step batch",
    "collective": "cut link bytes: reduce-scatter instead of all-gather, "
                  "EP all-to-all combine, overlap with compute",
}


def main() -> None:
    for mesh in ("16x16", "2x16x16"):
        rows = analyze(mesh)
        print(f"roofline/{mesh},0.0,cells={len(rows)}")
        for r in rows:
            if "skipped" in r:
                print(f"roofline/{mesh}/{r['arch']}/{r['shape']},0.0,"
                      f"SKIP:{r['skipped']}")
                continue
            print(
                f"roofline/{mesh}/{r['arch']}/{r['shape']},0.0,"
                f"t_c={r['t_compute']:.3f}s;t_m_floor={r['t_mem_floor']:.3f}s;"
                f"t_m_upper={r['t_mem_upper']:.3f}s;t_x={r['t_coll']:.3f}s;"
                f"dominant={r['dominant']};useful={r['useful_ratio']:.2f}")
    # also dump a machine-readable summary for EXPERIMENTS.md generation
    out = {m: analyze(m) for m in ("16x16", "2x16x16")}
    (ARTIFACTS / "roofline.json").write_text(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
