"""CI perf gate: fail when samples/s regresses against a committed baseline.

Usage:
    python benchmarks/check_perf.py CURRENT.json BASELINE.json \
        [--max-regression 0.30] [--serve BENCH_serve.json] \
        [--train BENCH_train.json]

Compares the ``normalized`` samples/s ratios of ``BENCH_throughput.json``
(each path's samples/s divided by its impl family's in-run reference at
the smallest batch) rather than raw samples/s: a machine-speed difference
between the baseline machine and the CI runner cancels out within a
family (Pallas interpret mode and multithreaded XLA scale differently
with core count, so families are never cross-ratioed), while a
batch-scaling or engine-overhead regression local to one path does not.
A key is a failure when its ratio drops more than ``--max-regression``
(default 30%) below baseline, or when it disappears from the current
run.  A cpu-count mismatch between baseline and current machines is
printed as a warning — if the runner class changes, refresh
``benchmarks/baselines/`` from the perf-smoke artifact of a trusted run.

With ``--serve`` the gate also enforces the continuous-batching
acceptance invariant recorded in ``BENCH_serve.json``: continuous p95
per-request latency strictly below flush-to-completion p95 on the same
Poisson trace.

The ``metered`` section (always produced) is gated on three invariants:
it must exist, the fused-metered and staged-metered runs must have
agreed on argmax and per-lane joules (``parity_ok``), and fused-metered
throughput must stay within a generous floor of the unmetered fused
kernel (the in-kernel meter's whole point is that billing is nearly
free; a collapse of that ratio is a regression even when every absolute
number moved).  The fused/staged ratio is printed for the record — on
CPU interpret mode it gauges dispatch plumbing, not TPU speed.

The ``compressed`` section (always produced) gates the bit-packed
datapath: packed-backend argmax parity against both the int8 fused
kernel and the einsum oracle, per-batch int8/packed byte-traffic ratios
(XLA ``bytes_accessed`` AND the exact operand ``input_bytes``) at a
>= 4x floor, and a non-degenerate clause-pruning record.

The ``predicted_vs_measured`` section (always produced) is the
calibrated analytic cost model's self-check: every session executable's
predicted sweep time must land within the recorded band of its measured
warm-sweep time, and the raw executable-cost ordering invariants
(metered >= unmetered) must hold — a flip means the lowering lost the
in-kernel meter.

When the current run carries a ``sharded`` section (multi-device hosts:
the CI multi-device leg runs the benchmark under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``), the gate also
requires argmax parity between the shard_map crossbar lowering and the
single-device kernel, and prints the sharded/single throughput ratios.

Stdlib-only on purpose — runs before (and regardless of) the jax install.
"""
from __future__ import annotations

import argparse
import json
import sys


def check_throughput(current: dict, baseline: dict,
                     max_regression: float) -> list[str]:
    failures = []
    cur = current.get("normalized", {})
    base = baseline.get("normalized", {})
    if not base:
        failures.append("baseline has no 'normalized' section")
    b_cpu = baseline.get("machine", {}).get("cpu_count")
    c_cpu = current.get("machine", {}).get("cpu_count")
    if b_cpu != c_cpu:
        print(f"  WARNING: baseline machine had cpu_count={b_cpu}, this "
              f"run has {c_cpu} — within-family ratios should still hold, "
              f"but refresh the baseline if the runner class changed")
    for key, b in sorted(base.items()):
        c = cur.get(key)
        if c is None:
            failures.append(f"{key}: missing from current run")
            continue
        floor = b * (1.0 - max_regression)
        verdict = "FAIL" if c < floor else "ok"
        print(f"  {key:24s} baseline {b:8.3f}  current {c:8.3f}  "
              f"floor {floor:8.3f}  {verdict}")
        if c < floor:
            failures.append(
                f"{key}: normalized samples/s {c:.3f} < floor {floor:.3f} "
                f"(baseline {b:.3f}, max regression {max_regression:.0%})")
    return failures


def check_sharded(current: dict) -> list[str]:
    """Gate the sharded-vs-single-device sweep when this run produced one
    (multi-device hosts; the CI multi-device leg).  Argmax parity between
    the shard_map lowering and the single-device kernel is a hard
    invariant; throughput ratios are printed for the record but not
    floored against a baseline (host-device psum overhead on CPU says
    nothing about TPU ICI behaviour)."""
    sharded = current.get("sharded")
    if not sharded:
        print("  (no sharded sweep in this run: single-device host)")
        return []
    mesh = sharded.get("mesh", {})
    print(f"  sharded sweep: {sharded.get('n_devices')} devices, "
          f"mesh {mesh}, grid {sharded.get('grid')}")
    for b, ratio in sorted(
            sharded.get("speedup_sharded_over_single", {}).items(),
            key=lambda kv: int(kv[0].lstrip("b"))):
        print(f"    {b:8s} sharded/single samples/s ratio {ratio:8.3f}")
    if not sharded.get("parity_ok"):
        return ["sharded sweep: shard_map predictions diverged from the "
                "single-device kernel (parity_ok is false)"]
    return []


def check_roofline(current: dict) -> list[str]:
    """Require the roofline section (every run must place its compiled
    executables on the roofline) and print it for the record — the
    values themselves are NOT gated: CI interpret-mode-on-CPU achieved
    fractions say nothing about TPU behaviour, the section exists so
    the scoreboard is never silently dropped."""
    roof = current.get("roofline")
    if not roof:
        return ["roofline: section missing from BENCH_throughput.json "
                "(benchmarks/roofline.py impact_roofline must run in "
                "every sweep)"]
    sessions = roof.get("sessions", {})
    if not sessions:
        return ["roofline: section has no per-session rows"]
    bad = [k for k, r in sessions.items()
           if not all(key in r for key in
                      ("intensity_flops_per_byte", "bound_side",
                       "roofline_bound_samples_per_s"))]
    if bad:
        return [f"roofline: malformed rows (missing keys): {sorted(bad)}"]
    print(f"  roofline ({roof.get('entry')}, peak {roof.get('peak_flops'):.3g}"
          f" flop/s, hbm {roof.get('hbm_bw'):.3g} B/s):")
    for key, r in sorted(sessions.items()):
        ach = r.get("achieved_fraction")
        print(f"    {key:14s} intensity {r['intensity_flops_per_byte']:8.2f} "
              f"flop/B  bound={r['bound_side']:7s} "
              f"cap {r['roofline_bound_samples_per_s']:12.3e} samples/s  "
              f"achieved {'n/a' if ach is None else f'{ach:.2e}'}")
    return []


def check_metered(current: dict, min_fused_ratio: float = 0.25) -> list[str]:
    """Gate the in-kernel-metering sweep: the section is mandatory (the
    benchmark always produces it), the fused and staged meters must have
    agreed (argmax + per-lane joules), and the fused-metered kernel must
    hold a sane fraction of unmetered-fused throughput.  The floor is
    deliberately loose — CPU interpret mode prices kernel dispatch, not
    the TPU meter — but a collapse below it means the metered path fell
    off the fused kernel entirely."""
    metered = current.get("metered")
    if not metered:
        return ["metered sweep missing from BENCH_throughput.json "
                "(benchmarks.impact_throughput must always produce it)"]
    failures = []
    for b, ratio in sorted(
            metered.get("ratio_fused_metered_over_unmetered", {}).items(),
            key=lambda kv: int(kv[0].lstrip("b"))):
        verdict = "FAIL" if ratio < min_fused_ratio else "ok"
        print(f"  metered {b:6s} fused/unmetered samples/s ratio "
              f"{ratio:6.3f}  floor {min_fused_ratio:.2f}  {verdict}")
        if ratio < min_fused_ratio:
            failures.append(
                f"metered {b}: fused-metered throughput fell to "
                f"{ratio:.3f}x of the unmetered fused kernel "
                f"(floor {min_fused_ratio})")
    for b, ratio in sorted(
            metered.get("ratio_fused_metered_over_staged", {}).items(),
            key=lambda kv: int(kv[0].lstrip("b"))):
        print(f"  metered {b:6s} fused/staged    samples/s ratio "
              f"{ratio:6.3f}  (for the record)")
    if not metered.get("parity_ok"):
        failures.append(
            "metered sweep: fused-metered argmax or per-lane joules "
            "diverged from the staged oracle (parity_ok is false)")
    return failures


def check_compressed(current: dict, min_bytes_ratio: float = 4.0) -> list[str]:
    """Gate the compressed-datapath sweep: the section is mandatory (the
    benchmark always produces it), the packed backend must have agreed
    on argmax with both the int8 fused kernel and the einsum oracle
    (``parity_ok``), and BOTH byte-traffic ratios must clear the 4x
    floor per batch — XLA ``bytes_accessed`` (what the compiled sweep
    touches) and the exact operand footprint ``input_bytes``.  They fail
    differently: a packing pass that dequantizes outside the kernel
    keeps operands small but restores the full in-kernel traffic; an
    operand-layout regression does the reverse.  The pruning record must
    carry a positive effective-clause count and packed-backend parity on
    its calibration batch."""
    comp = current.get("compressed")
    if not comp:
        return ["compressed sweep missing from BENCH_throughput.json "
                "(benchmarks.impact_throughput must always produce it)"]
    failures = []
    for b, c in sorted(comp.get("cost_analysis", {}).items(),
                       key=lambda kv: int(kv[0].lstrip("b"))):
        for metric in ("ratio_bytes_accessed", "ratio_input_bytes"):
            ratio = c.get(metric)
            ok = ratio is not None and ratio >= min_bytes_ratio
            shown = "missing" if ratio is None else f"{ratio:7.3f}"
            print(f"  compressed {b:6s} int8/packed {metric:21s} {shown}  "
                  f"floor {min_bytes_ratio:.2f}  {'ok' if ok else 'FAIL'}")
            if not ok:
                failures.append(
                    f"compressed {b}: {metric} {shown} below the "
                    f"{min_bytes_ratio}x floor — the packed clause "
                    f"operand is not shrinking sweep traffic")
    if not comp.get("cost_analysis"):
        failures.append("compressed sweep has no cost_analysis record")
    if not comp.get("parity_ok"):
        failures.append(
            "compressed sweep: packed-backend argmax diverged from the "
            "int8 kernel or the einsum oracle (parity_ok is false)")
    pr = comp.get("pruning", {})
    print(f"  compressed pruning: {pr.get('n_effective', '?')}/"
          f"{pr.get('n_clauses', '?')} clauses effective "
          f"({pr.get('n_never_fired', '?')} never fired, "
          f"{pr.get('n_duplicates', '?')} duplicates), "
          f"{pr.get('energy_per_effective_clause_j', 0.0):.3e} J per "
          f"effective clause per datapoint")
    if pr.get("n_effective", 0) <= 0:
        failures.append(
            "compressed pruning: no effective clauses survived the "
            "calibration batch (degenerate pruning record)")
    if not pr.get("packed_parity_on_calibration"):
        failures.append(
            "compressed pruning: pruned-system packed predictions "
            "diverged from the einsum oracle on the calibration batch")
    return failures


def check_cost_model(current: dict) -> list[str]:
    """Gate the calibrated cost model's predicted-vs-measured section:
    the section is mandatory (the benchmark always produces it), every
    entry's predicted/measured ratio must sit inside the recorded band,
    and the raw-cost ordering invariants carrying a ``must_be_at_least``
    floor hard-fail on a flip (a metered kernel whose executable costs
    *less* than the unmetered one has lost its meter — a sign error no
    throughput floor can see)."""
    pvm = current.get("predicted_vs_measured")
    if not pvm:
        return ["predicted_vs_measured section missing from "
                "BENCH_throughput.json (benchmarks.impact_throughput "
                "must always produce it)"]
    failures = []
    lo, hi = pvm.get("band", (0.0, float("inf")))
    for key, e in sorted(pvm.get("entries", {}).items()):
        ratio = e["ratio_pred_over_meas"]
        ok = lo <= ratio <= hi
        ref = " (calibration ref)" if e.get("calibration_ref") else ""
        print(f"  costmodel {key:28s} pred/meas {ratio:7.3f}  "
              f"band [{lo:.2f}, {hi:.2f}]  "
              f"{'ok' if ok else 'FAIL'}{ref}")
        if not ok:
            failures.append(
                f"cost model {key}: predicted/measured ratio {ratio:.3f} "
                f"outside band [{lo}, {hi}]")
    if not pvm.get("entries"):
        failures.append("predicted_vs_measured has no entries")
    for key, o in sorted(pvm.get("orderings", {}).items()):
        ratio = o["raw_cost_ratio"]
        floor = o.get("must_be_at_least")
        if floor is None:
            print(f"  costmodel {key:28s} raw-cost ratio {ratio:7.3f}  "
                  f"(for the record)")
            continue
        ok = ratio >= floor
        print(f"  costmodel {key:28s} raw-cost ratio {ratio:7.3f}  "
              f"floor {floor:.2f}  {'ok' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"cost model {key}: raw executable cost ratio {ratio:.3f} "
                f"< {floor} — the metered kernel prices below the "
                f"unmetered one (meter lost in lowering?)")
    return failures


def check_train(train: dict) -> list[str]:
    """Gate the online-training benchmark (``BENCH_train.json``): the
    Pallas feedback kernel must have walked a bit-identical TA trajectory
    to the einsum oracle (``parity.exact`` — the draws are precomputed
    operands, so this is equality, not a tolerance), held-out accuracy
    after the interleaved run must clear the stored floor AND improve on
    the deployment accuracy, the f64 per-update write bills must equal
    the running meter and the aggregated report lane at 1e-9, per-request
    read bills must have kept reconciling at 1e-9 while updates mutated
    the fabric, and serving-only reports must bill exactly zero write
    energy."""
    failures = []
    parity = train.get("parity", {})
    if not parity.get("exact"):
        failures.append(
            "train: ta_feedback kernel and oracle TA trajectories "
            "diverged (parity.exact is false) — the feedback primitive "
            "lost bit-exactness")
    online = train.get("online", {})
    floor = train.get("acc_floor")
    acc_b, acc_a = online.get("acc_before"), online.get("acc_after")
    if acc_a is None or floor is None:
        failures.append("train: online section missing acc_after/acc_floor")
    else:
        print(f"  train accuracy: {acc_b:.3f} -> {acc_a:.3f} after "
              f"{online.get('n_updates', '?')} updates  floor {floor:.2f}  "
              f"{'ok' if acc_a >= floor else 'FAIL'}")
        if acc_a < floor:
            failures.append(
                f"train: held-out accuracy {acc_a:.3f} after online "
                f"updates is below the floor {floor:.2f}")
        if not acc_a > acc_b:
            failures.append(
                f"train: online updates did not improve held-out accuracy "
                f"({acc_b:.3f} -> {acc_a:.3f})")
    wm = train.get("write_meter", {})
    rel = wm.get("rel_err", float("inf"))
    agg, meter = wm.get("aggregate_j"), wm.get("running_meter_j")
    print(f"  train write meter: {meter if meter is not None else '?'} J, "
          f"per-update-sum rel err {rel:.3e}")
    if not rel <= 1e-9:
        failures.append(
            f"train: f64 sum of per-update write bills drifts {rel:.3e} "
            f"from the running write meter (> 1e-9)")
    if agg != meter:
        failures.append(
            f"train: aggregated report write lane {agg} != running "
            f"meter {meter}")
    read_rel = train.get("read_billing", {}).get("max_rel_err",
                                                 float("inf"))
    if not read_rel <= 1e-9:
        failures.append(
            f"train: per-request read bills drifted {read_rel:.3e} from "
            f"the batch meter during the interleaved run (> 1e-9)")
    serving_w = train.get("serving_only", {}).get("write_energy_j")
    if serving_w != 0.0:
        failures.append(
            f"train: serving-only report bills {serving_w} J of write "
            f"energy (must be exactly 0.0)")
    return failures


def check_serve(serve: dict) -> list[str]:
    # A run where a scheduler completed nothing has no percentiles at
    # all — that is a gate failure to report, not a KeyError to crash
    # on (zero-completed BENCH_serve.json files happen when the Poisson
    # trace sheds everything, e.g. a mis-set queue_capacity).
    missing = [mode for mode in ("continuous", "flush")
               if "p95_s" not in serve.get(mode, {})]
    if missing:
        return [
            f"serve: no p95_s for {mode} (completed="
            f"{serve.get(mode, {}).get('completed', 0)}, offered="
            f"{serve.get('n_requests', '?')}) — scheduler completed "
            f"no requests" for mode in missing]
    p95_c = serve["continuous"]["p95_s"]
    p95_f = serve["flush"]["p95_s"]
    shed = serve["continuous"].get("shed", 0)
    print(f"  serve p95: continuous {p95_c * 1e3:.2f} ms, "
          f"flush {p95_f * 1e3:.2f} ms "
          f"(ratio {serve.get('p95_ratio_flush_over_continuous', 0):.2f}x)")
    failures = []
    if not p95_c < p95_f:
        failures.append(
            f"continuous p95 {p95_c:.4f}s is not below flush p95 "
            f"{p95_f:.4f}s")
    if shed:
        failures.append(f"continuous scheduler shed {shed} requests")
    return failures


def check_multi_tenant(serve: dict) -> list[str]:
    """Gate the multi-tenant model-zoo claims: co-resident argmax parity
    against the per-tenant oracle, tenant-pure billing (bill sums ==
    shared batch meter), strictly fewer fused sweeps than N independent
    per-tenant engines on the same trace, and SLO-class ordering (gold
    p99 below standard p99 — priority admission + immediate firing must
    actually buy latency)."""
    mt = serve.get("multi_tenant")
    if mt is None:
        return ["serve: BENCH_serve.json has no multi_tenant section "
                "(benchmarks/impact_throughput.py did not run the "
                "model-zoo sweep)"]
    failures = []
    mism = mt.get("parity_mismatches")
    if mism != 0:
        failures.append(
            f"multi_tenant: {mism} co-resident predictions diverge from "
            f"the per-tenant single-session oracle "
            f"(of {mt.get('parity_checked', '?')} checked)")
    rel = mt.get("billing_rel_err", float("inf"))
    if not rel < 1e-9:
        failures.append(
            f"multi_tenant: per-tenant bill sums drift {rel:.3e} from "
            f"the shared batch meter (>= 1e-9) — billing is not "
            f"tenant-pure")
    sweeps = mt.get("sweeps", {})
    co = sweeps.get("coresident", float("inf"))
    per = sweeps.get("per_tenant_engines", 0)
    if not co < per:
        failures.append(
            f"multi_tenant: co-resident serving took {co} sweeps vs "
            f"{per} for per-tenant engines — crossbar co-residency is "
            f"not coalescing work")
    slo = mt.get("per_slo", {})
    gold = slo.get("gold", {}).get("p99_s")
    std = slo.get("standard", {}).get("p99_s")
    if gold is None or std is None:
        failures.append(
            f"multi_tenant: missing per-SLO p99 (classes present: "
            f"{sorted(slo)}) — need both 'gold' and 'standard'")
    else:
        print(f"  multi-tenant p99: gold {gold * 1e3:.2f} ms, standard "
              f"{std * 1e3:.2f} ms; sweeps {co} coresident vs {per} "
              f"per-tenant engines")
        if not gold < std:
            failures.append(
                f"multi_tenant: gold p99 {gold:.4f}s is not below "
                f"standard p99 {std:.4f}s — SLO classes are not "
                f"differentiating service")
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="BENCH_throughput.json from this run")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--max-regression", type=float, default=0.30,
                    help="tolerated fractional drop in normalized "
                         "samples/s (default 0.30)")
    ap.add_argument("--serve", default=None,
                    help="BENCH_serve.json to gate the continuous-vs-flush "
                         "p95 invariant")
    ap.add_argument("--train", default=None,
                    help="BENCH_train.json to gate the online-training "
                         "parity/accuracy/write-meter invariants")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    print(f"perf gate: {args.current} vs {args.baseline} "
          f"(max regression {args.max_regression:.0%})")
    failures = check_throughput(current, baseline, args.max_regression)
    failures += check_metered(current)
    failures += check_compressed(current)
    failures += check_cost_model(current)
    failures += check_roofline(current)
    failures += check_sharded(current)
    if args.serve:
        with open(args.serve) as f:
            serve = json.load(f)
        failures += check_serve(serve)
        failures += check_multi_tenant(serve)
    if args.train:
        with open(args.train) as f:
            train = json.load(f)
        failures += check_train(train)
    if failures:
        print("\nPERF GATE FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
