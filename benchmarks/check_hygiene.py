"""CI hygiene gate: fail when generated artifacts are tracked in git.

Usage:
    python benchmarks/check_hygiene.py

Three classes of generated files must never be committed:

* compiled Python bytecode (``*.pyc`` / ``__pycache__`` directories);
* benchmark outputs under ``artifacts/`` (``BENCH_*.json`` land there on
  every run — the COMMITTED copies live in ``benchmarks/baselines/``,
  which this gate deliberately does not match);
* Chrome-tracing timelines (``*.trace.json`` anywhere — serve runs emit
  them next to the bench JSON and they are upload-artifact material, not
  repo material).

Violations print one ``::error file=...`` annotation per path so the CI
run summary links straight to the offending file.

Stdlib-only on purpose — runs in the hygiene job before (and regardless
of) any jax install.
"""
from __future__ import annotations

import re
import subprocess
import sys

#: (label, pattern) pairs; a path matching ANY pattern is a violation.
RULES: tuple[tuple[str, re.Pattern], ...] = (
    ("compiled Python bytecode",
     re.compile(r"(^|/)__pycache__(/|$)|\.pyc$")),
    ("benchmark artifact JSON",
     re.compile(r"^artifacts/.*\.json$")),
    ("Chrome-tracing timeline",
     re.compile(r"\.trace\.json$")),
)


def find_violations(paths: list[str]) -> list[tuple[str, str]]:
    """Return ``(path, label)`` for every path matching a hygiene rule."""
    bad = []
    for p in paths:
        for label, rx in RULES:
            if rx.search(p):
                bad.append((p, label))
                break
    return bad


def tracked_files() -> list[str]:
    """Every path git tracks, from the repo the cwd sits in."""
    res = subprocess.run(["git", "ls-files"], check=True,
                         capture_output=True, text=True)
    return [line for line in res.stdout.splitlines() if line]


def main() -> int:
    paths = tracked_files()
    bad = find_violations(paths)
    if bad:
        for path, label in bad:
            print(f"::error file={path}::{label} is tracked in git: {path}")
        print(f"hygiene gate FAILED: {len(bad)} tracked artifact(s)")
        return 1
    print(f"hygiene gate passed ({len(paths)} tracked files clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
