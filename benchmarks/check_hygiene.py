"""CI hygiene gate: fail when generated artifacts are tracked in git.

Usage:
    python benchmarks/check_hygiene.py

Three classes of generated files must never be committed:

* compiled Python bytecode (``*.pyc`` / ``__pycache__`` directories);
* benchmark outputs under ``artifacts/`` (``BENCH_*.json`` land there on
  every run — the COMMITTED copies live in ``benchmarks/baselines/``,
  which this gate deliberately does not match);
* Chrome-tracing timelines (``*.trace.json`` anywhere — serve runs emit
  them next to the bench JSON and they are upload-artifact material, not
  repo material).

Violations print one ``::error file=...`` annotation per path so the CI
run summary links straight to the offending file.

The gate also requires ``.gitignore`` to cover every class it polices
(``REQUIRED_IGNORES``): tracked-file checks only catch an artifact
AFTER someone commits it — the ignore line is what stops ``git add -A``
from committing it in the first place.  The serve traces sat tracked
for three releases precisely because ``.gitignore`` had no
``*.trace.json`` line while this gate only matched ``BENCH_*``.

Stdlib-only on purpose — runs in the hygiene job before (and regardless
of) any jax install.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

#: (label, pattern) pairs; a path matching ANY pattern is a violation.
RULES: tuple[tuple[str, re.Pattern], ...] = (
    ("compiled Python bytecode",
     re.compile(r"(^|/)__pycache__(/|$)|\.pyc$")),
    ("benchmark artifact JSON",
     re.compile(r"^artifacts/.*\.json$")),
    ("Chrome-tracing timeline",
     re.compile(r"\.trace\.json$")),
)

#: Every artifact class RULES polices must also be git-ignored, so the
#: artifacts cannot be committed by a bulk ``git add`` in the first
#: place.  Exact-line match against .gitignore.
REQUIRED_IGNORES: tuple[str, ...] = (
    "__pycache__/",
    "*.pyc",
    "artifacts/BENCH_*.json",
    "artifacts/STATIC_*.json",
    "*.trace.json",
)


def find_violations(paths: list[str]) -> list[tuple[str, str]]:
    """Return ``(path, label)`` for every path matching a hygiene rule."""
    bad = []
    for p in paths:
        for label, rx in RULES:
            if rx.search(p):
                bad.append((p, label))
                break
    return bad


def gitignore_gaps(gitignore_lines: list[str]) -> list[str]:
    """The REQUIRED_IGNORES entries missing from the given .gitignore
    content (comments/blank lines ignored)."""
    present = {line.strip() for line in gitignore_lines
               if line.strip() and not line.strip().startswith("#")}
    return [pat for pat in REQUIRED_IGNORES if pat not in present]


def tracked_files() -> list[str]:
    """Every path git tracks, from the repo the cwd sits in."""
    res = subprocess.run(["git", "ls-files"], check=True,
                         capture_output=True, text=True)
    return [line for line in res.stdout.splitlines() if line]


def main() -> int:
    paths = tracked_files()
    bad = find_violations(paths)
    for path, label in bad:
        print(f"::error file={path}::{label} is tracked in git: {path}")
    gaps = (gitignore_gaps(open(".gitignore").read().splitlines())
            if os.path.exists(".gitignore") else list(REQUIRED_IGNORES))
    for pat in gaps:
        print(f"::error file=.gitignore::missing ignore pattern: {pat}")
    if bad or gaps:
        print(f"hygiene gate FAILED: {len(bad)} tracked artifact(s), "
              f"{len(gaps)} missing .gitignore pattern(s)")
        return 1
    print(f"hygiene gate passed ({len(paths)} tracked files clean, "
          f"{len(REQUIRED_IGNORES)} ignore patterns present)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
