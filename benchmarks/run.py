"""Benchmark orchestrator: one section per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import traceback
import warnings


def main() -> None:
    # Benchmarks must run on the RuntimeSpec/InferenceSession API, not
    # the deprecated per-call kwargs: promote the shim warning to an
    # error here (pytest.ini does the same for the test suite) so every
    # CI leg that drives a benchmark enforces the migration.
    from repro.impact import SpecDeprecationWarning
    warnings.simplefilter("error", SpecDeprecationWarning)
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section names to run")
    args = ap.parse_args()

    from . import (fig7_8_variability, fig13_tuning_sweep, impact_throughput,
                   roofline, table4_energy, table5_datasets,
                   table6_comparison)
    sections = {
        "table4": table4_energy.main,
        "table5": table5_datasets.main,
        "table6": table6_comparison.main,
        "fig7_8": fig7_8_variability.main,
        "fig13": fig13_tuning_sweep.main,
        "roofline": roofline.main,
        "impact_throughput": impact_throughput.main,
    }
    chosen = (args.only.split(",") if args.only else list(sections))
    print("name,us_per_call,derived")
    for name in chosen:
        try:
            sections[name]()
        except Exception as e:
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{str(e)[:120]}")
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
