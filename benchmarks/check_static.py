"""CI static-analysis gate: contract lint + compiled-IR audit.

Two layers, one exit code (same contract as check_perf.py /
check_hygiene.py — 0 clean, 1 on any violation):

**Layer 2 — contract lint** (``repro.analysis.lint``, stdlib ``ast``
only, no jax needed): IMPACT001-005 over ``src/repro/**``.  Runs in the
jax-free hygiene CI job via ``--lint-only``.

**Layer 1 — IR audit** (``repro.analysis.ir_audit``, needs jax):
compiles a deterministic reference system under the representative
runtime specs (fused, staged, packed, metered, co-resident) and audits
every executable's lowered StableHLO — precision ladder (no f64, no
sub-f32 meters), host isolation (no callbacks/infeed/outfeed), Pallas
VMEM working set vs budget — and diffs each executable's op-histogram
fingerprint against ``benchmarks/baselines/IR_fingerprints.json``.
Fingerprint drift is reported as a warning (recorded, not gated): the
lowering legitimately moves across jax versions; refresh the committed
baselines with ``--update-baselines`` when a drift is intentional.

Usage:
    python benchmarks/check_static.py                # both layers
    python benchmarks/check_static.py --lint-only    # layer 2, no jax
    python benchmarks/check_static.py --hlo DUMP.mlir  # audit a raw dump
    python benchmarks/check_static.py --update-baselines
    python benchmarks/check_static.py --vmem-budget 1048576
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

BASELINES = os.path.join(REPO, "benchmarks", "baselines",
                         "IR_fingerprints.json")
REPORT = os.path.join(REPO, "artifacts", "STATIC_audit.json")

#: The audited runtime matrix: every kernel-variant family the sessions
#: can route to (fused / metered-fused / staged oracle / bit-packed /
#: co-resident), each with one predict shape so the audit stays cheap.
AUDIT_SPECS = (
    ("fused", dict(backend="pallas", metering="fused",
                   batch_sizes=(8,), capacity=8)),
    ("staged", dict(backend="pallas", metering="staged",
                    batch_sizes=(8,), capacity=8)),
    ("packed", dict(backend="pallas-packed", packing="2bit",
                    batch_sizes=(8,))),
    ("metered-backend", dict(backend="pallas-metered", metering="fused",
                             batch_sizes=(8,))),
    ("oracle", dict(backend="xla", batch_sizes=(8,))),
)


def run_lint(root: str) -> list[str]:
    """Layer 2 over ``root`` -> list of failure strings."""
    from repro.analysis import lint
    findings = lint.lint_tree(root)
    active = [f for f in findings if not f.waived]
    waived = [f for f in findings if f.waived]
    for f in waived:
        print(f"  waived: {f}")
    for f in active:
        # GitHub annotation on the offending line.
        print(f"::error file={f.path},line={f.line}::{f.rule}: {f.message}")
    print(f"lint: {len(active)} finding(s), {len(waived)} waived "
          f"({sum(1 for _ in lint.iter_target_files(root))} files)")
    return [str(f) for f in active]


def _reference_system():
    """The deterministic small system every audit run compiles — fixed
    seeds so executable fingerprints are reproducible run to run."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import CoTMConfig
    from repro.core.cotm import CoTMParams
    from repro.impact import IMPACTConfig, build_system

    K, n, m, n_states = 64, 32, 4, 64
    cfg = CoTMConfig(n_literals=K, n_clauses=n, n_classes=m,
                     n_states=n_states)
    rng = np.random.default_rng(0)
    ta = np.where(rng.random((K, n)) < 0.1, n_states + 1, n_states)
    w = rng.integers(-20, 20, (m, n))
    params = CoTMParams(ta_state=jnp.asarray(ta, jnp.int32),
                        weights=jnp.asarray(w, jnp.int32))
    return build_system(params, cfg, jax.random.key(0),
                        IMPACTConfig(variability=False, finetune=False))


def run_audit(vmem_budget: int | None,
              update_baselines: bool) -> tuple[list[str], dict]:
    """Layer 1 -> (failures, report-JSON dict)."""
    from repro.impact import RuntimeSpec

    baselines = None
    if os.path.exists(BASELINES) and not update_baselines:
        with open(BASELINES) as f:
            baselines = json.load(f)
    elif not update_baselines:
        print(f"  note: no committed baselines at {BASELINES} — "
              f"run --update-baselines to record them")

    system = _reference_system()
    failures: list[str] = []
    report: dict = {"sessions": {}}
    new_baselines: dict = {}
    for tag, kw in AUDIT_SPECS:
        if vmem_budget is not None:
            kw = dict(kw, vmem_budget_bytes=vmem_budget)
        session = system.compile(RuntimeSpec(**kw))
        # The online-training feedback executable rides every
        # non-co-resident session: audit it alongside the serving
        # entries (batch 8 = the doubled 2B feedback row count).
        session.warm(8, "ta_feedback")
        base = (baselines or {}).get(tag)
        rep = session.audit(baselines=base)
        report["sessions"][tag] = rep.to_json()
        new_baselines[tag] = rep.fingerprints
        n_err = sum(f.severity == "error" for f in rep.findings)
        n_warn = len(rep.findings) - n_err
        print(f"  audit[{tag}]: {len(rep.fingerprints)} executable(s), "
              f"{n_err} error(s), {n_warn} warning(s), "
              f"vmem max {max(rep.vmem_bytes.values(), default=0)} B "
              f"/ budget {rep.vmem_budget_bytes} B")
        for f in rep.findings:
            print(f"    {f.severity}: {f}")
            if f.severity == "error":
                failures.append(f"audit[{tag}]: {f}")
    if update_baselines:
        os.makedirs(os.path.dirname(BASELINES), exist_ok=True)
        with open(BASELINES, "w") as f:
            json.dump(new_baselines, f, indent=1, sort_keys=True)
        print(f"  wrote {BASELINES}")
    return failures, report


def run_hlo(path: str) -> list[str]:
    """Audit a raw StableHLO text dump (precision + host-IO scans)."""
    from repro.analysis import ir_audit
    with open(path) as f:
        text = f.read()
    findings = ir_audit.audit_ir_text(text, entry=os.path.basename(path))
    for f in findings:
        print(f"  {f.severity}: {f}")
    print(f"hlo audit: {len(findings)} finding(s) in {path}")
    return [str(f) for f in findings if f.severity == "error"]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lint-only", action="store_true",
                    help="run only the stdlib contract lint (no jax)")
    ap.add_argument("--root", default=REPO,
                    help="repo root to lint (default: this repo)")
    ap.add_argument("--hlo", default=None,
                    help="audit a raw StableHLO text file instead of "
                         "compiling sessions")
    ap.add_argument("--vmem-budget", type=int, default=None,
                    help="override RuntimeSpec.vmem_budget_bytes for the "
                         "audited sessions")
    ap.add_argument("--update-baselines", action="store_true",
                    help="re-record benchmarks/baselines/"
                         "IR_fingerprints.json from this run")
    ap.add_argument("--report", default=REPORT,
                    help=f"audit report JSON path (default {REPORT})")
    args = ap.parse_args(argv)

    if args.hlo:
        failures = run_hlo(args.hlo)
    else:
        failures = run_lint(args.root)
        if not args.lint_only:
            audit_failures, report = run_audit(args.vmem_budget,
                                               args.update_baselines)
            failures += audit_failures
            os.makedirs(os.path.dirname(args.report), exist_ok=True)
            with open(args.report, "w") as f:
                json.dump(report, f, indent=1, sort_keys=True)
            print(f"  wrote {args.report}")

    if failures:
        print("\nSTATIC GATE FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("static gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
