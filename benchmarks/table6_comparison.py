"""Table 6 reproduction: accelerator comparison (TOPS/W, TOPS/mm^2,
energy ratios vs published IMC accelerators).

The competitor numbers are fixed constants from the paper's Table 6; ours
come from the trained system's energy report.  Paper's headline ratios:
2.23x vs ReRAM-CNN [24], 2.46x vs NOR-Flash neuromorphic [25], 0.61x vs
SRAM [26], 2.06x vs PCM [27].
"""
from __future__ import annotations

import jax

from .common import emit, trained_mnist_cotm

from repro.impact import build_system

COMPETITORS = {   # name: (TOPS/W, TOPS/mm2, accuracy %, tech)
    "ref24_ReRAM_CNN": (11.014, 1.164, 96.1, "ReRAM 1T1R"),
    "ref25_NORFlash_neuromorphic": (10.0, None, 94.7, "NOR-Flash"),
    "ref26_SRAM_BCNN": (40.3, None, 98.3, "65nm SRAM"),
    "ref27_PCM_DNN": (11.9, None, 93.7, "PCM 1T1R"),
    "ref28_ReRAM_CIM": (51.4, 0.284, 91.9, "22nm ReRAM"),
    "ref29_STTMRAM": (35.2, None, 96.2, "28nm STT-MRAM"),
    "ref31_ReRAM_edge": (27.2, 0.056, 92.1, "28nm ReRAM"),
}

PAPER_OURS = {"tops_per_w": 24.56, "tops_per_mm2": 0.17}


def main() -> None:
    cfg, params, lits, labels, sw_acc = trained_mnist_cotm()
    system = build_system(params, cfg, jax.random.key(3))
    _, report = system.infer_with_report(lits[:512])
    tops_w = report.tops_per_w
    tops_mm2 = report.tops_per_mm2     # system reports carry the area
    emit("table6/ours_tops_per_w", 0.0,
         f"ours={tops_w:.2f};paper={PAPER_OURS['tops_per_w']}")
    emit("table6/ours_tops_per_mm2", 0.0,
         f"ours={tops_mm2:.3f};paper={PAPER_OURS['tops_per_mm2']}")
    for name, (tw, tmm, acc, tech) in COMPETITORS.items():
        ratio = tops_w / tw
        derived = f"ratio_tops_w={ratio:.2f};their_tops_w={tw};tech={tech}"
        if tmm:
            derived += f";ratio_tops_mm2={tops_mm2 / tmm:.2f}"
        emit(f"table6/vs_{name}", 0.0, derived)
    # Paper's headline claims for reference
    emit("table6/paper_claims", 0.0,
         "2.23x_vs_ref24;2.46x_vs_ref25;0.61x_vs_ref26;2.06x_vs_ref27")


if __name__ == "__main__":
    main()
