"""Fig. 13 reproduction: accuracy & mapping cost vs pulse budget.

Sweeps the max pulse budget of the pre-tune / fine-tune phases and
records (a) classification accuracy on the crossbar system, (b) the
"cost" = fraction of weight cells outside their target conductance band
— the paper reaches 95.6% accuracy after 3 pre-tune pulses, 96.2% at 10,
and 96.31% after fine-tuning with <=6 extra pulses.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, trained_mnist_cotm

from repro.core import to_unipolar
from repro.impact.tiles import encode_class_tile, encode_clause_tile, weight_targets
from repro.impact.yflash import G_RANGE_HI, G_RANGE_LO


def main() -> None:
    cfg, params, lits, labels, sw_acc = trained_mnist_cotm()
    from repro.core import include_mask
    include = include_mask(params.ta_state, cfg.n_states)
    clause_tile, _ = encode_clause_tile(include, jax.random.key(0))
    w_uni, _ = to_unipolar(params.weights)
    w_t = w_uni.T
    w_max = int(jnp.max(w_uni))
    target = np.asarray(weight_targets(w_t, w_max))
    seg = (G_RANGE_HI - G_RANGE_LO) / max(w_max, 1)

    def accuracy(class_g):
        clauses = clause_tile.clauses(lits[:512])
        from repro.impact.yflash import read_current
        scores = clauses.astype(jnp.float32) @ read_current(
            jnp.asarray(class_g))
        return float((jnp.argmax(scores, -1) == labels[:512]).mean())

    for budget in (1, 2, 3, 5, 10):
        t0 = time.time()
        tile, stats = encode_class_tile(
            w_t, jax.random.key(1), finetune=False, max_pulses=budget)
        us = (time.time() - t0) * 1e6
        acc = accuracy(tile.g)
        cost = float((np.abs(np.asarray(tile.g) - target)
                      > 20 * seg).mean())
        emit(f"fig13/pretune_budget_{budget}", us,
             f"acc={acc:.3f};cost={cost:.3f};paper_acc_3p=0.956;"
             "paper_acc_10p=0.962")

    t0 = time.time()
    tile, stats = encode_class_tile(w_t, jax.random.key(1), finetune=True,
                                    max_pulses=96)
    us = (time.time() - t0) * 1e6
    acc = accuracy(tile.g)
    cost = float((np.abs(np.asarray(tile.g) - target) > 5 * seg).mean())
    fine_pulses = float((stats["finetune_prog"]
                         + stats["finetune_erase"]).mean())
    emit("fig13/finetuned", us,
         f"acc={acc:.3f};cost_5seg={cost:.3f};"
         f"mean_finetune_pulses={fine_pulses:.1f};paper_acc=0.9631;"
         f"sw_acc={sw_acc:.3f}")

    # Beyond paper: closed-loop width-selecting controller — higher
    # accuracy at ~2.4x fewer pulses (=> ~2.4x less programming energy).
    t0 = time.time()
    tile, stats = encode_class_tile(w_t, jax.random.key(1), adaptive=True,
                                    max_pulses=96)
    us = (time.time() - t0) * 1e6
    acc = accuracy(tile.g)
    pulses = float((stats["pretune_prog"] + stats["pretune_erase"]).mean())
    err = float(np.abs(np.asarray(tile.g) - target).mean() / seg)
    emit("fig13/adaptive_controller_beyond_paper", us,
         f"acc={acc:.3f};mean_pulses={pulses:.1f};"
         f"mean_err_segments={err:.2f};sw_acc={sw_acc:.3f}")


if __name__ == "__main__":
    main()
