"""Table 5 reproduction: seven datasets at the paper's published
(classes, clauses, literals) dimensions, trained + mapped to crossbars.

Real datasets are unavailable offline; synthetic prototype stand-ins are
generated at the exact published dimensionality (DESIGN.md data note).
The claim validated per dataset: (a) CoTM trains to high accuracy at the
paper's sizing, (b) the crossbar mapping preserves that accuracy.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit

from repro.core import CoTMConfig, predict, train_epochs
from repro.data.synthetic import TABLE5, table5_dataset
from repro.impact import build_system

PAPER_ACC = {
    "iris": 96.67, "cifar2": 81.0, "kws6": 80.3, "fashion_mnist": 84.16,
    "emg": 87.0, "gesture_phase": 89.0, "human_activity": 84.0,
}


def run_dataset(name: str, n_train: int = 2000, epochs: int = 6):
    x, y, spec = table5_dataset(name, n_train, seed=0)
    xt, yt, _ = table5_dataset(name, 400, seed=7)
    lit = jnp.asarray(np.concatenate([x, 1 - x], -1).astype(bool))
    lit_t = jnp.asarray(np.concatenate([xt, 1 - xt], -1).astype(bool))
    cfg = CoTMConfig(n_literals=spec["literals"],
                     n_clauses=spec["clauses"],
                     n_classes=spec["classes"],
                     n_states=128, threshold=32, specificity=5.0)
    t0 = time.time()
    params = train_epochs(cfg.init(jax.random.key(0)), lit,
                          jnp.asarray(y), jax.random.key(1), cfg,
                          epochs=epochs, batch_size=50)
    train_us = (time.time() - t0) * 1e6
    sw = float((predict(params, lit_t, cfg) == jnp.asarray(yt)).mean())
    system = build_system(params, cfg, jax.random.key(2))
    hw = float((system.predict(lit_t) == jnp.asarray(yt)).mean())
    return train_us, sw, hw, spec


def main() -> None:
    for name in TABLE5:
        us, sw, hw, spec = run_dataset(name)
        emit(f"table5/{name}", us,
             f"sw_acc={sw:.3f};hw_acc={hw:.3f};"
             f"paper={PAPER_ACC[name] / 100:.3f};"
             f"dims={spec['classes']}c/{spec['clauses']}cl/"
             f"{spec['literals']}L;note=synthetic-standin")


if __name__ == "__main__":
    main()
