"""Figs. 7-8 reproduction: C2C and D2D variability statistics.

Paper anchors: C2C over 400 cycles — LCS mean 0.925 nS (SD ~4.8%), HCS
mean 1.01 uS (SD ~9.7%); D2D over ~100 devices — LCS ~0.9 nS (SD 0.04 nS),
HCS ~1.04 uS (SD 27.6 nS); programming pulse counts 23-61, erase 15-51.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit

from repro.impact.yflash import DeviceVariation, erase_pulse, program_pulse, pulse_until


def c2c(cycles: int = 400):
    """One device, many program/erase cycles (tolerance-band controller:
    pulse until within the paper's LCS/HCS bands, like their setup)."""
    key = jax.random.key(0)
    var = DeviceVariation.sample(jax.random.key(1), ())
    g = jnp.asarray(2.5e-6)
    lcs, hcs = [], []
    for c in range(cycles):
        key, kp = jax.random.split(key)
        g, _, _ = pulse_until(g[None] if g.ndim == 0 else g,
                              target_lo=jnp.zeros(1),
                              target_hi=jnp.full(1, 1e-9),
                              width_prog=200e-6, width_erase=100e-6,
                              var=DeviceVariation.none((1,)), key=kp,
                              max_pulses=128)
        lcs.append(float(g[0]))
        key, ke = jax.random.split(key)
        g, _, _ = pulse_until(g, target_lo=jnp.full(1, 1e-6),
                              target_hi=jnp.full(1, jnp.inf),
                              width_prog=200e-6, width_erase=100e-6,
                              var=DeviceVariation.none((1,)), key=ke,
                              max_pulses=128)
        hcs.append(float(g[0]))
    return np.asarray(lcs), np.asarray(hcs)


def d2d(n_devices: int = 100):
    key = jax.random.key(2)
    var = DeviceVariation.sample(jax.random.key(3), (n_devices,))
    g0 = 2.5e-6 * jnp.ones(n_devices)
    g_lcs, n_prog, _ = pulse_until(
        g0, target_lo=jnp.zeros(n_devices),
        target_hi=jnp.full(n_devices, 1e-9),
        width_prog=200e-6, width_erase=100e-6, var=var, key=key,
        max_pulses=256)
    g_hcs, _, n_er = pulse_until(
        g_lcs, target_lo=jnp.full(n_devices, 1e-6),
        target_hi=jnp.full(n_devices, jnp.inf),
        width_prog=200e-6, width_erase=100e-6, var=var,
        key=jax.random.key(4), max_pulses=256)
    return (np.asarray(g_lcs), np.asarray(n_prog), np.asarray(g_hcs),
            np.asarray(n_er))


def main() -> None:
    t0 = time.time()
    lcs, hcs = c2c(60)    # reduced cycle count for bench runtime
    us = (time.time() - t0) * 1e6
    emit("fig7/c2c_lcs", us,
         f"mean_nS={lcs.mean() * 1e9:.3f};sd_pct={lcs.std() / lcs.mean() * 100:.1f};"
         "paper_mean=0.925nS;paper_sd=4.8pct")
    emit("fig7/c2c_hcs", us,
         f"mean_uS={hcs.mean() * 1e6:.3f};sd_pct={hcs.std() / hcs.mean() * 100:.1f};"
         "paper_mean=1.01uS;paper_sd=9.74pct")

    t0 = time.time()
    g_lcs, n_prog, g_hcs, n_er = d2d()
    us = (time.time() - t0) * 1e6
    emit("fig8/d2d_lcs", us,
         f"mean_nS={g_lcs.mean() * 1e9:.3f};sd_nS={g_lcs.std() * 1e9:.3f};"
         "paper_mean=0.9nS;paper_sd=0.04nS")
    emit("fig8/d2d_hcs", us,
         f"mean_uS={g_hcs.mean() * 1e6:.3f};sd_nS={g_hcs.std() * 1e9:.1f};"
         "paper_mean=1.04uS;paper_sd=27.6nS")
    emit("fig8/d2d_prog_pulses", us,
         f"min={n_prog.min()};max={n_prog.max()};paper_range=23-61")
    emit("fig8/d2d_erase_pulses", us,
         f"min={n_er.min()};max={n_er.max()};paper_range=15-51")


if __name__ == "__main__":
    main()
