"""Shared benchmark utilities: trained-model cache + CSV emission."""
from __future__ import annotations

import pathlib
import pickle
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import CoTMConfig, booleanize, predict, train_epochs  # noqa: E402
from repro.data.synthetic import digits  # noqa: E402

ARTIFACTS = pathlib.Path(__file__).resolve().parents[1] / "artifacts"
ARTIFACTS.mkdir(exist_ok=True)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")


def trained_mnist_cotm(n_clauses: int = 500, epochs: int = 10,
                       n_train: int = 8000, tag: str = "bench"):
    """Train (or load cached) CoTM at the paper's MNIST dims.

    Returns (cfg, params, test literals, test labels, software accuracy).
    """
    cache = ARTIFACTS / f"cotm_{tag}_{n_clauses}c_{epochs}e.pkl"
    cfg = CoTMConfig(n_literals=1568, n_clauses=n_clauses, n_classes=10,
                     n_states=128, threshold=96, specificity=8.0)
    x_te, y_te = digits(1000, seed=2, jitter=2)
    lit_te = booleanize(jnp.asarray(x_te))
    if cache.exists():
        with open(cache, "rb") as f:
            blob = pickle.load(f)
        params = jax.tree.map(jnp.asarray, blob["params"])
    else:
        x_tr, y_tr = digits(n_train, seed=1, jitter=2)
        lit_tr = booleanize(jnp.asarray(x_tr))
        params = cfg.init(jax.random.key(0))
        t0 = time.time()
        params = train_epochs(params, lit_tr, jnp.asarray(y_tr),
                              jax.random.key(1), cfg, epochs=epochs,
                              batch_size=32)
        print(f"# trained CoTM {n_clauses}c x{epochs}ep in "
              f"{time.time() - t0:.0f}s", file=sys.stderr)
        with open(cache, "wb") as f:
            pickle.dump({"params": jax.tree.map(np.asarray, params)}, f)
    acc = float((predict(params, lit_te, cfg) == jnp.asarray(y_te)).mean())
    return cfg, params, lit_te, jnp.asarray(y_te), acc
